//! Extension example: spectra of **strided** convolutions — the paper's
//! crystal-torus framework with a genuine sublattice (`|det Z| = s²`,
//! §III), which the paper flags as the generalization its method allows.
//!
//! Analyzes a stride-2 encoder stack: each downsampling layer's symbol at a
//! coarse frequency is the `c_out × 4·c_in` concatenation of the four
//! aliased fine-frequency symbols. Reports per-layer extremes and shows why
//! strided layers cannot be orthogonal unless `c_out ≥ 4·c_in` (frequency
//! folding makes the blocks wide).
//!
//! ```sh
//! cargo run --release --example strided_encoder
//! ```

use conv_svd_lfa::conv::ConvKernel;
use conv_svd_lfa::lfa::{self, stride};
use conv_svd_lfa::numeric::Pcg64;
use conv_svd_lfa::report::{commas, Table};

fn main() {
    let mut rng = Pcg64::seeded(42);
    // Encoder: 3 stride-2 stages, channel-doubling (the usual CNN shape).
    let stages = [
        ("enc1", 3usize, 16usize, 32usize),
        ("enc2", 16, 32, 16),
        ("enc3", 32, 64, 8),
    ];

    println!("stride-2 encoder spectra (symbols are c_out x 4·c_in blocks)\n");
    let mut table = Table::new([
        "layer", "fine grid", "c_in→c_out", "#σ", "σ_max", "σ_min", "cond",
        "orthogonal possible?",
    ]);
    for (name, c_in, c_out, n) in stages {
        let k = ConvKernel::random_he(c_out, c_in, 3, 3, &mut rng);
        let spec = stride::strided_singular_values(&k, n, n, 2);
        // A strided layer is an isometry only if its (wide) blocks have
        // orthonormal rows: needs c_out ≥ 4·c_in ... which never holds in
        // channel-doubling encoders (c_out = 2·c_in < 4·c_in).
        let possible = c_out >= 4 * c_in;
        table.row([
            name.to_string(),
            format!("{n}x{n}"),
            format!("{c_in}→{c_out}"),
            commas(spec.num_values() as u128),
            format!("{:.4}", spec.sigma_max()),
            format!("{:.4}", spec.sigma_min()),
            format!("{:.1}", spec.condition_number()),
            if possible { "yes (c_out ≥ 4c_in)" } else { "no (c_out < 4c_in)" }.into(),
        ]);
    }
    print!("{}", table.render());

    // Cross-check one layer against plain (stride-1) LFA at the same grid:
    // striding folds energy — Σσ² drops by exactly s².
    let k = ConvKernel::random_he(16, 16, 3, 3, &mut rng);
    let n = 16;
    let plain = lfa::singular_values(&k, n, n, Default::default());
    let strided = stride::strided_singular_values(&k, n, n, 2);
    let e_plain: f64 = plain.values.iter().map(|v| v * v).sum();
    let e_strided: f64 = strided.values.iter().map(|v| v * v).sum();
    println!(
        "\nenergy folding check: Σσ²(stride 1) / Σσ²(stride 2) = {:.4} (theory: s² = 4)",
        e_plain / e_strided
    );
    assert!((e_plain / e_strided - 4.0).abs() < 1e-9);

    // Downsampling layers alias: σ_max(strided) can exceed the fine-grid
    // per-frequency norms (concatenation inequality):
    println!(
        "σ_max fine = {:.4} vs σ_max strided = {:.4} (≤ 1/s·√(s²)·σ_max,fine = σ_max,fine)",
        plain.sigma_max(),
        strided.sigma_max()
    );
    assert!(strided.sigma_max() <= plain.sigma_max() * (1.0 + 1e-12));

    println!("\nstrided_encoder OK");
}
