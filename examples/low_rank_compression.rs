//! Model compression by per-frequency low-rank truncation (§II-c:
//! Jaderberg / Zhang / Denton line of work, done exactly via the LFA SVD).
//!
//! Sweeps the rank of every conv layer of a VGG-style model and prints the
//! storage-vs-accuracy trade-off curve (Eckart–Young-optimal per rank).
//!
//! ```sh
//! cargo run --release --example low_rank_compression
//! ```

use conv_svd_lfa::lfa::LfaOptions;
use conv_svd_lfa::model::zoo;
use conv_svd_lfa::report::Table;
use conv_svd_lfa::spectral::lowrank;

fn main() {
    let model = zoo::vgg_small();
    println!("rank sweep over `{}` ({} layers)\n", model.name, model.layers.len());

    let mut table = Table::new(["layer", "c_in→c_out", "rank", "rel. error", "storage ratio"]);
    let mut chosen = Vec::new();
    for layer in &model.layers {
        let kernel = layer.materialize(model.seed);
        let sweep = lowrank::rank_sweep(&kernel, layer.height, layer.width, LfaOptions::default());
        // Pick the smallest rank with ≤ 5% relative error — a typical
        // compression operating point.
        let pick = sweep.iter().find(|(_, err, _)| *err <= 0.05).unwrap_or(sweep.last().unwrap());
        for &(r, err, storage) in &sweep {
            let marker = if r == pick.0 { "*" } else { "" };
            table.row([
                format!("{}{marker}", layer.name),
                format!("{}→{}", layer.c_in, layer.c_out),
                r.to_string(),
                format!("{err:.4}"),
                format!("{storage:.3}"),
            ]);
        }
        chosen.push((layer.name.clone(), pick.0, pick.1, pick.2));
    }
    print!("{}", table.render());

    println!("\nchosen operating points (≤5% relative error):");
    let mut total_ratio = 0.0;
    for (name, rank, err, storage) in &chosen {
        println!("  {name:<10} rank {rank:>2}  err {err:.3}  storage {storage:.3}");
        total_ratio += storage;
    }
    let mean = total_ratio / chosen.len() as f64;
    println!("mean storage ratio at the operating points: {mean:.3} (1.0 = dense symbols)");
    // Random He-init layers are near-isotropic, so aggressive compression
    // needs most of the spectrum; trained CNNs (low-rank-biased) compress
    // far better — this example validates the machinery + the trade-off
    // curve shape, not a specific compression factor.
    assert!(chosen.iter().all(|(_, _, err, _)| *err <= 0.05 + 1e-12));
    println!("\nlow_rank_compression OK");
}
