//! END-TO-END DRIVER for [`ModelPlan`]: a whole CNN planned once from a
//! `[[layer]]` TOML config and driven through every whole-model entry
//! point —
//!
//!   ModelPlan::build      every layer planned once; equal-shape layers
//!                         batched into groups sharing one workspace pool
//!   ModelPlan::execute    one batched sweep → per-layer + aggregate report
//!   ModelPlan::clip_all   plan-reuse spectral clipping (training-loop shape)
//!   ModelPlan::lowrank_all whole-model low-rank compression
//!
//! ```sh
//! cargo run --release --example model_audit [path/to/model.toml]
//! ```

use conv_svd_lfa::engine::ModelPlan;
use conv_svd_lfa::lfa::{self, LfaOptions};
use conv_svd_lfa::model::ModelConfig;
use conv_svd_lfa::report::{commas, secs, Table};

/// Default model when no config path is given: a small stack with an
/// equal-shape pair (conv2/conv3 batch into one group) and a strided
/// downsampling layer.
const DEFAULT_MODEL: &str = r#"
name = "demo-stack"
seed = 2025

[[layer]]
name   = "stem"
c_in   = 3
c_out  = 16
height = 16
width  = 16

[[layer]]
name   = "conv2"
c_in   = 16
c_out  = 16
height = 16
width  = 16

[[layer]]
name   = "conv3"
c_in   = 16
c_out  = 16
height = 16
width  = 16

[[layer]]
name   = "down"
c_in   = 16
c_out  = 32
height = 16
width  = 16
stride = 2
"#;

fn main() -> conv_svd_lfa::Result<()> {
    let model = match std::env::args().nth(1) {
        Some(path) => ModelConfig::load(std::path::Path::new(&path))?,
        None => ModelConfig::parse(DEFAULT_MODEL)?,
    };

    let t0 = std::time::Instant::now();
    let plan = ModelPlan::build(&model, LfaOptions::default())?;
    let t_plan = t0.elapsed();
    println!(
        "model `{}`: {} layers planned once in {} — {} equal-shape group(s), {} worker(s)",
        plan.name(),
        plan.layer_count(),
        secs(t_plan),
        plan.group_count(),
        plan.effective_threads()
    );
    for g in 0..plan.group_count() {
        let members = plan.group_members(g);
        let (rows, cols) = plan.layer_plan(members[0]).block_shape();
        let names: Vec<&str> = members.iter().map(|&i| plan.layer_name(i)).collect();
        println!("  group {g} ({rows}x{cols} blocks, one shared pool): {}", names.join(", "));
    }

    // One batched sweep over the whole model.
    let t1 = std::time::Instant::now();
    let spectra = plan.execute();
    let t_exec = t1.elapsed();

    let mut table = Table::new([
        "layer", "grid", "stride", "c", "#σ", "σ_max", "σ_min", "fro-defect",
    ]);
    for (i, layer) in spectra.layers.iter().enumerate() {
        let lp = plan.layer_plan(i);
        let k = lp.kernel();
        let defect = lfa::svd::frobenius_check_strided(
            k,
            lp.fine_rows(),
            lp.fine_cols(),
            lp.stride(),
            &layer.spectrum,
        );
        // Hard E2E check: every spectrum verified against the Frobenius
        // identity, strided layers included.
        assert!(defect < 1e-10, "{}: defect {defect}", layer.name);
        table.row([
            layer.name.clone(),
            format!("{}x{}", lp.fine_rows(), lp.fine_cols()),
            lp.stride().to_string(),
            format!("{}→{}", k.c_in, k.c_out),
            commas(layer.spectrum.num_values() as u128),
            format!("{:.4}", layer.spectrum.sigma_max()),
            format!("{:.4}", layer.spectrum.sigma_min()),
            format!("{defect:.1e}"),
        ]);
    }
    print!("{}", table.render());
    println!(
        "sweep {}: {} singular values, global σ_max {:.4}, Lipschitz composition bound {:.4}\n",
        secs(t_exec),
        commas(spectra.num_values() as u128),
        spectra.sigma_max(),
        spectra.lipschitz_upper_bound()
    );

    // Whole-model clipping: the training-loop shape (plan held, clip every
    // step). The kernel projection is defined for dense layers, so clip the
    // stride-1 sub-stack.
    let dense = ModelConfig {
        name: format!("{}-dense", model.name),
        seed: model.seed,
        layers: model.layers.iter().filter(|l| l.stride == 1).cloned().collect(),
    };
    let dense_plan = ModelPlan::build(&dense, LfaOptions::default())?;
    let cap = spectra.sigma_max() * 0.5;
    let clipped = dense_plan.clip_all(cap)?;
    let total_clipped: usize = clipped.iter().map(|c| c.clipped_count).sum();
    println!(
        "clip_all at {cap:.4}: {total_clipped} singular values capped across {} dense layers",
        clipped.len()
    );
    for (c, layer) in clipped.iter().zip(&dense.layers) {
        let after = lfa::svd::svd_full_from_grid(&c.grid);
        assert!(after.sigma.sigma_max() <= cap + 1e-9, "{} not capped", layer.name);
    }

    // Whole-model compression: rank-r truncation with the closed
    // Eckart–Young error.
    let rank = 4;
    let low = dense_plan.lowrank_all(rank);
    let mut ctable = Table::new(["layer", "rank", "rel-error", "storage"]);
    for (l, layer) in low.iter().zip(&dense.layers) {
        ctable.row([
            layer.name.clone(),
            l.rank.to_string(),
            format!("{:.2e}", l.rel_error),
            format!("{:.2}x", l.storage_ratio),
        ]);
    }
    print!("{}", ctable.render());

    println!("\nmodel_audit OK");
    Ok(())
}
