//! Exact pseudo-inversion of a convolution via the LFA SVD — the
//! pseudo-invertible-network application (§II-c, Bolluyt & Comaniciu 2024):
//! instead of their approximate layer restructuring, `A⁺ = V Σ⁺ Uᴴ` per
//! frequency gives the exact Moore–Penrose inverse.
//!
//! Demonstrated as image deconvolution: blur a synthetic image with a
//! random conv, recover it with `A⁺`, report PSNR; plus the channel-lifting
//! round-trip (`A⁺A = I` for tall operators).
//!
//! ```sh
//! cargo run --release --example pseudo_inverse
//! ```

use conv_svd_lfa::conv::{Boundary, ConvKernel, ConvOp};
use conv_svd_lfa::lfa::LfaOptions;
use conv_svd_lfa::numeric::Pcg64;
use conv_svd_lfa::spectral::{pinv, FreqOperator};

fn main() {
    // --- deconvolution: square full-rank 3-channel "image" operator ---
    let (n, c) = (32, 3);
    let mut rng = Pcg64::seeded(11);
    let blur = ConvKernel::random_he(c, c, 3, 3, &mut rng);

    // Synthetic image: smooth gradient + checker pattern per channel.
    let mut image = vec![0.0f64; n * n * c];
    for y in 0..n {
        for x in 0..n {
            for ch in 0..c {
                let v = (y as f64 / n as f64)
                    + 0.3 * (((x / 4 + y / 4) % 2) as f64)
                    + 0.1 * ch as f64;
                image[(y * n + x) * c + ch] = v;
            }
        }
    }

    let op = ConvOp::new(&blur, n, n, Boundary::Periodic);
    let blurred = op.forward(&image);

    let inv = pinv::pseudo_inverse(&blur, n, n, 1e-10, LfaOptions::default());
    println!(
        "pseudo-inverse built: {} singular values zeroed at rcond {:.0e}",
        inv.null_count, inv.rcond
    );
    let recovered = FreqOperator::new(&inv.grid).apply(&blurred);

    let mse: f64 = image
        .iter()
        .zip(&recovered)
        .map(|(a, b)| (a - b) * (a - b))
        .sum::<f64>()
        / image.len() as f64;
    let peak = image.iter().cloned().fold(0.0, f64::max);
    let psnr = 10.0 * (peak * peak / mse).log10();
    println!("deconvolution PSNR: {psnr:.1} dB (exact inverse: limited only by FP)");
    assert!(psnr > 100.0, "exact pseudo-inverse should be FP-exact; got {psnr} dB");

    // --- channel lifting: tall operator (3 → 8 channels), A⁺A = I ---
    let lift = ConvKernel::random_he(8, 3, 3, 3, &mut rng);
    let lop = ConvOp::new(&lift, n, n, Boundary::Periodic);
    let lifted = lop.forward(&image);
    let lift_inv = pinv::pseudo_inverse(&lift, n, n, 1e-10, LfaOptions::default());
    let back = FreqOperator::new(&lift_inv.grid).apply(&lifted);
    let worst = image.iter().zip(&back).map(|(a, b)| (a - b).abs()).fold(0.0, f64::max);
    println!("channel-lift round-trip (3→8→3): max |Δ| = {worst:.2e}");
    assert!(worst < 1e-8);

    // --- rank-deficient case: rcond actually guards the inversion ---
    let mut degenerate = ConvKernel::random_he(2, 2, 3, 3, &mut rng);
    for i in 0..2 {
        for r in 0..3 {
            for cc in 0..3 {
                let v = degenerate.get(0, i, r, cc);
                degenerate.set(1, i, r, cc, v); // duplicate output channel
            }
        }
    }
    let dinv = pinv::pseudo_inverse(&degenerate, 8, 8, 1e-8, LfaOptions::default());
    println!(
        "degenerate operator: {} of {} values treated as null (pinv stays bounded)",
        dinv.null_count,
        8 * 8 * 2
    );
    assert_eq!(dinv.null_count, 64, "one null direction per frequency");

    println!("\npseudo_inverse OK");
}
