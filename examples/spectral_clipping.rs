//! Spectral-norm regularization by singular-value clipping (§II-c:
//! Yoshida–Miyato / Sedghi et al. / Parseval networks use-case).
//!
//! Clips the operator norm of a conv layer at a target Lipschitz constant,
//! verifies the clipped operator's gain on data, and projects the clipped
//! operator back onto a 3×3 kernel the way training pipelines do.
//!
//! ```sh
//! cargo run --release --example spectral_clipping
//! ```

use conv_svd_lfa::conv::ConvKernel;
use conv_svd_lfa::lfa::{self, LfaOptions};
use conv_svd_lfa::numeric::Pcg64;
use conv_svd_lfa::report::Table;
use conv_svd_lfa::spectral::{clip, FreqOperator};

fn main() {
    let (n, c) = (32, 16);
    let cap = 1.0; // enforce a 1-Lipschitz layer
    let mut rng = Pcg64::seeded(7);
    let kernel = ConvKernel::random_he(c, c, 3, 3, &mut rng);

    let before = lfa::singular_values(&kernel, n, n, LfaOptions::default());
    println!(
        "layer {c}x{c}x3x3 on {n}x{n}: σ_max = {:.4} (target cap {cap})",
        before.sigma_max()
    );

    let res = clip::clip_spectral_norm(&kernel, n, n, cap, LfaOptions::default());
    println!(
        "clipped {} of {} singular values at σ = {cap}",
        res.clipped_count,
        before.num_values()
    );

    // 1. The exact clipped operator obeys the cap on real data.
    let fop = FreqOperator::new(&res.grid);
    let mut worst_gain = 0.0f64;
    for t in 0..10 {
        let mut trng = Pcg64::seeded(100 + t);
        let f = trng.normal_vec(n * n * c);
        let g = fop.apply(&f);
        let gain = norm(&g) / norm(&f);
        worst_gain = worst_gain.max(gain);
    }
    println!("exact clipped operator: worst observed gain = {worst_gain:.6} (≤ {cap})");
    assert!(worst_gain <= cap * (1.0 + 1e-9));

    // 2. The 3×3-projected kernel (what you'd put back into the network).
    let after = lfa::singular_values(&res.projected_kernel, n, n, LfaOptions::default());
    let mut table = Table::new(["quantity", "before", "exact clip", "3x3 projection"]);
    table.row([
        "σ_max".to_string(),
        format!("{:.4}", before.sigma_max()),
        format!("{cap:.4}"),
        format!("{:.4}", after.sigma_max()),
    ]);
    table.row([
        "‖W‖_F".to_string(),
        format!("{:.4}", kernel.frobenius_norm()),
        "-".to_string(),
        format!("{:.4}", res.projected_kernel.frobenius_norm()),
    ]);
    print!("{}", table.render());
    println!(
        "projection residual above cap: {:.1}% (support constraint re-adds energy; \
         iterate clip↔project to tighten, as in Sedghi et al. §4)",
        100.0 * (after.sigma_max() - cap).max(0.0) / cap
    );

    // 3. Iterated clip→project converges toward the cap.
    let mut k = kernel.clone();
    let mut sigmas = Vec::new();
    for _ in 0..15 {
        let r = clip::clip_spectral_norm(&k, n, n, cap, LfaOptions::default());
        k = r.projected_kernel;
        sigmas.push(lfa::singular_values(&k, n, n, LfaOptions::default()).sigma_max());
    }
    println!("iterated clip→project σ_max trajectory: {:?}",
        sigmas.iter().map(|v| (v * 1e4).round() / 1e4).collect::<Vec<_>>());
    assert!(sigmas.windows(2).all(|w| w[1] <= w[0] + 1e-12), "monotone decrease");
    assert!(
        *sigmas.last().unwrap() < cap * 1.05,
        "15 iterations bring σ_max within 5% of the cap"
    );
    println!("\nspectral_clipping OK");
}

fn norm(v: &[f64]) -> f64 {
    v.iter().map(|x| x * x).sum::<f64>().sqrt()
}
