//! END-TO-END DRIVER: audit the full spectrum of every conv layer of a
//! CNN through the complete three-layer stack —
//!
//!   rust coordinator (tile scheduler, worker pool)
//!     → PJRT runtime executing the AOT JAX/Pallas artifact where the
//!       layer shape matches the manifest
//!     → native rust LFA pipeline everywhere else
//!
//! and report the paper's headline comparison (LFA vs FFT runtime) on the
//! same workload. This is the "real small workload" validation run recorded
//! in EXPERIMENTS.md.
//!
//! ```sh
//! make artifacts && cargo run --release --example cnn_spectral_audit
//! ```

use conv_svd_lfa::baselines::fft_svd::{self, FftLayoutPolicy};
use conv_svd_lfa::coordinator::{Backend, ServiceConfig, SpectralService};
use conv_svd_lfa::lfa::Spectrum;
use conv_svd_lfa::model::zoo;
use conv_svd_lfa::report::{commas, secs, Table};

fn main() -> conv_svd_lfa::Result<()> {
    let model = zoo::resnet20ish();
    let threads = conv_svd_lfa::engine::resolve_threads(0);
    println!(
        "auditing model `{}`: {} conv layers, {} singular values total, {threads} worker(s)\n",
        model.name,
        model.layers.len(),
        commas(model.total_values() as u128)
    );

    let svc = SpectralService::start(ServiceConfig {
        workers: threads,
        backend: Backend::Auto,
        artifacts_dir: Some(SpectralService::default_artifacts_dir()),
        ..Default::default()
    })?;

    let t0 = std::time::Instant::now();
    let reports = svc.audit_model(&model)?;
    let total = t0.elapsed();

    let mut table = Table::new([
        "layer", "grid", "c", "#σ", "σ_max", "σ_min", "cond", "fro-defect", "time", "backend",
    ]);
    for r in &reports {
        table.row([
            r.name.clone(),
            format!("{}x{}", r.n, r.m),
            format!("{}→{}", r.c_in, r.c_out),
            commas(r.num_values as u128),
            format!("{:.4}", r.sigma_max),
            format!("{:.4}", r.sigma_min),
            format!("{:.1}", r.condition),
            format!("{:.1e}", r.frobenius_defect),
            secs(r.elapsed),
            if r.pjrt_tiles > 0 {
                format!("pjrt×{}", r.pjrt_tiles)
            } else {
                "native".to_string()
            },
        ]);
        // Hard E2E checks: verified spectra everywhere.
        assert!(r.frobenius_defect < 1e-3, "{}: defect {}", r.name, r.frobenius_defect);
        assert!(r.sigma_max > 0.0);
    }
    print!("{}", table.render());

    let metrics = svc.metrics();
    println!(
        "\ncoordinator: {} tiles ({} via PJRT artifact, {} native), Σ tile work {}, wall {}",
        metrics.tiles_completed,
        metrics.pjrt_tiles,
        metrics.native_tiles,
        secs(metrics.tile_work),
        secs(total),
    );

    // Headline comparison on this workload: LFA (native, through the
    // coordinator path) vs the FFT baseline, per layer.
    println!("\nheadline: LFA vs FFT on the audited layers");
    let mut cmp = Table::new(["layer", "LFA σ_max", "FFT σ_max", "max|Δσ|", "t_FFT/t_LFA"]);
    let mut speedups = Vec::new();
    for (layer, r) in model.layers.iter().zip(&reports) {
        let kernel = layer.materialize(model.seed);
        let t0 = std::time::Instant::now();
        let fft = fft_svd::singular_values(
            &kernel,
            layer.height,
            layer.width,
            FftLayoutPolicy::Natural,
            1,
        );
        let t_fft = t0.elapsed();
        let t0 = std::time::Instant::now();
        let lfa_again = conv_svd_lfa::lfa::singular_values(
            &kernel,
            layer.height,
            layer.width,
            Default::default(),
        );
        let t_lfa = t0.elapsed();
        let worst = Spectrum::divergence(&lfa_again.sorted_desc(), &fft.sorted_desc());
        let ratio = t_fft.as_secs_f64() / t_lfa.as_secs_f64();
        speedups.push(ratio);
        cmp.row([
            layer.name.clone(),
            format!("{:.4}", r.sigma_max),
            format!("{:.4}", fft.sigma_max()),
            format!("{worst:.1e}"),
            format!("{ratio:.2}"),
        ]);
    }
    print!("{}", cmp.render());
    let gm = (speedups.iter().map(|s| s.ln()).sum::<f64>() / speedups.len() as f64).exp();
    println!("\ngeometric-mean FFT/LFA runtime ratio over the model: {gm:.2}x");
    println!("(paper Table II reports 1.09–1.44x on a 16-core Xeon for n=256..16384)");

    svc.shutdown();
    println!("\ncnn_spectral_audit OK");
    Ok(())
}
