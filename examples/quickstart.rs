//! Quickstart: compute the full SVD spectrum of one convolutional layer
//! with LFA and sanity-check it against the FFT baseline and the
//! Frobenius identity.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use conv_svd_lfa::baselines::fft_svd::{self, FftLayoutPolicy};
use conv_svd_lfa::conv::ConvKernel;
use conv_svd_lfa::lfa::{self, LfaOptions};
use conv_svd_lfa::numeric::Pcg64;
use conv_svd_lfa::report::{commas, secs};

fn main() {
    // A 16→16-channel 3×3 convolution on a 64×64 feature map — the paper's
    // benchmark shape (§IV).
    let (n, c) = (64, 16);
    let mut rng = Pcg64::seeded(2025);
    let kernel = ConvKernel::random_he(c, c, 3, 3, &mut rng);

    println!("LFA SVD of a {c}x{c}x3x3 convolution on a {n}x{n} grid");
    println!("(the unrolled matrix would be {} x {} — never materialized)\n",
        commas((n * n * c) as u128), commas((n * n * c) as u128));

    // --- the one-call API ---
    let t0 = std::time::Instant::now();
    let spectrum = lfa::singular_values(&kernel, n, n, LfaOptions::default());
    let t_lfa = t0.elapsed();

    println!("{} singular values in {}", commas(spectrum.num_values() as u128), secs(t_lfa));
    println!("  σ_max     = {:.6}  (spectral norm / Lipschitz constant)", spectrum.sigma_max());
    println!("  σ_min     = {:.6}", spectrum.sigma_min());
    println!("  condition = {:.2}", spectrum.condition_number());

    let sorted = spectrum.sorted_desc();
    println!("  largest 5: {:?}", &sorted[..5].iter().map(|v| (v * 1e4).round() / 1e4).collect::<Vec<_>>());

    // --- cross-check vs the FFT route (Sedghi et al. 2019) ---
    let t0 = std::time::Instant::now();
    let fft = fft_svd::singular_values(&kernel, n, n, FftLayoutPolicy::Natural, 1);
    let t_fft = t0.elapsed();
    let worst = spectrum
        .sorted_desc()
        .iter()
        .zip(fft.sorted_desc())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f64::max);
    println!("\nFFT baseline: {} (LFA {}) — max |Δσ| = {worst:.2e}", secs(t_fft), secs(t_lfa));

    // --- invariant: Σσ² == n²·‖W‖²_F ---
    let defect = lfa::svd::frobenius_check(&kernel, n, n, &spectrum);
    println!("Frobenius identity defect: {defect:.2e}");
    assert!(defect < 1e-10);
    assert!(worst < 1e-9);
    println!("\nquickstart OK");
}
