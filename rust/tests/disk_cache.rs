//! The persistent disk cache tier (`engine::disk_cache`): spill-file
//! round trips, restart-warm audits that re-solve zero frequencies and
//! return bit-identical spectra, and the corruption suite — truncated,
//! bit-flipped, wrong-version and wrong-key spill files must fail
//! validation, be quarantined, and never be served.

use conv_svd_lfa::conv::ConvKernel;
use conv_svd_lfa::coordinator::{ServiceConfig, SpectralService};
use conv_svd_lfa::engine::{DiskCache, Signature, SpectralCache, SpectrumRequest};
use conv_svd_lfa::lfa::{self, LfaOptions};
use conv_svd_lfa::model::ModelConfig;
use conv_svd_lfa::numeric::Pcg64;
use std::fs;
use std::path::PathBuf;

/// Unique, self-cleaning spill directory per test (tests run in parallel
/// threads of one process, and possibly concurrently across processes).
struct TempDir(PathBuf);

impl TempDir {
    fn new(tag: &str) -> Self {
        let dir = std::env::temp_dir().join(format!("lfa-spill-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        TempDir(dir)
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = fs::remove_dir_all(&self.0);
    }
}

fn kernel(seed: u64) -> ConvKernel {
    let mut rng = Pcg64::seeded(seed);
    ConvKernel::random_he(3, 2, 3, 3, &mut rng)
}

fn sig_and_spectrum(seed: u64) -> (Signature, lfa::Spectrum) {
    let k = kernel(seed);
    let opts = LfaOptions::default();
    let sig = Signature::result(&k, 8, 8, 1, &opts, SpectrumRequest::Full);
    let spectrum = lfa::singular_values(&k, 8, 8, opts);
    (sig, spectrum)
}

const MODEL: &str = "name = \"tiny\"\nseed = 3\n\
    [[layer]]\nname = \"a\"\nc_in = 2\nc_out = 3\nheight = 8\nwidth = 8\n\
    [[layer]]\nname = \"b\"\nc_in = 3\nc_out = 2\nheight = 6\nwidth = 6\n";

fn service(dir: &TempDir) -> SpectralService {
    SpectralService::start(ServiceConfig {
        workers: 2,
        disk_cache_dir: Some(dir.0.clone()),
        ..Default::default()
    })
    .unwrap()
}

#[test]
fn spill_roundtrip_is_bit_exact_and_idempotent() {
    let tmp = TempDir::new("roundtrip");
    let disk = DiskCache::open(&tmp.0).unwrap();
    let (sig, spectrum) = sig_and_spectrum(1);
    assert!(disk.is_empty());
    assert!(disk.put(&sig, &spectrum), "first put writes a spill file");
    assert!(!disk.put(&sig, &spectrum), "second put is a content-addressed no-op");
    assert_eq!(disk.len(), 1);
    let back = disk.get(&sig).expect("spill file reads back");
    assert_eq!(back.values.len(), spectrum.values.len());
    for (a, b) in back.values.iter().zip(&spectrum.values) {
        assert_eq!(a.to_bits(), b.to_bits(), "round trip must be bit-exact");
    }
    assert_eq!(
        (back.n, back.m, back.c_out, back.c_in, back.per_freq),
        (spectrum.n, spectrum.m, spectrum.c_out, spectrum.c_in, spectrum.per_freq)
    );
    let s = disk.stats();
    assert_eq!((s.hits, s.misses, s.spills, s.corruptions), (1, 0, 1, 0));
}

#[test]
fn missing_entry_is_a_miss_not_an_error() {
    let tmp = TempDir::new("miss");
    let disk = DiskCache::open(&tmp.0).unwrap();
    let (sig, _) = sig_and_spectrum(2);
    assert!(disk.get(&sig).is_none());
    let s = disk.stats();
    assert_eq!((s.hits, s.misses, s.corruptions), (0, 1, 0));
}

/// Each corruption shape: tamper, assert the read is a quarantining miss
/// (None + corruption counted + file deleted), never a served value.
fn assert_quarantined(disk: &DiskCache, sig: &Signature, what: &str) {
    let path = disk.path_for(sig);
    assert!(path.exists(), "{what}: tampered file still present before read");
    assert!(disk.get(sig).is_none(), "{what}: corrupt spill must not be served");
    assert_eq!(disk.stats().corruptions, 1, "{what}: corruption must be counted");
    assert!(!path.exists(), "{what}: corrupt spill must be quarantined (deleted)");
    // The slot now reads as a plain miss and can be re-spilled.
    assert!(disk.get(sig).is_none());
    assert_eq!(disk.stats().misses, 1, "{what}: post-quarantine read is a miss");
}

#[test]
fn truncated_spill_is_quarantined() {
    let tmp = TempDir::new("truncate");
    let disk = DiskCache::open(&tmp.0).unwrap();
    let (sig, spectrum) = sig_and_spectrum(3);
    disk.put(&sig, &spectrum);
    let path = disk.path_for(&sig);
    let len = fs::metadata(&path).unwrap().len();
    let f = fs::OpenOptions::new().write(true).open(&path).unwrap();
    f.set_len(len / 2).unwrap();
    drop(f);
    assert_quarantined(&disk, &sig, "truncated");
}

#[test]
fn bit_flipped_spill_is_quarantined() {
    let tmp = TempDir::new("bitflip");
    let disk = DiskCache::open(&tmp.0).unwrap();
    let (sig, spectrum) = sig_and_spectrum(4);
    disk.put(&sig, &spectrum);
    let path = disk.path_for(&sig);
    let mut bytes = fs::read(&path).unwrap();
    // Flip one bit in the middle of the value payload: the checksum
    // (not the geometry checks) is what must catch this.
    let mid = 80 + (bytes.len() - 96) / 2;
    bytes[mid] ^= 0x10;
    fs::write(&path, &bytes).unwrap();
    assert_quarantined(&disk, &sig, "bit-flipped");
}

#[test]
fn wrong_version_spill_is_quarantined() {
    let tmp = TempDir::new("version");
    let disk = DiskCache::open(&tmp.0).unwrap();
    let (sig, spectrum) = sig_and_spectrum(5);
    disk.put(&sig, &spectrum);
    let path = disk.path_for(&sig);
    let mut bytes = fs::read(&path).unwrap();
    // The version field sits outside the checksummed region, so this is
    // exactly the "future format" shape: checksum fine, version not ours.
    bytes[8] = bytes[8].wrapping_add(1);
    fs::write(&path, &bytes).unwrap();
    assert_quarantined(&disk, &sig, "wrong-version");
}

#[test]
fn spill_under_wrong_key_is_quarantined() {
    let tmp = TempDir::new("wrongkey");
    let disk = DiskCache::open(&tmp.0).unwrap();
    let (sig_a, spectrum_a) = sig_and_spectrum(6);
    let (sig_b, _) = sig_and_spectrum(7);
    disk.put(&sig_a, &spectrum_a);
    // A well-formed file parked under another key's name (renamed spill,
    // colliding copy): the embedded digest must reject it.
    fs::copy(disk.path_for(&sig_a), disk.path_for(&sig_b)).unwrap();
    assert!(disk.get(&sig_b).is_none(), "foreign spill must not be served");
    assert_eq!(disk.stats().corruptions, 1);
    assert!(!disk.path_for(&sig_b).exists());
    // The original entry is untouched.
    assert!(disk.get(&sig_a).is_some());
}

#[test]
fn purge_empties_the_tier() {
    let tmp = TempDir::new("purge");
    let disk = DiskCache::open(&tmp.0).unwrap();
    for seed in 10..13 {
        let (sig, spectrum) = sig_and_spectrum(seed);
        disk.put(&sig, &spectrum);
    }
    assert_eq!(disk.len(), 3);
    assert_eq!(disk.purge(), 3);
    assert!(disk.is_empty());
}

/// The headline acceptance test: audit, kill the process state (drop the
/// service — the in-memory cache dies with it), restart against the same
/// spill directory, repeat the audit. The warm run must be pure disk
/// hits: zero frequencies re-solved, bit-identical singular values.
#[test]
fn restart_warm_audit_resolves_zero_frequencies_bit_identically() {
    let tmp = TempDir::new("restart");
    let model = ModelConfig::parse(MODEL).unwrap();

    let svc1 = service(&tmp);
    let cold = svc1.audit_model(&model).unwrap();
    assert!(cold.iter().all(|r| !r.cached && r.solved_freqs > 0));
    let stats1 = svc1.cache_stats().unwrap();
    assert_eq!(stats1.disk_spills, 2, "every computed layer spills");
    assert_eq!(stats1.disk_hits, 0);
    svc1.shutdown();

    // "Restart": a fresh service, fresh (empty) in-memory cache, same dir.
    let svc2 = service(&tmp);
    let warm = svc2.audit_model(&model).unwrap();
    for (c, w) in cold.iter().zip(&warm) {
        assert!(w.cached, "layer {} must be served from the disk tier", w.name);
        assert_eq!(w.solved_freqs, 0, "layer {} must re-solve nothing", w.name);
        assert_eq!(c.spectrum.values.len(), w.spectrum.values.len());
        for (a, b) in c.spectrum.values.iter().zip(&w.spectrum.values) {
            assert_eq!(a.to_bits(), b.to_bits(), "layer {}: bit-identical", w.name);
        }
    }
    let stats2 = svc2.cache_stats().unwrap();
    assert_eq!(stats2.disk_hits, 2, "both layers read back from disk");
    assert_eq!(stats2.disk_spills, 0, "disk-served layers are not re-spilled");
    assert_eq!(stats2.disk_corruptions, 0);
    // The daemon's /metrics endpoint renders this snapshot — the disk
    // counters must flow through it, not just through cache_stats().
    let m = svc2.metrics();
    assert_eq!(
        (m.disk_hits, m.disk_misses, m.disk_spills, m.disk_corruptions),
        (stats2.disk_hits, stats2.disk_misses, stats2.disk_spills, stats2.disk_corruptions)
    );
    svc2.shutdown();
}

/// A corrupted spill across a restart: the poisoned layer recomputes (and
/// re-spills); the healthy layer still hits. Nothing is ever served from
/// the bad file, and the recomputed spectrum matches the original.
#[test]
fn corrupted_spill_recomputes_and_reheals_across_restart() {
    let tmp = TempDir::new("reheal");
    let model = ModelConfig::parse(MODEL).unwrap();
    let svc1 = service(&tmp);
    let cold = svc1.audit_model(&model).unwrap();
    svc1.shutdown();

    // Corrupt exactly one spill file (deterministically: the first in
    // sorted order).
    let mut spills: Vec<PathBuf> = fs::read_dir(&tmp.0)
        .unwrap()
        .flatten()
        .map(|e| e.path())
        .filter(|p| p.extension().is_some_and(|x| x == "spill"))
        .collect();
    spills.sort();
    assert_eq!(spills.len(), 2);
    let victim = &spills[0];
    let mut bytes = fs::read(victim).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x01;
    fs::write(victim, &bytes).unwrap();

    let svc2 = service(&tmp);
    let warm = svc2.audit_model(&model).unwrap();
    let stats = svc2.cache_stats().unwrap();
    assert_eq!(stats.disk_corruptions, 1, "the tampered file is quarantined");
    assert_eq!(stats.disk_hits, 1, "the healthy layer still hits");
    assert_eq!(stats.disk_spills, 1, "the recomputed layer re-spills");
    let recomputed: Vec<_> = warm.iter().filter(|r| !r.cached).collect();
    assert_eq!(recomputed.len(), 1, "exactly one layer recomputes");
    assert!(recomputed[0].solved_freqs > 0);
    // Values are deterministic, so the recomputed layer agrees bit-for-bit
    // with the original cold run.
    for (c, w) in cold.iter().zip(&warm) {
        for (a, b) in c.spectrum.values.iter().zip(&w.spectrum.values) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
    // And the tier healed: a third run is pure hits again.
    assert_eq!(svc2.cache_stats().unwrap().entries, 2);
    svc2.shutdown();
    let svc3 = service(&tmp);
    let hot = svc3.audit_model(&model).unwrap();
    assert!(hot.iter().all(|r| r.cached && r.solved_freqs == 0));
    svc3.shutdown();
}

/// An entry too big for the memory budget is still served by the disk
/// tier: the tiers are independent, and disk has no byte budget.
#[test]
fn disk_tier_serves_entries_the_memory_budget_evicts() {
    let tmp = TempDir::new("tiny-mem");
    let disk = DiskCache::open(&tmp.0).unwrap();
    // A 1-byte budget: nothing survives in memory.
    let cache = SpectralCache::with_budget(1).with_disk(disk);
    let (sig, spectrum) = sig_and_spectrum(8);
    cache.insert(sig, std::sync::Arc::new(spectrum.clone()));
    let stats = cache.stats();
    assert_eq!(stats.entries, 0, "memory tier evicted the oversized entry");
    assert_eq!(stats.disk_spills, 1, "…but it was written through to disk");
    let back = cache.get(&sig).expect("served from disk despite memory eviction");
    for (a, b) in back.values.iter().zip(&spectrum.values) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    assert_eq!(cache.stats().disk_hits, 1);
}

/// Signatures isolate entries: a different weight draw never reads
/// another draw's spill file.
#[test]
fn keys_are_isolated_on_disk() {
    let tmp = TempDir::new("isolation");
    let disk = DiskCache::open(&tmp.0).unwrap();
    let (sig_a, spec_a) = sig_and_spectrum(20);
    let (sig_b, spec_b) = sig_and_spectrum(21);
    assert_ne!(sig_a.file_digest(), sig_b.file_digest());
    disk.put(&sig_a, &spec_a);
    disk.put(&sig_b, &spec_b);
    assert_eq!(disk.len(), 2);
    let a = disk.get(&sig_a).unwrap();
    let b = disk.get(&sig_b).unwrap();
    assert_ne!(a.values, b.values);
    assert_eq!(a.values, spec_a.values);
    assert_eq!(b.values, spec_b.values);
}

/// The config cross-check: a disk tier below a disabled cache is a
/// contradiction and must fail fast, not silently drop the tier.
#[test]
fn disk_dir_without_cache_is_rejected() {
    let tmp = TempDir::new("no-cache");
    let err = SpectralService::start(ServiceConfig {
        workers: 1,
        cache_bytes: None,
        disk_cache_dir: Some(tmp.0.clone()),
        ..Default::default()
    })
    .unwrap_err();
    assert!(
        err.to_string().contains("requires caching"),
        "unexpected error: {err}"
    );
}
