//! Allocation discipline of the structured (block-diagonal / dilated /
//! adjoint) engine paths: after a warm-up execution has populated the
//! workspace pool — including the per-group merge buffer the grouped
//! top-k sweep uses — `execute_request_into` on structured plans performs
//! **zero heap allocation**, exactly like the dense paths
//! pinned in `engine_alloc.rs`. Kept in its own file (with its own
//! counting global allocator) so unrelated parallel tests cannot perturb
//! the counter windows.

use conv_svd_lfa::conv::ConvKernel;
use conv_svd_lfa::engine::{SpectralPlan, SpectrumRequest, SweepOptions};
use conv_svd_lfa::lfa::{Fold, LfaOptions};
use conv_svd_lfa::numeric::Pcg64;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn structured_kernels(rng: &mut Pcg64) -> Vec<(&'static str, ConvKernel)> {
    vec![
        ("grouped g2", ConvKernel::random_he(4, 2, 3, 3, rng).with_groups(2)),
        ("depthwise", ConvKernel::random_he(4, 1, 3, 3, rng).with_groups(4)),
        ("dilated d2", ConvKernel::random_he(4, 4, 3, 3, rng).with_dilation(2)),
        ("transposed", ConvKernel::random_he(4, 3, 3, 3, rng).with_transposed(true)),
    ]
}

fn assert_structured_zero_alloc(tag: &str, k: &ConvKernel, folding: Fold) {
    let opts = LfaOptions { threads: 1, folding, ..Default::default() };
    let plan = SpectralPlan::new(k, 8, 8, opts);
    let mut out = vec![0.0f64; plan.values_len()];
    let full = SpectrumRequest::Full;
    // Warm-up: the pool (and the grouped merge buffer) may grow once.
    plan.execute_request_into(full, SweepOptions::default(), &mut out);
    let before = ALLOCS.load(Ordering::SeqCst);
    plan.execute_request_into(full, SweepOptions::default(), &mut out);
    plan.execute_request_into(full, SweepOptions::default(), &mut out);
    let after = ALLOCS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "{tag} {folding:?}: {} allocation(s) in warmed-up structured execute_request_into",
        after - before
    );
    assert!(out.iter().all(|v| v.is_finite() && *v >= 0.0));

    let mut tout = vec![0.0f64; plan.topk_values_len(2)];
    let topk = SpectrumRequest::TopK(2);
    plan.execute_request_into(topk, SweepOptions::default(), &mut tout);
    let before = ALLOCS.load(Ordering::SeqCst);
    plan.execute_request_into(topk, SweepOptions::default(), &mut tout);
    let after = ALLOCS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "{tag} {folding:?}: {} allocation(s) in warmed-up structured TopK sweep",
        after - before
    );
    assert!(tout.iter().all(|v| v.is_finite() && *v >= 0.0));
}

// One test, sequential scenarios: the harness runs #[test] fns on separate
// threads, and concurrent tests would pollute each other's counter windows.
#[test]
fn structured_execution_is_allocation_free_after_warmup() {
    let mut rng = Pcg64::seeded(9200);
    for (tag, k) in structured_kernels(&mut rng) {
        assert_structured_zero_alloc(tag, &k, Fold::Auto);
        assert_structured_zero_alloc(tag, &k, Fold::Off);
    }
}
