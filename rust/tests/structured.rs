//! Structured-convolution acceptance: grouped / depthwise / dilated /
//! transposed kernels through the planned engine must match the unrolled
//! reference and the per-frequency symbol reference across the full
//! configuration matrix (fold on/off, both layouts, serial/threaded,
//! Full + TopK), the block-diagonal group semantics must decompose into
//! independent per-group audits, and the result cache must never serve a
//! spectrum across a structure change (same weight bits, different
//! groups/dilation/transposed ⇒ miss).

use conv_svd_lfa::conv::ConvKernel;
use conv_svd_lfa::coordinator::SpectralService;
use conv_svd_lfa::engine::{
    NativeSerial, NativeThreaded, SpectralBackend, SpectralCache, SpectralPlan, SpectrumRequest,
};
use conv_svd_lfa::lfa::stride::unroll_strided;
use conv_svd_lfa::lfa::{self, BlockLayout, Fold, LfaOptions};
use conv_svd_lfa::linalg::{gk_svd, jacobi_svd};
use conv_svd_lfa::model::zoo;
use conv_svd_lfa::numeric::Pcg64;
use std::sync::Arc;

fn max_gap(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len(), "spectrum lengths differ");
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
}

/// One kernel per structured variant (plus a combined one): the rows of
/// the equivalence matrix. Channel counts are kept small enough that the
/// unrolled reference stays cheap.
fn structured_variants(rng: &mut Pcg64) -> Vec<(&'static str, ConvKernel)> {
    vec![
        ("grouped g2", ConvKernel::random_he(4, 2, 3, 3, rng).with_groups(2)),
        ("depthwise", ConvKernel::random_he(4, 1, 3, 3, rng).with_groups(4)),
        ("dilated d2", ConvKernel::random_he(3, 3, 3, 3, rng).with_dilation(2)),
        ("transposed", ConvKernel::random_he(4, 3, 3, 3, rng).with_transposed(true)),
        (
            "grouped+dilated+transposed",
            ConvKernel::random_he(4, 2, 3, 3, rng)
                .with_groups(2)
                .with_dilation(2)
                .with_transposed(true),
        ),
    ]
}

/// Frequency-by-frequency reference spectrum off the structure-aware
/// [`lfa::strided_symbol_at`] (direct trig, block-diagonal / adjoint
/// assembly, no tables) + the standalone Jacobi solver.
fn reference_spectrum(k: &ConvKernel, n: usize, m: usize, s: usize) -> Vec<f64> {
    let (nc, mc) = (n / s, m / s);
    let r = k.c_out.min(s * s * k.c_in_total());
    let mut values = vec![0.0f64; nc * mc * r];
    for ki in 0..nc {
        for kj in 0..mc {
            let block = lfa::strided_symbol_at(k, n, m, s, ki, kj);
            let sv = jacobi_svd::singular_values(&block);
            let f = ki * mc + kj;
            values[f * r..(f + 1) * r].copy_from_slice(&sv[..r]);
        }
    }
    values
}

/// The structured equivalence matrix: every variant × stride ∈ {1, 2} ×
/// both layouts × fold on/off × serial/threaded, against the
/// per-frequency symbol reference.
#[test]
fn structured_plans_match_the_per_frequency_reference() {
    let mut rng = Pcg64::seeded(9100);
    for (tag, k) in structured_variants(&mut rng) {
        for &(n, m, s) in &[(6usize, 6usize, 1usize), (8, 8, 2)] {
            let want = reference_spectrum(&k, n, m, s);
            for layout in [BlockLayout::BlockContiguous, BlockLayout::PlanarStrided] {
                for folding in [Fold::Auto, Fold::Off] {
                    for threads in [1usize, 3] {
                        let opts =
                            LfaOptions { layout, folding, threads, ..Default::default() };
                        let got = SpectralPlan::with_stride(&k, n, m, s, opts).execute();
                        let gap = max_gap(&got.values, &want);
                        assert!(
                            gap < 1e-10,
                            "{tag} {n}x{m}/{s} {layout:?} {folding:?} x{threads}: gap {gap}"
                        );
                        // Spectrum metadata carries the *operator* shape:
                        // total channels, swapped for transposed kernels.
                        let (co, ci) = if k.transposed {
                            (k.c_in_total(), k.c_out)
                        } else {
                            (k.c_out, k.c_in_total())
                        };
                        assert_eq!((got.c_out, got.c_in), (co, ci), "{tag}: operator dims");
                    }
                }
            }
        }
    }
}

/// The unrolled ground truth: the sorted engine spectrum equals the
/// singular values of the explicitly unrolled (structure-aware) operator
/// matrix to ≤ 1e-12·σ_max. Transposed kernels audit the adjoint, whose
/// singular values equal the forward operator's, so the same forward
/// unrolling is the reference for every variant.
#[test]
fn structured_spectra_match_the_unrolled_reference() {
    let mut rng = Pcg64::seeded(9101);
    for (tag, k) in structured_variants(&mut rng) {
        for &(n, m, s) in &[(6usize, 6usize, 1usize), (8, 8, 2)] {
            let a = unroll_strided(&k, n, m, s);
            let mut want = gk_svd::singular_values(&a);
            want.sort_by(|x, y| y.partial_cmp(x).unwrap());
            for folding in [Fold::Auto, Fold::Off] {
                let opts = LfaOptions { folding, threads: 1, ..Default::default() };
                let got =
                    SpectralPlan::with_stride(&k, n, m, s, opts).execute().sorted_desc();
                let scale = want.first().copied().unwrap_or(1.0).max(1.0);
                let gap = max_gap(&got, &want);
                assert!(
                    gap <= 1e-12 * scale,
                    "{tag} {n}x{m}/{s} {folding:?}: gap {gap:e} vs unrolled"
                );
            }
        }
    }
}

/// TopK and the backend strategies on structured plans: the partial sweep
/// reproduces the top of the full spectrum per frequency, and the serial
/// and threaded backends agree bitwise.
#[test]
fn structured_topk_and_backends_agree_with_full() {
    let mut rng = Pcg64::seeded(9102);
    for (tag, k) in structured_variants(&mut rng) {
        let plan = SpectralPlan::new(&k, 8, 8, LfaOptions { threads: 1, ..Default::default() });
        let full = NativeSerial.execute(&plan).unwrap();
        let threaded = NativeThreaded { threads: 3 }.execute(&plan).unwrap();
        assert_eq!(full.values, threaded.values, "{tag}: backends must agree bitwise");
        let scale = full.sigma_max().max(1.0);
        let topk = plan.execute_topk(2);
        let ke = topk.spectrum.rank_per_freq();
        assert!(ke <= 2, "{tag}: at most k values per frequency");
        for f in 0..8 * 8 {
            for j in 0..ke {
                let (x, y) = (topk.spectrum.at(f)[j], full.at(f)[j]);
                assert!(
                    (x - y).abs() <= 2e-8 * scale,
                    "{tag} f={f} j={j}: topk {x} vs full {y}"
                );
            }
        }
    }
}

/// Adjoint semantics: a transposed plan solves the *same* per-frequency
/// blocks as the forward plan (singular values are transpose-invariant),
/// so its values are bitwise identical — only the reported operator shape
/// swaps.
#[test]
fn transposed_plan_swaps_shape_and_keeps_values() {
    let mut rng = Pcg64::seeded(9103);
    let kf = ConvKernel::random_he(4, 3, 3, 3, &mut rng);
    let kt = kf.clone().with_transposed(true);
    let opts = LfaOptions { threads: 1, ..Default::default() };
    let a = SpectralPlan::new(&kf, 8, 8, opts).execute();
    let b = SpectralPlan::new(&kt, 8, 8, opts).execute();
    assert_eq!(a.values, b.values, "adjoint values must match forward bitwise");
    assert_eq!((a.c_out, a.c_in), (4, 3));
    assert_eq!((b.c_out, b.c_in), (3, 4), "transposed spectrum reports the adjoint shape");
}

/// Block-diagonal semantics: a grouped layer's per-frequency spectrum is
/// exactly the union of its groups' independent dense spectra — solve each
/// group as its own small dense kernel and merge.
#[test]
fn grouped_spectrum_is_the_union_of_per_group_spectra() {
    let mut rng = Pcg64::seeded(9104);
    let (gr, cg, g) = (2usize, 2usize, 2usize); // c_out/g, c_in/g, groups
    let k = ConvKernel::random_he(gr * g, cg, 3, 3, &mut rng).with_groups(g);
    let (n, m) = (6usize, 6usize);
    let opts = LfaOptions { threads: 1, ..Default::default() };
    let grouped = SpectralPlan::new(&k, n, m, opts).execute();
    // Extract each group's dense sub-kernel (OIHW rows are contiguous per
    // group: o ∈ [gi·gr, (gi+1)·gr) over the stored per-group width).
    let per_group: Vec<_> = (0..g)
        .map(|gi| {
            let mut sub = ConvKernel::zeros(gr, cg, 3, 3);
            let len = gr * cg * 3 * 3;
            sub.data.copy_from_slice(&k.data[gi * len..(gi + 1) * len]);
            sub.anchor = k.anchor;
            SpectralPlan::new(&sub, n, m, opts).execute()
        })
        .collect();
    let r = grouped.rank_per_freq();
    assert_eq!(r, g * gr.min(cg));
    for f in 0..n * m {
        let mut union: Vec<f64> = per_group.iter().flat_map(|s| s.at(f).to_vec()).collect();
        union.sort_by(|x, y| y.partial_cmp(x).unwrap());
        let gap = max_gap(grouped.at(f), &union);
        assert!(gap <= 1e-12, "f={f}: grouped vs per-group union gap {gap:e}");
    }
}

/// Cache-signature isolation: the same weight bits under different
/// structure (groups / dilation / transposed) must produce distinct
/// signatures — a cached dense result is never served for a structured
/// request and vice versa, and plans are not shared across structures.
#[test]
fn cache_signatures_isolate_structure() {
    let mut rng = Pcg64::seeded(9105);
    let base = ConvKernel::random_he(4, 4, 3, 3, &mut rng);
    let variants = [
        base.clone().with_groups(2),
        base.clone().with_groups(4),
        base.clone().with_dilation(2),
        base.clone().with_transposed(true),
    ];
    let opts = LfaOptions { threads: 1, ..Default::default() };
    let cache = SpectralCache::new();
    let dense_plan = cache.plan_for(&base, 8, 8, 1, opts);
    let dense_key = dense_plan.result_signature(SpectrumRequest::Full);
    cache.insert(dense_key, Arc::new(dense_plan.execute()));
    assert!(cache.get(&dense_key).is_some(), "dense result must round-trip");
    let mut keys = vec![dense_key];
    for k in &variants {
        let p = cache.plan_for(k, 8, 8, 1, opts);
        assert!(!Arc::ptr_eq(&p, &dense_plan), "plan cache must not share across structure");
        let key = p.result_signature(SpectrumRequest::Full);
        assert!(
            cache.get(&key).is_none(),
            "same weight bits, different structure must miss the result cache"
        );
        assert!(!keys.contains(&key), "structure variants must have pairwise distinct keys");
        keys.push(key);
    }
}

/// End-to-end: the `mobile-ish` builtin (depthwise-separable blocks, a
/// dilated context layer, a transposed decoder layer) audits through the
/// coordinator service with the Frobenius identity verified per layer,
/// and the transposed layer reports the adjoint's channel dims.
#[test]
fn mobile_ish_audits_end_to_end() {
    let model = zoo::mobile_ish();
    let svc = SpectralService::native(2);
    let reports = svc.audit_model(&model).unwrap();
    svc.shutdown();
    assert_eq!(reports.len(), model.layers.len());
    for (r, l) in reports.iter().zip(&model.layers) {
        assert!(r.sigma_max.is_finite() && r.sigma_max > 0.0, "{}: σ_max", r.name);
        assert!(
            r.frobenius_defect.is_finite() && r.frobenius_defect < 1e-10,
            "{}: Frobenius defect {:.3e}",
            r.name,
            r.frobenius_defect
        );
        let (co, ci) =
            if l.transposed { (l.c_in, l.c_out) } else { (l.c_out, l.c_in) };
        assert_eq!((r.c_out, r.c_in), (co, ci), "{}: operator channel dims", r.name);
    }
}
