//! Acceptance checks for the top-k partial-spectrum engine: across every
//! configuration axis (stride, layout, solver-irrelevant, threads), the
//! `SpectrumRequest::TopK(k)` path must reproduce the full pipeline's k
//! largest singular values per frequency to ≤ 1e-8 (relative to σ_max);
//! warm-started and cold sweeps must agree while warm sweeps spend fewer
//! solver steps; and the whole-model + coordinator paths must
//! stitch partial spectra identically to the per-layer engine.

use conv_svd_lfa::conv::ConvKernel;
use conv_svd_lfa::coordinator::SpectralService;
use conv_svd_lfa::engine::{ModelPlan, SpectralPlan, SpectrumRequest, SweepOptions};
use conv_svd_lfa::lfa::{BlockLayout, LfaOptions};
use conv_svd_lfa::model::ModelConfig;
use conv_svd_lfa::numeric::Pcg64;

/// Relative tolerance of the acceptance criterion (vs σ_max of the layer).
const REL_TOL: f64 = 1e-8;

fn assert_topk_matches_full(plan: &SpectralPlan, k: usize, label: &str) {
    let full = plan.execute();
    let top = plan.execute_topk(k);
    let ke = plan.topk_per_freq(k);
    assert_eq!(top.spectrum.rank_per_freq(), ke, "{label}");
    assert_eq!(top.spectrum.values.len(), plan.topk_values_len(k), "{label}");
    let scale = full.sigma_max().max(1e-300);
    for f in 0..plan.freqs() {
        let want = full.at(f);
        let got = top.spectrum.at(f);
        for j in 0..ke {
            assert!(
                (want[j] - got[j]).abs() <= REL_TOL * scale,
                "{label}: f={f} j={j}: topk {} vs full {}",
                got[j],
                want[j]
            );
        }
    }
}

#[test]
fn topk_matches_full_across_configs() {
    let mut rng = Pcg64::seeded(9001);
    for &(n, m) in &[(6usize, 6usize), (5, 7)] {
        for &(c_out, c_in) in &[(4usize, 4usize), (5, 3), (3, 5)] {
            let kernel = ConvKernel::random_he(c_out, c_in, 3, 3, &mut rng);
            for layout in [BlockLayout::BlockContiguous, BlockLayout::PlanarStrided] {
                for threads in [1usize, 3] {
                    let opts = LfaOptions { layout, threads, ..Default::default() };
                    let plan = SpectralPlan::new(&kernel, n, m, opts);
                    for k in [1usize, 2, 9] {
                        assert_topk_matches_full(
                            &plan,
                            k,
                            &format!("{n}x{m} {c_out}x{c_in} {layout:?} x{threads} k={k}"),
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn topk_matches_full_strided() {
    let mut rng = Pcg64::seeded(9002);
    for &(n, m, s) in &[(8usize, 8usize, 2usize), (6, 6, 3), (4, 8, 2)] {
        for &(c_out, c_in) in &[(3usize, 2usize), (4, 1)] {
            let kernel = ConvKernel::random_he(c_out, c_in, 3, 3, &mut rng);
            let opts = LfaOptions { threads: 1, ..Default::default() };
            let plan = SpectralPlan::with_stride(&kernel, n, m, s, opts);
            for k in [1usize, 2] {
                assert_topk_matches_full(&plan, k, &format!("{n}x{m}/{s} {c_out}x{c_in} k={k}"));
            }
        }
    }
}

#[test]
fn warm_and_cold_sweeps_agree_and_warm_is_cheaper() {
    // c=32: large enough that the Krylov loop converges before exhausting
    // the space, so the cross-frequency warm hint saves steps (at small c
    // both runs saturate at the space dimension and tie).
    let mut rng = Pcg64::seeded(9003);
    let kernel = ConvKernel::random_he(32, 32, 3, 3, &mut rng);
    let opts = LfaOptions { threads: 1, ..Default::default() };
    let plan = SpectralPlan::new(&kernel, 6, 6, opts);
    let warm = plan.execute_topk(2);
    let mut cold_values = vec![0.0f64; plan.topk_values_len(2)];
    let (cold_iterations, _) = plan.execute_request_into(
        SpectrumRequest::TopK(2),
        SweepOptions::cold(),
        &mut cold_values,
    );
    let scale = warm.spectrum.sigma_max();
    for (a, b) in warm.spectrum.values.iter().zip(&cold_values) {
        assert!((a - b).abs() <= 2.0 * REL_TOL * scale, "{a} vs {b}");
    }
    assert!(
        warm.iterations < cold_iterations,
        "warm {} !< cold {}",
        warm.iterations,
        cold_iterations
    );
}

#[test]
fn repeated_topk_execution_is_deterministic() {
    let mut rng = Pcg64::seeded(9004);
    let kernel = ConvKernel::random_he(4, 4, 3, 3, &mut rng);
    let plan = SpectralPlan::new(&kernel, 8, 8, LfaOptions { threads: 1, ..Default::default() });
    let a = plan.execute_topk(3);
    let b = plan.execute_topk(3);
    assert_eq!(a.spectrum.values, b.spectrum.values, "bitwise reproducible");
    assert_eq!(a.iterations, b.iterations);
}

#[test]
fn model_plan_topk_matches_per_layer_plans() {
    let model = ModelConfig::parse(
        "name = \"mix\"\nseed = 21\n\
         [[layer]]\nname = \"a1\"\nc_in = 3\nc_out = 4\nheight = 8\nwidth = 8\n\
         [[layer]]\nname = \"b\"\nc_in = 2\nc_out = 3\nheight = 6\nwidth = 6\n\
         [[layer]]\nname = \"s\"\nc_in = 2\nc_out = 4\nheight = 8\nwidth = 8\nstride = 2\n\
         [[layer]]\nname = \"a2\"\nc_in = 3\nc_out = 4\nheight = 4\nwidth = 8\n",
    )
    .unwrap();
    for threads in [1usize, 3] {
        let opts = LfaOptions { threads, ..Default::default() };
        let mp = ModelPlan::build(&model, opts).unwrap();
        let top = mp.top_k_all(2);
        assert!(top.iterations > 0);
        for (i, layer) in model.layers.iter().enumerate() {
            let kernel = layer.materialize(model.seed);
            let solo = SpectralPlan::with_stride(
                &kernel,
                layer.height,
                layer.width,
                layer.stride,
                LfaOptions { threads: 1, ..Default::default() },
            );
            let full = solo.execute();
            let got = &top.spectra.layers[i].spectrum;
            let ke = got.rank_per_freq();
            let scale = full.sigma_max();
            for f in 0..solo.freqs() {
                for j in 0..ke {
                    assert!(
                        (full.at(f)[j] - got.at(f)[j]).abs() <= REL_TOL * scale,
                        "x{threads} layer {} f={f} j={j}",
                        layer.name
                    );
                }
            }
        }
    }
}

#[test]
fn coordinator_topk_audit_matches_full_extremes() {
    let model = ModelConfig::parse(
        "name = \"svc\"\nseed = 5\n\
         [[layer]]\nname = \"c1\"\nc_in = 3\nc_out = 4\nheight = 8\nwidth = 8\n\
         [[layer]]\nname = \"c2\"\nc_in = 4\nc_out = 4\nheight = 8\nwidth = 8\n",
    )
    .unwrap();
    let svc = SpectralService::native(2);
    let full = svc.audit_model(&model).unwrap();
    let top = svc.audit_model_with(&model, SpectrumRequest::TopK(2)).unwrap();
    assert_eq!(full.len(), top.len());
    for (f, t) in full.iter().zip(&top) {
        assert_eq!(f.name, t.name);
        assert_eq!(t.spectrum.rank_per_freq(), 2, "partial spectra carry k values");
        assert!(!t.spectrum.is_full());
        let scale = f.sigma_max.max(1e-300);
        assert!(
            (f.sigma_max - t.sigma_max).abs() <= REL_TOL * scale,
            "{}: {} vs {}",
            f.name,
            f.sigma_max,
            t.sigma_max
        );
        // Frobenius verification is undefined on a partial spectrum.
        assert!(f.frobenius_defect.is_finite());
        assert!(t.frobenius_defect.is_nan());
        // Per frequency, the partial values are the full path's extremes.
        let freqs = t.spectrum.n * t.spectrum.m;
        for fi in 0..freqs {
            for j in 0..2 {
                assert!(
                    (f.spectrum.at(fi)[j] - t.spectrum.at(fi)[j]).abs() <= REL_TOL * scale,
                    "{} fi={fi} j={j}",
                    f.name
                );
            }
        }
    }
    svc.shutdown();
}

#[test]
fn explicit_pjrt_backend_rejects_topk_model_jobs() {
    use conv_svd_lfa::coordinator::{Backend, ModelJobSpec, Scheduler};
    let model = ModelConfig::parse(
        "name = \"p\"\nseed = 1\n\
         [[layer]]\nname = \"c1\"\nc_in = 2\nc_out = 2\nheight = 4\nwidth = 4\n",
    )
    .unwrap();
    let sched = Scheduler::native(1);
    // Explicitly requesting PJRT for a partial spectrum must fail loudly —
    // the AOT artifacts bake in the full per-frequency SVD, so silently
    // running native would misreport what was benchmarked.
    let spec = ModelJobSpec::new("p", model.clone())
        .with_backend(Backend::Pjrt)
        .with_request(SpectrumRequest::TopK(1));
    assert!(sched.run_model(spec).is_err());
    // Auto + top-k routes native by design and succeeds.
    let spec = ModelJobSpec::new("p", model)
        .with_backend(Backend::Auto)
        .with_request(SpectrumRequest::TopK(1));
    assert!(sched.run_model(spec).is_ok());
    sched.shutdown();
}

#[test]
fn backend_request_api_serves_topk() {
    use conv_svd_lfa::engine::{NativeSerial, NativeThreaded, SpectralBackend};
    let mut rng = Pcg64::seeded(9005);
    let kernel = ConvKernel::random_he(4, 4, 3, 3, &mut rng);
    let plan = SpectralPlan::new(&kernel, 8, 8, LfaOptions::default());
    let full = plan.execute();
    let scale = full.sigma_max();
    for backend in [&NativeSerial as &dyn SpectralBackend, &NativeThreaded { threads: 2 }] {
        let top = backend.execute_topk(&plan, 1).unwrap();
        assert!((top.spectrum.sigma_max() - full.sigma_max()).abs() <= REL_TOL * scale);
        assert!(top.iterations > 0, "{}", backend.name());
    }
}
