//! Numerical-health suite: convergence certificates, the escalation
//! ladder, and non-finite screening, end to end.
//!
//! Chaos-forced solver stalls (`SOLVER_STALL`) must surface as honest
//! certificates — retried-but-converged for a transient stall, degraded
//! for a persistent one — with degraded spectra served *flagged* and never
//! cached (memory or disk), and turned into typed errors under
//! `--strict-health`. NaN/Inf weights must be rejected with a typed error
//! before any tile runs, at every entry point: single layer, model build,
//! daemon SUBMIT, and the cache tiers.

use conv_svd_lfa::conv::ConvKernel;
use conv_svd_lfa::coordinator::{ServiceConfig, SpectralService};
use conv_svd_lfa::engine::{
    DiskCache, ModelPlan, Signature, SpectralCache, SpectralPlan, SpectrumRequest,
};
use conv_svd_lfa::error::ErrorKind;
use conv_svd_lfa::lfa::{BlockLayout, BlockSolver, Fold, LfaOptions, Precision};
use conv_svd_lfa::model::ModelConfig;
use conv_svd_lfa::numeric::Pcg64;
use conv_svd_lfa::testing::chaos;
use std::fs;
use std::path::PathBuf;
use std::sync::{Arc, Mutex, MutexGuard};

// ---------------------------------------------------------------------
// Shared plumbing (chaos state is process-global: every test in this
// file holds the guard, serializing the whole binary)
// ---------------------------------------------------------------------

struct ChaosGuard(#[allow(dead_code)] MutexGuard<'static, ()>);

impl Drop for ChaosGuard {
    fn drop(&mut self) {
        chaos::reset();
    }
}

fn chaos_guard() -> ChaosGuard {
    static LOCK: Mutex<()> = Mutex::new(());
    let guard = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    chaos::reset();
    ChaosGuard(guard)
}

struct TempDir(PathBuf);

impl TempDir {
    fn new(tag: &str) -> Self {
        let dir = std::env::temp_dir().join(format!("lfa-health-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        TempDir(dir)
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = fs::remove_dir_all(&self.0);
    }
}

const MODEL: &str = "name = \"tiny\"\nseed = 3\n\
    [[layer]]\nname = \"a\"\nc_in = 2\nc_out = 3\nheight = 8\nwidth = 8\n\
    [[layer]]\nname = \"b\"\nc_in = 3\nc_out = 2\nheight = 6\nwidth = 6\n";

/// A model whose second layer materializes to all-NaN weights
/// (`init = "const:nan"` is the config's divergence drill).
const POISONED: &str = "name = \"poisoned\"\nseed = 3\n\
    [[layer]]\nname = \"ok\"\nc_in = 2\nc_out = 3\nheight = 8\nwidth = 8\n\
    [[layer]]\nname = \"bad\"\nc_in = 2\nc_out = 2\nheight = 6\nwidth = 6\n\
    init = \"const:nan\"\n";

fn max_gap(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len(), "spectrum lengths differ");
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
}

// ---------------------------------------------------------------------
// Certificates and the escalation ladder
// ---------------------------------------------------------------------

/// A single transient stall is absorbed by the solver's internal
/// fresh-rotation restart: the sweep comes back fully converged with the
/// retry visible in the certificate, and the values are untouched.
#[test]
fn transient_stall_is_retried_and_certified_converged() {
    let _guard = chaos_guard();
    let mut rng = Pcg64::seeded(9001);
    let k = ConvKernel::random_he(3, 2, 3, 3, &mut rng);
    let opts = LfaOptions { threads: 1, ..Default::default() };
    let clean = SpectralPlan::new(&k, 6, 6, opts).execute();
    assert_eq!(clean.health.degraded_freqs, 0);
    assert_eq!(clean.health.retried_freqs, 0, "healthy run must not retry");

    chaos::arm(chaos::SOLVER_STALL, 1);
    let got = SpectralPlan::new(&k, 6, 6, opts).execute();
    assert_eq!(got.health.degraded_freqs, 0, "one stall must be recovered");
    assert!(got.health.retried_freqs >= 1, "the restart must be on the certificate");
    assert_eq!(
        got.health.converged_freqs + got.health.retried_freqs,
        clean.health.converged_freqs,
        "every solved frequency is accounted exactly once"
    );
    let scale = clean.sigma_max().max(1.0);
    let gap = max_gap(&got.values, &clean.values);
    assert!(gap <= 1e-12 * scale, "the retry must not perturb the values: gap {gap:e}");
}

/// A stall on the warm-started top-k path escalates to the full-Jacobi
/// rung: the frequency recovers, and the rung is counted.
#[test]
fn topk_stall_escalates_to_full_jacobi_and_recovers() {
    let _guard = chaos_guard();
    let mut rng = Pcg64::seeded(9002);
    let k = ConvKernel::random_he(3, 3, 3, 3, &mut rng);
    let plan = SpectralPlan::new(&k, 6, 6, LfaOptions { threads: 1, ..Default::default() });
    let clean = plan.execute_topk(1);

    chaos::arm(chaos::SOLVER_STALL, 1);
    let got = plan.execute_topk(1);
    let h = got.spectrum.health;
    assert_eq!(h.degraded_freqs, 0, "escalation must recover the frequency");
    assert!(h.retried_freqs >= 1);
    assert!(h.escalations >= 1, "the full-Jacobi rung must be counted");
    let scale = clean.spectrum.sigma_max().max(1.0);
    let gap = max_gap(&got.spectrum.values, &clean.spectrum.values);
    assert!(gap <= 1e-10 * scale, "escalated values must match: gap {gap:e}");
}

/// A persistent stall defeats the whole ladder: the spectrum ships with a
/// degraded certificate — but the values themselves stay correct (the
/// chaos point poisons certificates, not arithmetic), and the escalations
/// are all counted.
#[test]
fn persistent_stall_degrades_with_escalations_counted() {
    let _guard = chaos_guard();
    let mut rng = Pcg64::seeded(9003);
    let k = ConvKernel::random_he(3, 2, 3, 3, &mut rng);
    let opts = LfaOptions { threads: 1, ..Default::default() };
    let clean = SpectralPlan::new(&k, 6, 6, opts).execute();

    chaos::arm_always(chaos::SOLVER_STALL);
    let plan = SpectralPlan::new(&k, 6, 6, opts);
    let solved = plan.solved_freqs() as u64;
    let got = plan.execute();
    assert!(got.health.is_degraded());
    assert_eq!(got.health.degraded_freqs, solved, "every frequency stalls");
    assert_eq!(got.health.escalations, solved, "one ladder rung per frequency");
    let scale = clean.sigma_max().max(1.0);
    let gap = max_gap(&got.values, &clean.values);
    assert!(gap <= 1e-10 * scale, "degraded values are still best-effort correct: {gap:e}");
}

/// The f32 tier's f64 escalation rung really is a full-precision re-solve:
/// forcing every frequency up the ladder from an f32 plan reproduces the
/// plain-f64 spectrum to ≤ 1e-12·σ_max.
#[test]
fn escalated_f32_resolve_matches_plain_f64() {
    let _guard = chaos_guard();
    let mut rng = Pcg64::seeded(9004);
    let k = ConvKernel::random_he(4, 2, 3, 3, &mut rng);
    let base = LfaOptions { threads: 1, ..Default::default() };

    chaos::arm_always(chaos::SOLVER_STALL);
    let escalated =
        SpectralPlan::new(&k, 8, 8, LfaOptions { precision: Precision::F32, ..base }).execute();
    assert!(escalated.health.is_degraded(), "the sticky stall flags the sweep");
    assert!(escalated.health.escalations > 0, "every frequency must take the f64 rung");
    chaos::reset();

    let plain = SpectralPlan::new(&k, 8, 8, base).execute();
    assert_eq!(plain.health.degraded_freqs, 0);
    let scale = plain.sigma_max().max(1.0);
    let gap = max_gap(&escalated.values, &plain.values);
    assert!(gap <= 1e-12 * scale, "f64 rung must deliver f64 accuracy: gap {gap:e}");
}

/// Healthy-path certificates across the engine equivalence matrix: every
/// layout × solver × thread-count × folding × precision combination
/// certifies all solved frequencies with zero degraded, on the full and
/// the top-k path alike.
#[test]
fn healthy_paths_certify_across_the_matrix() {
    let _guard = chaos_guard();
    let mut rng = Pcg64::seeded(9005);
    let k = ConvKernel::random_he(3, 2, 3, 3, &mut rng);
    for layout in [BlockLayout::BlockContiguous, BlockLayout::PlanarStrided] {
        for solver in [BlockSolver::Jacobi, BlockSolver::GramEigen] {
            for threads in [1usize, 3] {
                for folding in [Fold::Auto, Fold::Off] {
                    for precision in [Precision::F64, Precision::F32, Precision::F32Refined] {
                        let opts = LfaOptions { layout, solver, threads, folding, precision };
                        let plan = SpectralPlan::new(&k, 6, 6, opts);
                        let tag = format!("{layout:?} {solver:?} x{threads} {folding:?} {precision:?}");
                        let spectrum = plan.execute();
                        let h = spectrum.health;
                        assert_eq!(h.degraded_freqs, 0, "{tag}: degraded on a healthy run");
                        assert_eq!(
                            h.converged_freqs + h.retried_freqs,
                            plan.solved_freqs() as u64,
                            "{tag}: certificate must cover every solved frequency"
                        );
                        assert!(h.worst_residual.is_finite(), "{tag}");
                        let top = plan.execute_topk(1);
                        assert_eq!(
                            top.spectrum.health.degraded_freqs, 0,
                            "{tag}: top-k degraded on a healthy run"
                        );
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// Degraded spectra: served flagged, never cached, strict-health fails
// ---------------------------------------------------------------------

/// A degraded spectrum is refused by the cache — memory *and* disk: the
/// insert is a no-op and no spill file is written.
#[test]
fn degraded_spectrum_is_never_cached_or_spilled() {
    let _guard = chaos_guard();
    let tmp = TempDir::new("degraded-cache");
    let cache =
        SpectralCache::with_budget_or_default(0).with_disk(DiskCache::open(&tmp.0).unwrap());
    let mut rng = Pcg64::seeded(9006);
    let k = ConvKernel::random_he(2, 2, 3, 3, &mut rng);
    let opts = LfaOptions { threads: 1, ..Default::default() };
    let sig = Signature::result(&k, 6, 6, 1, &opts, SpectrumRequest::Full);

    chaos::arm_always(chaos::SOLVER_STALL);
    let spectrum = SpectralPlan::new(&k, 6, 6, opts).execute();
    assert!(spectrum.health.is_degraded(), "precondition: the sweep must be degraded");
    chaos::reset();

    cache.insert(sig, Arc::new(spectrum));
    assert!(cache.get(&sig).is_none(), "a degraded spectrum must not be served back");
    let stats = cache.stats();
    assert_eq!(stats.entries, 0, "no memory entry for a degraded spectrum");
    assert_eq!(stats.disk_spills, 0, "no spill write for a degraded spectrum");
    assert_eq!(cache.disk().unwrap().len(), 0, "no spill file on disk");

    // The same signature with a healthy spectrum caches normally — the
    // gate keys on the certificate, not the signature.
    let healthy = SpectralPlan::new(&k, 6, 6, opts).execute();
    cache.insert(sig, Arc::new(healthy));
    assert!(cache.get(&sig).is_some());
    assert_eq!(cache.stats().disk_spills, 1);
}

/// Default service policy: a chaos-degraded audit is *served* — reports
/// come back flagged, metrics count the damage — and a repeat of the same
/// audit re-solves instead of hitting the cache. The same audit under
/// `strict_health` fails with the typed error.
#[test]
fn degraded_audit_is_served_flagged_and_strict_health_fails_typed() {
    let _guard = chaos_guard();
    let model = ModelConfig::parse(MODEL).unwrap();

    chaos::arm_always(chaos::SOLVER_STALL);
    let svc = SpectralService::native(2);
    let reports = svc.audit_model(&model).expect("default policy serves degraded results");
    assert!(reports.iter().all(|r| r.health.is_degraded()), "every layer is flagged");
    assert!(reports.iter().all(|r| r.sigma_max > 0.0), "values still served");
    let m = svc.metrics();
    assert!(m.degraded_freqs > 0, "degraded frequencies must be counted");
    assert!(m.escalations > 0, "ladder rungs must be counted");

    // Repeat audit: the degraded spectra were never admitted to the
    // cache, so every layer re-solves.
    let again = svc.audit_model(&model).unwrap();
    assert!(again.iter().all(|r| !r.cached), "degraded results must not serve from cache");
    svc.shutdown();

    // Strict policy: the same run is a typed job error.
    let strict = SpectralService::start(ServiceConfig {
        workers: 2,
        strict_health: true,
        ..Default::default()
    })
    .unwrap();
    let err = strict.audit_model(&model).unwrap_err();
    match err.kind() {
        ErrorKind::DegradedSpectrum { job, freqs } => {
            assert!(!job.is_empty());
            assert!(*freqs > 0, "the typed error must carry the degraded count");
        }
        other => panic!("expected DegradedSpectrum, got {other:?}"),
    }
    chaos::reset();
    // Disarmed, the strict service serves the same audit cleanly.
    let reports = strict.audit_model(&model).unwrap();
    assert!(reports.iter().all(|r| !r.health.is_degraded()));
    strict.shutdown();
}

// ---------------------------------------------------------------------
// Non-finite screening: typed rejection before any tile runs
// ---------------------------------------------------------------------

/// Single-layer path: NaN weights are rejected at submit time with the
/// typed error, before the job is ever accounted as submitted.
#[test]
fn nan_kernel_rejected_at_single_layer_submit() {
    let _guard = chaos_guard();
    let mut rng = Pcg64::seeded(9007);
    let mut k = ConvKernel::random_he(2, 2, 3, 3, &mut rng);
    k.data[5] = f64::NAN;
    k.data[7] = f64::INFINITY;

    let svc = SpectralService::native(2);
    let err = svc.analyze_layer("nan-layer", &k, 6, 6).unwrap_err();
    match err.kind() {
        ErrorKind::NonFiniteWeights { layer, count } => {
            assert!(layer.contains("nan-layer"), "layer id in the error: {layer}");
            assert_eq!(*count, 2, "both non-finite taps counted");
        }
        other => panic!("expected NonFiniteWeights, got {other:?}"),
    }
    let m = svc.metrics();
    assert_eq!(m.jobs_submitted, 0, "screening happens before submit accounting");
    assert_eq!(m.nonfinite_rejections, 1);

    // The same service still serves a healthy layer.
    let ok = ConvKernel::random_he(2, 2, 3, 3, &mut rng);
    assert!(svc.analyze_layer("ok", &ok, 6, 6).is_ok());
    svc.shutdown();
}

/// Model path: a poisoned layer fails the whole model build with the
/// typed error naming the layer, at plan time — no tile runs, and the
/// submitted-jobs counter stays untouched.
#[test]
fn nan_model_rejected_at_build_and_audit() {
    let _guard = chaos_guard();
    let model = ModelConfig::parse(POISONED).unwrap();

    // Direct plan build.
    let err = ModelPlan::build(&model, LfaOptions::default()).unwrap_err();
    match err.kind() {
        ErrorKind::NonFiniteWeights { layer, count } => {
            assert_eq!(layer, "bad");
            assert_eq!(*count, 2 * 2 * 3 * 3, "the whole const:nan tensor is non-finite");
        }
        other => panic!("expected NonFiniteWeights, got {other:?}"),
    }

    // Service audit: same typed kind survives the scheduler round-trip.
    let svc = SpectralService::native(2);
    let err = svc.audit_model(&model).unwrap_err();
    assert!(
        matches!(err.kind(), ErrorKind::NonFiniteWeights { .. }),
        "kind lost in transit: {err}"
    );
    let m = svc.metrics();
    assert_eq!(m.jobs_submitted, 0, "rejected before any layer job is accounted");
    assert_eq!(m.jobs_completed, 0);
    assert_eq!(m.nonfinite_rejections, 1);
    svc.shutdown();
}

/// Cache tier: the screen fires before the cache is consulted — a
/// poisoned model leaves a disk-backed cache completely untouched (no
/// entry, no plan, no spill file).
#[test]
fn nan_model_never_reaches_the_cache_tiers() {
    let _guard = chaos_guard();
    let tmp = TempDir::new("nan-cache");
    let cache =
        SpectralCache::with_budget_or_default(0).with_disk(DiskCache::open(&tmp.0).unwrap());
    let model = ModelConfig::parse(POISONED).unwrap();
    let err = ModelPlan::build_cached(&model, LfaOptions::default(), &cache).unwrap_err();
    assert!(matches!(err.kind(), ErrorKind::NonFiniteWeights { .. }));
    let stats = cache.stats();
    assert_eq!(stats.entries, 0, "no result entry for a rejected model");
    assert_eq!(stats.disk_spills, 0);
    assert_eq!(cache.disk().unwrap().len(), 0, "no spill file for a rejected model");
}

// ---------------------------------------------------------------------
// The daemon wire protocol
// ---------------------------------------------------------------------

#[cfg(feature = "daemon")]
mod daemon {
    use super::*;
    use conv_svd_lfa::coordinator::server::serve;
    use conv_svd_lfa::coordinator::DaemonConfig;
    use std::io::{BufRead, BufReader, Write};
    use std::net::{SocketAddr, TcpStream};
    use std::time::Duration;

    struct Client {
        reader: BufReader<TcpStream>,
        writer: TcpStream,
    }

    impl Client {
        fn connect(addr: SocketAddr) -> Self {
            let stream = TcpStream::connect(addr).unwrap();
            stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
            let reader = BufReader::new(stream.try_clone().unwrap());
            Client { reader, writer: stream }
        }

        fn send(&mut self, line: &str) -> String {
            writeln!(self.writer, "{line}").unwrap();
            let mut reply = String::new();
            self.reader.read_line(&mut reply).unwrap();
            assert!(!reply.is_empty(), "daemon closed the connection on {line:?}");
            reply.trim_end().to_string()
        }
    }

    fn field<'a>(reply: &'a str, key: &str) -> &'a str {
        reply
            .split_whitespace()
            .find_map(|tok| tok.strip_prefix(key).and_then(|t| t.strip_prefix('=')))
            .unwrap_or_else(|| panic!("no {key}= in {reply:?}"))
    }

    fn daemon(service: ServiceConfig) -> DaemonConfig {
        DaemonConfig { service, addr: "127.0.0.1:0".to_string(), ..Default::default() }
    }

    fn write_file(dir: &TempDir, name: &str, contents: &str) -> String {
        let path = dir.0.join(name);
        fs::write(&path, contents).unwrap();
        path.to_str().unwrap().to_string()
    }

    /// A NaN model submitted over the socket dies with `ERR nonfinite`
    /// before any solve: `jobs_submitted` stays zero and the daemon keeps
    /// serving healthy submissions.
    #[test]
    fn daemon_rejects_nonfinite_model_before_any_solve() {
        let _guard = chaos_guard();
        let tmp = TempDir::new("daemon-nan");
        let poisoned = write_file(&tmp, "poisoned.toml", POISONED);
        let healthy = write_file(&tmp, "model.toml", MODEL);
        let handle = serve(daemon(ServiceConfig::default())).unwrap();
        let mut c = Client::connect(handle.addr());

        let id = field(&c.send(&format!("SUBMIT t1 {poisoned}")), "id").to_string();
        let reply = c.send(&format!("WAIT {id}"));
        assert!(
            reply.starts_with("ERR nonfinite id="),
            "typed nonfinite reply expected: {reply}"
        );
        assert_eq!(field(&reply, "layer"), "bad");
        assert_eq!(field(&reply, "count"), "36");
        let metrics = c.send("METRICS");
        assert!(
            metrics.contains("jobs_submitted=0"),
            "rejected before submit accounting: {metrics}"
        );
        assert!(metrics.contains("nonfinite_rejections=1"), "{metrics}");

        // The daemon is unpoisoned: a healthy model completes.
        let id2 = field(&c.send(&format!("SUBMIT t1 {healthy}")), "id").to_string();
        assert!(c.send(&format!("WAIT {id2}")).starts_with("DONE"));
        assert_eq!(c.send("SHUTDOWN"), "OK shutting-down");
        handle.wait();
    }

    /// Degraded-but-served over the wire: the job completes, the health
    /// metrics are exported, and a repeat submit re-solves (never cached).
    /// The same submission against a `--strict-health` daemon is a typed
    /// `ERR degraded` failure.
    #[test]
    fn daemon_serves_degraded_flagged_and_strict_health_fails() {
        let _guard = chaos_guard();
        let tmp = TempDir::new("daemon-degraded");
        let model = write_file(&tmp, "model.toml", MODEL);

        chaos::arm_always(chaos::SOLVER_STALL);
        let handle = serve(daemon(ServiceConfig::default())).unwrap();
        let mut c = Client::connect(handle.addr());
        let id = field(&c.send(&format!("SUBMIT t1 {model}")), "id").to_string();
        let done = c.send(&format!("WAIT {id}"));
        assert!(done.starts_with("DONE"), "default policy serves degraded: {done}");
        let metrics = c.send("METRICS");
        assert!(!metrics.contains("degraded_freqs=0"), "damage must be exported: {metrics}");
        for key in ["degraded_freqs=", "escalations=", "nonfinite_rejections="] {
            assert!(metrics.contains(key), "METRICS must report {key}: {metrics}");
        }
        // Repeat: the degraded spectra were never cached.
        let id2 = field(&c.send(&format!("SUBMIT t1 {model}")), "id").to_string();
        let done2 = c.send(&format!("WAIT {id2}"));
        assert_eq!(field(&done2, "cached"), "0", "degraded must not serve from cache: {done2}");
        assert_eq!(c.send("SHUTDOWN"), "OK shutting-down");
        handle.wait();

        // Strict daemon, same chaos: typed failure on the wire.
        let handle = serve(daemon(ServiceConfig {
            strict_health: true,
            ..Default::default()
        }))
        .unwrap();
        let mut c = Client::connect(handle.addr());
        let id = field(&c.send(&format!("SUBMIT t1 {model}")), "id").to_string();
        let reply = c.send(&format!("WAIT {id}"));
        assert!(
            reply.starts_with("ERR degraded job=") && reply.contains("freqs="),
            "strict health must fail typed: {reply}"
        );
        chaos::reset();
        // Disarmed, the strict daemon completes the same model.
        let id2 = field(&c.send(&format!("SUBMIT t1 {model}")), "id").to_string();
        assert!(c.send(&format!("WAIT {id2}")).starts_with("DONE"));
        assert_eq!(c.send("SHUTDOWN"), "OK shutting-down");
        handle.wait();
    }
}
