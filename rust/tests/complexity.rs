//! Complexity regression tests for Table I: operation-count models and
//! coarse runtime-scaling checks (kept loose — single-core CI box).

use conv_svd_lfa::baselines::{explicit_svd, fft_svd, FftLayoutPolicy};
use conv_svd_lfa::conv::ConvKernel;
use conv_svd_lfa::lfa::{self, svd::flops_estimate, LfaOptions};
use conv_svd_lfa::numeric::Pcg64;
use std::time::Instant;

#[test]
fn lfa_flops_model_is_linear_in_grid() {
    // O(n·m·c³): doubling the grid area doubles the estimate.
    let base = flops_estimate(16, 16, 8, 8, 3, 3);
    let double_area = flops_estimate(32, 16, 8, 8, 3, 3);
    assert!((double_area / base - 2.0).abs() < 1e-12);
    // O(c³) in channels (same c_in = c_out = c): 2x channels → ~8x SVD part.
    let c8 = flops_estimate(16, 16, 8, 8, 3, 3);
    let c16 = flops_estimate(16, 16, 16, 16, 3, 3);
    let ratio = c16 / c8;
    assert!(ratio > 6.0 && ratio < 9.0, "channel scaling ratio {ratio}");
}

#[test]
fn lfa_transform_runtime_scales_linearly() {
    // s_F(2n) / s_F(n) ≈ 4 (area) — allow a generous band for timer noise.
    let mut rng = Pcg64::seeded(200);
    let k = ConvKernel::random_he(16, 16, 3, 3, &mut rng);
    let time_symbols = |n: usize| {
        // Warm + best-of-3 to de-noise.
        let mut best = f64::INFINITY;
        for _ in 0..3 {
            let t0 = Instant::now();
            std::hint::black_box(lfa::compute_symbols(
                &k,
                n,
                n,
                lfa::BlockLayout::BlockContiguous,
            ));
            best = best.min(t0.elapsed().as_secs_f64());
        }
        best
    };
    // Both points chosen beyond LLC capacity (64 MB and 256 MB outputs):
    // comparing an in-cache with an out-of-cache size inflates the ratio.
    let t128 = time_symbols(128);
    let t256 = time_symbols(256);
    let ratio = t256 / t128;
    assert!(
        ratio > 2.0 && ratio < 8.5,
        "area scaling ratio {ratio} (want ≈4)"
    );
}

#[test]
fn explicit_memory_model_grows_quartically() {
    let k = ConvKernel::zeros(16, 16, 3, 3);
    let b16 = explicit_svd::dense_bytes(&k, 16, 16) as f64;
    let b32 = explicit_svd::dense_bytes(&k, 32, 32) as f64;
    assert_eq!(b32 / b16, 16.0, "n⁴ growth");
}

#[test]
fn lfa_beats_fft_transform_time_at_scale() {
    // Table III's s_F column: the LFA transform must be faster than the FFT
    // transform for reasonably sized grids (here n=64, c=16).
    let mut rng = Pcg64::seeded(201);
    let k = ConvKernel::random_he(16, 16, 3, 3, &mut rng);
    let n = 64;
    let mut lfa_best = f64::INFINITY;
    let mut fft_best = f64::INFINITY;
    for _ in 0..3 {
        let t0 = Instant::now();
        std::hint::black_box(lfa::compute_symbols(&k, n, n, lfa::BlockLayout::BlockContiguous));
        lfa_best = lfa_best.min(t0.elapsed().as_secs_f64());
        let t0 = Instant::now();
        std::hint::black_box(fft_svd::fft_symbols(&k, n, n));
        fft_best = fft_best.min(t0.elapsed().as_secs_f64());
    }
    assert!(
        lfa_best < fft_best,
        "LFA transform {lfa_best:.4}s should beat FFT transform {fft_best:.4}s"
    );
}

#[test]
fn total_value_counts_match_paper_formula() {
    // Paper: n=256, c=16 → 1,048,576 singular values (n²·c).
    let count = |n: usize, c: usize| n * n * c;
    assert_eq!(count(256, 16), 1_048_576);
    assert_eq!(count(16384, 16), 4_294_967_296usize);
    // And our Spectrum delivers exactly that many.
    let mut rng = Pcg64::seeded(202);
    let k = ConvKernel::random_he(4, 4, 3, 3, &mut rng);
    let s = lfa::singular_values(&k, 10, 10, LfaOptions::default());
    assert_eq!(s.num_values(), 400);
}

#[test]
fn fft_layout_conversion_cost_is_real() {
    // Table IV: converting the FFT's planar layout to block-contiguous
    // costs measurable time (s_copy > 0) and grows with the grid.
    let mut rng = Pcg64::seeded(203);
    let k = ConvKernel::random_he(16, 16, 3, 3, &mut rng);
    let (_, t) = fft_svd::singular_values_timed(&k, 32, 32, FftLayoutPolicy::ConvertToContiguous, 1);
    assert!(t.copy.as_nanos() > 0);
    let (_, t_nat) = fft_svd::singular_values_timed(&k, 32, 32, FftLayoutPolicy::Natural, 1);
    // Natural policy does no conversion: its "copy" stage is just the timer
    // overhead around a no-op branch.
    assert!(t_nat.copy < t.copy, "no-op copy {:?} vs real copy {:?}", t_nat.copy, t.copy);
    assert!(t_nat.copy.as_micros() < 1000);
}
