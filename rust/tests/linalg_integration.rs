//! Cross-algorithm linear-algebra consistency at sizes larger than the
//! unit tests: Golub–Kahan vs Jacobi vs Gram-eigen vs power iteration.

use conv_svd_lfa::linalg::{gk_svd, jacobi_eig, jacobi_svd, norms, power, qr};
use conv_svd_lfa::numeric::{CMat, Mat, Pcg64};

#[test]
fn four_solvers_agree_on_real_matrices() {
    let mut rng = Pcg64::seeded(100);
    for &(m, n) in &[(24usize, 24usize), (40, 17), (17, 40)] {
        let a = Mat::random_normal(m, n, &mut rng);
        let s_gk = gk_svd::singular_values(&a);
        let ac = CMat::from_real(&a);
        let s_j = jacobi_svd::singular_values(&ac);
        let s_g = jacobi_eig::singular_values_gram(&ac);
        for i in 0..n.min(m) {
            assert!((s_gk[i] - s_j[i]).abs() < 1e-8, "{m}x{n} gk/jacobi idx {i}");
            assert!((s_gk[i] - s_g[i]).abs() < 1e-6, "{m}x{n} gk/gram idx {i}");
        }
        let p = power::spectral_norm(&a, 3000, 1e-12, &mut rng);
        assert!(
            (p.sigma_max - s_gk[0]).abs() / s_gk[0] < 1e-6,
            "{m}x{n} power {} vs {}",
            p.sigma_max,
            s_gk[0]
        );
        assert!(norms::holder_bound(&a) >= s_gk[0] * (1.0 - 1e-12));
    }
}

#[test]
fn graded_singular_values_resolved() {
    // Matrix with exponentially graded spectrum: σ_i = 2^-i, built from
    // random orthogonal factors; all solvers must resolve the grading.
    let n = 12;
    let mut rng = Pcg64::seeded(101);
    let qa = qr::qr(&Mat::random_normal(n, n, &mut rng)).q;
    let qb = qr::qr(&Mat::random_normal(n, n, &mut rng)).q;
    let mut a = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            let mut acc = 0.0;
            for k in 0..n {
                acc += qa[(i, k)] * 0.5f64.powi(k as i32) * qb[(j, k)];
            }
            a[(i, j)] = acc;
        }
    }
    let s = gk_svd::singular_values(&a);
    let sj = jacobi_svd::singular_values(&CMat::from_real(&a));
    for i in 0..n {
        let want = 0.5f64.powi(i as i32);
        assert!((s[i] - want).abs() / want < 1e-8, "gk idx {i}: {} vs {want}", s[i]);
        assert!((sj[i] - want).abs() / want < 1e-8, "jacobi idx {i}");
    }
}

#[test]
fn gk_full_svd_at_scale() {
    let mut rng = Pcg64::seeded(102);
    let (m, n) = (60, 45);
    let a = Mat::random_normal(m, n, &mut rng);
    let r = gk_svd::svd(&a, true);
    let u = r.u.as_ref().unwrap();
    let vt = r.vt.as_ref().unwrap();
    // Reconstruct.
    let mut us = Mat::zeros(m, n);
    for i in 0..m {
        for j in 0..n {
            us[(i, j)] = u[(i, j)] * r.s[j];
        }
    }
    let recon = us.matmul(vt);
    assert!(recon.max_abs_diff(&a) < 1e-8);
    assert!(qr::orthonormality_defect(u) < 1e-9);
    assert!(qr::orthonormality_defect(&vt.transpose()) < 1e-9);
}

#[test]
fn jacobi_svd_full_at_scale_complex() {
    let mut rng = Pcg64::seeded(103);
    let a = CMat::random_normal(32, 20, &mut rng);
    let dec = jacobi_svd::svd(&a);
    assert!(dec.u.orthonormality_defect() < 1e-9);
    assert!(dec.v.orthonormality_defect() < 1e-9);
    // A v_i == σ_i u_i
    for j in 0..dec.s.len() {
        let v: Vec<_> = (0..20).map(|i| dec.v[(i, j)]).collect();
        let av = a.matvec(&v);
        for i in 0..32 {
            let want = dec.u[(i, j)].scale(dec.s[j]);
            assert!((av[i] - want).abs() < 1e-9, "col {j} row {i}");
        }
    }
}

#[test]
fn hermitian_eigh_at_scale() {
    let mut rng = Pcg64::seeded(104);
    let n = 24;
    let a = CMat::random_normal(n, n, &mut rng);
    let mut h = CMat::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            h[(i, j)] = (a[(i, j)] + a[(j, i)].conj()).scale(0.5);
        }
    }
    let e = jacobi_eig::eigh(&h);
    assert!(e.q.orthonormality_defect() < 1e-9);
    // H q_i == λ_i q_i
    for j in 0..n {
        let q: Vec<_> = (0..n).map(|i| e.q[(i, j)]).collect();
        let hq = h.matvec(&q);
        for i in 0..n {
            let want = e.q[(i, j)].scale(e.lambda[j]);
            assert!((hq[i] - want).abs() < 1e-8, "eigpair {j}");
        }
    }
}

#[test]
fn near_degenerate_spectrum() {
    // Clustered singular values (σ = 1, 1, 1, 1e-1, 1e-1) must come out
    // grouped correctly from both SVD routes.
    let n = 5;
    let mut rng = Pcg64::seeded(105);
    let qa = qr::qr(&Mat::random_normal(n, n, &mut rng)).q;
    let qb = qr::qr(&Mat::random_normal(n, n, &mut rng)).q;
    let sig = [1.0, 1.0, 1.0, 0.1, 0.1];
    let mut a = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            let mut acc = 0.0;
            for k in 0..n {
                acc += qa[(i, k)] * sig[k] * qb[(j, k)];
            }
            a[(i, j)] = acc;
        }
    }
    for s in [gk_svd::singular_values(&a), jacobi_svd::singular_values(&CMat::from_real(&a))] {
        for (got, want) in s.iter().zip(&sig) {
            assert!((got - want).abs() < 1e-9, "{got} vs {want}");
        }
    }
}
