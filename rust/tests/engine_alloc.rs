//! Acceptance check for the engine's allocation discipline: after a plan's
//! first (warm-up) execution has populated the workspace pool,
//! `execute_request_into` on a caller-provided buffer performs **zero heap
//! allocation** — the per-frequency hot loop only touches preallocated
//! scratch, and the sink indirection of the unified sweep
//! (`sweep_with`, `density`) adds none of its own. Verified with a
//! counting global allocator; this file holds only these tests so
//! unrelated parallel tests cannot perturb the counter.

use conv_svd_lfa::conv::ConvKernel;
use conv_svd_lfa::engine::{
    DensityRequest, DensitySink, FullAssembly, ModelPlan, SpectralCache, SpectralPlan,
    SpectrumRequest, SweepOptions,
};
use conv_svd_lfa::lfa::{BlockSolver, Fold, LfaOptions, Precision};
use conv_svd_lfa::model::ModelConfig;
use conv_svd_lfa::numeric::Pcg64;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn assert_zero_alloc_after_warmup(
    solver: BlockSolver,
    stride: usize,
    folding: Fold,
    precision: Precision,
) {
    let mut rng = Pcg64::seeded(8000 + stride as u64);
    let kernel = ConvKernel::random_he(4, 4, 3, 3, &mut rng);
    let opts = LfaOptions { solver, threads: 1, folding, precision, ..Default::default() };
    let plan = SpectralPlan::with_stride(&kernel, 8, 8, stride, opts);
    let mut out = vec![0.0f64; plan.values_len()];
    let full = SpectrumRequest::Full;
    let opts = SweepOptions::default();
    // Warm-up: the pool may grow its spine / solver scratch once.
    plan.execute_request_into(full, opts, &mut out);
    let before = ALLOCS.load(Ordering::SeqCst);
    plan.execute_request_into(full, opts, &mut out);
    plan.execute_request_into(full, opts, &mut out);
    let after = ALLOCS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "{solver:?} stride {stride} {folding:?} {precision:?}: {} allocation(s) in \
         warmed-up execute_request_into",
        after - before
    );
    assert!(out.iter().all(|v| v.is_finite() && *v >= 0.0));
}

/// Top-k discipline: after one warm-up sweep has sized the Krylov
/// scratch, the warm-started `TopK(k)` hot loop — symbol fill, Lanczos
/// steps with full reorthogonalization, the tridiagonal solves, the
/// completion probe, the warm-hint carry between frequencies — performs
/// zero heap allocation, for both warm and per-frequency-cold sweeps.
fn assert_topk_zero_alloc_after_warmup(
    stride: usize,
    k: usize,
    folding: Fold,
    precision: Precision,
) {
    let mut rng = Pcg64::seeded(8100 + stride as u64);
    let kernel = ConvKernel::random_he(4, 4, 3, 3, &mut rng);
    let opts = LfaOptions { threads: 1, folding, precision, ..Default::default() };
    let plan = SpectralPlan::with_stride(&kernel, 8, 8, stride, opts);
    let request = SpectrumRequest::TopK(k);
    let mut out = vec![0.0f64; plan.topk_values_len(k)];
    // Warm-up: the pool may grow its spine / Krylov scratch once.
    plan.execute_request_into(request, SweepOptions::default(), &mut out);
    let before = ALLOCS.load(Ordering::SeqCst);
    plan.execute_request_into(request, SweepOptions::default(), &mut out);
    plan.execute_request_into(request, SweepOptions { threads: Some(1), cold_start: true }, &mut out);
    let after = ALLOCS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "topk k={k} stride {stride} {folding:?} {precision:?}: {} allocation(s) in \
         warmed-up TopK execute_request_into",
        after - before
    );
    assert!(out.iter().all(|v| v.is_finite() && *v >= 0.0));
}

/// Sink discipline: the unified sweep's sink indirection is free. A
/// warmed-up `sweep_with` into a [`FullAssembly`] strip — the exact
/// code path `execute_request_into` drives per worker — performs zero
/// heap allocation per frequency, and so does a warmed-up census
/// [`DensitySink`] sweep re-using a preallocated histogram (the
/// `density()` convenience allocates its result object; the hot loop
/// behind it must not).
fn assert_sink_zero_alloc_after_warmup() {
    let mut rng = Pcg64::seeded(8300);
    let kernel = ConvKernel::random_he(4, 4, 3, 3, &mut rng);
    let plan =
        SpectralPlan::new(&kernel, 8, 8, LfaOptions { threads: 1, ..Default::default() });
    let mut out = vec![0.0f64; plan.values_len()];
    // Warm-up sizes the pool once.
    {
        let mut sink = FullAssembly::strip(&plan, 0, &mut out);
        plan.sweep_with(SpectrumRequest::Full, &mut sink);
    }
    let before = ALLOCS.load(Ordering::SeqCst);
    {
        let mut sink = FullAssembly::strip(&plan, 0, &mut out);
        plan.sweep_with(SpectrumRequest::Full, &mut sink);
    }
    let after = ALLOCS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "{} allocation(s) in a warmed-up sweep_with(FullAssembly)",
        after - before
    );
    // The density sink itself: histogram commits + mirror weighting stay
    // allocation-free once the sink's buffers exist.
    let mut sink = DensitySink::new(&plan, 32, 10.0);
    plan.sweep_with(SpectrumRequest::Full, &mut sink);
    let before = ALLOCS.load(Ordering::SeqCst);
    plan.sweep_with(SpectrumRequest::Full, &mut sink);
    let after = ALLOCS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "{} allocation(s) in a warmed-up sweep_with(DensitySink)",
        after - before
    );
    // End-to-end guard at the API surface: a repeat `density()` census
    // allocates only its result object (bins vector + health ledger),
    // never per frequency — bounded by a small constant, not the grid.
    let req = DensityRequest { bins: 32, sample: 1 };
    let _ = plan.density(req);
    let before = ALLOCS.load(Ordering::SeqCst);
    let d = plan.density(req);
    let after = ALLOCS.load(Ordering::SeqCst);
    assert!(
        after - before <= 16,
        "{} allocation(s) in a warmed-up density() census — per-frequency leak?",
        after - before
    );
    assert!(d.count() > 0);
}

/// Whole-model discipline: a warmed-up serial `ModelPlan::execute_into` —
/// the group-major batched sweep over every layer, including an
/// equal-shape group sharing one workspace pool and a strided layer —
/// performs zero heap allocation.
fn assert_model_zero_alloc_after_warmup() {
    let model = ModelConfig::parse(
        "name = \"alloc\"\nseed = 13\n\
         [[layer]]\nname = \"a1\"\nc_in = 3\nc_out = 4\nheight = 8\nwidth = 8\n\
         [[layer]]\nname = \"a2\"\nc_in = 3\nc_out = 4\nheight = 8\nwidth = 8\n\
         [[layer]]\nname = \"s\"\nc_in = 2\nc_out = 4\nheight = 8\nwidth = 8\nstride = 2\n",
    )
    .unwrap();
    let plan =
        ModelPlan::build(&model, LfaOptions { threads: 1, ..Default::default() }).unwrap();
    let mut out = vec![0.0f64; plan.values_len()];
    // Warm-up: pools may grow solver scratch once.
    plan.execute_into(&mut out);
    let before = ALLOCS.load(Ordering::SeqCst);
    plan.execute_into(&mut out);
    plan.execute_into(&mut out);
    let after = ALLOCS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "{} allocation(s) in warmed-up whole-model execute_into",
        after - before
    );
    assert!(out.iter().all(|v| v.is_finite() && *v >= 0.0));
}

/// Cache discipline: serving a repeat spectrum is a hash lookup. After a
/// result is cached, computing the content signature (FNV over the weight
/// bits, no buffers) plus the lookup itself (`Arc` clone of the stored
/// spectrum) performs **zero heap allocation** — no per-frequency scratch
/// is ever touched on a hit.
fn assert_cache_hit_zero_alloc() {
    let mut rng = Pcg64::seeded(8200);
    let kernel = ConvKernel::random_he(4, 4, 3, 3, &mut rng);
    let plan = SpectralPlan::new(&kernel, 8, 8, LfaOptions { threads: 1, ..Default::default() });
    let cache = SpectralCache::new();
    let key = plan.result_signature(SpectrumRequest::Full);
    cache.insert(key, Arc::new(plan.execute()));
    // Warm-up lookup (the map sized itself at insert time).
    assert!(cache.get(&key).is_some());
    let before = ALLOCS.load(Ordering::SeqCst);
    let rekeyed = plan.result_signature(SpectrumRequest::Full);
    let hit = cache.get(&rekeyed);
    let again = cache.get(&rekeyed);
    let after = ALLOCS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "{} allocation(s) in a warmed-up signature + cache hit",
        after - before
    );
    assert!(hit.is_some() && again.is_some());
}

// One test, sequential scenarios: the harness runs #[test] fns on separate
// threads, and concurrent tests would pollute each other's counter windows.
// Both folding modes are covered: the folded hot loop (solve the
// fundamental domain + in-row mirror + `mirror_fill` assembly) must be as
// allocation-free as the unfolded reference. So are all three precision
// tiers: the f32 planes/scratch and the refinement scratch are sized at
// plan/checkout time, so the reduced-precision hot loops (and the
// per-frequency f64 polish of `F32Refined`) allocate nothing either.
#[test]
fn execute_is_allocation_free_after_warmup() {
    assert_zero_alloc_after_warmup(BlockSolver::Jacobi, 1, Fold::Auto, Precision::F64);
    assert_zero_alloc_after_warmup(BlockSolver::Jacobi, 1, Fold::Off, Precision::F64);
    assert_zero_alloc_after_warmup(BlockSolver::GramEigen, 1, Fold::Auto, Precision::F64);
    assert_zero_alloc_after_warmup(BlockSolver::GramEigen, 1, Fold::Off, Precision::F64);
    assert_zero_alloc_after_warmup(BlockSolver::Jacobi, 2, Fold::Auto, Precision::F64);
    assert_zero_alloc_after_warmup(BlockSolver::Jacobi, 2, Fold::Off, Precision::F64);
    assert_zero_alloc_after_warmup(BlockSolver::Jacobi, 1, Fold::Auto, Precision::F32);
    assert_zero_alloc_after_warmup(BlockSolver::Jacobi, 2, Fold::Off, Precision::F32);
    assert_zero_alloc_after_warmup(BlockSolver::GramEigen, 1, Fold::Auto, Precision::F32);
    assert_zero_alloc_after_warmup(BlockSolver::Jacobi, 1, Fold::Auto, Precision::F32Refined);
    assert_zero_alloc_after_warmup(BlockSolver::Jacobi, 2, Fold::Off, Precision::F32Refined);
    assert_topk_zero_alloc_after_warmup(1, 2, Fold::Auto, Precision::F64);
    assert_topk_zero_alloc_after_warmup(1, 2, Fold::Off, Precision::F64);
    assert_topk_zero_alloc_after_warmup(2, 1, Fold::Auto, Precision::F64);
    assert_topk_zero_alloc_after_warmup(2, 1, Fold::Off, Precision::F64);
    assert_topk_zero_alloc_after_warmup(1, 2, Fold::Auto, Precision::F32);
    assert_topk_zero_alloc_after_warmup(2, 1, Fold::Off, Precision::F32);
    assert_topk_zero_alloc_after_warmup(1, 2, Fold::Auto, Precision::F32Refined);
    assert_sink_zero_alloc_after_warmup();
    assert_model_zero_alloc_after_warmup();
    assert_cache_hit_zero_alloc();
}
