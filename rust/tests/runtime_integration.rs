//! Integration: rust PJRT runtime executes the AOT artifacts and the
//! numbers agree with the native LFA implementation.
//!
//! Requires a build with `--features pjrt` (the whole file is compiled out
//! otherwise) and `make artifacts` to have run (skips with a message if the
//! manifest is missing).
#![cfg(feature = "pjrt")]

use conv_svd_lfa::conv::ConvKernel;
use conv_svd_lfa::lfa::{self, LfaOptions};
use conv_svd_lfa::numeric::Pcg64;
use conv_svd_lfa::runtime::{load_manifest, select, PjrtEngine, PjrtExecutor};
use std::path::PathBuf;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.txt").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

fn random_kernel(spec: &conv_svd_lfa::runtime::ArtifactSpec, seed: u64) -> ConvKernel {
    let mut rng = Pcg64::seeded(seed);
    ConvKernel::random_he(spec.c_out, spec.c_in, spec.kh, spec.kw, &mut rng)
}

fn native_values(kernel: &ConvKernel, n: usize, m: usize) -> Vec<f64> {
    lfa::singular_values(kernel, n, m, LfaOptions::default()).values
}

fn check_close(pjrt: &[f32], native: &[f64], scale: f64, what: &str) {
    assert_eq!(pjrt.len(), native.len(), "{what}: length");
    for (i, (a, b)) in pjrt.iter().zip(native).enumerate() {
        assert!(
            (*a as f64 - b).abs() < 2e-4 * scale.max(1.0),
            "{what}: idx {i}: pjrt {a} vs native {b}"
        );
    }
}

#[test]
fn whole_grid_artifact_matches_native() {
    let Some(dir) = artifacts_dir() else { return };
    let specs = load_manifest(&dir).unwrap();
    let spec = select(&specs, 8, 8, 4, 4, 3, 3, false).expect("8x8 c4 artifact");
    let kernel = random_kernel(spec, 2024);
    let w: Vec<f32> = kernel.data.iter().map(|&v| v as f32).collect();
    let mut engine = PjrtEngine::cpu().unwrap();
    let got = engine.run_grid(spec, &w).unwrap();
    let want = native_values(&kernel, 8, 8);
    let scale = want.iter().cloned().fold(0.0, f64::max);
    check_close(&got, &want, scale, "whole grid");
}

#[test]
fn tiled_artifact_stitches_to_native() {
    let Some(dir) = artifacts_dir() else { return };
    let specs = load_manifest(&dir).unwrap();
    let spec = select(&specs, 32, 32, 16, 16, 3, 3, true).expect("tiled 32x32 artifact");
    assert!(!spec.is_whole_grid(), "selection should pick the tiled variant");
    let kernel = random_kernel(spec, 7);
    let w: Vec<f32> = kernel.data.iter().map(|&v| v as f32).collect();
    let mut engine = PjrtEngine::cpu().unwrap();
    // Execute the tiles out of order to prove offset-independence.
    let mut got = vec![0f32; spec.n * spec.m * spec.rank];
    let per_call = spec.out_len();
    let mut offsets: Vec<usize> = (0..spec.calls_for_grid()).collect();
    offsets.reverse();
    for c in offsets {
        let row = c * spec.tile_rows;
        let tile = engine.run_tile(spec, &w, row as i32).unwrap();
        got[c * per_call..(c + 1) * per_call].copy_from_slice(&tile);
    }
    let want = native_values(&kernel, 32, 32);
    let scale = want.iter().cloned().fold(0.0, f64::max);
    check_close(&got, &want, scale, "tiled grid");
}

#[test]
fn executor_thread_serves_many_clients() {
    let Some(dir) = artifacts_dir() else { return };
    let specs = load_manifest(&dir).unwrap();
    let spec = select(&specs, 16, 16, 8, 8, 3, 3, false).expect("16x16 c8 artifact").clone();
    let exec = PjrtExecutor::spawn().unwrap();
    let kernel = random_kernel(&spec, 99);
    let w: Vec<f32> = kernel.data.iter().map(|&v| v as f32).collect();
    let want = native_values(&kernel, 16, 16);
    let scale = want.iter().cloned().fold(0.0, f64::max);
    std::thread::scope(|s| {
        for t in 0..4 {
            let exec = exec.clone();
            let spec = spec.clone();
            let w = w.clone();
            let want = want.clone();
            s.spawn(move || {
                let got = exec.run_grid(&spec, &w).unwrap();
                check_close(&got, &want, scale, &format!("client {t}"));
            });
        }
    });
}

#[test]
fn rejects_wrong_weight_length() {
    let Some(dir) = artifacts_dir() else { return };
    let specs = load_manifest(&dir).unwrap();
    let spec = select(&specs, 8, 8, 4, 4, 3, 3, false).unwrap();
    let mut engine = PjrtEngine::cpu().unwrap();
    assert!(engine.run_tile(spec, &[0f32; 3], 0).is_err());
}
