//! Fault-injection suite for the service and the `lfa-convd` daemon:
//! worker panics mid-tile, injected tile failures, disk-spill write
//! failures, client disconnects mid-request, slow consumers, and request
//! timeouts. Every fault must degrade gracefully — a typed error reply,
//! no hang, no poisoned scheduler state, and subsequent requests served.
#![cfg(feature = "daemon")]

use conv_svd_lfa::coordinator::server::serve;
use conv_svd_lfa::coordinator::{DaemonConfig, ServiceConfig, SpectralService};
use conv_svd_lfa::engine::{DiskCache, Signature, SpectralCache, SpectrumRequest};
use conv_svd_lfa::lfa::{self, LfaOptions};
use conv_svd_lfa::model::ModelConfig;
use conv_svd_lfa::numeric::Pcg64;
use conv_svd_lfa::testing::chaos;
use std::fs;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::sync::{Mutex, MutexGuard};
use std::time::Duration;

// ---------------------------------------------------------------------
// Shared plumbing
// ---------------------------------------------------------------------

/// Chaos state is process-global and these tests run as parallel threads
/// of one binary — an injection point armed by one test could fire inside
/// another's scheduler tiles. *Every* test in this file holds this guard
/// (serializing the whole file), and chaos is disarmed on entry and on
/// drop (even when the test itself panics).
struct ChaosGuard(#[allow(dead_code)] MutexGuard<'static, ()>);

impl Drop for ChaosGuard {
    fn drop(&mut self) {
        chaos::reset();
    }
}

fn chaos_guard() -> ChaosGuard {
    static LOCK: Mutex<()> = Mutex::new(());
    // A previous test panicking while holding the lock is fine — chaos
    // state is reset on entry either way.
    let guard = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    chaos::reset();
    ChaosGuard(guard)
}

struct TempDir(PathBuf);

impl TempDir {
    fn new(tag: &str) -> Self {
        let dir = std::env::temp_dir().join(format!("lfa-daemon-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        TempDir(dir)
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = fs::remove_dir_all(&self.0);
    }
}

const MODEL: &str = "name = \"tiny\"\nseed = 3\n\
    [[layer]]\nname = \"a\"\nc_in = 2\nc_out = 3\nheight = 8\nwidth = 8\n\
    [[layer]]\nname = \"b\"\nc_in = 3\nc_out = 2\nheight = 6\nwidth = 6\n";

fn write_model(dir: &TempDir) -> String {
    let path = dir.0.join("model.toml");
    fs::write(&path, MODEL).unwrap();
    path.to_str().unwrap().to_string()
}

struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    fn connect(addr: SocketAddr) -> Self {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let reader = BufReader::new(stream.try_clone().unwrap());
        Client { reader, writer: stream }
    }

    fn send(&mut self, line: &str) -> String {
        writeln!(self.writer, "{line}").unwrap();
        let mut reply = String::new();
        self.reader.read_line(&mut reply).unwrap();
        assert!(!reply.is_empty(), "daemon closed the connection on {line:?}");
        reply.trim_end().to_string()
    }
}

/// Pull `key=` out of a `DONE …` / `QUEUED …` reply.
fn field<'a>(reply: &'a str, key: &str) -> &'a str {
    reply
        .split_whitespace()
        .find_map(|tok| tok.strip_prefix(key).and_then(|t| t.strip_prefix('=')))
        .unwrap_or_else(|| panic!("no {key}= in {reply:?}"))
}

fn daemon(service: ServiceConfig, tweak: impl FnOnce(&mut DaemonConfig)) -> DaemonConfig {
    let mut config = DaemonConfig {
        service,
        addr: "127.0.0.1:0".to_string(),
        ..Default::default()
    };
    tweak(&mut config);
    config
}

// ---------------------------------------------------------------------
// Chaos at the service layer: typed errors, no poisoned state
// ---------------------------------------------------------------------

/// A worker panicking mid-tile must surface as a typed job error — and
/// the scheduler (threads, locks, queue) must stay fully usable after.
#[test]
fn worker_panic_degrades_to_typed_error_and_service_survives() {
    let _guard = chaos_guard();
    let model = ModelConfig::parse(MODEL).unwrap();
    let svc = SpectralService::native(2);
    chaos::arm(chaos::TILE_PANIC, 1);
    let err = svc.audit_model(&model).unwrap_err().to_string();
    assert!(err.contains("panicked mid-tile"), "untyped panic error: {err}");
    chaos::reset();
    // No poisoned mutexes, no dead workers: the same service serves the
    // same audit cleanly.
    let reports = svc.audit_model(&model).unwrap();
    assert!(reports.iter().all(|r| r.sigma_max > 0.0));
    svc.shutdown();
}

/// An injected tile *failure* (typed error, no unwinding) takes the same
/// graceful path.
#[test]
fn injected_tile_failure_is_typed_and_recoverable() {
    let _guard = chaos_guard();
    let model = ModelConfig::parse(MODEL).unwrap();
    let svc = SpectralService::native(2);
    chaos::arm(chaos::TILE_ERROR, 1);
    let err = svc.audit_model(&model).unwrap_err().to_string();
    assert!(err.contains("chaos: injected tile failure"), "unexpected error: {err}");
    chaos::reset();
    assert!(svc.audit_model(&model).is_ok());
    let m = svc.metrics();
    assert!(m.jobs_failed > 0, "the failed job must be accounted");
    svc.shutdown();
}

/// A failing spill write (full/read-only disk) must not fail the job —
/// the tier degrades to memory-only for that entry and heals on the next
/// write.
#[test]
fn disk_write_failure_degrades_without_failing_the_insert() {
    let _guard = chaos_guard();
    let tmp = TempDir::new("disk-chaos");
    let cache =
        SpectralCache::with_budget_or_default(0).with_disk(DiskCache::open(&tmp.0).unwrap());
    let mut rng = Pcg64::seeded(11);
    let kernel = conv_svd_lfa::conv::ConvKernel::random_he(2, 2, 3, 3, &mut rng);
    let opts = LfaOptions::default();
    let sig = Signature::result(&kernel, 8, 8, 1, &opts, SpectrumRequest::Full);
    let spectrum = std::sync::Arc::new(lfa::singular_values(&kernel, 8, 8, opts));

    chaos::arm(chaos::DISK_WRITE_FAIL, 1);
    cache.insert(sig, std::sync::Arc::clone(&spectrum));
    let stats = cache.stats();
    assert_eq!(stats.disk_spills, 0, "the injected write failure must drop the spill");
    assert_eq!(stats.entries, 1, "…but the memory tier still serves the entry");
    assert!(cache.get(&sig).is_some());

    // Disarmed, the same content heals onto disk on the next insert.
    chaos::reset();
    cache.insert(sig, spectrum);
    assert_eq!(cache.stats().disk_spills, 1);
    assert!(cache.disk().unwrap().get(&sig).is_some());
}

// ---------------------------------------------------------------------
// The daemon protocol end to end
// ---------------------------------------------------------------------

#[test]
fn daemon_protocol_end_to_end() {
    let _guard = chaos_guard();
    let tmp = TempDir::new("proto");
    let model = write_model(&tmp);
    let handle = serve(daemon(ServiceConfig::default(), |_| {})).unwrap();
    let mut c = Client::connect(handle.addr());

    assert_eq!(c.send("PING"), "PONG");
    assert!(c.send("FROB").starts_with("ERR bad-request unknown command"));
    assert!(c.send("SUBMIT t1").starts_with("ERR bad-request usage:"));
    assert!(c.send("SUBMIT t1 no-such-model").starts_with("ERR bad-request"));
    assert_eq!(c.send("POLL 99"), "ERR unknown-job id=99");

    // Cold audit.
    let queued = c.send(&format!("SUBMIT t1 {model}"));
    assert_eq!(field(&queued, "tenant"), "t1");
    assert_eq!(field(&queued, "cost"), "2", "cost = layer count");
    let id = field(&queued, "id").to_string();
    let done = c.send(&format!("WAIT {id}"));
    assert!(done.starts_with("DONE id="), "unexpected: {done}");
    assert_eq!(field(&done, "layers"), "2");
    assert!(field(&done, "solved").parse::<usize>().unwrap() > 0);
    assert_eq!(field(&done, "cached"), "0");
    // Terminal state is stable and repeatable.
    assert_eq!(c.send(&format!("POLL {id}")), done);

    // Warm repeat in the same daemon: pure memory-cache hits.
    let id2 = field(&c.send(&format!("SUBMIT t1 {model}")), "id").to_string();
    let done2 = c.send(&format!("WAIT {id2}"));
    assert_eq!(field(&done2, "cached"), "2");
    assert_eq!(field(&done2, "solved"), "0");
    assert_eq!(field(&done2, "sigma_max"), field(&done, "sigma_max"));

    // Partial-spectrum submissions ride the same path.
    let id3 = field(&c.send(&format!("SUBMIT t2 {model} top-k=1")), "id").to_string();
    assert!(c.send(&format!("WAIT {id3}")).starts_with("DONE"));

    // Metrics: one line of key=value pairs fed by the scheduler snapshot.
    let metrics = c.send("METRICS");
    assert!(metrics.starts_with("METRICS "));
    for key in ["jobs_completed=", "cache_hits=", "disk_hits=", "tenants=", "quota_rejections="] {
        assert!(metrics.contains(key), "METRICS must report {key}: {metrics}");
    }
    let stats = c.send("STATS");
    assert!(stats.starts_with("STATS hits="), "unexpected: {stats}");

    // The HTTP scrape endpoint on a fresh connection.
    let mut http = TcpStream::connect(handle.addr()).unwrap();
    http.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    write!(http, "GET /metrics HTTP/1.1\r\nHost: localhost\r\n\r\n").unwrap();
    let mut response = String::new();
    http.read_to_string(&mut response).unwrap();
    assert!(response.starts_with("HTTP/1.1 200 OK"), "bad response: {response}");
    assert!(response.contains("lfa_jobs_completed "));
    assert!(response.contains("lfa_disk_hits "));

    assert_eq!(c.send("QUIT"), "BYE");
    let mut c2 = Client::connect(handle.addr());
    assert_eq!(c2.send("SHUTDOWN"), "OK shutting-down");
    handle.wait();
}

/// `SUBMIT … density=B` jobs ride the same queue: the `DONE` reply
/// carries the density tail (bins/sample/coverage/epsilon), repeat
/// submissions are served from the cache's density tier, a different
/// histogram shape is a different key, and the option parsing is typed.
#[test]
fn daemon_density_jobs_end_to_end() {
    let _guard = chaos_guard();
    let tmp = TempDir::new("density");
    let model = write_model(&tmp);
    let handle = serve(daemon(ServiceConfig::default(), |_| {})).unwrap();
    let mut c = Client::connect(handle.addr());

    // Typed option validation, before anything queues.
    assert!(
        c.send(&format!("SUBMIT t1 {model} density=0"))
            .starts_with("ERR bad-request bad density"),
        "zero bins must be rejected"
    );
    assert!(
        c.send(&format!("SUBMIT t1 {model} density-sample=2"))
            .starts_with("ERR bad-request density-sample requires"),
        "a sampling stride without density=B must be rejected"
    );
    assert!(
        c.send(&format!("SUBMIT t1 {model} density=32 top-k=1"))
            .starts_with("ERR bad-request density conflicts"),
        "density and top-k must be mutually exclusive"
    );

    // Cold census: full coverage, no sampling error, nothing cached.
    let id = field(&c.send(&format!("SUBMIT t1 {model} density=32")), "id").to_string();
    let done = c.send(&format!("WAIT {id}"));
    assert!(done.starts_with("DONE id="), "unexpected: {done}");
    assert_eq!(field(&done, "layers"), "2");
    assert_eq!(field(&done, "density_bins"), "32");
    assert_eq!(field(&done, "sample"), "1");
    assert_eq!(field(&done, "coverage"), "1.000", "a census covers the whole grid");
    assert_eq!(field(&done, "epsilon"), "0.0000", "a census carries no sampling error");
    assert_eq!(field(&done, "cached"), "0");

    // Warm repeat: both layers served from the density cache tier, the
    // exact σ_max byte-identical to the cold run's.
    let id2 = field(&c.send(&format!("SUBMIT t1 {model} density=32")), "id").to_string();
    let done2 = c.send(&format!("WAIT {id2}"));
    assert_eq!(field(&done2, "cached"), "2", "repeat must hit the density tier: {done2}");
    assert_eq!(field(&done2, "solved"), "0");
    assert_eq!(field(&done2, "sigma_max"), field(&done, "sigma_max"));

    // A sampled sweep is a *different* content address — it must not be
    // served from the census entry, and its error bar is visible.
    let id3 =
        field(&c.send(&format!("SUBMIT t2 {model} density=32 density-sample=2")), "id").to_string();
    let done3 = c.send(&format!("WAIT {id3}"));
    assert!(done3.starts_with("DONE id="), "unexpected: {done3}");
    assert_eq!(field(&done3, "cached"), "0", "sampled request must miss the census entry");
    assert_eq!(field(&done3, "sample"), "2");
    assert!(
        field(&done3, "coverage").parse::<f64>().unwrap() < 1.0,
        "a sub-lattice sweep must report partial coverage: {done3}"
    );
    assert!(
        field(&done3, "epsilon").parse::<f64>().unwrap() > 0.0,
        "a sampled histogram must carry a DKW error bar: {done3}"
    );

    // The shared STATS formatter reports the density tier.
    let stats = c.send("STATS");
    assert!(stats.starts_with("STATS hits="), "unexpected: {stats}");
    assert!(stats.contains("densities="), "STATS must report the density tier: {stats}");
    let densities: usize = field(&stats, "densities").parse().unwrap();
    assert!(densities >= 4, "census + sampled entries for both layers: {stats}");

    assert_eq!(c.send("SHUTDOWN"), "OK shutting-down");
    handle.wait();
}

/// A client vanishing mid-request leaves the daemon — and other clients'
/// jobs — untouched.
#[test]
fn client_disconnect_mid_request_leaves_daemon_healthy() {
    let _guard = chaos_guard();
    let tmp = TempDir::new("disconnect");
    let model = write_model(&tmp);
    let handle = serve(daemon(ServiceConfig::default(), |_| {})).unwrap();

    // A half-written request line, then a hard drop.
    let mut rude = TcpStream::connect(handle.addr()).unwrap();
    rude.write_all(b"SUBMIT t1 ").unwrap();
    drop(rude);
    // A clean disconnect with a job in flight: the job survives the
    // connection and stays pollable from a *new* connection.
    let mut submitter = Client::connect(handle.addr());
    let id = field(&submitter.send(&format!("SUBMIT t1 {model}")), "id").to_string();
    drop(submitter);

    let mut c = Client::connect(handle.addr());
    assert_eq!(c.send("PING"), "PONG");
    let done = c.send(&format!("WAIT {id}"));
    assert!(done.starts_with("DONE id="), "orphaned job must still complete: {done}");
    assert_eq!(c.send("SHUTDOWN"), "OK shutting-down");
    handle.wait();
}

/// A connection that goes quiet gets the typed slow-consumer reply and is
/// closed — handler threads are never parked on dead clients.
#[test]
fn slow_consumer_is_timed_out_with_a_typed_reply() {
    let _guard = chaos_guard();
    let handle =
        serve(daemon(ServiceConfig::default(), |d| d.io_timeout = Duration::from_millis(250)))
            .unwrap();
    let stream = TcpStream::connect(handle.addr()).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    // Send nothing; the daemon must speak first, then hang up.
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.starts_with("ERR slow-consumer"), "unexpected: {line}");
    assert_eq!(reader.read_line(&mut line).unwrap(), 0, "connection must be closed");
    // The daemon itself is unaffected.
    let mut c = Client::connect(handle.addr());
    assert_eq!(c.send("PING"), "PONG");
    assert_eq!(c.send("SHUTDOWN"), "OK shutting-down");
    handle.wait();
}

/// Jobs queued past their deadline are cancelled *unrun*: the reply is a
/// typed timeout and the scheduler never sees the job.
#[test]
fn request_timeout_cancels_queued_jobs_without_running_them() {
    let _guard = chaos_guard();
    let tmp = TempDir::new("timeout");
    let model = write_model(&tmp);
    let handle = serve(daemon(ServiceConfig::default(), |d| {
        d.request_timeout = Duration::from_millis(200);
        d.start_paused = true; // hold dispatch so the deadline passes while queued
    }))
    .unwrap();
    let mut c = Client::connect(handle.addr());
    let id = field(&c.send(&format!("SUBMIT t1 {model}")), "id").to_string();
    std::thread::sleep(Duration::from_millis(350));
    assert_eq!(c.send(&format!("POLL {id}")), format!("ERR timeout id={id}"));
    assert_eq!(c.send(&format!("WAIT {id}")), format!("ERR timeout id={id}"));
    // Release dispatch: the runner must *skip* the expired job.
    assert_eq!(c.send("RESUME"), "OK resumed");
    std::thread::sleep(Duration::from_millis(100));
    let metrics = c.send("METRICS");
    assert!(
        metrics.contains("jobs_submitted=0"),
        "an expired queued job must never reach the scheduler: {metrics}"
    );
    assert!(metrics.contains("jobs_queued=0"), "the cancelled job must leave the queue");
    assert_eq!(c.send("PING"), "PONG");
    assert_eq!(c.send("SHUTDOWN"), "OK shutting-down");
    handle.wait();
}

/// A worker panic inside a daemon-dispatched job becomes a typed
/// `ERR failed` reply, and the daemon keeps serving.
#[test]
fn daemon_survives_worker_panic_mid_job() {
    let _guard = chaos_guard();
    let tmp = TempDir::new("panic");
    let model = write_model(&tmp);
    let handle = serve(daemon(ServiceConfig::default(), |_| {})).unwrap();
    let mut c = Client::connect(handle.addr());
    chaos::arm(chaos::TILE_PANIC, 1);
    let id = field(&c.send(&format!("SUBMIT t1 {model}")), "id").to_string();
    let reply = c.send(&format!("WAIT {id}"));
    assert!(
        reply.starts_with(&format!("ERR failed id={id}")) && reply.contains("panicked mid-tile"),
        "panic must become a typed failure reply: {reply}"
    );
    chaos::reset();
    // Same daemon, same scheduler: the next submission completes.
    let id2 = field(&c.send(&format!("SUBMIT t1 {model}")), "id").to_string();
    assert!(c.send(&format!("WAIT {id2}")).starts_with("DONE"));
    assert_eq!(c.send("SHUTDOWN"), "OK shutting-down");
    handle.wait();
}

/// The daemon acceptance path: audit over the socket, SHUTDOWN, restart a
/// daemon on the same spill directory, repeat the audit — pure disk hits,
/// zero frequencies re-solved, identical reported σ_max.
#[test]
fn daemon_restart_warm_audit_hits_disk() {
    let _guard = chaos_guard();
    let tmp = TempDir::new("restart");
    let model = write_model(&tmp);
    let spill = tmp.0.join("spill");
    let service = |dir: &PathBuf| ServiceConfig {
        disk_cache_dir: Some(dir.clone()),
        ..Default::default()
    };

    let handle = serve(daemon(service(&spill), |_| {})).unwrap();
    let mut c = Client::connect(handle.addr());
    let id = field(&c.send(&format!("SUBMIT t1 {model}")), "id").to_string();
    let cold = c.send(&format!("WAIT {id}"));
    assert!(field(&cold, "solved").parse::<usize>().unwrap() > 0);
    let stats = c.send("STATS");
    assert!(stats.contains("disk_spills=2"), "cold run must spill both layers: {stats}");
    assert_eq!(c.send("SHUTDOWN"), "OK shutting-down");
    handle.wait();

    // Restart on the same directory: a fresh process's daemon, warm disk.
    let handle = serve(daemon(service(&spill), |_| {})).unwrap();
    let mut c = Client::connect(handle.addr());
    let id = field(&c.send(&format!("SUBMIT t1 {model}")), "id").to_string();
    let warm = c.send(&format!("WAIT {id}"));
    assert_eq!(field(&warm, "solved"), "0", "warm restart must re-solve nothing: {warm}");
    assert_eq!(field(&warm, "cached"), "2");
    assert_eq!(field(&warm, "sigma_max"), field(&cold, "sigma_max"));
    let stats = c.send("STATS");
    assert!(stats.contains("disk_hits=2"), "both layers must read back: {stats}");
    assert!(stats.contains("disk_corruptions=0"), "clean spill files: {stats}");
    assert_eq!(c.send("SHUTDOWN"), "OK shutting-down");
    handle.wait();
}
