//! ModelPlan acceptance: whole-model batched execution must reproduce the
//! per-layer [`SpectralPlan`] results across mixed layouts, strides, kernel
//! sizes and thread counts; batched-group execution must be deterministic;
//! and the coordinator's whole-model job path must match direct execution.

use conv_svd_lfa::coordinator::{ModelJobSpec, Scheduler, SpectralService};
use conv_svd_lfa::engine::{ModelPlan, NativeSerial, NativeThreaded, SpectralPlan};
use conv_svd_lfa::lfa::{self, BlockLayout, BlockSolver, Fold, LfaOptions};
use conv_svd_lfa::model::ModelConfig;

const TOL: f64 = 1e-10;

/// Mixed everything: strides 1 and 2, square and rectangular grids, tall
/// and wide channel counts, and two kernel sizes inside one equal-shape
/// group (conv1/conv3/conv5 all have 4×3 blocks; conv5 is 5×5 so the
/// shared pool must cover 25 taps).
const MIXED: &str = r#"
name = "mixed-strides"
seed = 42

[[layer]]
name   = "conv1"
c_in   = 3
c_out  = 4
height = 8
width  = 8

[[layer]]
name   = "conv2"
c_in   = 2
c_out  = 6
height = 8
width  = 8
stride = 2

[[layer]]
name   = "conv3"
c_in   = 3
c_out  = 4
height = 6
width  = 8

[[layer]]
name   = "conv4"
c_in   = 4
c_out  = 3
height = 6
width  = 6
init   = "glorot"

[[layer]]
name   = "conv5"
c_in   = 3
c_out  = 4
kernel = 5
height = 8
width  = 8
"#;

fn mixed_model() -> ModelConfig {
    ModelConfig::parse(MIXED).unwrap()
}

fn max_gap(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len(), "spectrum lengths differ");
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
}

#[test]
fn whole_model_matches_per_layer_plans_across_configs() {
    let model = mixed_model();
    for layout in [BlockLayout::BlockContiguous, BlockLayout::PlanarStrided] {
        for solver in [BlockSolver::Jacobi, BlockSolver::GramEigen] {
            for threads in [1usize, 3] {
                let opts = LfaOptions { layout, solver, threads, ..Default::default() };
                let mp = ModelPlan::build(&model, opts).unwrap();
                let spectra = mp.execute();
                for (layer, got) in model.layers.iter().zip(&spectra.layers) {
                    let kernel = layer.materialize(model.seed);
                    let want = SpectralPlan::with_stride(
                        &kernel,
                        layer.height,
                        layer.width,
                        layer.stride,
                        LfaOptions { threads: 1, ..opts },
                    )
                    .execute();
                    assert_eq!(got.name, layer.name);
                    assert_eq!(got.spectrum.values.len(), layer.num_values());
                    let gap = max_gap(&got.spectrum.values, &want.values);
                    assert!(
                        gap < TOL,
                        "{} {layout:?} {solver:?} x{threads}: gap {gap}",
                        layer.name
                    );
                }
            }
        }
    }
}

#[test]
fn batched_groups_share_pools_and_stay_deterministic() {
    let model = mixed_model();
    let opts = LfaOptions { threads: 3, ..Default::default() };
    let mp = ModelPlan::build(&model, opts).unwrap();
    // conv1, conv3 and conv5 all have 4×3 blocks → one batched group.
    assert_eq!(mp.group_count(), 3);
    assert_eq!(mp.group_members(0), &[0, 2, 4]);
    let a = mp.execute();
    let b = mp.execute();
    for (x, y) in a.layers.iter().zip(&b.layers) {
        assert_eq!(
            x.spectrum.values, y.spectrum.values,
            "repeated batched execution must be bitwise identical"
        );
    }
    // A freshly built plan — and the serial (unbatched-threads) sweep —
    // must agree bitwise too.
    let serial = ModelPlan::build(&model, LfaOptions { threads: 1, ..Default::default() })
        .unwrap()
        .execute();
    for (x, y) in a.layers.iter().zip(&serial.layers) {
        assert_eq!(x.spectrum.values, y.spectrum.values);
    }
}

/// Whole-model folding: the batched sweep over folded layers (mixed
/// strides, odd/even grids, equal-shape groups) agrees with the unfolded
/// reference to ≤ 1e-12 for full spectra and to the Krylov tolerance for
/// top-k, serial and threaded.
#[test]
fn whole_model_folded_matches_unfolded() {
    let model = mixed_model();
    for threads in [1usize, 3] {
        let folded =
            ModelPlan::build(&model, LfaOptions { threads, ..Default::default() }).unwrap();
        let unfolded = ModelPlan::build(
            &model,
            LfaOptions { threads, folding: Fold::Off, ..Default::default() },
        )
        .unwrap();
        let a = folded.execute();
        let b = unfolded.execute();
        let scale = b.sigma_max().max(1.0);
        for (x, y) in a.layers.iter().zip(&b.layers) {
            assert_eq!(x.name, y.name);
            for (v, w) in x.spectrum.values.iter().zip(&y.spectrum.values) {
                assert!(
                    (v - w).abs() <= 1e-12 * scale,
                    "x{threads} layer {}: {v} vs {w}",
                    x.name
                );
            }
        }
        let ta = folded.top_k_all(2);
        let tb = unfolded.top_k_all(2);
        assert!(ta.iterations > 0);
        for (x, y) in ta.spectra.layers.iter().zip(&tb.spectra.layers) {
            for (v, w) in x.spectrum.values.iter().zip(&y.spectrum.values) {
                assert!(
                    (v - w).abs() <= 2e-8 * scale,
                    "topk x{threads} layer {}: {v} vs {w}",
                    x.name
                );
            }
        }
    }
}

#[test]
fn execute_with_backends_matches_direct_execution() {
    let model = mixed_model();
    let mp = ModelPlan::build(&model, LfaOptions::default()).unwrap();
    let direct = mp.execute();
    let serial = mp.execute_with(&NativeSerial).unwrap();
    let threaded = mp.execute_with(&NativeThreaded { threads: 2 }).unwrap();
    for ((d, s), t) in direct.layers.iter().zip(&serial.layers).zip(&threaded.layers) {
        assert_eq!(d.spectrum.values, s.spectrum.values);
        assert_eq!(d.spectrum.values, t.spectrum.values);
    }
}

#[test]
fn full_svd_clip_and_lowrank_whole_model() {
    // Stride-1 stack (clip's kernel projection needs dense layers).
    let model = ModelConfig::parse(
        "name = \"dense\"\nseed = 9\n\
         [[layer]]\nname = \"l0\"\nc_in = 4\nc_out = 4\nheight = 6\nwidth = 6\n\
         [[layer]]\nname = \"l1\"\nc_in = 4\nc_out = 4\nheight = 6\nwidth = 6\n",
    )
    .unwrap();
    let mp = ModelPlan::build(&model, LfaOptions { threads: 1, ..Default::default() }).unwrap();
    let spectra = mp.execute();

    // full_svd_all reproduces the batched sweep's singular values.
    let svds = mp.full_svd_all();
    assert_eq!(svds.len(), 2);
    for (svd, layer) in svds.iter().zip(&spectra.layers) {
        let gap = max_gap(&svd.sigma.values, &layer.spectrum.values);
        assert!(gap < TOL, "full_svd_all vs execute: gap {gap}");
    }

    // clip_all caps every layer's spectral norm.
    let cap = spectra.sigma_max() * 0.6;
    let clipped = mp.clip_all(cap).unwrap();
    assert_eq!(clipped.len(), 2);
    assert!(clipped.iter().any(|c| c.clipped_count > 0), "cap must bite");
    for c in &clipped {
        let after = lfa::svd::svd_full_from_grid(&c.grid);
        assert!(after.sigma.sigma_max() <= cap + 1e-9);
    }

    // Full-rank truncation is lossless; rank-1 is not (generically).
    let lossless = mp.lowrank_all(4);
    assert!(lossless.iter().all(|l| l.rel_error < 1e-12));
    let crushed = mp.lowrank_all(1);
    assert!(crushed.iter().all(|l| l.rank == 1));
    assert!(crushed.iter().any(|l| l.rel_error > 1e-6));

    // clip_all on a strided model is a clean error, not a bad projection.
    let strided = ModelConfig::parse(
        "[[layer]]\nc_in = 2\nc_out = 4\nheight = 8\nwidth = 8\nstride = 2\n",
    )
    .unwrap();
    let smp = ModelPlan::build(&strided, LfaOptions::default()).unwrap();
    assert!(smp.clip_all(1.0).is_err());
}

#[test]
fn scheduler_whole_model_job_matches_direct_plan() {
    let model = mixed_model();
    let direct = ModelPlan::build(&model, LfaOptions { threads: 1, ..Default::default() })
        .unwrap()
        .execute();
    let sched = Scheduler::native(3);
    let result = sched.run_model(ModelJobSpec::new("mixed", model.clone())).unwrap();
    assert_eq!(result.id, "mixed");
    assert_eq!(result.layers.len(), model.layers.len());
    assert_eq!(result.pjrt_tiles, 0);
    assert!(result.native_tiles >= model.layers.len());
    for (got, want) in result.layers.iter().zip(&direct.layers) {
        assert_eq!(got.name, want.name);
        assert_eq!(
            got.spectrum.values, want.spectrum.values,
            "scheduler model path must match the planned sweep bitwise"
        );
    }
    let m = sched.metrics.snapshot();
    assert_eq!(m.jobs_completed as usize, model.layers.len());
    assert_eq!(
        m.values_computed as usize,
        model.layers.iter().map(|l| l.num_values()).sum::<usize>()
    );
    sched.shutdown();
}

#[test]
fn service_audit_verifies_strided_layers() {
    let model = mixed_model();
    let svc = SpectralService::native(2);
    let reports = svc.audit_model(&model).unwrap();
    assert_eq!(reports.len(), model.layers.len());
    for (r, layer) in reports.iter().zip(&model.layers) {
        assert_eq!(r.num_values, layer.num_values());
        assert!(
            r.frobenius_defect < 1e-10,
            "{}: defect {}",
            r.name,
            r.frobenius_defect
        );
        assert!(r.sigma_max > 0.0);
    }
    svc.shutdown();
}
