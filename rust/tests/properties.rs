//! Property-based tests (in-repo `testing::prop` harness — proptest is not
//! in the offline crate set) over the coordinator-level invariants:
//! routing/tiling correctness, spectrum identities, transform equivalences.

use conv_svd_lfa::baselines::fft_svd::{self, FftLayoutPolicy};
use conv_svd_lfa::conv::{Boundary, ConvKernel, ConvOp};
use conv_svd_lfa::coordinator::{JobSpec, Scheduler};
use conv_svd_lfa::lfa::{self, BlockLayout, LfaOptions};
use conv_svd_lfa::linalg::power::LinOp;
use conv_svd_lfa::numeric::Pcg64;
use conv_svd_lfa::testing::{prop_assert, prop_check, prop_close, Gen};

fn random_kernel(g: &mut Gen) -> ConvKernel {
    let c_out = g.usize_in(1, 5);
    let c_in = g.usize_in(1, 5);
    let k = *g.pick(&[1usize, 3, 5]);
    let seed = g.rng.next_u64();
    let mut rng = Pcg64::seeded(seed);
    ConvKernel::random_he(c_out, c_in, k, k, &mut rng)
}

#[test]
fn prop_frobenius_identity() {
    // Σσ² == n·m·‖W‖²_F for every kernel and grid (periodic), PROVIDED the
    // kernel fits in the grid — wrapped taps that collide add up and break
    // the identity (see lfa::svd::frobenius_check docs).
    prop_check("frobenius identity", 40, |g| {
        let kern = random_kernel(g);
        let n = g.usize_in(kern.kh.max(2), 10.max(kern.kh));
        let m = g.usize_in(kern.kw.max(2), 10.max(kern.kw));
        let s = lfa::singular_values(&kern, n, m, LfaOptions::default());
        let lhs: f64 = s.values.iter().map(|v| v * v).sum();
        let rhs = (n * m) as f64 * kern.frobenius_norm().powi(2);
        prop_close(lhs, rhs, 1e-9, "Σσ² vs nm·‖W‖²")
    });
}

#[test]
fn prop_lfa_equals_fft() {
    prop_check("lfa == fft", 30, |g| {
        let kern = random_kernel(g);
        let n = g.usize_in(2, 9);
        let m = g.usize_in(2, 9);
        let a = lfa::singular_values(&kern, n, m, LfaOptions::default()).sorted_desc();
        let b = fft_svd::singular_values(&kern, n, m, FftLayoutPolicy::Natural, 1).sorted_desc();
        for (x, y) in a.iter().zip(&b) {
            prop_close(*x, *y, 1e-9, "σ")?;
        }
        Ok(())
    });
}

#[test]
fn prop_scaling_homogeneity() {
    // σ(αA) == |α|·σ(A).
    prop_check("scaling homogeneity", 25, |g| {
        let kern = random_kernel(g);
        let alpha = g.f64_in(-3.0, 3.0);
        let mut scaled = kern.clone();
        scaled.data.iter_mut().for_each(|v| *v *= alpha);
        let n = g.usize_in(2, 8);
        let s1 = lfa::singular_values(&kern, n, n, LfaOptions::default());
        let s2 = lfa::singular_values(&scaled, n, n, LfaOptions::default());
        for (a, b) in s1.values.iter().zip(&s2.values) {
            prop_close(a * alpha.abs(), *b, 1e-9, "α-homogeneity")?;
        }
        Ok(())
    });
}

#[test]
fn prop_operator_gain_bounded_by_sigma_max() {
    // ‖A f‖ ≤ σ_max ‖f‖ for the actual (periodic) conv operator.
    prop_check("gain bound", 25, |g| {
        let kern = random_kernel(g);
        let n = g.usize_in(3, 8);
        let op = ConvOp::new(&kern, n, n, Boundary::Periodic);
        let s = lfa::singular_values(&kern, n, n, LfaOptions::default());
        let f = g.rng.normal_vec(op.in_dim());
        let y = op.forward(&f);
        let fn2: f64 = f.iter().map(|v| v * v).sum::<f64>().sqrt();
        let yn2: f64 = y.iter().map(|v| v * v).sum::<f64>().sqrt();
        prop_assert(
            yn2 <= s.sigma_max() * fn2 * (1.0 + 1e-9),
            format!("gain {} > σ_max {}", yn2 / fn2.max(1e-300), s.sigma_max()),
        )
    });
}

#[test]
fn prop_tiling_is_seamless() {
    // Any tile partition of the rows yields exactly the full spectrum.
    prop_check("tile stitching", 20, |g| {
        let kern = random_kernel(g);
        let n = g.usize_in(3, 10);
        let m = g.usize_in(2, 6);
        let full = lfa::singular_values(&kern, n, m, LfaOptions::default());
        let r = full.rank_per_freq();
        let mut lo = 0;
        let mut collected = Vec::new();
        while lo < n {
            let hi = (lo + g.usize_in(1, 3)).min(n);
            collected.extend(lfa::tile_singular_values(
                &kern,
                n,
                m,
                lo,
                hi,
                lfa::BlockSolver::Jacobi,
            ));
            lo = hi;
        }
        prop_assert(collected.len() == n * m * r, "length")?;
        for (a, b) in collected.iter().zip(&full.values) {
            prop_close(*a, *b, 1e-12, "tiled σ")?;
        }
        Ok(())
    });
}

#[test]
fn prop_scheduler_arbitrary_tile_rows() {
    // The coordinator yields identical spectra for any tile_rows choice.
    let sched = Scheduler::native(2);
    prop_check("scheduler tiling", 12, |g| {
        let kern = random_kernel(g);
        let n = g.usize_in(3, 10);
        let tile_rows = g.usize_in(1, n);
        let res = sched
            .run(JobSpec::new("p", kern.clone(), n, n).with_tile_rows(tile_rows))
            .map_err(|e| e.to_string())?;
        let want = lfa::singular_values(&kern, n, n, LfaOptions::default());
        for (a, b) in res.spectrum.values.iter().zip(&want.values) {
            prop_close(*a, *b, 1e-12, "σ")?;
        }
        Ok(())
    });
    sched.shutdown();
}

#[test]
fn prop_layout_roundtrip_preserves_symbols() {
    prop_check("layout roundtrip", 20, |g| {
        let kern = random_kernel(g);
        let n = g.usize_in(2, 8);
        let a = lfa::compute_symbols(&kern, n, n, BlockLayout::BlockContiguous);
        let b = a.to_layout(BlockLayout::PlanarStrided).to_layout(BlockLayout::BlockContiguous);
        prop_assert(a.max_abs_diff(&b) < 1e-15, "roundtrip changed symbols")
    });
}

#[test]
fn prop_transpose_kernel_spectrum_identical() {
    // σ(A) == σ(Aᵀ): the transposed conv has the same singular values.
    prop_check("transpose spectrum", 20, |g| {
        let kern = random_kernel(g);
        let n = g.usize_in(2, 8);
        let s1 = lfa::singular_values(&kern, n, n, LfaOptions::default()).sorted_desc();
        let s2 =
            lfa::singular_values(&kern.transpose_kernel(), n, n, LfaOptions::default()).sorted_desc();
        for (a, b) in s1.iter().zip(&s2) {
            prop_close(*a, *b, 1e-9, "σ(A) vs σ(Aᵀ)")?;
        }
        Ok(())
    });
}
