//! Integration tests for the [`SpectralCache`] subsystem: content-addressed
//! result caching (bit-identical hits across the stride/layout/fold
//! matrix, cached-vs-uncached ≤ 1e-12 against the unfolded reference),
//! weight-mutation invalidation, byte-budgeted LRU eviction under real
//! sweeps, plan sharing across `ModelPlan` builds, and the cached
//! whole-model entry points (`execute_cached` / `top_k_all_cached` /
//! `clip_all_cached` — the repeat-audit and training-loop shapes).

use conv_svd_lfa::conv::ConvKernel;
use conv_svd_lfa::engine::{ModelPlan, SpectralCache, SpectralPlan, SpectrumRequest};
use conv_svd_lfa::lfa::{BlockLayout, Fold, LfaOptions};
use conv_svd_lfa::model::ModelConfig;
use conv_svd_lfa::numeric::Pcg64;
use std::sync::Arc;

fn kernel(c_out: usize, c_in: usize, seed: u64) -> ConvKernel {
    let mut rng = Pcg64::seeded(seed);
    ConvKernel::random_he(c_out, c_in, 3, 3, &mut rng)
}

/// A small three-layer model: two stride-1 layers (one of which the
/// "training step" below mutates) plus a strided layer.
const BASE_MODEL: &str = "name = \"cache-model\"\nseed = 5\n\
    [[layer]]\nname = \"a\"\nc_in = 2\nc_out = 3\nheight = 8\nwidth = 8\n\
    [[layer]]\nname = \"b\"\nc_in = 3\nc_out = 3\nheight = 6\nwidth = 6\n\
    [[layer]]\nname = \"s\"\nc_in = 2\nc_out = 4\nheight = 8\nwidth = 8\nstride = 2\n";

/// The same model after "one training step touched layer b": its weights
/// are drawn differently, every other layer's bits are unchanged.
fn mutated_model() -> ModelConfig {
    let toml = BASE_MODEL.replace("name = \"b\"", "name = \"b\"\ninit = \"glorot\"");
    ModelConfig::parse(&toml).unwrap()
}

fn serial() -> LfaOptions {
    LfaOptions { threads: 1, ..Default::default() }
}

#[test]
fn cache_hit_is_bit_identical_across_the_config_matrix() {
    let cache = SpectralCache::new();
    let k = kernel(3, 2, 1);
    for &(n, m, stride) in &[(8usize, 8usize, 1usize), (6, 8, 2), (5, 7, 1)] {
        for layout in [BlockLayout::BlockContiguous, BlockLayout::PlanarStrided] {
            for folding in [Fold::Auto, Fold::Off] {
                let opts = LfaOptions { layout, folding, ..serial() };
                let plan = SpectralPlan::with_stride(&k, n, m, stride, opts);
                let key = plan.result_signature(SpectrumRequest::Full);
                assert!(cache.get(&key).is_none(), "distinct configs must not collide");
                let cold = Arc::new(plan.execute());
                cache.insert(key, Arc::clone(&cold));
                let hit = cache.get(&key).expect("just inserted");
                assert!(Arc::ptr_eq(&hit, &cold), "a hit returns the shared spectrum");
                assert_eq!(hit.values, plan.execute().values, "bit-identical to a cold run");
                // Cached-vs-uncached equivalence: the served spectrum
                // matches a fresh *unfolded* execution to ≤ 1e-12.
                let reference = SpectralPlan::with_stride(
                    &k,
                    n,
                    m,
                    stride,
                    LfaOptions { folding: Fold::Off, ..opts },
                )
                .execute();
                let scale = reference.sigma_max().max(1.0);
                for (a, b) in hit.values.iter().zip(&reference.values) {
                    assert!(
                        (a - b).abs() <= 1e-12 * scale,
                        "{n}x{m}/{stride} {layout:?} {folding:?}: {a} vs {b}"
                    );
                }
            }
        }
    }
}

#[test]
fn weight_mutation_misses_while_the_old_entry_stays_valid() {
    let cache = SpectralCache::new();
    let k = kernel(3, 3, 2);
    let plan = SpectralPlan::new(&k, 8, 8, serial());
    let key = plan.result_signature(SpectrumRequest::Full);
    cache.insert(key, Arc::new(plan.execute()));
    // One weight moves by one part in 10¹² — a clipped layer, a training
    // step. The content signature changes, so the lookup misses.
    let mut k2 = k.clone();
    k2.data[4] *= 1.0 + 1e-12;
    let key2 = SpectralPlan::new(&k2, 8, 8, serial()).result_signature(SpectrumRequest::Full);
    assert_ne!(key, key2);
    assert!(cache.get(&key2).is_none(), "mutated weights must miss");
    // The old entry still serves the old weights — correct, not stale.
    assert!(cache.get(&key).is_some());
}

#[test]
fn model_sweep_cold_then_warm_then_one_mutated_layer() {
    let model = ModelConfig::parse(BASE_MODEL).unwrap();
    let cache = SpectralCache::new();
    let plan = ModelPlan::build_cached(&model, serial(), &cache).unwrap();
    let cold = plan.execute_cached(&cache);
    assert_eq!(cold.cache_hits, 0);
    assert!(cold.freqs_solved > 0);
    // The cold cached sweep is the plain batched sweep, bit for bit.
    let plain = plan.execute();
    for (a, b) in cold.spectra.layers.iter().zip(&plain.layers) {
        assert_eq!(a.spectrum.values, b.spectrum.values, "{}", a.name);
    }
    // Warm repeat: every layer hits, zero frequencies re-solved.
    let warm = plan.execute_cached(&cache);
    assert_eq!(warm.cache_hits, plan.layer_count());
    assert_eq!(warm.freqs_solved, 0, "a repeat sweep re-solves nothing");
    assert_eq!(warm.iterations, 0);
    for (a, b) in warm.spectra.layers.iter().zip(&cold.spectra.layers) {
        assert!(Arc::ptr_eq(&a.spectrum, &b.spectrum), "{}: hit shares the buffer", a.name);
    }
    // One training step mutates layer b: rebuilding reuses the cached
    // *plans* of unchanged layers, and re-solves only the mutated one.
    let plan2 = ModelPlan::build_cached(&mutated_model(), serial(), &cache).unwrap();
    assert!(
        Arc::ptr_eq(plan.layer_plan_shared(0), plan2.layer_plan_shared(0)),
        "unchanged layer a shares one planned object across builds"
    );
    assert!(
        !Arc::ptr_eq(plan.layer_plan_shared(1), plan2.layer_plan_shared(1)),
        "mutated layer b re-plans"
    );
    let mixed = plan2.execute_cached(&cache);
    assert_eq!(mixed.cache_hits, plan.layer_count() - 1);
    assert_eq!(mixed.freqs_solved, plan2.layer_plan(1).solved_freqs());
    assert!(Arc::ptr_eq(
        &mixed.spectra.layers[0].spectrum,
        &cold.spectra.layers[0].spectrum
    ));
    assert_ne!(mixed.spectra.layers[1].spectrum.values, cold.spectra.layers[1].spectrum.values);
}

#[test]
fn tiny_byte_budget_evicts_but_sweeps_stay_correct() {
    let model = ModelConfig::parse(BASE_MODEL).unwrap();
    // Probe how many bytes the whole model needs, then grant one byte
    // less: the cold sweep must evict at least one layer.
    let probe = SpectralCache::new();
    let plan = ModelPlan::build_cached(&model, serial(), &probe).unwrap();
    plan.execute_cached(&probe);
    let need = probe.stats().bytes;
    assert!(need > 0);
    let cache = SpectralCache::with_budget(need - 1);
    let cold = plan.execute_cached(&cache);
    assert!(cold.evictions >= 1, "budget below the working set must evict");
    let held = cache.stats();
    assert!(held.entries < plan.layer_count());
    assert!(held.bytes <= need - 1);
    // The warm sweep hits what survived, recomputes the rest — and the
    // values come out identical either way.
    let warm = plan.execute_cached(&cache);
    assert!(warm.cache_hits >= 1 && warm.cache_hits < plan.layer_count());
    assert!(warm.freqs_solved > 0 && warm.freqs_solved < cold.freqs_solved);
    for (a, b) in warm.spectra.layers.iter().zip(&cold.spectra.layers) {
        assert_eq!(a.spectrum.values, b.spectrum.values, "{}", a.name);
    }
}

#[test]
fn topk_partial_spectra_cache_under_their_own_signature() {
    let model = ModelConfig::parse(BASE_MODEL).unwrap();
    let cache = SpectralCache::new();
    let plan = ModelPlan::build_cached(&model, serial(), &cache).unwrap();
    let full = plan.execute_cached(&cache);
    // TopK(1) is a different request, therefore a different signature:
    // the full-spectrum entries must not answer it.
    let top = plan.top_k_all_cached(1, &cache);
    assert_eq!(top.cache_hits, 0, "no cross-request hits");
    assert!(top.spectra.layers.iter().all(|l| l.spectrum.is_partial()));
    let top2 = plan.top_k_all_cached(1, &cache);
    assert_eq!(top2.cache_hits, plan.layer_count());
    assert_eq!(top2.freqs_solved, 0);
    for (a, b) in top2.spectra.layers.iter().zip(&top.spectra.layers) {
        assert!(Arc::ptr_eq(&a.spectrum, &b.spectrum));
    }
    // Aggregate extremes: partial spectra poison σ_min (NaN guard), the
    // full sweep keeps a real value; σ_max is exact on both.
    assert!(top.spectra.sigma_min().is_nan());
    assert!(full.spectra.sigma_min().is_finite());
    let scale = full.spectra.sigma_max().max(1.0);
    assert!((top.spectra.sigma_max() - full.spectra.sigma_max()).abs() <= 1e-8 * scale);
}

#[test]
fn clip_screening_serves_unchanged_layers_from_cache() {
    // clip_all is stride-1 only: keep the dense sub-stack.
    let model = ModelConfig::parse(BASE_MODEL).unwrap();
    let dense = ModelConfig {
        name: "dense".into(),
        seed: model.seed,
        layers: model.layers.iter().filter(|l| l.stride == 1).cloned().collect(),
    };
    let cache = SpectralCache::new();
    let plan = ModelPlan::build_cached(&dense, serial(), &cache).unwrap();
    let cap = plan.execute().sigma_max() * 0.5;
    let first = plan.clip_all_cached(cap, &cache).unwrap();
    let hits_after_first = cache.stats().hits;
    // The repeat screen (the next "training step" with unchanged weights)
    // serves every top-1 screen from cache.
    let second = plan.clip_all_cached(cap, &cache).unwrap();
    assert_eq!(
        cache.stats().hits - hits_after_first,
        plan.layer_count() as u64,
        "repeat screening must be pure lookup"
    );
    let uncached = plan.clip_all(cap).unwrap();
    for ((a, b), c) in first.iter().zip(&second).zip(&uncached) {
        assert_eq!(a.sigma_before, b.sigma_before);
        assert_eq!(a.clipped_count, b.clipped_count);
        assert_eq!(a.sigma_before, c.sigma_before);
        assert_eq!(a.clipped_count, c.clipped_count);
    }
}
