//! Integration tests for the L3 coordinator: tile scheduling correctness,
//! backpressure, PJRT/native routing, model audits and metrics.

use conv_svd_lfa::conv::ConvKernel;
use conv_svd_lfa::coordinator::{Backend, JobSpec, Scheduler, SchedulerConfig, SpectralService};
#[cfg(feature = "pjrt")]
use conv_svd_lfa::coordinator::ServiceConfig;
use conv_svd_lfa::lfa::{self, LfaOptions};
use conv_svd_lfa::model::zoo;
use conv_svd_lfa::numeric::Pcg64;
#[cfg(feature = "pjrt")]
use std::path::PathBuf;

fn kernel(c_out: usize, c_in: usize, seed: u64) -> ConvKernel {
    let mut rng = Pcg64::seeded(seed);
    ConvKernel::random_he(c_out, c_in, 3, 3, &mut rng)
}

#[test]
fn scheduler_matches_direct_lfa() {
    let k = kernel(4, 3, 1);
    let sched = Scheduler::native(3);
    let result = sched.run(JobSpec::new("t", k.clone(), 16, 16)).unwrap();
    let direct = lfa::singular_values(&k, 16, 16, LfaOptions::default());
    assert_eq!(result.spectrum.values.len(), direct.values.len());
    for (a, b) in result.spectrum.values.iter().zip(&direct.values) {
        assert!((a - b).abs() < 1e-12);
    }
    assert!(result.native_tiles > 0);
    assert_eq!(result.pjrt_tiles, 0);
    sched.shutdown();
}

#[test]
fn many_jobs_pipeline_through_bounded_queue() {
    let sched = Scheduler::start(
        SchedulerConfig { workers: 2, queue_depth: 2, artifacts: vec![] },
        None,
    );
    // More jobs than queue depth: backpressure must not deadlock.
    let mut rxs = Vec::new();
    for j in 0..12 {
        let k = kernel(3, 3, 100 + j);
        rxs.push((j, k.clone(), sched.submit(JobSpec::new(format!("job{j}"), k, 8, 8))));
    }
    for (j, k, rx) in rxs {
        let res = rx.recv().unwrap().unwrap();
        let want = lfa::singular_values(&k, 8, 8, LfaOptions::default());
        for (a, b) in res.spectrum.values.iter().zip(&want.values) {
            assert!((a - b).abs() < 1e-12, "job{j}");
        }
    }
    let m = sched.metrics.snapshot();
    assert_eq!(m.jobs_completed, 12);
    assert_eq!(m.jobs_submitted, 12);
    sched.shutdown();
}

#[test]
fn explicit_tile_rows_respected() {
    let k = kernel(2, 2, 5);
    let sched = Scheduler::native(2);
    // Folded (default): only the 7 fundamental-domain rows of the 12-row
    // grid are tiled (rows 0..=6; the rest mirror) → ceil(7/5) = 2 tiles.
    let res = sched.run(JobSpec::new("t", k.clone(), 12, 12).with_tile_rows(5)).unwrap();
    assert_eq!(res.native_tiles, 2);
    // Unfolded: all 12 rows / 5 per tile = 3 tiles.
    let res = sched
        .run(JobSpec::new("t2", k, 12, 12).with_tile_rows(5).with_folding(lfa::Fold::Off))
        .unwrap();
    assert_eq!(res.native_tiles, 3);
    sched.shutdown();
}

#[test]
fn folded_and_unfolded_jobs_agree_and_account_all_values() {
    let k = kernel(3, 3, 9);
    let sched = Scheduler::native(3);
    let folded = sched.run(JobSpec::new("f", k.clone(), 11, 7)).unwrap();
    let unfolded =
        sched.run(JobSpec::new("u", k.clone(), 11, 7).with_folding(lfa::Fold::Off)).unwrap();
    assert_eq!(folded.spectrum.values.len(), unfolded.spectrum.values.len());
    let scale = unfolded.spectrum.sigma_max().max(1.0);
    for (a, b) in folded.spectrum.values.iter().zip(&unfolded.spectrum.values) {
        assert!((a - b).abs() <= 1e-12 * scale, "{a} vs {b}");
    }
    // Folded jobs deliver (and account) the full grid's values.
    let m = sched.metrics.snapshot();
    assert_eq!(m.values_computed as usize, 2 * 11 * 7 * 3);
    sched.shutdown();
}

#[test]
fn pjrt_backend_requires_artifact() {
    let k = kernel(2, 2, 6); // no artifact for 2x2 channels
    let sched = Scheduler::native(1);
    let err = sched.run(JobSpec::new("t", k, 8, 8).with_backend(Backend::Pjrt));
    assert!(err.is_err(), "explicit PJRT without artifact must fail");
    sched.shutdown();
}

#[cfg(feature = "pjrt")]
fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.txt").exists() {
        Some(dir)
    } else {
        eprintln!("skipping PJRT part: run `make artifacts`");
        None
    }
}

#[cfg(feature = "pjrt")]
#[test]
fn service_auto_routes_to_pjrt_when_artifact_matches() {
    let Some(dir) = artifacts_dir() else { return };
    let svc = SpectralService::start(ServiceConfig {
        workers: 2,
        backend: Backend::Auto,
        artifacts_dir: Some(dir),
        ..Default::default()
    })
    .unwrap();
    // 32x32 c16 matches the tiled artifact.
    let k = kernel(16, 16, 7);
    let rep = svc.analyze_layer("conv", &k, 32, 32).unwrap();
    assert!(rep.pjrt_tiles > 0, "should route via PJRT");
    // Verify against native.
    let want = lfa::singular_values(&k, 32, 32, LfaOptions::default());
    let scale = want.sigma_max();
    for (a, b) in rep.spectrum.values.iter().zip(&want.values) {
        assert!((a - b).abs() < 2e-4 * scale.max(1.0), "{a} vs {b}");
    }
    assert!(rep.frobenius_defect < 1e-3, "defect {}", rep.frobenius_defect);
    // Unmatched shape falls back to native.
    let k2 = kernel(5, 5, 8);
    let rep2 = svc.analyze_layer("odd", &k2, 8, 8).unwrap();
    assert_eq!(rep2.pjrt_tiles, 0);
    assert!(rep2.frobenius_defect < 1e-10);
    svc.shutdown();
}

#[test]
fn audit_lenet_native() {
    let svc = SpectralService::native(2);
    let reports = svc.audit_model(&zoo::lenet()).unwrap();
    assert_eq!(reports.len(), 2);
    for r in &reports {
        assert!(r.sigma_max > 0.0);
        assert!(r.frobenius_defect < 1e-10, "{}: {}", r.name, r.frobenius_defect);
        assert_eq!(r.num_values, r.n * r.m * r.c_out.min(r.c_in));
    }
    let m = svc.metrics();
    assert_eq!(m.jobs_completed, 2);
    assert_eq!(m.values_computed as usize, zoo::lenet().total_values());
    svc.shutdown();
}

#[test]
fn audit_is_deterministic() {
    let svc = SpectralService::native(2);
    let r1 = svc.audit_model(&zoo::lenet()).unwrap();
    let r2 = svc.audit_model(&zoo::lenet()).unwrap();
    for (a, b) in r1.iter().zip(&r2) {
        assert_eq!(a.sigma_max, b.sigma_max);
    }
    svc.shutdown();
}
