//! Integration tests for the L3 coordinator: tile scheduling correctness,
//! backpressure, PJRT/native routing, model audits, the content-addressed
//! result cache and metrics.

use conv_svd_lfa::conv::ConvKernel;
use conv_svd_lfa::coordinator::{
    Backend, JobSpec, Scheduler, SchedulerConfig, ServiceConfig, SpectralService,
};
use conv_svd_lfa::engine::SpectrumRequest;
use conv_svd_lfa::lfa::{self, LfaOptions, Precision};
use conv_svd_lfa::model::{zoo, ModelConfig};
use conv_svd_lfa::numeric::Pcg64;
#[cfg(feature = "pjrt")]
use std::path::PathBuf;
use std::sync::Arc;

fn kernel(c_out: usize, c_in: usize, seed: u64) -> ConvKernel {
    let mut rng = Pcg64::seeded(seed);
    ConvKernel::random_he(c_out, c_in, 3, 3, &mut rng)
}

#[test]
fn scheduler_matches_direct_lfa() {
    let k = kernel(4, 3, 1);
    let sched = Scheduler::native(3);
    let result = sched.run(JobSpec::new("t", k.clone(), 16, 16)).unwrap();
    let direct = lfa::singular_values(&k, 16, 16, LfaOptions::default());
    assert_eq!(result.spectrum.values.len(), direct.values.len());
    for (a, b) in result.spectrum.values.iter().zip(&direct.values) {
        assert!((a - b).abs() < 1e-12);
    }
    assert!(result.native_tiles > 0);
    assert_eq!(result.pjrt_tiles, 0);
    sched.shutdown();
}

#[test]
fn many_jobs_pipeline_through_bounded_queue() {
    let sched = Scheduler::start(
        SchedulerConfig { workers: 2, queue_depth: 2, ..Default::default() },
        None,
    );
    // More jobs than queue depth: backpressure must not deadlock.
    let mut rxs = Vec::new();
    for j in 0..12 {
        let k = kernel(3, 3, 100 + j);
        rxs.push((j, k.clone(), sched.submit(JobSpec::new(format!("job{j}"), k, 8, 8))));
    }
    for (j, k, rx) in rxs {
        let res = rx.recv().unwrap().unwrap();
        let want = lfa::singular_values(&k, 8, 8, LfaOptions::default());
        for (a, b) in res.spectrum.values.iter().zip(&want.values) {
            assert!((a - b).abs() < 1e-12, "job{j}");
        }
    }
    let m = sched.metrics.snapshot();
    assert_eq!(m.jobs_completed, 12);
    assert_eq!(m.jobs_submitted, 12);
    sched.shutdown();
}

#[test]
fn explicit_tile_rows_respected() {
    let k = kernel(2, 2, 5);
    let sched = Scheduler::native(2);
    // Folded (default): only the 7 fundamental-domain rows of the 12-row
    // grid are tiled (rows 0..=6; the rest mirror) → ceil(7/5) = 2 tiles.
    let res = sched.run(JobSpec::new("t", k.clone(), 12, 12).with_tile_rows(5)).unwrap();
    assert_eq!(res.native_tiles, 2);
    // Unfolded: all 12 rows / 5 per tile = 3 tiles.
    let res = sched
        .run(JobSpec::new("t2", k, 12, 12).with_tile_rows(5).with_folding(lfa::Fold::Off))
        .unwrap();
    assert_eq!(res.native_tiles, 3);
    sched.shutdown();
}

#[test]
fn folded_and_unfolded_jobs_agree_and_account_all_values() {
    let k = kernel(3, 3, 9);
    let sched = Scheduler::native(3);
    let folded = sched.run(JobSpec::new("f", k.clone(), 11, 7)).unwrap();
    let unfolded =
        sched.run(JobSpec::new("u", k.clone(), 11, 7).with_folding(lfa::Fold::Off)).unwrap();
    assert_eq!(folded.spectrum.values.len(), unfolded.spectrum.values.len());
    let scale = unfolded.spectrum.sigma_max().max(1.0);
    for (a, b) in folded.spectrum.values.iter().zip(&unfolded.spectrum.values) {
        assert!((a - b).abs() <= 1e-12 * scale, "{a} vs {b}");
    }
    // Folded jobs deliver (and account) the full grid's values.
    let m = sched.metrics.snapshot();
    assert_eq!(m.values_computed as usize, 2 * 11 * 7 * 3);
    sched.shutdown();
}

#[test]
fn pjrt_backend_requires_artifact() {
    let k = kernel(2, 2, 6); // no artifact for 2x2 channels
    let sched = Scheduler::native(1);
    let err = sched.run(JobSpec::new("t", k, 8, 8).with_backend(Backend::Pjrt));
    assert!(err.is_err(), "explicit PJRT without artifact must fail");
    sched.shutdown();
}

#[cfg(feature = "pjrt")]
fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.txt").exists() {
        Some(dir)
    } else {
        eprintln!("skipping PJRT part: run `make artifacts`");
        None
    }
}

#[cfg(feature = "pjrt")]
#[test]
fn service_auto_routes_to_pjrt_when_artifact_matches() {
    let Some(dir) = artifacts_dir() else { return };
    let svc = SpectralService::start(ServiceConfig {
        workers: 2,
        backend: Backend::Auto,
        artifacts_dir: Some(dir),
        ..Default::default()
    })
    .unwrap();
    // 32x32 c16 matches the tiled artifact.
    let k = kernel(16, 16, 7);
    let rep = svc.analyze_layer("conv", &k, 32, 32).unwrap();
    assert!(rep.pjrt_tiles > 0, "should route via PJRT");
    // Verify against native.
    let want = lfa::singular_values(&k, 32, 32, LfaOptions::default());
    let scale = want.sigma_max();
    for (a, b) in rep.spectrum.values.iter().zip(&want.values) {
        assert!((a - b).abs() < 2e-4 * scale.max(1.0), "{a} vs {b}");
    }
    assert!(rep.frobenius_defect < 1e-3, "defect {}", rep.frobenius_defect);
    // Unmatched shape falls back to native.
    let k2 = kernel(5, 5, 8);
    let rep2 = svc.analyze_layer("odd", &k2, 8, 8).unwrap();
    assert_eq!(rep2.pjrt_tiles, 0);
    assert!(rep2.frobenius_defect < 1e-10);
    svc.shutdown();
}

/// Regression for the PJRT cache gate: artifact results (f32) now cache
/// under keys pinned to `Precision::F32`, so a repeat PJRT audit is a
/// pure hit — zero tiles, shared buffer — while f64-native consumers of
/// the same content still recompute at full precision.
#[cfg(feature = "pjrt")]
#[test]
fn pjrt_repeat_audit_is_pure_cache_hit() {
    let Some(dir) = artifacts_dir() else { return };
    let artifacts = conv_svd_lfa::runtime::load_manifest(&dir).unwrap();
    let exec = conv_svd_lfa::runtime::PjrtExecutor::spawn().unwrap();
    let sched = Scheduler::start(
        SchedulerConfig { workers: 2, artifacts, ..Default::default() },
        Some(exec),
    );
    let k = kernel(16, 16, 7);
    let cold = sched.run(JobSpec::new("a", k.clone(), 32, 32)).unwrap();
    assert!(cold.pjrt_tiles > 0, "should route via PJRT");
    assert!(!cold.cached);
    let warm = sched.run(JobSpec::new("b", k.clone(), 32, 32)).unwrap();
    assert!(warm.cached, "repeat PJRT audit must be a pure cache hit");
    assert_eq!(warm.solved_freqs, 0);
    assert_eq!(warm.pjrt_tiles + warm.native_tiles, 0);
    assert!(Arc::ptr_eq(&warm.spectrum, &cold.spectrum), "hit shares the cached buffer");
    // A native f32 sweep of the same content is the same accuracy tier:
    // it shares the PJRT entry's key and hits.
    let f32nat = sched
        .run(
            JobSpec::new("c", k.clone(), 32, 32)
                .with_backend(Backend::Native)
                .with_precision(Precision::F32),
        )
        .unwrap();
    assert!(f32nat.cached, "native f32 and PJRT results share one tier");
    // An f64-native job of the same content keys its own tier: recompute.
    let f64nat =
        sched.run(JobSpec::new("d", k, 32, 32).with_backend(Backend::Native)).unwrap();
    assert!(!f64nat.cached, "f64 consumers must never see the f32 entry");
    assert!(f64nat.native_tiles > 0);
    sched.shutdown();
}

#[test]
fn audit_lenet_native() {
    let svc = SpectralService::native(2);
    let reports = svc.audit_model(&zoo::lenet()).unwrap();
    assert_eq!(reports.len(), 2);
    for r in &reports {
        assert!(r.sigma_max > 0.0);
        assert!(r.frobenius_defect < 1e-10, "{}: {}", r.name, r.frobenius_defect);
        assert_eq!(r.num_values, r.n * r.m * r.c_out.min(r.c_in));
    }
    let m = svc.metrics();
    assert_eq!(m.jobs_completed, 2);
    assert_eq!(m.values_computed as usize, zoo::lenet().total_values());
    svc.shutdown();
}

#[test]
fn audit_is_deterministic() {
    // Caching off: with it on, the second audit would be served the
    // first one's buffer and the comparison would be vacuous — this
    // test exists to catch nondeterminism in the *sweep*.
    let svc = SpectralService::start(ServiceConfig {
        workers: 2,
        cache_bytes: None,
        ..Default::default()
    })
    .unwrap();
    let r1 = svc.audit_model(&zoo::lenet()).unwrap();
    let r2 = svc.audit_model(&zoo::lenet()).unwrap();
    for (a, b) in r1.iter().zip(&r2) {
        assert!(!a.cached && !b.cached);
        assert_eq!(a.sigma_max, b.sigma_max);
        assert_eq!(a.spectrum.values, b.spectrum.values);
    }
    svc.shutdown();
}

// --- SpectralCache: content-addressed result & plan caching ---

#[test]
fn repeat_job_is_served_from_cache() {
    let k = kernel(3, 3, 21);
    let sched = Scheduler::native(2);
    let cold = sched.run(JobSpec::new("a", k.clone(), 10, 10)).unwrap();
    assert!(!cold.cached);
    assert!(cold.solved_freqs > 0);
    // Same content, different job id: the signature is over the weight
    // bits and geometry, so this is a hit — the very same buffer, zero
    // tiles, zero frequencies re-solved.
    let warm = sched.run(JobSpec::new("b", k.clone(), 10, 10)).unwrap();
    assert!(warm.cached, "identical content must be served from cache");
    assert_eq!(warm.solved_freqs, 0, "a cache hit re-solves zero frequencies");
    assert_eq!(warm.native_tiles + warm.pjrt_tiles, 0);
    assert!(Arc::ptr_eq(&warm.spectrum, &cold.spectrum), "hit shares the cached buffer");
    // A weight mutation changes the content signature: full recompute.
    let mut k2 = k.clone();
    k2.data[0] += 0.25;
    let changed = sched.run(JobSpec::new("c", k2, 10, 10)).unwrap();
    assert!(!changed.cached, "mutated weights must miss");
    assert_ne!(changed.spectrum.values, cold.spectrum.values);
    // Different grid or folding also miss (each is its own signature).
    let other_grid = sched.run(JobSpec::new("d", k.clone(), 8, 10)).unwrap();
    assert!(!other_grid.cached);
    let unfolded =
        sched.run(JobSpec::new("e", k.clone(), 10, 10).with_folding(lfa::Fold::Off)).unwrap();
    assert!(!unfolded.cached);
    let m = sched.metrics.snapshot();
    assert_eq!((m.cache_hits, m.cache_misses), (1, 4));
    sched.shutdown();
}

/// Signatures pin the precision tier, so f32 results — native here, and
/// PJRT by the same key construction — are cacheable: a repeat f32 audit
/// is a pure hit, and no tier is ever served another tier's spectrum.
#[test]
fn reduced_precision_jobs_cache_independently() {
    let k = kernel(4, 3, 31);
    let sched = Scheduler::native(2);
    let f64cold = sched.run(JobSpec::new("a", k.clone(), 10, 10)).unwrap();
    assert!(!f64cold.cached);
    // Same content at f32: its own signature — a miss, not a downgrade.
    let f32cold = sched
        .run(JobSpec::new("b", k.clone(), 10, 10).with_precision(Precision::F32))
        .unwrap();
    assert!(!f32cold.cached, "an f32 job must not be served the f64 spectrum");
    assert!(f32cold.solved_freqs > 0);
    let scale = f64cold.spectrum.sigma_max().max(1.0);
    for (a, b) in f32cold.spectrum.values.iter().zip(&f64cold.spectrum.values) {
        assert!((a - b).abs() <= 1e-4 * scale, "f32 {a} vs f64 {b}");
    }
    // Repeat f32 audit: a pure hit on the f32 entry.
    let f32warm = sched
        .run(JobSpec::new("c", k.clone(), 10, 10).with_precision(Precision::F32))
        .unwrap();
    assert!(f32warm.cached, "repeat f32 audit must be a pure cache hit");
    assert_eq!(f32warm.solved_freqs, 0);
    assert_eq!(f32warm.native_tiles + f32warm.pjrt_tiles, 0);
    assert!(Arc::ptr_eq(&f32warm.spectrum, &f32cold.spectrum));
    // Refined is its own tier and restores f64-grade accuracy.
    let refined = sched
        .run(JobSpec::new("d", k.clone(), 10, 10).with_precision(Precision::F32Refined))
        .unwrap();
    assert!(!refined.cached, "refined must not be served the f32 spectrum");
    for (a, b) in refined.spectrum.values.iter().zip(&f64cold.spectrum.values) {
        assert!((a - b).abs() <= 1e-12 * scale, "refined {a} vs f64 {b}");
    }
    // And the f64 entry is still there, untouched.
    let f64warm = sched.run(JobSpec::new("e", k, 10, 10)).unwrap();
    assert!(f64warm.cached);
    assert!(Arc::ptr_eq(&f64warm.spectrum, &f64cold.spectrum));
    let m = sched.metrics.snapshot();
    assert_eq!((m.cache_hits, m.cache_misses), (2, 3));
    sched.shutdown();
}

/// The service's `precision` config threads through whole-model audits,
/// and a repeat reduced-precision audit hits the cache layer-by-layer.
#[test]
fn service_precision_threads_through_model_audits() {
    let model = zoo::lenet();
    let reference = SpectralService::native(2);
    let want = reference.audit_model(&model).unwrap();
    reference.shutdown();
    let svc = SpectralService::start(ServiceConfig {
        workers: 2,
        precision: Precision::F32,
        ..Default::default()
    })
    .unwrap();
    let cold = svc.audit_model(&model).unwrap();
    assert!(cold.iter().all(|r| !r.cached && r.solved_freqs > 0));
    for (c, w) in cold.iter().zip(&want) {
        let scale = w.sigma_max.max(1.0);
        assert!(
            (c.sigma_max - w.sigma_max).abs() <= 1e-4 * scale,
            "{}: f32 σ_max {} vs f64 {}",
            c.name,
            c.sigma_max,
            w.sigma_max
        );
    }
    let warm = svc.audit_model(&model).unwrap();
    assert!(warm.iter().all(|r| r.cached), "repeat f32 audit must hit layer-by-layer");
    assert_eq!(warm.iter().map(|r| r.solved_freqs).sum::<usize>(), 0);
    for (c, w) in cold.iter().zip(&warm) {
        assert!(Arc::ptr_eq(&c.spectrum, &w.spectrum));
    }
    svc.shutdown();
}

#[test]
fn disabled_cache_recomputes_every_job() {
    let k = kernel(3, 2, 22);
    let sched = Scheduler::start(
        SchedulerConfig { workers: 2, cache_bytes: None, ..Default::default() },
        None,
    );
    assert!(sched.cache().is_none());
    let a = sched.run(JobSpec::new("a", k.clone(), 8, 8)).unwrap();
    let b = sched.run(JobSpec::new("b", k, 8, 8)).unwrap();
    assert!(!a.cached && !b.cached);
    assert!(b.solved_freqs > 0);
    assert_eq!(a.spectrum.values, b.spectrum.values, "determinism does not need the cache");
    let m = sched.metrics.snapshot();
    assert_eq!((m.cache_hits, m.cache_misses), (0, 0));
    sched.shutdown();
}

#[test]
fn repeat_model_audit_is_served_entirely_from_cache() {
    let model = zoo::lenet();
    let svc = SpectralService::native(2);
    let cold = svc.audit_model(&model).unwrap();
    assert!(cold.iter().all(|r| !r.cached && r.solved_freqs > 0));
    let warm = svc.audit_model(&model).unwrap();
    assert!(warm.iter().all(|r| r.cached), "unchanged model must hit layer-by-layer");
    assert_eq!(warm.iter().map(|r| r.solved_freqs).sum::<usize>(), 0);
    for (c, w) in cold.iter().zip(&warm) {
        assert!(Arc::ptr_eq(&c.spectrum, &w.spectrum), "{}: hit shares the buffer", c.name);
        assert_eq!(c.sigma_max, w.sigma_max);
        assert_eq!(c.sigma_min, w.sigma_min);
    }
    let m = svc.metrics();
    assert_eq!(m.cache_hits as usize, model.layers.len());
    assert_eq!(m.cache_misses as usize, model.layers.len());
    let stats = svc.cache_stats().expect("cache is on by default");
    assert_eq!(stats.hits, m.cache_hits);
    assert_eq!(stats.entries, model.layers.len());
    assert!(stats.bytes > 0 && stats.bytes <= stats.capacity);
    svc.shutdown();
}

/// The training-loop shape: after a "step" mutates one layer's weights,
/// a re-audit recomputes only that layer — the rest hit the cache.
#[test]
fn mutated_layer_recomputes_while_the_rest_hit() {
    const BASE: &str = "name = \"two\"\nseed = 5\n\
        [[layer]]\nname = \"a\"\nc_in = 2\nc_out = 3\nheight = 8\nwidth = 8\n\
        [[layer]]\nname = \"b\"\nc_in = 3\nc_out = 3\nheight = 6\nwidth = 6\n";
    let base = ModelConfig::parse(BASE).unwrap();
    // The same model with layer b's weights drawn differently — the
    // stand-in for one training step touching one layer.
    let mutated = ModelConfig::parse(&BASE.replace(
        "name = \"b\"",
        "name = \"b\"\ninit = \"glorot\"",
    ))
    .unwrap();
    let svc = SpectralService::native(2);
    let cold = svc.audit_model(&base).unwrap();
    let mixed = svc.audit_model(&mutated).unwrap();
    assert!(mixed[0].cached, "unchanged layer a must hit");
    assert_eq!(mixed[0].solved_freqs, 0);
    assert!(!mixed[1].cached, "mutated layer b must recompute");
    assert!(mixed[1].solved_freqs > 0);
    assert!(Arc::ptr_eq(&cold[0].spectrum, &mixed[0].spectrum));
    assert_ne!(cold[1].spectrum.values, mixed[1].spectrum.values);
    let m = svc.metrics();
    assert_eq!((m.cache_hits, m.cache_misses), (1, 3));
    svc.shutdown();
}

#[test]
fn queue_depth_zero_means_default_and_explicit_is_respected() {
    let d = SchedulerConfig::default();
    assert_eq!(d.effective_queue_depth(), SchedulerConfig::DEFAULT_QUEUE_DEPTH);
    let svc = SpectralService::native(1);
    assert_eq!(svc.queue_depth(), SchedulerConfig::DEFAULT_QUEUE_DEPTH);
    svc.shutdown();
    let svc = SpectralService::start(ServiceConfig {
        workers: 1,
        queue_depth: 3,
        ..Default::default()
    })
    .unwrap();
    assert_eq!(svc.queue_depth(), 3);
    // The explicit depth still pipelines more jobs than it has slots.
    for j in 0..8 {
        let k = kernel(2, 2, 300 + j);
        let rep = svc.analyze_layer("q", &k, 6, 6).unwrap();
        assert!(rep.sigma_max > 0.0);
    }
    svc.shutdown();
}

/// Regression: under a top-k request the retained per-frequency values
/// are the *largest* ones, so σ_min and the condition number are
/// undefined — they must report NaN (like `frobenius_defect` already
/// does), not the smallest retained value.
#[test]
fn topk_audit_reports_nan_extremes() {
    const MODEL: &str = "name = \"nan\"\nseed = 9\n\
        [[layer]]\nname = \"a\"\nc_in = 3\nc_out = 4\nheight = 8\nwidth = 8\n";
    let model = ModelConfig::parse(MODEL).unwrap();
    let svc = SpectralService::native(2);
    let reports = svc.audit_model_with(&model, SpectrumRequest::TopK(1)).unwrap();
    for r in &reports {
        assert!(r.spectrum.is_partial());
        assert!(r.sigma_max > 0.0, "{}: σ_max is exact under top-k", r.name);
        assert!(r.sigma_min.is_nan(), "{}: σ_min off a truncated spectrum", r.name);
        assert!(r.condition.is_nan(), "{}: condition off a truncated spectrum", r.name);
        assert!(r.frobenius_defect.is_nan());
        // The smallest *computed* value stays accessible, clearly named.
        assert!(r.spectrum.min_stored() > 0.0 && r.spectrum.min_stored().is_finite());
    }
    // Full requests still report real extremes.
    let full = svc.audit_model(&model).unwrap();
    assert!(full[0].sigma_min.is_finite() && full[0].condition.is_finite());
    svc.shutdown();
}
