//! Cross-validation of the three routes to the spectrum (the paper's core
//! correctness claim): LFA == FFT == explicit under periodic boundary
//! conditions, plus the Fig. 6 boundary-condition behaviour in miniature.

use conv_svd_lfa::baselines::{explicit_svd, fft_svd, FftLayoutPolicy};
use conv_svd_lfa::conv::{Boundary, ConvKernel};
use conv_svd_lfa::lfa::{self, BlockSolver, LfaOptions, Spectrum};
use conv_svd_lfa::numeric::Pcg64;

fn max_gap(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
}

#[test]
fn three_routes_agree_periodic() {
    let mut rng = Pcg64::seeded(11);
    for &(n, c_out, c_in) in &[(4usize, 3usize, 3usize), (6, 2, 4), (8, 4, 2)] {
        let k = ConvKernel::random_he(c_out, c_in, 3, 3, &mut rng);
        let lfa_sorted =
            lfa::singular_values(&k, n, n, LfaOptions::default()).sorted_desc();
        let fft_sorted =
            fft_svd::singular_values(&k, n, n, FftLayoutPolicy::Natural, 1).sorted_desc();
        let exp = explicit_svd::singular_values(&k, n, n, Boundary::Periodic);
        // explicit has n²·c_out values incl. zeros when c_out > c_in; compare
        // the top n²·min values.
        let top = lfa_sorted.len();
        assert!(max_gap(&lfa_sorted, &fft_sorted) < 1e-10, "lfa vs fft n={n}");
        assert!(
            max_gap(&lfa_sorted, &exp.values[..top]) < 1e-7,
            "lfa vs explicit n={n}: {}",
            max_gap(&lfa_sorted, &exp.values[..top])
        );
        // Values the explicit route has beyond min(c_in,c_out) per frequency
        // must be (numerically) zero.
        for &v in &exp.values[top..] {
            assert!(v < 1e-8, "trailing explicit σ = {v}");
        }
    }
}

#[test]
fn solver_choice_is_equivalent() {
    let mut rng = Pcg64::seeded(12);
    let k = ConvKernel::random_he(5, 5, 3, 3, &mut rng);
    let a = lfa::singular_values(
        &k,
        10,
        10,
        LfaOptions { solver: BlockSolver::Jacobi, ..Default::default() },
    );
    let b = lfa::singular_values(
        &k,
        10,
        10,
        LfaOptions { solver: BlockSolver::GramEigen, ..Default::default() },
    );
    assert!(max_gap(&a.values, &b.values) < 1e-7);
}

#[test]
fn fig6_boundary_divergence_shrinks_with_n() {
    // Fig. 6: Dirichlet vs periodic spectra converge as n grows.
    let mut rng = Pcg64::seeded(13);
    let k = ConvKernel::random_he(4, 4, 3, 3, &mut rng);
    let mut divs = Vec::new();
    for &n in &[4usize, 8, 16] {
        let periodic = lfa::singular_values(&k, n, n, LfaOptions::default()).sorted_desc();
        let dirichlet = explicit_svd::singular_values(&k, n, n, Boundary::Dirichlet);
        let div = Spectrum::divergence(&periodic, &dirichlet.values);
        divs.push((n, div));
    }
    assert!(
        divs[0].1 > divs[2].1,
        "divergence should shrink: {divs:?}"
    );
    assert!(divs[2].1 < 0.05, "n=16 divergence should be small: {divs:?}");
}

#[test]
fn kernel_anchor_only_changes_phases() {
    // Shifting the anchor multiplies symbols by a unit phase — singular
    // values are invariant (translation invariance, the property LFA
    // exploits).
    let mut rng = Pcg64::seeded(14);
    let mut k1 = ConvKernel::random_he(3, 3, 3, 3, &mut rng);
    k1.anchor = (1, 1);
    let mut k2 = k1.clone();
    k2.anchor = (0, 2);
    let s1 = lfa::singular_values(&k1, 8, 8, LfaOptions::default());
    let s2 = lfa::singular_values(&k2, 8, 8, LfaOptions::default());
    assert!(max_gap(&s1.values, &s2.values) < 1e-10);
}

#[test]
fn one_by_one_kernels_and_large_kernels() {
    let mut rng = Pcg64::seeded(15);
    // 1x1 and 5x5 kernels through both fast routes.
    for (kh, kw) in [(1usize, 1usize), (5, 5), (1, 3), (3, 5)] {
        let k = ConvKernel::random_he(3, 2, kh, kw, &mut rng);
        let a = lfa::singular_values(&k, 8, 8, LfaOptions::default()).sorted_desc();
        let b = fft_svd::singular_values(&k, 8, 8, FftLayoutPolicy::Natural, 1).sorted_desc();
        assert!(max_gap(&a, &b) < 1e-10, "{kh}x{kw}");
    }
}

#[test]
fn wrap_around_kernels_larger_than_grid() {
    // 5x5 kernel on a 4x4 grid: taps wrap and accumulate. LFA and FFT must
    // agree on this degenerate (but well-defined) case too.
    let mut rng = Pcg64::seeded(16);
    let k = ConvKernel::random_he(2, 2, 5, 5, &mut rng);
    let a = lfa::singular_values(&k, 4, 4, LfaOptions::default()).sorted_desc();
    let b = fft_svd::singular_values(&k, 4, 4, FftLayoutPolicy::Natural, 1).sorted_desc();
    let c = explicit_svd::singular_values(&k, 4, 4, Boundary::Periodic);
    assert!(max_gap(&a, &b) < 1e-10);
    assert!(max_gap(&a, &c.values[..a.len()]) < 1e-8);
}

#[test]
fn layout_policy_does_not_change_values() {
    let mut rng = Pcg64::seeded(17);
    let k = ConvKernel::random_he(4, 4, 3, 3, &mut rng);
    let nat = fft_svd::singular_values(&k, 12, 12, FftLayoutPolicy::Natural, 1);
    let conv = fft_svd::singular_values(&k, 12, 12, FftLayoutPolicy::ConvertToContiguous, 1);
    assert!(max_gap(&nat.values, &conv.values) < 1e-12);
}

#[test]
fn non_square_grids() {
    let mut rng = Pcg64::seeded(18);
    let k = ConvKernel::random_he(3, 3, 3, 3, &mut rng);
    for (n, m) in [(4usize, 12usize), (5, 7), (16, 2)] {
        let a = lfa::singular_values(&k, n, m, LfaOptions::default()).sorted_desc();
        let b = fft_svd::singular_values(&k, n, m, FftLayoutPolicy::Natural, 1).sorted_desc();
        assert!(max_gap(&a, &b) < 1e-10, "({n},{m})");
        assert_eq!(a.len(), n * m * 3);
    }
}
