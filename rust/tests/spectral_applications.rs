//! End-to-end tests of the application modules (§II-c of the paper):
//! clipping, low-rank compression, pseudo-inverse, spectral-norm
//! estimators — all through the public API on realistic layer shapes.

use conv_svd_lfa::conv::{Boundary, ConvKernel, ConvOp};
use conv_svd_lfa::lfa::{self, compute_symbols, BlockLayout, LfaOptions};
use conv_svd_lfa::linalg::power::LinOp;
use conv_svd_lfa::numeric::Pcg64;
use conv_svd_lfa::spectral::{clip, freq_op::FreqOperator, lipschitz, lowrank, pinv};

#[test]
fn clipping_enforces_lipschitz_bound_on_operator() {
    let mut rng = Pcg64::seeded(300);
    let k = ConvKernel::random_he(8, 8, 3, 3, &mut rng);
    let (n, m) = (16, 16);
    let before = lfa::singular_values(&k, n, m, LfaOptions::default()).sigma_max();
    let cap = before * 0.6;
    let res = clip::clip_spectral_norm(&k, n, m, cap, LfaOptions::default());
    // The exact clipped operator really is 1-Lipschitz at the cap: apply it
    // to random inputs and check the gain.
    let fop = FreqOperator::new(&res.grid);
    for trial in 0..5 {
        let mut trial_rng = Pcg64::seeded(301 + trial);
        let f = trial_rng.normal_vec(n * m * 8);
        let g = fop.apply(&f);
        let fn2: f64 = f.iter().map(|v| v * v).sum::<f64>().sqrt();
        let gn2: f64 = g.iter().map(|v| v * v).sum::<f64>().sqrt();
        assert!(gn2 <= cap * fn2 * (1.0 + 1e-9), "gain {} > cap {cap}", gn2 / fn2);
    }
}

#[test]
fn low_rank_operator_acts_close_to_original() {
    let mut rng = Pcg64::seeded(310);
    let k = ConvKernel::random_he(8, 8, 3, 3, &mut rng);
    let (n, m) = (8, 8);
    let c = lowrank::compress(&k, n, m, 6, LfaOptions::default());
    let exact = compute_symbols(&k, n, m, BlockLayout::BlockContiguous);
    let f_exact = FreqOperator::new(&exact);
    let f_low = FreqOperator::new(&c.grid);
    // Average relative error over random inputs should be within ~2x of the
    // Eckart–Young bound (inputs are not aligned with the residual space).
    let mut rel_acc = 0.0;
    let trials = 8;
    for t in 0..trials {
        let mut trng = Pcg64::seeded(311 + t);
        let f = trng.normal_vec(n * m * 8);
        let y1 = f_exact.apply(&f);
        let y2 = f_low.apply(&f);
        let err: f64 = y1.iter().zip(&y2).map(|(a, b)| (a - b) * (a - b)).sum::<f64>().sqrt();
        let den: f64 = y1.iter().map(|v| v * v).sum::<f64>().sqrt();
        rel_acc += err / den;
    }
    let mean_rel = rel_acc / trials as f64;
    assert!(mean_rel < 2.0 * c.rel_error + 0.05, "mean {mean_rel} vs EY {}", c.rel_error);
}

#[test]
fn compression_storage_decreases_with_rank() {
    let mut rng = Pcg64::seeded(320);
    let k = ConvKernel::random_he(8, 8, 3, 3, &mut rng);
    let sweep = lowrank::rank_sweep(&k, 8, 8, LfaOptions::default());
    for w in sweep.windows(2) {
        assert!(w[0].2 < w[1].2, "storage grows with rank");
        assert!(w[0].1 >= w[1].1, "error shrinks with rank");
    }
}

#[test]
fn pinv_solves_deconvolution() {
    // Blur (random conv) then deconvolve via A⁺: recovers the input under
    // periodic BC when A is square full-rank.
    let mut rng = Pcg64::seeded(330);
    let k = ConvKernel::random_he(4, 4, 3, 3, &mut rng);
    let (n, m) = (12, 12);
    let op = ConvOp::new(&k, n, m, Boundary::Periodic);
    let image = rng.normal_vec(op.in_dim());
    let blurred = op.forward(&image);
    let inv = pinv::pseudo_inverse(&k, n, m, 1e-10, LfaOptions::default());
    let recovered = FreqOperator::new(&inv.grid).apply(&blurred);
    for (a, b) in image.iter().zip(&recovered) {
        assert!((a - b).abs() < 1e-6, "{a} vs {b}");
    }
}

#[test]
fn estimator_ordering_on_realistic_layer() {
    let mut rng = Pcg64::seeded(340);
    let k = ConvKernel::random_he(16, 16, 3, 3, &mut rng);
    let rep = lipschitz::spectral_report(&k, 16, 16, LfaOptions::default());
    // exact == power, both ≤ certified bounds.
    assert!((rep.exact_lfa - rep.power_iteration).abs() / rep.exact_lfa < 1e-5);
    assert!(rep.ym_upper_bound >= rep.exact_lfa);
    assert!(rep.holder_bound >= rep.exact_lfa);
    assert!(rep.condition.is_finite());
}

#[test]
fn clip_then_reclip_is_idempotent() {
    let mut rng = Pcg64::seeded(350);
    let k = ConvKernel::random_he(4, 4, 3, 3, &mut rng);
    let (n, m) = (8, 8);
    let cap = 0.5;
    let first = clip::clip_spectral_norm(&k, n, m, cap, LfaOptions::default());
    // Re-clip the *projected* kernel: second projection should change it
    // much less than the first did (Dykstra-like shrinking steps).
    let d1: f64 = k
        .data
        .iter()
        .zip(&first.projected_kernel.data)
        .map(|(a, b)| (a - b) * (a - b))
        .sum::<f64>()
        .sqrt();
    let second =
        clip::clip_spectral_norm(&first.projected_kernel, n, m, cap, LfaOptions::default());
    let d2: f64 = first
        .projected_kernel
        .data
        .iter()
        .zip(&second.projected_kernel.data)
        .map(|(a, b)| (a - b) * (a - b))
        .sum::<f64>()
        .sqrt();
    assert!(d2 < d1 * 0.6, "second projection {d2} vs first {d1}");
}
