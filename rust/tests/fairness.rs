//! Multi-tenant fairness: property tests for the daemon's deficit-round-
//! robin [`FairQueue`] (admission quotas, bounded delay for well-behaved
//! tenants, determinism) plus end-to-end checks over the daemon socket —
//! a flooding tenant cannot starve a well-behaved one, quota rejections
//! name the right tenant, and a serial and a threaded daemon make
//! identical admission decisions for the same submission script.
#![cfg(feature = "daemon")]

use conv_svd_lfa::coordinator::server::serve;
use conv_svd_lfa::coordinator::{DaemonConfig, FairQueue, ServiceConfig};
use conv_svd_lfa::testing::{prop_assert, prop_check, Gen};
use std::collections::{HashMap, VecDeque};
use std::fs;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::time::Duration;

// ---------------------------------------------------------------------
// FairQueue unit + property tests
// ---------------------------------------------------------------------

/// Random op sequences against a reference model: quotas are enforced
/// per tenant with the exact (tenant, pending, quota) rejection payload,
/// pops respect per-tenant FIFO order, no job is lost or duplicated —
/// and a twin queue fed the identical sequence stays in lockstep (the
/// determinism the daemon's serial-vs-threaded admission test relies on).
#[test]
fn fairqueue_random_ops_match_reference_model() {
    prop_check("fairqueue invariants", 150, |g: &mut Gen| {
        let quota = g.usize_in(1, 4);
        let quantum = g.usize_in(1, 4);
        let mut q = FairQueue::new(quota, quantum);
        let mut twin = FairQueue::new(quota, quantum);
        let tenants = ["a", "b", "c"];
        // Reference model: per-tenant FIFO of queued ids + in-flight count.
        let mut queued: HashMap<&str, VecDeque<u64>> =
            tenants.iter().map(|t| (*t, VecDeque::new())).collect();
        let mut in_flight: HashMap<&str, usize> = tenants.iter().map(|t| (*t, 0)).collect();
        let mut next_id = 0u64;
        let ops = g.usize_in(10, 80);
        for _ in 0..ops {
            match g.usize_in(0, 2) {
                0 => {
                    let t = *g.pick(&tenants);
                    let cost = g.usize_in(1, 5);
                    let id = next_id;
                    next_id += 1;
                    let r = q.try_enqueue(t, id, cost);
                    prop_assert(r == twin.try_enqueue(t, id, cost), "twin diverged: enqueue")?;
                    let pending = queued[t].len() + in_flight[t];
                    match r {
                        Ok(()) => {
                            prop_assert(pending < quota, format!("admitted over quota: {t}"))?;
                            queued.get_mut(t).unwrap().push_back(id);
                        }
                        Err(e) => {
                            prop_assert(
                                pending >= quota,
                                format!("rejected under quota: {t} at {pending}/{quota}"),
                            )?;
                            prop_assert(
                                e.tenant == t && e.pending == pending && e.quota == quota,
                                format!("wrong rejection payload: {e:?}"),
                            )?;
                        }
                    }
                }
                1 => {
                    let r = q.pop();
                    prop_assert(r == twin.pop(), "twin diverged: pop")?;
                    match r {
                        Some((id, t)) => {
                            let fifo = queued.get_mut(t.as_str()).unwrap();
                            prop_assert(
                                fifo.front() == Some(&id),
                                format!("pop broke {t}'s FIFO order: got {id}"),
                            )?;
                            fifo.pop_front();
                            *in_flight.get_mut(t.as_str()).unwrap() += 1;
                        }
                        None => {
                            prop_assert(
                                queued.values().all(|f| f.is_empty()),
                                "pop returned None with work queued",
                            )?;
                        }
                    }
                }
                _ => {
                    let t = *g.pick(&tenants);
                    if in_flight[t] > 0 {
                        q.complete(t);
                        twin.complete(t);
                        *in_flight.get_mut(t).unwrap() -= 1;
                    }
                }
            }
        }
        // Drain: everything admitted must come out, exactly once.
        let mut remaining: usize = queued.values().map(|f| f.len()).sum();
        while let Some((id, t)) = q.pop() {
            let fifo = queued.get_mut(t.as_str()).unwrap();
            prop_assert(fifo.pop_front() == Some(id), "drain lost FIFO order")?;
            remaining -= 1;
        }
        prop_assert(remaining == 0, format!("{remaining} admitted jobs never dispatched"))?;
        Ok(())
    });
}

/// Bounded delay: however deep another tenant's backlog, a well-behaved
/// tenant's unit-cost job is served within one cursor sweep — with two
/// active tenants, within 2 pops of being enqueued.
#[test]
fn well_behaved_tenant_is_served_within_one_sweep() {
    prop_check("bounded delay under flood", 100, |g: &mut Gen| {
        let quantum = g.usize_in(1, 4);
        let mut q = FairQueue::new(1_000, quantum);
        let flood_depth = g.usize_in(5, 40);
        for id in 0..flood_depth as u64 {
            q.try_enqueue("flood", id, g.usize_in(1, 5)).unwrap();
        }
        // Let the flood get an arbitrary head start.
        for _ in 0..g.usize_in(0, 5) {
            q.pop();
        }
        q.try_enqueue("good", 9_999, 1).unwrap();
        let served_within = (1..=2).any(|_| matches!(q.pop(), Some((9_999, _))));
        prop_assert(
            served_within,
            format!("good tenant starved behind a {flood_depth}-deep flood"),
        )?;
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Daemon-level fairness over the socket
// ---------------------------------------------------------------------

struct TempDir(PathBuf);

impl TempDir {
    fn new(tag: &str) -> Self {
        let dir = std::env::temp_dir().join(format!("lfa-fair-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        TempDir(dir)
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = fs::remove_dir_all(&self.0);
    }
}

/// A model big enough that a job takes real milliseconds (so completion
/// races in the flood test have a wide margin), small enough to stay fast.
fn write_model(dir: &TempDir) -> PathBuf {
    let path = dir.0.join("model.toml");
    fs::write(
        &path,
        "name = \"fair\"\nseed = 3\n\
         [[layer]]\nname = \"a\"\nc_in = 2\nc_out = 3\nheight = 24\nwidth = 24\n",
    )
    .unwrap();
    path
}

struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    fn connect(addr: SocketAddr) -> Self {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let reader = BufReader::new(stream.try_clone().unwrap());
        Client { reader, writer: stream }
    }

    fn send(&mut self, line: &str) -> String {
        writeln!(self.writer, "{line}").unwrap();
        let mut reply = String::new();
        self.reader.read_line(&mut reply).unwrap();
        assert!(!reply.is_empty(), "daemon closed the connection on {line:?}");
        reply.trim_end().to_string()
    }
}

fn queued_id(reply: &str) -> u64 {
    assert!(reply.starts_with("QUEUED id="), "not an admission reply: {reply}");
    reply
        .split_whitespace()
        .find_map(|tok| tok.strip_prefix("id="))
        .unwrap()
        .parse()
        .unwrap()
}

fn daemon_config(workers: usize, max_inflight: usize, quota: usize, paused: bool) -> DaemonConfig {
    DaemonConfig {
        service: ServiceConfig { workers, tenant_quota: quota, ..Default::default() },
        addr: "127.0.0.1:0".to_string(),
        max_inflight,
        start_paused: paused,
        ..Default::default()
    }
}

/// A flooding tenant submits a deep backlog, then a well-behaved tenant
/// submits one job; with a single runner the well-behaved job must
/// complete while most of the flood is still pending — FIFO would have
/// completed the entire flood first.
#[test]
fn flooding_tenant_cannot_starve_well_behaved_one() {
    let tmp = TempDir::new("flood");
    let model = write_model(&tmp);
    let model = model.to_str().unwrap();
    let handle = serve(daemon_config(2, 1, 8, true)).unwrap();
    let mut c = Client::connect(handle.addr());
    let flood_ids: Vec<u64> =
        (0..6).map(|_| queued_id(&c.send(&format!("SUBMIT flood {model}")))).collect();
    let good_id = queued_id(&c.send(&format!("SUBMIT good {model}")));
    assert_eq!(c.send("RESUME"), "OK resumed");
    let done = c.send(&format!("WAIT {good_id}"));
    assert!(done.starts_with("DONE id="), "good tenant's job must complete: {done}");
    // The flood was submitted first; strict FIFO would finish all 6 flood
    // jobs before the good tenant's. DRR must interleave instead.
    let flood_done = flood_ids
        .iter()
        .filter(|id| c.send(&format!("POLL {id}")).starts_with("DONE"))
        .count();
    assert!(
        flood_done < flood_ids.len(),
        "good tenant was served only after the whole flood drained"
    );
    // Drain and stop; the flood does complete eventually (no lost jobs).
    for id in &flood_ids {
        assert!(c.send(&format!("WAIT {id}")).starts_with("DONE"), "flood job {id} lost");
    }
    assert_eq!(c.send("SHUTDOWN"), "OK shutting-down");
    handle.wait();
}

/// Quota rejections are per-tenant and carry the right payload: the
/// flooding tenant is named (never the well-behaved one), with its own
/// pending count; the other tenant still gets admitted.
#[test]
fn quota_rejection_names_the_offending_tenant() {
    let tmp = TempDir::new("quota");
    let model = write_model(&tmp);
    let model = model.to_str().unwrap();
    // Paused: nothing completes, so admission state is exact.
    let handle = serve(daemon_config(1, 1, 2, true)).unwrap();
    let mut c = Client::connect(handle.addr());
    let mut admitted = Vec::new();
    admitted.push(queued_id(&c.send(&format!("SUBMIT flood {model}"))));
    admitted.push(queued_id(&c.send(&format!("SUBMIT flood {model}"))));
    let rejected = c.send(&format!("SUBMIT flood {model}"));
    assert_eq!(rejected, "ERR quota tenant=flood pending=2 limit=2");
    // The other tenant's budget is untouched.
    admitted.push(queued_id(&c.send(&format!("SUBMIT calm {model}"))));
    assert_eq!(c.send("RESUME"), "OK resumed");
    for id in &admitted {
        assert!(c.send(&format!("WAIT {id}")).starts_with("DONE"));
    }
    // Completion freed the flood tenant's quota.
    assert!(c.send(&format!("SUBMIT flood {model}")).starts_with("QUEUED"));
    assert_eq!(c.send("SHUTDOWN"), "OK shutting-down");
    handle.wait();
}

/// Admission decisions depend only on the submission sequence, never on
/// scheduler threading: a serial daemon (1 worker, 1 runner) and a
/// threaded one (4 workers, 4 runners) given the same paused submission
/// script produce byte-identical reply transcripts.
#[test]
fn serial_and_threaded_daemons_admit_identically() {
    let tmp = TempDir::new("determinism");
    let model = write_model(&tmp);
    let model = model.to_str().unwrap();
    let script: Vec<&str> = vec!["a", "a", "b", "a", "b", "b", "a", "c", "a", "b"];
    let mut transcripts = Vec::new();
    for (workers, inflight) in [(1, 1), (4, 4)] {
        let handle = serve(daemon_config(workers, inflight, 3, true)).unwrap();
        let mut c = Client::connect(handle.addr());
        let mut transcript = Vec::new();
        for tenant in &script {
            let reply = c.send(&format!("SUBMIT {tenant} {model}"));
            if let Some(rest) = reply.strip_prefix("ERR quota ") {
                assert!(
                    rest.contains(&format!("tenant={tenant}")),
                    "rejection names the wrong tenant: {reply}"
                );
            }
            transcript.push(reply);
        }
        // Drain so shutdown is clean.
        assert_eq!(c.send("RESUME"), "OK resumed");
        for reply in &transcript {
            if reply.starts_with("QUEUED") {
                let id = queued_id(reply);
                assert!(c.send(&format!("WAIT {id}")).starts_with("DONE"));
            }
        }
        assert_eq!(c.send("SHUTDOWN"), "OK shutting-down");
        handle.wait();
        transcripts.push(transcript);
    }
    assert_eq!(
        transcripts[0], transcripts[1],
        "serial and threaded admission must be byte-identical"
    );
    // Sanity on the shared transcript: quota 3 per tenant, nothing ran
    // while paused → a (5 submits) admits 3, b (4) admits 3, c (1) admits 1.
    let queued = transcripts[0].iter().filter(|r| r.starts_with("QUEUED")).count();
    let rejected = transcripts[0].iter().filter(|r| r.starts_with("ERR quota")).count();
    assert_eq!((queued, rejected), (7, 3));
}
