//! Engine equivalence: the planned execution core ([`SpectralPlan`]) must
//! reproduce the per-frequency reference pipeline — `symbol_at` (direct
//! trig, no tables) + the standalone block solvers — to ≤ 1e-10 across
//! every configuration axis: both block layouts, both solvers, strided and
//! unstrided kernels, odd and even grids, square and rectangular channel
//! counts. Plus reuse semantics: repeated `execute()` on one plan is
//! bitwise identical.

use conv_svd_lfa::conv::ConvKernel;
use conv_svd_lfa::engine::{
    FullAssembly, NativeSerial, NativeThreaded, SpectralBackend, SpectralPlan, SpectrumRequest,
    SweepOptions,
};
use conv_svd_lfa::lfa::symbol::symbol_at;
use conv_svd_lfa::lfa::{self, BlockLayout, BlockSolver, Fold, LfaOptions, Precision};
use conv_svd_lfa::linalg::{jacobi_eig, jacobi_svd};
use conv_svd_lfa::numeric::{CMat, Pcg64};

const TOL: f64 = 1e-10;

fn solve_reference(block: &CMat, solver: BlockSolver) -> Vec<f64> {
    match solver {
        BlockSolver::Jacobi => jacobi_svd::singular_values(block),
        BlockSolver::GramEigen => jacobi_eig::singular_values_gram(block),
    }
}

/// Frequency-by-frequency reference spectrum, bypassing the engine
/// entirely: direct per-frequency trig (`symbol_at`) + allocating solvers.
fn reference_unstrided(k: &ConvKernel, n: usize, m: usize, solver: BlockSolver) -> Vec<f64> {
    let r = k.c_out.min(k.c_in);
    let mut values = vec![0.0f64; n * m * r];
    for ki in 0..n {
        for kj in 0..m {
            let sv = solve_reference(&symbol_at(k, n, m, ki, kj), solver);
            let f = ki * m + kj;
            values[f * r..(f + 1) * r].copy_from_slice(&sv[..r]);
        }
    }
    values
}

fn reference_strided(
    k: &ConvKernel,
    n: usize,
    m: usize,
    s: usize,
    solver: BlockSolver,
) -> Vec<f64> {
    let (nc, mc) = (n / s, m / s);
    let r = k.c_out.min(s * s * k.c_in);
    let mut values = vec![0.0f64; nc * mc * r];
    for ki in 0..nc {
        for kj in 0..mc {
            let block = lfa::strided_symbol_at(k, n, m, s, ki, kj);
            let sv = solve_reference(&block, solver);
            let f = ki * mc + kj;
            values[f * r..(f + 1) * r].copy_from_slice(&sv[..r]);
        }
    }
    values
}

fn max_gap(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len(), "spectrum lengths differ");
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
}

#[test]
fn plan_matches_reference_across_all_configs() {
    let mut rng = Pcg64::seeded(7001);
    // Odd/even, square/rectangular grids; square/tall/wide channel counts.
    for &(n, m) in &[(6usize, 6usize), (5, 7), (8, 3), (4, 4)] {
        for &(c_out, c_in) in &[(3usize, 3usize), (4, 2), (2, 4)] {
            let k = ConvKernel::random_he(c_out, c_in, 3, 3, &mut rng);
            for layout in [BlockLayout::BlockContiguous, BlockLayout::PlanarStrided] {
                for solver in [BlockSolver::Jacobi, BlockSolver::GramEigen] {
                    let want = reference_unstrided(&k, n, m, solver);
                    for threads in [1usize, 3] {
                        for folding in [Fold::Auto, Fold::Off] {
                            // Full literal on purpose: a new LfaOptions
                            // field must be weighed for this matrix.
                            let opts = LfaOptions {
                                layout,
                                solver,
                                threads,
                                folding,
                                precision: Precision::F64,
                            };
                            let got = SpectralPlan::new(&k, n, m, opts).execute();
                            let gap = max_gap(&got.values, &want);
                            assert!(
                                gap < TOL,
                                "{n}x{m} {c_out}x{c_in} {layout:?} {solver:?} x{threads} \
                                 {folding:?}: gap {gap}"
                            );
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn strided_plan_matches_reference() {
    let mut rng = Pcg64::seeded(7002);
    for &(n, m, s) in &[(8usize, 8usize, 2usize), (6, 6, 3), (4, 8, 2)] {
        for &(c_out, c_in) in &[(2usize, 2usize), (3, 2)] {
            let k = ConvKernel::random_he(c_out, c_in, 3, 3, &mut rng);
            for solver in [BlockSolver::Jacobi, BlockSolver::GramEigen] {
                let want = reference_strided(&k, n, m, s, solver);
                let opts = LfaOptions { solver, threads: 1, ..Default::default() };
                let got = SpectralPlan::with_stride(&k, n, m, s, opts).execute();
                let gap = max_gap(&got.values, &want);
                assert!(gap < TOL, "{n}x{m}/{s} {c_out}x{c_in} {solver:?}: gap {gap}");
            }
        }
    }
}

#[test]
fn legacy_entry_points_match_plan() {
    // The public lfa:: wrappers are the plan — but assert it anyway so a
    // future de-unification shows up as a test failure.
    let mut rng = Pcg64::seeded(7003);
    let k = ConvKernel::random_he(3, 3, 3, 3, &mut rng);
    let plan = SpectralPlan::new(&k, 9, 9, LfaOptions::default());
    let via_plan = plan.execute();
    let via_lfa = lfa::singular_values(&k, 9, 9, LfaOptions::default());
    assert_eq!(via_plan.values, via_lfa.values);
    let strided = lfa::strided_singular_values(&k, 8, 8, 2);
    let strided_plan =
        SpectralPlan::with_stride(&k, 8, 8, 2, LfaOptions::default()).execute();
    assert_eq!(strided.values, strided_plan.values);
}

#[test]
fn one_plan_executes_many_times_identically() {
    let mut rng = Pcg64::seeded(7004);
    let k = ConvKernel::random_he(4, 4, 3, 3, &mut rng);
    let plan = SpectralPlan::new(&k, 12, 12, LfaOptions { threads: 2, ..Default::default() });
    let first = plan.execute();
    let second = plan.execute();
    assert_eq!(first.values, second.values, "plan reuse must be bitwise reproducible");
    // The request-driven driver on a caller buffer agrees too.
    let mut buf = vec![0.0f64; plan.values_len()];
    plan.execute_request_into(SpectrumRequest::Full, SweepOptions::default(), &mut buf);
    assert_eq!(buf, first.values);
}

#[test]
fn backends_agree_with_plan_execute() {
    let mut rng = Pcg64::seeded(7005);
    let k = ConvKernel::random_he(3, 2, 3, 3, &mut rng);
    let plan = SpectralPlan::new(&k, 10, 10, LfaOptions::default());
    let direct = plan.execute();
    let serial = NativeSerial.execute(&plan).unwrap();
    let threaded = NativeThreaded { threads: 4 }.execute(&plan).unwrap();
    assert_eq!(direct.values, serial.values);
    assert_eq!(direct.values, threaded.values);
    assert_eq!(serial.n, 10);
    assert_eq!(serial.c_out, 3);
}

#[test]
fn tile_execution_stitches_to_full_grid() {
    // Raw row-range tiling is the *unfolded* contract (every row solved
    // independently); `lfa::tile_singular_values` is its public face —
    // pin its stitched output against an unfolded whole-grid plan.
    let mut rng = Pcg64::seeded(7006);
    let k = ConvKernel::random_he(3, 3, 3, 3, &mut rng);
    let plan = SpectralPlan::new(
        &k,
        9,
        5,
        LfaOptions { threads: 1, folding: Fold::Off, ..Default::default() },
    );
    let full = plan.execute();
    let r = plan.rank();
    let mut stitched = vec![0.0f64; plan.values_len()];
    for (lo, hi) in [(0usize, 2usize), (2, 3), (3, 9)] {
        let chunk = lfa::tile_singular_values(&k, 9, 5, lo, hi, BlockSolver::Jacobi);
        stitched[lo * 5 * r..hi * 5 * r].copy_from_slice(&chunk);
    }
    assert_eq!(stitched, full.values);
}

/// The acceptance matrix of the folding change: folded and unfolded
/// execution agree to ≤ 1e-12 on singular values across stride ∈ {1, 2},
/// both layouts, serial and threaded, Full and TopK requests, and odd and
/// even grids (odd axes have no Nyquist line; even axes self-pair it).
#[test]
fn folded_matches_unfolded_across_the_full_matrix() {
    let mut rng = Pcg64::seeded(7008);
    for &(n, m, s) in &[
        (6usize, 6usize, 1usize),
        (5, 7, 1),
        (4, 4, 1),
        (7, 4, 1),
        (8, 8, 2),
        (4, 8, 2),
        (12, 6, 2),
    ] {
        for &(c_out, c_in) in &[(3usize, 3usize), (4, 2)] {
            let k = ConvKernel::random_he(c_out, c_in, 3, 3, &mut rng);
            for layout in [BlockLayout::BlockContiguous, BlockLayout::PlanarStrided] {
                for threads in [1usize, 3] {
                    let base = LfaOptions { layout, threads, ..Default::default() };
                    let folded = SpectralPlan::with_stride(&k, n, m, s, base);
                    let unfolded = SpectralPlan::with_stride(
                        &k,
                        n,
                        m,
                        s,
                        LfaOptions { folding: Fold::Off, ..base },
                    );
                    assert!(folded.folded() && !unfolded.folded());
                    assert!(
                        folded.solved_freqs() < unfolded.solved_freqs(),
                        "folding must shrink the solved set ({n}x{m}/{s})"
                    );
                    // Full spectra: ≤ 1e-12.
                    let a = folded.execute();
                    let b = unfolded.execute();
                    let scale = b.sigma_max().max(1.0);
                    for (x, y) in a.values.iter().zip(&b.values) {
                        assert!(
                            (x - y).abs() <= 1e-12 * scale,
                            "{n}x{m}/{s} {c_out}x{c_in} {layout:?} x{threads}: {x} vs {y}"
                        );
                    }
                    // TopK: both sides carry the Krylov tolerance.
                    let ta = folded.execute_topk(2);
                    let tb = unfolded.execute_topk(2);
                    for (x, y) in ta.spectrum.values.iter().zip(&tb.spectrum.values) {
                        assert!(
                            (x - y).abs() <= 2e-8 * scale,
                            "topk {n}x{m}/{s} {c_out}x{c_in} {layout:?} x{threads}: {x} vs {y}"
                        );
                    }
                }
            }
        }
    }
}

/// The cached arm of the acceptance matrix: a spectrum served through
/// [`SpectralCache`] (plan drawn from the plan cache, result from the
/// result cache) is bitwise identical to direct execution, and agrees
/// with the unfolded uncached reference to ≤ 1e-12 — across stride,
/// layout and folding. Plans with equal signatures are shared objects.
#[test]
fn cached_paths_match_direct_execution_across_the_matrix() {
    use conv_svd_lfa::engine::SpectralCache;
    use std::sync::Arc;
    let cache = SpectralCache::new();
    let mut rng = Pcg64::seeded(7010);
    for &(n, m, s) in &[(6usize, 6usize, 1usize), (5, 7, 1), (8, 8, 2)] {
        for layout in [BlockLayout::BlockContiguous, BlockLayout::PlanarStrided] {
            for folding in [Fold::Auto, Fold::Off] {
                let k = ConvKernel::random_he(3, 2, 3, 3, &mut rng);
                let opts = LfaOptions { layout, folding, threads: 1, ..Default::default() };
                // Plan cache: equal signatures share one planned object.
                let p1 = cache.plan_for(&k, n, m, s, opts);
                let p2 = cache.plan_for(&k, n, m, s, opts);
                assert!(Arc::ptr_eq(&p1, &p2), "{n}x{m}/{s}: plan must be shared");
                let direct = SpectralPlan::with_stride(&k, n, m, s, opts).execute();
                let key = p1.result_signature(SpectrumRequest::Full);
                cache.insert(key, Arc::new(p1.execute()));
                let served = cache.get(&key).expect("just inserted");
                assert_eq!(served.values, direct.values, "cached == direct, bitwise");
                let reference = SpectralPlan::with_stride(
                    &k,
                    n,
                    m,
                    s,
                    LfaOptions { folding: Fold::Off, ..opts },
                )
                .execute();
                let scale = reference.sigma_max().max(1.0);
                for (a, b) in served.values.iter().zip(&reference.values) {
                    assert!(
                        (a - b).abs() <= 1e-12 * scale,
                        "{n}x{m}/{s} {layout:?} {folding:?}: {a} vs {b}"
                    );
                }
            }
        }
    }
}

/// The precision-tier acceptance matrix: across stride ∈ {1, 2}, both
/// layouts, folded and unfolded, serial and threaded, Full and TopK —
/// the f32 sweep tracks the f64 spectrum to ≤ 1e-4·σ_max (single-precision
/// assembly + Jacobi round-off), and the f32-refined tier restores the
/// crate's ≤ 1e-12 guarantee (its f64 polish runs off exactly-assembled
/// blocks, so the f32 sweep only steers which rotations warm-start it).
#[test]
fn precision_tiers_track_f64_across_the_matrix() {
    let mut rng = Pcg64::seeded(7011);
    for &(n, m, s) in &[(6usize, 6usize, 1usize), (5, 7, 1), (8, 8, 2), (12, 6, 2)] {
        for &(c_out, c_in) in &[(3usize, 3usize), (4, 2)] {
            let k = ConvKernel::random_he(c_out, c_in, 3, 3, &mut rng);
            for layout in [BlockLayout::BlockContiguous, BlockLayout::PlanarStrided] {
                for folding in [Fold::Auto, Fold::Off] {
                    for threads in [1usize, 3] {
                        let base = LfaOptions { layout, folding, threads, ..Default::default() };
                        let f64sp = SpectralPlan::with_stride(&k, n, m, s, base).execute();
                        let scale = f64sp.sigma_max().max(1.0);
                        let f32sp = SpectralPlan::with_stride(
                            &k,
                            n,
                            m,
                            s,
                            LfaOptions { precision: Precision::F32, ..base },
                        )
                        .execute();
                        let refined_plan = SpectralPlan::with_stride(
                            &k,
                            n,
                            m,
                            s,
                            LfaOptions { precision: Precision::F32Refined, ..base },
                        );
                        let refsp = refined_plan.execute();
                        let tag = format!(
                            "{n}x{m}/{s} {c_out}x{c_in} {layout:?} {folding:?} x{threads}"
                        );
                        let g32 = max_gap(&f32sp.values, &f64sp.values);
                        assert!(g32 <= 1e-4 * scale, "{tag}: f32 gap {g32:e}");
                        let gref = max_gap(&refsp.values, &f64sp.values);
                        assert!(gref <= 1e-12 * scale, "{tag}: refined gap {gref:e}");
                        // TopK: the partial sweep carries the same tiers.
                        let t64 = SpectralPlan::with_stride(&k, n, m, s, base).execute_topk(2);
                        let t32 = SpectralPlan::with_stride(
                            &k,
                            n,
                            m,
                            s,
                            LfaOptions { precision: Precision::F32, ..base },
                        )
                        .execute_topk(2);
                        let tref = refined_plan.execute_topk(2);
                        let tg32 = max_gap(&t32.spectrum.values, &t64.spectrum.values);
                        assert!(tg32 <= 2e-3 * scale, "{tag}: topk f32 gap {tg32:e}");
                        let tgref = max_gap(&tref.spectrum.values, &t64.spectrum.values);
                        assert!(tgref <= 1e-8 * scale, "{tag}: topk refined gap {tgref:e}");
                    }
                }
            }
        }
    }
}

/// The SIMD kernels and their scalar fallbacks are *bit-comparable*: the
/// scalar paths mirror the vector lanes' split/interleaved accumulation
/// order exactly, so forcing scalar execution reproduces the SIMD spectra
/// bit-for-bit at every precision tier — the CI no-AVX2 job and a
/// `-Ctarget-cpu=native` build must agree on every value.
#[test]
fn forced_scalar_execution_is_bit_identical_to_simd() {
    use conv_svd_lfa::numeric::{active_kernel_name, set_force_scalar};
    let mut rng = Pcg64::seeded(7012);
    for &(n, m, s) in &[(6usize, 6usize, 1usize), (8, 8, 2)] {
        let k = ConvKernel::random_he(4, 3, 3, 3, &mut rng);
        for precision in [Precision::F64, Precision::F32, Precision::F32Refined] {
            let opts = LfaOptions { threads: 1, precision, ..Default::default() };
            let plan = SpectralPlan::with_stride(&k, n, m, s, opts);
            let auto = plan.execute();
            set_force_scalar(true);
            let forced_name = active_kernel_name();
            let scalar = plan.execute();
            set_force_scalar(false);
            assert_eq!(forced_name, "scalar");
            assert_eq!(
                auto.values, scalar.values,
                "{n}x{m}/{s} {precision:?}: SIMD and scalar must agree bitwise"
            );
        }
    }
}

/// Self-paired frequencies (DC and Nyquist lines) are solved exactly once:
/// the folded solve count equals `(freqs + self_paired)/2` on every grid
/// parity, and the folded spectra at those frequencies match the unfolded
/// reference (no double-mirroring artifacts).
#[test]
fn self_paired_frequencies_are_solved_once() {
    use conv_svd_lfa::lfa::spectrum::mirror_freq;
    let mut rng = Pcg64::seeded(7009);
    let k = ConvKernel::random_he(3, 2, 3, 3, &mut rng);
    for &(n, m) in &[(4usize, 4usize), (5, 5), (4, 5), (5, 4), (2, 2), (1, 7)] {
        let plan = SpectralPlan::new(&k, n, m, LfaOptions { threads: 1, ..Default::default() });
        let self_paired = (0..n * m).filter(|&f| mirror_freq(n, m, f) == f).count();
        assert_eq!(
            plan.solved_freqs(),
            (n * m + self_paired) / 2,
            "{n}x{m}: {self_paired} self-paired"
        );
        let off = SpectralPlan::new(
            &k,
            n,
            m,
            LfaOptions { threads: 1, folding: Fold::Off, ..Default::default() },
        );
        let a = plan.execute();
        let b = off.execute();
        for f in (0..n * m).filter(|&f| mirror_freq(n, m, f) == f) {
            for (x, y) in a.at(f).iter().zip(b.at(f)) {
                assert!((x - y).abs() < 1e-12, "{n}x{m} f={f}");
            }
        }
    }
}

/// The differential matrix of the sink-driven driver refactor: every
/// public entry point is a thin wrapper over one request-driven sweep, so
/// the spectra they produce are **bit-identical** (`f64::to_bits`) —
/// `execute()` vs `execute_request_into(Full)` vs a caller-supplied
/// [`FullAssembly`] sink through `sweep_with`, and `execute_topk(k)` vs
/// `execute_request_into(TopK(k))` — across fold × precision × structure
/// (dense / grouped / depthwise / dilated / transposed) × threads.
#[test]
fn sink_driven_entry_points_are_bit_identical_across_the_matrix() {
    let mut rng = Pcg64::seeded(7013);
    let kernels: Vec<(&str, ConvKernel)> = vec![
        ("dense", ConvKernel::random_he(4, 3, 3, 3, &mut rng)),
        ("grouped g2", ConvKernel::random_he(4, 2, 3, 3, &mut rng).with_groups(2)),
        ("depthwise", ConvKernel::random_he(4, 1, 3, 3, &mut rng).with_groups(4)),
        ("dilated d2", ConvKernel::random_he(3, 3, 3, 3, &mut rng).with_dilation(2)),
        ("transposed", ConvKernel::random_he(4, 3, 3, 3, &mut rng).with_transposed(true)),
    ];
    for (name, k) in &kernels {
        for folding in [Fold::Auto, Fold::Off] {
            for precision in [Precision::F64, Precision::F32, Precision::F32Refined] {
                for threads in [1usize, 3] {
                    let opts = LfaOptions { threads, folding, precision, ..Default::default() };
                    let plan = SpectralPlan::new(k, 8, 8, opts);
                    let tag = format!("{name} {folding:?} {precision:?} x{threads}");
                    let spectrum = plan.execute();
                    let mut buf = vec![0.0f64; plan.values_len()];
                    plan.execute_request_into(
                        SpectrumRequest::Full,
                        SweepOptions::default(),
                        &mut buf,
                    );
                    for (a, b) in spectrum.values.iter().zip(&buf) {
                        assert_eq!(a.to_bits(), b.to_bits(), "{tag}: execute vs request_into");
                    }
                    // Caller-supplied sink: the serial whole-domain sweep
                    // must land on the same bits (compare on the serial
                    // plan — sweep_with is single-threaded by contract).
                    if threads == 1 {
                        let mut sunk = vec![0.0f64; plan.values_len()];
                        let mut sink = FullAssembly::strip(&plan, 0, &mut sunk);
                        plan.sweep_with(SpectrumRequest::Full, &mut sink);
                        drop(sink);
                        for (a, b) in spectrum.values.iter().zip(&sunk) {
                            assert_eq!(a.to_bits(), b.to_bits(), "{tag}: execute vs sweep_with");
                        }
                    }
                    // TopK rides the same driver with the same bits.
                    let top = plan.execute_topk(2);
                    let mut tbuf = vec![0.0f64; plan.topk_values_len(2)];
                    plan.execute_request_into(
                        SpectrumRequest::TopK(2),
                        SweepOptions::default(),
                        &mut tbuf,
                    );
                    for (a, b) in top.spectrum.values.iter().zip(&tbuf) {
                        assert_eq!(a.to_bits(), b.to_bits(), "{tag}: topk vs request_into");
                    }
                }
            }
        }
    }
}

/// The factor arm of the differential matrix: `full_svd()` and
/// `topk_svd(k)` ride the same unified sweep, so across fold × structure
/// their sigma tracks `execute()` to ≤ 1e-12·σ_max, the reconstructed
/// products `UΣVᴴ` of folded and unfolded factor sweeps agree to the same
/// bound (factors are phase-ambiguous; the product is not), and the dense
/// reconstruction matches the direct trig symbol.
#[test]
fn factor_paths_track_the_unified_driver_across_fold_and_structure() {
    let mut rng = Pcg64::seeded(7014);
    let kernels: Vec<(&str, ConvKernel)> = vec![
        ("dense", ConvKernel::random_he(3, 2, 3, 3, &mut rng)),
        ("grouped g2", ConvKernel::random_he(4, 2, 3, 3, &mut rng).with_groups(2)),
        ("transposed", ConvKernel::random_he(3, 2, 3, 3, &mut rng).with_transposed(true)),
    ];
    let (n, m) = (6usize, 6usize);
    for (name, k) in &kernels {
        let base = LfaOptions { threads: 1, ..Default::default() };
        let folded = SpectralPlan::new(k, n, m, base);
        let unfolded =
            SpectralPlan::new(k, n, m, LfaOptions { folding: Fold::Off, ..base });
        let spectrum = folded.execute();
        let scale = spectrum.sigma_max().max(1.0);
        let fa = folded.full_svd();
        let fb = unfolded.full_svd();
        for (j, (a, b)) in spectrum.values.iter().zip(&fa.sigma.values).enumerate() {
            assert!((a - b).abs() <= 1e-12 * scale, "{name}: sigma[{j}] {a} vs {b}");
        }
        for f in 0..folded.freqs() {
            let ra = fa.symbol(f);
            let rb = fb.symbol(f);
            assert!(
                ra.max_abs_diff(&rb) <= 1e-12 * scale,
                "{name} f={f}: folded vs unfolded reconstruction"
            );
        }
        if *name == "dense" {
            for ki in 0..n {
                for kj in 0..m {
                    let recon = fa.symbol(ki * m + kj);
                    let want = symbol_at(k, n, m, ki, kj);
                    assert!(
                        recon.max_abs_diff(&want) <= 1e-10 * scale,
                        "{name} ({ki},{kj}): reconstruction vs direct symbol"
                    );
                }
            }
        }
        // TopK factors carry the Krylov tolerance on the truncation.
        let ta = folded.topk_svd(2);
        let tb = unfolded.topk_svd(2);
        for f in 0..folded.freqs() {
            let ra = ta.truncated_symbol(f);
            let rb = tb.truncated_symbol(f);
            assert!(
                ra.max_abs_diff(&rb) <= 2e-8 * scale,
                "{name} f={f}: topk truncated reconstruction"
            );
        }
    }
}
