//! Engine equivalence: the planned execution core ([`SpectralPlan`]) must
//! reproduce the per-frequency reference pipeline — `symbol_at` (direct
//! trig, no tables) + the standalone block solvers — to ≤ 1e-10 across
//! every configuration axis: both block layouts, both solvers, strided and
//! unstrided kernels, odd and even grids, square and rectangular channel
//! counts. Plus reuse semantics: repeated `execute()` on one plan is
//! bitwise identical.

use conv_svd_lfa::conv::ConvKernel;
use conv_svd_lfa::engine::{NativeSerial, NativeThreaded, SpectralBackend, SpectralPlan};
use conv_svd_lfa::lfa::symbol::symbol_at;
use conv_svd_lfa::lfa::{self, BlockLayout, BlockSolver, LfaOptions};
use conv_svd_lfa::linalg::{jacobi_eig, jacobi_svd};
use conv_svd_lfa::numeric::{CMat, Pcg64};

const TOL: f64 = 1e-10;

fn solve_reference(block: &CMat, solver: BlockSolver) -> Vec<f64> {
    match solver {
        BlockSolver::Jacobi => jacobi_svd::singular_values(block),
        BlockSolver::GramEigen => jacobi_eig::singular_values_gram(block),
    }
}

/// Frequency-by-frequency reference spectrum, bypassing the engine
/// entirely: direct per-frequency trig (`symbol_at`) + allocating solvers.
fn reference_unstrided(k: &ConvKernel, n: usize, m: usize, solver: BlockSolver) -> Vec<f64> {
    let r = k.c_out.min(k.c_in);
    let mut values = vec![0.0f64; n * m * r];
    for ki in 0..n {
        for kj in 0..m {
            let sv = solve_reference(&symbol_at(k, n, m, ki, kj), solver);
            let f = ki * m + kj;
            values[f * r..(f + 1) * r].copy_from_slice(&sv[..r]);
        }
    }
    values
}

fn reference_strided(
    k: &ConvKernel,
    n: usize,
    m: usize,
    s: usize,
    solver: BlockSolver,
) -> Vec<f64> {
    let (nc, mc) = (n / s, m / s);
    let r = k.c_out.min(s * s * k.c_in);
    let mut values = vec![0.0f64; nc * mc * r];
    for ki in 0..nc {
        for kj in 0..mc {
            let block = lfa::strided_symbol_at(k, n, m, s, ki, kj);
            let sv = solve_reference(&block, solver);
            let f = ki * mc + kj;
            values[f * r..(f + 1) * r].copy_from_slice(&sv[..r]);
        }
    }
    values
}

fn max_gap(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len(), "spectrum lengths differ");
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
}

#[test]
fn plan_matches_reference_across_all_configs() {
    let mut rng = Pcg64::seeded(7001);
    // Odd/even, square/rectangular grids; square/tall/wide channel counts.
    for &(n, m) in &[(6usize, 6usize), (5, 7), (8, 3), (4, 4)] {
        for &(c_out, c_in) in &[(3usize, 3usize), (4, 2), (2, 4)] {
            let k = ConvKernel::random_he(c_out, c_in, 3, 3, &mut rng);
            for layout in [BlockLayout::BlockContiguous, BlockLayout::PlanarStrided] {
                for solver in [BlockSolver::Jacobi, BlockSolver::GramEigen] {
                    let want = reference_unstrided(&k, n, m, solver);
                    for threads in [1usize, 3] {
                        let opts = LfaOptions { layout, solver, threads };
                        let got = SpectralPlan::new(&k, n, m, opts).execute();
                        let gap = max_gap(&got.values, &want);
                        assert!(
                            gap < TOL,
                            "{n}x{m} {c_out}x{c_in} {layout:?} {solver:?} x{threads}: gap {gap}"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn strided_plan_matches_reference() {
    let mut rng = Pcg64::seeded(7002);
    for &(n, m, s) in &[(8usize, 8usize, 2usize), (6, 6, 3), (4, 8, 2)] {
        for &(c_out, c_in) in &[(2usize, 2usize), (3, 2)] {
            let k = ConvKernel::random_he(c_out, c_in, 3, 3, &mut rng);
            for solver in [BlockSolver::Jacobi, BlockSolver::GramEigen] {
                let want = reference_strided(&k, n, m, s, solver);
                let opts = LfaOptions { solver, threads: 1, ..Default::default() };
                let got = SpectralPlan::with_stride(&k, n, m, s, opts).execute();
                let gap = max_gap(&got.values, &want);
                assert!(gap < TOL, "{n}x{m}/{s} {c_out}x{c_in} {solver:?}: gap {gap}");
            }
        }
    }
}

#[test]
fn legacy_entry_points_match_plan() {
    // The public lfa:: wrappers are the plan — but assert it anyway so a
    // future de-unification shows up as a test failure.
    let mut rng = Pcg64::seeded(7003);
    let k = ConvKernel::random_he(3, 3, 3, 3, &mut rng);
    let plan = SpectralPlan::new(&k, 9, 9, LfaOptions::default());
    let via_plan = plan.execute();
    let via_lfa = lfa::singular_values(&k, 9, 9, LfaOptions::default());
    assert_eq!(via_plan.values, via_lfa.values);
    let strided = lfa::strided_singular_values(&k, 8, 8, 2);
    let strided_plan =
        SpectralPlan::with_stride(&k, 8, 8, 2, LfaOptions::default()).execute();
    assert_eq!(strided.values, strided_plan.values);
}

#[test]
fn one_plan_executes_many_times_identically() {
    let mut rng = Pcg64::seeded(7004);
    let k = ConvKernel::random_he(4, 4, 3, 3, &mut rng);
    let plan = SpectralPlan::new(&k, 12, 12, LfaOptions { threads: 2, ..Default::default() });
    let first = plan.execute();
    let second = plan.execute();
    assert_eq!(first.values, second.values, "plan reuse must be bitwise reproducible");
    // execute_into on a caller buffer agrees too.
    let mut buf = vec![0.0f64; plan.values_len()];
    plan.execute_into(&mut buf);
    assert_eq!(buf, first.values);
}

#[test]
fn backends_agree_with_plan_execute() {
    let mut rng = Pcg64::seeded(7005);
    let k = ConvKernel::random_he(3, 2, 3, 3, &mut rng);
    let plan = SpectralPlan::new(&k, 10, 10, LfaOptions::default());
    let direct = plan.execute();
    let serial = NativeSerial.execute(&plan).unwrap();
    let threaded = NativeThreaded { threads: 4 }.execute(&plan).unwrap();
    assert_eq!(direct.values, serial.values);
    assert_eq!(direct.values, threaded.values);
    assert_eq!(serial.n, 10);
    assert_eq!(serial.c_out, 3);
}

#[test]
fn tile_execution_stitches_to_full_grid() {
    let mut rng = Pcg64::seeded(7006);
    let k = ConvKernel::random_he(3, 3, 3, 3, &mut rng);
    let plan = SpectralPlan::new(&k, 9, 5, LfaOptions { threads: 1, ..Default::default() });
    let full = plan.execute();
    let r = plan.rank();
    let mut stitched = vec![0.0f64; plan.values_len()];
    for (lo, hi) in [(0usize, 2usize), (2, 3), (3, 9)] {
        let chunk = &mut stitched[lo * 5 * r..hi * 5 * r];
        plan.execute_rows_pooled(lo, hi, chunk);
    }
    assert_eq!(stitched, full.values);
}
