//! Table IV regenerator: effect of memory layout on `s_F`, `s_copy` and
//! `s_SVD`.
//!
//! Four configurations per n, mirroring the paper's rows:
//!   FFT  natural layout      (planar/strided blocks; no conversion)
//!   FFT  + convert           (pay `s_copy` to make blocks contiguous)
//!   LFA  block-contiguous    (the natural LFA layout — "row-major")
//!   LFA  planar (+ convert)  (force the bad layout, then convert back)
//!
//! Paper findings to reproduce in shape: contiguous blocks make `s_SVD`
//! fastest; the conversion cost outweighs its benefit for the FFT; LFA gets
//! the good layout for free.

use conv_svd_lfa::baselines::{fft_svd, FftLayoutPolicy};
use conv_svd_lfa::bench_util::bench_args;
use conv_svd_lfa::conv::ConvKernel;
use conv_svd_lfa::lfa::{self, svd::svd_pass, BlockLayout, LfaOptions};
use conv_svd_lfa::numeric::Pcg64;
use conv_svd_lfa::report::{secs, Table};
use std::time::{Duration, Instant};

/// Single-threaded options: Table IV isolates per-core cache behaviour.
fn serial() -> LfaOptions {
    LfaOptions { threads: 1, ..Default::default() }
}

fn main() {
    let (bench, full) = bench_args();
    let c = 16;
    let ns: Vec<usize> = if full { vec![64, 128, 256] } else { vec![64, 128] };
    let mut rng = Pcg64::seeded(703);
    let kernel = ConvKernel::random_he(c, c, 3, 3, &mut rng);

    println!("# Table IV — memory-layout effects (c = {c}, single thread: layout effects\n# are per-core cache behaviour)");
    let mut table = Table::new(["n", "method", "layout", "s_F", "s_copy", "s_SVD", "s_total"]);
    let mut csv =
        Table::new(["n", "method", "layout", "transform_s", "copy_s", "svd_s", "total_s"]);

    for &n in &ns {
        // --- FFT natural (strided blocks) ---
        let m1 = bench.measure("fft-nat", || {
            fft_svd::singular_values_timed(&kernel, n, n, FftLayoutPolicy::Natural, 1).1
        });
        let s1 = fft_svd::singular_values_timed(&kernel, n, n, FftLayoutPolicy::Natural, 1).1;
        emit(&mut table, &mut csv, n, "FFT", "planar (native)", s1.transform, s1.copy, s1.svd, m1.median());

        // --- FFT + conversion ---
        let m2 = bench.measure("fft-conv", || {
            fft_svd::singular_values_timed(&kernel, n, n, FftLayoutPolicy::ConvertToContiguous, 1)
                .1
        });
        let s2 =
            fft_svd::singular_values_timed(&kernel, n, n, FftLayoutPolicy::ConvertToContiguous, 1).1;
        emit(&mut table, &mut csv, n, "FFT", "→ contiguous", s2.transform, s2.copy, s2.svd, m2.median());

        // --- LFA block-contiguous (the default) ---
        let m3 = bench.measure("lfa-cont", || {
            lfa::singular_values_timed(&kernel, n, n, serial()).1
        });
        let s3 = lfa::singular_values_timed(&kernel, n, n, serial()).1;
        emit(&mut table, &mut csv, n, "LFA", "contiguous (native)", s3.transform, s3.copy, s3.svd, m3.median());

        // --- LFA forced planar, then converted back (the paper's ✗ row) ---
        let lfa_planar = || {
            let t0 = Instant::now();
            let grid = lfa::compute_symbols(&kernel, n, n, BlockLayout::PlanarStrided);
            let t_f = t0.elapsed();
            let t0 = Instant::now();
            let grid = grid.to_layout(BlockLayout::BlockContiguous);
            let t_copy = t0.elapsed();
            let t0 = Instant::now();
            let (v, _) = svd_pass(&grid, serial());
            let t_svd = t0.elapsed();
            (v, t_f, t_copy, t_svd)
        };
        let m4 = bench.measure("lfa-planar", || lfa_planar().0);
        let (_, t_f, t_copy, t_svd) = lfa_planar();
        emit(&mut table, &mut csv, n, "LFA", "planar → contiguous", t_f, t_copy, t_svd, m4.median());

        // --- LFA planar, SVD directly on strided blocks (no conversion) ---
        let lfa_strided = || {
            let t0 = Instant::now();
            let grid = lfa::compute_symbols(&kernel, n, n, BlockLayout::PlanarStrided);
            let t_f = t0.elapsed();
            let t0 = Instant::now();
            let (v, _) = svd_pass(&grid, LfaOptions { layout: BlockLayout::PlanarStrided, ..serial() });
            let t_svd = t0.elapsed();
            (v, t_f, t_svd)
        };
        let m5 = bench.measure("lfa-strided", || lfa_strided().0);
        let (_, t_f5, t_svd5) = lfa_strided();
        emit(&mut table, &mut csv, n, "LFA", "planar (no conv.)", t_f5, Duration::ZERO, t_svd5, m5.median());
    }
    print!("{}", table.render());
    match csv.save_csv("table4_layout") {
        Ok(p) => println!("CSV: {}", p.display()),
        Err(e) => eprintln!("csv write failed: {e}"),
    }
    println!(
        "expected shape (paper Table IV): contiguous-block SVD is fastest;\n\
         explicit conversion costs more than it saves; LFA's native layout wins."
    );
}

#[allow(clippy::too_many_arguments)]
fn emit(
    table: &mut Table,
    csv: &mut Table,
    n: usize,
    method: &str,
    layout: &str,
    t_f: Duration,
    t_copy: Duration,
    t_svd: Duration,
    total: Duration,
) {
    table.row([
        n.to_string(),
        method.to_string(),
        layout.to_string(),
        secs(t_f),
        if t_copy == Duration::ZERO { "-".into() } else { secs(t_copy) },
        secs(t_svd),
        secs(total),
    ]);
    csv.row([
        n.to_string(),
        method.to_string(),
        layout.to_string(),
        format!("{:.6}", t_f.as_secs_f64()),
        format!("{:.6}", t_copy.as_secs_f64()),
        format!("{:.6}", t_svd.as_secs_f64()),
        format!("{:.6}", total.as_secs_f64()),
    ]);
}
