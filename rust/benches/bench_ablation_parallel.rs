//! Ablation: the "embarrassingly parallel" claim (paper §V) — thread
//! scaling of the LFA pipeline through the coordinator, plus tile-size
//! sensitivity.
//!
//! NOTE: this container exposes a single core; scaling beyond 1 thread
//! shows scheduling overhead only. The bench still validates that the
//! parallel decomposition is correct and overhead-bounded, and produces
//! the series that on a multi-core box exhibits the linear scaling.

use conv_svd_lfa::bench_util::bench_args;
use conv_svd_lfa::conv::ConvKernel;
use conv_svd_lfa::coordinator::{JobSpec, Scheduler};
use conv_svd_lfa::engine::resolve_threads;
use conv_svd_lfa::lfa::{self, LfaOptions};
use conv_svd_lfa::numeric::Pcg64;
use conv_svd_lfa::report::{secs, Table};

fn main() {
    let (bench, full) = bench_args();
    let (n, c) = if full { (256, 16) } else { (128, 16) };
    let mut rng = Pcg64::seeded(900);
    let kernel = ConvKernel::random_he(c, c, 3, 3, &mut rng);
    let cores = resolve_threads(0);

    println!("# Ablation — thread scaling (n = {n}, c = {c}; host cores = {cores})");
    let mut table = Table::new(["threads", "in-process LFA", "coordinator", "speedup vs 1"]);
    let mut base = None;
    for threads in [1usize, 2, 4, 8] {
        let direct = bench.measure("direct", || {
            lfa::singular_values(&kernel, n, n, LfaOptions { threads, ..Default::default() })
        });
        let sched = Scheduler::native(threads);
        let coord = bench.measure("coord", || {
            sched.run(JobSpec::new("b", kernel.clone(), n, n)).unwrap()
        });
        sched.shutdown();
        let d = direct.median().as_secs_f64();
        if threads == 1 {
            base = Some(d);
        }
        table.row([
            threads.to_string(),
            secs(direct.median()),
            secs(coord.median()),
            format!("{:.2}x", base.unwrap() / d),
        ]);
    }
    print!("{}", table.render());

    println!("\n# tile-size sensitivity (coordinator, 1 worker thread)");
    let mut t2 = Table::new(["tile_rows", "tiles", "time", "overhead vs best"]);
    let sched = Scheduler::native(1);
    let mut results = Vec::new();
    for tile_rows in [1usize, 2, 8, 32, n] {
        let m = bench.measure("tile", || {
            sched
                .run(JobSpec::new("t", kernel.clone(), n, n).with_tile_rows(tile_rows))
                .unwrap()
        });
        results.push((tile_rows, n.div_ceil(tile_rows), m.median()));
    }
    sched.shutdown();
    let best = results.iter().map(|r| r.2).min().unwrap();
    for (tile_rows, tiles, t) in results {
        t2.row([
            tile_rows.to_string(),
            tiles.to_string(),
            secs(t),
            format!("{:.1}%", 100.0 * (t.as_secs_f64() / best.as_secs_f64() - 1.0)),
        ]);
    }
    print!("{}", t2.render());
    println!("expected: per-tile overhead visible only for tiny tiles; the default\nheuristic (≥8 tiles/worker) sits in the flat region.");
}
