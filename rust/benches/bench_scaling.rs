//! Table I regenerator (complexity): measured scaling exponents of the
//! three methods vs their theoretical orders, by log–log slope fitting
//! over a geometric n-sweep.
//!
//!   explicit  O(n⁶)  (we fit on the SVD of the n²c × n²c dense matrix)
//!   FFT       O(n² (c + log n) c²) ≈ slope 2 in n (plus log factor)
//!   LFA       O(n² c³)            = slope exactly 2 in n
//!
//! Also channel scaling at fixed n, the plan-reuse margin, the
//! whole-model batching margin (`ModelPlan` — one planned object, one
//! sweep — vs N independent per-layer plan executions), the
//! **top-k partial-spectrum margin**: warm-started Krylov iteration
//! (`SpectrumRequest::TopK`) vs the full fused Jacobi path, with the
//! per-frequency iteration counts that cross-frequency warm-starting
//! saves over cold starts — the **conjugate-pair folding margin**
//! (`Fold::Auto` vs `Fold::Off`, serial + threaded, with a verdict line):
//! solving only the fundamental domain of `θ → −θ` and mirroring the
//! conjugate half — the **SpectralCache cold-vs-warm margin**: a
//! repeat audit of an unchanged model served entirely from the
//! content-addressed result cache (zero frequencies re-solved) vs the
//! cold sweep that populates it — the **disk-cold-vs-disk-warm
//! margin**: the same repeat audit after losing the memory tier (the
//! daemon-restart scenario), served from checksummed spill files vs
//! re-sweeping and re-spilling — the **simd-vs-scalar margin**: the
//! runtime-detected AVX2+FMA complex kernels against the bit-identical
//! forced-scalar fallback on the same plan (full + top-k, serial +
//! threaded, with a verdict line) — the **f32-vs-f64 precision
//! margin**: the single-precision sweep (double the SIMD lanes,
//! ~1e-4·σ_max) and the `f32-refined` tier (f32 sweep + one f64 polish
//! per frequency, ≤1e-12 restored) against the f64 reference — and the
//! **grouped-vs-dense margin**: a grouped layer's per-frequency symbol is
//! block diagonal, so the engine solves `g` blocks of `c/g × c/g` instead
//! of one `c × c` SVD (`c³/g²` vs `c³` flops); depthwise (`g = c`,
//! scalar symbols) is the limit case and the acceptance line — and the
//! **density-vs-full margin**: the streaming `DensitySink` histogram
//! (exact σ_max via a warm top-1 pass, full SVDs on a 1/s² sub-lattice
//! only) against the full sweep it summarizes, with the worst quantile
//! deviation and the DKW ±ε error bar in the verdict line.
//!
//! Flags: `--quick` (fewer samples), `--full` (bigger sizes), `--smoke`
//! (CI bench-smoke: reduced sizes), `--json <path>` (machine-readable
//! `{bench, case, ns_per_iter, commit, unix_time}` lines — uploaded as
//! `BENCH_scaling.json`).

use conv_svd_lfa::baselines::{explicit_svd, fft_svd, FftLayoutPolicy};
use conv_svd_lfa::bench_util::{bench_opts, JsonLines};
use conv_svd_lfa::conv::{Boundary, ConvKernel};
use conv_svd_lfa::engine::{
    resolve_threads, DensityRequest, DiskCache, ModelPlan, SpectralCache, SpectralPlan,
    SpectrumRequest, SweepOptions,
};
use conv_svd_lfa::lfa::{self, Fold, LfaOptions, Precision};
use conv_svd_lfa::model::{Init, LayerConfig, ModelConfig};
use conv_svd_lfa::numeric::{active_kernel_name, set_force_scalar, Pcg64};
use conv_svd_lfa::report::Table;

/// Serial options: the scaling fits want single-core numbers.
fn serial() -> LfaOptions {
    LfaOptions { threads: 1, ..Default::default() }
}

/// Full sweep into a reused buffer at the plan's own thread count — the
/// bench-side shim over the one request-driven driver.
fn full_into(plan: &SpectralPlan, out: &mut [f64]) {
    plan.execute_request_into(SpectrumRequest::Full, SweepOptions::default(), out);
}

/// Full sweep with an explicit worker count.
fn full_into_threads(plan: &SpectralPlan, threads: usize, out: &mut [f64]) {
    plan.execute_request_into(SpectrumRequest::Full, SweepOptions::with_threads(threads), out);
}

/// Top-k sweep with an explicit worker count and warm-start policy;
/// returns the solver iteration steps spent.
fn topk_into_threads(
    plan: &SpectralPlan,
    k: usize,
    threads: usize,
    warm: bool,
    out: &mut [f64],
) -> u64 {
    let opts = SweepOptions { threads: Some(threads), cold_start: !warm };
    plan.execute_request_into(SpectrumRequest::TopK(k), opts, out).0
}

fn slope(points: &[(f64, f64)]) -> f64 {
    // least-squares slope in log-log space
    let n = points.len() as f64;
    let (mut sx, mut sy, mut sxx, mut sxy) = (0.0, 0.0, 0.0, 0.0);
    for &(x, y) in points {
        let (lx, ly) = (x.ln(), y.ln());
        sx += lx;
        sy += ly;
        sxx += lx * lx;
        sxy += lx * ly;
    }
    (n * sxy - sx * sy) / (n * sxx - sx * sx)
}

/// A homogeneous stack: `depth` conv layers of `c×c` channels on an `n×n`
/// grid — the equal-shape batching case ModelPlan groups into one sweep.
fn equal_shape_model(depth: usize, c: usize, n: usize) -> ModelConfig {
    let layers = (0..depth)
        .map(|i| LayerConfig {
            name: format!("conv{i}"),
            c_in: c,
            c_out: c,
            kh: 3,
            kw: 3,
            height: n,
            width: n,
            stride: 1,
            groups: 1,
            dilation: 1,
            transposed: false,
            init: Init::He,
        })
        .collect();
    ModelConfig { name: format!("stack-{depth}x c{c} n{n}"), seed: 77, layers }
}

fn main() {
    let opts = bench_opts();
    let bench = opts.bench;
    let mut json = JsonLines::new("bench_scaling");
    let c = 8;
    let mut rng = Pcg64::seeded(1000);
    let kernel = ConvKernel::random_he(c, c, 3, 3, &mut rng);

    // --- n-scaling ---
    let ns_fast: Vec<usize> = if opts.smoke {
        vec![16, 32]
    } else if opts.full {
        vec![32, 64, 128, 256]
    } else {
        vec![32, 64, 128]
    };
    let ns_explicit: Vec<usize> = if opts.smoke { vec![4, 6] } else { vec![4, 6, 8, 12] };
    let mut lfa_pts = Vec::new();
    let mut fft_pts = Vec::new();
    let mut exp_pts = Vec::new();
    for &n in &ns_fast {
        let m = bench.measure("lfa", || lfa::singular_values(&kernel, n, n, serial()));
        json.record_measurement(&format!("lfa n={n}"), &m);
        lfa_pts.push((n as f64, m.min().as_secs_f64()));
        let m = bench.measure("fft", || {
            fft_svd::singular_values(&kernel, n, n, FftLayoutPolicy::Natural, 1)
        });
        json.record_measurement(&format!("fft n={n}"), &m);
        fft_pts.push((n as f64, m.min().as_secs_f64()));
    }
    for &n in &ns_explicit {
        let m = bench.measure("explicit", || {
            explicit_svd::singular_values(&kernel, n, n, Boundary::Periodic)
        });
        json.record_measurement(&format!("explicit n={n}"), &m);
        exp_pts.push((n as f64, m.min().as_secs_f64()));
    }

    // --- c-scaling at fixed n ---
    let n_fixed = if opts.smoke { 16 } else { 32 };
    let cs: Vec<usize> = if opts.smoke { vec![4, 8] } else { vec![4, 8, 16, 32] };
    let mut lfa_c = Vec::new();
    for &cc in &cs {
        let mut rng = Pcg64::seeded(1001 + cc as u64);
        let k = ConvKernel::random_he(cc, cc, 3, 3, &mut rng);
        let m = bench.measure("lfa-c", || lfa::singular_values(&k, n_fixed, n_fixed, serial()));
        json.record_measurement(&format!("lfa c={cc} n={n_fixed}"), &m);
        lfa_c.push((cc as f64, m.min().as_secs_f64()));
    }

    // --- plan-once/execute-many vs plan-per-call (paper-c16 shapes) ---
    // `lfa::singular_values` builds a throwaway SpectralPlan per call; a
    // held plan skips phase-table construction and all per-call allocation.
    // This is the repeated-spectrum workload (training-loop clipping).
    let mut plan_rows: Vec<[String; 4]> = Vec::new();
    let ns_plan: Vec<usize> = if opts.smoke {
        vec![16]
    } else if opts.full {
        vec![32, 64]
    } else {
        vec![32]
    };
    for &n in &ns_plan {
        let mut rng = Pcg64::seeded(1002 + n as u64);
        let k16 = ConvKernel::random_he(16, 16, 3, 3, &mut rng);
        let m = bench.measure("plan-per-call", || lfa::singular_values(&k16, n, n, serial()));
        json.record_measurement(&format!("plan-per-call c16 n={n}"), &m);
        let per_call = m.min().as_secs_f64();
        let plan = SpectralPlan::new(&k16, n, n, serial());
        let mut out = vec![0.0f64; plan.values_len()];
        full_into(&plan, &mut out); // warm the workspace pool
        let m = bench.measure("plan-reuse", || {
            full_into(&plan, &mut out);
            out[0]
        });
        json.record_measurement(&format!("plan-reuse c16 n={n}"), &m);
        let reused = m.min().as_secs_f64();
        plan_rows.push([
            format!("c16 n={n}"),
            format!("{:.3} ms", per_call * 1e3),
            format!("{:.3} ms", reused * 1e3),
            format!("{:.2}x", per_call / reused.max(1e-12)),
        ]);
    }

    // --- ModelPlan: whole-model batched sweep vs per-layer plans ---
    // The equal-shape special case: `depth` identical layers. Both sides
    // hold prebuilt plans and reuse output buffers; the model side batches
    // all layers into one group-major sweep (shared workspace pool, a
    // single scoped fan-out when threaded) while the per-layer side
    // executes N independent plans back-to-back.
    let (depth, mc, mn) = if opts.smoke { (6, 4, 16) } else { (8, 8, 32) };
    let threads = resolve_threads(0);
    let model = equal_shape_model(depth, mc, mn);
    let mut model_rows: Vec<[String; 4]> = Vec::new();
    let mut thread_counts = vec![1usize];
    if threads > 1 {
        thread_counts.push(threads);
    }
    for &t in &thread_counts {
        let lfa_opts = LfaOptions { threads: t, ..Default::default() };
        let mplan = ModelPlan::build(&model, lfa_opts).expect("valid model");
        let mut mout = vec![0.0f64; mplan.values_len()];
        mplan.execute_into(&mut mout); // warm all pools
        let m = bench.measure("model-plan", || {
            mplan.execute_into(&mut mout);
            mout[0]
        });
        json.record_measurement(&format!("model-plan {depth}xc{mc} n={mn} t={t}"), &m);
        let batched = m.min().as_secs_f64();

        let plans: Vec<SpectralPlan> = model
            .layers
            .iter()
            .map(|l| {
                let k = l.materialize(model.seed);
                SpectralPlan::new(&k, l.height, l.width, lfa_opts)
            })
            .collect();
        let mut outs: Vec<Vec<f64>> =
            plans.iter().map(|p| vec![0.0f64; p.values_len()]).collect();
        for (p, o) in plans.iter().zip(outs.iter_mut()) {
            full_into(p, o); // warm per-layer pools
        }
        let m = bench.measure("per-layer-plans", || {
            for (p, o) in plans.iter().zip(outs.iter_mut()) {
                full_into(p, o);
            }
            outs[0][0]
        });
        json.record_measurement(&format!("per-layer-plans {depth}xc{mc} n={mn} t={t}"), &m);
        let independent = m.min().as_secs_f64();
        model_rows.push([
            format!("{depth}x c{mc} n={mn} threads={t}"),
            format!("{:.3} ms", independent * 1e3),
            format!("{:.3} ms", batched * 1e3),
            format!("{:.2}x", independent / batched.max(1e-12)),
        ]);
    }

    // --- TopK partial spectrum: full fused vs warm/cold top-k (k=4) ---
    // Production consumers (clipping, Lipschitz bounds, compression) only
    // need a few extreme values per frequency; the warm-started Krylov
    // sweep computes exactly those. The c³-vs-c²k gap means the margin
    // grows with the channel count, so the largest case is the headline.
    let kk = 4usize;
    let topk_cases: Vec<(usize, usize)> = if opts.smoke {
        vec![(16, 16), (64, 16)]
    } else if opts.full {
        vec![(32, 32), (64, 32), (128, 32)]
    } else {
        vec![(16, 32), (32, 16), (64, 16)]
    };
    let mut topk_rows: Vec<[String; 6]> = Vec::new();
    let mut topk_verdict = String::new();
    for &(c, n) in &topk_cases {
        let mut rng = Pcg64::seeded(1003 + c as u64);
        let k = ConvKernel::random_he(c, c, 3, 3, &mut rng);
        let plan = SpectralPlan::new(&k, n, n, serial());
        let freqs = plan.freqs() as f64;
        let mut out_full = vec![0.0f64; plan.values_len()];
        full_into(&plan, &mut out_full); // warm the pool
        let m = bench.measure("topk-baseline-full", || {
            full_into(&plan, &mut out_full);
            out_full[0]
        });
        json.record_measurement(&format!("topk-baseline-full c={c} n={n}"), &m);
        let t_full = m.min().as_secs_f64();

        let mut out_top = vec![0.0f64; plan.topk_values_len(kk)];
        let warm_iters = topk_into_threads(&plan, kk, 1, true, &mut out_top); // warm the pool
        let m = bench.measure("topk-warm", || {
            topk_into_threads(&plan, kk, 1, true, &mut out_top);
            out_top[0]
        });
        json.record_measurement(&format!("topk-warm k={kk} c={c} n={n}"), &m);
        let t_warm = m.min().as_secs_f64();

        let cold_iters = topk_into_threads(&plan, kk, 1, false, &mut out_top);
        let m = bench.measure("topk-cold", || {
            topk_into_threads(&plan, kk, 1, false, &mut out_top);
            out_top[0]
        });
        json.record_measurement(&format!("topk-cold k={kk} c={c} n={n}"), &m);
        let t_cold = m.min().as_secs_f64();

        let speedup = t_full / t_warm.max(1e-12);
        topk_rows.push([
            format!("c{c} n={n}"),
            format!("{:.3} ms", t_full * 1e3),
            format!("{:.3} ms", t_warm * 1e3),
            format!("{speedup:.2}x"),
            format!("{:.2} / {:.2}", warm_iters as f64 / freqs, cold_iters as f64 / freqs),
            format!("{:.2}x", t_cold / t_warm.max(1e-12)),
        ]);
        // The last case is the largest; its margin is the acceptance line.
        topk_verdict = format!(
            "topk verdict: largest case c{c} n={n} — top-{kk} warm {speedup:.2}x \
             faster than full fused (target ≥3x), warm {:.2} vs cold {:.2} \
             iters/freq",
            warm_iters as f64 / freqs,
            cold_iters as f64 / freqs
        );
    }

    // --- Fold: conjugate-pair frequency folding vs Fold::Off ---
    // Real kernels give A(−θ) = conj(A(θ)); the folded domain solves about
    // half the per-frequency SVDs and mirrors the rest. The acceptance
    // line is the full-spectrum native-threaded path on a 64-channel
    // layer: the speedup should approach the fold ratio (~2x) as the
    // O(c³) SVD stage dominates.
    let (fold_c, fold_n) = if opts.smoke {
        (64usize, 8usize)
    } else if opts.full {
        (64, 32)
    } else {
        (64, 16)
    };
    let mut fold_rows_tbl: Vec<[String; 5]> = Vec::new();
    let mut fold_verdict = String::new();
    {
        let mut rng = Pcg64::seeded(1004);
        let k = ConvKernel::random_he(fold_c, fold_c, 3, 3, &mut rng);
        let folded = SpectralPlan::new(&k, fold_n, fold_n, serial());
        let unfolded =
            SpectralPlan::new(&k, fold_n, fold_n, LfaOptions { folding: Fold::Off, ..serial() });
        let ratio = unfolded.solved_freqs() as f64 / folded.solved_freqs() as f64;
        let mut out = vec![0.0f64; folded.values_len()];
        for &t in &thread_counts {
            full_into_threads(&folded, t, &mut out); // warm the pools
            let m = bench.measure("fold-on", || {
                full_into_threads(&folded, t, &mut out);
                out[0]
            });
            json.record_measurement(&format!("fold-on c={fold_c} n={fold_n} t={t}"), &m);
            let t_fold = m.min().as_secs_f64();
            full_into_threads(&unfolded, t, &mut out);
            let m = bench.measure("fold-off", || {
                full_into_threads(&unfolded, t, &mut out);
                out[0]
            });
            json.record_measurement(&format!("fold-off c={fold_c} n={fold_n} t={t}"), &m);
            let t_off = m.min().as_secs_f64();
            let speedup = t_off / t_fold.max(1e-12);
            fold_rows_tbl.push([
                format!("c{fold_c} n={fold_n} threads={t}"),
                format!("{:.3} ms", t_off * 1e3),
                format!("{:.3} ms", t_fold * 1e3),
                format!("{speedup:.2}x"),
                format!("{}/{}", folded.solved_freqs(), unfolded.solved_freqs()),
            ]);
            // The threaded row (last when multi-core) is the acceptance line.
            fold_verdict = format!(
                "fold verdict: c{fold_c} n={fold_n} threads={t} — folded {speedup:.2}x \
                 faster than Fold::Off (target ≥1.7x on the full-spectrum \
                 native-threaded path), frequencies solved {}/{} (fold {ratio:.2}x)",
                folded.solved_freqs(),
                unfolded.solved_freqs()
            );
        }
    }

    // --- SpectralCache: cold vs warm repeat model audits ---
    // The repeat-traffic scenario (training-loop clipping à la
    // Senderovich et al., repeated Lipschitz audits à la Sedghi et al.):
    // the second audit of an unchanged model should be a hash lookup per
    // layer, not a sweep. Cold clears the cache every iteration (so the
    // measured time includes the inserts); warm hits every layer and
    // re-solves zero frequencies — that invariant is asserted, not
    // assumed, and the margin is the acceptance line.
    let (cd, cc, cn) = if opts.smoke { (6usize, 4usize, 16usize) } else { (8, 8, 32) };
    let cache_model = equal_shape_model(cd, cc, cn);
    let mut cache_rows: Vec<[String; 4]> = Vec::new();
    let cache_verdict = {
        let cache = SpectralCache::new();
        let cplan =
            ModelPlan::build_cached(&cache_model, serial(), &cache).expect("valid model");
        let m = bench.measure("cache-cold", || {
            cache.clear();
            cplan.execute_cached(&cache).freqs_solved
        });
        json.record_measurement(&format!("cache-cold {cd}xc{cc} n={cn}"), &m);
        let t_cold = m.min().as_secs_f64();
        // The last cold iteration left the cache populated: measure the
        // pure-hit repeat, pinning its zero-work invariant first.
        let probe = cplan.execute_cached(&cache);
        assert_eq!(probe.cache_hits, cplan.layer_count(), "warm repeat must hit every layer");
        assert_eq!(probe.freqs_solved, 0, "warm repeat must re-solve zero frequencies");
        let m = bench.measure("cache-warm", || cplan.execute_cached(&cache).cache_hits);
        json.record_measurement(&format!("cache-warm {cd}xc{cc} n={cn}"), &m);
        let t_warm = m.min().as_secs_f64();
        let speedup = t_cold / t_warm.max(1e-12);
        cache_rows.push([
            format!("{cd}x c{cc} n={cn}"),
            format!("{:.3} ms", t_cold * 1e3),
            format!("{:.3} ms", t_warm * 1e3),
            format!("{speedup:.2}x"),
        ]);
        format!(
            "cache verdict: {cd}x c{cc} n={cn} — warm repeat audit {speedup:.2}x faster \
             than cold (target ≥5x; {}/{cd} layers served from cache, 0 frequencies \
             re-solved)",
            probe.cache_hits
        )
    };

    // --- Disk tier: disk-cold vs disk-warm repeat audits ---
    // The daemon-restart scenario: a warm *process* serves repeats from
    // the in-memory LRU (cache-warm above); a warm *spill directory*
    // serves a fresh process that lost its memory tier. disk-cold purges
    // the spill files and drops the memory results every iteration, so
    // the measured time includes the sweep plus the checksummed spill
    // writes; disk-warm drops only the memory results, so every layer
    // comes back through a validated disk read and re-solves zero
    // frequencies — asserted, not assumed.
    let disk_dir = std::env::temp_dir().join(format!("lfa-bench-spill-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&disk_dir);
    let mut disk_rows: Vec<[String; 4]> = Vec::new();
    let disk_verdict = {
        let cache =
            SpectralCache::new().with_disk(DiskCache::open(&disk_dir).expect("bench spill dir"));
        let cplan =
            ModelPlan::build_cached(&cache_model, serial(), &cache).expect("valid model");
        let m = bench.measure("disk-cold", || {
            cache.clear_results();
            cache.disk().expect("disk tier attached").purge();
            cplan.execute_cached(&cache).freqs_solved
        });
        json.record_measurement(&format!("disk-cold {cd}xc{cc} n={cn}"), &m);
        let t_cold = m.min().as_secs_f64();
        // The last cold iteration left its spill files behind: drop the
        // memory tier and pin the restart-shaped zero-work invariant.
        cache.clear_results();
        let probe = cplan.execute_cached(&cache);
        assert_eq!(probe.freqs_solved, 0, "disk-warm repeat must re-solve zero frequencies");
        assert_eq!(
            probe.cache_hits,
            cplan.layer_count(),
            "disk-warm repeat must serve every layer from the spill files"
        );
        let m = bench.measure("disk-warm", || {
            cache.clear_results();
            cplan.execute_cached(&cache).cache_hits
        });
        json.record_measurement(&format!("disk-warm {cd}xc{cc} n={cn}"), &m);
        let t_warm = m.min().as_secs_f64();
        let speedup = t_cold / t_warm.max(1e-12);
        disk_rows.push([
            format!("{cd}x c{cc} n={cn}"),
            format!("{:.3} ms", t_cold * 1e3),
            format!("{:.3} ms", t_warm * 1e3),
            format!("{speedup:.2}x"),
        ]);
        format!(
            "disk verdict: {cd}x c{cc} n={cn} — disk-warm restart audit {speedup:.2}x faster \
             than disk-cold (target: faster than the cold sweep it replaces; \
             {}/{cd} layers read back from spill files, 0 frequencies re-solved)",
            probe.cache_hits
        )
    };
    let _ = std::fs::remove_dir_all(&disk_dir);

    // --- SIMD & precision: vectorized kernels vs forced scalar, f32 vs f64 ---
    // The acceptance case is a 64-channel full sweep, where the O(c³)
    // per-frequency complex kernels (split-complex phase multiply, Gram
    // formation, Jacobi rotations) dominate and the AVX2+FMA lanes pay
    // off. Forced scalar runs the bit-identical fallback on the *same*
    // plan, so the margin is pure vectorization. The precision rows rerun
    // the same shapes at f32 (double the lane width, ~1e-4·σ_max) and
    // f32-refined (f32 sweep + one f64 polish per frequency, ≤1e-12
    // restored — accuracy pinned by tests/engine_equivalence.rs, not here).
    let (sp_c, sp_n) = (fold_c, fold_n);
    let mut simd_rows: Vec<[String; 5]> = Vec::new();
    let mut prec_rows: Vec<[String; 6]> = Vec::new();
    let simd_verdict;
    let prec_verdict;
    {
        let mut rng = Pcg64::seeded(1005);
        let k = ConvKernel::random_he(sp_c, sp_c, 3, 3, &mut rng);
        let plan_at = |precision| {
            SpectralPlan::new(&k, sp_n, sp_n, LfaOptions { precision, ..serial() })
        };
        let p64 = plan_at(Precision::F64);
        let p32 = plan_at(Precision::F32);
        let pref = plan_at(Precision::F32Refined);
        let kernel = active_kernel_name();
        let mut out = vec![0.0f64; p64.values_len()];
        let mut outk = vec![0.0f64; p64.topk_values_len(kk)];
        // Serial full-sweep headline numbers, captured for the verdicts.
        let (mut v_scalar64, mut v_auto64, mut v_auto32, mut v_ref) = (0.0, 0.0, 0.0, 0.0);
        for &t in &thread_counts {
            // Full sweep: forced scalar f64, then auto at all three tiers.
            set_force_scalar(true);
            let m = bench.measure("simd-scalar-full", || {
                full_into_threads(&p64, t, &mut out);
                out[0]
            });
            json.record_measurement(
                &format!("simd-vs-scalar full scalar f64 c={sp_c} n={sp_n} t={t}"),
                &m,
            );
            let t_scalar64 = m.min().as_secs_f64();
            set_force_scalar(false);
            let m = bench.measure("simd-auto-full", || {
                full_into_threads(&p64, t, &mut out);
                out[0]
            });
            json.record_measurement(
                &format!("simd-vs-scalar full auto f64 c={sp_c} n={sp_n} t={t}"),
                &m,
            );
            let t_auto64 = m.min().as_secs_f64();
            json.record(&format!("f32-vs-f64 full f64 c={sp_c} n={sp_n} t={t}"), t_auto64 * 1e9);
            let m = bench.measure("prec-f32-full", || {
                full_into_threads(&p32, t, &mut out);
                out[0]
            });
            json.record_measurement(&format!("f32-vs-f64 full f32 c={sp_c} n={sp_n} t={t}"), &m);
            let t_auto32 = m.min().as_secs_f64();
            let m = bench.measure("prec-refined-full", || {
                full_into_threads(&pref, t, &mut out);
                out[0]
            });
            json.record_measurement(
                &format!("f32-vs-f64 full f32-refined c={sp_c} n={sp_n} t={t}"),
                &m,
            );
            let t_ref = m.min().as_secs_f64();
            if t == 1 {
                (v_scalar64, v_auto64, v_auto32, v_ref) = (t_scalar64, t_auto64, t_auto32, t_ref);
            }
            simd_rows.push([
                format!("full c{sp_c} n={sp_n} threads={t}"),
                format!("{:.3} ms", t_scalar64 * 1e3),
                format!("{:.3} ms", t_auto64 * 1e3),
                format!("{:.2}x", t_scalar64 / t_auto64.max(1e-12)),
                kernel.to_string(),
            ]);
            prec_rows.push([
                format!("full c{sp_c} n={sp_n} threads={t}"),
                format!("{:.3} ms", t_auto64 * 1e3),
                format!("{:.3} ms", t_auto32 * 1e3),
                format!("{:.2}x", t_auto64 / t_auto32.max(1e-12)),
                format!("{:.3} ms", t_ref * 1e3),
                format!("{:.2}x", t_auto64 / t_ref.max(1e-12)),
            ]);

            // Top-k (k=4), warm-started, same kernel/precision grid.
            set_force_scalar(true);
            let m = bench.measure("simd-scalar-topk", || {
                topk_into_threads(&p64, kk, t, true, &mut outk);
                outk[0]
            });
            json.record_measurement(
                &format!("simd-vs-scalar topk scalar f64 k={kk} c={sp_c} n={sp_n} t={t}"),
                &m,
            );
            let k_scalar64 = m.min().as_secs_f64();
            set_force_scalar(false);
            let m = bench.measure("simd-auto-topk", || {
                topk_into_threads(&p64, kk, t, true, &mut outk);
                outk[0]
            });
            json.record_measurement(
                &format!("simd-vs-scalar topk auto f64 k={kk} c={sp_c} n={sp_n} t={t}"),
                &m,
            );
            let k_auto64 = m.min().as_secs_f64();
            json.record(
                &format!("f32-vs-f64 topk f64 k={kk} c={sp_c} n={sp_n} t={t}"),
                k_auto64 * 1e9,
            );
            let m = bench.measure("prec-f32-topk", || {
                topk_into_threads(&p32, kk, t, true, &mut outk);
                outk[0]
            });
            json.record_measurement(
                &format!("f32-vs-f64 topk f32 k={kk} c={sp_c} n={sp_n} t={t}"),
                &m,
            );
            let k_auto32 = m.min().as_secs_f64();
            let m = bench.measure("prec-refined-topk", || {
                topk_into_threads(&pref, kk, t, true, &mut outk);
                outk[0]
            });
            json.record_measurement(
                &format!("f32-vs-f64 topk f32-refined k={kk} c={sp_c} n={sp_n} t={t}"),
                &m,
            );
            let k_ref = m.min().as_secs_f64();
            simd_rows.push([
                format!("topk k={kk} c{sp_c} n={sp_n} threads={t}"),
                format!("{:.3} ms", k_scalar64 * 1e3),
                format!("{:.3} ms", k_auto64 * 1e3),
                format!("{:.2}x", k_scalar64 / k_auto64.max(1e-12)),
                kernel.to_string(),
            ]);
            prec_rows.push([
                format!("topk k={kk} c{sp_c} n={sp_n} threads={t}"),
                format!("{:.3} ms", k_auto64 * 1e3),
                format!("{:.3} ms", k_auto32 * 1e3),
                format!("{:.2}x", k_auto64 / k_auto32.max(1e-12)),
                format!("{:.3} ms", k_ref * 1e3),
                format!("{:.2}x", k_auto64 / k_ref.max(1e-12)),
            ]);
        }
        let s64 = v_scalar64 / v_auto64.max(1e-12);
        let s32 = v_scalar64 / v_auto32.max(1e-12);
        simd_verdict = if kernel == "scalar" {
            format!(
                "simd verdict: c{sp_c} n={sp_n} serial full sweep — AVX2+FMA unavailable on \
                 this host, auto ran the scalar fallback ({s64:.2}x vs forced scalar, expected \
                 ~1x); the ≥1.5x (f64) / ≥2.5x (f32) targets apply to AVX2 hosts only"
            )
        } else {
            format!(
                "simd verdict: c{sp_c} n={sp_n} serial full sweep — {kernel} f64 {s64:.2}x over \
                 forced scalar (target ≥1.5x), f32 {s32:.2}x over scalar f64 (target ≥2.5x)"
            )
        };
        prec_verdict = format!(
            "precision verdict: c{sp_c} n={sp_n} serial full sweep — f32 {:.2}x over f64, \
             f32-refined {:.2}x over f64 (accuracy: f32 ~1e-4·σ_max, f32-refined ≤1e-12; \
             pinned by the engine_equivalence precision matrix)",
            v_auto64 / v_auto32.max(1e-12),
            v_auto64 / v_ref.max(1e-12)
        );
    }

    // --- Grouped vs dense: block-diagonal structured symbols ---
    // Same total channel width c, three structures: dense (one c×c SVD per
    // frequency), grouped g=8 (8 SVDs of (c/8)×(c/8) — c³/64 flops), and
    // depthwise g=c (c scalar symbols — the MobileNet block). All serial,
    // warmed pools, full spectra; the depthwise-vs-dense margin is the
    // acceptance line (it should be large — the block solve is g² cheaper).
    let (gv_c, gv_n) = (fold_c, fold_n);
    let mut grouped_rows: Vec<[String; 4]> = Vec::new();
    let grouped_verdict = {
        let mut rng = Pcg64::seeded(1006);
        let cases = [
            ("dense", ConvKernel::random_he(gv_c, gv_c, 3, 3, &mut rng)),
            (
                "grouped g=8",
                ConvKernel::random_he(gv_c, gv_c / 8, 3, 3, &mut rng).with_groups(8),
            ),
            (
                "depthwise",
                ConvKernel::random_he(gv_c, 1, 3, 3, &mut rng).with_groups(gv_c),
            ),
        ];
        let mut times = Vec::new();
        for (tag, k) in &cases {
            let plan = SpectralPlan::new(k, gv_n, gv_n, serial());
            let mut out = vec![0.0f64; plan.values_len()];
            full_into(&plan, &mut out); // warm the pool
            let m = bench.measure("grouped-vs-dense", || {
                full_into(&plan, &mut out);
                out[0]
            });
            json.record_measurement(&format!("grouped-vs-dense {tag} c={gv_c} n={gv_n}"), &m);
            times.push(m.min().as_secs_f64());
        }
        let dense_t = times[0];
        for ((tag, _), &t) in cases.iter().zip(&times) {
            grouped_rows.push([
                format!("{tag} c{gv_c} n={gv_n}"),
                format!("{:.3} ms", t * 1e3),
                format!("{:.2}x", dense_t / t.max(1e-12)),
                if *tag == "dense" { "1 block/freq".into() } else { "block-diagonal".into() },
            ]);
        }
        format!(
            "grouped verdict: c{gv_c} n={gv_n} serial full sweep — depthwise {:.2}x faster \
             than dense (target: measurably faster, block solves are g² cheaper), \
             grouped g=8 {:.2}x",
            dense_t / times[2].max(1e-12),
            dense_t / times[1].max(1e-12)
        )
    };

    // --- Health overhead: certified sweep vs values-only consumption ---
    // Convergence certificates are woven into the solve (the residual the
    // certificate reports is the same quantity the Jacobi/Krylov stopping
    // test already computes), so there is no "certificates off" switch to
    // flip. This section bounds what the health layer *adds on top of the
    // hot loop* — per-frequency verdict aggregation and the Spectrum
    // packaging that carries SpectrumHealth — by comparing the certified
    // path (`execute()`, health carried on the result) against the leanest
    // values-only path (`execute_request_into` into a reused buffer,
    // certificate discarded). The acceptance line: ≤2% on the 64-channel
    // full sweep.
    let (hv_c, hv_n) = (fold_c, fold_n);
    let mut health_rows: Vec<[String; 4]> = Vec::new();
    let health_verdict = {
        let mut rng = Pcg64::seeded(1007);
        let k = ConvKernel::random_he(hv_c, hv_c, 3, 3, &mut rng);
        let plan = SpectralPlan::new(&k, hv_n, hv_n, serial());
        let mut out = vec![0.0f64; plan.values_len()];
        full_into(&plan, &mut out); // warm the pool
        let m = bench.measure("health-values-only", || {
            full_into(&plan, &mut out);
            out[0]
        });
        json.record_measurement(&format!("health-overhead values-only c={hv_c} n={hv_n}"), &m);
        let t_values = m.min().as_secs_f64();
        let m = bench.measure("health-certified", || {
            let spectrum = plan.execute();
            spectrum.health.converged_freqs
        });
        json.record_measurement(&format!("health-overhead certified c={hv_c} n={hv_n}"), &m);
        let t_cert = m.min().as_secs_f64();
        let overhead = (t_cert / t_values.max(1e-12) - 1.0) * 100.0;
        health_rows.push([
            format!("c{hv_c} n={hv_n} serial full"),
            format!("{:.3} ms", t_values * 1e3),
            format!("{:.3} ms", t_cert * 1e3),
            format!("{overhead:+.2}%"),
        ]);
        format!(
            "health verdict: c{hv_c} n={hv_n} serial full sweep — certified path \
             {overhead:+.2}% vs values-only (target ≤2%: certificate bookkeeping \
             must be free next to the O(c³) per-frequency solve)"
        )
    };

    // --- Density vs full: streaming histogram analytics (DensitySink) ---
    // The Yi-2020 asymptotic-distribution workload: bulk spectral shape +
    // exact extremes on grids where materializing the full spectrum is the
    // wrong tool. The density path pays one warm top-1 Krylov pass over
    // the whole grid (σ_max exact) plus full SVDs on a 1/s² coarse
    // sub-lattice only, streamed into a histogram — nothing n·m·rank-sized
    // is ever allocated. The verdict reports the measured speedup over the
    // full sweep and the worst quantile deviation against the full sweep's
    // exact (sorted) quantiles, with the resolution-independent DKW 95%
    // CDF error bar ±ε the result itself carries.
    let (dv_c, dv_n) = if opts.smoke {
        (16usize, 64usize)
    } else if opts.full {
        (64, 1024)
    } else {
        (32, 256)
    };
    let mut density_rows: Vec<[String; 6]> = Vec::new();
    let density_verdict = {
        let mut rng = Pcg64::seeded(1008);
        let k = ConvKernel::random_he(dv_c, dv_c, 3, 3, &mut rng);
        let plan = SpectralPlan::new(&k, dv_n, dv_n, LfaOptions::default());
        let mut out = vec![0.0f64; plan.values_len()];
        full_into(&plan, &mut out); // warm the pool
        let m = bench.measure("density-baseline-full", || {
            full_into(&plan, &mut out);
            out[0]
        });
        json.record_measurement(&format!("density-vs-full full c={dv_c} n={dv_n}"), &m);
        let t_full = m.min().as_secs_f64();
        // Exact quantiles from the full sweep: sort a copy once.
        let mut sorted = out.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("finite singular values"));
        let exact_q = |q: f64| {
            let i = (q * (sorted.len() - 1) as f64).round() as usize;
            sorted[i.min(sorted.len() - 1)]
        };
        let sigma_max = sorted[sorted.len() - 1].max(1e-300);
        let qs = [0.25, 0.5, 0.75, 0.9, 0.99];
        let (mut headline_speedup, mut headline_dev, mut headline_eps) = (0.0f64, 0.0f64, 0.0f64);
        for &s in &[2u32, 4] {
            let req = DensityRequest { bins: 256, sample: s };
            let d = plan.density(req); // warm the pool + keep the result
            let m = bench.measure("density-sampled", || plan.density(req).count());
            json.record_measurement(
                &format!("density-vs-full density s={s} c={dv_c} n={dv_n}"),
                &m,
            );
            let t_density = m.min().as_secs_f64();
            let speedup = t_full / t_density.max(1e-12);
            let dev = qs
                .iter()
                .map(|&q| (d.quantile(q) - exact_q(q)).abs())
                .fold(0.0f64, f64::max)
                / sigma_max;
            density_rows.push([
                format!("c{dv_c} n={dv_n} sample={s}"),
                format!("{:.3} ms", t_full * 1e3),
                format!("{:.3} ms", t_density * 1e3),
                format!("{speedup:.2}x"),
                format!("{dev:.4}"),
                format!("{:.4}", d.cdf_epsilon()),
            ]);
            // The coarsest sub-lattice is the headline case.
            (headline_speedup, headline_dev, headline_eps) =
                (speedup, dev, d.cdf_epsilon());
        }
        format!(
            "density verdict: c{dv_c} n={dv_n} sample=4 — density sweep \
             {headline_speedup:.2}x faster than the full sweep ({:.1}% of its wall \
             time, target ≤25%), max quantile deviation {headline_dev:.4}·σ_max \
             (DKW 95% ±ε {headline_eps:.4}; σ_max exact via the top-1 pass)",
            100.0 / headline_speedup.max(1e-12)
        )
    };

    println!("# Table I — measured scaling exponents vs theory");
    let mut table = Table::new(["series", "fit slope", "theory", "verdict"]);
    let rows: Vec<(&str, f64, f64, f64)> = vec![
        ("LFA vs n", slope(&lfa_pts), 2.0, 0.5),
        ("FFT vs n", slope(&fft_pts), 2.0, 0.7), // +log factor pushes it up
        ("explicit vs n", slope(&exp_pts), 6.0, 1.6),
        ("LFA vs c", slope(&lfa_c), 3.0, 0.8),
    ];
    for (name, got, want, tol) in rows {
        let ok = (got - want).abs() <= tol || (name.contains("FFT") && got >= want - tol);
        table.row([
            name.to_string(),
            format!("{got:.2}"),
            format!("{want:.1}"),
            if ok { "ok".into() } else { format!("OFF by {:.2}", got - want) },
        ]);
    }
    print!("{}", table.render());

    println!("\n# SpectralPlan — plan-once/execute-many vs plan-per-call");
    let mut ptable = Table::new(["shape", "plan-per-call", "plan-reuse", "speedup"]);
    for row in plan_rows {
        ptable.row(row);
    }
    print!("{}", ptable.render());

    println!("\n# ModelPlan — whole-model batched sweep vs per-layer plans");
    let mut mtable = Table::new(["workload", "per-layer plans", "model-plan", "speedup"]);
    for row in model_rows {
        mtable.row(row);
    }
    print!("{}", mtable.render());

    println!("\n# TopK — warm-started partial spectrum (k=4) vs full fused path");
    let mut ttable = Table::new([
        "shape",
        "full fused",
        "topk warm",
        "speedup",
        "iters/freq warm/cold",
        "warm vs cold",
    ]);
    for row in topk_rows {
        ttable.row(row);
    }
    print!("{}", ttable.render());
    println!("{topk_verdict}");

    println!("\n# Fold — conjugate-pair frequency folding vs Fold::Off (full spectrum)");
    let mut ftable = Table::new(["workload", "fold off", "folded", "speedup", "freqs solved"]);
    for row in fold_rows_tbl {
        ftable.row(row);
    }
    print!("{}", ftable.render());
    println!("{fold_verdict}");

    println!("\n# SpectralCache — cold vs warm repeat audit (content-addressed results)");
    let mut ctable = Table::new(["workload", "cold (sweep+insert)", "warm (all hits)", "speedup"]);
    for row in cache_rows {
        ctable.row(row);
    }
    print!("{}", ctable.render());
    println!("{cache_verdict}");

    println!("\n# Disk tier — disk-cold vs disk-warm restart audit (persistent spill files)");
    let mut dtable = Table::new(["workload", "disk-cold (sweep+spill)", "disk-warm (reads)", "speedup"]);
    for row in disk_rows {
        dtable.row(row);
    }
    print!("{}", dtable.render());
    println!("{disk_verdict}");

    println!("\n# SIMD — AVX2+FMA complex kernels vs forced scalar (simd-vs-scalar)");
    let mut stable = Table::new(["workload", "forced scalar", "auto", "speedup", "kernel"]);
    for row in simd_rows {
        stable.row(row);
    }
    print!("{}", stable.render());
    println!("{simd_verdict}");

    println!("\n# Precision — f32 / f32-refined vs the f64 reference (f32-vs-f64)");
    let mut qtable = Table::new([
        "workload",
        "f64",
        "f32",
        "f32 speedup",
        "f32-refined",
        "refined speedup",
    ]);
    for row in prec_rows {
        qtable.row(row);
    }
    print!("{}", qtable.render());
    println!("{prec_verdict}");

    println!("\n# Grouped vs dense — block-diagonal structured symbols (grouped-vs-dense)");
    let mut gtable = Table::new(["workload", "time", "vs dense", "per-frequency solve"]);
    for row in grouped_rows {
        gtable.row(row);
    }
    print!("{}", gtable.render());
    println!("{grouped_verdict}");

    println!("\n# Health — certified sweep vs values-only consumption (health-overhead)");
    let mut htable = Table::new(["workload", "values-only", "certified", "overhead"]);
    for row in health_rows {
        htable.row(row);
    }
    print!("{}", htable.render());
    println!("{health_verdict}");

    println!("\n# Density — sampled streaming histogram vs the full sweep (density-vs-full)");
    let mut ytable = Table::new([
        "workload",
        "full sweep",
        "density",
        "speedup",
        "max |Δq|/σ_max",
        "DKW ±ε",
    ]);
    for row in density_rows {
        ytable.row(row);
    }
    print!("{}", ytable.render());
    println!("{density_verdict}");

    if let Some(path) = &opts.json {
        json.write(path).expect("writing bench json");
        println!("\njson: {} ({} cases)", path.display(), json.len());
    }

    println!(
        "notes: explicit slope < 6 at tiny n (LAPACK-style constants dominate);\n\
         LFA-vs-c < 3 until c is large enough for the O(c³) SVD to dominate the\n\
         O(c²k²) transform. Trends, not exact asymptotics, at these sizes."
    );
}
