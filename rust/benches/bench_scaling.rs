//! Table I regenerator (complexity): measured scaling exponents of the
//! three methods vs their theoretical orders, by log–log slope fitting
//! over a geometric n-sweep.
//!
//!   explicit  O(n⁶)  (we fit on the SVD of the n²c × n²c dense matrix)
//!   FFT       O(n² (c + log n) c²) ≈ slope 2 in n (plus log factor)
//!   LFA       O(n² c³)            = slope exactly 2 in n
//!
//! Also channel scaling at fixed n: both fast methods are O(c³)-dominated.

use conv_svd_lfa::baselines::{explicit_svd, fft_svd, FftLayoutPolicy};
use conv_svd_lfa::bench_util::bench_args;
use conv_svd_lfa::conv::{Boundary, ConvKernel};
use conv_svd_lfa::engine::SpectralPlan;
use conv_svd_lfa::lfa::{self, LfaOptions};
use conv_svd_lfa::numeric::Pcg64;
use conv_svd_lfa::report::Table;

/// Serial options: the scaling fits want single-core numbers.
fn serial() -> LfaOptions {
    LfaOptions { threads: 1, ..Default::default() }
}

fn slope(points: &[(f64, f64)]) -> f64 {
    // least-squares slope in log-log space
    let n = points.len() as f64;
    let (mut sx, mut sy, mut sxx, mut sxy) = (0.0, 0.0, 0.0, 0.0);
    for &(x, y) in points {
        let (lx, ly) = (x.ln(), y.ln());
        sx += lx;
        sy += ly;
        sxx += lx * lx;
        sxy += lx * ly;
    }
    (n * sxy - sx * sy) / (n * sxx - sx * sx)
}

fn main() {
    let (bench, full) = bench_args();
    let c = 8;
    let mut rng = Pcg64::seeded(1000);
    let kernel = ConvKernel::random_he(c, c, 3, 3, &mut rng);

    // --- n-scaling ---
    let ns_fast: Vec<usize> = if full { vec![32, 64, 128, 256] } else { vec![32, 64, 128] };
    let ns_explicit: Vec<usize> = vec![4, 6, 8, 12];
    let mut lfa_pts = Vec::new();
    let mut fft_pts = Vec::new();
    let mut exp_pts = Vec::new();
    for &n in &ns_fast {
        let t = bench
            .measure("lfa", || lfa::singular_values(&kernel, n, n, serial()))
            .min()
            .as_secs_f64();
        lfa_pts.push((n as f64, t));
        let t = bench
            .measure("fft", || {
                fft_svd::singular_values(&kernel, n, n, FftLayoutPolicy::Natural, 1)
            })
            .min()
            .as_secs_f64();
        fft_pts.push((n as f64, t));
    }
    for &n in &ns_explicit {
        let t = bench
            .measure("explicit", || {
                explicit_svd::singular_values(&kernel, n, n, Boundary::Periodic)
            })
            .min()
            .as_secs_f64();
        exp_pts.push((n as f64, t));
    }

    // --- c-scaling at fixed n ---
    let n_fixed = 32;
    let mut lfa_c = Vec::new();
    for &cc in &[4usize, 8, 16, 32] {
        let mut rng = Pcg64::seeded(1001 + cc as u64);
        let k = ConvKernel::random_he(cc, cc, 3, 3, &mut rng);
        let t = bench
            .measure("lfa-c", || lfa::singular_values(&k, n_fixed, n_fixed, serial()))
            .min()
            .as_secs_f64();
        lfa_c.push((cc as f64, t));
    }

    // --- plan-once/execute-many vs plan-per-call (paper-c16 shapes) ---
    // `lfa::singular_values` builds a throwaway SpectralPlan per call; a
    // held plan skips phase-table construction and all per-call allocation.
    // This is the repeated-spectrum workload (training-loop clipping).
    let mut plan_rows: Vec<[String; 4]> = Vec::new();
    let ns_plan: Vec<usize> = if full { vec![32, 64] } else { vec![32] };
    for &n in &ns_plan {
        let mut rng = Pcg64::seeded(1002 + n as u64);
        let k16 = ConvKernel::random_he(16, 16, 3, 3, &mut rng);
        let per_call = bench
            .measure("plan-per-call", || lfa::singular_values(&k16, n, n, serial()))
            .min()
            .as_secs_f64();
        let plan = SpectralPlan::new(&k16, n, n, serial());
        let mut out = vec![0.0f64; plan.values_len()];
        plan.execute_into(&mut out); // warm the workspace pool
        let reused = bench
            .measure("plan-reuse", || {
                plan.execute_into(&mut out);
                out[0]
            })
            .min()
            .as_secs_f64();
        plan_rows.push([
            format!("c16 n={n}"),
            format!("{:.3} ms", per_call * 1e3),
            format!("{:.3} ms", reused * 1e3),
            format!("{:.2}x", per_call / reused.max(1e-12)),
        ]);
    }

    println!("# Table I — measured scaling exponents vs theory");
    let mut table = Table::new(["series", "fit slope", "theory", "verdict"]);
    let rows: Vec<(&str, f64, f64, f64)> = vec![
        ("LFA vs n", slope(&lfa_pts), 2.0, 0.5),
        ("FFT vs n", slope(&fft_pts), 2.0, 0.7), // +log factor pushes it up
        ("explicit vs n", slope(&exp_pts), 6.0, 1.6),
        ("LFA vs c", slope(&lfa_c), 3.0, 0.8),
    ];
    for (name, got, want, tol) in rows {
        let ok = (got - want).abs() <= tol || (name.contains("FFT") && got >= want - tol);
        table.row([
            name.to_string(),
            format!("{got:.2}"),
            format!("{want:.1}"),
            if ok { "ok".into() } else { format!("OFF by {:.2}", got - want) },
        ]);
    }
    print!("{}", table.render());

    println!("\n# SpectralPlan — plan-once/execute-many vs plan-per-call");
    let mut ptable = Table::new(["shape", "plan-per-call", "plan-reuse", "speedup"]);
    for row in plan_rows {
        ptable.row(row);
    }
    print!("{}", ptable.render());

    println!(
        "notes: explicit slope < 6 at tiny n (LAPACK-style constants dominate);\n\
         LFA-vs-c < 3 until c is large enough for the O(c³) SVD to dominate the\n\
         O(c²k²) transform. Trends, not exact asymptotics, at these sizes."
    );
}
