//! Fig. 7a regenerator: runtime of explicit vs FFT vs LFA over input size,
//! c = 16, k = 3. Log–log series; the observable shape: explicit blows up
//! and hits its wall early, FFT is fastest for tiny n, LFA overtakes from
//! n ≈ 16 and stays ahead.
//!
//! Paper sweep: n ∈ {4..16384}, explicit up to 64, on a 16-core Xeon.
//! Default here: n ∈ {4..128}, explicit up to 16 (single-core CI box);
//! `--full` extends to n = 256 and explicit n = 32.

use conv_svd_lfa::baselines::{explicit_svd, fft_svd, FftLayoutPolicy};
use conv_svd_lfa::bench_util::bench_args;
use conv_svd_lfa::conv::{Boundary, ConvKernel};
use conv_svd_lfa::engine::resolve_threads;
use conv_svd_lfa::lfa::{self, LfaOptions};
use conv_svd_lfa::numeric::Pcg64;
use conv_svd_lfa::report::{commas, secs, Table};

fn main() {
    let (bench, full) = bench_args();
    let c = 16;
    let ns: Vec<usize> = if full { vec![4, 8, 16, 32, 64, 128, 256] } else { vec![4, 8, 16, 32, 64, 128] };
    // n=16,c=16 explicit = 4096² dense SVD ≈ 80 s/run on this box.
    let explicit_cap = if full { 16 } else { 8 };

    let mut rng = Pcg64::seeded(700);
    let kernel = ConvKernel::random_he(c, c, 3, 3, &mut rng);
    let threads = resolve_threads(0);

    println!("# Fig. 7a — runtime vs input size (c = {c}, k = 3, {threads} thread(s))");
    let mut table = Table::new(["n", "#σ", "explicit", "FFT", "LFA", "FFT/LFA"]);
    let mut csv = Table::new(["n", "values", "explicit_s", "fft_s", "lfa_s"]);

    for &n in &ns {
        let lfa_m = bench.measure("lfa", || {
            lfa::singular_values(&kernel, n, n, LfaOptions { threads, ..Default::default() })
        });
        let fft_m = bench.measure("fft", || {
            fft_svd::singular_values(&kernel, n, n, FftLayoutPolicy::Natural, threads)
        });
        let explicit = if n <= explicit_cap {
            Some(bench.measure("explicit", || {
                explicit_svd::singular_values(&kernel, n, n, Boundary::Periodic)
            }))
        } else {
            None
        };
        let nvals = n * n * c;
        let ratio = fft_m.median().as_secs_f64() / lfa_m.median().as_secs_f64();
        table.row([
            n.to_string(),
            commas(nvals as u128),
            explicit
                .as_ref()
                .map(|e| secs(e.median()))
                .unwrap_or_else(|| "— (wall)".into()),
            secs(fft_m.median()),
            secs(lfa_m.median()),
            format!("{ratio:.2}"),
        ]);
        csv.row([
            n.to_string(),
            nvals.to_string(),
            explicit
                .as_ref()
                .map(|e| format!("{:.6}", e.median().as_secs_f64()))
                .unwrap_or_default(),
            format!("{:.6}", fft_m.median().as_secs_f64()),
            format!("{:.6}", lfa_m.median().as_secs_f64()),
        ]);
    }
    print!("{}", table.render());
    match csv.save_csv("fig7a_runtime") {
        Ok(p) => println!("CSV: {}", p.display()),
        Err(e) => eprintln!("csv write failed: {e}"),
    }
    println!(
        "expected shape: explicit superlinear blow-up (O(n⁶)); FFT fastest for\n\
         n ≤ 8; LFA ahead for n ≥ 16 with the gap widening (paper §IV-b)"
    );
}
