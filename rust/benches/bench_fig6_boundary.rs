//! Fig. 6 regenerator: effect of boundary conditions (Dirichlet vs
//! periodic) on the singular-value distribution, for increasing input
//! sizes with c = 16 fixed.
//!
//! The paper plots the sorted spectra of 3 random weight tensors at
//! n ∈ {4, 8, 32}; the observable is that the periodic (LFA) spectrum
//! converges to the zero-padded (explicit) one as n grows. We print the
//! spectral series quantiles + the divergence metric per n, and write the
//! full series to CSV for plotting.

use conv_svd_lfa::baselines::explicit_svd;
use conv_svd_lfa::conv::{Boundary, ConvKernel};
use conv_svd_lfa::lfa::{self, LfaOptions, Spectrum};
use conv_svd_lfa::numeric::Pcg64;
use conv_svd_lfa::report::Table;

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let c = 16;
    // Explicit (Dirichlet) SVD is the cost ceiling here: n=32·c=16 means a
    // 16,384² dense matrix — include it only with --full. Default matches
    // the paper's n ∈ {4, 8} + a reduced-c n=32 point.
    // Explicit-SVD cost gates the sizes: (16,16) is a 4096² dense SVD
    // (~80 s/tensor on this box), so it is --full only; the default keeps
    // the paper's n ∈ {4,8} panels at c=16 and adds a reduced-c n=16 point.
    let cases: Vec<(usize, usize)> = if full {
        vec![(4, c), (8, c), (16, c), (32, 4)]
    } else {
        vec![(4, c), (8, c), (16, 8)]
    };

    println!("# Fig. 6 — boundary-condition effect on the spectrum (c varies per row)");
    let mut table = Table::new([
        "n", "c", "#σ", "divergence", "σmax per.", "σmax Dir.", "median per.", "median Dir.",
    ]);
    let mut csv = Table::new(["tensor", "n", "c", "idx", "periodic", "dirichlet"]);

    for &(n, c) in &cases {
        // Three random tensors, like the paper's three panels-worth.
        let mut divs = Vec::new();
        for tensor in 0..3u64 {
            let mut rng = Pcg64::seeded(600 + tensor);
            let k = ConvKernel::random_he(c, c, 3, 3, &mut rng);
            let periodic = lfa::singular_values(&k, n, n, LfaOptions::default()).sorted_desc();
            let dirichlet = explicit_svd::singular_values(&k, n, n, Boundary::Dirichlet).values;
            let div = Spectrum::divergence(&periodic, &dirichlet);
            divs.push(div);
            // Sampled series for the plot (64 quantile points).
            let len = periodic.len().max(dirichlet.len());
            let points = 64.min(len);
            for s in 0..points {
                let q = s as f64 / (points - 1).max(1) as f64;
                let pi = ((periodic.len() - 1) as f64 * q) as usize;
                let di = ((dirichlet.len() - 1) as f64 * q) as usize;
                csv.row([
                    tensor.to_string(),
                    n.to_string(),
                    c.to_string(),
                    s.to_string(),
                    format!("{:.6}", periodic[pi]),
                    format!("{:.6}", dirichlet[di]),
                ]);
            }
            if tensor == 0 {
                let med = |xs: &[f64]| xs[xs.len() / 2];
                table.row([
                    n.to_string(),
                    c.to_string(),
                    periodic.len().to_string(),
                    format!("{div:.4}"),
                    format!("{:.4}", periodic[0]),
                    format!("{:.4}", dirichlet[0]),
                    format!("{:.4}", med(&periodic)),
                    format!("{:.4}", med(&dirichlet)),
                ]);
            }
        }
        let mean = divs.iter().sum::<f64>() / divs.len() as f64;
        println!("n={n:<3} c={c:<3} mean divergence over 3 tensors: {mean:.4}");
    }
    print!("{}", table.render());
    match csv.save_csv("fig6_boundary") {
        Ok(p) => println!("series CSV: {}", p.display()),
        Err(e) => eprintln!("csv write failed: {e}"),
    }
    println!("expected shape: divergence shrinks monotonically with n (boundary has\nvanishing influence for growing lattice sizes — paper §IV-a)");
}
