//! Fig. 7b + Table II regenerator: LFA vs FFT runtime for large n, and the
//! s_FFT/s_LFA speed-up ratio per n.
//!
//! Paper: n = 2⁸..2¹⁴ (up to 4.3G singular values, hours of runtime on a
//! 16-core Xeon). Default here: n = 2⁵..2⁸ single-core; `--full` extends
//! to 2⁹ (≈4.2M values). The observable: the ratio starts near ~1 and
//! grows with n as the FFT's log n factor bites.

use conv_svd_lfa::baselines::{fft_svd, FftLayoutPolicy};
use conv_svd_lfa::bench_util::bench_args;
use conv_svd_lfa::conv::ConvKernel;
use conv_svd_lfa::engine::resolve_threads;
use conv_svd_lfa::lfa::{self, LfaOptions};
use conv_svd_lfa::numeric::Pcg64;
use conv_svd_lfa::report::{commas, secs, Table};

fn main() {
    let (bench, full) = bench_args();
    let c = 16;
    let ns: Vec<usize> = if full { vec![32, 64, 128, 256, 512] } else { vec![32, 64, 128, 256] };
    let mut rng = Pcg64::seeded(701);
    let kernel = ConvKernel::random_he(c, c, 3, 3, &mut rng);
    let threads = resolve_threads(0);

    println!("# Fig. 7b / Table II — LFA vs FFT at scale (c = {c}, {threads} thread(s))");
    let mut table = Table::new(["n", "no. of SVs", "s_FFT", "s_LFA", "s_FFT/s_LFA"]);
    let mut csv = Table::new(["n", "values", "fft_s", "lfa_s", "ratio"]);
    for &n in &ns {
        let lfa_m = bench.measure("lfa", || {
            lfa::singular_values(&kernel, n, n, LfaOptions { threads, ..Default::default() })
        });
        let fft_m = bench.measure("fft", || {
            fft_svd::singular_values(&kernel, n, n, FftLayoutPolicy::Natural, threads)
        });
        let ratio = fft_m.median().as_secs_f64() / lfa_m.median().as_secs_f64();
        table.row([
            n.to_string(),
            commas((n * n * c) as u128),
            secs(fft_m.median()),
            secs(lfa_m.median()),
            format!("{ratio:.2}"),
        ]);
        csv.row([
            n.to_string(),
            (n * n * c).to_string(),
            format!("{:.6}", fft_m.median().as_secs_f64()),
            format!("{:.6}", lfa_m.median().as_secs_f64()),
            format!("{ratio:.4}"),
        ]);
    }
    print!("{}", table.render());
    match csv.save_csv("fig7b_table2") {
        Ok(p) => println!("CSV: {}", p.display()),
        Err(e) => eprintln!("csv write failed: {e}"),
    }
    println!(
        "paper Table II (16-core Xeon): ratio 1.09 @ n=256 rising to 1.44 @ n=16384.\n\
         expected shape here: ratio ≥ ~1 and non-decreasing with n."
    );
}
