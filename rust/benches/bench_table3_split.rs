//! Table III regenerator: total runtime split into transform time `s_F`
//! and SVD time `s_SVD` for the FFT and LFA routes.
//!
//! Paper observation: `s_F` is dramatically smaller for LFA (O(1) vs
//! O(log n) per frequency *and* better constants), and `s_SVD` is also
//! smaller because LFA's output layout is block-contiguous.

use conv_svd_lfa::baselines::{fft_svd, FftLayoutPolicy};
use conv_svd_lfa::bench_util::bench_args;
use conv_svd_lfa::conv::ConvKernel;
use conv_svd_lfa::engine::resolve_threads;
use conv_svd_lfa::lfa::{self, LfaOptions};
use conv_svd_lfa::numeric::Pcg64;
use conv_svd_lfa::report::{commas, secs, Table};

fn main() {
    let (bench, full) = bench_args();
    let c = 16;
    let ns: Vec<usize> = if full { vec![64, 128, 256, 512] } else { vec![64, 128, 256] };
    let mut rng = Pcg64::seeded(702);
    let kernel = ConvKernel::random_he(c, c, 3, 3, &mut rng);
    let threads = resolve_threads(0);

    println!("# Table III — s_F vs s_SVD split (c = {c}, {threads} thread(s))");
    let mut table = Table::new(["n", "no. of SVs", "method", "s_F", "s_SVD", "s_total"]);
    let mut csv = Table::new(["n", "method", "transform_s", "svd_s", "total_s"]);
    for &n in &ns {
        // Median-of-samples for each stage: rerun the timed pipelines.
        let fft = bench.measure("fft", || {
            fft_svd::singular_values_timed(&kernel, n, n, FftLayoutPolicy::Natural, threads).1
        });
        let lfa_t = bench.measure("lfa", || {
            lfa::singular_values_timed(
                &kernel,
                n,
                n,
                LfaOptions { threads, ..Default::default() },
            )
            .1
        });
        // The measurement samples are StageTimings; take the last run's split
        // (representative) but the median total.
        let fft_last = fft_svd::singular_values_timed(&kernel, n, n, FftLayoutPolicy::Natural, threads).1;
        let lfa_last =
            lfa::singular_values_timed(&kernel, n, n, LfaOptions { threads, ..Default::default() }).1;
        for (name, split, total_med) in [
            ("FFT", fft_last, fft.median()),
            ("LFA", lfa_last, lfa_t.median()),
        ] {
            table.row([
                n.to_string(),
                commas((n * n * c) as u128),
                name.to_string(),
                secs(split.transform),
                secs(split.svd),
                secs(total_med),
            ]);
            csv.row([
                n.to_string(),
                name.to_string(),
                format!("{:.6}", split.transform.as_secs_f64()),
                format!("{:.6}", split.svd.as_secs_f64()),
                format!("{:.6}", total_med.as_secs_f64()),
            ]);
        }
    }
    print!("{}", table.render());
    match csv.save_csv("table3_split") {
        Ok(p) => println!("CSV: {}", p.display()),
        Err(e) => eprintln!("csv write failed: {e}"),
    }
    println!(
        "expected shape: s_F(LFA) ≪ s_F(FFT) (paper: 82s vs 318s at n=8192);\n\
         s_SVD comparable-or-better for LFA thanks to the contiguous layout."
    );
}
