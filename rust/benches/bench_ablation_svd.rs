//! Ablation (not in the paper): choice of per-block SVD solver.
//!
//!   one-sided Jacobi on A_k      (our default)
//!   Hermitian Jacobi on A_kᴴA_k  (the Gram route the PJRT artifact uses)
//!   Golub–Kahan on realified A_k (what a LAPACK-style dense SVD would do)
//!
//! Also reports accuracy vs float64 Jacobi ground truth, justifying the
//! DESIGN.md default.

use conv_svd_lfa::bench_util::bench_args;
use conv_svd_lfa::conv::ConvKernel;
use conv_svd_lfa::lfa::{self, BlockSolver, LfaOptions};
use conv_svd_lfa::linalg::gk_svd;
use conv_svd_lfa::numeric::{Mat, Pcg64};
use conv_svd_lfa::report::{secs, Table};

fn main() {
    let (bench, full) = bench_args();
    let n = 64;
    let cs: Vec<usize> = if full { vec![4, 8, 16, 32] } else { vec![4, 8, 16] };

    println!("# Ablation — per-block SVD solver (n = {n}, values only)");
    let mut table = Table::new(["c", "jacobi", "gram-eigen", "gk(real-embed)", "gram vs jacobi max|Δσ|"]);
    for &c in &cs {
        let mut rng = Pcg64::seeded(800 + c as u64);
        let kernel = ConvKernel::random_he(c, c, 3, 3, &mut rng);
        let jac = bench.measure("jacobi", || {
            lfa::singular_values(
                &kernel,
                n,
                n,
                LfaOptions { solver: BlockSolver::Jacobi, threads: 1, ..Default::default() },
            )
        });
        let gram = bench.measure("gram", || {
            lfa::singular_values(
                &kernel,
                n,
                n,
                LfaOptions { solver: BlockSolver::GramEigen, threads: 1, ..Default::default() },
            )
        });
        // GK on the realified blocks: embed C^{c×c} into R^{2c×2c}
        // ([re -im; im re]) whose singular values are ours, doubled.
        let grid = lfa::compute_symbols(&kernel, n, n, lfa::BlockLayout::BlockContiguous);
        let mut tie_rng = Pcg64::seeded(4242);
        let gk = bench.measure("gk", || {
            let mut out = Vec::with_capacity(n * n * c);
            for f in 0..grid.freqs() {
                let b = grid.block(f);
                let mut real = Mat::zeros(2 * c, 2 * c);
                for i in 0..c {
                    for j in 0..c {
                        let z = b[(i, j)];
                        real[(i, j)] = z.re;
                        real[(i, j + c)] = -z.im;
                        real[(i + c, j)] = z.im;
                        real[(i + c, j + c)] = z.re;
                    }
                }
                // The embedding doubles every σ exactly; Golub–Reinsch can
                // stall on the exact tie. Break it at the 1e-13 level
                // (below reporting precision — this row measures *time*).
                for v in real.data.iter_mut() {
                    *v += 1e-13 * tie_rng.normal();
                }
                let s = gk_svd::singular_values(&real);
                // Each σ appears twice in the embedding; take every other.
                out.extend(s.into_iter().step_by(2).take(c));
            }
            out
        });
        let s_j = lfa::singular_values(
            &kernel,
            n,
            n,
            LfaOptions { solver: BlockSolver::Jacobi, threads: 1, ..Default::default() },
        );
        let s_g = lfa::singular_values(
            &kernel,
            n,
            n,
            LfaOptions { solver: BlockSolver::GramEigen, threads: 1, ..Default::default() },
        );
        let gap = s_j
            .values
            .iter()
            .zip(&s_g.values)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max);
        table.row([
            c.to_string(),
            secs(jac.median()),
            secs(gram.median()),
            secs(gk.median()),
            format!("{gap:.1e}"),
        ]);
    }
    print!("{}", table.render());
    println!(
        "expected: Jacobi fastest & most accurate (no condition-squaring, no\n\
         2x real embedding); Gram competitive; GK pays the 8x real-embed cost."
    );
}
