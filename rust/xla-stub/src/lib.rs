//! Offline **API stub** for the `xla` crate (the PJRT bindings of
//! LaurentMazare's `xla-rs`), covering exactly the surface
//! `conv-svd-lfa`'s `runtime::pjrt` module uses.
//!
//! The offline image does not ship the real crate, but the `pjrt`-gated
//! code must not rot unchecked — CI runs
//! `cargo check --all-targets --features pjrt` against this stub so every
//! signature the runtime calls keeps typechecking. At runtime every entry
//! point fails fast with a clear message ([`PjRtClient::cpu`] is the sole
//! constructor and always errors), which lands in the coordinator's
//! documented "PJRT unavailable → native only" fallback path.
//!
//! To execute real artifacts, point the `xla` path dependency in
//! `rust/Cargo.toml` at the real crate instead of this stub; no source
//! changes are needed.

use std::fmt;

/// Error type mirroring `xla::Error` far enough for `{e:?}` formatting.
pub struct Error {
    msg: String,
}

impl Error {
    fn stub() -> Self {
        Self {
            msg: "xla stub: the offline image has no PJRT runtime; point the `xla` \
                  path dependency at the real crate to execute artifacts"
                .to_string(),
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

/// Host-side literal (dense tensor) handle.
pub struct Literal {
    _private: (),
}

impl Literal {
    /// 1-D f32 literal.
    pub fn vec1(_values: &[f32]) -> Literal {
        Literal { _private: () }
    }

    /// Scalar i32 literal.
    pub fn scalar(_value: i32) -> Literal {
        Literal { _private: () }
    }

    /// Reshape to `dims`.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Err(Error::stub())
    }

    /// Unwrap a 1-tuple result.
    pub fn to_tuple1(&self) -> Result<Literal, Error> {
        Err(Error::stub())
    }

    /// Copy out as a typed host vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(Error::stub())
    }
}

/// Parsed HLO module.
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    /// Parse an HLO text file.
    pub fn from_text_file<P: AsRef<std::path::Path>>(_path: P) -> Result<HloModuleProto, Error> {
        Err(Error::stub())
    }
}

/// An XLA computation ready for compilation.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Device-side buffer returned by an execution.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    /// Fetch the buffer back to the host.
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(Error::stub())
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with the given argument literals.
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(Error::stub())
    }
}

/// PJRT client handle. The stub's only constructor fails, so no other
/// method is reachable at runtime — they exist to keep callers typechecked.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Always fails in the stub: there is no PJRT runtime offline.
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(Error::stub())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(Error::stub())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_fails_fast() {
        let err = PjRtClient::cpu().unwrap_err();
        let msg = format!("{err:?}");
        assert!(msg.contains("xla stub"), "{msg}");
    }

    #[test]
    fn literal_surface_typechecks() {
        let w = Literal::vec1(&[1.0f32, 2.0]);
        assert!(w.reshape(&[2]).is_err());
        let s = Literal::scalar(3);
        assert!(s.to_tuple1().is_err());
        assert!(s.to_vec::<f32>().is_err());
        assert!(HloModuleProto::from_text_file("nope.hlo").is_err());
    }
}
