//! Convolutional weight tensors.
//!
//! Layout is channel-first **OIHW** (`[c_out, c_in, k_h, k_w]`), matching the
//! PyTorch tensors the paper operates on. A kernel also carries its *anchor*
//! (the tap that sits on the output pixel), so that the displacement set
//! `N = {y = (r,c) − anchor}` of the multiplication operators `M_y` in
//! `(A∗f)(x) = Σ_y M_y f(x+y)` is explicit. Cross-correlation convention
//! (what deep-learning frameworks call "convolution").
//!
//! ## Structured convolutions
//!
//! Beyond the dense case, a kernel can describe a *structured* convolution
//! (see `docs/WORKLOADS.md` for the full supported matrix):
//!
//! - **Grouped** (`groups = g > 1`): the channel mixing is block-diagonal —
//!   input group `gi` only reaches output group `gi`. As in PyTorch, the
//!   stored `c_in` is the **per-group** input width, so the operator acts on
//!   [`c_in_total()`](ConvKernel::c_in_total)` = c_in·groups` input channels
//!   and `data` holds `c_out·c_in·kh·kw` weights. `groups == c_out ==
//!   c_in_total` is depthwise.
//! - **Dilated** (`dilation = d > 1`): tap `(r,c)` sits at displacement
//!   `d·(r−ar, c−ac)`; the support spreads but the tap count (and therefore
//!   the symbol cost) is unchanged.
//! - **Transposed** (`transposed = true`): the kernel is interpreted as the
//!   *adjoint* mapping `Aᵀ` (`c_out → c_in_total` channels, e.g. a decoder /
//!   up-convolution). Singular values are those of the forward map; singular
//!   vector roles swap.

use crate::numeric::{Mat, Pcg64};

/// A convolution kernel in OIHW layout, optionally grouped / dilated /
/// transposed (see the [module docs](self) for the structure semantics).
#[derive(Clone, Debug)]
pub struct ConvKernel {
    pub c_out: usize,
    /// Per-group input channel count (PyTorch grouped layout). The operator's
    /// total input width is [`c_in_total()`](ConvKernel::c_in_total).
    pub c_in: usize,
    pub kh: usize,
    pub kw: usize,
    /// Anchor tap (row, col). For odd kernels this is the center.
    pub anchor: (usize, usize),
    /// Channel groups `g ≥ 1`; `c_out` must be divisible by `g`. Dense = 1.
    pub groups: usize,
    /// Tap spacing `d ≥ 1` in pixels. Dense = 1.
    pub dilation: usize,
    /// Interpret the kernel as the adjoint operator `Aᵀ`.
    pub transposed: bool,
    /// OIHW data: `data[((o·c_in + i)·kh + r)·kw + c]`.
    pub data: Vec<f64>,
}

impl ConvKernel {
    /// Zero-initialized kernel with centered anchor (dense: `groups = 1`,
    /// `dilation = 1`, not transposed).
    pub fn zeros(c_out: usize, c_in: usize, kh: usize, kw: usize) -> Self {
        Self {
            c_out,
            c_in,
            kh,
            kw,
            anchor: (kh / 2, kw / 2),
            groups: 1,
            dilation: 1,
            transposed: false,
            data: vec![0.0; c_out * c_in * kh * kw],
        }
    }

    /// Split the channel mixing into `groups` independent blocks. The stored
    /// `c_in` is reinterpreted as the per-group input width (the operator
    /// then has `c_in · groups` total input channels), matching how grouped
    /// weight tensors are laid out in PyTorch.
    ///
    /// Panics unless `groups ≥ 1` and `c_out % groups == 0`.
    pub fn with_groups(mut self, groups: usize) -> Self {
        assert!(groups >= 1, "groups must be >= 1, got {groups}");
        assert!(
            self.c_out % groups == 0,
            "c_out {} not divisible by groups {}",
            self.c_out,
            groups
        );
        self.groups = groups;
        self
    }

    /// Space taps `dilation` pixels apart. Panics unless `dilation ≥ 1`.
    pub fn with_dilation(mut self, dilation: usize) -> Self {
        assert!(dilation >= 1, "dilation must be >= 1, got {dilation}");
        self.dilation = dilation;
        self
    }

    /// Mark the kernel as describing the adjoint (transposed) operator.
    pub fn with_transposed(mut self, transposed: bool) -> Self {
        self.transposed = transposed;
        self
    }

    /// Total input channel count of the operator: `c_in · groups`.
    #[inline(always)]
    pub fn c_in_total(&self) -> usize {
        self.c_in * self.groups
    }

    /// Output channels per group: `c_out / groups`.
    #[inline(always)]
    pub fn group_c_out(&self) -> usize {
        self.c_out / self.groups
    }

    /// `true` when this is a plain dense forward convolution — the case the
    /// unstructured fast paths (tap extraction, dense symbol grids, AOT
    /// artifact matching) are specialized for.
    #[inline(always)]
    pub fn is_dense(&self) -> bool {
        self.groups == 1 && self.dilation == 1 && !self.transposed
    }

    /// Number of non-finite (NaN/Inf) weights — the plan/submit-time
    /// screen of the numerical-health layer. A diverging training loop
    /// poisons every symbol and therefore every singular value, so kernels
    /// with a nonzero count are rejected with a typed
    /// `Error::NonFiniteWeights` before any frequency is solved.
    pub fn non_finite_count(&self) -> usize {
        self.data.iter().filter(|v| !v.is_finite()).count()
    }

    /// He/Kaiming-normal initialization — std `√(2 / (c_in·kh·kw))`,
    /// the standard for ReLU CNNs and what the paper's "random weight
    /// tensors" look like in practice.
    pub fn random_he(c_out: usize, c_in: usize, kh: usize, kw: usize, rng: &mut Pcg64) -> Self {
        let std = (2.0 / (c_in * kh * kw) as f64).sqrt();
        let mut k = Self::zeros(c_out, c_in, kh, kw);
        for v in k.data.iter_mut() {
            *v = rng.normal_with(0.0, std);
        }
        k
    }

    /// Glorot/Xavier-uniform initialization.
    pub fn random_glorot(c_out: usize, c_in: usize, kh: usize, kw: usize, rng: &mut Pcg64) -> Self {
        let fan_in = (c_in * kh * kw) as f64;
        let fan_out = (c_out * kh * kw) as f64;
        let bound = (6.0 / (fan_in + fan_out)).sqrt();
        let mut k = Self::zeros(c_out, c_in, kh, kw);
        for v in k.data.iter_mut() {
            *v = rng.uniform_in(-bound, bound);
        }
        k
    }

    #[inline(always)]
    pub fn idx(&self, o: usize, i: usize, r: usize, c: usize) -> usize {
        debug_assert!(o < self.c_out && i < self.c_in && r < self.kh && c < self.kw);
        ((o * self.c_in + i) * self.kh + r) * self.kw + c
    }

    #[inline(always)]
    pub fn get(&self, o: usize, i: usize, r: usize, c: usize) -> f64 {
        self.data[self.idx(o, i, r, c)]
    }

    #[inline(always)]
    pub fn set(&mut self, o: usize, i: usize, r: usize, c: usize, v: f64) {
        let idx = self.idx(o, i, r, c);
        self.data[idx] = v;
    }

    /// Displacements `y = (dy, dx)` of every tap relative to the anchor,
    /// in row-major tap order. Dilation scales every displacement by `d`
    /// (the tap grid spreads; the tap *count* is unchanged).
    pub fn displacements(&self) -> Vec<(isize, isize)> {
        let (ar, ac) = (self.anchor.0 as isize, self.anchor.1 as isize);
        let d = self.dilation as isize;
        let mut ys = Vec::with_capacity(self.kh * self.kw);
        for r in 0..self.kh as isize {
            for c in 0..self.kw as isize {
                ys.push((d * (r - ar), d * (c - ac)));
            }
        }
        ys
    }

    /// The Yoshida–Miyato reshape: `c_out × (c_in·kh·kw)` dense matrix whose
    /// largest singular value is the (loose) spectral-norm proxy of §II-b.
    pub fn reshaped_matrix(&self) -> Mat {
        let cols = self.c_in * self.kh * self.kw;
        let mut m = Mat::zeros(self.c_out, cols);
        for o in 0..self.c_out {
            for i in 0..self.c_in {
                for r in 0..self.kh {
                    for c in 0..self.kw {
                        m[(o, (i * self.kh + r) * self.kw + c)] = self.get(o, i, r, c);
                    }
                }
            }
        }
        m
    }

    /// Total number of parameters.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Frobenius norm of the weight tensor.
    pub fn frobenius_norm(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    /// Flip spatially and swap in/out channels: the kernel of the transposed
    /// operator `Aᵀ` (used by power iteration and the pseudo-inverse checks).
    ///
    /// Structure-aware: groups transpose per block (output group `gi` of `Aᵀ`
    /// is the transpose of block `gi` of `A`), dilation carries over
    /// unchanged, and the `transposed` flag is preserved as-is (this builds
    /// an *explicit* transpose rather than toggling the interpretation bit).
    pub fn transpose_kernel(&self) -> ConvKernel {
        let g = self.groups;
        let gr = self.group_c_out();
        // Aᵀ maps c_out → c_in_total, so its stored shape is
        // [c_in·g, c_out/g, kh, kw] with the same group count.
        let mut t = ConvKernel::zeros(self.c_in * g, gr, self.kh, self.kw)
            .with_groups(g)
            .with_dilation(self.dilation)
            .with_transposed(self.transposed);
        // Aᵀ has taps W'[i,o,r',c'] = W[o,i,kh−1−r', kw−1−c'] with anchor
        // mirrored so that displacements negate.
        t.anchor = (self.kh - 1 - self.anchor.0, self.kw - 1 - self.anchor.1);
        for gi in 0..g {
            for o in 0..gr {
                for i in 0..self.c_in {
                    for r in 0..self.kh {
                        for c in 0..self.kw {
                            t.set(
                                gi * self.c_in + i,
                                o,
                                self.kh - 1 - r,
                                self.kw - 1 - c,
                                self.get(gi * gr + o, i, r, c),
                            );
                        }
                    }
                }
            }
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_roundtrip() {
        let mut k = ConvKernel::zeros(2, 3, 3, 3);
        k.set(1, 2, 0, 2, 7.5);
        assert_eq!(k.get(1, 2, 0, 2), 7.5);
        assert_eq!(k.data.iter().filter(|&&v| v != 0.0).count(), 1);
    }

    #[test]
    fn displacements_centered_3x3() {
        let k = ConvKernel::zeros(1, 1, 3, 3);
        let ys = k.displacements();
        assert_eq!(ys.len(), 9);
        assert_eq!(ys[0], (-1, -1));
        assert_eq!(ys[4], (0, 0));
        assert_eq!(ys[8], (1, 1));
    }

    #[test]
    fn he_init_statistics() {
        let mut rng = Pcg64::seeded(71);
        let k = ConvKernel::random_he(32, 32, 3, 3, &mut rng);
        let n = k.data.len() as f64;
        let mean = k.data.iter().sum::<f64>() / n;
        let var = k.data.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / n;
        let want = 2.0 / (32.0 * 9.0);
        assert!(mean.abs() < 0.002, "mean {mean}");
        assert!((var - want).abs() / want < 0.1, "var {var} want {want}");
    }

    #[test]
    fn reshaped_matrix_shape() {
        let mut rng = Pcg64::seeded(72);
        let k = ConvKernel::random_he(4, 5, 3, 3, &mut rng);
        let m = k.reshaped_matrix();
        assert_eq!((m.rows, m.cols), (4, 45));
        assert!((m.frobenius_norm() - k.frobenius_norm()).abs() < 1e-12);
    }

    #[test]
    fn transpose_displacements_negate() {
        let k = ConvKernel::zeros(2, 3, 3, 5);
        let t = k.transpose_kernel();
        let mut ys = k.displacements();
        let mut yts: Vec<(isize, isize)> = t.displacements().iter().map(|&(a, b)| (-a, -b)).collect();
        ys.sort_unstable();
        yts.sort_unstable();
        assert_eq!(ys, yts);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Pcg64::seeded(73);
        let k = ConvKernel::random_he(3, 4, 3, 3, &mut rng);
        let tt = k.transpose_kernel().transpose_kernel();
        assert_eq!(tt.c_out, k.c_out);
        assert_eq!(tt.data, k.data);
        assert_eq!(tt.anchor, k.anchor);
    }

    #[test]
    fn structured_accessors() {
        let k = ConvKernel::zeros(8, 2, 3, 3).with_groups(4).with_dilation(2);
        assert_eq!(k.c_in_total(), 8);
        assert_eq!(k.group_c_out(), 2);
        assert!(!k.is_dense());
        assert!(ConvKernel::zeros(4, 4, 3, 3).is_dense());
        // depthwise: one channel per group
        let dw = ConvKernel::zeros(6, 1, 3, 3).with_groups(6);
        assert_eq!(dw.c_in_total(), 6);
        assert_eq!(dw.group_c_out(), 1);
        assert_eq!(dw.len(), 6 * 9);
    }

    #[test]
    fn dilated_displacements_scale() {
        let k = ConvKernel::zeros(1, 1, 3, 3).with_dilation(3);
        let ys = k.displacements();
        assert_eq!(ys[0], (-3, -3));
        assert_eq!(ys[4], (0, 0));
        assert_eq!(ys[8], (3, 3));
    }

    #[test]
    fn grouped_transpose_involution() {
        let mut rng = Pcg64::seeded(74);
        let mut k = ConvKernel::random_he(6, 2, 3, 3, &mut rng)
            .with_groups(3)
            .with_dilation(2);
        k.anchor = (0, 1);
        let t = k.transpose_kernel();
        assert_eq!((t.c_out, t.c_in, t.groups, t.dilation), (6, 2, 3, 2));
        let tt = t.transpose_kernel();
        assert_eq!(tt.data, k.data);
        assert_eq!(tt.anchor, k.anchor);
        // block gi of Aᵀ is the transpose of block gi of A
        assert_eq!(t.get(2, 1, 2, 2), k.get(3, 0, 0, 0));
    }

    #[test]
    #[should_panic(expected = "not divisible")]
    fn groups_must_divide_c_out() {
        let _ = ConvKernel::zeros(6, 2, 3, 3).with_groups(4);
    }
}
