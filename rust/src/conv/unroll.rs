//! Explicit (unrolled) matrix representation of a convolutional mapping —
//! the doubly-circulant structure of the paper's Fig. 1a.
//!
//! This is the substrate of the *naive baseline*: build the
//! `(h·w·c_out) × (h·w·c_in)` matrix and feed it to the dense SVD. It is also
//! the ground truth that the LFA and FFT routes are validated against, and —
//! with Dirichlet boundary conditions — the reference spectrum for the
//! boundary-condition study (Fig. 6).

use super::apply::Boundary;
use super::kernel::ConvKernel;
use crate::numeric::Mat;

/// Dense unrolled matrix of the convolution over an `h×w` grid.
///
/// Row index: `(x_row·w + x_col)·c_out + o`; column index:
/// `(x'_row·w + x'_col)·c_in_total + i_total` — identical ordering to
/// [`crate::conv::ConvOp::forward`] on flat vectors.
///
/// Structure-aware: grouped kernels only populate each output channel's
/// own group of input columns (block-diagonal channel coupling), dilated
/// kernels place taps at `dilation`-spaced displacements. This is the
/// ground-truth matrix the structured spectral paths are validated
/// against; the transposed-conv reference is this matrix's transpose.
pub fn unroll_dense(kernel: &ConvKernel, h: usize, w: usize, boundary: Boundary) -> Mat {
    let cin_total = kernel.c_in_total();
    let rows = h * w * kernel.c_out;
    let cols = h * w * cin_total;
    let mut a = Mat::zeros(rows, cols);
    let (ar, ac) = (kernel.anchor.0 as isize, kernel.anchor.1 as isize);
    let gr = kernel.group_c_out();
    let d = kernel.dilation as isize;
    for xr in 0..h as isize {
        for xc in 0..w as isize {
            for r in 0..kernel.kh as isize {
                for c in 0..kernel.kw as isize {
                    let (sr, sc) = (xr + d * (r - ar), xc + d * (c - ac));
                    let src = match boundary {
                        Boundary::Periodic => {
                            let rr = sr.rem_euclid(h as isize) as usize;
                            let cc = sc.rem_euclid(w as isize) as usize;
                            rr * w + cc
                        }
                        Boundary::Dirichlet => {
                            if sr < 0 || sr >= h as isize || sc < 0 || sc >= w as isize {
                                continue;
                            }
                            sr as usize * w + sc as usize
                        }
                    };
                    let dst = xr as usize * w + xc as usize;
                    for o in 0..kernel.c_out {
                        let col0 = src * cin_total + (o / gr) * kernel.c_in;
                        for i in 0..kernel.c_in {
                            let v = kernel.get(o, i, r as usize, c as usize);
                            if v != 0.0 {
                                a[(dst * kernel.c_out + o, col0 + i)] += v;
                            }
                        }
                    }
                }
            }
        }
    }
    a
}

/// Compressed sparse row representation of the unrolled matrix — the memory
/// footprint the "sparse with sparsity pattern according to fig. 1a" remark
/// refers to (`nnz ≤ rows · c_in · kh · kw`).
pub struct CsrMatrix {
    pub rows: usize,
    pub cols: usize,
    pub row_ptr: Vec<usize>,
    pub col_idx: Vec<usize>,
    pub values: Vec<f64>,
}

impl CsrMatrix {
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        let mut y = vec![0.0; self.rows];
        for r in 0..self.rows {
            let mut acc = 0.0;
            for p in self.row_ptr[r]..self.row_ptr[r + 1] {
                acc += self.values[p] * x[self.col_idx[p]];
            }
            y[r] = acc;
        }
        y
    }

    /// Density = nnz / (rows·cols); tiny for real CNN shapes.
    pub fn density(&self) -> f64 {
        self.nnz() as f64 / (self.rows as f64 * self.cols as f64)
    }
}

/// Sparse unrolled matrix (CSR). Same index conventions as [`unroll_dense`].
pub fn unroll_csr(kernel: &ConvKernel, h: usize, w: usize, boundary: Boundary) -> CsrMatrix {
    let cin_total = kernel.c_in_total();
    let rows = h * w * kernel.c_out;
    let cols = h * w * cin_total;
    let mut row_ptr = Vec::with_capacity(rows + 1);
    let mut col_idx = Vec::new();
    let mut values = Vec::new();
    let (ar, ac) = (kernel.anchor.0 as isize, kernel.anchor.1 as isize);
    let gr = kernel.group_c_out();
    let d = kernel.dilation as isize;
    row_ptr.push(0);
    // Scratch accumulating one row at a time (duplicate columns merged).
    let mut entries: Vec<(usize, f64)> = Vec::new();
    for xr in 0..h as isize {
        for xc in 0..w as isize {
            for o in 0..kernel.c_out {
                entries.clear();
                for r in 0..kernel.kh as isize {
                    for c in 0..kernel.kw as isize {
                        let (sr, sc) = (xr + d * (r - ar), xc + d * (c - ac));
                        let src = match boundary {
                            Boundary::Periodic => {
                                let rr = sr.rem_euclid(h as isize) as usize;
                                let cc = sc.rem_euclid(w as isize) as usize;
                                rr * w + cc
                            }
                            Boundary::Dirichlet => {
                                if sr < 0 || sr >= h as isize || sc < 0 || sc >= w as isize {
                                    continue;
                                }
                                sr as usize * w + sc as usize
                            }
                        };
                        let col0 = src * cin_total + (o / gr) * kernel.c_in;
                        for i in 0..kernel.c_in {
                            let v = kernel.get(o, i, r as usize, c as usize);
                            if v != 0.0 {
                                entries.push((col0 + i, v));
                            }
                        }
                    }
                }
                entries.sort_unstable_by_key(|e| e.0);
                let mut merged: Vec<(usize, f64)> = Vec::with_capacity(entries.len());
                for &(ci, v) in entries.iter() {
                    match merged.last_mut() {
                        Some(last) if last.0 == ci => last.1 += v,
                        _ => merged.push((ci, v)),
                    }
                }
                for (ci, v) in merged {
                    col_idx.push(ci);
                    values.push(v);
                }
                row_ptr.push(col_idx.len());
            }
        }
    }
    // Row order above is (x, o) nested the same way as unroll_dense rows.
    CsrMatrix { rows, cols, row_ptr, col_idx, values }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::ConvOp;
    use crate::linalg::power::LinOp;
    use crate::numeric::Pcg64;

    #[test]
    fn dense_matches_direct_apply() {
        let mut rng = Pcg64::seeded(90);
        let k = ConvKernel::random_he(3, 2, 3, 3, &mut rng);
        for bc in [Boundary::Periodic, Boundary::Dirichlet] {
            let op = ConvOp::new(&k, 4, 5, bc);
            let a = unroll_dense(&k, 4, 5, bc);
            let f = rng.normal_vec(op.in_dim());
            let direct = op.forward(&f);
            let via_mat = a.matvec(&f);
            for (x, y) in direct.iter().zip(&via_mat) {
                assert!((x - y).abs() < 1e-12, "{bc:?}");
            }
        }
    }

    #[test]
    fn csr_matches_dense() {
        let mut rng = Pcg64::seeded(91);
        let k = ConvKernel::random_he(2, 3, 3, 3, &mut rng);
        for bc in [Boundary::Periodic, Boundary::Dirichlet] {
            let dense = unroll_dense(&k, 5, 4, bc);
            let csr = unroll_csr(&k, 5, 4, bc);
            assert_eq!((csr.rows, csr.cols), (dense.rows, dense.cols));
            let f = rng.normal_vec(dense.cols);
            let y1 = dense.matvec(&f);
            let y2 = csr.matvec(&f);
            for (x, y) in y1.iter().zip(&y2) {
                assert!((x - y).abs() < 1e-12, "{bc:?}");
            }
        }
    }

    #[test]
    fn structured_unroll_matches_direct_apply() {
        // Grouped + dilated: the unrolled matrix, the CSR form and the
        // direct operator agree entry-for-entry under both boundaries.
        let mut rng = Pcg64::seeded(95);
        let k = ConvKernel::random_he(4, 2, 3, 3, &mut rng).with_groups(2).with_dilation(2);
        for bc in [Boundary::Periodic, Boundary::Dirichlet] {
            let op = ConvOp::new(&k, 5, 6, bc);
            let a = unroll_dense(&k, 5, 6, bc);
            assert_eq!((a.rows, a.cols), (op.out_dim(), op.in_dim()));
            let f = rng.normal_vec(op.in_dim());
            let direct = op.forward(&f);
            let via_mat = a.matvec(&f);
            for (x, y) in direct.iter().zip(&via_mat) {
                assert!((x - y).abs() < 1e-12, "{bc:?}");
            }
            let csr = unroll_csr(&k, 5, 6, bc);
            let via_csr = csr.matvec(&f);
            for (x, y) in direct.iter().zip(&via_csr) {
                assert!((x - y).abs() < 1e-12, "{bc:?}");
            }
        }
    }

    #[test]
    fn grouped_unroll_is_channel_block_diagonal() {
        // Cross-group channel couplings must be exactly zero.
        let mut rng = Pcg64::seeded(96);
        let k = ConvKernel::random_he(4, 1, 3, 3, &mut rng).with_groups(4);
        let a = unroll_dense(&k, 4, 4, Boundary::Periodic);
        for dst in 0..16 {
            for src in 0..16 {
                for o in 0..4 {
                    for i in 0..4 {
                        if o != i {
                            assert_eq!(a[(dst * 4 + o, src * 4 + i)], 0.0);
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn periodic_rows_have_equal_abs_sum() {
        // Doubly-circulant structure: every (output-channel) row of the
        // periodic unrolled matrix contains the same multiset of weights.
        let mut rng = Pcg64::seeded(92);
        let k = ConvKernel::random_he(2, 2, 3, 3, &mut rng);
        let a = unroll_dense(&k, 6, 6, Boundary::Periodic);
        let row_sum = |r: usize| -> f64 { (0..a.cols).map(|c| a[(r, c)].abs()).sum() };
        for o in 0..2 {
            let want = row_sum(o);
            for x in 0..36 {
                assert!((row_sum(x * 2 + o) - want).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn dirichlet_is_submatrix_effect() {
        // Zero padding only removes couplings: |A_dirichlet| ≤ |A_periodic|
        // entrywise (for same-sign structure it's entry subset).
        let mut rng = Pcg64::seeded(93);
        let k = ConvKernel::random_he(1, 1, 3, 3, &mut rng);
        let ap = unroll_dense(&k, 4, 4, Boundary::Periodic);
        let ad = unroll_dense(&k, 4, 4, Boundary::Dirichlet);
        for r in 0..ap.rows {
            for c in 0..ap.cols {
                let p = ap[(r, c)];
                let d = ad[(r, c)];
                assert!(d == 0.0 || (d - p).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn small_grid_wrap_accumulates() {
        // 2x2 grid with 3x3 kernel: wrapped taps collide and must sum.
        let mut k = ConvKernel::zeros(1, 1, 3, 3);
        for r in 0..3 {
            for c in 0..3 {
                k.set(0, 0, r, c, 1.0);
            }
        }
        let a = unroll_dense(&k, 2, 2, Boundary::Periodic);
        // Every entry: each of 4 inputs is hit by multiple taps summing to 9/4...
        // total sum per row must be 9 (all taps).
        for r in 0..4 {
            let s: f64 = (0..4).map(|c| a[(r, c)]).sum();
            assert!((s - 9.0).abs() < 1e-12);
        }
        let csr = unroll_csr(&k, 2, 2, Boundary::Periodic);
        assert_eq!(csr.nnz(), 16); // 4 rows × 4 distinct columns after merging
    }

    #[test]
    fn csr_density_small() {
        let mut rng = Pcg64::seeded(94);
        let k = ConvKernel::random_he(4, 4, 3, 3, &mut rng);
        let csr = unroll_csr(&k, 16, 16, Boundary::Dirichlet);
        // nnz per row ≤ c_in·kh·kw = 36 of 1024 columns (≈3.5%), shrinking
        // as 1/(h·w) for larger grids.
        assert!(csr.density() <= 36.0 / 1024.0 + 1e-12, "density {}", csr.density());
    }
}
