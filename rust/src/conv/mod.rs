//! Convolution substrate: weight tensors (OIHW), direct operator
//! application under periodic/Dirichlet boundary conditions, and explicit
//! unrolled matrices (dense + CSR) — the paper's Fig. 1a objects.

pub mod apply;
pub mod kernel;
pub mod unroll;

pub use apply::{Boundary, ConvOp};
pub use kernel::ConvKernel;
pub use unroll::{unroll_csr, unroll_dense, CsrMatrix};
