//! Direct application of a convolution as a linear operator — without ever
//! materializing the unrolled matrix.
//!
//! Feature maps are flat vectors in spatial-major, channel-minor order:
//! `f[(x_row·width + x_col)·channels + ch]`, the same order the unrolled
//! matrices of [`super::unroll`] use, so the two agree index-for-index.

use super::kernel::ConvKernel;
use crate::linalg::power::LinOp;

/// Boundary condition of the convolution.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Boundary {
    /// Periodic wrap-around — the assumption under which LFA/FFT are exact.
    Periodic,
    /// Zero padding (Dirichlet) — the CNN default the paper compares against.
    Dirichlet,
}

/// A convolution operator `A : R^{h×w×c_in} → R^{h×w×c_out}` over a fixed
/// grid with a fixed boundary condition.
///
/// Structure-aware: grouped kernels only couple an output channel to its
/// own group's input channels (input width = [`ConvKernel::c_in_total`]),
/// and dilated kernels read taps at `dilation`-spaced displacements. The
/// `transposed` audit flag is **not** consumed here — `forward` always
/// applies the forward mapping the taps define; the adjoint is
/// [`Self::transpose_apply`] (what a transposed-conv audit measures).
pub struct ConvOp<'a> {
    pub kernel: &'a ConvKernel,
    pub height: usize,
    pub width: usize,
    pub boundary: Boundary,
}

impl<'a> ConvOp<'a> {
    pub fn new(kernel: &'a ConvKernel, height: usize, width: usize, boundary: Boundary) -> Self {
        Self { kernel, height, width, boundary }
    }

    /// Apply the convolution: `out[x, o] = Σ_i Σ_y W[o,i,y] · f[x+d·y, i]`
    /// where `i` ranges over output channel `o`'s group and `d` is the
    /// dilation.
    pub fn forward(&self, f: &[f64]) -> Vec<f64> {
        let k = self.kernel;
        let (h, w) = (self.height, self.width);
        let cin_total = k.c_in_total();
        assert_eq!(f.len(), h * w * cin_total, "input length mismatch");
        let mut out = vec![0.0; h * w * k.c_out];
        let (ar, ac) = (k.anchor.0 as isize, k.anchor.1 as isize);
        let gr = k.group_c_out();
        let d = k.dilation as isize;
        for xr in 0..h as isize {
            for xc in 0..w as isize {
                for r in 0..k.kh as isize {
                    for c in 0..k.kw as isize {
                        let (sr, sc) = (xr + d * (r - ar), xc + d * (c - ac));
                        let Some(src) = self.resolve(sr, sc) else { continue };
                        let in_base = src * cin_total;
                        let out_base = (xr as usize * w + xc as usize) * k.c_out;
                        for o in 0..k.c_out {
                            let group_base = in_base + (o / gr) * k.c_in;
                            let mut acc = 0.0;
                            for i in 0..k.c_in {
                                acc += k.get(o, i, r as usize, c as usize) * f[group_base + i];
                            }
                            out[out_base + o] += acc;
                        }
                    }
                }
            }
        }
        out
    }

    /// Apply the transposed operator `Aᵀ` — the mapping a transposed-conv
    /// (`ConvKernel::transposed`) audit measures.
    pub fn transpose_apply(&self, g: &[f64]) -> Vec<f64> {
        let k = self.kernel;
        let (h, w) = (self.height, self.width);
        let cin_total = k.c_in_total();
        assert_eq!(g.len(), h * w * k.c_out, "input length mismatch");
        let mut out = vec![0.0; h * w * cin_total];
        let (ar, ac) = (k.anchor.0 as isize, k.anchor.1 as isize);
        let gr = k.group_c_out();
        let d = k.dilation as isize;
        // (Aᵀ g)[x', i] = Σ_o Σ_y W[o,i,y] g[x, o] where x' = x + d·y.
        for xr in 0..h as isize {
            for xc in 0..w as isize {
                for r in 0..k.kh as isize {
                    for c in 0..k.kw as isize {
                        let (sr, sc) = (xr + d * (r - ar), xc + d * (c - ac));
                        let Some(dst) = self.resolve(sr, sc) else { continue };
                        let g_base = (xr as usize * w + xc as usize) * k.c_out;
                        let out_base = dst * cin_total;
                        for o in 0..k.c_out {
                            let group_base = out_base + (o / gr) * k.c_in;
                            let gv = g[g_base + o];
                            for i in 0..k.c_in {
                                out[group_base + i] += k.get(o, i, r as usize, c as usize) * gv;
                            }
                        }
                    }
                }
            }
        }
        out
    }

    /// Resolve a (possibly out-of-range) spatial coordinate to a flat index.
    #[inline(always)]
    fn resolve(&self, r: isize, c: isize) -> Option<usize> {
        let (h, w) = (self.height as isize, self.width as isize);
        match self.boundary {
            Boundary::Periodic => {
                let rr = r.rem_euclid(h) as usize;
                let cc = c.rem_euclid(w) as usize;
                Some(rr * self.width + cc)
            }
            Boundary::Dirichlet => {
                if r < 0 || r >= h || c < 0 || c >= w {
                    None
                } else {
                    Some(r as usize * self.width + c as usize)
                }
            }
        }
    }
}

impl LinOp for ConvOp<'_> {
    fn in_dim(&self) -> usize {
        self.height * self.width * self.kernel.c_in_total()
    }
    fn out_dim(&self) -> usize {
        self.height * self.width * self.kernel.c_out
    }
    fn apply(&self, x: &[f64]) -> Vec<f64> {
        self.forward(x)
    }
    fn apply_t(&self, x: &[f64]) -> Vec<f64> {
        self.transpose_apply(x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numeric::Pcg64;

    #[test]
    fn identity_kernel_is_identity() {
        let mut k = ConvKernel::zeros(1, 1, 3, 3);
        k.set(0, 0, 1, 1, 1.0); // center tap
        let op = ConvOp::new(&k, 4, 5, Boundary::Periodic);
        let mut rng = Pcg64::seeded(80);
        let f = rng.normal_vec(20);
        let g = op.forward(&f);
        assert_eq!(f, g);
    }

    #[test]
    fn shift_kernel_wraps_periodically() {
        // Tap at displacement (0, +1) reads the right neighbor.
        let mut k = ConvKernel::zeros(1, 1, 3, 3);
        k.set(0, 0, 1, 2, 1.0);
        let op = ConvOp::new(&k, 1, 4, Boundary::Periodic);
        let f = vec![1.0, 2.0, 3.0, 4.0];
        let g = op.forward(&f);
        assert_eq!(g, vec![2.0, 3.0, 4.0, 1.0]);
    }

    #[test]
    fn shift_kernel_dirichlet_drops_boundary() {
        let mut k = ConvKernel::zeros(1, 1, 3, 3);
        k.set(0, 0, 1, 2, 1.0);
        let op = ConvOp::new(&k, 1, 4, Boundary::Dirichlet);
        let f = vec![1.0, 2.0, 3.0, 4.0];
        let g = op.forward(&f);
        assert_eq!(g, vec![2.0, 3.0, 4.0, 0.0]);
    }

    #[test]
    fn channel_mixing() {
        // 1x1 kernel = pure channel map.
        let mut k = ConvKernel::zeros(2, 2, 1, 1);
        k.set(0, 0, 0, 0, 1.0);
        k.set(0, 1, 0, 0, 2.0);
        k.set(1, 0, 0, 0, 3.0);
        k.set(1, 1, 0, 0, 4.0);
        let op = ConvOp::new(&k, 1, 1, Boundary::Periodic);
        let g = op.forward(&[1.0, 1.0]);
        assert_eq!(g, vec![3.0, 7.0]);
    }

    #[test]
    fn transpose_is_adjoint() {
        // ⟨A f, g⟩ == ⟨f, Aᵀ g⟩ for both boundary conditions.
        let mut rng = Pcg64::seeded(81);
        let k = ConvKernel::random_he(3, 2, 3, 3, &mut rng);
        for bc in [Boundary::Periodic, Boundary::Dirichlet] {
            let op = ConvOp::new(&k, 5, 6, bc);
            let f = rng.normal_vec(op.in_dim());
            let g = rng.normal_vec(op.out_dim());
            let af = op.forward(&f);
            let atg = op.transpose_apply(&g);
            let lhs: f64 = af.iter().zip(&g).map(|(a, b)| a * b).sum();
            let rhs: f64 = f.iter().zip(&atg).map(|(a, b)| a * b).sum();
            assert!((lhs - rhs).abs() < 1e-10, "{bc:?}: {lhs} vs {rhs}");
        }
    }

    #[test]
    fn transpose_apply_matches_transposed_kernel_periodic() {
        // Aᵀ as an operator == conv with transpose_kernel() under periodic BC.
        let mut rng = Pcg64::seeded(82);
        let k = ConvKernel::random_he(3, 2, 3, 3, &mut rng);
        let kt = k.transpose_kernel();
        let op = ConvOp::new(&k, 4, 4, Boundary::Periodic);
        let opt = ConvOp::new(&kt, 4, 4, Boundary::Periodic);
        let g = rng.normal_vec(op.out_dim());
        let a = op.transpose_apply(&g);
        let b = opt.forward(&g);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn dilated_shift_reads_spaced_neighbor() {
        // Tap at displacement (0, +1) with dilation 2 reads index +2.
        let mut k = ConvKernel::zeros(1, 1, 3, 3);
        k.set(0, 0, 1, 2, 1.0);
        let k = k.with_dilation(2);
        let op = ConvOp::new(&k, 1, 4, Boundary::Periodic);
        let g = op.forward(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(g, vec![3.0, 4.0, 1.0, 2.0]);
    }

    #[test]
    fn grouped_forward_stays_within_groups() {
        // 2 groups of 1→1 channels, 1x1 taps: a pure per-group scale.
        let mut k = ConvKernel::zeros(2, 1, 1, 1);
        k.set(0, 0, 0, 0, 2.0);
        k.set(1, 0, 0, 0, 5.0);
        let k = k.with_groups(2);
        let op = ConvOp::new(&k, 1, 1, Boundary::Periodic);
        assert_eq!(op.in_dim(), 2, "total input channels");
        let g = op.forward(&[1.0, 10.0]);
        assert_eq!(g, vec![2.0, 50.0], "no cross-group coupling");
    }

    #[test]
    fn structured_transpose_is_adjoint() {
        // ⟨A f, g⟩ == ⟨f, Aᵀ g⟩ for a grouped + dilated kernel.
        let mut rng = Pcg64::seeded(84);
        let k = ConvKernel::random_he(4, 2, 3, 3, &mut rng).with_groups(2).with_dilation(2);
        for bc in [Boundary::Periodic, Boundary::Dirichlet] {
            let op = ConvOp::new(&k, 5, 6, bc);
            let f = rng.normal_vec(op.in_dim());
            let g = rng.normal_vec(op.out_dim());
            let af = op.forward(&f);
            let atg = op.transpose_apply(&g);
            let lhs: f64 = af.iter().zip(&g).map(|(a, b)| a * b).sum();
            let rhs: f64 = f.iter().zip(&atg).map(|(a, b)| a * b).sum();
            assert!((lhs - rhs).abs() < 1e-10, "{bc:?}: {lhs} vs {rhs}");
        }
    }

    #[test]
    fn linearity() {
        let mut rng = Pcg64::seeded(83);
        let k = ConvKernel::random_he(2, 2, 3, 3, &mut rng);
        let op = ConvOp::new(&k, 3, 3, Boundary::Dirichlet);
        let f1 = rng.normal_vec(op.in_dim());
        let f2 = rng.normal_vec(op.in_dim());
        let sum: Vec<f64> = f1.iter().zip(&f2).map(|(a, b)| a + b).collect();
        let g1 = op.forward(&f1);
        let g2 = op.forward(&f2);
        let gs = op.forward(&sum);
        for i in 0..gs.len() {
            assert!((gs[i] - g1[i] - g2[i]).abs() < 1e-12);
        }
    }
}
