//! Artifact manifest: maps static pipeline configurations to the AOT HLO
//! text files emitted by `python/compile/aot.py`.

use crate::bail;
use crate::error::{Context, Result};
use std::path::{Path, PathBuf};

/// Static description of one AOT artifact (mirrors
/// `python/compile/model.py::SpectrumConfig`).
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactSpec {
    pub name: String,
    pub n: usize,
    pub m: usize,
    pub c_out: usize,
    pub c_in: usize,
    pub kh: usize,
    pub kw: usize,
    /// Frequency rows computed per execution (== n for whole-grid artifacts).
    pub tile_rows: usize,
    /// `min(c_out, c_in)` — singular values per frequency.
    pub rank: usize,
    pub sweeps: usize,
    /// HLO text file, relative to the manifest directory.
    pub file: PathBuf,
}

impl ArtifactSpec {
    /// Number of frequencies per execution.
    pub fn freqs_per_call(&self) -> usize {
        self.tile_rows * self.m
    }

    /// Output length (f32 count) per execution.
    pub fn out_len(&self) -> usize {
        self.freqs_per_call() * self.rank
    }

    /// Whether this artifact covers the whole grid in one call.
    pub fn is_whole_grid(&self) -> bool {
        self.tile_rows == self.n
    }

    /// Executions needed to cover the full grid.
    pub fn calls_for_grid(&self) -> usize {
        self.n.div_ceil(self.tile_rows)
    }
}

/// Parse `manifest.txt` lines of the form
/// `name key=value key=value ... file=<rel-path>`.
pub fn parse_manifest(text: &str, dir: &Path) -> Result<Vec<ArtifactSpec>> {
    let mut specs = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_whitespace();
        let name = parts.next().context("empty manifest line")?.to_string();
        let mut kv = std::collections::HashMap::new();
        for part in parts {
            let (k, v) = part
                .split_once('=')
                .with_context(|| format!("manifest line {}: bad token {part}", lineno + 1))?;
            kv.insert(k.to_string(), v.to_string());
        }
        let get = |k: &str| -> Result<usize> {
            kv.get(k)
                .with_context(|| format!("manifest line {}: missing {k}", lineno + 1))?
                .parse::<usize>()
                .with_context(|| format!("manifest line {}: bad {k}", lineno + 1))
        };
        let file = kv
            .get("file")
            .with_context(|| format!("manifest line {}: missing file", lineno + 1))?;
        let spec = ArtifactSpec {
            name,
            n: get("n")?,
            m: get("m")?,
            c_out: get("c_out")?,
            c_in: get("c_in")?,
            kh: get("kh")?,
            kw: get("kw")?,
            tile_rows: get("tile_rows")?,
            rank: get("rank")?,
            sweeps: get("sweeps")?,
            file: dir.join(file),
        };
        if spec.tile_rows == 0 || spec.tile_rows > spec.n {
            bail!("manifest line {}: invalid tile_rows", lineno + 1);
        }
        specs.push(spec);
    }
    Ok(specs)
}

/// Load the manifest from an artifacts directory.
pub fn load_manifest(dir: &Path) -> Result<Vec<ArtifactSpec>> {
    let path = dir.join("manifest.txt");
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading {}; run `make artifacts` first", path.display()))?;
    parse_manifest(&text, dir)
}

/// Pick the best artifact for a layer shape: exact channel/kernel match and
/// grid match, preferring tiled artifacts (shardable) over whole-grid ones
/// when `prefer_tiled` is set.
pub fn select<'a>(
    specs: &'a [ArtifactSpec],
    n: usize,
    m: usize,
    c_out: usize,
    c_in: usize,
    kh: usize,
    kw: usize,
    prefer_tiled: bool,
) -> Option<&'a ArtifactSpec> {
    let mut candidates: Vec<&ArtifactSpec> = specs
        .iter()
        .filter(|s| {
            s.n == n && s.m == m && s.c_out == c_out && s.c_in == c_in && s.kh == kh && s.kw == kw
        })
        .collect();
    candidates.sort_by_key(|s| s.tile_rows);
    if prefer_tiled {
        candidates.into_iter().find(|s| !s.is_whole_grid()).or_else(|| {
            select(specs, n, m, c_out, c_in, kh, kw, false)
        })
    } else {
        candidates.into_iter().last()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
lfa_spectrum_n8x8_c4x4_k3x3_t8 n=8 m=8 c_out=4 c_in=4 kh=3 kw=3 tile_rows=8 rank=4 sweeps=12 file=a.hlo.txt
lfa_spectrum_n32x32_c16x16_k3x3_t4 n=32 m=32 c_out=16 c_in=16 kh=3 kw=3 tile_rows=4 rank=16 sweeps=12 file=b.hlo.txt
lfa_spectrum_n32x32_c16x16_k3x3_t32 n=32 m=32 c_out=16 c_in=16 kh=3 kw=3 tile_rows=32 rank=16 sweeps=12 file=c.hlo.txt
";

    #[test]
    fn parses_sample() {
        let specs = parse_manifest(SAMPLE, Path::new("/art")).unwrap();
        assert_eq!(specs.len(), 3);
        assert_eq!(specs[0].n, 8);
        assert_eq!(specs[1].tile_rows, 4);
        assert_eq!(specs[1].calls_for_grid(), 8);
        assert_eq!(specs[1].out_len(), 4 * 32 * 16);
        assert!(specs[2].is_whole_grid());
        assert_eq!(specs[0].file, Path::new("/art/a.hlo.txt"));
    }

    #[test]
    fn selection_prefers_tiled_when_asked() {
        let specs = parse_manifest(SAMPLE, Path::new("/art")).unwrap();
        let tiled = select(&specs, 32, 32, 16, 16, 3, 3, true).unwrap();
        assert_eq!(tiled.tile_rows, 4);
        let whole = select(&specs, 32, 32, 16, 16, 3, 3, false).unwrap();
        assert_eq!(whole.tile_rows, 32);
    }

    #[test]
    fn selection_misses_unknown_shape() {
        let specs = parse_manifest(SAMPLE, Path::new("/art")).unwrap();
        assert!(select(&specs, 8, 8, 2, 2, 3, 3, true).is_none());
    }

    #[test]
    fn rejects_bad_tile_rows() {
        let bad = "x n=8 m=8 c_out=4 c_in=4 kh=3 kw=3 tile_rows=0 rank=4 sweeps=12 file=x.hlo.txt";
        assert!(parse_manifest(bad, Path::new("/")).is_err());
    }
}
