//! PJRT runtime: load AOT-compiled HLO text artifacts and execute them on
//! the CPU PJRT client from the request path (python is never involved at
//! runtime).
//!
//! The artifact manifest ([`artifact`]) is always available — it is plain
//! parsing with no XLA dependency. The execution layers are gated behind
//! the off-by-default `pjrt` cargo feature; by default the feature compiles
//! against the in-tree `xla` API stub (`rust/xla-stub/`, so the gated code
//! typechecks offline and CI can gate it) whose client constructor fails
//! fast — swap the path dependency for the real `xla` crate to execute:
//!
//! - `PjrtEngine` (feature `pjrt` only) — thread-local engine: client +
//!   compiled-executable cache. `PjRtClient` is `Rc`-based (not `Send`), so
//!   an engine lives and dies on one thread.
//! - [`PjrtExecutor`] — a dedicated executor thread owning one engine,
//!   driven through an mpsc channel. The coordinator's worker pool sends
//!   tile jobs to it and receives spectra back; this is how the non-`Send`
//!   client composes with the multi-threaded scheduler.
//!
//! Without the feature, a stub [`PjrtExecutor`] whose `spawn()` always
//! fails keeps the coordinator's routing code compiling unchanged; every
//! job simply runs on the native backend.

pub mod artifact;

pub use artifact::{load_manifest, parse_manifest, select, ArtifactSpec};

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::{ExecReply, PjrtEngine, PjrtExecutor};

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::{ExecReply, PjrtExecutor};
