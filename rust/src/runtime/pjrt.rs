//! The real PJRT engine + executor (feature `pjrt`): compiles HLO text
//! artifacts with the `xla` crate and executes tiles on the CPU client.

use crate::err;
use crate::error::{Context, Result};
use crate::runtime::ArtifactSpec;
use std::collections::HashMap;
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// Thread-local PJRT engine: one CPU client + executable cache.
pub struct PjrtEngine {
    client: xla::PjRtClient,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl PjrtEngine {
    /// Create a CPU engine.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| err!("PJRT cpu client: {e:?}"))?;
        Ok(Self { client, cache: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an artifact (cached by name).
    pub fn prepare(&mut self, spec: &ArtifactSpec) -> Result<()> {
        if self.cache.contains_key(&spec.name) {
            return Ok(());
        }
        let path = spec
            .file
            .to_str()
            .with_context(|| format!("non-utf8 artifact path {:?}", spec.file))?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| err!("parsing HLO text {path}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| err!("compiling artifact {}: {e:?}", spec.name))?;
        self.cache.insert(spec.name.clone(), exe);
        Ok(())
    }

    /// Execute one tile: weights (OIHW, f32) + frequency-row offset →
    /// `tile_rows·m·rank` singular values (frequency-major, descending per
    /// frequency).
    pub fn run_tile(
        &mut self,
        spec: &ArtifactSpec,
        weights: &[f32],
        row_offset: i32,
    ) -> Result<Vec<f32>> {
        let expect = spec.c_out * spec.c_in * spec.kh * spec.kw;
        if weights.len() != expect {
            return Err(err!(
                "weight length {} != {expect} for artifact {}",
                weights.len(),
                spec.name
            ));
        }
        self.prepare(spec)?;
        let exe = self.cache.get(&spec.name).expect("prepared above");
        let w = xla::Literal::vec1(weights)
            .reshape(&[spec.c_out as i64, spec.c_in as i64, spec.kh as i64, spec.kw as i64])
            .map_err(|e| err!("reshaping weights: {e:?}"))?;
        let off = xla::Literal::scalar(row_offset);
        let result = exe
            .execute::<xla::Literal>(&[w, off])
            .map_err(|e| err!("executing {}: {e:?}", spec.name))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| err!("fetching result: {e:?}"))?;
        // Lowered with return_tuple=True → 1-tuple.
        let out = lit.to_tuple1().map_err(|e| err!("untupling result: {e:?}"))?;
        let values = out.to_vec::<f32>().map_err(|e| err!("reading f32s: {e:?}"))?;
        if values.len() != spec.out_len() {
            return Err(err!(
                "artifact {} returned {} values, expected {}",
                spec.name,
                values.len(),
                spec.out_len()
            ));
        }
        Ok(values)
    }

    /// Run the full grid by sweeping the artifact over all row tiles.
    pub fn run_grid(&mut self, spec: &ArtifactSpec, weights: &[f32]) -> Result<Vec<f32>> {
        let mut values = Vec::with_capacity(spec.n * spec.m * spec.rank);
        let mut row = 0usize;
        while row < spec.n {
            values.extend(self.run_tile(spec, weights, row as i32)?);
            row += spec.tile_rows;
        }
        values.truncate(spec.n * spec.m * spec.rank);
        Ok(values)
    }
}

/// A tile job for the executor thread.
struct ExecRequest {
    spec: ArtifactSpec,
    weights: Vec<f32>,
    row_offset: i32,
    reply: mpsc::Sender<Result<ExecReply>>,
}

/// Executor reply: singular values + on-thread execution latency.
pub struct ExecReply {
    pub values: Vec<f32>,
    pub latency: Duration,
}

/// Handle to a dedicated PJRT executor thread. Cheap to clone; all clones
/// feed the same engine through a channel (requests are serialized — XLA's
/// CPU executable is internally multi-threaded, so one engine saturates the
/// machine for large tiles while small tiles interleave with native work).
#[derive(Clone)]
pub struct PjrtExecutor {
    tx: mpsc::Sender<ExecRequest>,
}

impl PjrtExecutor {
    /// Spawn the executor thread. Fails fast if the client cannot start.
    pub fn spawn() -> Result<Self> {
        let (tx, rx) = mpsc::channel::<ExecRequest>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        std::thread::Builder::new()
            .name("pjrt-executor".into())
            .spawn(move || {
                let mut engine = match PjrtEngine::cpu() {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(req) = rx.recv() {
                    let t0 = Instant::now();
                    let out = engine
                        .run_tile(&req.spec, &req.weights, req.row_offset)
                        .map(|values| ExecReply { values, latency: t0.elapsed() });
                    let _ = req.reply.send(out);
                }
            })
            .context("spawning pjrt-executor thread")?;
        ready_rx.recv().context("executor thread died during init")??;
        Ok(Self { tx })
    }

    /// Execute a tile synchronously (blocks the calling worker, not the
    /// executor queue).
    pub fn run_tile(
        &self,
        spec: &ArtifactSpec,
        weights: &[f32],
        row_offset: i32,
    ) -> Result<ExecReply> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send(ExecRequest {
                spec: spec.clone(),
                weights: weights.to_vec(),
                row_offset,
                reply: reply_tx,
            })
            .map_err(|_| err!("pjrt executor thread is gone"))?;
        reply_rx.recv().map_err(|_| err!("pjrt executor dropped the reply"))?
    }

    /// Run the full grid for an artifact (tile sweep through the executor).
    pub fn run_grid(&self, spec: &ArtifactSpec, weights: &[f32]) -> Result<Vec<f32>> {
        let mut values = Vec::with_capacity(spec.n * spec.m * spec.rank);
        let mut row = 0usize;
        while row < spec.n {
            values.extend(self.run_tile(spec, weights, row as i32)?.values);
            row += spec.tile_rows;
        }
        values.truncate(spec.n * spec.m * spec.rank);
        Ok(values)
    }
}
