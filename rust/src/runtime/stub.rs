//! Stub PJRT executor for builds without the `pjrt` feature.
//!
//! The type is uninhabited (its only field is [`std::convert::Infallible`]),
//! so `spawn()` is the sole constructor and it always fails — every call
//! site that matches on a live executor is statically unreachable, and the
//! coordinator falls back to the native backend without any `cfg` noise at
//! its call sites.

use crate::err;
use crate::error::Result;
use crate::runtime::ArtifactSpec;
use std::time::Duration;

/// Executor reply: singular values + on-thread execution latency.
pub struct ExecReply {
    pub values: Vec<f32>,
    pub latency: Duration,
}

/// Uninhabited stand-in for the real executor handle.
#[derive(Clone)]
pub struct PjrtExecutor {
    _void: std::convert::Infallible,
}

impl PjrtExecutor {
    /// Always fails: the crate was built without the `pjrt` feature.
    pub fn spawn() -> Result<Self> {
        Err(err!("PJRT is unavailable: built without the `pjrt` feature"))
    }

    /// Statically unreachable (no value of `Self` exists).
    pub fn run_tile(
        &self,
        _spec: &ArtifactSpec,
        _weights: &[f32],
        _row_offset: i32,
    ) -> Result<ExecReply> {
        match self._void {}
    }

    /// Statically unreachable (no value of `Self` exists).
    pub fn run_grid(&self, _spec: &ArtifactSpec, _weights: &[f32]) -> Result<Vec<f32>> {
        match self._void {}
    }
}
