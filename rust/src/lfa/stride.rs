//! Strided convolutions — the paper's crystal-torus machinery with a
//! nontrivial sublattice (§III: `T_{A,C} = L^u(A)/L(C)` with
//! `|det Z| = s²` degrees of freedom per cell).
//!
//! A stride-`s` convolution is `C = D_s ∘ A` (convolve, then keep every
//! `s`-th pixel). Downsampling folds frequencies: the `s²` fine frequencies
//! `k_ab = (κ + (a, b)) / s` alias onto the same coarse frequency `κ`, so
//! the symbol of `C` at `κ` is the **horizontal concatenation**
//!
//! ```text
//!   C_κ = (1/s) · [ A_{k_00} | A_{k_01} | … | A_{k_(s-1)(s-1)} ]
//! ```
//!
//! of shape `c_out × s²·c_in` — exactly the rectangular blocks of
//! Sedghi et al.'s strided appendix, derived here in the LFA picture. The
//! spectrum of `C` is the union of the per-κ block SVDs, computed in
//! `O((n/s)(m/s) · s²·c_in · c_out · min(..))` — still linear in the grid.

use super::spectrum::Spectrum;
use super::symbol::symbol_at;
use crate::conv::ConvKernel;
use crate::engine::SpectralPlan;
use crate::numeric::CMat;

/// The symbol of the stride-`s` convolution at coarse frequency
/// `κ = (ki/(n/s), kj/(m/s))`: a `c_out × s²·c_in_total` matrix
/// (structure-aware — grouped blocks are channel-block-diagonal within
/// every aliasing column group, dilation enters through the fine symbols).
/// For a `transposed` kernel the returned block is the conjugate
/// transpose, `s²·c_in_total × c_out` — the adjoint operator's symbol.
///
/// Requires `s` to divide `n` and `m`.
pub fn strided_symbol_at(
    kernel: &ConvKernel,
    n: usize,
    m: usize,
    s: usize,
    ki: usize,
    kj: usize,
) -> CMat {
    assert!(s > 0 && n % s == 0 && m % s == 0, "stride must divide the grid");
    let (nc, mc) = (n / s, m / s);
    debug_assert!(ki < nc && kj < mc);
    let cin = kernel.c_in_total();
    let mut block = CMat::zeros(kernel.c_out, s * s * cin);
    let scale = 1.0 / s as f64;
    for a in 0..s {
        for b in 0..s {
            // fine frequency (ki + a·nc, kj + b·mc) / n — i.e. index into the
            // full fine dual grid. `symbol_at` hands the adjoint symbol for
            // transposed kernels; undo that here and re-transpose the whole
            // concatenated block at the end (adjoint of the strided op, not
            // a concatenation of fine adjoints).
            let fine = symbol_at(kernel, n, m, ki + a * nc, kj + b * mc);
            let fine = if kernel.transposed { fine.hermitian() } else { fine };
            let col0 = (a * s + b) * cin;
            for o in 0..kernel.c_out {
                for i in 0..cin {
                    block[(o, col0 + i)] = fine[(o, i)].scale(scale);
                }
            }
        }
    }
    if kernel.transposed {
        block.hermitian()
    } else {
        block
    }
}

/// All singular values of the stride-`s` convolution on an `n×m` fine grid
/// (output grid `(n/s)×(m/s)`), grouped per coarse frequency, descending.
///
/// Thin wrapper over [`SpectralPlan::with_stride`]: the plan folds the
/// `s²`-fold frequency aliasing into its block geometry and runs the same
/// planned, allocation-free symbol→SVD loop as the dense path. Use
/// [`strided_plan`] directly for repeated spectra of one layer.
pub fn strided_singular_values(kernel: &ConvKernel, n: usize, m: usize, s: usize) -> Spectrum {
    strided_plan(kernel, n, m, s, Default::default()).execute()
}

/// Plan the stride-`s` pipeline for repeated execution (plan once, execute
/// many — e.g. per-step spectral norms of a strided encoder during
/// training).
pub fn strided_plan(
    kernel: &ConvKernel,
    n: usize,
    m: usize,
    s: usize,
    opts: crate::lfa::LfaOptions,
) -> SpectralPlan {
    SpectralPlan::with_stride(kernel, n, m, s, opts)
}

/// Dense unrolled matrix of the strided convolution (ground truth for the
/// tests): rows = coarse outputs, columns = fine inputs. Periodic BC.
///
/// Structure-aware like [`crate::conv::unroll_dense`]: grouped kernels
/// populate block-diagonal channel couplings, dilated kernels read
/// `dilation`-spaced taps. Always the **forward** mapping — the
/// transposed-conv reference is this matrix's transpose (same singular
/// values).
pub fn unroll_strided(kernel: &ConvKernel, n: usize, m: usize, s: usize) -> crate::numeric::Mat {
    assert!(s > 0 && n % s == 0 && m % s == 0);
    let (nc, mc) = (n / s, m / s);
    let cin_total = kernel.c_in_total();
    let rows = nc * mc * kernel.c_out;
    let cols = n * m * cin_total;
    let mut a = crate::numeric::Mat::zeros(rows, cols);
    let (ar, ac) = (kernel.anchor.0 as isize, kernel.anchor.1 as isize);
    let gr = kernel.group_c_out();
    let d = kernel.dilation as isize;
    for xr in 0..nc {
        for xc in 0..mc {
            // Output pixel (xr, xc) reads the fine-grid stencil at (s·xr, s·xc).
            let (fr, fc) = ((s * xr) as isize, (s * xc) as isize);
            for r in 0..kernel.kh as isize {
                for c in 0..kernel.kw as isize {
                    let (sr, sc) = (fr + d * (r - ar), fc + d * (c - ac));
                    let rr = sr.rem_euclid(n as isize) as usize;
                    let cc = sc.rem_euclid(m as isize) as usize;
                    let src = rr * m + cc;
                    let dst = xr * mc + xc;
                    for o in 0..kernel.c_out {
                        let col0 = src * cin_total + (o / gr) * kernel.c_in;
                        for i in 0..kernel.c_in {
                            a[(dst * kernel.c_out + o, col0 + i)] +=
                                kernel.get(o, i, r as usize, c as usize);
                        }
                    }
                }
            }
        }
    }
    a
}

/// Aliasing-index permutation of the strided symbol under frequency
/// negation — the coarse-grid side of conjugate-pair folding
/// ([`crate::lfa::Fold`]).
///
/// For real kernels every fine symbol satisfies `A(−k) = conj(A(k))`, but
/// the coarse block at `−κ` concatenates the *negated* fine frequencies,
/// whose aliasing offsets land in permuted positions:
/// `C(−κ) = conj(C(κ))·P`, where `P` permutes the `s²` column groups
/// sub-axis-wise. On one axis, the group offset paired with `a` is
/// `(s − a) mod s` when that axis' coarse component is zero (the offsets
/// negate in place) and `s − 1 − a` otherwise (negation crosses into the
/// next coarse cell). Column permutations leave singular values untouched
/// and carry the right factors as `V(−κ) = Pᵀ·conj(V(κ))` — the rule the
/// engine's folded factor paths apply per aliasing row group.
#[inline]
pub fn alias_mirror_index(s: usize, coarse_component_is_zero: bool, a: usize) -> usize {
    debug_assert!(a < s);
    if coarse_component_is_zero {
        (s - a) % s
    } else {
        s - 1 - a
    }
}

/// Singular values of the transposed (fractionally-strided / upsampling)
/// convolution `Cᵀ` — identical multiset to `C`'s by the SVD's symmetry,
/// exposed as an explicit helper for pseudo-invertible-network use.
pub fn transposed_strided_singular_values(
    kernel: &ConvKernel,
    n: usize,
    m: usize,
    s: usize,
) -> Spectrum {
    strided_singular_values(kernel, n, m, s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gk_svd;
    use crate::numeric::Pcg64;

    fn max_gap(a: &[f64], b: &[f64]) -> f64 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
    }

    #[test]
    fn stride_one_matches_plain_lfa() {
        let mut rng = Pcg64::seeded(400);
        let k = ConvKernel::random_he(3, 2, 3, 3, &mut rng);
        let s1 = strided_singular_values(&k, 6, 6, 1);
        let plain = crate::lfa::singular_values(&k, 6, 6, Default::default());
        assert_eq!(s1.values.len(), plain.values.len());
        assert!(max_gap(&s1.values, &plain.values) < 1e-12);
    }

    #[test]
    fn stride_two_matches_explicit_matrix() {
        let mut rng = Pcg64::seeded(401);
        for (c_out, c_in) in [(2usize, 2usize), (3, 2), (2, 3)] {
            let k = ConvKernel::random_he(c_out, c_in, 3, 3, &mut rng);
            let (n, m, s) = (8, 8, 2);
            let lfa_sorted = strided_singular_values(&k, n, m, s).sorted_desc();
            let explicit = unroll_strided(&k, n, m, s);
            let gk = gk_svd::singular_values(&explicit);
            // explicit has min(rows, cols) values; compare the leading ones.
            let top = lfa_sorted.len().min(gk.len());
            assert!(
                max_gap(&lfa_sorted[..top], &gk[..top]) < 1e-8,
                "{c_out}x{c_in}: {}",
                max_gap(&lfa_sorted[..top], &gk[..top])
            );
        }
    }

    #[test]
    fn stride_three_matches_explicit_matrix() {
        let mut rng = Pcg64::seeded(402);
        let k = ConvKernel::random_he(2, 2, 3, 3, &mut rng);
        let (n, m, s) = (6, 6, 3);
        let lfa_sorted = strided_singular_values(&k, n, m, s).sorted_desc();
        let gk = gk_svd::singular_values(&unroll_strided(&k, n, m, s));
        let top = lfa_sorted.len().min(gk.len());
        assert!(max_gap(&lfa_sorted[..top], &gk[..top]) < 1e-8);
    }

    #[test]
    fn strided_frobenius_identity() {
        // ‖C‖²_F = Σσ²; for the strided operator the closed form is
        // (n/s)(m/s)·‖W‖²_F (each coarse output still reads every tap once),
        // provided the kernel fits without self-aliasing.
        let mut rng = Pcg64::seeded(403);
        let k = ConvKernel::random_he(3, 3, 3, 3, &mut rng);
        let (n, m, s) = (8, 8, 2);
        let spec = strided_singular_values(&k, n, m, s);
        let lhs: f64 = spec.values.iter().map(|v| v * v).sum();
        let rhs = ((n / s) * (m / s)) as f64 * k.frobenius_norm().powi(2);
        assert!((lhs - rhs).abs() / rhs < 1e-10, "{lhs} vs {rhs}");
    }

    #[test]
    fn nonsquare_strides_and_grids() {
        let mut rng = Pcg64::seeded(404);
        let k = ConvKernel::random_he(2, 2, 3, 3, &mut rng);
        let (n, m, s) = (4, 8, 2);
        let lfa_sorted = strided_singular_values(&k, n, m, s).sorted_desc();
        let gk = gk_svd::singular_values(&unroll_strided(&k, n, m, s));
        let top = lfa_sorted.len().min(gk.len());
        assert!(max_gap(&lfa_sorted[..top], &gk[..top]) < 1e-8);
    }

    #[test]
    fn strided_spectral_norm_bounds_operator_gain() {
        use crate::linalg::power::LinOp;
        let mut rng = Pcg64::seeded(405);
        let k = ConvKernel::random_he(4, 2, 3, 3, &mut rng);
        let (n, m, s) = (8, 8, 2);
        let a = unroll_strided(&k, n, m, s);
        let spec = strided_singular_values(&k, n, m, s);
        let x = rng.normal_vec(a.in_dim());
        let y = a.apply(&x);
        let gain = y.iter().map(|v| v * v).sum::<f64>().sqrt()
            / x.iter().map(|v| v * v).sum::<f64>().sqrt();
        assert!(gain <= spec.sigma_max() * (1.0 + 1e-9));
    }

    #[test]
    #[should_panic(expected = "stride must divide")]
    fn rejects_nondividing_stride() {
        let k = ConvKernel::zeros(1, 1, 3, 3);
        strided_singular_values(&k, 7, 7, 2);
    }

    #[test]
    fn alias_mirror_permutation_is_a_self_inverse_bijection() {
        for s in 1..=4usize {
            for zero in [true, false] {
                let mut seen = vec![false; s];
                for a in 0..s {
                    let b = alias_mirror_index(s, zero, a);
                    assert!(b < s);
                    assert!(!seen[b], "s={s} zero={zero}: {b} hit twice");
                    seen[b] = true;
                    assert_eq!(alias_mirror_index(s, zero, b), a, "involution");
                }
            }
        }
    }

    #[test]
    fn strided_symbol_mirrors_as_conjugate_column_permutation() {
        // C(−κ) = conj(C(κ))·P with P permuting the s² aliasing column
        // groups by `alias_mirror_index` per axis — the identity the
        // engine's folded factor mirroring relies on.
        let mut rng = Pcg64::seeded(406);
        for &(n, m, s) in &[(8usize, 8usize, 2usize), (6, 6, 3), (4, 8, 2)] {
            let k = ConvKernel::random_he(3, 2, 3, 3, &mut rng);
            let (nc, mc) = (n / s, m / s);
            let cin = k.c_in;
            for ki in 0..nc {
                for kj in 0..mc {
                    let (mi, mj) = ((nc - ki) % nc, (mc - kj) % mc);
                    let at = strided_symbol_at(&k, n, m, s, ki, kj);
                    let neg = strided_symbol_at(&k, n, m, s, mi, mj);
                    for a in 0..s {
                        for b in 0..s {
                            let sa = alias_mirror_index(s, ki == 0, a);
                            let sb = alias_mirror_index(s, kj == 0, b);
                            for o in 0..k.c_out {
                                for i in 0..cin {
                                    let got = neg[(o, (a * s + b) * cin + i)];
                                    let want = at[(o, (sa * s + sb) * cin + i)].conj();
                                    assert!(
                                        (got - want).abs() < 1e-12,
                                        "{n}x{m}/{s} κ=({ki},{kj}) sub=({a},{b})"
                                    );
                                }
                            }
                        }
                    }
                }
            }
        }
    }
}
