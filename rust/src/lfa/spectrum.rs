//! The spectrum of a convolutional mapping: per-frequency singular values
//! and (optionally) per-frequency singular vector factors — plus the
//! **mirror-aware assembly** helpers behind conjugate-pair frequency
//! folding ([`crate::lfa::Fold`]): real kernels give `A(−θ) = conj(A(θ))`,
//! so a folded execution solves only a fundamental domain of `θ → −θ` and
//! [`mirror_fill`] / [`conj_factor`] complete the conjugate half.

use crate::numeric::CMat;

/// Flat index of the conjugate mirror of frequency `f = i·m + j` on an
/// `n×m` dual grid: `(−i mod n)·m + (−j mod m)`. A fixed point of this map
/// is a **self-paired** frequency (the DC point and, on even axes, the
/// Nyquist lines), which a folded execution solves exactly once.
#[inline]
pub fn mirror_freq(n: usize, m: usize, f: usize) -> usize {
    let (i, j) = (f / m, f % m);
    ((n - i) % n) * m + (m - j) % m
}

/// Number of frequencies in the canonical fundamental domain of `θ → −θ`
/// on an `n×m` dual grid: rows `0..=n/2`, with the self-paired rows (row 0
/// and, for even `n`, row `n/2`) folded along the column axis to columns
/// `0..=m/2`. Equals `(n·m + s)/2` where `s` counts the self-paired
/// frequencies — the block-SVD count a folded execution performs.
pub fn folded_freqs(n: usize, m: usize) -> usize {
    let half_row = m / 2 + 1;
    (0..=n / 2).map(|i| if i == 0 || 2 * i == n { half_row } else { m }).sum()
}

/// Complete a frequency-major values buffer (`n·m·per_freq` long,
/// `per_freq` values per frequency) from its canonical fundamental domain:
/// every frequency outside the domain receives a copy of its conjugate
/// mirror's values (`σ(A(−θ)) = σ(conj(A(θ))) = σ(A(θ))`). Idempotent —
/// callers whose folded sweeps already filled the self-paired rows in-row
/// (the engine's tiles do) lose nothing by running it over the whole
/// buffer. The single assembly step shared by the plan's folded
/// executions, `ModelPlan`'s batched sweeps and the coordinator's folded
/// tile jobs.
pub fn mirror_fill(n: usize, m: usize, per_freq: usize, values: &mut [f64]) {
    assert_eq!(values.len(), n * m * per_freq, "values buffer length mismatch");
    let r = per_freq;
    let h = n / 2;
    let hm = m / 2;
    let (top, bottom) = values.split_at_mut((h + 1).min(n) * m * r);
    // Self-paired rows mirror along the column axis, within the row.
    let mut row = 0usize;
    loop {
        let base = row * m * r;
        for j in (hm + 1)..m {
            let src = base + (m - j) * r;
            let dst = base + j * r;
            top.copy_within(src..src + r, dst);
        }
        if row == 0 && n % 2 == 0 && h > 0 {
            row = h;
        } else {
            break;
        }
    }
    // Every row below the fold line mirrors a solved upper row.
    for i in (h + 1)..n {
        let si = n - i;
        for j in 0..m {
            let sj = (m - j) % m;
            let src = (si * m + sj) * r;
            let dst = ((i - h - 1) * m + j) * r;
            bottom[dst..dst + r].copy_from_slice(&top[src..src + r]);
        }
    }
}

/// Elementwise conjugate of a factor matrix: the **left** factor of a
/// mirrored frequency (`A(−θ) = conj(A(θ)) ⇒ U(−θ) = conj(U(θ))`). The
/// right factor additionally permutes its aliasing row groups for strided
/// plans — see `lfa::stride::alias_mirror_index`.
pub fn conj_factor(mat: &CMat) -> CMat {
    let mut out = CMat::zeros(mat.rows, mat.cols);
    for i in 0..mat.rows {
        for j in 0..mat.cols {
            out[(i, j)] = mat[(i, j)].conj();
        }
    }
    out
}

/// Numerical-health summary of the solves that produced a [`Spectrum`]:
/// how many frequencies converged cleanly, how many needed the escalation
/// ladder, and how many are still degraded after it — plus the worst
/// relative solver residual observed. Carried on every `Spectrum`,
/// aggregated across layers by `ModelSpectra`, and surfaced on the
/// coordinator's `LayerReport` and the daemon wire protocol, so a consumer
/// can always tell a certified spectrum from a best-effort one.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SpectrumHealth {
    /// Frequencies whose solver certificate met tolerance first try.
    pub converged_freqs: u64,
    /// Frequencies that needed at least one retry/escalation rung but
    /// ended converged.
    pub retried_freqs: u64,
    /// Frequencies still unconverged after the full escalation ladder —
    /// their values are best-effort and the spectrum must not be cached.
    pub degraded_freqs: u64,
    /// Total escalation-ladder rungs taken (internal fresh restarts plus
    /// full-Jacobi / f64 re-solves), across all frequencies.
    pub escalations: u64,
    /// Worst relative solver residual across all frequencies (off-diagonal
    /// for Jacobi, Ritz residual for the Krylov top-k path).
    pub worst_residual: f64,
}

impl SpectrumHealth {
    /// Health of a spectrum with `freqs` frequencies solved cleanly —
    /// the label for exact/direct paths (baselines, disk-cache decode).
    pub fn clean(freqs: u64) -> Self {
        Self { converged_freqs: freqs, ..Self::default() }
    }

    /// Whether any frequency remains unconverged after the ladder. A
    /// degraded spectrum is served flagged, never cached, and fails the
    /// job under `--strict-health`.
    pub fn is_degraded(&self) -> bool {
        self.degraded_freqs > 0
    }

    /// Fold in the verdict of one frequency: converged cleanly, retried
    /// (recovered after `escalations` rungs), or degraded.
    pub fn absorb(&mut self, converged: bool, retried: bool, escalations: u64, residual: f64) {
        if !converged {
            self.degraded_freqs += 1;
        } else if retried {
            self.retried_freqs += 1;
        } else {
            self.converged_freqs += 1;
        }
        self.escalations += escalations;
        if residual > self.worst_residual {
            self.worst_residual = residual;
        }
    }

    /// Merge another health summary into this one (counts add, worst
    /// residual maxes) — layer aggregation and threaded-strip reduction.
    pub fn merge(&mut self, other: &Self) {
        self.converged_freqs += other.converged_freqs;
        self.retried_freqs += other.retried_freqs;
        self.degraded_freqs += other.degraded_freqs;
        self.escalations += other.escalations;
        if other.worst_residual > self.worst_residual {
            self.worst_residual = other.worst_residual;
        }
    }
}

/// Singular values of a convolution, grouped by frequency.
///
/// A **full** spectrum stores `min(c_out, c_in)` values per frequency; the
/// full operator has `n·m·min(c_out, c_in)` nonzero-capable singular values
/// (`n·m·c` for square channel counts, matching the paper's counts — e.g.
/// `n=256, c=16 → 1,048,576`). A **partial** (top-k) spectrum, as produced
/// by the engine's `SpectrumRequest::TopK` mode, stores only the `k`
/// largest values per frequency; [`Spectrum::per_freq`] records which of
/// the two a given instance is, so every consumer indexes correctly.
#[derive(Clone, Debug)]
pub struct Spectrum {
    pub n: usize,
    pub m: usize,
    pub c_out: usize,
    pub c_in: usize,
    /// Singular values stored per frequency: `min(c_out, c_in)` for full
    /// spectra, `k` for top-k partial spectra.
    pub per_freq: usize,
    /// `values[f·r .. (f+1)·r]` are the descending singular values at
    /// frequency `f`, with `r = per_freq`.
    pub values: Vec<f64>,
    /// Convergence evidence for the solves behind these values.
    pub health: SpectrumHealth,
}

impl Spectrum {
    /// Values stored per frequency (`min(c_out, c_in)` for a full spectrum,
    /// `k` for a top-k partial one).
    pub fn rank_per_freq(&self) -> usize {
        self.per_freq
    }

    /// Whether this spectrum stores every singular value per frequency (as
    /// opposed to a top-k partial spectrum).
    pub fn is_full(&self) -> bool {
        self.per_freq == self.c_out.min(self.c_in)
    }

    /// Whether this is a partial (top-k) spectrum: only the `per_freq`
    /// **largest** values per frequency are stored, so any statistic that
    /// needs the small end of the spectrum ([`Self::sigma_min`],
    /// [`Self::condition_number`], the Frobenius identity) is undefined —
    /// those accessors return NaN rather than a silently wrong number.
    pub fn is_partial(&self) -> bool {
        !self.is_full()
    }

    pub fn num_values(&self) -> usize {
        self.values.len()
    }

    /// Values at one frequency (descending).
    pub fn at(&self, f: usize) -> &[f64] {
        let r = self.rank_per_freq();
        &self.values[f * r..(f + 1) * r]
    }

    /// Largest singular value — the spectral norm / Lipschitz constant of
    /// the (periodic) convolution.
    pub fn sigma_max(&self) -> f64 {
        self.values.iter().cloned().fold(0.0, f64::max)
    }

    /// Smallest singular value of the operator. **NaN for a partial
    /// (top-k) spectrum**: the retained per-frequency values are the
    /// *largest* ones, so the smallest stored value says nothing about the
    /// operator's σ_min — reporting it would be silently wrong (the same
    /// convention `frobenius_defect` uses for unverifiable spectra). Use
    /// [`Self::min_stored`] for the smallest *computed* value.
    pub fn sigma_min(&self) -> f64 {
        if self.is_partial() {
            return f64::NAN;
        }
        self.min_stored()
    }

    /// Smallest **stored** singular value across all frequencies — the
    /// operator's σ_min for a full spectrum, merely the smallest computed
    /// extreme for a top-k partial one.
    pub fn min_stored(&self) -> f64 {
        self.values.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    /// Condition number `σ_max/σ_min` (∞ if singular; NaN for a partial
    /// spectrum — see [`Self::sigma_min`]).
    pub fn condition_number(&self) -> f64 {
        let lo = self.sigma_min();
        if lo.is_nan() {
            return f64::NAN;
        }
        if lo == 0.0 {
            f64::INFINITY
        } else {
            self.sigma_max() / lo
        }
    }

    /// All values sorted descending (the series plotted in Fig. 6).
    pub fn sorted_desc(&self) -> Vec<f64> {
        let mut v = self.values.clone();
        v.sort_by(|a, b| b.partial_cmp(a).unwrap());
        v
    }

    /// Frobenius norm of the operator: `√(Σ σ²)`. For a periodic convolution
    /// this equals `√(n·m)·‖W‖_F` — a cheap internal consistency check.
    pub fn frobenius_norm(&self) -> f64 {
        self.values.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    /// Effective rank at tolerance `tol·σ_max`.
    pub fn rank(&self, tol: f64) -> usize {
        let cutoff = self.sigma_max() * tol;
        self.values.iter().filter(|&&v| v > cutoff).count()
    }

    /// Symmetric divergence between two *sorted* spectra: mean relative
    /// pointwise gap. Used for the Fig. 6 boundary-condition comparison
    /// (spectra may have slightly different lengths for Dirichlet vs
    /// periodic — compare by quantile).
    pub fn divergence(sorted_a: &[f64], sorted_b: &[f64]) -> f64 {
        assert!(!sorted_a.is_empty() && !sorted_b.is_empty());
        let len = sorted_a.len().max(sorted_b.len());
        let sample = |xs: &[f64], q: f64| -> f64 {
            let pos = q * (xs.len() - 1) as f64;
            let lo = pos.floor() as usize;
            let hi = pos.ceil() as usize;
            let t = pos - lo as f64;
            xs[lo] * (1.0 - t) + xs[hi] * t
        };
        let scale = sorted_a[0].max(sorted_b[0]).max(1e-300);
        let mut acc = 0.0;
        for s in 0..len {
            let q = s as f64 / (len - 1).max(1) as f64;
            acc += (sample(sorted_a, q) - sample(sorted_b, q)).abs() / scale;
        }
        acc / len as f64
    }
}

/// Full SVD of a convolution: per-frequency factors
/// `A_k = U_k Σ_k V_kᴴ`. The *global* singular vectors
/// `F_k^{c_out} U_k`, `F_k^{c_in} V_k` are never materialized (that's the
/// point of the method); [`crate::spectral::FreqOperator`] applies them
/// implicitly via FFTs when an operator is needed in the spatial domain.
pub struct FullSvd {
    pub n: usize,
    pub m: usize,
    pub c_out: usize,
    pub c_in: usize,
    /// Per-frequency left factors (`c_out×r`).
    pub u: Vec<CMat>,
    /// Per-frequency singular values, same layout as [`Spectrum::values`].
    pub sigma: Spectrum,
    /// Per-frequency right factors (`c_in×r`).
    pub v: Vec<CMat>,
}

impl FullSvd {
    /// Reconstruct the symbol at frequency `f` from its factors.
    pub fn symbol(&self, f: usize) -> CMat {
        let r = self.sigma.rank_per_freq();
        let s = self.sigma.at(f);
        let u = &self.u[f];
        let v = &self.v[f];
        let mut us = CMat::zeros(u.rows, r);
        for i in 0..u.rows {
            for j in 0..r {
                us[(i, j)] = u[(i, j)].scale(s[j]);
            }
        }
        us.matmul(&v.hermitian())
    }
}

/// Partial (top-k) SVD of a convolution: per frequency, the `k` largest
/// singular values with their left/right singular vectors — the output of
/// the engine's warm-started Krylov (Lanczos) sweep
/// (`SpectralPlan::topk_svd`). The rank-`k` truncation
/// `U_k Σ_k V_kᴴ` it spans is the Eckart–Young-optimal rank-`k`
/// approximation of each symbol, which is all that low-rank compression
/// needs — at `O(n·m·c²k)` instead of the full `O(n·m·c³)`.
pub struct TopKSvd {
    pub n: usize,
    pub m: usize,
    pub c_out: usize,
    pub c_in: usize,
    /// Triplets kept per frequency.
    pub k: usize,
    /// Per-frequency left factors (`c_out×k`).
    pub u: Vec<CMat>,
    /// Per-frequency top-k singular values (`per_freq == k`).
    pub sigma: Spectrum,
    /// Per-frequency right factors (`c_in×k`).
    pub v: Vec<CMat>,
    /// Total solver iteration steps spent across all frequencies.
    pub iterations: u64,
    /// Total spectral energy `Σ_k ‖A_k‖_F² = Σ_{k,j} σ_{k,j}²` over **all**
    /// singular values, accumulated exactly from the symbol blocks during
    /// the sweep. This is what lets a partial SVD still report the exact
    /// Eckart–Young relative error: `√(1 − Σ_{kept} σ²/total)`.
    pub total_energy: f64,
}

impl TopKSvd {
    /// Rank-`k` truncated symbol at frequency `f`: `U_k Σ_k V_kᴴ`.
    pub fn truncated_symbol(&self, f: usize) -> CMat {
        let s = self.sigma.at(f);
        let u = &self.u[f];
        let v = &self.v[f];
        let mut us = CMat::zeros(u.rows, self.k);
        for i in 0..u.rows {
            for j in 0..self.k {
                us[(i, j)] = u[(i, j)].scale(s[j]);
            }
        }
        us.matmul(&v.hermitian())
    }
}

/// Streaming singular-value **density**: a weighted histogram of the
/// operator's `n·m·rank` singular values over `[0, σ_max]`, produced by
/// the engine's density sweep (`SpectralPlan::density`) without ever
/// materializing the full spectrum — `O(bins)` state for an
/// `O(n·m·rank)`-value population, the regime the asymptotic-distribution
/// results (Yi 2020) address.
///
/// **Accuracy contract.** `sigma_max` is *exact* (a dedicated warm top-1
/// Krylov pass over the whole dual grid, top-k-grade accuracy). The bulk
/// is a census when `sample == 1`; for `sample > 1` only every
/// `sample`-th frequency row/column is solved and the histogram is an
/// estimate whose distribution-free 95% CDF error band is
/// [`Self::cdf_epsilon`] (Dvoretzky–Kiefer–Wolfowitz on the binned
/// count). `sigma_min_sampled` is the smallest *sampled* value — a Krylov
/// extremes pass cannot certify the small end, so it is labeled sampled
/// even in a census of a folded grid's solved half (where it is exact by
/// the mirror symmetry `σ(−θ) = σ(θ)`).
#[derive(Clone, Debug)]
pub struct SpectralDensity {
    /// Coarse dual-grid rows.
    pub n: usize,
    /// Coarse dual-grid columns.
    pub m: usize,
    /// Singular values per frequency (the block rank).
    pub per_freq: usize,
    /// Weighted counts over `bins.len()` equal-width bins spanning
    /// `[0, hi]`; values ≥ `hi` clamp into the last bin.
    pub bins: Vec<u64>,
    /// Histogram upper edge (= the exact `sigma_max`).
    pub hi: f64,
    /// Exact largest singular value (dedicated whole-grid top-1 pass).
    pub sigma_max: f64,
    /// Smallest singular value seen among sampled frequencies.
    pub sigma_min_sampled: f64,
    /// Frequencies actually solved by the density sweep.
    pub solved_freqs: u64,
    /// Frequencies accounted for in `bins` including conjugate-mirror
    /// weights (`== n·m` for a census).
    pub covered_freqs: u64,
    /// Total dual-grid frequencies (`n·m`).
    pub total_freqs: u64,
    /// Sub-lattice step the sweep used (1 = census).
    pub sample: u32,
    /// Solver iteration steps spent (extremes pass).
    pub iterations: u64,
    /// Aggregated convergence evidence from both passes — the same health
    /// rules as any spectrum (degraded densities are refused by caches).
    pub health: SpectrumHealth,
}

impl SpectralDensity {
    /// Total weighted count of binned singular values
    /// (`covered_freqs · per_freq`).
    pub fn count(&self) -> u64 {
        self.bins.iter().sum()
    }

    /// Fraction of the dual grid the histogram accounts for (mirror
    /// weights included): 1.0 for a census.
    pub fn sampled_fraction(&self) -> f64 {
        if self.total_freqs == 0 {
            return 1.0;
        }
        self.covered_freqs as f64 / self.total_freqs as f64
    }

    /// Distribution-free 95% error band on the empirical CDF when the
    /// grid was sub-sampled (Dvoretzky–Kiefer–Wolfowitz:
    /// `ε = √(ln 40 / 2N)` for `N` sampled values): the true CDF lies
    /// within `±ε` of the histogram's, so quantiles are bracketed by
    /// [`Self::quantile_bounds`]. A census has no sampling error — 0.0.
    pub fn cdf_epsilon(&self) -> f64 {
        if self.covered_freqs >= self.total_freqs {
            return 0.0;
        }
        let n = self.count();
        if n == 0 {
            return 1.0;
        }
        (40.0f64.ln() / (2.0 * n as f64)).sqrt()
    }

    /// The `q`-quantile (`q` from the bottom: `quantile(0.5)` is the
    /// median singular value) estimated from the histogram by a CDF walk
    /// with linear interpolation inside the crossing bin — accurate to
    /// one bin width (`hi / bins.len()`). The clamped ends return the
    /// known support directly — `0.0` and `hi` (the *exact* σ_max from
    /// the extremes pass) — rather than the extreme *sampled* bins, so
    /// [`Self::quantile_bounds`] stays an honest bracket when `q ± ε`
    /// runs off either end: past the last sampled value the empirical
    /// CDF carries no information, but no singular value exceeds σ_max.
    pub fn quantile(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 || self.bins.is_empty() {
            return 0.0;
        }
        let q = q.clamp(0.0, 1.0);
        if q <= 0.0 {
            return 0.0;
        }
        if q >= 1.0 {
            return self.hi;
        }
        let width = self.hi / self.bins.len() as f64;
        let target = q * total as f64;
        let mut below = 0u64;
        for (b, &c) in self.bins.iter().enumerate() {
            let upto = below + c;
            if (upto as f64) >= target {
                let frac = if c == 0 { 1.0 } else { (target - below as f64) / c as f64 };
                return (b as f64 + frac.clamp(0.0, 1.0)) * width;
            }
            below = upto;
        }
        self.hi
    }

    /// Quantile bracket honoring the sampling error bar:
    /// `(quantile(q − ε), quantile(q + ε))` with `ε =`
    /// [`Self::cdf_epsilon`]. For a census both ends collapse onto
    /// [`Self::quantile`].
    pub fn quantile_bounds(&self, q: f64) -> (f64, f64) {
        let eps = self.cdf_epsilon();
        (self.quantile(q - eps), self.quantile(q + eps))
    }

    /// Whether any contributing frequency ended degraded — same rule as
    /// [`Spectrum`]'s ([`SpectrumHealth::is_degraded`]).
    pub fn is_degraded(&self) -> bool {
        self.health.is_degraded()
    }

    /// Approximate heap + inline footprint, the unit the result caches
    /// budget by.
    pub fn approx_bytes(&self) -> usize {
        std::mem::size_of::<Self>() + self.bins.len() * std::mem::size_of::<u64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spectrum(values: Vec<f64>, r: usize) -> Spectrum {
        let f = values.len() / r;
        Spectrum {
            n: f,
            m: 1,
            c_out: r,
            c_in: r,
            per_freq: r,
            values,
            health: SpectrumHealth::default(),
        }
    }

    #[test]
    fn extremes_and_condition() {
        let s = spectrum(vec![3.0, 1.0, 4.0, 2.0], 2);
        assert_eq!(s.sigma_max(), 4.0);
        assert_eq!(s.sigma_min(), 1.0);
        assert_eq!(s.condition_number(), 4.0);
    }

    #[test]
    fn singular_operator_condition_infinite() {
        let s = spectrum(vec![1.0, 0.0], 1);
        assert!(s.condition_number().is_infinite());
    }

    #[test]
    fn partial_spectrum_reports_nan_extremes() {
        // 2 values per frequency retained out of rank 3: σ_min/cond are
        // undefined (the small end was never computed) and must say so.
        let s = Spectrum {
            n: 2,
            m: 1,
            c_out: 3,
            c_in: 3,
            per_freq: 2,
            values: vec![3.0, 2.0, 4.0, 1.0],
            health: SpectrumHealth::default(),
        };
        assert!(s.is_partial() && !s.is_full());
        assert_eq!(s.sigma_max(), 4.0, "σ_max is exact on a top-k spectrum");
        assert!(s.sigma_min().is_nan(), "σ_min must be NaN, not the smallest retained value");
        assert!(s.condition_number().is_nan());
        assert_eq!(s.min_stored(), 1.0, "the smallest *computed* value stays accessible");
    }

    #[test]
    fn sorted_desc() {
        let s = spectrum(vec![1.0, 3.0, 2.0, 0.5], 2);
        assert_eq!(s.sorted_desc(), vec![3.0, 2.0, 1.0, 0.5]);
    }

    #[test]
    fn rank_with_tolerance() {
        let s = spectrum(vec![10.0, 1.0, 1e-12, 5.0], 2);
        assert_eq!(s.rank(1e-10), 3);
    }

    #[test]
    fn divergence_zero_for_identical() {
        let a = vec![5.0, 3.0, 1.0];
        assert_eq!(Spectrum::divergence(&a, &a), 0.0);
    }

    #[test]
    fn divergence_scales() {
        let a = vec![2.0, 2.0, 2.0, 2.0];
        let b = vec![1.0, 1.0, 1.0, 1.0];
        // gap = 1.0 everywhere, scale = 2 → 0.5
        assert!((Spectrum::divergence(&a, &b) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn divergence_handles_different_lengths() {
        let a = vec![1.0; 100];
        let b = vec![1.0; 73];
        assert!(Spectrum::divergence(&a, &b) < 1e-12);
    }

    #[test]
    fn mirror_freq_is_an_involution() {
        for &(n, m) in &[(4usize, 4usize), (5, 7), (1, 6), (6, 1), (2, 2), (1, 1)] {
            for f in 0..n * m {
                let fm = mirror_freq(n, m, f);
                assert!(fm < n * m);
                assert_eq!(mirror_freq(n, m, fm), f, "{n}x{m} f={f}");
            }
        }
    }

    #[test]
    fn folded_freqs_counts_half_plus_self_paired() {
        for &(n, m) in &[
            (4usize, 4usize),
            (5, 5),
            (4, 5),
            (5, 4),
            (1, 1),
            (2, 2),
            (1, 6),
            (6, 1),
            (8, 8),
            (64, 64),
        ] {
            let self_paired = (0..n * m).filter(|&f| mirror_freq(n, m, f) == f).count();
            assert_eq!(
                folded_freqs(n, m),
                (n * m + self_paired) / 2,
                "{n}x{m}: {} self-paired",
                self_paired
            );
        }
        // The DC point is always self-paired; even axes add Nyquist lines.
        assert_eq!(folded_freqs(64, 64), 2050);
    }

    #[test]
    fn mirror_fill_copies_conjugate_partners() {
        for &(n, m, r) in &[(5usize, 4usize, 2usize), (4, 4, 1), (6, 5, 3), (1, 4, 2)] {
            // Seed every canonical frequency with a distinct value, poison
            // the rest, then assert the poison is replaced by the mirror.
            let mut values = vec![f64::NAN; n * m * r];
            for f in 0..n * m {
                if mirror_freq(n, m, f) >= f {
                    for j in 0..r {
                        values[f * r + j] = (f * r + j) as f64;
                    }
                }
            }
            mirror_fill(n, m, r, &mut values);
            for f in 0..n * m {
                let canon = f.min(mirror_freq(n, m, f));
                for j in 0..r {
                    assert_eq!(
                        values[f * r + j],
                        (canon * r + j) as f64,
                        "{n}x{m} r={r} f={f} j={j}"
                    );
                }
            }
        }
    }

    #[test]
    fn health_absorb_and_merge() {
        let mut h = SpectrumHealth::default();
        h.absorb(true, false, 0, 1e-14);
        h.absorb(true, true, 2, 1e-9);
        h.absorb(false, true, 3, 0.5);
        assert_eq!(h.converged_freqs, 1);
        assert_eq!(h.retried_freqs, 1);
        assert_eq!(h.degraded_freqs, 1);
        assert_eq!(h.escalations, 5);
        assert_eq!(h.worst_residual, 0.5);
        assert!(h.is_degraded());
        let mut sum = SpectrumHealth::clean(4);
        assert!(!sum.is_degraded());
        sum.merge(&h);
        assert_eq!(sum.converged_freqs, 5);
        assert_eq!(sum.degraded_freqs, 1);
        assert_eq!(sum.worst_residual, 0.5);
    }

    #[test]
    fn conj_factor_conjugates_entries() {
        use crate::numeric::Pcg64;
        let mut rng = Pcg64::seeded(77);
        let a = CMat::random_normal(3, 2, &mut rng);
        let c = conj_factor(&a);
        for i in 0..3 {
            for j in 0..2 {
                let want = a[(i, j)].conj();
                let got = c[(i, j)];
                assert!((got - want).abs() == 0.0, "({i},{j})");
            }
        }
    }

    fn density(bins: Vec<u64>, hi: f64, covered: u64, total: u64) -> SpectralDensity {
        let count: u64 = bins.iter().sum();
        SpectralDensity {
            n: 1,
            m: total as usize,
            per_freq: if covered > 0 { (count / covered.max(1)) as usize } else { 1 },
            bins,
            hi,
            sigma_max: hi,
            sigma_min_sampled: 0.0,
            solved_freqs: covered,
            covered_freqs: covered,
            total_freqs: total,
            sample: if covered >= total { 1 } else { 2 },
            iterations: 0,
            health: SpectrumHealth::default(),
        }
    }

    #[test]
    fn density_quantiles_walk_the_cdf() {
        // 4 bins over [0, 8]: counts 1, 1, 1, 1 — a uniform staircase.
        let d = density(vec![1, 1, 1, 1], 8.0, 4, 4);
        assert_eq!(d.count(), 4);
        assert_eq!(d.sampled_fraction(), 1.0);
        assert_eq!(d.cdf_epsilon(), 0.0, "census has no sampling error");
        assert_eq!(d.quantile(0.0), 0.0);
        assert_eq!(d.quantile(1.0), 8.0);
        // quantile(0.5) → 2 of 4 values, crossing ends exactly at bin 1's
        // upper edge: (1 + 1.0)·2.0 = 4.0.
        assert!((d.quantile(0.5) - 4.0).abs() < 1e-12);
        assert!((d.quantile(0.25) - 2.0).abs() < 1e-12);
        let (lo, hi) = d.quantile_bounds(0.5);
        assert_eq!((lo, hi), (d.quantile(0.5), d.quantile(0.5)));
    }

    #[test]
    fn density_sampling_reports_dkw_band() {
        let d = density(vec![10, 10, 10, 10], 4.0, 20, 80);
        assert_eq!(d.sampled_fraction(), 0.25);
        let eps = d.cdf_epsilon();
        let want = (40.0f64.ln() / 80.0).sqrt();
        assert!((eps - want).abs() < 1e-12, "{eps} vs {want}");
        let (lo, hi) = d.quantile_bounds(0.5);
        assert!(lo < d.quantile(0.5) && d.quantile(0.5) < hi);
        assert!(!d.is_degraded());
        assert!(d.approx_bytes() >= 4 * 8);
    }

    #[test]
    fn density_empty_and_zero_edge_cases() {
        let d = density(vec![0, 0], 0.0, 0, 4);
        assert_eq!(d.count(), 0);
        assert_eq!(d.quantile(0.5), 0.0);
        assert_eq!(d.cdf_epsilon(), 1.0, "no data: the band is vacuous");
    }
}
