//! The LFA SVD pipeline (Algorithm 1 of the paper): symbols → per-frequency
//! SVD → full spectrum, with a timed variant that separates the two stages
//! exactly as Tables III/IV do (`s_F` vs `s_SVD`).
//!
//! Every entry point here is a thin wrapper over the planned execution core
//! in [`crate::engine`]: a [`SpectralPlan`] is built (phase tables +
//! workspace pool), executed, and dropped. Callers that compute the same
//! layer's spectrum repeatedly (training-loop clipping, repeated audits)
//! should hold a [`SpectralPlan`] themselves and call `execute()` on it —
//! plan-once/execute-many skips the planning cost and all per-call
//! allocation.

use super::spectrum::{FullSvd, Spectrum, SpectrumHealth};
use super::symbol::{BlockLayout, SymbolGrid};
use crate::conv::ConvKernel;
use crate::engine::{SpectralPlan, SpectrumRequest, Workspace};
use crate::linalg::jacobi_svd;
use crate::numeric::{C64, CMat};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Which per-block solver to use for the `c_out×c_in` SVDs.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum BlockSolver {
    /// One-sided Jacobi on `A_k` (default; best accuracy).
    Jacobi,
    /// Hermitian Jacobi on the Gram matrix `A_kᴴA_k` (ablation; squares the
    /// condition number but is the shape the pure-HLO artifact uses).
    GramEigen,
}

/// Conjugate-pair frequency folding: whether full-grid executions solve
/// only a fundamental domain of the involution `θ → −θ` on the dual torus
/// and mirror the rest.
///
/// Real kernel weights give `A(−θ) = conj(A(θ))`, so the two frequencies
/// of a conjugate pair share the exact same singular values (and
/// conjugated singular vectors — see
/// [`crate::lfa::spectrum::mirror_fill`]). Folding halves the per-layer
/// SVD work; `Off` is the unfolded reference every folded path is
/// cross-checked against in tests and benches.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, Default)]
pub enum Fold {
    /// Fold whenever the symmetry holds. Kernels in this crate carry real
    /// weights, so this always folds — the default.
    #[default]
    Auto,
    /// Solve every frequency independently (reference / escape hatch).
    Off,
}

/// Numeric precision tier for the spectral engine.
///
/// Selects the scalar width the fused symbol→SVD hot loop runs at. All
/// *outputs* (spectra, cache entries, CLI reports) are f64 regardless of
/// tier — the tier controls the arithmetic, not the interface.
///
/// Paper error bounds: the LFA decomposition itself is exact (Theorem 1 —
/// the per-frequency symbols *are* the operator blocks), so precision only
/// enters through floating-point round-off in assembly and decomposition.
/// `F64` keeps the crate's ≤1e-12 verification thresholds; `F32` degrades
/// them to ~1e-4·σ_max (assembly + Jacobi round-off at ε≈1.2e-7, Gram-route
/// paths worse — see docs/PAPER_MAP.md); `F32Refined` restores ≤1e-12 by
/// polishing every frequency against an exactly-assembled f64 block.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, Default)]
pub enum Precision {
    /// Full double precision everywhere (default).
    #[default]
    F64,
    /// Single precision end-to-end: f32 phase tables, f32 weights, f32
    /// solves; values widened to f64 at the output boundary. Roughly
    /// 1e-4·σ_max absolute accuracy; twice the SIMD lane width.
    F32,
    /// f32 sweep plus one f64 refinement pass per frequency: the f32
    /// rotations warm-start an exactly-assembled f64 polish, recovering
    /// the ≤1e-12 guarantee at a fraction of the full f64 cost.
    F32Refined,
}

impl Precision {
    /// Parse the CLI spelling (`f64`, `f32`, `f32-refined`).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "f64" => Some(Self::F64),
            "f32" => Some(Self::F32),
            "f32-refined" | "f32_refined" => Some(Self::F32Refined),
            _ => None,
        }
    }

    /// Canonical CLI/report spelling.
    pub fn name(self) -> &'static str {
        match self {
            Self::F64 => "f64",
            Self::F32 => "f32",
            Self::F32Refined => "f32-refined",
        }
    }
}

impl std::fmt::Display for Precision {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Options for the LFA pipeline.
#[derive(Clone, Copy, Debug)]
pub struct LfaOptions {
    pub layout: BlockLayout,
    pub solver: BlockSolver,
    /// Worker threads: `0` = auto (`available_parallelism`), `1` = serial.
    /// Frequencies are embarrassingly parallel. The same convention applies
    /// in the scheduler and the CLI (see [`crate::engine::resolve_threads`]).
    pub threads: usize,
    /// Conjugate-pair frequency folding (default [`Fold::Auto`]: solve the
    /// fundamental domain of `θ → −θ`, mirror the conjugate half).
    pub folding: Fold,
    /// Scalar width of the per-frequency hot loop (default
    /// [`Precision::F64`]). Outputs are always f64.
    pub precision: Precision,
}

impl Default for LfaOptions {
    fn default() -> Self {
        Self {
            layout: BlockLayout::BlockContiguous,
            solver: BlockSolver::Jacobi,
            threads: 0,
            folding: Fold::Auto,
            precision: Precision::F64,
        }
    }
}

/// Stage timing split reported by the `_timed` variants (Table III/IV).
#[derive(Clone, Copy, Debug, Default)]
pub struct StageTiming {
    /// Transform time `s_F` (symbol computation / FFT).
    pub transform: Duration,
    /// Layout-conversion time `s_copy` (zero when no conversion happens).
    pub copy: Duration,
    /// Per-block SVD time `s_SVD`.
    pub svd: Duration,
}

impl StageTiming {
    pub fn total(&self) -> Duration {
        self.transform + self.copy + self.svd
    }
}

/// Singular values of the convolution on an `n×m` grid via LFA.
///
/// Builds a [`SpectralPlan`] and executes it once (fused symbol→SVD, no
/// intermediate symbol grid). Hold a plan yourself for repeated spectra.
pub fn singular_values(kernel: &ConvKernel, n: usize, m: usize, opts: LfaOptions) -> Spectrum {
    SpectralPlan::new(kernel, n, m, opts).execute()
}

/// Timed variant separating `s_F` and `s_SVD` (Table III). Unlike
/// [`singular_values`] this materializes the symbol grid between the stages
/// so the two timings are observable — exactly the paper's measurement.
///
/// Materialized symbol grids are only defined for forward ungrouped
/// kernels (`groups == 1`, not transposed; dilation is fine — see
/// [`SpectralPlan::compute_symbols`]); structured kernels take the fused
/// [`singular_values`] path instead.
pub fn singular_values_timed(
    kernel: &ConvKernel,
    n: usize,
    m: usize,
    opts: LfaOptions,
) -> (Spectrum, StageTiming) {
    let plan = SpectralPlan::new(kernel, n, m, opts);
    let t0 = Instant::now();
    let grid = plan.compute_symbols();
    let transform = t0.elapsed();
    let t1 = Instant::now();
    let (values, health) = svd_pass(&grid, opts);
    let svd = t1.elapsed();
    (
        Spectrum {
            n,
            m,
            c_out: kernel.c_out,
            c_in: kernel.c_in,
            per_freq: kernel.c_out.min(kernel.c_in),
            values,
            health,
        },
        StageTiming { transform, copy: Duration::ZERO, svd },
    )
}

/// Run the per-block singular value pass over an existing symbol grid.
/// Exposed so the FFT baseline can share the identical SVD stage (keeping
/// the Table III comparison honest: only the transform differs). Uses the
/// same per-worker [`Workspace`]s as the planned path — one scratch set per
/// worker, zero allocation per frequency. Returns the values plus the
/// pass's aggregated [`SpectrumHealth`] (certificates only — the baseline
/// stage reports but does not escalate; the planned engine's ladder lives
/// in [`SpectralPlan`]).
pub fn svd_pass(grid: &SymbolGrid, opts: LfaOptions) -> (Vec<f64>, SpectrumHealth) {
    let r = grid.c_out.min(grid.c_in);
    let freqs = grid.freqs();
    let mut values = vec![0.0f64; freqs * r];
    let threads = crate::engine::resolve_threads(opts.threads).min(freqs.max(1));
    if threads <= 1 {
        let mut ws = Workspace::for_block(grid.c_out, grid.c_in, 1);
        let health = svd_pass_range(grid, opts, 0, freqs, &mut ws, &mut values);
        return (values, health);
    }
    let chunk = freqs.div_ceil(threads);
    let agg = Mutex::new(SpectrumHealth::default());
    let agg_ref = &agg;
    std::thread::scope(|s| {
        let mut rest: &mut [f64] = &mut values;
        let mut lo = 0usize;
        while lo < freqs {
            let hi = (lo + chunk).min(freqs);
            let (head, tail) = std::mem::take(&mut rest).split_at_mut((hi - lo) * r);
            rest = tail;
            s.spawn(move || {
                let mut ws = Workspace::for_block(grid.c_out, grid.c_in, 1);
                let health = svd_pass_range(grid, opts, lo, hi, &mut ws, head);
                agg_ref.lock().unwrap().merge(&health);
            });
            lo = hi;
        }
    });
    (values, agg.into_inner().unwrap())
}

/// SVD the blocks `[f_lo, f_hi)`; writes into `out[(f−f_lo)·r ..]`.
/// Honors `opts.precision`: the grid's f64 blocks are narrowed for the
/// `F32` tier and refined against for `F32Refined`. Returns the range's
/// aggregated certificates.
fn svd_pass_range(
    grid: &SymbolGrid,
    opts: LfaOptions,
    f_lo: usize,
    f_hi: usize,
    ws: &mut Workspace,
    out: &mut [f64],
) -> SpectrumHealth {
    let r = grid.c_out.min(grid.c_in);
    let mut health = SpectrumHealth::default();
    for f in f_lo..f_hi {
        grid.block_into(f, &mut ws.block);
        let dst = &mut out[(f - f_lo) * r..(f - f_lo + 1) * r];
        let cert = match opts.precision {
            Precision::F64 => ws.solve_block(opts.solver, grid.c_out, grid.c_in, dst),
            Precision::F32 => {
                for (d, s) in ws.block32.iter_mut().zip(ws.block.iter()) {
                    *d = s.to_c32();
                }
                ws.solve_block32(opts.solver, grid.c_out, grid.c_in, dst)
            }
            Precision::F32Refined => ws.solve_block_refined(grid.c_out, grid.c_in, dst),
        };
        health.absorb(cert.converged, cert.restarted, 0, cert.residual);
    }
    health
}

/// Full SVD with per-frequency factors `U_k, Σ_k, V_k`.
pub fn svd_full(kernel: &ConvKernel, n: usize, m: usize, opts: LfaOptions) -> FullSvd {
    SpectralPlan::new(kernel, n, m, opts).full_svd()
}

/// Full SVD from an existing symbol grid.
pub fn svd_full_from_grid(grid: &SymbolGrid) -> FullSvd {
    let freqs = grid.freqs();
    let r = grid.c_out.min(grid.c_in);
    let mut u = Vec::with_capacity(freqs);
    let mut v = Vec::with_capacity(freqs);
    let mut values = vec![0.0f64; freqs * r];
    let mut health = SpectrumHealth::default();
    for f in 0..freqs {
        let block = grid.block(f);
        let dec = jacobi_svd::svd(&block);
        health.absorb(dec.cert.converged, dec.cert.restarted, 0, dec.cert.residual);
        values[f * r..(f + 1) * r].copy_from_slice(&dec.s[..r]);
        u.push(dec.u);
        v.push(dec.v);
    }
    FullSvd {
        n: grid.n,
        m: grid.m,
        c_out: grid.c_out,
        c_in: grid.c_in,
        u,
        sigma: Spectrum {
            n: grid.n,
            m: grid.m,
            c_out: grid.c_out,
            c_in: grid.c_in,
            per_freq: r,
            values,
            health,
        },
        v,
    }
}

/// Streaming interface: compute the singular values for the frequency-row
/// tile `[row_lo, row_hi)` only, returning `(row_hi−row_lo)·m·r` values.
/// Symbols for the tile are computed on the fly and discarded — memory
/// stays proportional to the tile.
///
/// NOTE: this builds a throwaway plan per call. The coordinator shares one
/// [`SpectralPlan`] across all of a job's tiles instead (see
/// `coordinator::scheduler`), which is the right shape whenever more than
/// one tile of the same layer is computed.
pub fn tile_singular_values(
    kernel: &ConvKernel,
    n: usize,
    m: usize,
    row_lo: usize,
    row_hi: usize,
    solver: BlockSolver,
) -> Vec<f64> {
    // Folding is off: a tile is an arbitrary row range of the full grid,
    // and its caller stitches tiles without a mirror pass — every column
    // of every requested row must be solved directly.
    let opts = LfaOptions { solver, threads: 1, folding: Fold::Off, ..Default::default() };
    let plan = SpectralPlan::new(kernel, n, m, opts);
    let r = kernel.c_out.min(kernel.c_in_total());
    let mut values = vec![0.0f64; (row_hi - row_lo) * m * r];
    plan.execute_request_rows_pooled(SpectrumRequest::Full, row_lo, row_hi, &mut values);
    values
}

/// Frobenius-norm identity `Σσ² = n·m·‖W‖_F²` — used as a cheap runtime
/// verification by the coordinator (periodic BC). Holds exactly only when
/// the kernel fits in the grid (`kh ≤ n`, `kw ≤ m`): larger kernels wrap and
/// colliding taps accumulate, adding cross terms to the left side.
pub fn frobenius_check(kernel: &ConvKernel, n: usize, m: usize, spectrum: &Spectrum) -> f64 {
    frobenius_check_strided(kernel, n, m, 1, spectrum)
}

/// [`frobenius_check`] for the strided operator `C = D_s ∘ A` on an `n×m`
/// fine grid. Each coarse block is the `1/s`-scaled concatenation of its
/// `s²` aliasing fine symbols, so summing `‖block‖²` over the `(n/s)·(m/s)`
/// coarse frequencies covers every fine symbol once at weight `1/s²`:
/// `Σσ² = n·m·‖W‖_F²/s²`.
///
/// The identity is structure-oblivious: grouping only masks weights that
/// are zero anyway, transposition preserves singular values, and dilation
/// relocates taps without changing `‖W‖_F` — so the check applies to every
/// structured variant, with the same caveat that *distinct* taps must stay
/// distinct on the torus (`dilation·(kh−1) < n`, `dilation·(kw−1) < m`).
pub fn frobenius_check_strided(
    kernel: &ConvKernel,
    n: usize,
    m: usize,
    s: usize,
    spectrum: &Spectrum,
) -> f64 {
    let lhs: f64 = spectrum.values.iter().map(|v| v * v).sum();
    let rhs = (n * m) as f64 / (s * s) as f64 * kernel.frobenius_norm().powi(2);
    ((lhs - rhs) / rhs.max(1e-300)).abs()
}

/// Apply a spectral transfer function `σ ↦ g(σ)` per frequency, rebuilding
/// the symbol grid `U_k g(Σ_k) V_kᴴ`. The workhorse behind clipping,
/// low-rank truncation and the pseudo-inverse (`spectral` module).
pub fn map_singular_values<F: Fn(f64) -> f64>(svd: &FullSvd, g: F) -> SymbolGrid {
    let freqs = svd.sigma.n * svd.sigma.m;
    let r = svd.sigma.rank_per_freq();
    let mut grid = SymbolGrid::zeros(
        svd.n,
        svd.m,
        svd.c_out,
        svd.c_in,
        BlockLayout::BlockContiguous,
    );
    for f in 0..freqs {
        let s = svd.sigma.at(f);
        let u = &svd.u[f];
        let v = &svd.v[f];
        let mut us = CMat::zeros(u.rows, r);
        for i in 0..u.rows {
            for j in 0..r {
                us[(i, j)] = u[(i, j)].scale(g(s[j]));
            }
        }
        let block = us.matmul(&v.hermitian());
        grid.set_block(f, &block);
    }
    grid
}

/// Total FLOP estimate for the LFA route (Table I: `O(n·m·c³)`), used by the
/// complexity regression tests.
pub fn flops_estimate(n: usize, m: usize, c_out: usize, c_in: usize, kh: usize, kw: usize) -> f64 {
    let c = c_out.min(c_in) as f64;
    let transform = (n * m * c_out * c_in * kh * kw) as f64 * 6.0;
    // One-sided Jacobi: ~constant sweeps × n(n-1)/2 rotations × 6m flops each.
    let svd = (n * m) as f64 * (8.0 * c * c * (c_out.max(c_in) as f64) * 6.0);
    transform + svd
}

/// Scratch-free singular values from a raw block (helper shared with the
/// runtime verification path).
pub fn block_singular_values(block_data: &[C64], c_out: usize, c_in: usize) -> Vec<f64> {
    let mut block = CMat::zeros(c_out, c_in);
    block.data.copy_from_slice(block_data);
    jacobi_svd::singular_values(&block)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lfa::symbol::compute_symbols_parallel;
    use crate::numeric::Pcg64;

    #[test]
    fn one_by_one_kernel_spectrum_is_channel_matrix() {
        // 1x1 conv: every frequency has the same symbol = the channel matrix.
        let mut rng = Pcg64::seeded(110);
        let k = ConvKernel::random_he(3, 3, 1, 1, &mut rng);
        let s = singular_values(&k, 4, 4, LfaOptions::default());
        let mut chan = CMat::zeros(3, 3);
        for o in 0..3 {
            for i in 0..3 {
                chan[(o, i)] = C64::real(k.get(o, i, 0, 0));
            }
        }
        let want = jacobi_svd::singular_values(&chan);
        for f in 0..16 {
            for (a, b) in s.at(f).iter().zip(&want) {
                assert!((a - b).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn frobenius_identity_holds() {
        let mut rng = Pcg64::seeded(111);
        let k = ConvKernel::random_he(4, 3, 3, 3, &mut rng);
        let s = singular_values(&k, 8, 6, LfaOptions::default());
        assert!(frobenius_check(&k, 8, 6, &s) < 1e-10);
    }

    #[test]
    fn strided_frobenius_identity_holds() {
        let mut rng = Pcg64::seeded(118);
        let k = ConvKernel::random_he(4, 2, 3, 3, &mut rng);
        let s = SpectralPlan::with_stride(&k, 8, 8, 2, LfaOptions::default()).execute();
        assert!(frobenius_check_strided(&k, 8, 8, 2, &s) < 1e-10);
    }

    #[test]
    fn solvers_agree() {
        let mut rng = Pcg64::seeded(112);
        let k = ConvKernel::random_he(4, 4, 3, 3, &mut rng);
        let s1 = singular_values(
            &k,
            6,
            6,
            LfaOptions { solver: BlockSolver::Jacobi, ..Default::default() },
        );
        let s2 = singular_values(
            &k,
            6,
            6,
            LfaOptions { solver: BlockSolver::GramEigen, ..Default::default() },
        );
        for (a, b) in s1.values.iter().zip(&s2.values) {
            assert!((a - b).abs() < 1e-7, "{a} vs {b}");
        }
    }

    #[test]
    fn parallel_agrees_with_serial() {
        let mut rng = Pcg64::seeded(113);
        let k = ConvKernel::random_he(3, 3, 3, 3, &mut rng);
        let s1 = singular_values(&k, 12, 12, LfaOptions::default());
        let s4 = singular_values(&k, 12, 12, LfaOptions { threads: 4, ..Default::default() });
        for (a, b) in s1.values.iter().zip(&s4.values) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn tile_interface_matches_full() {
        let mut rng = Pcg64::seeded(114);
        let k = ConvKernel::random_he(2, 3, 3, 3, &mut rng);
        let (n, m) = (8, 8);
        let full = singular_values(&k, n, m, LfaOptions::default());
        let r = full.rank_per_freq();
        for (lo, hi) in [(0, 3), (3, 8)] {
            let tile = tile_singular_values(&k, n, m, lo, hi, BlockSolver::Jacobi);
            assert_eq!(tile.len(), (hi - lo) * m * r);
            for (t, f) in tile.iter().zip(&full.values[lo * m * r..hi * m * r]) {
                assert!((t - f).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn full_svd_reconstructs_symbols() {
        let mut rng = Pcg64::seeded(115);
        let k = ConvKernel::random_he(3, 2, 3, 3, &mut rng);
        let grid = compute_symbols_parallel(&k, 5, 5, BlockLayout::BlockContiguous, 1);
        let svd = svd_full_from_grid(&grid);
        for f in 0..25 {
            let recon = svd.symbol(f);
            assert!(recon.max_abs_diff(&grid.block(f)) < 1e-10, "f={f}");
        }
    }

    #[test]
    fn map_identity_preserves_grid() {
        let mut rng = Pcg64::seeded(116);
        let k = ConvKernel::random_he(2, 2, 3, 3, &mut rng);
        let grid = compute_symbols_parallel(&k, 4, 4, BlockLayout::BlockContiguous, 1);
        let svd = svd_full_from_grid(&grid);
        let grid2 = map_singular_values(&svd, |s| s);
        assert!(grid.max_abs_diff(&grid2) < 1e-10);
    }

    #[test]
    fn timed_stages_are_nonzero() {
        let mut rng = Pcg64::seeded(117);
        let k = ConvKernel::random_he(4, 4, 3, 3, &mut rng);
        let (_, t) = singular_values_timed(&k, 16, 16, LfaOptions::default());
        assert!(t.transform > Duration::ZERO);
        assert!(t.svd > Duration::ZERO);
    }
}
