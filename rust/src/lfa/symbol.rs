//! Symbol computation — the heart of the LFA approach (Algorithm 1).
//!
//! For each frequency `k = (i/n, j/m)` on the dual torus `T*_{n,m}`, the
//! symbol of the convolution `A` is the `c_out×c_in` complex matrix
//!
//! ```text
//!   A_k = Σ_{y∈N} M_y · e^{2πi⟨k,y⟩}
//! ```
//!
//! computed in `O(c_out·c_in·k_h·k_w)` per frequency — **independent of
//! `n, m`** — versus the FFT route's `O(log(nm))` amortized per entry. Two
//! structural optimizations (both recorded in DESIGN.md §Perf):
//!
//! 1. **Phase separability**: `e^{2πi(i·dy/n + j·dx/m)}` factors into two
//!    1-D phase tables (`n·kh + m·kw` trig evaluations total instead of
//!    `n·m·kh·kw`), leaving only complex multiplies in the inner loop.
//! 2. **Block-contiguous output**: symbols are written row-major per block,
//!    which Table IV shows is exactly the layout the downstream SVD wants —
//!    LFA gets it for free, the FFT does not.
//!
//! Since the [`crate::engine`] refactor the phase tables live in the
//! [`crate::engine::SpectralPlan`] (computed once per plan, reused across
//! executions); the grid builders here are thin wrappers over it. This
//! module keeps the [`SymbolGrid`] container, the per-frequency reference
//! [`symbol_at`], and the inverse transform [`taps_from_symbols`].

use crate::conv::ConvKernel;
use crate::numeric::{C64, CMat};
use std::f64::consts::PI;

/// Memory layout of a [`SymbolGrid`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum BlockLayout {
    /// `[freq][c_out][c_in]` — each block contiguous, row-major (LFA-natural).
    BlockContiguous,
    /// `[c_out][c_in][freq]` — planar, block elements strided by `n·m`
    /// (FFT-natural: each channel pair's transformed plane is contiguous).
    PlanarStrided,
}

/// All `n·m` symbols of a convolution on an `n×m` grid.
pub struct SymbolGrid {
    pub n: usize,
    pub m: usize,
    pub c_out: usize,
    pub c_in: usize,
    pub layout: BlockLayout,
    pub data: Vec<C64>,
}

impl SymbolGrid {
    pub fn zeros(n: usize, m: usize, c_out: usize, c_in: usize, layout: BlockLayout) -> Self {
        Self { n, m, c_out, c_in, layout, data: vec![C64::ZERO; n * m * c_out * c_in] }
    }

    /// Number of frequencies (= blocks).
    #[inline]
    pub fn freqs(&self) -> usize {
        self.n * self.m
    }

    /// Flat element index for frequency `f = i·m + j`, entry `(o, ic)`.
    #[inline(always)]
    pub fn idx(&self, f: usize, o: usize, ic: usize) -> usize {
        match self.layout {
            BlockLayout::BlockContiguous => (f * self.c_out + o) * self.c_in + ic,
            BlockLayout::PlanarStrided => (o * self.c_in + ic) * (self.n * self.m) + f,
        }
    }

    #[inline(always)]
    pub fn get(&self, f: usize, o: usize, ic: usize) -> C64 {
        self.data[self.idx(f, o, ic)]
    }

    #[inline(always)]
    pub fn set(&mut self, f: usize, o: usize, ic: usize, v: C64) {
        let i = self.idx(f, o, ic);
        self.data[i] = v;
    }

    /// Copy the block at frequency `f` into a dense matrix.
    pub fn block(&self, f: usize) -> CMat {
        let mut b = CMat::zeros(self.c_out, self.c_in);
        match self.layout {
            BlockLayout::BlockContiguous => {
                let base = f * self.c_out * self.c_in;
                b.data.copy_from_slice(&self.data[base..base + self.c_out * self.c_in]);
            }
            BlockLayout::PlanarStrided => {
                for o in 0..self.c_out {
                    for ic in 0..self.c_in {
                        b[(o, ic)] = self.get(f, o, ic);
                    }
                }
            }
        }
        b
    }

    /// Copy the block at frequency `f` into a caller-provided scratch slice
    /// (`c_out·c_in` long, row-major) without allocating.
    #[inline]
    pub fn block_into(&self, f: usize, out: &mut [C64]) {
        debug_assert_eq!(out.len(), self.c_out * self.c_in);
        match self.layout {
            BlockLayout::BlockContiguous => {
                let base = f * self.c_out * self.c_in;
                out.copy_from_slice(&self.data[base..base + out.len()]);
            }
            BlockLayout::PlanarStrided => {
                let nm = self.n * self.m;
                for (p, o) in out.iter_mut().enumerate() {
                    *o = self.data[p * nm + f];
                }
            }
        }
    }

    /// Write a block (row-major `c_out×c_in`) into frequency `f`.
    pub fn set_block(&mut self, f: usize, block: &CMat) {
        assert_eq!((block.rows, block.cols), (self.c_out, self.c_in));
        for o in 0..self.c_out {
            for ic in 0..self.c_in {
                self.set(f, o, ic, block[(o, ic)]);
            }
        }
    }

    /// Convert to the requested layout (the `s_copy` cost of Table IV).
    pub fn to_layout(&self, layout: BlockLayout) -> SymbolGrid {
        if layout == self.layout {
            return SymbolGrid {
                n: self.n,
                m: self.m,
                c_out: self.c_out,
                c_in: self.c_in,
                layout,
                data: self.data.clone(),
            };
        }
        let mut out = SymbolGrid::zeros(self.n, self.m, self.c_out, self.c_in, layout);
        for f in 0..self.freqs() {
            for o in 0..self.c_out {
                for ic in 0..self.c_in {
                    out.set(f, o, ic, self.get(f, o, ic));
                }
            }
        }
        out
    }

    /// Max entrywise distance to another grid (layout-independent).
    pub fn max_abs_diff(&self, other: &SymbolGrid) -> f64 {
        assert_eq!(
            (self.n, self.m, self.c_out, self.c_in),
            (other.n, other.m, other.c_out, other.c_in)
        );
        let mut worst = 0.0f64;
        for f in 0..self.freqs() {
            for o in 0..self.c_out {
                for ic in 0..self.c_in {
                    worst = worst.max((self.get(f, o, ic) - other.get(f, o, ic)).abs());
                }
            }
        }
        worst
    }
}

/// 1-D phase tables: `table[d][i] = e^{2πi · i·y_d / n}` for each distinct
/// tap offset `y_d` along one axis.
fn phase_table(n: usize, offsets: &[isize]) -> Vec<Vec<C64>> {
    offsets
        .iter()
        .map(|&dy| {
            (0..n)
                .map(|i| C64::cis(2.0 * PI * (i as f64) * (dy as f64) / (n as f64)))
                .collect()
        })
        .collect()
}

/// Compute the symbol at a single frequency `k = (ki/n, kj/m)` — line 5 of
/// Algorithm 1. `O(c_out·c_in·kh·kw)`, no dependence on `n, m`.
///
/// Structure-aware reference:
///
/// - **Groups** make the symbol *block-diagonal*: the returned matrix is
///   `c_out × c_in_total`, with group `gi`'s `(c_out/g) × c_in` block at
///   rows `gi·c_out/g..` and columns `gi·c_in..` and zeros elsewhere.
///   Depthwise (`g = c_out = c_in_total`) degenerates to a diagonal of
///   scalar symbols.
/// - **Dilation** multiplies every displacement by `d` — a pure phase
///   change `e^{2πi⟨k, d·y⟩}`; the flop count is unchanged.
/// - **Transposed** kernels return the *adjoint symbol* `A_kᴴ`
///   (`c_in_total × c_out`): per frequency the adjoint of a convolution is
///   the conjugate-transpose of its symbol, so `Aᵀ = VΣUᴴ` shares the
///   forward singular values with the vector roles swapped.
pub fn symbol_at(kernel: &ConvKernel, n: usize, m: usize, ki: usize, kj: usize) -> CMat {
    let (ar, ac) = (kernel.anchor.0 as isize, kernel.anchor.1 as isize);
    let d = kernel.dilation as isize;
    let gr = kernel.group_c_out();
    let mut b = CMat::zeros(kernel.c_out, kernel.c_in_total());
    for r in 0..kernel.kh {
        let dy = d * (r as isize - ar);
        let py = C64::cis(2.0 * PI * (ki as f64) * (dy as f64) / (n as f64));
        for c in 0..kernel.kw {
            let dx = d * (c as isize - ac);
            let px = C64::cis(2.0 * PI * (kj as f64) * (dx as f64) / (m as f64));
            let phase = py * px;
            for o in 0..kernel.c_out {
                let col0 = (o / gr) * kernel.c_in;
                for ic in 0..kernel.c_in {
                    let w = kernel.get(o, ic, r, c);
                    if w != 0.0 {
                        let v = b[(o, col0 + ic)];
                        b[(o, col0 + ic)] = v + phase.scale(w);
                    }
                }
            }
        }
    }
    if kernel.transposed { b.hermitian() } else { b }
}

/// Compute all `n·m` symbols (single-threaded). See
/// [`compute_symbols_parallel`] for the multi-core version.
///
/// Thin wrapper over [`crate::engine::SpectralPlan::compute_symbols`] — the
/// phase tables live in the plan; this builds a throwaway plan per call.
pub fn compute_symbols(kernel: &ConvKernel, n: usize, m: usize, layout: BlockLayout) -> SymbolGrid {
    compute_symbols_parallel(kernel, n, m, layout, 1)
}

/// Write a block-contiguous shard covering rows `[row_lo, row_hi)` into a
/// grid of either layout.
pub fn scatter_shard(grid: &mut SymbolGrid, row_lo: usize, row_hi: usize, shard: &[C64]) {
    let block_len = grid.c_out * grid.c_in;
    let m = grid.m;
    debug_assert_eq!(shard.len(), (row_hi - row_lo) * m * block_len);
    match grid.layout {
        BlockLayout::BlockContiguous => {
            let base = row_lo * m * block_len;
            grid.data[base..base + shard.len()].copy_from_slice(shard);
        }
        BlockLayout::PlanarStrided => {
            let nm = grid.n * grid.m;
            for f_local in 0..(row_hi - row_lo) * m {
                let f = row_lo * m + f_local;
                for p in 0..block_len {
                    grid.data[p * nm + f] = shard[f_local * block_len + p];
                }
            }
        }
    }
}

/// Multi-threaded symbol computation (`threads == 0` = auto): thin wrapper
/// over [`crate::engine::SpectralPlan::compute_symbols`], which shards
/// frequency rows across scoped workers against the planned phase tables.
pub fn compute_symbols_parallel(
    kernel: &ConvKernel,
    n: usize,
    m: usize,
    layout: BlockLayout,
    threads: usize,
) -> SymbolGrid {
    use crate::engine::SpectralPlan;
    use crate::lfa::svd::LfaOptions;
    let opts = LfaOptions { layout, threads, ..Default::default() };
    SpectralPlan::new(kernel, n, m, opts).compute_symbols()
}

/// Inverse transform: recover the multiplication operators `M_y` (i.e. the
/// weight taps) from a symbol grid by evaluating the inverse Fourier sum at
/// each displacement:
///
/// ```text
///   M_y = (1/nm) Σ_k A_k e^{−2πi⟨k,y⟩}
/// ```
///
/// If the grid came from a genuine `kh×kw` convolution this is exact; for a
/// modified grid (clipped/truncated spectrum) it is the least-squares
/// projection onto kernels of that support — the standard way to pull
/// spectral edits back into weight space.
///
/// Dense-only: the inverse sum assumes taps on the unit grid and a fully
/// mixed channel block, so it recovers a `groups = 1`, `dilation = 1`
/// forward kernel. Structured grids must be pulled back per group / on the
/// dilated tap lattice by the caller ([`SpectralPlan::compute_symbols`]
/// refuses to build grids for grouped or transposed kernels for the same
/// reason).
///
/// [`SpectralPlan::compute_symbols`]: crate::engine::SpectralPlan::compute_symbols
pub fn taps_from_symbols(
    grid: &SymbolGrid,
    kh: usize,
    kw: usize,
    anchor: (usize, usize),
) -> ConvKernel {
    let (n, m) = (grid.n, grid.m);
    let mut kernel = ConvKernel::zeros(grid.c_out, grid.c_in, kh, kw);
    kernel.anchor = anchor;
    let (ar, ac) = (anchor.0 as isize, anchor.1 as isize);
    let row_offsets: Vec<isize> = (0..kh as isize).map(|r| r - ar).collect();
    let col_offsets: Vec<isize> = (0..kw as isize).map(|c| c - ac).collect();
    // Conjugate tables give e^{−2πi…}.
    let py = phase_table(n, &row_offsets);
    let px = phase_table(m, &col_offsets);
    let scale = 1.0 / (n * m) as f64;
    for r in 0..kh {
        for c in 0..kw {
            for o in 0..grid.c_out {
                for ic in 0..grid.c_in {
                    let mut acc = C64::ZERO;
                    for i in 0..n {
                        let pyv = py[r][i].conj();
                        for j in 0..m {
                            let phase = pyv * px[c][j].conj();
                            acc = acc.mul_add(grid.get(i * m + j, o, ic), phase);
                        }
                    }
                    // Real weights: imaginary residue is numerical noise for
                    // grids originating from real kernels.
                    kernel.set(o, ic, r, c, acc.re * scale);
                }
            }
        }
    }
    kernel
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numeric::Pcg64;

    #[test]
    fn zero_frequency_is_tap_sum() {
        let mut rng = Pcg64::seeded(100);
        let k = ConvKernel::random_he(3, 2, 3, 3, &mut rng);
        let b = symbol_at(&k, 8, 8, 0, 0);
        for o in 0..3 {
            for i in 0..2 {
                let want: f64 = (0..3).flat_map(|r| (0..3).map(move |c| (r, c)))
                    .map(|(r, c)| k.get(o, i, r, c))
                    .sum();
                assert!((b[(o, i)].re - want).abs() < 1e-12);
                assert!(b[(o, i)].im.abs() < 1e-12);
            }
        }
    }

    #[test]
    fn identity_kernel_has_unit_symbols() {
        let mut k = ConvKernel::zeros(2, 2, 3, 3);
        k.set(0, 0, 1, 1, 1.0);
        k.set(1, 1, 1, 1, 1.0);
        let g = compute_symbols(&k, 4, 4, BlockLayout::BlockContiguous);
        for f in 0..16 {
            let b = g.block(f);
            assert!(b.max_abs_diff(&CMat::eye(2)) < 1e-12);
        }
    }

    #[test]
    fn grid_matches_symbol_at() {
        let mut rng = Pcg64::seeded(101);
        let k = ConvKernel::random_he(2, 3, 3, 3, &mut rng);
        let (n, m) = (5, 7);
        let g = compute_symbols(&k, n, m, BlockLayout::BlockContiguous);
        for i in 0..n {
            for j in 0..m {
                let want = symbol_at(&k, n, m, i, j);
                let got = g.block(i * m + j);
                assert!(got.max_abs_diff(&want) < 1e-12, "({i},{j})");
            }
        }
    }

    #[test]
    fn layouts_agree() {
        let mut rng = Pcg64::seeded(102);
        let k = ConvKernel::random_he(3, 3, 3, 3, &mut rng);
        let a = compute_symbols(&k, 6, 4, BlockLayout::BlockContiguous);
        let b = compute_symbols(&k, 6, 4, BlockLayout::PlanarStrided);
        assert!(a.max_abs_diff(&b) < 1e-14);
        let c = b.to_layout(BlockLayout::BlockContiguous);
        assert_eq!(c.layout, BlockLayout::BlockContiguous);
        assert!(a.max_abs_diff(&c) < 1e-14);
    }

    #[test]
    fn parallel_matches_serial() {
        let mut rng = Pcg64::seeded(103);
        let k = ConvKernel::random_he(4, 4, 3, 3, &mut rng);
        for layout in [BlockLayout::BlockContiguous, BlockLayout::PlanarStrided] {
            let serial = compute_symbols(&k, 16, 16, layout);
            for threads in [2, 3, 8] {
                let par = compute_symbols_parallel(&k, 16, 16, layout, threads);
                assert!(serial.max_abs_diff(&par) < 1e-15, "{layout:?} x{threads}");
            }
        }
    }

    #[test]
    fn symbols_roundtrip_to_taps() {
        let mut rng = Pcg64::seeded(104);
        let k = ConvKernel::random_he(2, 2, 3, 3, &mut rng);
        let g = compute_symbols(&k, 8, 8, BlockLayout::BlockContiguous);
        let k2 = taps_from_symbols(&g, 3, 3, k.anchor);
        for (a, b) in k.data.iter().zip(&k2.data) {
            assert!((a - b).abs() < 1e-12, "{a} vs {b}");
        }
    }

    #[test]
    fn conjugate_symmetry_for_real_kernels() {
        // Real weights ⇒ A_{−k} = conj(A_k).
        let mut rng = Pcg64::seeded(105);
        let k = ConvKernel::random_he(2, 2, 3, 3, &mut rng);
        let (n, m) = (6, 6);
        let g = compute_symbols(&k, n, m, BlockLayout::BlockContiguous);
        for i in 0..n {
            for j in 0..m {
                let f = i * m + j;
                let fneg = ((n - i) % n) * m + (m - j) % m;
                let b = g.block(f);
                let bneg = g.block(fneg);
                for o in 0..2 {
                    for ic in 0..2 {
                        assert!((b[(o, ic)] - bneg[(o, ic)].conj()).abs() < 1e-12);
                    }
                }
            }
        }
    }

    #[test]
    fn grouped_symbol_is_block_diagonal() {
        let mut rng = Pcg64::seeded(108);
        let k = ConvKernel::random_he(6, 2, 3, 3, &mut rng).with_groups(3);
        let b = symbol_at(&k, 8, 8, 3, 5);
        assert_eq!((b.rows, b.cols), (6, 6));
        // Off-block entries vanish; on-block entries match the per-group
        // dense symbol of the extracted sub-kernel.
        for gi in 0..3 {
            let mut sub = ConvKernel::zeros(2, 2, 3, 3);
            for o in 0..2 {
                for i in 0..2 {
                    for r in 0..3 {
                        for c in 0..3 {
                            sub.set(o, i, r, c, k.get(gi * 2 + o, i, r, c));
                        }
                    }
                }
            }
            let bs = symbol_at(&sub, 8, 8, 3, 5);
            for o in 0..6 {
                for ic in 0..6 {
                    let inside = o / 2 == gi && ic / 2 == gi;
                    if inside {
                        assert!((b[(o, ic)] - bs[(o % 2, ic % 2)]).abs() < 1e-14);
                    } else if o / 2 == gi {
                        assert!(b[(o, ic)].abs() == 0.0, "off-block leak at ({o},{ic})");
                    }
                }
            }
        }
    }

    #[test]
    fn dilated_symbol_matches_spread_kernel() {
        // A d-dilated k×k kernel has the same symbol as the dense
        // (d·(k−1)+1)-wide kernel with the taps spread out.
        let mut rng = Pcg64::seeded(109);
        let k = ConvKernel::random_he(2, 3, 3, 3, &mut rng).with_dilation(2);
        let mut spread = ConvKernel::zeros(2, 3, 5, 5);
        for o in 0..2 {
            for i in 0..3 {
                for r in 0..3 {
                    for c in 0..3 {
                        spread.set(o, i, 2 * r, 2 * c, k.get(o, i, r, c));
                    }
                }
            }
        }
        for (ki, kj) in [(0, 0), (1, 3), (7, 2), (5, 5)] {
            let a = symbol_at(&k, 8, 8, ki, kj);
            let b = symbol_at(&spread, 8, 8, ki, kj);
            assert!(a.max_abs_diff(&b) < 1e-13, "({ki},{kj})");
        }
    }

    #[test]
    fn transposed_symbol_is_adjoint() {
        let mut rng = Pcg64::seeded(110);
        let k = ConvKernel::random_he(2, 3, 3, 3, &mut rng);
        let kt = k.clone().with_transposed(true);
        let a = symbol_at(&k, 6, 6, 2, 4);
        let at = symbol_at(&kt, 6, 6, 2, 4);
        assert_eq!((at.rows, at.cols), (3, 2));
        for o in 0..2 {
            for ic in 0..3 {
                assert!((at[(ic, o)] - a[(o, ic)].conj()).abs() < 1e-15);
            }
        }
    }

    #[test]
    fn block_into_matches_block() {
        let mut rng = Pcg64::seeded(106);
        let k = ConvKernel::random_he(3, 2, 3, 3, &mut rng);
        for layout in [BlockLayout::BlockContiguous, BlockLayout::PlanarStrided] {
            let g = compute_symbols(&k, 4, 4, layout);
            let mut scratch = vec![C64::ZERO; 6];
            for f in 0..16 {
                g.block_into(f, &mut scratch);
                let b = g.block(f);
                for o in 0..3 {
                    for ic in 0..2 {
                        assert_eq!(scratch[o * 2 + ic], b[(o, ic)]);
                    }
                }
            }
        }
    }

    #[test]
    fn non_square_grid() {
        let mut rng = Pcg64::seeded(107);
        let k = ConvKernel::random_he(2, 2, 3, 3, &mut rng);
        let g = compute_symbols(&k, 3, 9, BlockLayout::BlockContiguous);
        assert_eq!(g.freqs(), 27);
        let k2 = taps_from_symbols(&g, 3, 3, k.anchor);
        for (a, b) in k.data.iter().zip(&k2.data) {
            assert!((a - b).abs() < 1e-12);
        }
    }
}
