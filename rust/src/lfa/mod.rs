//! Local Fourier Analysis of convolutional mappings — the paper's core
//! contribution.
//!
//! - [`symbol`]: the [`SymbolGrid`] container, the per-frequency reference
//!   `A_k = Σ_y M_y e^{2πi⟨k,y⟩}` (Algorithm 1 line 5), and the inverse
//!   transform back to weight taps.
//! - [`spectrum`]: spectra and full per-frequency SVD containers.
//! - [`svd`]: the end-to-end pipeline with stage timing (Tables II–IV) and
//!   spectral transfer functions for the application modules.
//! - [`stride`]: the crystal-torus strided machinery (§III).
//!
//! All pipelines here execute through the planned core in
//! [`crate::engine`]; these modules define the math and the public API.

pub mod spectrum;
pub mod stride;
pub mod svd;
pub mod symbol;

pub use spectrum::{FullSvd, SpectralDensity, Spectrum, SpectrumHealth, TopKSvd};
pub use stride::{strided_plan, strided_singular_values, strided_symbol_at};
pub use svd::{
    singular_values, singular_values_timed, svd_full, tile_singular_values, BlockSolver, Fold,
    LfaOptions, Precision, StageTiming,
};
pub use symbol::{
    compute_symbols, compute_symbols_parallel, symbol_at, taps_from_symbols, BlockLayout,
    SymbolGrid,
};
