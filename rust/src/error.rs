//! Minimal error handling for the offline crate set (no `anyhow`).
//!
//! The whole system reports failures as human-readable strings with context
//! chains — there is no error taxonomy to match on, so a single string-backed
//! [`Error`] plus the [`Context`] extension trait covers every call site.
//! The [`crate::err!`] and [`crate::bail!`] macros mirror the `anyhow!` /
//! `bail!` idiom so call sites read the same as they would with the crate.

use std::fmt;

/// A string-backed error with a context chain folded into the message.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg(msg: impl Into<String>) -> Self {
        Self { msg: msg.into() }
    }

    /// Prepend a context line, `anyhow::Context`-style.
    pub fn context(self, msg: impl fmt::Display) -> Self {
        Self { msg: format!("{msg}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<String> for Error {
    fn from(msg: String) -> Self {
        Self { msg }
    }
}

impl From<&str> for Error {
    fn from(msg: &str) -> Self {
        Self { msg: msg.to_string() }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Self { msg: e.to_string() }
    }
}

/// Crate-wide result alias (defaults to [`Error`]).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `anyhow::Context`-alike for `Result` and `Option`.
pub trait Context<T> {
    /// Attach a fixed context message.
    fn context(self, msg: impl fmt::Display) -> Result<T>;
    /// Attach a lazily-built context message.
    fn with_context<S: fmt::Display>(self, f: impl FnOnce() -> S) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{msg}: {e}")))
    }

    fn with_context<S: fmt::Display>(self, f: impl FnOnce() -> S) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.ok_or_else(|| Error::msg(msg.to_string()))
    }

    fn with_context<S: fmt::Display>(self, f: impl FnOnce() -> S) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Build an [`Error`] from a format string: `err!("bad {x}")`.
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::error::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`]: `bail!("bad {x}")`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::err!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        Err(err!("inner {}", 42))
    }

    #[test]
    fn macros_and_context_chain() {
        let e = fails().context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner 42");
        let e2 = fails().with_context(|| format!("step {}", 1)).unwrap_err();
        assert_eq!(e2.to_string(), "step 1: inner 42");
    }

    #[test]
    fn option_context() {
        let none: Option<u8> = None;
        assert_eq!(none.context("missing").unwrap_err().to_string(), "missing");
        assert_eq!(Some(3u8).context("missing").unwrap(), 3);
    }

    #[test]
    fn bail_returns() {
        fn f(x: u8) -> Result<u8> {
            if x == 0 {
                bail!("zero");
            }
            Ok(x)
        }
        assert!(f(0).is_err());
        assert_eq!(f(2).unwrap(), 2);
    }

    #[test]
    fn io_error_converts() {
        fn f() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/path")?;
            Ok(s)
        }
        assert!(f().is_err());
    }
}
