//! Minimal error handling for the offline crate set (no `anyhow`).
//!
//! The whole system reports failures as human-readable strings with context
//! chains — plus a small [`ErrorKind`] taxonomy for the few failures the
//! coordinator must *dispatch on* (non-finite weights rejected at submit
//! time, spectra still degraded after the escalation ladder) so the daemon
//! can map them to distinct wire responses instead of string-matching.
//! The [`crate::err!`] and [`crate::bail!`] macros mirror the `anyhow!` /
//! `bail!` idiom so call sites read the same as they would with the crate.

use std::fmt;

/// Typed classification of the failures the numerical-health layer needs
/// to route differently. Everything else is [`ErrorKind::Generic`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ErrorKind {
    /// An ordinary string-backed failure.
    Generic,
    /// Kernel weights contained NaN/Inf — rejected before any tile ran.
    NonFiniteWeights {
        /// Layer (or kernel) name the bad weights belong to.
        layer: String,
        /// Number of non-finite entries found.
        count: usize,
    },
    /// A spectrum stayed degraded after the escalation ladder and the job
    /// ran under strict health.
    DegradedSpectrum {
        /// Job / layer identifier.
        job: String,
        /// Number of frequencies still unconverged.
        freqs: usize,
    },
}

/// A string-backed error with a context chain folded into the message and
/// an optional typed [`ErrorKind`] for dispatch.
pub struct Error {
    msg: String,
    kind: ErrorKind,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg(msg: impl Into<String>) -> Self {
        Self { msg: msg.into(), kind: ErrorKind::Generic }
    }

    /// Typed rejection of NaN/Inf kernel weights (screened at plan/submit
    /// time, before any frequency is solved).
    pub fn non_finite_weights(layer: impl Into<String>, count: usize) -> Self {
        let layer = layer.into();
        Self {
            msg: format!("layer '{layer}': {count} non-finite kernel weight(s) (NaN/Inf)"),
            kind: ErrorKind::NonFiniteWeights { layer, count },
        }
    }

    /// Typed strict-health failure: `freqs` frequencies of `job` remained
    /// unconverged after the escalation ladder.
    pub fn degraded_spectrum(job: impl Into<String>, freqs: usize) -> Self {
        let job = job.into();
        Self {
            msg: format!(
                "job '{job}': spectrum degraded — {freqs} frequenc{} unconverged after escalation",
                if freqs == 1 { "y" } else { "ies" }
            ),
            kind: ErrorKind::DegradedSpectrum { job, freqs },
        }
    }

    /// The typed classification (Generic for plain string errors).
    pub fn kind(&self) -> &ErrorKind {
        &self.kind
    }

    /// Prepend a context line, `anyhow::Context`-style. The typed kind
    /// survives the wrap.
    pub fn context(self, msg: impl fmt::Display) -> Self {
        Self { msg: format!("{msg}: {}", self.msg), kind: self.kind }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<String> for Error {
    fn from(msg: String) -> Self {
        Self::msg(msg)
    }
}

impl From<&str> for Error {
    fn from(msg: &str) -> Self {
        Self::msg(msg)
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Self::msg(e.to_string())
    }
}

/// Crate-wide result alias (defaults to [`Error`]).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `anyhow::Context`-alike for `Result` and `Option`.
pub trait Context<T> {
    /// Attach a fixed context message.
    fn context(self, msg: impl fmt::Display) -> Result<T>;
    /// Attach a lazily-built context message.
    fn with_context<S: fmt::Display>(self, f: impl FnOnce() -> S) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{msg}: {e}")))
    }

    fn with_context<S: fmt::Display>(self, f: impl FnOnce() -> S) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.ok_or_else(|| Error::msg(msg.to_string()))
    }

    fn with_context<S: fmt::Display>(self, f: impl FnOnce() -> S) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Build an [`Error`] from a format string: `err!("bad {x}")`.
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::error::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`]: `bail!("bad {x}")`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::err!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        Err(err!("inner {}", 42))
    }

    #[test]
    fn macros_and_context_chain() {
        let e = fails().context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner 42");
        let e2 = fails().with_context(|| format!("step {}", 1)).unwrap_err();
        assert_eq!(e2.to_string(), "step 1: inner 42");
    }

    #[test]
    fn option_context() {
        let none: Option<u8> = None;
        assert_eq!(none.context("missing").unwrap_err().to_string(), "missing");
        assert_eq!(Some(3u8).context("missing").unwrap(), 3);
    }

    #[test]
    fn bail_returns() {
        fn f(x: u8) -> Result<u8> {
            if x == 0 {
                bail!("zero");
            }
            Ok(x)
        }
        assert!(f(0).is_err());
        assert_eq!(f(2).unwrap(), 2);
    }

    #[test]
    fn typed_kinds_survive_context() {
        let e = Error::non_finite_weights("conv1", 3);
        assert_eq!(
            *e.kind(),
            ErrorKind::NonFiniteWeights { layer: "conv1".into(), count: 3 }
        );
        let wrapped = e.context("submit");
        assert!(wrapped.to_string().starts_with("submit: "));
        assert!(matches!(wrapped.kind(), ErrorKind::NonFiniteWeights { .. }));
        let d = Error::degraded_spectrum("job-7", 2);
        assert_eq!(*d.kind(), ErrorKind::DegradedSpectrum { job: "job-7".into(), freqs: 2 });
        assert!(d.to_string().contains("2 frequencies"));
        assert_eq!(*err!("plain").kind(), ErrorKind::Generic);
    }

    #[test]
    fn io_error_converts() {
        fn f() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/path")?;
            Ok(s)
        }
        assert!(f().is_err());
    }
}
