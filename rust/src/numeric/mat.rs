//! Dense real and complex matrices with explicit memory-layout control.
//!
//! The paper's Table IV shows that whether the per-frequency blocks are
//! stored row-major (contiguous) or in the FFT's natural planar layout has a
//! first-order effect on SVD runtime. Layout is therefore a visible property
//! of the matrix types here, not an implementation detail.
//!
//! Both matrix types are generic over the [`Real`] scalar width with an
//! `f64` default — `Mat`/`CMat` written anywhere in the crate mean the
//! double-precision instantiation, exactly as before the generic port,
//! while the f32 SIMD tier instantiates `CMat<f32>`.

use crate::numeric::complex::C;
use crate::numeric::real::Real;
use crate::numeric::rng::Pcg64;
use std::fmt;

/// Element-storage order of a dense matrix.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Layout {
    /// C order — rows are contiguous.
    RowMajor,
    /// Fortran order — columns are contiguous.
    ColMajor,
}

impl Layout {
    #[inline]
    pub fn index(self, rows: usize, cols: usize, r: usize, c: usize) -> usize {
        match self {
            Layout::RowMajor => r * cols + c,
            Layout::ColMajor => c * rows + r,
        }
    }
}

// ---------------------------------------------------------------------------
// Real dense matrix
// ---------------------------------------------------------------------------

/// Dense real matrix (`f64` unless instantiated otherwise).
#[derive(Clone, PartialEq)]
pub struct Mat<T = f64> {
    pub rows: usize,
    pub cols: usize,
    pub layout: Layout,
    pub data: Vec<T>,
}

impl<T: Real> Mat<T> {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, layout: Layout::RowMajor, data: vec![T::ZERO; rows * cols] }
    }

    pub fn zeros_with(rows: usize, cols: usize, layout: Layout) -> Self {
        Self { rows, cols, layout, data: vec![T::ZERO; rows * cols] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = T::ONE;
        }
        m
    }

    pub fn from_rows(rows: &[&[T]]) -> Self {
        let r = rows.len();
        let c = if r == 0 { 0 } else { rows[0].len() };
        let mut m = Self::zeros(r, c);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(row.len(), c, "ragged rows");
            for (j, &v) in row.iter().enumerate() {
                m[(i, j)] = v;
            }
        }
        m
    }

    pub fn random_normal(rows: usize, cols: usize, rng: &mut Pcg64) -> Self {
        let data = (0..rows * cols).map(|_| T::from_f64(rng.normal())).collect();
        Self { rows, cols, layout: Layout::RowMajor, data }
    }

    /// Widen/narrow every entry to another scalar width through `f64`.
    pub fn convert<U: Real>(&self) -> Mat<U> {
        Mat {
            rows: self.rows,
            cols: self.cols,
            layout: self.layout,
            data: self.data.iter().map(|&x| U::from_f64(x.to_f64())).collect(),
        }
    }

    #[inline(always)]
    pub fn idx(&self, r: usize, c: usize) -> usize {
        debug_assert!(r < self.rows && c < self.cols);
        self.layout.index(self.rows, self.cols, r, c)
    }

    /// Return a copy in the requested layout (no-op clone if it matches).
    pub fn to_layout(&self, layout: Layout) -> Self {
        if layout == self.layout {
            return self.clone();
        }
        let mut out = Self::zeros_with(self.rows, self.cols, layout);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out[(r, c)] = self[(r, c)];
            }
        }
        out
    }

    pub fn transpose(&self) -> Self {
        let mut out = Self::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out[(c, r)] = self[(r, c)];
            }
        }
        out
    }

    /// Plain triple-loop matmul (used by tests and small problems only).
    pub fn matmul(&self, other: &Mat<T>) -> Mat<T> {
        assert_eq!(self.cols, other.rows, "dim mismatch");
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == T::ZERO {
                    continue;
                }
                for j in 0..other.cols {
                    out[(i, j)] += a * other[(k, j)];
                }
            }
        }
        out
    }

    /// Matrix–vector product.
    pub fn matvec(&self, x: &[T]) -> Vec<T> {
        assert_eq!(self.cols, x.len());
        let mut y = vec![T::ZERO; self.rows];
        match self.layout {
            Layout::RowMajor => {
                for (i, yi) in y.iter_mut().enumerate() {
                    let row = &self.data[i * self.cols..(i + 1) * self.cols];
                    *yi = row.iter().zip(x).map(|(&a, &b)| a * b).sum();
                }
            }
            Layout::ColMajor => {
                for (j, &xj) in x.iter().enumerate() {
                    if xj == T::ZERO {
                        continue;
                    }
                    let col = &self.data[j * self.rows..(j + 1) * self.rows];
                    for (yi, &a) in y.iter_mut().zip(col) {
                        *yi += a * xj;
                    }
                }
            }
        }
        y
    }

    /// Transposed matrix–vector product `Aᵀ x`.
    pub fn matvec_t(&self, x: &[T]) -> Vec<T> {
        assert_eq!(self.rows, x.len());
        let mut y = vec![T::ZERO; self.cols];
        for i in 0..self.rows {
            let xi = x[i];
            if xi == T::ZERO {
                continue;
            }
            for j in 0..self.cols {
                y[j] += self[(i, j)] * xi;
            }
        }
        y
    }

    pub fn frobenius_norm(&self) -> T {
        self.data.iter().map(|&v| v * v).sum::<T>().sqrt()
    }

    pub fn max_abs_diff(&self, other: &Mat<T>) -> T {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let mut m = T::ZERO;
        for r in 0..self.rows {
            for c in 0..self.cols {
                m = m.max((self[(r, c)] - other[(r, c)]).abs());
            }
        }
        m
    }
}

impl<T: Real> std::ops::Index<(usize, usize)> for Mat<T> {
    type Output = T;
    #[inline(always)]
    fn index(&self, (r, c): (usize, usize)) -> &T {
        &self.data[self.idx(r, c)]
    }
}

impl<T: Real> std::ops::IndexMut<(usize, usize)> for Mat<T> {
    #[inline(always)]
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut T {
        let i = self.idx(r, c);
        &mut self.data[i]
    }
}

impl<T: Real> fmt::Debug for Mat<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} ({:?})", self.rows, self.cols, self.layout)?;
        let rmax = self.rows.min(8);
        let cmax = self.cols.min(8);
        for r in 0..rmax {
            write!(f, "  [")?;
            for c in 0..cmax {
                write!(f, "{:>10.4}", self[(r, c)])?;
            }
            writeln!(f, "{}]", if cmax < self.cols { " …" } else { "" })?;
        }
        if rmax < self.rows {
            writeln!(f, "  …")?;
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Complex dense matrix
// ---------------------------------------------------------------------------

/// Dense complex matrix over [`C<T>`] (`C64` unless instantiated otherwise).
#[derive(Clone, PartialEq)]
pub struct CMat<T = f64> {
    pub rows: usize,
    pub cols: usize,
    pub layout: Layout,
    pub data: Vec<C<T>>,
}

impl<T: Real> CMat<T> {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, layout: Layout::RowMajor, data: vec![C::ZERO; rows * cols] }
    }

    pub fn zeros_with(rows: usize, cols: usize, layout: Layout) -> Self {
        Self { rows, cols, layout, data: vec![C::ZERO; rows * cols] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = C::ONE;
        }
        m
    }

    pub fn from_real(m: &Mat<T>) -> Self {
        let mut out = Self::zeros_with(m.rows, m.cols, m.layout);
        for (dst, &src) in out.data.iter_mut().zip(&m.data) {
            *dst = C::real(src);
        }
        out
    }

    pub fn random_normal(rows: usize, cols: usize, rng: &mut Pcg64) -> Self {
        let data = (0..rows * cols)
            .map(|_| C::new(T::from_f64(rng.normal()), T::from_f64(rng.normal())))
            .collect();
        Self { rows, cols, layout: Layout::RowMajor, data }
    }

    /// Widen/narrow every entry to another scalar width through `f64`.
    pub fn convert<U: Real>(&self) -> CMat<U> {
        CMat {
            rows: self.rows,
            cols: self.cols,
            layout: self.layout,
            data: self.data.iter().map(|z| z.convert()).collect(),
        }
    }

    #[inline(always)]
    pub fn idx(&self, r: usize, c: usize) -> usize {
        debug_assert!(r < self.rows && c < self.cols);
        self.layout.index(self.rows, self.cols, r, c)
    }

    pub fn to_layout(&self, layout: Layout) -> Self {
        if layout == self.layout {
            return self.clone();
        }
        let mut out = Self::zeros_with(self.rows, self.cols, layout);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out[(r, c)] = self[(r, c)];
            }
        }
        out
    }

    /// Hermitian (conjugate) transpose.
    pub fn hermitian(&self) -> CMat<T> {
        let mut out = CMat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out[(c, r)] = self[(r, c)].conj();
            }
        }
        out
    }

    pub fn matmul(&self, other: &CMat<T>) -> CMat<T> {
        assert_eq!(self.cols, other.rows, "dim mismatch");
        let mut out = CMat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                for j in 0..other.cols {
                    let o = out.idx(i, j);
                    out.data[o] = out.data[o].mul_add(a, other[(k, j)]);
                }
            }
        }
        out
    }

    /// `Aᴴ A` — the Gram matrix (Hermitian positive semidefinite).
    pub fn gram(&self) -> CMat<T> {
        let n = self.cols;
        let mut g = CMat::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                let mut s = C::ZERO;
                for r in 0..self.rows {
                    s = s.mul_add(self[(r, i)].conj(), self[(r, j)]);
                }
                g[(i, j)] = s;
                g[(j, i)] = s.conj();
            }
        }
        g
    }

    pub fn matvec(&self, x: &[C<T>]) -> Vec<C<T>> {
        assert_eq!(self.cols, x.len());
        let mut y = vec![C::ZERO; self.rows];
        for r in 0..self.rows {
            let mut s = C::ZERO;
            for c in 0..self.cols {
                s = s.mul_add(self[(r, c)], x[c]);
            }
            y[r] = s;
        }
        y
    }

    pub fn frobenius_norm(&self) -> T {
        self.data.iter().map(|v| v.norm_sqr()).sum::<T>().sqrt()
    }

    pub fn max_abs_diff(&self, other: &CMat<T>) -> T {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let mut m = T::ZERO;
        for r in 0..self.rows {
            for c in 0..self.cols {
                m = m.max((self[(r, c)] - other[(r, c)]).abs());
            }
        }
        m
    }

    /// `‖AᴴA − I‖_∞` — deviation from having orthonormal columns.
    pub fn orthonormality_defect(&self) -> T {
        let g = self.gram();
        let mut m = T::ZERO;
        for r in 0..g.rows {
            for c in 0..g.cols {
                let want = if r == c { C::ONE } else { C::ZERO };
                m = m.max((g[(r, c)] - want).abs());
            }
        }
        m
    }
}

impl<T: Real> std::ops::Index<(usize, usize)> for CMat<T> {
    type Output = C<T>;
    #[inline(always)]
    fn index(&self, (r, c): (usize, usize)) -> &C<T> {
        &self.data[self.idx(r, c)]
    }
}

impl<T: Real> std::ops::IndexMut<(usize, usize)> for CMat<T> {
    #[inline(always)]
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut C<T> {
        let i = self.idx(r, c);
        &mut self.data[i]
    }
}

impl<T: Real> fmt::Debug for CMat<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "CMat {}x{} ({:?})", self.rows, self.cols, self.layout)?;
        let rmax = self.rows.min(6);
        let cmax = self.cols.min(6);
        for r in 0..rmax {
            write!(f, "  [")?;
            for c in 0..cmax {
                write!(f, " {}", self[(r, c)])?;
            }
            writeln!(f, "{}]", if cmax < self.cols { " …" } else { "" })?;
        }
        if rmax < self.rows {
            writeln!(f, "  …")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numeric::complex::c64;

    #[test]
    fn layout_roundtrip_real() {
        let mut rng = Pcg64::seeded(1);
        let a = Mat::random_normal(5, 7, &mut rng);
        let b = a.to_layout(Layout::ColMajor);
        assert_eq!(b.layout, Layout::ColMajor);
        assert_eq!(a.max_abs_diff(&b), 0.0);
        let c = b.to_layout(Layout::RowMajor);
        assert_eq!(a.data, c.data);
    }

    #[test]
    fn matvec_agrees_across_layouts() {
        let mut rng = Pcg64::seeded(2);
        let a = Mat::random_normal(6, 4, &mut rng);
        let x = rng.normal_vec(4);
        let y1 = a.matvec(&x);
        let y2 = a.to_layout(Layout::ColMajor).matvec(&x);
        for (u, v) in y1.iter().zip(&y2) {
            assert!((u - v).abs() < 1e-12);
        }
    }

    #[test]
    fn real_matmul_identity() {
        let mut rng = Pcg64::seeded(3);
        let a = Mat::random_normal(4, 4, &mut rng);
        let i = Mat::eye(4);
        assert!(a.matmul(&i).max_abs_diff(&a) < 1e-15);
        assert!(i.matmul(&a).max_abs_diff(&a) < 1e-15);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Pcg64::seeded(4);
        let a = Mat::random_normal(3, 5, &mut rng);
        assert_eq!(a.transpose().transpose().data, a.data);
    }

    #[test]
    fn matvec_t_matches_transpose() {
        let mut rng = Pcg64::seeded(5);
        let a = Mat::random_normal(5, 3, &mut rng);
        let x = rng.normal_vec(5);
        let y1 = a.matvec_t(&x);
        let y2 = a.transpose().matvec(&x);
        for (u, v) in y1.iter().zip(&y2) {
            assert!((u - v).abs() < 1e-12);
        }
    }

    #[test]
    fn hermitian_transpose() {
        let mut a = CMat::zeros(2, 3);
        a[(0, 1)] = c64(1.0, 2.0);
        let h = a.hermitian();
        assert_eq!(h.rows, 3);
        assert_eq!(h[(1, 0)], c64(1.0, -2.0));
    }

    #[test]
    fn complex_matmul_assoc_with_identity() {
        let mut rng = Pcg64::seeded(6);
        let a = CMat::random_normal(4, 4, &mut rng);
        let i = CMat::eye(4);
        assert!(a.matmul(&i).max_abs_diff(&a) < 1e-14);
    }

    #[test]
    fn gram_is_hermitian_psd_diag() {
        let mut rng = Pcg64::seeded(7);
        let a = CMat::random_normal(6, 4, &mut rng);
        let g = a.gram();
        for i in 0..4 {
            assert!(g[(i, i)].im.abs() < 1e-12);
            assert!(g[(i, i)].re >= 0.0);
            for j in 0..4 {
                assert!((g[(i, j)] - g[(j, i)].conj()).abs() < 1e-12);
            }
        }
        // gram equals explicit AᴴA
        let g2 = a.hermitian().matmul(&a);
        assert!(g.max_abs_diff(&g2) < 1e-12);
    }

    #[test]
    fn frobenius_matches_manual() {
        let a = Mat::from_rows(&[&[3.0, 0.0], &[0.0, 4.0]]);
        assert!((a.frobenius_norm() - 5.0).abs() < 1e-15);
    }

    #[test]
    fn f32_instantiation_and_conversion() {
        let mut rng = Pcg64::seeded(8);
        let a: CMat = CMat::random_normal(3, 3, &mut rng);
        let a32: CMat<f32> = a.convert();
        let back: CMat = a32.convert();
        assert!(a.max_abs_diff(&back) < 1e-6);
        assert!((a32.frobenius_norm() as f64 - a.frobenius_norm()).abs() < 1e-5);
    }
}
