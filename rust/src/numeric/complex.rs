//! Double-precision complex arithmetic.
//!
//! The offline crate set has no `num-complex`, so we carry our own small,
//! `#[repr(C)]`, `Copy` complex type. Layout is `[re, im]`, compatible with
//! the interleaved representation used by the FFT substrate and by the
//! real/imag plane pairs exchanged with the PJRT artifacts.

use std::fmt;
use std::iter::Sum;
use std::ops::{Add, AddAssign, Div, DivAssign, Mul, MulAssign, Neg, Sub, SubAssign};

/// A complex number over `f64`.
#[derive(Clone, Copy, PartialEq, Default)]
#[repr(C)]
pub struct C64 {
    pub re: f64,
    pub im: f64,
}

/// Shorthand constructor.
#[inline(always)]
pub const fn c64(re: f64, im: f64) -> C64 {
    C64 { re, im }
}

impl C64 {
    pub const ZERO: C64 = c64(0.0, 0.0);
    pub const ONE: C64 = c64(1.0, 0.0);
    pub const I: C64 = c64(0.0, 1.0);

    #[inline(always)]
    pub const fn new(re: f64, im: f64) -> Self {
        Self { re, im }
    }

    /// Purely real complex number.
    #[inline(always)]
    pub const fn real(re: f64) -> Self {
        Self { re, im: 0.0 }
    }

    /// `e^{iθ} = cos θ + i sin θ`.
    #[inline]
    pub fn cis(theta: f64) -> Self {
        let (s, c) = theta.sin_cos();
        Self { re: c, im: s }
    }

    /// Complex conjugate.
    #[inline(always)]
    pub fn conj(self) -> Self {
        Self { re: self.re, im: -self.im }
    }

    /// Squared magnitude `|z|²` (cheaper than `abs`).
    #[inline(always)]
    pub fn norm_sqr(self) -> f64 {
        self.re * self.re + self.im * self.im
    }

    /// Magnitude `|z|`, overflow-safe via `hypot`.
    #[inline]
    pub fn abs(self) -> f64 {
        self.re.hypot(self.im)
    }

    /// Argument (phase) in `(-π, π]`.
    #[inline]
    pub fn arg(self) -> f64 {
        self.im.atan2(self.re)
    }

    /// Multiplicative inverse. `1/0` produces infinities like `f64`.
    #[inline]
    pub fn inv(self) -> Self {
        let d = self.norm_sqr();
        Self { re: self.re / d, im: -self.im / d }
    }

    /// `self * other.conj()` — the building block of Hermitian inner products.
    #[inline(always)]
    pub fn mul_conj(self, other: Self) -> Self {
        Self {
            re: self.re * other.re + self.im * other.im,
            im: self.im * other.re - self.re * other.im,
        }
    }

    /// Fused multiply-add: `self + a * b`. The inner loop of every kernel in
    /// this crate; kept in one place so it can be re-tuned centrally.
    #[inline(always)]
    pub fn mul_add(self, a: Self, b: Self) -> Self {
        Self {
            re: self.re + a.re * b.re - a.im * b.im,
            im: self.im + a.re * b.im + a.im * b.re,
        }
    }

    /// Scale by a real factor.
    #[inline(always)]
    pub fn scale(self, s: f64) -> Self {
        Self { re: self.re * s, im: self.im * s }
    }

    /// Principal square root.
    pub fn sqrt(self) -> Self {
        if self.re == 0.0 && self.im == 0.0 {
            return Self::ZERO;
        }
        let m = self.abs();
        let re = ((m + self.re) * 0.5).sqrt();
        let im_mag = ((m - self.re) * 0.5).sqrt();
        Self { re, im: if self.im >= 0.0 { im_mag } else { -im_mag } }
    }

    /// True if either component is NaN.
    #[inline]
    pub fn is_nan(self) -> bool {
        self.re.is_nan() || self.im.is_nan()
    }

    /// True if both components are finite.
    #[inline]
    pub fn is_finite(self) -> bool {
        self.re.is_finite() && self.im.is_finite()
    }
}

impl Add for C64 {
    type Output = C64;
    #[inline(always)]
    fn add(self, rhs: C64) -> C64 {
        c64(self.re + rhs.re, self.im + rhs.im)
    }
}

impl Sub for C64 {
    type Output = C64;
    #[inline(always)]
    fn sub(self, rhs: C64) -> C64 {
        c64(self.re - rhs.re, self.im - rhs.im)
    }
}

impl Mul for C64 {
    type Output = C64;
    #[inline(always)]
    fn mul(self, rhs: C64) -> C64 {
        c64(
            self.re * rhs.re - self.im * rhs.im,
            self.re * rhs.im + self.im * rhs.re,
        )
    }
}

impl Div for C64 {
    type Output = C64;
    #[inline]
    fn div(self, rhs: C64) -> C64 {
        // Smith's algorithm: avoids overflow for large components.
        if rhs.re.abs() >= rhs.im.abs() {
            let r = rhs.im / rhs.re;
            let d = rhs.re + rhs.im * r;
            c64((self.re + self.im * r) / d, (self.im - self.re * r) / d)
        } else {
            let r = rhs.re / rhs.im;
            let d = rhs.re * r + rhs.im;
            c64((self.re * r + self.im) / d, (self.im * r - self.re) / d)
        }
    }
}

impl Neg for C64 {
    type Output = C64;
    #[inline(always)]
    fn neg(self) -> C64 {
        c64(-self.re, -self.im)
    }
}

impl Mul<f64> for C64 {
    type Output = C64;
    #[inline(always)]
    fn mul(self, rhs: f64) -> C64 {
        self.scale(rhs)
    }
}

impl Mul<C64> for f64 {
    type Output = C64;
    #[inline(always)]
    fn mul(self, rhs: C64) -> C64 {
        rhs.scale(self)
    }
}

impl Div<f64> for C64 {
    type Output = C64;
    #[inline(always)]
    fn div(self, rhs: f64) -> C64 {
        c64(self.re / rhs, self.im / rhs)
    }
}

impl AddAssign for C64 {
    #[inline(always)]
    fn add_assign(&mut self, rhs: C64) {
        self.re += rhs.re;
        self.im += rhs.im;
    }
}

impl SubAssign for C64 {
    #[inline(always)]
    fn sub_assign(&mut self, rhs: C64) {
        self.re -= rhs.re;
        self.im -= rhs.im;
    }
}

impl MulAssign for C64 {
    #[inline(always)]
    fn mul_assign(&mut self, rhs: C64) {
        *self = *self * rhs;
    }
}

impl DivAssign for C64 {
    #[inline]
    fn div_assign(&mut self, rhs: C64) {
        *self = *self / rhs;
    }
}

impl From<f64> for C64 {
    #[inline(always)]
    fn from(re: f64) -> Self {
        c64(re, 0.0)
    }
}

impl Sum for C64 {
    fn sum<I: Iterator<Item = C64>>(iter: I) -> C64 {
        iter.fold(C64::ZERO, |a, b| a + b)
    }
}

impl fmt::Debug for C64 {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.im >= 0.0 {
            write!(f, "{:+.6}{:+.6}i", self.re, self.im)
        } else {
            write!(f, "{:+.6}{:+.6}i", self.re, self.im)
        }
    }
}

impl fmt::Display for C64 {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(self, f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const EPS: f64 = 1e-12;

    fn close(a: C64, b: C64) -> bool {
        (a - b).abs() < EPS
    }

    #[test]
    fn add_sub_mul() {
        let a = c64(1.0, 2.0);
        let b = c64(3.0, -4.0);
        assert!(close(a + b, c64(4.0, -2.0)));
        assert!(close(a - b, c64(-2.0, 6.0)));
        // (1+2i)(3-4i) = 3 -4i +6i -8i² = 11 + 2i
        assert!(close(a * b, c64(11.0, 2.0)));
    }

    #[test]
    fn div_matches_mul_inv() {
        let a = c64(1.5, -0.25);
        let b = c64(-2.0, 0.75);
        assert!(close(a / b, a * b.inv()));
        assert!(close((a / b) * b, a));
    }

    #[test]
    fn div_extreme_magnitudes() {
        // Smith's algorithm keeps this finite where the naive formula overflows.
        let a = c64(1e300, 1e300);
        let b = c64(1e300, 1e-300);
        let q = a / b;
        assert!(q.is_finite());
        assert!((q.re - 1.0).abs() < 1e-9);
    }

    #[test]
    fn cis_is_unit() {
        for k in 0..100 {
            let t = k as f64 * 0.1 - 5.0;
            let z = C64::cis(t);
            assert!((z.abs() - 1.0).abs() < EPS);
            assert!((z.arg() - t.sin().atan2(t.cos())).abs() < 1e-10);
        }
    }

    #[test]
    fn conj_mul_is_norm() {
        let a = c64(3.0, 4.0);
        let n = a * a.conj();
        assert!(close(n, c64(25.0, 0.0)));
        assert_eq!(a.norm_sqr(), 25.0);
        assert_eq!(a.abs(), 5.0);
    }

    #[test]
    fn mul_conj_matches() {
        let a = c64(1.0, 2.0);
        let b = c64(3.0, -4.0);
        assert!(close(a.mul_conj(b), a * b.conj()));
    }

    #[test]
    fn mul_add_matches() {
        let acc = c64(0.5, -0.5);
        let a = c64(1.0, 2.0);
        let b = c64(-3.0, 1.0);
        assert!(close(acc.mul_add(a, b), acc + a * b));
    }

    #[test]
    fn sqrt_squares_back() {
        for &z in &[c64(4.0, 0.0), c64(-4.0, 0.0), c64(3.0, 4.0), c64(-1.0, -1.0)] {
            let r = z.sqrt();
            assert!(close(r * r, z), "sqrt({z:?}) = {r:?}");
            assert!(r.re >= 0.0, "principal branch");
        }
    }

    #[test]
    fn sqrt_zero() {
        assert_eq!(C64::ZERO.sqrt(), C64::ZERO);
    }

    #[test]
    fn scalar_ops() {
        let a = c64(2.0, -6.0);
        assert!(close(a * 0.5, c64(1.0, -3.0)));
        assert!(close(0.5 * a, c64(1.0, -3.0)));
        assert!(close(a / 2.0, c64(1.0, -3.0)));
    }

    #[test]
    fn sum_iterator() {
        let v = vec![c64(1.0, 1.0); 10];
        let s: C64 = v.into_iter().sum();
        assert!(close(s, c64(10.0, 10.0)));
    }

    #[test]
    fn abs_overflow_safe() {
        let z = c64(1e200, 1e200);
        assert!(z.abs().is_finite());
    }
}
