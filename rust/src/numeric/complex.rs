//! Complex arithmetic, generic over the real scalar width.
//!
//! The offline crate set has no `num-complex`, so we carry our own small,
//! `#[repr(C)]`, `Copy` complex type [`C<T>`] over any [`Real`] scalar.
//! Layout is `[re, im]`, compatible with the interleaved representation
//! used by the FFT substrate and by the real/imag plane pairs exchanged
//! with the PJRT artifacts. [`C64`] (`C<f64>`) is the crate-wide default —
//! every pre-existing call site compiles unchanged against the alias —
//! and [`C32`] (`C<f32>`) is the half-width tier the SIMD f32 paths run on.

use super::real::Real;
use std::fmt;
use std::iter::Sum;
use std::ops::{Add, AddAssign, Div, DivAssign, Mul, MulAssign, Neg, Sub, SubAssign};

/// A complex number over the real scalar `T`.
#[derive(Clone, Copy, PartialEq, Default)]
#[repr(C)]
pub struct C<T> {
    pub re: T,
    pub im: T,
}

/// Double-precision complex — the crate-wide default scalar.
pub type C64 = C<f64>;
/// Single-precision complex — the SIMD/f32 tier.
pub type C32 = C<f32>;

/// Shorthand constructor (double precision).
#[inline(always)]
pub const fn c64(re: f64, im: f64) -> C64 {
    C { re, im }
}

/// Shorthand constructor (single precision).
#[inline(always)]
pub const fn c32(re: f32, im: f32) -> C32 {
    C { re, im }
}

impl<T: Real> C<T> {
    pub const ZERO: C<T> = C { re: T::ZERO, im: T::ZERO };
    pub const ONE: C<T> = C { re: T::ONE, im: T::ZERO };
    pub const I: C<T> = C { re: T::ZERO, im: T::ONE };

    #[inline(always)]
    pub const fn new(re: T, im: T) -> Self {
        Self { re, im }
    }

    /// Purely real complex number.
    #[inline(always)]
    pub const fn real(re: T) -> Self {
        Self { re, im: T::ZERO }
    }

    /// `e^{iθ} = cos θ + i sin θ`.
    #[inline]
    pub fn cis(theta: T) -> Self {
        let (s, c) = theta.sin_cos();
        Self { re: c, im: s }
    }

    /// Complex conjugate.
    #[inline(always)]
    pub fn conj(self) -> Self {
        Self { re: self.re, im: -self.im }
    }

    /// Squared magnitude `|z|²` (cheaper than `abs`).
    #[inline(always)]
    pub fn norm_sqr(self) -> T {
        self.re * self.re + self.im * self.im
    }

    /// Magnitude `|z|`, overflow-safe via `hypot`.
    #[inline]
    pub fn abs(self) -> T {
        self.re.hypot(self.im)
    }

    /// Argument (phase) in `(-π, π]`.
    #[inline]
    pub fn arg(self) -> T {
        self.im.atan2(self.re)
    }

    /// Multiplicative inverse. `1/0` produces infinities like the scalar.
    #[inline]
    pub fn inv(self) -> Self {
        let d = self.norm_sqr();
        Self { re: self.re / d, im: -self.im / d }
    }

    /// `self * other.conj()` — the building block of Hermitian inner products.
    #[inline(always)]
    pub fn mul_conj(self, other: Self) -> Self {
        Self {
            re: self.re * other.re + self.im * other.im,
            im: self.im * other.re - self.re * other.im,
        }
    }

    /// Fused multiply-add: `self + a * b`. The inner loop of every kernel in
    /// this crate; kept in one place so it can be re-tuned centrally.
    #[inline(always)]
    pub fn mul_add(self, a: Self, b: Self) -> Self {
        Self {
            re: self.re + a.re * b.re - a.im * b.im,
            im: self.im + a.re * b.im + a.im * b.re,
        }
    }

    /// Scale by a real factor.
    #[inline(always)]
    pub fn scale(self, s: T) -> Self {
        Self { re: self.re * s, im: self.im * s }
    }

    /// Principal square root.
    pub fn sqrt(self) -> Self {
        if self.re == T::ZERO && self.im == T::ZERO {
            return Self::ZERO;
        }
        let m = self.abs();
        let re = ((m + self.re) * T::HALF).sqrt();
        let im_mag = ((m - self.re) * T::HALF).sqrt();
        Self { re, im: if self.im >= T::ZERO { im_mag } else { -im_mag } }
    }

    /// True if either component is NaN.
    #[inline]
    pub fn is_nan(self) -> bool {
        self.re.is_nan() || self.im.is_nan()
    }

    /// True if both components are finite.
    #[inline]
    pub fn is_finite(self) -> bool {
        self.re.is_finite() && self.im.is_finite()
    }

    /// Widen/narrow to another scalar width through `f64`.
    #[inline(always)]
    pub fn convert<U: Real>(self) -> C<U> {
        C { re: U::from_f64(self.re.to_f64()), im: U::from_f64(self.im.to_f64()) }
    }
}

impl C64 {
    /// Narrow to single precision.
    #[inline(always)]
    pub fn to_c32(self) -> C32 {
        C { re: self.re as f32, im: self.im as f32 }
    }
}

impl C32 {
    /// Widen to double precision.
    #[inline(always)]
    pub fn to_c64(self) -> C64 {
        C { re: self.re as f64, im: self.im as f64 }
    }
}

impl<T: Real> Add for C<T> {
    type Output = C<T>;
    #[inline(always)]
    fn add(self, rhs: C<T>) -> C<T> {
        C { re: self.re + rhs.re, im: self.im + rhs.im }
    }
}

impl<T: Real> Sub for C<T> {
    type Output = C<T>;
    #[inline(always)]
    fn sub(self, rhs: C<T>) -> C<T> {
        C { re: self.re - rhs.re, im: self.im - rhs.im }
    }
}

impl<T: Real> Mul for C<T> {
    type Output = C<T>;
    #[inline(always)]
    fn mul(self, rhs: C<T>) -> C<T> {
        C {
            re: self.re * rhs.re - self.im * rhs.im,
            im: self.re * rhs.im + self.im * rhs.re,
        }
    }
}

impl<T: Real> Div for C<T> {
    type Output = C<T>;
    #[inline]
    fn div(self, rhs: C<T>) -> C<T> {
        // Smith's algorithm: avoids overflow for large components.
        if rhs.re.abs() >= rhs.im.abs() {
            let r = rhs.im / rhs.re;
            let d = rhs.re + rhs.im * r;
            C { re: (self.re + self.im * r) / d, im: (self.im - self.re * r) / d }
        } else {
            let r = rhs.re / rhs.im;
            let d = rhs.re * r + rhs.im;
            C { re: (self.re * r + self.im) / d, im: (self.im * r - self.re) / d }
        }
    }
}

impl<T: Real> Neg for C<T> {
    type Output = C<T>;
    #[inline(always)]
    fn neg(self) -> C<T> {
        C { re: -self.re, im: -self.im }
    }
}

impl<T: Real> Mul<T> for C<T> {
    type Output = C<T>;
    #[inline(always)]
    fn mul(self, rhs: T) -> C<T> {
        self.scale(rhs)
    }
}

// The orphan rules (E0210) forbid `impl<T: Real> Mul<C<T>> for T`, so the
// scalar-on-the-left form is spelled out per implementor.
impl Mul<C64> for f64 {
    type Output = C64;
    #[inline(always)]
    fn mul(self, rhs: C64) -> C64 {
        rhs.scale(self)
    }
}

impl Mul<C32> for f32 {
    type Output = C32;
    #[inline(always)]
    fn mul(self, rhs: C32) -> C32 {
        rhs.scale(self)
    }
}

impl<T: Real> Div<T> for C<T> {
    type Output = C<T>;
    #[inline(always)]
    fn div(self, rhs: T) -> C<T> {
        C { re: self.re / rhs, im: self.im / rhs }
    }
}

impl<T: Real> AddAssign for C<T> {
    #[inline(always)]
    fn add_assign(&mut self, rhs: C<T>) {
        self.re += rhs.re;
        self.im += rhs.im;
    }
}

impl<T: Real> SubAssign for C<T> {
    #[inline(always)]
    fn sub_assign(&mut self, rhs: C<T>) {
        self.re -= rhs.re;
        self.im -= rhs.im;
    }
}

impl<T: Real> MulAssign for C<T> {
    #[inline(always)]
    fn mul_assign(&mut self, rhs: C<T>) {
        *self = *self * rhs;
    }
}

impl<T: Real> DivAssign for C<T> {
    #[inline]
    fn div_assign(&mut self, rhs: C<T>) {
        *self = *self / rhs;
    }
}

impl<T: Real> From<T> for C<T> {
    #[inline(always)]
    fn from(re: T) -> Self {
        C { re, im: T::ZERO }
    }
}

impl<T: Real> Sum for C<T> {
    fn sum<I: Iterator<Item = C<T>>>(iter: I) -> C<T> {
        iter.fold(C::ZERO, |a, b| a + b)
    }
}

impl<T: Real> fmt::Debug for C<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:+.6}{:+.6}i", self.re, self.im)
    }
}

impl<T: Real> fmt::Display for C<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(self, f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const EPS: f64 = 1e-12;

    fn close(a: C64, b: C64) -> bool {
        (a - b).abs() < EPS
    }

    #[test]
    fn add_sub_mul() {
        let a = c64(1.0, 2.0);
        let b = c64(3.0, -4.0);
        assert!(close(a + b, c64(4.0, -2.0)));
        assert!(close(a - b, c64(-2.0, 6.0)));
        // (1+2i)(3-4i) = 3 -4i +6i -8i² = 11 + 2i
        assert!(close(a * b, c64(11.0, 2.0)));
    }

    #[test]
    fn div_matches_mul_inv() {
        let a = c64(1.5, -0.25);
        let b = c64(-2.0, 0.75);
        assert!(close(a / b, a * b.inv()));
        assert!(close((a / b) * b, a));
    }

    #[test]
    fn div_extreme_magnitudes() {
        // Smith's algorithm keeps this finite where the naive formula overflows.
        let a = c64(1e300, 1e300);
        let b = c64(1e300, 1e-300);
        let q = a / b;
        assert!(q.is_finite());
        assert!((q.re - 1.0).abs() < 1e-9);
    }

    #[test]
    fn cis_is_unit() {
        for k in 0..100 {
            let t = k as f64 * 0.1 - 5.0;
            let z = C64::cis(t);
            assert!((z.abs() - 1.0).abs() < EPS);
            assert!((z.arg() - t.sin().atan2(t.cos())).abs() < 1e-10);
        }
    }

    #[test]
    fn conj_mul_is_norm() {
        let a = c64(3.0, 4.0);
        let n = a * a.conj();
        assert!(close(n, c64(25.0, 0.0)));
        assert_eq!(a.norm_sqr(), 25.0);
        assert_eq!(a.abs(), 5.0);
    }

    #[test]
    fn mul_conj_matches() {
        let a = c64(1.0, 2.0);
        let b = c64(3.0, -4.0);
        assert!(close(a.mul_conj(b), a * b.conj()));
    }

    #[test]
    fn mul_add_matches() {
        let acc = c64(0.5, -0.5);
        let a = c64(1.0, 2.0);
        let b = c64(-3.0, 1.0);
        assert!(close(acc.mul_add(a, b), acc + a * b));
    }

    #[test]
    fn sqrt_squares_back() {
        for &z in &[c64(4.0, 0.0), c64(-4.0, 0.0), c64(3.0, 4.0), c64(-1.0, -1.0)] {
            let r = z.sqrt();
            assert!(close(r * r, z), "sqrt({z:?}) = {r:?}");
            assert!(r.re >= 0.0, "principal branch");
        }
    }

    #[test]
    fn sqrt_zero() {
        assert_eq!(C64::ZERO.sqrt(), C64::ZERO);
    }

    #[test]
    fn scalar_ops() {
        let a = c64(2.0, -6.0);
        assert!(close(a * 0.5, c64(1.0, -3.0)));
        assert!(close(0.5 * a, c64(1.0, -3.0)));
        assert!(close(a / 2.0, c64(1.0, -3.0)));
    }

    #[test]
    fn sum_iterator() {
        let v = vec![c64(1.0, 1.0); 10];
        let s: C64 = v.into_iter().sum();
        assert!(close(s, c64(10.0, 10.0)));
    }

    #[test]
    fn abs_overflow_safe() {
        let z = c64(1e200, 1e200);
        assert!(z.abs().is_finite());
    }

    #[test]
    fn f32_arithmetic_mirrors_f64() {
        let a = c32(1.0, 2.0);
        let b = c32(3.0, -4.0);
        let p = a * b;
        assert!((p.re - 11.0).abs() < 1e-5 && (p.im - 2.0).abs() < 1e-5);
        assert!((0.5f32 * a - a.scale(0.5)).abs() < 1e-6);
        let z = C32::cis(0.3);
        assert!((z.abs() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn width_conversions_roundtrip() {
        let a = c64(0.125, -2.5);
        assert_eq!(a.to_c32().to_c64(), a, "dyadic values convert exactly");
        let w: C32 = a.convert();
        assert_eq!(w, a.to_c32());
    }
}
