//! Deterministic pseudo-random numbers (PCG-XSH-RR 64/32).
//!
//! Used for weight initialization in the model zoo, synthetic workloads in
//! the benches, and the property-testing harness. No `rand` crate in the
//! offline set; PCG is small, fast and statistically solid for these uses.

/// PCG-XSH-RR 64/32 generator (O'Neill 2014).
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg64 {
    /// Create a generator from a seed and stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg64 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Seed-only constructor (stream 0).
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e39cb94b95bdb)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Unbiased integer in `[0, n)` (Lemire rejection).
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let m = (x as u128) * (n as u128);
                ((m >> 64) as u64, m as u64)
            };
            if lo >= n || lo >= n.wrapping_neg() % n {
                return hi;
            }
        }
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast here).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-300 {
                let u2 = self.uniform();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with given mean and standard deviation.
    #[inline]
    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Fill a vector with standard-normal samples.
    pub fn normal_vec(&mut self, len: usize) -> Vec<f64> {
        (0..len).map(|_| self.normal()).collect()
    }

    /// Fill a vector with uniform `[lo, hi)` samples.
    pub fn uniform_vec(&mut self, len: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..len).map(|_| self.uniform_in(lo, hi)).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg64::seeded(42);
        let mut b = Pcg64::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::seeded(1);
        let mut b = Pcg64::seeded(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut rng = Pcg64::seeded(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean = {mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Pcg64::seeded(11);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let k = rng.below(10) as usize;
            assert!(k < 10);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::seeded(13);
        let n = 50_000;
        let xs = rng.normal_vec(n);
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean = {mean}");
        assert!((var - 1.0).abs() < 0.05, "var = {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::seeded(3);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>(), "shuffle moved something");
    }
}
