//! Explicitly vectorized inner-loop kernels with a portable fallback.
//!
//! The per-frequency hot loops — symbol-assembly tap contraction, Jacobi
//! conjugate dots and row rotations, Gram rank-1 updates, Krylov matvecs —
//! spend essentially all of their time in four primitive shapes. This
//! module implements each one twice:
//!
//! - an **AVX2+FMA** `std::arch` path (x86_64 only), selected at runtime
//!   via CPUID so a generic build still uses it on capable hardware;
//! - a **portable lane-emulating fallback** that mirrors the vector
//!   register layout (4 f64 / 8 f32 lanes), accumulation order and FMA
//!   rounding exactly, using scalar `mul_add`. The two paths are therefore
//!   **bit-identical** for either lane width — the equivalence tests
//!   assert it — so enabling SIMD can never change a spectrum.
//!
//! Complex data stays interleaved `[re, im]` (`#[repr(C)]` [`C<T>`]);
//! the symbol-assembly kernel instead takes **split** `re`/`im` phase
//! planes, which turns the complex tap contraction into two independent
//! real dot products — the best-vectorizing form of that loop.
//!
//! Dispatch is per-call through [`SimdReal`], with the one-time CPUID
//! result cached; [`set_force_scalar`] (and the `CONV_SVD_NO_SIMD`
//! environment variable) pin the fallback for benches, tests and the
//! no-AVX2 CI job.

use super::complex::C;
use super::real::Real;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::OnceLock;

/// Process-wide "pretend the CPU has no vector unit" switch.
static FORCE_SCALAR: AtomicBool = AtomicBool::new(false);

/// Cached CPUID result (plus the `CONV_SVD_NO_SIMD` env override).
fn detected() -> bool {
    static DETECTED: OnceLock<bool> = OnceLock::new();
    *DETECTED.get_or_init(|| {
        if std::env::var_os("CONV_SVD_NO_SIMD").is_some() {
            return false;
        }
        #[cfg(target_arch = "x86_64")]
        {
            std::arch::is_x86_feature_detected!("avx2")
                && std::arch::is_x86_feature_detected!("fma")
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            false
        }
    })
}

/// Whether the vectorized paths are currently in use.
#[inline]
pub fn simd_active() -> bool {
    detected() && !FORCE_SCALAR.load(Ordering::Relaxed)
}

/// Force (or release) the portable fallback, regardless of CPU support.
/// Used by the SIMD-vs-scalar bench sections and equivalence tests.
pub fn set_force_scalar(force: bool) {
    FORCE_SCALAR.store(force, Ordering::Relaxed);
}

/// Human-readable name of the active kernel path (for bench stamps).
pub fn active_kernel_name() -> &'static str {
    if simd_active() {
        "avx2+fma"
    } else {
        "scalar"
    }
}

/// The four vector kernel shapes, per scalar width. `f64` runs 4 lanes,
/// `f32` 8; both fall back to the bit-identical lane emulation when AVX2
/// is absent or disabled.
pub trait SimdReal: Real {
    /// Split-complex tap contraction: `(Σ w·re, Σ w·im)`.
    fn dot_split(w: &[Self], re: &[Self], im: &[Self]) -> (Self, Self);
    /// Hermitian inner product `Σ x_i · conj(y_i)`.
    fn cdot_conj(x: &[C<Self>], y: &[C<Self>]) -> C<Self>;
    /// Plain inner product `Σ x_i · y_i`.
    fn cdot(x: &[C<Self>], y: &[C<Self>]) -> C<Self>;
    /// `y += s·x` (complex axpy — the Gram rank-1 update row).
    fn caxpy(s: C<Self>, x: &[C<Self>], y: &mut [C<Self>]);
    /// Paired Jacobi row rotation: `p' = c·p − sp·q`, `q' = sm·p + c·q`.
    fn crot(p: &mut [C<Self>], q: &mut [C<Self>], c: Self, sp: C<Self>, sm: C<Self>);
}

// ---------------------------------------------------------------------------
// Portable lane-emulating fallback, generic over the width.
//
// LANES accumulators are combined pairwise in the same order as the AVX2
// horizontal sums, every multiply-accumulate is a fused `mul_add`, and the
// tail is handled identically — which is what makes scalar and vector
// paths bit-identical.
// ---------------------------------------------------------------------------

mod scalar {
    use super::{Real, C};

    /// Pairwise lane reduction matching the AVX2 horizontal sums:
    /// `(l0+l1)+(l2+l3)` for 4 lanes, the same tree again across halves
    /// for 8.
    #[inline(always)]
    pub fn reduce<T: Real, const LANES: usize>(acc: &[T; LANES]) -> T {
        match LANES {
            4 => (acc[0] + acc[1]) + (acc[2] + acc[3]),
            8 => {
                let lo = (acc[0] + acc[1]) + (acc[2] + acc[3]);
                let hi = (acc[4] + acc[5]) + (acc[6] + acc[7]);
                lo + hi
            }
            _ => acc.iter().copied().sum(),
        }
    }

    pub fn dot_split<T: Real, const LANES: usize>(w: &[T], re: &[T], im: &[T]) -> (T, T) {
        debug_assert!(re.len() >= w.len() && im.len() >= w.len());
        let n = w.len();
        let mut ar = [T::ZERO; LANES];
        let mut ai = [T::ZERO; LANES];
        let chunks = n / LANES;
        for k in 0..chunks {
            let i = k * LANES;
            for l in 0..LANES {
                ar[l] = w[i + l].mul_add(re[i + l], ar[l]);
                ai[l] = w[i + l].mul_add(im[i + l], ai[l]);
            }
        }
        let mut sr = reduce(&ar);
        let mut si = reduce(&ai);
        for i in chunks * LANES..n {
            sr = w[i].mul_add(re[i], sr);
            si = w[i].mul_add(im[i], si);
        }
        (sr, si)
    }

    /// Shared body of the two complex dots on the flat interleaved view:
    /// `CONJ` flips the sign pattern (`x·conj(y)` vs `x·y`).
    pub fn cdot_flat<T: Real, const LANES: usize, const CONJ: bool>(x: &[T], y: &[T]) -> C<T> {
        debug_assert_eq!(x.len(), y.len());
        let n = x.len();
        let mut ar = [T::ZERO; LANES];
        let mut ai = [T::ZERO; LANES];
        let chunks = n / LANES;
        for k in 0..chunks {
            let i = k * LANES;
            for l in 0..LANES {
                // Lane layout: even = re, odd = im. `yn` is y with the
                // imaginary lanes negated; `xs` is x with re/im swapped.
                let (xv, yv) = (x[i + l], y[i + l]);
                let xs = if l % 2 == 0 { x[i + l + 1] } else { x[i + l - 1] };
                let yn = if l % 2 == 1 { -yv } else { yv };
                if CONJ {
                    // re: x·y elementwise; im: swap(x)·(y with −im lanes).
                    ar[l] = xv.mul_add(yv, ar[l]);
                    ai[l] = xs.mul_add(yn, ai[l]);
                } else {
                    // re: x·(y with −im lanes); im: swap(x)·y.
                    ar[l] = xv.mul_add(yn, ar[l]);
                    ai[l] = xs.mul_add(yv, ai[l]);
                }
            }
        }
        let mut sr = reduce(&ar);
        let mut si = reduce(&ai);
        for k in chunks * (LANES / 2)..n / 2 {
            let (xr, xi) = (x[2 * k], x[2 * k + 1]);
            let (yr, yi) = (y[2 * k], y[2 * k + 1]);
            if CONJ {
                sr = xr.mul_add(yr, sr);
                sr = xi.mul_add(yi, sr);
                si = xi.mul_add(yr, si);
                si = xr.mul_add(-yi, si);
            } else {
                sr = xr.mul_add(yr, sr);
                sr = xi.mul_add(-yi, sr);
                si = xi.mul_add(yr, si);
                si = xr.mul_add(yi, si);
            }
        }
        C { re: sr, im: si }
    }

    /// `y += s·x` on the flat view, mirroring `fmaddsub` rounding:
    /// `t = s.im·x_swapped`, then `re' = s.re·x − t` / `im' = s.re·x + t`,
    /// each as one fused op.
    pub fn caxpy_flat<T: Real>(s: C<T>, x: &[T], y: &mut [T]) {
        debug_assert_eq!(x.len(), y.len());
        for k in 0..x.len() / 2 {
            let (xr, xi) = (x[2 * k], x[2 * k + 1]);
            let tr = s.im * xi;
            let ti = s.im * xr;
            y[2 * k] += s.re.mul_add(xr, -tr);
            y[2 * k + 1] += s.re.mul_add(xi, ti);
        }
    }

    pub fn crot_flat<T: Real>(p: &mut [T], q: &mut [T], c: T, sp: C<T>, sm: C<T>) {
        debug_assert_eq!(p.len(), q.len());
        for k in 0..p.len() / 2 {
            let (pr, pi) = (p[2 * k], p[2 * k + 1]);
            let (qr, qi) = (q[2 * k], q[2 * k + 1]);
            // sp·q and sm·p with fmaddsub rounding (t rounded once, then
            // one fused op per component).
            let spq_r = sp.re.mul_add(qr, -(sp.im * qi));
            let spq_i = sp.re.mul_add(qi, sp.im * qr);
            let smp_r = sm.re.mul_add(pr, -(sm.im * pi));
            let smp_i = sm.re.mul_add(pi, sm.im * pr);
            p[2 * k] = c.mul_add(pr, -spq_r);
            p[2 * k + 1] = c.mul_add(pi, -spq_i);
            q[2 * k] = c.mul_add(qr, smp_r);
            q[2 * k + 1] = c.mul_add(qi, smp_i);
        }
    }
}

/// Reinterpret an interleaved complex slice as its flat scalar view.
#[inline(always)]
fn flat<T: Real>(x: &[C<T>]) -> &[T] {
    // Safety: C<T> is #[repr(C)] { re: T, im: T } — exactly two Ts.
    unsafe { std::slice::from_raw_parts(x.as_ptr() as *const T, x.len() * 2) }
}

#[inline(always)]
fn flat_mut<T: Real>(x: &mut [C<T>]) -> &mut [T] {
    unsafe { std::slice::from_raw_parts_mut(x.as_mut_ptr() as *mut T, x.len() * 2) }
}

// ---------------------------------------------------------------------------
// AVX2 + FMA kernels (x86_64).
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::C;
    use std::arch::x86_64::*;

    /// Lane sum in the fixed order `(l0+l1)+(l2+l3)`.
    #[inline(always)]
    unsafe fn hsum_pd(v: __m256d) -> f64 {
        let mut lanes = [0.0f64; 4];
        _mm256_storeu_pd(lanes.as_mut_ptr(), v);
        (lanes[0] + lanes[1]) + (lanes[2] + lanes[3])
    }

    #[inline(always)]
    unsafe fn hsum_ps(v: __m256) -> f32 {
        let mut lanes = [0.0f32; 8];
        _mm256_storeu_ps(lanes.as_mut_ptr(), v);
        ((lanes[0] + lanes[1]) + (lanes[2] + lanes[3]))
            + ((lanes[4] + lanes[5]) + (lanes[6] + lanes[7]))
    }

    /// `[+0.0, -0.0, …]` — XOR negates the imaginary (odd) lanes.
    #[inline(always)]
    unsafe fn neg_im_pd() -> __m256d {
        _mm256_set_pd(-0.0, 0.0, -0.0, 0.0)
    }

    #[inline(always)]
    unsafe fn neg_im_ps() -> __m256 {
        _mm256_set_ps(-0.0, 0.0, -0.0, 0.0, -0.0, 0.0, -0.0, 0.0)
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn dot_split_f64(w: &[f64], re: &[f64], im: &[f64]) -> (f64, f64) {
        let n = w.len();
        let mut ar = _mm256_setzero_pd();
        let mut ai = _mm256_setzero_pd();
        let chunks = n / 4;
        for k in 0..chunks {
            let i = k * 4;
            let vw = _mm256_loadu_pd(w.as_ptr().add(i));
            ar = _mm256_fmadd_pd(vw, _mm256_loadu_pd(re.as_ptr().add(i)), ar);
            ai = _mm256_fmadd_pd(vw, _mm256_loadu_pd(im.as_ptr().add(i)), ai);
        }
        let mut sr = hsum_pd(ar);
        let mut si = hsum_pd(ai);
        for i in chunks * 4..n {
            sr = w[i].mul_add(re[i], sr);
            si = w[i].mul_add(im[i], si);
        }
        (sr, si)
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn dot_split_f32(w: &[f32], re: &[f32], im: &[f32]) -> (f32, f32) {
        let n = w.len();
        let mut ar = _mm256_setzero_ps();
        let mut ai = _mm256_setzero_ps();
        let chunks = n / 8;
        for k in 0..chunks {
            let i = k * 8;
            let vw = _mm256_loadu_ps(w.as_ptr().add(i));
            ar = _mm256_fmadd_ps(vw, _mm256_loadu_ps(re.as_ptr().add(i)), ar);
            ai = _mm256_fmadd_ps(vw, _mm256_loadu_ps(im.as_ptr().add(i)), ai);
        }
        let mut sr = hsum_ps(ar);
        let mut si = hsum_ps(ai);
        for i in chunks * 8..n {
            sr = w[i].mul_add(re[i], sr);
            si = w[i].mul_add(im[i], si);
        }
        (sr, si)
    }

    /// Both complex dots on the flat interleaved f64 view. `CONJ` selects
    /// `Σ x·conj(y)`; see the scalar twin for the lane algebra.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn cdot_flat_f64<const CONJ: bool>(x: &[f64], y: &[f64]) -> C<f64> {
        let n = x.len();
        let sign = neg_im_pd();
        let mut ar = _mm256_setzero_pd();
        let mut ai = _mm256_setzero_pd();
        let chunks = n / 4;
        for k in 0..chunks {
            let i = k * 4;
            let vx = _mm256_loadu_pd(x.as_ptr().add(i));
            let vy = _mm256_loadu_pd(y.as_ptr().add(i));
            let xs = _mm256_permute_pd(vx, 0b0101);
            let yn = _mm256_xor_pd(vy, sign);
            if CONJ {
                ar = _mm256_fmadd_pd(vx, vy, ar);
                ai = _mm256_fmadd_pd(xs, yn, ai);
            } else {
                ar = _mm256_fmadd_pd(vx, yn, ar);
                ai = _mm256_fmadd_pd(xs, vy, ai);
            }
        }
        let mut sr = hsum_pd(ar);
        let mut si = hsum_pd(ai);
        for k in chunks * 2..n / 2 {
            let (xr, xi) = (x[2 * k], x[2 * k + 1]);
            let (yr, yi) = (y[2 * k], y[2 * k + 1]);
            if CONJ {
                sr = xr.mul_add(yr, sr);
                sr = xi.mul_add(yi, sr);
                si = xi.mul_add(yr, si);
                si = xr.mul_add(-yi, si);
            } else {
                sr = xr.mul_add(yr, sr);
                sr = xi.mul_add(-yi, sr);
                si = xi.mul_add(yr, si);
                si = xr.mul_add(yi, si);
            }
        }
        C { re: sr, im: si }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn cdot_flat_f32<const CONJ: bool>(x: &[f32], y: &[f32]) -> C<f32> {
        let n = x.len();
        let sign = neg_im_ps();
        let mut ar = _mm256_setzero_ps();
        let mut ai = _mm256_setzero_ps();
        let chunks = n / 8;
        for k in 0..chunks {
            let i = k * 8;
            let vx = _mm256_loadu_ps(x.as_ptr().add(i));
            let vy = _mm256_loadu_ps(y.as_ptr().add(i));
            let xs = _mm256_permute_ps(vx, 0xB1);
            let yn = _mm256_xor_ps(vy, sign);
            if CONJ {
                ar = _mm256_fmadd_ps(vx, vy, ar);
                ai = _mm256_fmadd_ps(xs, yn, ai);
            } else {
                ar = _mm256_fmadd_ps(vx, yn, ar);
                ai = _mm256_fmadd_ps(xs, vy, ai);
            }
        }
        let mut sr = hsum_ps(ar);
        let mut si = hsum_ps(ai);
        for k in chunks * 4..n / 2 {
            let (xr, xi) = (x[2 * k], x[2 * k + 1]);
            let (yr, yi) = (y[2 * k], y[2 * k + 1]);
            if CONJ {
                sr = xr.mul_add(yr, sr);
                sr = xi.mul_add(yi, sr);
                si = xi.mul_add(yr, si);
                si = xr.mul_add(-yi, si);
            } else {
                sr = xr.mul_add(yr, sr);
                sr = xi.mul_add(-yi, sr);
                si = xi.mul_add(yr, si);
                si = xr.mul_add(yi, si);
            }
        }
        C { re: sr, im: si }
    }

    /// Complex scalar × vector: `s·v` per interleaved pair, with the
    /// `t = s.im·swap(v)` then `fmaddsub(s.re, v, t)` rounding pattern.
    #[inline(always)]
    unsafe fn cmul_vec_pd(sre: __m256d, sim: __m256d, v: __m256d) -> __m256d {
        let t = _mm256_mul_pd(sim, _mm256_permute_pd(v, 0b0101));
        _mm256_fmaddsub_pd(sre, v, t)
    }

    #[inline(always)]
    unsafe fn cmul_vec_ps(sre: __m256, sim: __m256, v: __m256) -> __m256 {
        let t = _mm256_mul_ps(sim, _mm256_permute_ps(v, 0xB1));
        _mm256_fmaddsub_ps(sre, v, t)
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn caxpy_f64(s: C<f64>, x: &[f64], y: &mut [f64]) {
        let n = x.len();
        let sre = _mm256_set1_pd(s.re);
        let sim = _mm256_set1_pd(s.im);
        let chunks = n / 4;
        for k in 0..chunks {
            let i = k * 4;
            let vx = _mm256_loadu_pd(x.as_ptr().add(i));
            let vy = _mm256_loadu_pd(y.as_ptr().add(i));
            _mm256_storeu_pd(y.as_mut_ptr().add(i), _mm256_add_pd(vy, cmul_vec_pd(sre, sim, vx)));
        }
        for k in chunks * 2..n / 2 {
            let (xr, xi) = (x[2 * k], x[2 * k + 1]);
            let tr = s.im * xi;
            let ti = s.im * xr;
            y[2 * k] += s.re.mul_add(xr, -tr);
            y[2 * k + 1] += s.re.mul_add(xi, ti);
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn caxpy_f32(s: C<f32>, x: &[f32], y: &mut [f32]) {
        let n = x.len();
        let sre = _mm256_set1_ps(s.re);
        let sim = _mm256_set1_ps(s.im);
        let chunks = n / 8;
        for k in 0..chunks {
            let i = k * 8;
            let vx = _mm256_loadu_ps(x.as_ptr().add(i));
            let vy = _mm256_loadu_ps(y.as_ptr().add(i));
            _mm256_storeu_ps(y.as_mut_ptr().add(i), _mm256_add_ps(vy, cmul_vec_ps(sre, sim, vx)));
        }
        for k in chunks * 4..n / 2 {
            let (xr, xi) = (x[2 * k], x[2 * k + 1]);
            let tr = s.im * xi;
            let ti = s.im * xr;
            y[2 * k] += s.re.mul_add(xr, -tr);
            y[2 * k + 1] += s.re.mul_add(xi, ti);
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn crot_f64(p: &mut [f64], q: &mut [f64], c: f64, sp: C<f64>, sm: C<f64>) {
        let n = p.len();
        let vc = _mm256_set1_pd(c);
        let spr = _mm256_set1_pd(sp.re);
        let spi = _mm256_set1_pd(sp.im);
        let smr = _mm256_set1_pd(sm.re);
        let smi = _mm256_set1_pd(sm.im);
        let chunks = n / 4;
        for k in 0..chunks {
            let i = k * 4;
            let vp = _mm256_loadu_pd(p.as_ptr().add(i));
            let vq = _mm256_loadu_pd(q.as_ptr().add(i));
            let spq = cmul_vec_pd(spr, spi, vq);
            let smp = cmul_vec_pd(smr, smi, vp);
            _mm256_storeu_pd(p.as_mut_ptr().add(i), _mm256_fmsub_pd(vc, vp, spq));
            _mm256_storeu_pd(q.as_mut_ptr().add(i), _mm256_fmadd_pd(vc, vq, smp));
        }
        for k in chunks * 2..n / 2 {
            let (pr, pi) = (p[2 * k], p[2 * k + 1]);
            let (qr, qi) = (q[2 * k], q[2 * k + 1]);
            let spq_r = sp.re.mul_add(qr, -(sp.im * qi));
            let spq_i = sp.re.mul_add(qi, sp.im * qr);
            let smp_r = sm.re.mul_add(pr, -(sm.im * pi));
            let smp_i = sm.re.mul_add(pi, sm.im * pr);
            p[2 * k] = c.mul_add(pr, -spq_r);
            p[2 * k + 1] = c.mul_add(pi, -spq_i);
            q[2 * k] = c.mul_add(qr, smp_r);
            q[2 * k + 1] = c.mul_add(qi, smp_i);
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn crot_f32(p: &mut [f32], q: &mut [f32], c: f32, sp: C<f32>, sm: C<f32>) {
        let n = p.len();
        let vc = _mm256_set1_ps(c);
        let spr = _mm256_set1_ps(sp.re);
        let spi = _mm256_set1_ps(sp.im);
        let smr = _mm256_set1_ps(sm.re);
        let smi = _mm256_set1_ps(sm.im);
        let chunks = n / 8;
        for k in 0..chunks {
            let i = k * 8;
            let vp = _mm256_loadu_ps(p.as_ptr().add(i));
            let vq = _mm256_loadu_ps(q.as_ptr().add(i));
            let spq = cmul_vec_ps(spr, spi, vq);
            let smp = cmul_vec_ps(smr, smi, vp);
            _mm256_storeu_ps(p.as_mut_ptr().add(i), _mm256_fmsub_ps(vc, vp, spq));
            _mm256_storeu_ps(q.as_mut_ptr().add(i), _mm256_fmadd_ps(vc, vq, smp));
        }
        for k in chunks * 4..n / 2 {
            let (pr, pi) = (p[2 * k], p[2 * k + 1]);
            let (qr, qi) = (q[2 * k], q[2 * k + 1]);
            let spq_r = sp.re.mul_add(qr, -(sp.im * qi));
            let spq_i = sp.re.mul_add(qi, sp.im * qr);
            let smp_r = sm.re.mul_add(pr, -(sm.im * pi));
            let smp_i = sm.re.mul_add(pi, sm.im * pr);
            p[2 * k] = c.mul_add(pr, -spq_r);
            p[2 * k + 1] = c.mul_add(pi, -spq_i);
            q[2 * k] = c.mul_add(qr, smp_r);
            q[2 * k + 1] = c.mul_add(qi, smp_i);
        }
    }
}

impl SimdReal for f64 {
    #[inline]
    fn dot_split(w: &[f64], re: &[f64], im: &[f64]) -> (f64, f64) {
        #[cfg(target_arch = "x86_64")]
        if simd_active() {
            return unsafe { avx2::dot_split_f64(w, re, im) };
        }
        scalar::dot_split::<f64, 4>(w, re, im)
    }

    #[inline]
    fn cdot_conj(x: &[C<f64>], y: &[C<f64>]) -> C<f64> {
        debug_assert_eq!(x.len(), y.len());
        #[cfg(target_arch = "x86_64")]
        if simd_active() {
            return unsafe { avx2::cdot_flat_f64::<true>(flat(x), flat(y)) };
        }
        scalar::cdot_flat::<f64, 4, true>(flat(x), flat(y))
    }

    #[inline]
    fn cdot(x: &[C<f64>], y: &[C<f64>]) -> C<f64> {
        debug_assert_eq!(x.len(), y.len());
        #[cfg(target_arch = "x86_64")]
        if simd_active() {
            return unsafe { avx2::cdot_flat_f64::<false>(flat(x), flat(y)) };
        }
        scalar::cdot_flat::<f64, 4, false>(flat(x), flat(y))
    }

    #[inline]
    fn caxpy(s: C<f64>, x: &[C<f64>], y: &mut [C<f64>]) {
        debug_assert_eq!(x.len(), y.len());
        #[cfg(target_arch = "x86_64")]
        if simd_active() {
            return unsafe { avx2::caxpy_f64(s, flat(x), flat_mut(y)) };
        }
        scalar::caxpy_flat(s, flat(x), flat_mut(y))
    }

    #[inline]
    fn crot(p: &mut [C<f64>], q: &mut [C<f64>], c: f64, sp: C<f64>, sm: C<f64>) {
        debug_assert_eq!(p.len(), q.len());
        #[cfg(target_arch = "x86_64")]
        if simd_active() {
            return unsafe { avx2::crot_f64(flat_mut(p), flat_mut(q), c, sp, sm) };
        }
        scalar::crot_flat(flat_mut(p), flat_mut(q), c, sp, sm)
    }
}

impl SimdReal for f32 {
    #[inline]
    fn dot_split(w: &[f32], re: &[f32], im: &[f32]) -> (f32, f32) {
        #[cfg(target_arch = "x86_64")]
        if simd_active() {
            return unsafe { avx2::dot_split_f32(w, re, im) };
        }
        scalar::dot_split::<f32, 8>(w, re, im)
    }

    #[inline]
    fn cdot_conj(x: &[C<f32>], y: &[C<f32>]) -> C<f32> {
        debug_assert_eq!(x.len(), y.len());
        #[cfg(target_arch = "x86_64")]
        if simd_active() {
            return unsafe { avx2::cdot_flat_f32::<true>(flat(x), flat(y)) };
        }
        scalar::cdot_flat::<f32, 8, true>(flat(x), flat(y))
    }

    #[inline]
    fn cdot(x: &[C<f32>], y: &[C<f32>]) -> C<f32> {
        debug_assert_eq!(x.len(), y.len());
        #[cfg(target_arch = "x86_64")]
        if simd_active() {
            return unsafe { avx2::cdot_flat_f32::<false>(flat(x), flat(y)) };
        }
        scalar::cdot_flat::<f32, 8, false>(flat(x), flat(y))
    }

    #[inline]
    fn caxpy(s: C<f32>, x: &[C<f32>], y: &mut [C<f32>]) {
        debug_assert_eq!(x.len(), y.len());
        #[cfg(target_arch = "x86_64")]
        if simd_active() {
            return unsafe { avx2::caxpy_f32(s, flat(x), flat_mut(y)) };
        }
        scalar::caxpy_flat(s, flat(x), flat_mut(y))
    }

    #[inline]
    fn crot(p: &mut [C<f32>], q: &mut [C<f32>], c: f32, sp: C<f32>, sm: C<f32>) {
        debug_assert_eq!(p.len(), q.len());
        #[cfg(target_arch = "x86_64")]
        if simd_active() {
            return unsafe { avx2::crot_f32(flat_mut(p), flat_mut(q), c, sp, sm) };
        }
        scalar::crot_flat(flat_mut(p), flat_mut(q), c, sp, sm)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numeric::Pcg64;

    fn cvec<T: Real>(rng: &mut Pcg64, n: usize) -> Vec<C<T>> {
        (0..n)
            .map(|_| C { re: T::from_f64(rng.normal()), im: T::from_f64(rng.normal()) })
            .collect()
    }

    /// Run `f` on the active path and again with the fallback forced;
    /// restores the toggle.
    fn both_paths<R>(f: impl Fn() -> R) -> (R, R) {
        let active = f();
        set_force_scalar(true);
        let forced = f();
        set_force_scalar(false);
        (active, forced)
    }

    #[test]
    fn dot_split_matches_reference_and_paths_agree() {
        let mut rng = Pcg64::seeded(900);
        for n in [0usize, 1, 3, 4, 7, 8, 15, 64, 129] {
            let w: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let re: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let im: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let (sr, si) = <f64 as SimdReal>::dot_split(&w, &re, &im);
            let want_r: f64 = w.iter().zip(&re).map(|(a, b)| a * b).sum();
            let want_i: f64 = w.iter().zip(&im).map(|(a, b)| a * b).sum();
            assert!((sr - want_r).abs() < 1e-10 && (si - want_i).abs() < 1e-10, "n={n}");
            let (a, b) = both_paths(|| <f64 as SimdReal>::dot_split(&w, &re, &im));
            assert_eq!(a, b, "n={n}: simd and scalar must agree bitwise");
        }
    }

    #[test]
    fn cdots_match_reference_and_paths_agree() {
        let mut rng = Pcg64::seeded(901);
        for n in [0usize, 1, 2, 3, 5, 8, 33, 100] {
            let x = cvec::<f64>(&mut rng, n);
            let y = cvec::<f64>(&mut rng, n);
            let want_c: C<f64> =
                x.iter().zip(&y).fold(C::ZERO, |acc, (a, b)| acc + *a * b.conj());
            let want_p: C<f64> = x.iter().zip(&y).fold(C::ZERO, |acc, (a, b)| acc + *a * *b);
            let got_c = <f64 as SimdReal>::cdot_conj(&x, &y);
            let got_p = <f64 as SimdReal>::cdot(&x, &y);
            assert!((got_c - want_c).abs() < 1e-10, "conj n={n}");
            assert!((got_p - want_p).abs() < 1e-10, "plain n={n}");
            let (a, b) = both_paths(|| <f64 as SimdReal>::cdot_conj(&x, &y));
            assert_eq!((a.re, a.im), (b.re, b.im), "conj n={n} bitwise");
            let (a, b) = both_paths(|| <f64 as SimdReal>::cdot(&x, &y));
            assert_eq!((a.re, a.im), (b.re, b.im), "plain n={n} bitwise");
        }
    }

    #[test]
    fn cdots_f32_paths_agree_bitwise() {
        let mut rng = Pcg64::seeded(902);
        for n in [0usize, 1, 4, 7, 8, 9, 64, 101] {
            let x = cvec::<f32>(&mut rng, n);
            let y = cvec::<f32>(&mut rng, n);
            let (a, b) = both_paths(|| <f32 as SimdReal>::cdot_conj(&x, &y));
            assert_eq!((a.re, a.im), (b.re, b.im), "conj n={n}");
            let w: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let re: Vec<f32> = x.iter().map(|z| z.re).collect();
            let im: Vec<f32> = x.iter().map(|z| z.im).collect();
            let (a, b) = both_paths(|| <f32 as SimdReal>::dot_split(&w, &re, &im));
            assert_eq!(a, b, "split n={n}");
        }
    }

    #[test]
    fn caxpy_and_crot_match_reference_and_paths_agree() {
        let mut rng = Pcg64::seeded(903);
        for n in [0usize, 1, 2, 5, 8, 31] {
            let s = C { re: rng.normal(), im: rng.normal() };
            let x = cvec::<f64>(&mut rng, n);
            let y0 = cvec::<f64>(&mut rng, n);
            let mut want = y0.clone();
            for (w, xv) in want.iter_mut().zip(&x) {
                *w += s * *xv;
            }
            let mut got = y0.clone();
            <f64 as SimdReal>::caxpy(s, &x, &mut got);
            for (a, b) in got.iter().zip(&want) {
                assert!((*a - *b).abs() < 1e-10, "caxpy n={n}");
            }
            let (a, b) = both_paths(|| {
                let mut y = y0.clone();
                <f64 as SimdReal>::caxpy(s, &x, &mut y);
                y
            });
            assert!(a.iter().zip(&b).all(|(p, q)| p == q), "caxpy n={n} bitwise");

            let c = rng.normal();
            let sp = C { re: rng.normal(), im: rng.normal() };
            let sm = sp.conj().scale(-1.0);
            let p0 = cvec::<f64>(&mut rng, n);
            let q0 = cvec::<f64>(&mut rng, n);
            let run = || {
                let mut p = p0.clone();
                let mut q = q0.clone();
                <f64 as SimdReal>::crot(&mut p, &mut q, c, sp, sm);
                (p, q)
            };
            let ((pa, qa), (pb, qb)) = both_paths(run);
            assert!(pa.iter().zip(&pb).all(|(x, y)| x == y), "crot p n={n}");
            assert!(qa.iter().zip(&qb).all(|(x, y)| x == y), "crot q n={n}");
            // Reference rotation.
            for i in 0..n {
                let want_p = p0[i].scale(c) - sp * q0[i];
                let want_q = sm * p0[i] + q0[i].scale(c);
                assert!((pa[i] - want_p).abs() < 1e-10, "crot ref p n={n}");
                assert!((qa[i] - want_q).abs() < 1e-10, "crot ref q n={n}");
            }
        }
    }

    #[test]
    fn force_scalar_toggle_reports() {
        set_force_scalar(true);
        assert!(!simd_active());
        assert_eq!(active_kernel_name(), "scalar");
        set_force_scalar(false);
    }
}
