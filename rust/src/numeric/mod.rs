//! Numeric substrate: complex arithmetic, dense matrices with explicit
//! memory layout, a deterministic PRNG, the [`Real`] scalar abstraction
//! (f64/f32), and the SIMD kernel dispatch layer.

pub mod complex;
pub mod mat;
pub mod real;
pub mod rng;
pub mod simd;

pub use complex::{c32, c64, C, C32, C64};
pub use mat::{CMat, Layout, Mat};
pub use real::Real;
pub use rng::Pcg64;
pub use simd::{active_kernel_name, set_force_scalar, simd_active, SimdReal};
