//! Numeric substrate: complex arithmetic, dense matrices with explicit
//! memory layout, and a deterministic PRNG.

pub mod complex;
pub mod mat;
pub mod rng;

pub use complex::{c64, C64};
pub use mat::{CMat, Layout, Mat};
pub use rng::Pcg64;
