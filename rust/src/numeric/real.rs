//! The [`Real`] scalar abstraction: the one trait the whole numeric and
//! linear-algebra substrate is generic over.
//!
//! Two implementors exist — `f64` (the default everywhere; every public
//! `C64`/`CMat` alias resolves to it) and `f32` (the half-width SIMD tier
//! behind [`crate::lfa::Precision::F32`]). The trait carries exactly what
//! the kernels need:
//!
//! - arithmetic/comparison bounds and the usual transcendental helpers
//!   (`sqrt`, `hypot`, `sin_cos`, `atan2`);
//! - conversions to/from `f64`, the crate's interchange precision (the
//!   PRNG, the spectrum output buffers, and all public APIs speak `f64`);
//! - **per-precision tolerance constants**. Every magic threshold in the
//!   solvers (`1e-12` Jacobi convergence, `1e-300` division guards,
//!   `1e-13` Lanczos breakdown, …) is an f64-ism; its f32 analogue lives
//!   here, scaled to f32's ~1.2e-7 machine epsilon, so a solver written
//!   once against `T::SVD_TOL` converges correctly at either width.
//!
//! Tolerances are deliberately associated consts, not parameters: they are
//! properties of the arithmetic, not of the caller.

use std::fmt::{Debug, Display};
use std::iter::Sum;
use std::ops::{Add, AddAssign, Div, DivAssign, Mul, MulAssign, Neg, Sub, SubAssign};

/// A real scalar the spectral engine can run on. Implemented for `f64` and
/// `f32`; sealed in practice by the tolerance-constant surface.
pub trait Real:
    Copy
    + Clone
    + Debug
    + Display
    + Default
    + PartialEq
    + PartialOrd
    + Send
    + Sync
    + 'static
    + Add<Output = Self>
    + Sub<Output = Self>
    + Mul<Output = Self>
    + Div<Output = Self>
    + Neg<Output = Self>
    + AddAssign
    + SubAssign
    + MulAssign
    + DivAssign
    + Sum
{
    const ZERO: Self;
    const ONE: Self;
    const TWO: Self;
    const HALF: Self;
    /// Machine epsilon.
    const EPS: Self;
    /// Underflow-guard floor for divisions (`max(TINY)` denominators).
    const TINY: Self;
    /// "Numerically negligible vector norm" floor (warm-start hints, etc.).
    const SMALL: Self;
    /// One-sided Jacobi SVD relative off-diagonal convergence tolerance.
    const SVD_TOL: Self;
    /// Two-sided Hermitian Jacobi relative off-norm tolerance.
    const EIG_TOL: Self;
    /// Lanczos β breakdown threshold (relative to the running scale).
    const BREAKDOWN: Self;
    /// Implicit-QL deflation guard (relative off-diagonal floor).
    const QL_EPS: Self;
    /// Inverse-iteration shift perturbation.
    const SHIFT: Self;

    fn from_f64(x: f64) -> Self;
    fn to_f64(self) -> f64;
    fn from_usize(x: usize) -> Self {
        Self::from_f64(x as f64)
    }
    /// Fused multiply-add `self·a + b` with a single rounding — the scalar
    /// twin of the SIMD FMA lanes, so the portable fallback can reproduce
    /// the vectorized kernels bit-for-bit.
    fn mul_add(self, a: Self, b: Self) -> Self;
    fn abs(self) -> Self;
    fn sqrt(self) -> Self;
    fn hypot(self, other: Self) -> Self;
    fn atan2(self, other: Self) -> Self;
    fn sin_cos(self) -> (Self, Self);
    fn max(self, other: Self) -> Self;
    fn min(self, other: Self) -> Self;
    fn recip(self) -> Self;
    fn is_nan(self) -> bool;
    fn is_finite(self) -> bool;
}

macro_rules! forward_real_methods {
    () => {
        #[inline(always)]
        fn mul_add(self, a: Self, b: Self) -> Self {
            self.mul_add(a, b)
        }
        #[inline(always)]
        fn abs(self) -> Self {
            self.abs()
        }
        #[inline(always)]
        fn sqrt(self) -> Self {
            self.sqrt()
        }
        #[inline(always)]
        fn hypot(self, other: Self) -> Self {
            self.hypot(other)
        }
        #[inline(always)]
        fn atan2(self, other: Self) -> Self {
            self.atan2(other)
        }
        #[inline(always)]
        fn sin_cos(self) -> (Self, Self) {
            self.sin_cos()
        }
        #[inline(always)]
        fn max(self, other: Self) -> Self {
            self.max(other)
        }
        #[inline(always)]
        fn min(self, other: Self) -> Self {
            self.min(other)
        }
        #[inline(always)]
        fn recip(self) -> Self {
            self.recip()
        }
        #[inline(always)]
        fn is_nan(self) -> bool {
            self.is_nan()
        }
        #[inline(always)]
        fn is_finite(self) -> bool {
            self.is_finite()
        }
    };
}

impl Real for f64 {
    const ZERO: f64 = 0.0;
    const ONE: f64 = 1.0;
    const TWO: f64 = 2.0;
    const HALF: f64 = 0.5;
    const EPS: f64 = f64::EPSILON;
    const TINY: f64 = 1e-300;
    const SMALL: f64 = 1e-150;
    const SVD_TOL: f64 = 1e-12;
    const EIG_TOL: f64 = 1e-15;
    const BREAKDOWN: f64 = 1e-13;
    const QL_EPS: f64 = 1e-16;
    const SHIFT: f64 = 1e-12;

    #[inline(always)]
    fn from_f64(x: f64) -> f64 {
        x
    }
    #[inline(always)]
    fn to_f64(self) -> f64 {
        self
    }
    forward_real_methods!();
}

impl Real for f32 {
    const ZERO: f32 = 0.0;
    const ONE: f32 = 1.0;
    const TWO: f32 = 2.0;
    const HALF: f32 = 0.5;
    const EPS: f32 = f32::EPSILON;
    const TINY: f32 = 1e-30;
    const SMALL: f32 = 1e-15;
    // f32 ε ≈ 1.19e-7: tolerances sit a little above it so the sweeps
    // terminate instead of chasing round-off.
    const SVD_TOL: f32 = 1e-6;
    const EIG_TOL: f32 = 2e-7;
    const BREAKDOWN: f32 = 1e-5;
    const QL_EPS: f32 = 2e-7;
    const SHIFT: f32 = 1e-6;

    #[inline(always)]
    fn from_f64(x: f64) -> f32 {
        x as f32
    }
    #[inline(always)]
    fn to_f64(self) -> f64 {
        self as f64
    }
    forward_real_methods!();
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip<T: Real>() {
        assert_eq!(T::from_f64(2.0).to_f64(), 2.0);
        assert_eq!(T::from_usize(7).to_f64(), 7.0);
        assert_eq!((T::ONE + T::ONE).to_f64(), T::TWO.to_f64());
        assert!(T::EPS > T::ZERO && T::EPS < T::ONE);
        assert!(T::TINY > T::ZERO && T::TINY < T::SMALL);
        assert!(T::SVD_TOL > T::EPS * T::HALF);
    }

    #[test]
    fn both_widths_roundtrip() {
        roundtrip::<f64>();
        roundtrip::<f32>();
    }

    #[test]
    fn transcendentals_forward() {
        let (s, c) = <f32 as Real>::sin_cos(0.0f32);
        assert_eq!((s, c), (0.0, 1.0));
        assert_eq!(<f64 as Real>::hypot(3.0, 4.0), 5.0);
        assert_eq!(Real::max(1.0f32, 2.0), 2.0);
    }
}
