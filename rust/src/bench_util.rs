//! Minimal statistical benchmarking harness (criterion is not in the
//! offline crate set). Each paper-table bench is a `harness = false`
//! binary built on this module.
//!
//! Method: warmup runs, then `samples` timed runs; report median and MAD
//! (median absolute deviation) — robust against scheduler noise on the
//! single-core CI box.

use std::time::{Duration, Instant};

/// One measurement series.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub samples: Vec<Duration>,
}

impl Measurement {
    pub fn median(&self) -> Duration {
        let mut v: Vec<Duration> = self.samples.clone();
        v.sort();
        v[v.len() / 2]
    }

    /// Median absolute deviation.
    pub fn mad(&self) -> Duration {
        let med = self.median();
        let mut devs: Vec<Duration> = self
            .samples
            .iter()
            .map(|&s| if s > med { s - med } else { med - s })
            .collect();
        devs.sort();
        devs[devs.len() / 2]
    }

    pub fn min(&self) -> Duration {
        *self.samples.iter().min().unwrap()
    }
}

/// Benchmark runner with a global time budget per measurement.
pub struct Bench {
    pub warmup: usize,
    pub samples: usize,
    /// Skip additional samples once a measurement exceeds this budget
    /// (long-running points get fewer repetitions, like criterion's
    /// adaptive sampling).
    pub sample_budget: Duration,
}

impl Default for Bench {
    fn default() -> Self {
        Self { warmup: 1, samples: 5, sample_budget: Duration::from_secs(20) }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Self { warmup: 1, samples: 3, sample_budget: Duration::from_secs(10) }
    }

    /// Measure a closure. The closure's return value is passed to a sink to
    /// prevent the optimizer from eliding the work.
    pub fn measure<T, F: FnMut() -> T>(&self, name: &str, mut f: F) -> Measurement {
        for _ in 0..self.warmup {
            sink(f());
        }
        let mut samples = Vec::with_capacity(self.samples);
        let mut spent = Duration::ZERO;
        for i in 0..self.samples {
            let t0 = Instant::now();
            sink(f());
            let dt = t0.elapsed();
            spent += dt;
            samples.push(dt);
            if spent > self.sample_budget && i >= 1 {
                break;
            }
        }
        Measurement { name: name.to_string(), samples }
    }
}

/// Opaque value sink (black_box substitute on stable).
#[inline]
pub fn sink<T>(value: T) -> T {
    std::hint::black_box(value)
}

/// Parse common bench CLI flags: `--quick` (fewer samples) and `--full`
/// (extended problem sizes). Returns (bench, full).
pub fn bench_args() -> (Bench, bool) {
    let opts = bench_opts();
    (opts.bench, opts.full)
}

/// Full bench CLI options. Beyond [`bench_args`]'s `--quick`/`--full`:
///
/// - `--smoke`: CI bench-smoke mode — quick sampling **and** reduced
///   problem sizes, so the harness finishes in seconds and the recorded
///   numbers form a per-commit trajectory rather than a precise benchmark.
/// - `--json <path>` (or `--json=<path>`): write every measurement as a
///   machine-readable JSON line (see [`JsonLines`]) to `path`.
pub struct BenchOpts {
    pub bench: Bench,
    pub full: bool,
    pub smoke: bool,
    pub json: Option<std::path::PathBuf>,
}

/// Parse [`BenchOpts`] from `std::env::args()`. `cargo bench` passes
/// `--bench`; unknown flags are ignored.
pub fn bench_opts() -> BenchOpts {
    let args: Vec<String> = std::env::args().collect();
    let has = |name: &str| args.iter().any(|a| a == name);
    let smoke = has("--smoke");
    let quick = smoke || has("--quick");
    let full = has("--full") && !smoke;
    let mut json = None;
    let mut it = args.iter().peekable();
    while let Some(a) = it.next() {
        if a == "--json" {
            json = it.peek().map(|p| std::path::PathBuf::from(p.as_str()));
        } else if let Some(p) = a.strip_prefix("--json=") {
            json = Some(std::path::PathBuf::from(p));
        }
    }
    BenchOpts { bench: if quick { Bench::quick() } else { Bench::default() }, full, smoke, json }
}

/// Machine-readable bench output: one `{"bench": …, "case": …,
/// "ns_per_iter": …, "commit": …, "unix_time": …}` JSON object per line,
/// the format CI uploads as `BENCH_<name>.json`. Every line is stamped
/// with the git commit (from `GITHUB_SHA` in CI, `git rev-parse` locally)
/// and the record's creation time, so the perf trajectory the artifacts
/// accumulate stays attributable across runs.
pub struct JsonLines {
    bench: String,
    commit: String,
    unix_time: u64,
    lines: Vec<String>,
}

impl JsonLines {
    pub fn new(bench: &str) -> Self {
        let unix_time = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs())
            .unwrap_or(0);
        Self::with_stamp(bench, &detect_commit(), unix_time)
    }

    /// [`Self::new`] with an explicit commit/time stamp (tests, replays).
    pub fn with_stamp(bench: &str, commit: &str, unix_time: u64) -> Self {
        Self {
            bench: bench.to_string(),
            commit: commit.to_string(),
            unix_time,
            lines: Vec::new(),
        }
    }

    /// Record one case's nanoseconds-per-iteration.
    pub fn record(&mut self, case: &str, ns_per_iter: f64) {
        self.lines.push(format!(
            "{{\"bench\":\"{}\",\"case\":\"{}\",\"ns_per_iter\":{:.1},\
             \"commit\":\"{}\",\"unix_time\":{}}}",
            escape(&self.bench),
            escape(case),
            ns_per_iter,
            escape(&self.commit),
            self.unix_time
        ));
    }

    /// [`Self::record`] from a [`Measurement`] (its minimum sample — robust
    /// against scheduler noise, matching how the tables report).
    pub fn record_measurement(&mut self, case: &str, m: &Measurement) {
        self.record(case, m.min().as_secs_f64() * 1e9);
    }

    /// Write all recorded lines to `path` (one JSON object per line).
    pub fn write(&self, path: &std::path::Path) -> std::io::Result<()> {
        let mut text = self.lines.join("\n");
        text.push('\n');
        std::fs::write(path, text)
    }

    pub fn len(&self) -> usize {
        self.lines.len()
    }

    pub fn is_empty(&self) -> bool {
        self.lines.is_empty()
    }
}

/// Minimal JSON string escaping (case names are plain ASCII identifiers,
/// but don't let a stray quote corrupt the record).
fn escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// The commit hash stamped onto every JSON line: `GITHUB_SHA` when CI set
/// it, `git rev-parse` when running in a checkout, `"unknown"` otherwise.
fn detect_commit() -> String {
    if let Ok(sha) = std::env::var("GITHUB_SHA") {
        let sha = sha.trim().to_string();
        if !sha.is_empty() {
            return sha;
        }
    }
    if let Ok(out) = std::process::Command::new("git").args(["rev-parse", "HEAD"]).output() {
        if out.status.success() {
            if let Ok(sha) = String::from_utf8(out.stdout) {
                let sha = sha.trim().to_string();
                if !sha.is_empty() {
                    return sha;
                }
            }
        }
    }
    "unknown".to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_and_mad() {
        let m = Measurement {
            name: "x".into(),
            samples: vec![
                Duration::from_millis(10),
                Duration::from_millis(12),
                Duration::from_millis(11),
                Duration::from_millis(100), // outlier
                Duration::from_millis(11),
            ],
        };
        assert_eq!(m.median(), Duration::from_millis(11));
        assert!(m.mad() <= Duration::from_millis(1));
        assert_eq!(m.min(), Duration::from_millis(10));
    }

    #[test]
    fn measure_runs_and_counts() {
        let b = Bench { warmup: 1, samples: 4, sample_budget: Duration::from_secs(5) };
        let mut count = 0;
        let m = b.measure("inc", || {
            count += 1;
            count
        });
        assert_eq!(count, 5); // 1 warmup + 4 samples
        assert_eq!(m.samples.len(), 4);
    }

    #[test]
    fn budget_cuts_long_measurements() {
        let b = Bench { warmup: 0, samples: 10, sample_budget: Duration::from_millis(1) };
        let m = b.measure("sleepy", || std::thread::sleep(Duration::from_millis(2)));
        assert!(m.samples.len() < 10);
        assert!(m.samples.len() >= 2);
    }

    #[test]
    fn json_lines_format() {
        let mut j = JsonLines::with_stamp("bench_scaling", "abc123", 1_750_000_000);
        assert!(j.is_empty());
        j.record("lfa n=32", 1234.56);
        j.record_measurement(
            "case \"quoted\"",
            &Measurement { name: "x".into(), samples: vec![Duration::from_nanos(500)] },
        );
        assert_eq!(j.len(), 2);
        assert_eq!(
            j.lines[0],
            "{\"bench\":\"bench_scaling\",\"case\":\"lfa n=32\",\"ns_per_iter\":1234.6,\
             \"commit\":\"abc123\",\"unix_time\":1750000000}"
        );
        assert!(j.lines[1].contains("\\\"quoted\\\""));
        assert!(j.lines[1].contains("\"ns_per_iter\":500.0"));
    }

    #[test]
    fn json_lines_auto_stamp_is_present() {
        let mut j = JsonLines::new("b");
        j.record("case", 1.0);
        // Whatever environment this runs in, every line carries a commit
        // stamp and a timestamp field.
        assert!(j.lines[0].contains("\"commit\":\""));
        assert!(j.lines[0].contains("\"unix_time\":"));
    }
}
