//! Fast Fourier transform substrate (no rustfft in the offline crate set).
//!
//! Provides an in-place iterative radix-2 Cooley–Tukey FFT with precomputed
//! twiddle tables, a Bluestein (chirp-z) fallback for arbitrary lengths, and
//! row–column 2-D transforms. This is the engine of the *FFT baseline*
//! (Sedghi et al. 2019): pad each `c_out×c_in` filter plane to `n×m`,
//! transform, and SVD the per-frequency blocks.

pub mod plan;

pub use plan::FftPlan;

use crate::numeric::C64;
use std::cell::RefCell;
use std::rc::Rc;

/// Direction of the transform.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Direction {
    Forward,
    Inverse,
}

/// Per-thread plan cache capacity. The one-shot entry points below juggle
/// at most a handful of lengths per workload (grid rows/cols and their
/// Bluestein inner lengths build plans recursively, not through here).
const PLAN_CACHE_CAP: usize = 8;

thread_local! {
    /// Most-recently-used-first list of this thread's one-shot plans.
    static PLANS: RefCell<Vec<Rc<FftPlan>>> = const { RefCell::new(Vec::new()) };
}

/// The calling thread's plan for length `n`: built once, then reused by
/// every one-shot transform of that length on this thread (move-to-front
/// LRU, capacity [`PLAN_CACHE_CAP`]). Twiddle tables and bit-reversal (or
/// the Bluestein chirp machinery) are *not* rebuilt per call — the fix for
/// the old one-shot `fft` that planned on every invocation.
fn thread_plan(n: usize) -> Rc<FftPlan> {
    PLANS.with(|cell| {
        let mut plans = cell.borrow_mut();
        if let Some(pos) = plans.iter().position(|p| p.len() == n) {
            let p = plans.remove(pos);
            plans.insert(0, Rc::clone(&p));
            return p;
        }
        let p = Rc::new(FftPlan::new(n));
        plans.insert(0, Rc::clone(&p));
        plans.truncate(PLAN_CACHE_CAP);
        p
    })
}

/// One-shot forward FFT of arbitrary length. The plan is drawn from a
/// small per-thread cache, so repeated one-shot calls of the same length
/// (the FFT baseline's row/column sweeps, `FreqOperator` applications)
/// don't rebuild twiddle tables; hold an [`FftPlan`] yourself only when
/// you want the plan's lifetime explicit.
pub fn fft(data: &mut [C64]) {
    thread_plan(data.len()).forward(data);
}

/// One-shot inverse FFT (normalized by `1/n`), same per-thread plan cache
/// as [`fft`].
pub fn ifft(data: &mut [C64]) {
    thread_plan(data.len()).inverse(data);
}

/// Naive `O(n²)` DFT — the correctness oracle for tests.
pub fn dft_reference(data: &[C64], dir: Direction) -> Vec<C64> {
    let n = data.len();
    let sign = match dir {
        Direction::Forward => -1.0,
        Direction::Inverse => 1.0,
    };
    let mut out = vec![C64::ZERO; n];
    for (k, o) in out.iter_mut().enumerate() {
        let mut acc = C64::ZERO;
        for (j, &x) in data.iter().enumerate() {
            let theta = sign * 2.0 * std::f64::consts::PI * (k * j % n) as f64 / n as f64;
            acc = acc.mul_add(x, C64::cis(theta));
        }
        *o = if dir == Direction::Inverse { acc.scale(1.0 / n as f64) } else { acc };
    }
    out
}

/// 2-D forward FFT over a row-major `rows×cols` grid, in place.
pub fn fft2(data: &mut [C64], rows: usize, cols: usize) {
    fft2_dir(data, rows, cols, Direction::Forward);
}

/// 2-D inverse FFT (normalized), in place.
pub fn ifft2(data: &mut [C64], rows: usize, cols: usize) {
    fft2_dir(data, rows, cols, Direction::Inverse);
}

fn fft2_dir(data: &mut [C64], rows: usize, cols: usize, dir: Direction) {
    assert_eq!(data.len(), rows * cols, "grid shape mismatch");
    let row_plan = thread_plan(cols);
    let col_plan = thread_plan(rows);
    // Transform rows (contiguous).
    for r in 0..rows {
        let row = &mut data[r * cols..(r + 1) * cols];
        row_plan.transform(row, dir);
    }
    // Transform columns via gather/scatter through a scratch buffer.
    let mut scratch = vec![C64::ZERO; rows];
    for c in 0..cols {
        for r in 0..rows {
            scratch[r] = data[r * cols + c];
        }
        col_plan.transform(&mut scratch, dir);
        for r in 0..rows {
            data[r * cols + c] = scratch[r];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numeric::{c64, Pcg64};

    fn rand_signal(n: usize, seed: u64) -> Vec<C64> {
        let mut rng = Pcg64::seeded(seed);
        (0..n).map(|_| C64::new(rng.normal(), rng.normal())).collect()
    }

    fn max_err(a: &[C64], b: &[C64]) -> f64 {
        a.iter().zip(b).map(|(x, y)| (*x - *y).abs()).fold(0.0, f64::max)
    }

    #[test]
    fn matches_dft_power_of_two() {
        for &n in &[1usize, 2, 4, 8, 64, 256] {
            let x = rand_signal(n, 100 + n as u64);
            let want = dft_reference(&x, Direction::Forward);
            let mut got = x.clone();
            fft(&mut got);
            assert!(max_err(&got, &want) < 1e-9 * (n as f64), "n={n}");
        }
    }

    #[test]
    fn matches_dft_arbitrary_lengths() {
        for &n in &[3usize, 5, 6, 7, 12, 15, 17, 31, 100] {
            let x = rand_signal(n, 200 + n as u64);
            let want = dft_reference(&x, Direction::Forward);
            let mut got = x.clone();
            fft(&mut got);
            assert!(max_err(&got, &want) < 1e-8 * (n as f64).max(1.0), "n={n}");
        }
    }

    #[test]
    fn roundtrip() {
        for &n in &[8usize, 12, 17, 64, 100] {
            let x = rand_signal(n, 300 + n as u64);
            let mut y = x.clone();
            fft(&mut y);
            ifft(&mut y);
            assert!(max_err(&x, &y) < 1e-10 * (n as f64), "n={n}");
        }
    }

    #[test]
    fn impulse_is_flat() {
        let n = 16;
        let mut x = vec![C64::ZERO; n];
        x[0] = C64::ONE;
        fft(&mut x);
        for v in &x {
            assert!((v.re - 1.0).abs() < 1e-12 && v.im.abs() < 1e-12);
        }
    }

    #[test]
    fn parseval() {
        let n = 128;
        let x = rand_signal(n, 7);
        let mut y = x.clone();
        fft(&mut y);
        let e_time: f64 = x.iter().map(|v| v.norm_sqr()).sum();
        let e_freq: f64 = y.iter().map(|v| v.norm_sqr()).sum::<f64>() / n as f64;
        assert!((e_time - e_freq).abs() < 1e-8 * e_time);
    }

    #[test]
    fn linearity() {
        let n = 32;
        let a = rand_signal(n, 8);
        let b = rand_signal(n, 9);
        let sum: Vec<C64> = a.iter().zip(&b).map(|(x, y)| *x + *y).collect();
        let mut fa = a.clone();
        let mut fb = b.clone();
        let mut fs = sum.clone();
        fft(&mut fa);
        fft(&mut fb);
        fft(&mut fs);
        let lin: Vec<C64> = fa.iter().zip(&fb).map(|(x, y)| *x + *y).collect();
        assert!(max_err(&fs, &lin) < 1e-10);
    }

    #[test]
    fn fft2_matches_row_col_dft() {
        let (r, c) = (6usize, 8usize);
        let x = rand_signal(r * c, 10);
        // Reference: DFT rows then cols.
        let mut want = x.clone();
        for i in 0..r {
            let row: Vec<C64> = want[i * c..(i + 1) * c].to_vec();
            let f = dft_reference(&row, Direction::Forward);
            want[i * c..(i + 1) * c].copy_from_slice(&f);
        }
        for j in 0..c {
            let col: Vec<C64> = (0..r).map(|i| want[i * c + j]).collect();
            let f = dft_reference(&col, Direction::Forward);
            for i in 0..r {
                want[i * c + j] = f[i];
            }
        }
        let mut got = x.clone();
        fft2(&mut got, r, c);
        assert!(max_err(&got, &want) < 1e-9);
    }

    #[test]
    fn fft2_roundtrip() {
        let (r, c) = (16usize, 12usize);
        let x = rand_signal(r * c, 11);
        let mut y = x.clone();
        fft2(&mut y, r, c);
        ifft2(&mut y, r, c);
        assert!(max_err(&x, &y) < 1e-9);
    }

    #[test]
    fn shift_theorem() {
        // x[(j+1) mod n] ↦ X[k]·e^{2πik/n}
        let n = 64;
        let x = rand_signal(n, 12);
        let mut shifted: Vec<C64> = (0..n).map(|j| x[(j + 1) % n]).collect();
        let mut fx = x.clone();
        fft(&mut fx);
        fft(&mut shifted);
        for k in 0..n {
            let phase = C64::cis(2.0 * std::f64::consts::PI * k as f64 / n as f64);
            let want = fx[k] * phase;
            assert!((shifted[k] - want).abs() < 1e-9, "k={k}");
        }
    }

    #[test]
    fn single_element() {
        let mut x = vec![c64(3.0, -2.0)];
        fft(&mut x);
        assert_eq!(x[0], c64(3.0, -2.0));
    }

    #[test]
    fn one_shot_plans_are_cached_per_thread() {
        // Two one-shot transforms of the same length share one plan …
        let a = thread_plan(96);
        let b = thread_plan(96);
        assert!(Rc::ptr_eq(&a, &b), "same length must reuse the cached plan");
        // … which moves to the front on reuse, and distinct lengths
        // coexist up to the cap, evicting least-recently-used beyond it.
        let lens: Vec<usize> = (1..=PLAN_CACHE_CAP + 1).map(|i| 96 + i).collect();
        for &n in &lens {
            let _ = thread_plan(n);
        }
        let oldest = thread_plan(96);
        assert!(
            !Rc::ptr_eq(&a, &oldest),
            "filling the cache past capacity must evict the oldest plan"
        );
        // Cached plans still transform correctly (the reuse is pure).
        let x = rand_signal(96, 42);
        let want = dft_reference(&x, Direction::Forward);
        let mut got = x.clone();
        fft(&mut got);
        assert!(max_err(&got, &want) < 1e-8 * 96.0);
        ifft(&mut got);
        assert!(max_err(&got, &x) < 1e-10 * 96.0);
    }
}
