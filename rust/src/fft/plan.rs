//! FFT execution plans: precomputed twiddles + bit-reversal for radix-2,
//! Bluestein chirp-z machinery for arbitrary lengths.

use super::Direction;
use crate::numeric::C64;
use std::f64::consts::PI;

enum Algo {
    /// Iterative radix-2 Cooley–Tukey (n = 2^k).
    Radix2 {
        /// Bit-reversal permutation.
        rev: Vec<u32>,
        /// Forward twiddles, grouped per stage: for stage length `len`,
        /// `len/2` factors `e^{-2πi j/len}`, stages concatenated.
        twiddles: Vec<C64>,
    },
    /// Bluestein chirp-z: any n via a radix-2 convolution of length m ≥ 2n−1.
    Bluestein {
        m: usize,
        inner: Box<FftPlan>,
        /// Chirp `e^{-iπ j²/n}` for j in 0..n (forward convention).
        chirp: Vec<C64>,
        /// FFT of the zero-padded conjugate-chirp filter, forward direction.
        filter_fft: Vec<C64>,
    },
}

/// A reusable transform plan for a fixed length.
pub struct FftPlan {
    n: usize,
    algo: Algo,
}

impl FftPlan {
    pub fn new(n: usize) -> Self {
        assert!(n > 0, "FFT length must be positive");
        let algo = if n.is_power_of_two() {
            Algo::Radix2 { rev: bit_reversal(n), twiddles: stage_twiddles(n) }
        } else {
            let m = (2 * n - 1).next_power_of_two();
            let inner = Box::new(FftPlan::new(m));
            let chirp: Vec<C64> = (0..n)
                .map(|j| {
                    // j² mod 2n keeps the angle argument small for huge n.
                    let jj = (j * j) % (2 * n);
                    C64::cis(-PI * jj as f64 / n as f64)
                })
                .collect();
            // Filter b[j] = conj(chirp[|j|]) wrapped into length m.
            let mut b = vec![C64::ZERO; m];
            b[0] = chirp[0].conj();
            for j in 1..n {
                b[j] = chirp[j].conj();
                b[m - j] = chirp[j].conj();
            }
            inner.transform(&mut b, Direction::Forward);
            Algo::Bluestein { m, inner, chirp, filter_fft: b }
        };
        FftPlan { n, algo }
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    pub fn forward(&self, data: &mut [C64]) {
        self.transform(data, Direction::Forward);
    }

    /// Inverse transform, normalized by `1/n`.
    pub fn inverse(&self, data: &mut [C64]) {
        self.transform(data, Direction::Inverse);
    }

    /// Run the plan in the given direction (inverse includes the `1/n`).
    pub fn transform(&self, data: &mut [C64], dir: Direction) {
        assert_eq!(data.len(), self.n, "plan length mismatch");
        match &self.algo {
            Algo::Radix2 { rev, twiddles } => {
                // Inverse via conjugation: IFFT(x) = conj(FFT(conj(x)))/n.
                if dir == Direction::Inverse {
                    for v in data.iter_mut() {
                        *v = v.conj();
                    }
                }
                radix2_forward(data, rev, twiddles);
                if dir == Direction::Inverse {
                    let s = 1.0 / self.n as f64;
                    for v in data.iter_mut() {
                        *v = v.conj().scale(s);
                    }
                }
            }
            Algo::Bluestein { m, inner, chirp, filter_fft } => {
                let n = self.n;
                let conj_in = dir == Direction::Inverse;
                let mut a = vec![C64::ZERO; *m];
                for j in 0..n {
                    let x = if conj_in { data[j].conj() } else { data[j] };
                    a[j] = x * chirp[j];
                }
                inner.transform(&mut a, Direction::Forward);
                for (av, bv) in a.iter_mut().zip(filter_fft.iter()) {
                    *av = *av * *bv;
                }
                inner.transform(&mut a, Direction::Inverse);
                for j in 0..n {
                    let y = a[j] * chirp[j];
                    data[j] = if conj_in { y.conj().scale(1.0 / n as f64) } else { y };
                }
            }
        }
    }
}

fn bit_reversal(n: usize) -> Vec<u32> {
    let bits = n.trailing_zeros();
    (0..n as u32).map(|i| i.reverse_bits() >> (32 - bits.max(1)) as u32).collect()
}

fn stage_twiddles(n: usize) -> Vec<C64> {
    let mut tw = Vec::with_capacity(n.max(1));
    let mut len = 2;
    while len <= n {
        let half = len / 2;
        for j in 0..half {
            tw.push(C64::cis(-2.0 * PI * j as f64 / len as f64));
        }
        len <<= 1;
    }
    tw
}

fn radix2_forward(data: &mut [C64], rev: &[u32], twiddles: &[C64]) {
    let n = data.len();
    if n == 1 {
        return;
    }
    // Bit-reverse permutation.
    for i in 0..n {
        let j = rev[i] as usize;
        if i < j {
            data.swap(i, j);
        }
    }
    // Butterfly stages with precomputed twiddles.
    let mut len = 2;
    let mut tw_off = 0;
    while len <= n {
        let half = len / 2;
        let tws = &twiddles[tw_off..tw_off + half];
        let mut base = 0;
        while base < n {
            for j in 0..half {
                let u = data[base + j];
                let v = data[base + j + half] * tws[j];
                data[base + j] = u + v;
                data[base + j + half] = u - v;
            }
            base += len;
        }
        tw_off += half;
        len <<= 1;
    }
}
