//! The FFT baseline of Sedghi, Gupta & Long (ICLR 2019): transform each of
//! the `c_out·c_in` filter planes with a 2-D FFT of size `n×m`
//! (`O(n·m·log(nm))` each), gather the per-frequency `c_out×c_in` blocks,
//! and SVD them — total `O(n²c²(c + log n))` (Table I, row "FFT").
//!
//! Two fidelity details matter for the paper's Tables III/IV:
//!
//! 1. The FFT writes its output *plane by plane* — each `(o,i)` pair's
//!    spectrum is contiguous, so the per-frequency blocks are **strided**
//!    (`PlanarStrided`). That is the "memory layout produced by the FFT"
//!    whose SVD stage runs slower than LFA's block-contiguous one.
//! 2. Optionally converting to block-contiguous before the SVD reproduces
//!    the `s_copy` experiment of Table IV.
//!
//! The SVD stage is literally [`svd_pass`] — the engine-backed per-block
//! pass the LFA route uses, with the same per-worker solver workspaces —
//! so the Table III comparison isolates the transform alone.

use crate::conv::ConvKernel;
use crate::fft::FftPlan;
use crate::fft::Direction;
use crate::lfa::svd::{svd_pass, LfaOptions};
use crate::lfa::{BlockLayout, Spectrum, StageTiming, SymbolGrid};
use crate::numeric::C64;
use std::time::Instant;

/// Layout policy for the FFT route (Table IV's knob).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FftLayoutPolicy {
    /// SVD directly on the FFT's natural (strided) layout — what the paper
    /// found fastest overall for large `n`.
    Natural,
    /// Pay an explicit conversion to block-contiguous first (`s_copy`).
    ConvertToContiguous,
}

/// Compute the symbol grid via 2-D FFTs of the zero-padded, wrap-embedded
/// filter planes. Mathematically identical to `lfa::compute_symbols` for
/// periodic boundary conditions (up to FP roundoff).
pub fn fft_symbols(kernel: &ConvKernel, n: usize, m: usize) -> SymbolGrid {
    let mut grid =
        SymbolGrid::zeros(n, m, kernel.c_out, kernel.c_in, BlockLayout::PlanarStrided);
    let nm = n * m;
    let (ar, ac) = (kernel.anchor.0 as isize, kernel.anchor.1 as isize);
    let row_plan = FftPlan::new(m);
    let col_plan = FftPlan::new(n);
    let mut plane = vec![C64::ZERO; nm];
    for o in 0..kernel.c_out {
        for i in 0..kernel.c_in {
            // Embed taps at wrapped displacement positions.
            plane.iter_mut().for_each(|z| *z = C64::ZERO);
            for r in 0..kernel.kh {
                for c in 0..kernel.kw {
                    let w = kernel.get(o, i, r, c);
                    if w == 0.0 {
                        continue;
                    }
                    let dy = (r as isize - ar).rem_euclid(n as isize) as usize;
                    let dx = (c as isize - ac).rem_euclid(m as isize) as usize;
                    plane[dy * m + dx] += C64::real(w);
                }
            }
            // 2-D FFT in place (rows then columns).
            for rr in 0..n {
                row_plan.transform(&mut plane[rr * m..(rr + 1) * m], Direction::Forward);
            }
            let mut scratch = vec![C64::ZERO; n];
            for cc in 0..m {
                for rr in 0..n {
                    scratch[rr] = plane[rr * m + cc];
                }
                col_plan.transform(&mut scratch, Direction::Forward);
                for rr in 0..n {
                    plane[rr * m + cc] = scratch[rr];
                }
            }
            // DFT uses e^{−2πi…}; the symbol convention is e^{+2πi…}. For
            // real weights the two are complex conjugates, so conjugate here
            // to make the grids comparable entry-for-entry with LFA.
            let base = (o * kernel.c_in + i) * nm;
            for (dst, &src) in grid.data[base..base + nm].iter_mut().zip(plane.iter()) {
                *dst = src.conj();
            }
        }
    }
    grid
}

/// Singular values via the FFT baseline.
pub fn singular_values(
    kernel: &ConvKernel,
    n: usize,
    m: usize,
    policy: FftLayoutPolicy,
    threads: usize,
) -> Spectrum {
    singular_values_timed(kernel, n, m, policy, threads).0
}

/// Timed FFT baseline: `s_F` (FFT), `s_copy` (layout conversion, if any),
/// `s_SVD` — the exact decomposition of Tables III/IV.
pub fn singular_values_timed(
    kernel: &ConvKernel,
    n: usize,
    m: usize,
    policy: FftLayoutPolicy,
    threads: usize,
) -> (Spectrum, StageTiming) {
    let t0 = Instant::now();
    let grid = fft_symbols(kernel, n, m);
    let transform = t0.elapsed();

    let t1 = Instant::now();
    let grid = match policy {
        FftLayoutPolicy::Natural => grid,
        FftLayoutPolicy::ConvertToContiguous => grid.to_layout(BlockLayout::BlockContiguous),
    };
    let copy = t1.elapsed();

    let t2 = Instant::now();
    let (values, health) =
        svd_pass(&grid, LfaOptions { threads, layout: grid.layout, ..Default::default() });
    let svd = t2.elapsed();
    (
        Spectrum {
            n,
            m,
            c_out: kernel.c_out,
            c_in: kernel.c_in,
            per_freq: kernel.c_out.min(kernel.c_in),
            values,
            health,
        },
        StageTiming { transform, copy, svd },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lfa;
    use crate::numeric::Pcg64;

    #[test]
    fn fft_symbols_match_lfa_symbols() {
        let mut rng = Pcg64::seeded(130);
        let k = ConvKernel::random_he(3, 2, 3, 3, &mut rng);
        for (n, m) in [(4usize, 4usize), (8, 8), (6, 10), (5, 7)] {
            let lfa_grid = lfa::compute_symbols(&k, n, m, BlockLayout::BlockContiguous);
            let fft_grid = fft_symbols(&k, n, m);
            let diff = lfa_grid.max_abs_diff(&fft_grid);
            assert!(diff < 1e-10, "({n},{m}): {diff}");
        }
    }

    #[test]
    fn fft_values_match_lfa_values() {
        let mut rng = Pcg64::seeded(131);
        let k = ConvKernel::random_he(4, 4, 3, 3, &mut rng);
        let (n, m) = (8, 8);
        let s_lfa = lfa::singular_values(&k, n, m, Default::default());
        for policy in [FftLayoutPolicy::Natural, FftLayoutPolicy::ConvertToContiguous] {
            let s_fft = singular_values(&k, n, m, policy, 1);
            for (a, b) in s_lfa.values.iter().zip(&s_fft.values) {
                assert!((a - b).abs() < 1e-9, "{policy:?}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn nonsquare_kernel_counts() {
        let mut rng = Pcg64::seeded(132);
        let k = ConvKernel::random_he(5, 3, 3, 3, &mut rng);
        let s = singular_values(&k, 4, 6, FftLayoutPolicy::Natural, 1);
        assert_eq!(s.values.len(), 4 * 6 * 3);
    }

    #[test]
    fn timing_split_reports_copy_only_when_converting() {
        let mut rng = Pcg64::seeded(133);
        let k = ConvKernel::random_he(2, 2, 3, 3, &mut rng);
        let (_, t_nat) = singular_values_timed(&k, 8, 8, FftLayoutPolicy::Natural, 1);
        assert!(t_nat.copy.as_nanos() < t_nat.total().as_nanos());
        let (_, t_conv) =
            singular_values_timed(&k, 8, 8, FftLayoutPolicy::ConvertToContiguous, 1);
        assert!(t_conv.copy.as_nanos() > 0);
    }
}
