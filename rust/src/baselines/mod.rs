//! The two baselines the paper compares against (Table I / Fig. 7):
//! the naive explicit dense SVD and the FFT route of Sedghi et al. (2019).

pub mod explicit_svd;
pub mod fft_svd;

pub use fft_svd::FftLayoutPolicy;
