//! The *naive explicit* baseline: unroll the convolution into its dense
//! `(n·m·c_out)×(n·m·c_in)` matrix and run a dense SVD — `O(n⁶c³)` for
//! square inputs (Table I, row "explicit"). Practical only for tiny `n`;
//! the benches use it exactly the way the paper does (Fig. 7a, up to the
//! memory/time wall).

use crate::conv::{unroll_dense, Boundary, ConvKernel};
use crate::lfa::{Spectrum, SpectrumHealth};
use crate::linalg::gk_svd;
use std::time::{Duration, Instant};

/// Singular values of the convolution via the explicit dense matrix.
pub fn singular_values(kernel: &ConvKernel, n: usize, m: usize, boundary: Boundary) -> Spectrum {
    singular_values_timed(kernel, n, m, boundary).0
}

/// Timed variant: `(unroll time, svd time)` — the "transform" analogue.
pub fn singular_values_timed(
    kernel: &ConvKernel,
    n: usize,
    m: usize,
    boundary: Boundary,
) -> (Spectrum, (Duration, Duration)) {
    let t0 = Instant::now();
    let a = unroll_dense(kernel, n, m, boundary);
    let unroll = t0.elapsed();
    let t1 = Instant::now();
    let mut values = gk_svd::singular_values(&a);
    let svd = t1.elapsed();
    // Keep descending global order; the per-frequency association is lost in
    // the explicit route (the paper's too) — Spectrum stores the flat list.
    values.sort_by(|x, y| y.partial_cmp(x).unwrap());
    (
        Spectrum {
            n,
            m,
            c_out: kernel.c_out,
            c_in: kernel.c_in,
            per_freq: kernel.c_out.min(kernel.c_in),
            values,
            // The dense GK route carries no per-frequency certificates (the
            // frequency association itself is lost) — empty evidence.
            health: SpectrumHealth::default(),
        },
        (unroll, svd),
    )
}

/// Memory footprint (bytes) of the dense unrolled matrix — the "memory
/// capacity becomes quickly a limiting factor" wall of §IV-b.
pub fn dense_bytes(kernel: &ConvKernel, n: usize, m: usize) -> usize {
    n * m * kernel.c_out * n * m * kernel.c_in * std::mem::size_of::<f64>()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lfa::{self, LfaOptions};
    use crate::numeric::Pcg64;

    #[test]
    fn explicit_periodic_matches_lfa() {
        let mut rng = Pcg64::seeded(120);
        let k = ConvKernel::random_he(2, 2, 3, 3, &mut rng);
        let (n, m) = (4, 4);
        let explicit = singular_values(&k, n, m, Boundary::Periodic);
        let lfa_spec = lfa::singular_values(&k, n, m, LfaOptions::default());
        let lfa_sorted = lfa_spec.sorted_desc();
        assert_eq!(explicit.values.len(), lfa_sorted.len());
        for (a, b) in explicit.values.iter().zip(&lfa_sorted) {
            assert!((a - b).abs() < 1e-8, "explicit {a} vs lfa {b}");
        }
    }

    #[test]
    fn dirichlet_differs_from_periodic_for_small_n() {
        let mut rng = Pcg64::seeded(121);
        let k = ConvKernel::random_he(2, 2, 3, 3, &mut rng);
        let p = singular_values(&k, 4, 4, Boundary::Periodic);
        let d = singular_values(&k, 4, 4, Boundary::Dirichlet);
        let div = Spectrum::divergence(&p.values, &d.values);
        assert!(div > 1e-3, "boundary effect should be visible at n=4: {div}");
    }

    #[test]
    fn memory_model() {
        let k = ConvKernel::zeros(16, 16, 3, 3);
        // n=64, c=16 → 65,536² doubles = 32 GiB
        assert_eq!(dense_bytes(&k, 64, 64), 65536usize * 65536 * 8);
    }
}
