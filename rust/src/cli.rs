//! Hand-rolled CLI argument parsing (no clap in the offline crate set),
//! plus the binary's help text (kept here so the library's tests can pin
//! that every subcommand stays documented).

use crate::error::Result;
use crate::{bail, err};
use std::collections::HashMap;

/// Help text for the `conv-svd-lfa` binary. Every subcommand `main.rs`
/// dispatches on must appear here — enforced by `help_documents_every_command`.
pub const HELP: &str = "\
conv-svd-lfa — efficient SVD of convolutional mappings by Local Fourier Analysis

USAGE: conv-svd-lfa <command> [options]

COMMANDS
  analyze      --n <N> [--m M] [--c-in C] [--c-out C] [--k K] [--threads T]
               [--seed S] [--method lfa|fft|explicit] [--top J]
               [--groups G] [--dilation D] [--transposed]
               [--precision f64|f32|f32-refined]
               Compute the spectrum of a random conv layer. --groups G
               audits a grouped layer (G = C for depthwise), --dilation D
               spaces the taps D pixels apart, --transposed audits the
               adjoint (deconvolution) operator; structured kernels run
               on the LFA engine only (fft/explicit are dense baselines).
  audit        <builtin-or-config.toml> [--threads T] [--backend auto|native|pjrt]
               [--artifacts DIR] [--top-k K] [--no-fold] [--csv]
               [--density B] [--density-sample S]
               [--groups G] [--dilation D] [--transposed]
               [--precision f64|f32|f32-refined]
               [--cache-bytes N] [--no-cache] [--disk-cache-dir DIR]
               [--strict-health]
               Analyze all conv layers of a model through the coordinator
               service (one planned model job, tiled across the worker
               pool). With --top-k K, tiles compute only the K largest
               singular values per frequency (warm-started Krylov
               iteration; native — artifacts bake in the full SVD, so
               combining --top-k with --backend pjrt is an error; σ_min
               and cond report NaN, since the retained extremes say
               nothing about the small end of the spectrum).
               --groups/--dilation/--transposed override the structure of
               *every* layer in the model — the what-if knob for auditing
               a grouped/dilated/transposed variant of a dense builtin
               (channel counts must stay divisible by G).
               --density B streams each layer's whole singular-value
               population into a B-bin histogram instead of materializing
               it: σ_max stays exact (a dedicated warm top-1 pass); the
               bulk statistics (σ_min*, q50*/q90*/q99* quantiles) come
               from the histogram. --density-sample S solves only every
               S-th dual-grid row/column (~1/S² of the SVD work) and the
               report carries the 95% DKW error bar ±ε on every CDF read
               plus a coverage column, so the sampled-vs-solved fraction
               is always visible. Density results are content-addressed
               and cached like spectra (memory tier only); --density
               conflicts with --top-k and runs native (not pjrt).
               Builtins: lenet, vgg-small, resnet20ish, mobile-ish,
               paper-c16-n<N>.
  audit-model  <builtin-or-config.toml> [--threads T] [--solver jacobi|gram]
               [--top J] [--top-k K] [--no-fold] [--csv] [--repeat R]
               [--precision f64|f32|f32-refined]
               [--cache-bytes N] [--no-cache] [--disk-cache-dir DIR]
               [--strict-health]
               Whole-model spectral report straight off a ModelPlan: every
               layer planned once, equal-shape layers batched into shared
               workspace groups, executed as one sweep. Emits the per-layer
               table plus aggregate statistics (global sigma extrema,
               Lipschitz composition bound, batching summary). With
               --top-k K the sweep runs the partial-spectrum engine
               (only the K extreme values per frequency, warm-started
               along the dual grid) and reports the iteration counts the
               warm starts saved. --repeat R runs the sweep R times
               against the result cache — the repeat-audit shape; the
               warm runs serve every unchanged layer from cache. The
               config is [[layer]] TOML (keys: name, c_in, c_out,
               kernel|kh/kw, height, width, stride, groups, dilation,
               transposed, init); c_in is always the total input
               channel count. The mobile-ish builtin exercises every
               structured variant in one model.
  compare      --n <N> [--c C] [--threads T] [--with-explicit]
               LFA vs FFT (vs explicit) runtimes + agreement on one layer.
  serve        [--addr HOST:PORT] [--threads T] [--max-inflight J]
               [--tenant-quota Q] [--request-timeout-ms MS]
               [--io-timeout-ms MS] [--quantum U] [--allow-remote]
               [--cache-bytes N] [--no-cache] [--disk-cache-dir DIR]
               [--precision f64|f32|f32-refined] [--no-fold]
               [--strict-health]
               Run lfa-convd, the long-running spectral-audit daemon
               (built with the default `daemon` feature): a TCP line
               protocol over the coordinator service — PING, SUBMIT
               <tenant> <model> [top-k=K | density=B [density-sample=S]],
               POLL <id>, WAIT <id>, METRICS, STATS, QUIT, SHUTDOWN —
               plus plain-HTTP GET /metrics for scrapers. Density jobs
               stream histograms like `audit --density` and append
               density_bins/sample/coverage/epsilon to the DONE reply. Every SUBMIT names a tenant;
               a tenant holding --tenant-quota jobs queued + running
               (default 8) gets a typed backpressure reply (ERR quota
               tenant=T pending=P limit=Q) instead of queueing deeper,
               and admitted jobs dispatch in deficit-round-robin order
               weighted by layer count, so a flooding tenant cannot
               starve a well-behaved one. Jobs expire after
               --request-timeout-ms (default 30000) — still-queued jobs
               are cancelled unrun, late results discarded — and idle
               connections close after --io-timeout-ms (default 10000).
               The daemon binds loopback (default 127.0.0.1:7733) and
               refuses routable addresses unless --allow-remote; all
               clients share one warm result cache, so point
               --disk-cache-dir at a persistent directory to keep that
               warmth across restarts.
  artifacts    [--dir DIR] [--run NAME]
               List AOT artifacts; optionally execute one via PJRT
               (requires a build with --features pjrt).
  help         Show this text.

--threads 0 (the default) means auto: one worker per available core.

Structured convolutions (grouped / depthwise / dilated / transposed) run
on the native LFA engine: a grouped layer's per-frequency symbol is block
diagonal, so the engine solves g independent c_out/g x s^2*c_in/g blocks
per frequency (depthwise layers degenerate to scalar symbols — g times
cheaper than the dense layer of the same total shape); dilation only
changes the phase tables; a transposed layer is the adjoint symbol, so
its singular values equal the forward layer's and only the reported
operator shape swaps. Folding, precision tiers, --top-k, caching and the
whole-model batching all apply per block — see docs/WORKLOADS.md for the
full supported-configuration matrix. PJRT artifacts bake dense forward
geometry in, so structured layers always route native.

Conjugate-pair frequency folding is on by default for native execution:
real kernels give A(-θ) = conj(A(θ)), so both audit commands solve only a
fundamental domain of the dual grid (about half the frequencies — the
report's `frequencies solved:` line counts what each layer actually
decomposed: folded native layers their fundamental domain, PJRT-routed
layers the full grid, cache-served layers nothing) and mirror the rest.
--no-fold solves every frequency independently (the unfolded reference).

--precision selects the scalar width of the native hot loop (outputs are
always f64): f64 (default) keeps the ≤1e-12 verification thresholds; f32
runs the SIMD-friendly single-precision sweep (~1e-4·σ_max accuracy,
twice the lane width); f32-refined runs the f32 sweep plus one f64
polish per frequency, restoring ≤1e-12 at a fraction of the f64 cost.
PJRT artifacts always compute in f32 regardless of the flag.

Result & plan caching is on by default for both audit commands: spectra
are content-addressed by the kernel weight bits + geometry + options
(including the precision tier — an f32 result is never served where an
f64 one was requested; PJRT results cache under f32-pinned keys), so
repeat audits of unchanged layers are served from an LRU cache without
re-solving a single frequency. The `cache: H hits / M misses / E
evictions` report line shows the traffic; --cache-bytes N caps the result
cache (0 = the default budget) and --no-cache disables caching entirely.

--disk-cache-dir DIR adds a persistent tier below the in-memory LRU: every
computed spectrum is written through to a checksummed, versioned spill
file content-addressed by the same weight-bit signature, and read back in
later processes — a repeat audit after a restart re-solves zero
frequencies and returns bit-identical singular values. Spill files that
fail validation (truncated, bit-flipped, wrong version) are quarantined:
deleted, counted in the disk_corruptions metric, and never served. The
tier requires the result cache (combining it with --no-cache is an
error) and degrades to memory-only with a warning if DIR is unusable.

Every native solve ships a convergence certificate: the per-frequency
block solvers report sweep/residual evidence, and a frequency whose
certificate misses tolerance retries up a bounded escalation ladder —
fresh-rotation restart, top-k → full Jacobi, f32 → f64 re-solve — before
it is ever declared degraded. Audits print the aggregate on a `health:`
report line (certified / retried / escalations / degraded frequencies,
plus nonfinite rejections on the service path). A spectrum still
degraded after the ladder is served *flagged* but never cached — neither
the in-memory LRU nor the disk tier will admit it — so a transient
failure is never replayed. --strict-health (audit, audit-model, serve)
turns a degraded result into a typed error instead: the CLI exits
nonzero naming the layer, and the daemon replies ERR degraded job=I
freqs=N. Kernel weights containing NaN/Inf are rejected at submit time,
before any frequency is solved: the CLI reports the layer and count, the
daemon replies ERR nonfinite, and the rejection is counted in the
nonfinite_rejections metric (jobs_submitted is not incremented).
";

/// Parsed command line: subcommand, positionals, `--key value` / `--flag`
/// options.
pub struct Cli {
    pub command: String,
    pub positional: Vec<String>,
    options: HashMap<String, String>,
    flags: Vec<String>,
}

impl Cli {
    /// Parse `std::env::args()`.
    pub fn from_env(flag_names: &[&str]) -> Result<Cli> {
        let args: Vec<String> = std::env::args().skip(1).collect();
        Self::parse(&args, flag_names)
    }

    /// Parse an argument list. `flag_names` lists boolean options (no
    /// value); anything else starting with `--` takes a value.
    pub fn parse(args: &[String], flag_names: &[&str]) -> Result<Cli> {
        let mut command = String::new();
        let mut positional = Vec::new();
        let mut options = HashMap::new();
        let mut flags = Vec::new();
        let mut it = args.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                let (name, inline) = match name.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (name, None),
                };
                if flag_names.contains(&name) {
                    if inline.is_some() {
                        bail!("flag --{name} does not take a value");
                    }
                    flags.push(name.to_string());
                } else {
                    let value = match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| err!("option --{name} needs a value"))?
                            .clone(),
                    };
                    options.insert(name.to_string(), value);
                }
            } else if command.is_empty() {
                command = a.clone();
            } else {
                positional.push(a.clone());
            }
        }
        Ok(Cli { command, positional, options, flags })
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T> {
        match self.options.get(name) {
            None => Ok(default),
            Some(v) => {
                v.parse::<T>().map_err(|_| err!("--{name}: cannot parse {v:?}"))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_and_options() {
        let c = Cli::parse(&args("analyze --n 32 --threads 4 --verbose layer.toml"), &["verbose"])
            .unwrap();
        assert_eq!(c.command, "analyze");
        assert_eq!(c.positional, vec!["layer.toml"]);
        assert_eq!(c.opt("n"), Some("32"));
        assert_eq!(c.opt_parse::<usize>("threads", 1).unwrap(), 4);
        assert!(c.flag("verbose"));
        assert!(!c.flag("quiet"));
    }

    #[test]
    fn inline_values() {
        let c = Cli::parse(&args("bench --n=64"), &[]).unwrap();
        assert_eq!(c.opt("n"), Some("64"));
    }

    #[test]
    fn missing_value_errors() {
        assert!(Cli::parse(&args("x --n"), &[]).is_err());
    }

    #[test]
    fn defaults() {
        let c = Cli::parse(&args("x"), &[]).unwrap();
        assert_eq!(c.opt_parse::<usize>("n", 16).unwrap(), 16);
    }

    #[test]
    fn help_documents_every_command() {
        // The commands main.rs dispatches on; `audit-model` usage
        // (ModelPlan-backed whole-model report) is pinned here too.
        for cmd in ["analyze", "audit", "audit-model", "compare", "serve", "artifacts", "help"] {
            assert!(HELP.contains(cmd), "HELP must document {cmd:?}");
        }
        for detail in ["--solver jacobi|gram", "ModelPlan", "stride", "Lipschitz"] {
            assert!(HELP.contains(detail), "HELP must document audit-model's {detail:?}");
        }
        // The partial-spectrum mode is documented on both audit paths
        // (usage line + prose for each).
        assert!(
            HELP.matches("--top-k K").count() >= 2,
            "HELP must document --top-k on audit and audit-model"
        );
        // Conjugate-pair folding: the escape hatch appears on both audit
        // usage lines, and the prose names the report line it affects.
        assert!(
            HELP.matches("--no-fold").count() >= 3,
            "HELP must document --no-fold on audit and audit-model"
        );
        assert!(HELP.contains("frequencies solved:"), "HELP must name the fold report line");
        // Result/plan caching: both audit usage lines carry the knobs, and
        // the prose names the cache report line and the repeat mode.
        assert!(
            HELP.matches("--no-cache").count() >= 3,
            "HELP must document --no-cache on audit and audit-model"
        );
        assert!(
            HELP.matches("--cache-bytes").count() >= 3,
            "HELP must document --cache-bytes on audit and audit-model"
        );
        assert!(HELP.contains("cache: H hits / M misses / E"), "HELP must name the cache line");
        assert!(HELP.contains("--repeat R"), "HELP must document audit-model --repeat");
        // Precision tiers: every native-sweep command's usage line carries
        // the flag, and the prose explains the tiers + PJRT's f32 pin.
        assert!(
            HELP.matches("--precision f64|f32|f32-refined").count() >= 3,
            "HELP must document --precision on analyze, audit and audit-model"
        );
        for detail in ["f32-refined", "≤1e-12", "f32-pinned"] {
            assert!(HELP.contains(detail), "HELP must document precision {detail:?}");
        }
        // Structured convolutions: the flag triple appears on both the
        // analyze and audit usage lines, the TOML keys are listed for
        // audit-model, the structured builtin is named, and the prose
        // explains the block-diagonal/adjoint semantics + the native-only
        // routing and points at the workload matrix.
        assert!(
            HELP.matches("--groups G] [--dilation D] [--transposed]").count() >= 2,
            "HELP must document --groups/--dilation/--transposed on analyze and audit"
        );
        for detail in [
            "groups, dilation,\n               transposed",
            "mobile-ish",
            "block\ndiagonal",
            "adjoint symbol",
            "docs/WORKLOADS.md",
            "structured layers always route native",
        ] {
            assert!(HELP.contains(detail), "HELP must document structured convs: {detail:?}");
        }
        // The streaming-density mode: the knob pair on the audit usage
        // line, the daemon's density submit option, and the prose pinning
        // the accuracy contract (exact extremes, sampled bulk with DKW
        // error bars) and the cache/top-k/pjrt interactions.
        assert!(HELP.contains("--density B"), "HELP must document audit --density");
        assert!(HELP.contains("--density-sample S"), "HELP must document --density-sample");
        assert!(
            HELP.contains("density=B [density-sample=S]"),
            "HELP must document the daemon's density submit option"
        );
        for detail in ["σ_max stays exact", "DKW error bar", "coverage", "conflicts with --top-k"] {
            assert!(HELP.contains(detail), "HELP must document density: {detail:?}");
        }
        // The daemon: usage line, the line protocol, multi-tenant fair
        // queueing with typed backpressure, and the loopback-only default.
        for detail in [
            "serve        [--addr HOST:PORT]",
            "SUBMIT",
            "POLL <id>, WAIT <id>",
            "SHUTDOWN",
            "GET /metrics",
            "ERR quota\n               tenant=T pending=P limit=Q",
            "deficit-round-robin",
            "--tenant-quota",
            "--request-timeout-ms",
            "--io-timeout-ms",
            "--allow-remote",
            "127.0.0.1:7733",
        ] {
            assert!(HELP.contains(detail), "HELP must document the daemon: {detail:?}");
        }
        // The persistent disk tier: the knob on audit, audit-model and
        // serve, plus the prose pinning its hard guarantees.
        assert!(
            HELP.matches("--disk-cache-dir DIR").count() >= 4,
            "HELP must document --disk-cache-dir on audit, audit-model, serve and the prose"
        );
        for detail in [
            "spill",
            "bit-identical",
            "quarantined",
            "disk_corruptions",
            "re-solves zero\nfrequencies",
        ] {
            assert!(HELP.contains(detail), "HELP must document the disk tier: {detail:?}");
        }
        // The numerical-health layer: the strict flag on audit,
        // audit-model and serve usage lines plus the prose, which must pin
        // the escalation ladder, the flagged-but-never-cached rule, the
        // health report line and both typed daemon error replies.
        assert!(
            HELP.matches("--strict-health").count() >= 4,
            "HELP must document --strict-health on audit, audit-model and serve"
        );
        for detail in [
            "convergence certificate",
            "escalation ladder",
            "f32 → f64 re-solve",
            "`health:`",
            "never cached",
            "ERR degraded job=I",
            "ERR nonfinite",
            "nonfinite_rejections",
        ] {
            assert!(HELP.contains(detail), "HELP must document numerical health: {detail:?}");
        }
    }
}
