//! Hand-rolled CLI argument parsing (no clap in the offline crate set).

use crate::error::Result;
use crate::{bail, err};
use std::collections::HashMap;

/// Parsed command line: subcommand, positionals, `--key value` / `--flag`
/// options.
pub struct Cli {
    pub command: String,
    pub positional: Vec<String>,
    options: HashMap<String, String>,
    flags: Vec<String>,
}

impl Cli {
    /// Parse `std::env::args()`.
    pub fn from_env(flag_names: &[&str]) -> Result<Cli> {
        let args: Vec<String> = std::env::args().skip(1).collect();
        Self::parse(&args, flag_names)
    }

    /// Parse an argument list. `flag_names` lists boolean options (no
    /// value); anything else starting with `--` takes a value.
    pub fn parse(args: &[String], flag_names: &[&str]) -> Result<Cli> {
        let mut command = String::new();
        let mut positional = Vec::new();
        let mut options = HashMap::new();
        let mut flags = Vec::new();
        let mut it = args.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                let (name, inline) = match name.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (name, None),
                };
                if flag_names.contains(&name) {
                    if inline.is_some() {
                        bail!("flag --{name} does not take a value");
                    }
                    flags.push(name.to_string());
                } else {
                    let value = match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| err!("option --{name} needs a value"))?
                            .clone(),
                    };
                    options.insert(name.to_string(), value);
                }
            } else if command.is_empty() {
                command = a.clone();
            } else {
                positional.push(a.clone());
            }
        }
        Ok(Cli { command, positional, options, flags })
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T> {
        match self.options.get(name) {
            None => Ok(default),
            Some(v) => {
                v.parse::<T>().map_err(|_| err!("--{name}: cannot parse {v:?}"))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_and_options() {
        let c = Cli::parse(&args("analyze --n 32 --threads 4 --verbose layer.toml"), &["verbose"])
            .unwrap();
        assert_eq!(c.command, "analyze");
        assert_eq!(c.positional, vec!["layer.toml"]);
        assert_eq!(c.opt("n"), Some("32"));
        assert_eq!(c.opt_parse::<usize>("threads", 1).unwrap(), 4);
        assert!(c.flag("verbose"));
        assert!(!c.flag("quiet"));
    }

    #[test]
    fn inline_values() {
        let c = Cli::parse(&args("bench --n=64"), &[]).unwrap();
        assert_eq!(c.opt("n"), Some("64"));
    }

    #[test]
    fn missing_value_errors() {
        assert!(Cli::parse(&args("x --n"), &[]).is_err());
    }

    #[test]
    fn defaults() {
        let c = Cli::parse(&args("x"), &[]).unwrap();
        assert_eq!(c.opt_parse::<usize>("n", 16).unwrap(), 16);
    }
}
