//! Minimal property-testing harness (proptest is not in the offline crate
//! set). Runs a property against many seeded random cases and reports the
//! first failing case with its seed so it can be replayed.
//!
//! ```
//! use conv_svd_lfa::testing::{prop_assert, prop_check, Gen};
//! prop_check("abs is nonnegative", 100, |g: &mut Gen| {
//!     let x = g.f64_in(-100.0, 100.0);
//!     prop_assert(x.abs() >= 0.0, format!("abs({x}) < 0"))
//! });
//! ```

use crate::numeric::Pcg64;

/// Case generator handed to each property invocation.
pub struct Gen {
    pub rng: Pcg64,
    pub case: usize,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi >= lo);
        lo + self.rng.below((hi - lo + 1) as u64) as usize
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform_in(lo, hi)
    }

    pub fn normal(&mut self) -> f64 {
        self.rng.normal()
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        let i = self.rng.below(xs.len() as u64) as usize;
        &xs[i]
    }

    pub fn bool(&mut self) -> bool {
        self.rng.below(2) == 1
    }
}

/// Property outcome: `Ok(())` or a failure message.
pub type PropResult = Result<(), String>;

/// Assert inside a property.
pub fn prop_assert(cond: bool, msg: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Assert two floats are close (relative to scale).
pub fn prop_close(a: f64, b: f64, tol: f64, what: &str) -> PropResult {
    let scale = a.abs().max(b.abs()).max(1.0);
    if (a - b).abs() <= tol * scale {
        Ok(())
    } else {
        Err(format!("{what}: {a} vs {b} (tol {tol}, scale {scale})"))
    }
}

/// Run `cases` random cases of a property. Panics (test failure) on the
/// first failing case, printing the case index and seed for replay.
/// Honors `PROP_SEED` (base seed) and `PROP_CASES` env overrides.
pub fn prop_check<F: FnMut(&mut Gen) -> PropResult>(name: &str, cases: usize, mut prop: F) {
    let base_seed: u64 = std::env::var("PROP_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(
        0x5EED_0000_0000_0000 | name.bytes().fold(0u64, |h, b| h.wrapping_mul(31).wrapping_add(b as u64)) & 0xFFFF_FFFF,
    );
    let cases: usize =
        std::env::var("PROP_CASES").ok().and_then(|s| s.parse().ok()).unwrap_or(cases);
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64);
        let mut g = Gen { rng: Pcg64::seeded(seed), case };
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property '{name}' failed on case {case}/{cases} \
                 (replay with PROP_SEED={base_seed} PROP_CASES={}): {msg}",
                case + 1
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut ran = 0;
        prop_check("trivial", 25, |g| {
            ran += 1;
            prop_assert(g.usize_in(0, 10) <= 10, "range")
        });
        assert_eq!(ran, 25);
    }

    #[test]
    #[should_panic(expected = "property 'failing'")]
    fn failing_property_panics_with_seed() {
        prop_check("failing", 10, |g| {
            let x = g.f64_in(0.0, 1.0);
            prop_assert(x < 0.5, format!("x = {x}"))
        });
    }

    #[test]
    fn close_helper() {
        assert!(prop_close(1.0, 1.0 + 1e-12, 1e-9, "eq").is_ok());
        assert!(prop_close(1.0, 2.0, 1e-9, "neq").is_err());
    }

    #[test]
    fn gen_ranges() {
        let mut g = Gen { rng: Pcg64::seeded(1), case: 0 };
        for _ in 0..100 {
            let v = g.usize_in(3, 7);
            assert!((3..=7).contains(&v));
        }
        assert!([true, false].contains(&g.bool()));
        let xs = [1, 2, 3];
        assert!(xs.contains(g.pick(&xs)));
    }
}
