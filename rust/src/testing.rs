//! Minimal property-testing harness (proptest is not in the offline crate
//! set). Runs a property against many seeded random cases and reports the
//! first failing case with its seed so it can be replayed.
//!
//! ```
//! use conv_svd_lfa::testing::{prop_assert, prop_check, Gen};
//! prop_check("abs is nonnegative", 100, |g: &mut Gen| {
//!     let x = g.f64_in(-100.0, 100.0);
//!     prop_assert(x.abs() >= 0.0, format!("abs({x}) < 0"))
//! });
//! ```

use crate::numeric::Pcg64;

pub mod chaos {
    //! Fault-injection hooks for the coordinator and daemon test suites.
    //!
    //! Production code calls [`fire`] at named injection points; the call
    //! is a single relaxed atomic load unless a test has [`arm`]ed the
    //! point, so the hooks cost nothing in normal operation. An armed
    //! point fires exactly once after a configurable number of passes —
    //! e.g. `arm(TILE_PANIC, 1)` makes the *next* tile execution panic
    //! mid-flight, which is how `tests/service_daemon.rs` proves a worker
    //! panic degrades to a typed job error instead of a hang.
    //!
    //! State is process-global (the scheduler's workers are real threads);
    //! tests that arm points must serialize themselves (a shared mutex)
    //! and [`reset`] when done.

    use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};

    /// Panic in the middle of executing a tile (worker-thread crash).
    pub const TILE_PANIC: usize = 0;
    /// Fail a tile with a typed error (solver-level failure).
    pub const TILE_ERROR: usize = 1;
    /// Fail a disk-cache spill write (full / read-only disk).
    pub const DISK_WRITE_FAIL: usize = 2;
    /// Force an iterative solver (Jacobi sweep / Krylov top-k) to report
    /// sweep exhaustion: the computed values are left intact but the
    /// convergence certificate comes back `converged: false`, exercising
    /// the escalation ladder and the degraded-spectrum plumbing without
    /// needing a genuinely pathological matrix.
    pub const SOLVER_STALL: usize = 3;
    const POINTS: usize = 4;

    /// Countdown value meaning "fire on every pass" ([`arm_always`]).
    const STICKY: u32 = u32::MAX;

    /// Fast path: any point armed at all?
    static ENABLED: AtomicBool = AtomicBool::new(false);
    /// Per-point countdown: 0 = disarmed, `n` = fire on the n-th pass,
    /// [`STICKY`] = fire on every pass until [`reset`].
    static ARMED: [AtomicU32; POINTS] =
        [AtomicU32::new(0), AtomicU32::new(0), AtomicU32::new(0), AtomicU32::new(0)];

    /// Arm `point` to fire on its `nth` upcoming pass (1 = the next one).
    /// `nth = 0` disarms the point.
    pub fn arm(point: usize, nth: u32) {
        ARMED[point].store(nth, Ordering::SeqCst);
        ENABLED.store(true, Ordering::SeqCst);
    }

    /// Arm `point` to fire on **every** pass until [`reset`] — the shape
    /// the escalation-ladder tests need (a stall that also defeats every
    /// retry rung, leaving the frequency genuinely degraded).
    pub fn arm_always(point: usize) {
        arm(point, STICKY);
    }

    /// Disarm every point.
    pub fn reset() {
        for a in &ARMED {
            a.store(0, Ordering::SeqCst);
        }
        ENABLED.store(false, Ordering::SeqCst);
    }

    /// Called by production code at an injection point. Returns whether
    /// the armed fault should trigger here. Free (one relaxed load) when
    /// nothing is armed.
    pub fn fire(point: usize) -> bool {
        if !ENABLED.load(Ordering::Relaxed) {
            return false;
        }
        // Count this pass down; exactly one caller observes the 1 → 0
        // transition and fires (workers race to this on purpose). A
        // sticky arming never counts down and fires for everyone.
        let prev = ARMED[point]
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |v| {
                if v == STICKY {
                    Some(v)
                } else {
                    v.checked_sub(1)
                }
            })
            .unwrap_or(0);
        prev == 1 || prev == STICKY
    }
}

/// Case generator handed to each property invocation.
pub struct Gen {
    pub rng: Pcg64,
    pub case: usize,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi >= lo);
        lo + self.rng.below((hi - lo + 1) as u64) as usize
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform_in(lo, hi)
    }

    pub fn normal(&mut self) -> f64 {
        self.rng.normal()
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        let i = self.rng.below(xs.len() as u64) as usize;
        &xs[i]
    }

    pub fn bool(&mut self) -> bool {
        self.rng.below(2) == 1
    }
}

/// Property outcome: `Ok(())` or a failure message.
pub type PropResult = Result<(), String>;

/// Assert inside a property.
pub fn prop_assert(cond: bool, msg: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Assert two floats are close (relative to scale).
pub fn prop_close(a: f64, b: f64, tol: f64, what: &str) -> PropResult {
    let scale = a.abs().max(b.abs()).max(1.0);
    if (a - b).abs() <= tol * scale {
        Ok(())
    } else {
        Err(format!("{what}: {a} vs {b} (tol {tol}, scale {scale})"))
    }
}

/// Run `cases` random cases of a property. Panics (test failure) on the
/// first failing case, printing the case index and seed for replay.
/// Honors `PROP_SEED` (base seed) and `PROP_CASES` env overrides.
pub fn prop_check<F: FnMut(&mut Gen) -> PropResult>(name: &str, cases: usize, mut prop: F) {
    let base_seed: u64 = std::env::var("PROP_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(
        0x5EED_0000_0000_0000 | name.bytes().fold(0u64, |h, b| h.wrapping_mul(31).wrapping_add(b as u64)) & 0xFFFF_FFFF,
    );
    let cases: usize =
        std::env::var("PROP_CASES").ok().and_then(|s| s.parse().ok()).unwrap_or(cases);
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64);
        let mut g = Gen { rng: Pcg64::seeded(seed), case };
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property '{name}' failed on case {case}/{cases} \
                 (replay with PROP_SEED={base_seed} PROP_CASES={}): {msg}",
                case + 1
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut ran = 0;
        prop_check("trivial", 25, |g| {
            ran += 1;
            prop_assert(g.usize_in(0, 10) <= 10, "range")
        });
        assert_eq!(ran, 25);
    }

    #[test]
    #[should_panic(expected = "property 'failing'")]
    fn failing_property_panics_with_seed() {
        prop_check("failing", 10, |g| {
            let x = g.f64_in(0.0, 1.0);
            prop_assert(x < 0.5, format!("x = {x}"))
        });
    }

    #[test]
    fn close_helper() {
        assert!(prop_close(1.0, 1.0 + 1e-12, 1e-9, "eq").is_ok());
        assert!(prop_close(1.0, 2.0, 1e-9, "neq").is_err());
    }

    #[test]
    fn gen_ranges() {
        let mut g = Gen { rng: Pcg64::seeded(1), case: 0 };
        for _ in 0..100 {
            let v = g.usize_in(3, 7);
            assert!((3..=7).contains(&v));
        }
        assert!([true, false].contains(&g.bool()));
        let xs = [1, 2, 3];
        assert!(xs.contains(g.pick(&xs)));
    }
}
