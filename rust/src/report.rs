//! Plain-text table + CSV reporting shared by the CLI, examples and
//! benches (every paper table/figure regenerator prints through this) —
//! plus the report *lines* shared by the CLI audit commands and the
//! daemon's `STATS` reply (cache traffic, fold accounting, health gates,
//! density tables), so both front ends describe the same run the same way.

use crate::conv::ConvKernel;
use crate::engine::{CacheStats, LayerDensity, ModelSpectra};
use crate::error::{Error, Result};
use std::fmt::Write as _;

/// A simple aligned-column table.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>, I: IntoIterator<Item = S>>(headers: I) -> Self {
        Self { headers: headers.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    pub fn row<S: Into<String>, I: IntoIterator<Item = S>>(&mut self, cells: I) {
        let row: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(row.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(row);
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let cols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..cols {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let mut out = String::new();
        let fmt_row = |out: &mut String, cells: &[String]| {
            for (c, cell) in cells.iter().enumerate() {
                let _ = write!(out, "{:>width$}  ", cell, width = widths[c]);
            }
            out.push('\n');
        };
        fmt_row(&mut out, &self.headers);
        let total: usize = widths.iter().sum::<usize>() + 2 * cols;
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            fmt_row(&mut out, row);
        }
        out
    }

    /// Render as CSV (for plotting outside).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let esc = |s: &String| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.clone()
            }
        };
        out.push_str(&self.headers.iter().map(esc).collect::<Vec<_>>().join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(esc).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    /// Write the CSV next to the repo's `target/reports/` directory.
    pub fn save_csv(&self, name: &str) -> std::io::Result<std::path::PathBuf> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("target/reports");
        std::fs::create_dir_all(&dir)?;
        let path = dir.join(format!("{name}.csv"));
        std::fs::write(&path, self.to_csv())?;
        Ok(path)
    }
}

/// Format a duration in seconds with adaptive precision (paper tables use
/// seconds).
pub fn secs(d: std::time::Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 100.0 {
        format!("{s:.1}")
    } else if s >= 1.0 {
        format!("{s:.2}")
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.1}µs", s * 1e6)
    }
}

/// The truthful `frequencies solved: S/T …` report line shared by the
/// audit commands: `S` sums what each layer *actually* decomposed —
/// folded native layers their fundamental domain, PJRT-routed/unfolded
/// layers the full grid, cache-served layers nothing — so mixed runs
/// report a correct ratio instead of assuming every layer folded. The
/// label is derived from per-layer *outcomes*, not configuration flags:
/// `folded_layers` counts layers that actually solved a folded domain,
/// `cached_layers` counts layers served from the result cache, and the
/// saving is attributed to whichever contributed ("fold", "cache", or
/// "fold + cache"). `S == T` means nothing was reduced — every solved
/// layer swept its full grid (fold disabled or PJRT-routed).
pub fn freqs_solved_line(
    solved: usize,
    total: usize,
    cached_layers: usize,
    folded: usize,
) -> String {
    if solved == 0 && total > 0 {
        format!("frequencies solved: 0/{total} (all served from cache)")
    } else if solved == total {
        // The outcome, not the flag: every solved layer swept its full
        // grid — because folding was off, or because PJRT routing (which
        // always sweeps the full grid) made it inapplicable.
        format!("frequencies solved: {total}/{total} (full grid)")
    } else {
        let label = match (folded > 0, cached_layers > 0) {
            (true, true) => "fold + cache",
            (false, true) => "cache",
            _ => "fold",
        };
        format!(
            "frequencies solved: {solved}/{total} ({label} {:.2}x)",
            total as f64 / solved.max(1) as f64
        )
    }
}

/// The `c` column of the audit-model tables: operator channel dims —
/// total input width (grouped kernels store the per-group width), the
/// adjoint's swapped shape for transposed layers — plus a structure tag:
/// `g4` grouped, `d2` dilated, `T` transposed.
pub fn channels_desc(k: &ConvKernel) -> String {
    let (ci, co) =
        if k.transposed { (k.c_out, k.c_in_total()) } else { (k.c_in_total(), k.c_out) };
    let mut s = format!("{ci}→{co}");
    if k.groups > 1 {
        s.push_str(&format!(" g{}", k.groups));
    }
    if k.dilation > 1 {
        s.push_str(&format!(" d{}", k.dilation));
    }
    if k.transposed {
        s.push('ᵀ');
    }
    s
}

/// The `cache: H hits / M misses / E evictions` report line.
pub fn cache_line(stats: Option<CacheStats>) -> String {
    match stats {
        Some(s) => format!(
            "cache: {} hits / {} misses / {} evictions ({} entries, {}/{} bytes)",
            s.hits, s.misses, s.evictions, s.entries, s.bytes, s.capacity
        ),
        None => "cache: off".into(),
    }
}

/// The `disk: …` report line, printed when the disk tier is active.
pub fn disk_line(stats: Option<CacheStats>) -> Option<String> {
    let s = stats?;
    Some(format!(
        "disk: {} hits / {} misses / {} spills / {} corruptions",
        s.disk_hits, s.disk_misses, s.disk_spills, s.disk_corruptions
    ))
}

/// The cache counters as a `key=value` list — the daemon's `STATS` reply
/// body and the machine-readable twin of [`cache_line`] + [`disk_line`].
/// `densities` counts the streamed histogram entries the cache holds next
/// to full spectra.
pub fn stats_kv(stats: Option<CacheStats>) -> String {
    match stats {
        Some(s) => format!(
            "hits={} misses={} evictions={} entries={} densities={} bytes={} \
             disk_hits={} disk_misses={} disk_spills={} disk_corruptions={}",
            s.hits,
            s.misses,
            s.evictions,
            s.entries,
            s.density_entries,
            s.bytes,
            s.disk_hits,
            s.disk_misses,
            s.disk_spills,
            s.disk_corruptions
        ),
        None => "cache=off".to_string(),
    }
}

/// The `health:` report line + strict-health gate shared by the
/// audit-model sweeps, which run off the [`crate::engine::ModelPlan`]
/// directly (no coordinator service, so the aggregate comes from the
/// merged per-layer certificates instead of the metrics snapshot).
/// Degraded spectra are served flagged — and were refused by the result
/// cache — unless `strict` turns them into the typed error.
pub fn model_health_report(spectra: &ModelSpectra, strict: bool) -> Result<()> {
    let h = spectra.health();
    println!(
        "health: {} certified / {} retried / {} escalations / {} degraded freqs",
        h.converged_freqs, h.retried_freqs, h.escalations, h.degraded_freqs
    );
    if spectra.is_degraded() {
        let names = spectra.degraded_layers().join(", ");
        if strict {
            return Err(Error::degraded_spectrum(names, h.degraded_freqs as usize));
        }
        println!(
            "warning: degraded spectra served flagged, never cached: {names} \
             (re-run with --strict-health to fail instead)"
        );
    }
    Ok(())
}

/// The per-layer table of a density audit: exact extremes from the top-1
/// pass (`σ_max`), sampled statistics from the histogram (`σ_min*` and
/// the quantiles carry the `*` because they come from the sampled bulk),
/// and the coverage column that makes the accuracy contract visible —
/// solved/total frequencies plus the 95% DKW half-width `±ε` on every
/// CDF read.
pub fn density_table(layers: &[LayerDensity]) -> Table {
    let mut t = Table::new([
        "layer", "grid", "bins", "σ_max", "σ_min*", "q50*", "q90*", "q99*", "coverage", "±ε",
        "source",
    ]);
    for l in layers {
        let d = &l.density;
        t.row([
            l.name.clone(),
            format!("{}x{}", d.n, d.m),
            d.bins.len().to_string(),
            format!("{:.4}", d.sigma_max),
            format!("{:.4}", d.sigma_min_sampled),
            format!("{:.4}", d.quantile(0.50)),
            format!("{:.4}", d.quantile(0.90)),
            format!("{:.4}", d.quantile(0.99)),
            format!("{}/{} ({:.0}%)", d.covered_freqs, d.total_freqs, 100.0 * d.sampled_fraction()),
            if d.cdf_epsilon() == 0.0 {
                "exact".to_string()
            } else {
                format!("{:.3}", d.cdf_epsilon())
            },
            if l.cached { "cache".into() } else { format!("sample={}", d.sample) },
        ]);
    }
    t
}

/// Human-readable large counts (`4,294,967,296`).
pub fn commas(n: u128) -> String {
    let s = n.to_string();
    let mut out = String::new();
    for (i, ch) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(ch);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(["a", "long-header"]);
        t.row(["1", "2"]);
        t.row(["100", "x"]);
        let s = t.render();
        assert!(s.contains("long-header"));
        assert_eq!(s.lines().count(), 4);
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new(["x"]);
        t.row(["a,b"]);
        assert!(t.to_csv().contains("\"a,b\""));
    }

    #[test]
    fn duration_formats() {
        assert_eq!(secs(Duration::from_secs(200)), "200.0");
        assert_eq!(secs(Duration::from_millis(2500)), "2.50");
        assert!(secs(Duration::from_micros(1500)).ends_with("ms"));
        assert!(secs(Duration::from_nanos(900)).ends_with("µs"));
    }

    #[test]
    fn comma_grouping() {
        assert_eq!(commas(4294967296), "4,294,967,296");
        assert_eq!(commas(12), "12");
        assert_eq!(commas(1234), "1,234");
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new(["a", "b"]);
        t.row(["only-one"]);
    }

    #[test]
    fn stats_kv_covers_every_tier() {
        assert_eq!(stats_kv(None), "cache=off");
        let s = CacheStats { hits: 3, misses: 1, density_entries: 2, ..Default::default() };
        let kv = stats_kv(Some(s));
        assert!(kv.starts_with("hits=3 misses=1 "), "unexpected: {kv}");
        assert!(kv.contains("densities=2"), "density tier must be reported: {kv}");
        assert!(kv.contains("disk_corruptions=0"), "disk tier must be reported: {kv}");
    }

    #[test]
    fn freqs_solved_attributes_the_saving() {
        assert!(freqs_solved_line(0, 10, 2, 0).contains("all served from cache"));
        assert!(freqs_solved_line(10, 10, 0, 0).contains("full grid"));
        assert!(freqs_solved_line(5, 10, 0, 1).contains("fold 2.00x"));
        assert!(freqs_solved_line(5, 10, 1, 1).contains("fold + cache"));
        assert!(freqs_solved_line(5, 10, 1, 0).contains("(cache 2.00x"));
    }

    #[test]
    fn density_table_shows_the_accuracy_contract() {
        use crate::engine::{DensityRequest, LayerDensity, SpectralPlan};
        let mut rng = crate::numeric::Pcg64::seeded(5);
        let k = crate::conv::ConvKernel::random_he(2, 2, 3, 3, &mut rng);
        let plan = SpectralPlan::new(&k, 8, 8, crate::lfa::LfaOptions::default());
        let d = plan.density(DensityRequest { bins: 16, sample: 2 });
        let layers = vec![LayerDensity {
            name: "c1".into(),
            density: std::sync::Arc::new(d),
            cached: false,
        }];
        let s = density_table(&layers).render();
        assert!(s.contains("c1"), "layer name missing: {s}");
        assert!(s.contains("sample=2"), "sampling stride missing: {s}");
        assert!(s.contains("coverage"), "coverage column missing: {s}");
    }
}
