//! Plain-text table + CSV reporting shared by the CLI, examples and
//! benches (every paper table/figure regenerator prints through this).

use std::fmt::Write as _;

/// A simple aligned-column table.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>, I: IntoIterator<Item = S>>(headers: I) -> Self {
        Self { headers: headers.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    pub fn row<S: Into<String>, I: IntoIterator<Item = S>>(&mut self, cells: I) {
        let row: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(row.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(row);
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let cols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..cols {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let mut out = String::new();
        let fmt_row = |out: &mut String, cells: &[String]| {
            for (c, cell) in cells.iter().enumerate() {
                let _ = write!(out, "{:>width$}  ", cell, width = widths[c]);
            }
            out.push('\n');
        };
        fmt_row(&mut out, &self.headers);
        let total: usize = widths.iter().sum::<usize>() + 2 * cols;
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            fmt_row(&mut out, row);
        }
        out
    }

    /// Render as CSV (for plotting outside).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let esc = |s: &String| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.clone()
            }
        };
        out.push_str(&self.headers.iter().map(esc).collect::<Vec<_>>().join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(esc).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    /// Write the CSV next to the repo's `target/reports/` directory.
    pub fn save_csv(&self, name: &str) -> std::io::Result<std::path::PathBuf> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("target/reports");
        std::fs::create_dir_all(&dir)?;
        let path = dir.join(format!("{name}.csv"));
        std::fs::write(&path, self.to_csv())?;
        Ok(path)
    }
}

/// Format a duration in seconds with adaptive precision (paper tables use
/// seconds).
pub fn secs(d: std::time::Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 100.0 {
        format!("{s:.1}")
    } else if s >= 1.0 {
        format!("{s:.2}")
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.1}µs", s * 1e6)
    }
}

/// Human-readable large counts (`4,294,967,296`).
pub fn commas(n: u128) -> String {
    let s = n.to_string();
    let mut out = String::new();
    for (i, ch) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(ch);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(["a", "long-header"]);
        t.row(["1", "2"]);
        t.row(["100", "x"]);
        let s = t.render();
        assert!(s.contains("long-header"));
        assert_eq!(s.lines().count(), 4);
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new(["x"]);
        t.row(["a,b"]);
        assert!(t.to_csv().contains("\"a,b\""));
    }

    #[test]
    fn duration_formats() {
        assert_eq!(secs(Duration::from_secs(200)), "200.0");
        assert_eq!(secs(Duration::from_millis(2500)), "2.50");
        assert!(secs(Duration::from_micros(1500)).ends_with("ms"));
        assert!(secs(Duration::from_nanos(900)).ends_with("µs"));
    }

    #[test]
    fn comma_grouping() {
        assert_eq!(commas(4294967296), "4,294,967,296");
        assert_eq!(commas(12), "12");
        assert_eq!(commas(1234), "1,234");
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new(["a", "b"]);
        t.row(["only-one"]);
    }
}
