//! `conv-svd-lfa` — CLI for the LFA convolutional-SVD system.
//!
//! Subcommands:
//!   analyze      spectrum of one random conv layer (LFA, FFT or explicit)
//!   audit        analyze every layer of a model through the coordinator
//!   audit-model  whole-model spectral report straight off a ModelPlan
//!   compare      LFA vs FFT vs explicit on one layer, with timings
//!   serve        run lfa-convd, the long-running spectral-audit daemon
//!   artifacts    list AOT artifacts and smoke-run one through PJRT
//!   help         this text (see `cli::HELP`)

use conv_svd_lfa::baselines::{explicit_svd, fft_svd, FftLayoutPolicy};
use conv_svd_lfa::cli::{Cli, HELP};
use conv_svd_lfa::conv::{Boundary, ConvKernel};
use conv_svd_lfa::coordinator::{Backend, ServiceConfig, SpectralService};
use conv_svd_lfa::engine::{DensityRequest, ModelPlan, SpectralCache, SpectrumRequest};
use conv_svd_lfa::error::Result;
use conv_svd_lfa::lfa::{self, BlockSolver, Fold, LfaOptions, Precision};
use conv_svd_lfa::model::zoo;
use conv_svd_lfa::model::ModelConfig;
use conv_svd_lfa::numeric::Pcg64;
use conv_svd_lfa::report::{
    cache_line, channels_desc, commas, density_table, disk_line, freqs_solved_line,
    model_health_report, secs, Table,
};
use conv_svd_lfa::runtime::load_manifest;
#[cfg(feature = "pjrt")]
use conv_svd_lfa::runtime::PjrtEngine;
use conv_svd_lfa::{bail, err};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let cli = Cli::from_env(&[
        "with-explicit",
        "verbose",
        "csv",
        "no-fold",
        "no-cache",
        "transposed",
        "allow-remote",
        "strict-health",
    ])?;
    match cli.command.as_str() {
        "analyze" => cmd_analyze(&cli),
        "audit" => cmd_audit(&cli),
        "audit-model" => cmd_audit_model(&cli),
        "compare" => cmd_compare(&cli),
        #[cfg(feature = "daemon")]
        "serve" => cmd_serve(&cli),
        #[cfg(not(feature = "daemon"))]
        "serve" => bail!("this binary was built without the `daemon` feature"),
        "artifacts" => cmd_artifacts(&cli),
        "" | "help" | "--help" | "-h" => {
            print!("{HELP}");
            Ok(())
        }
        other => bail!("unknown command {other:?}; try `conv-svd-lfa help`"),
    }
}

fn cmd_analyze(cli: &Cli) -> Result<()> {
    let n: usize = cli.opt_parse("n", 32)?;
    let m: usize = cli.opt_parse("m", n)?;
    let c_in: usize = cli.opt_parse("c-in", cli.opt_parse("c", 16)?)?;
    let c_out: usize = cli.opt_parse("c-out", cli.opt_parse("c", 16)?)?;
    let k: usize = cli.opt_parse("k", 3)?;
    let threads: usize = cli.opt_parse("threads", 0)?;
    let seed: u64 = cli.opt_parse("seed", 2025)?;
    let top: usize = cli.opt_parse("top", 8)?;
    let method = cli.opt("method").unwrap_or("lfa");
    let precision = precision_opt(cli)?;
    if precision != Precision::F64 && method != "lfa" {
        bail!("--precision applies to the LFA engine only (method {method:?} is f64)");
    }
    let groups: usize = cli.opt_parse("groups", 1)?;
    let dilation: usize = cli.opt_parse("dilation", 1)?;
    let transposed = cli.flag("transposed");
    if groups == 0 || c_in % groups != 0 || c_out % groups != 0 {
        bail!("--groups {groups} must be nonzero and divide --c-in {c_in} and --c-out {c_out}");
    }
    if dilation == 0 {
        bail!("--dilation must be >= 1");
    }

    let mut rng = Pcg64::seeded(seed);
    // The kernel stores the per-group input width (c_in / groups);
    // c_in stays the activation tensor's total channel count.
    let kernel = ConvKernel::random_he(c_out, c_in / groups, k, k, &mut rng)
        .with_groups(groups)
        .with_dilation(dilation)
        .with_transposed(transposed);
    if !kernel.is_dense() && method != "lfa" {
        bail!(
            "structured kernels (--groups/--dilation/--transposed) run on the \
             LFA engine only (method {method:?} is a dense baseline)"
        );
    }
    let t0 = std::time::Instant::now();
    let spectrum = match method {
        "lfa" => lfa::singular_values(
            &kernel,
            n,
            m,
            LfaOptions { threads, precision, ..Default::default() },
        ),
        "fft" => fft_svd::singular_values(&kernel, n, m, FftLayoutPolicy::Natural, threads),
        "explicit" => explicit_svd::singular_values(&kernel, n, m, Boundary::Periodic),
        other => bail!("unknown method {other:?} (lfa|fft|explicit)"),
    };
    let dt = t0.elapsed();
    let sorted = spectrum.sorted_desc();
    let structure = {
        let mut tags = Vec::new();
        if groups > 1 {
            tags.push(format!("groups={groups}"));
        }
        if dilation > 1 {
            tags.push(format!("dilation={dilation}"));
        }
        if transposed {
            tags.push("transposed".to_string());
        }
        if tags.is_empty() { String::new() } else { format!(" [{}]", tags.join(", ")) }
    };
    println!(
        "layer {c_out}x{c_in}x{k}x{k}{structure} on {n}x{m} grid — {} singular values via {method} in {}",
        commas(sorted.len() as u128),
        secs(dt)
    );
    println!("  σ_max = {:.6}", spectrum.sigma_max());
    println!("  σ_min = {:.6}", spectrum.sigma_min());
    println!("  cond  = {:.3}", spectrum.condition_number());
    let shown: Vec<String> = sorted.iter().take(top).map(|v| format!("{v:.4}")).collect();
    println!("  top {top}: [{}]", shown.join(", "));
    Ok(())
}

fn load_model(name_or_path: &str) -> Result<ModelConfig> {
    if let Some(m) = zoo::builtin(name_or_path) {
        return Ok(m);
    }
    let path = std::path::Path::new(name_or_path);
    if path.exists() {
        return ModelConfig::load(path);
    }
    Err(err!(
        "no builtin model {name_or_path:?} (have {:?}) and no such file",
        zoo::builtin_names()
    ))
}

/// The `--precision {f64,f32,f32-refined}` option shared by the analyze
/// and audit commands (default f64).
fn precision_opt(cli: &Cli) -> Result<Precision> {
    match cli.opt("precision") {
        None => Ok(Precision::F64),
        Some(s) => Precision::parse(s)
            .ok_or_else(|| err!("unknown precision {s:?} (f64|f32|f32-refined)")),
    }
}

/// The `--cache-bytes N` / `--no-cache` pair shared by both audit
/// commands: `None` = caching disabled, `Some(0)` = the default budget.
fn cache_budget(cli: &Cli) -> Result<Option<usize>> {
    if cli.flag("no-cache") {
        if cli.opt("cache-bytes").is_some() {
            bail!("--no-cache conflicts with --cache-bytes");
        }
        return Ok(None);
    }
    Ok(Some(cli.opt_parse("cache-bytes", 0usize)?))
}

/// The `--disk-cache-dir DIR` option shared by the audit commands and the
/// daemon: the persistent spill tier below the in-memory result cache.
fn disk_cache_dir(cli: &Cli) -> Option<std::path::PathBuf> {
    cli.opt("disk-cache-dir").map(std::path::PathBuf::from)
}

fn cmd_audit(cli: &Cli) -> Result<()> {
    let target = cli
        .positional
        .first()
        .ok_or_else(|| err!("audit needs a builtin name or config path"))?;
    let mut model = load_model(target)?;
    // Structure overrides: audit a grouped / dilated / transposed variant
    // of any builtin or config. Applied to every layer (0 = keep the
    // layer's own setting), so channel counts must stay divisible.
    let groups: usize = cli.opt_parse("groups", 0)?;
    let dilation: usize = cli.opt_parse("dilation", 0)?;
    let transposed = cli.flag("transposed");
    for l in &mut model.layers {
        if groups > 0 {
            if l.c_in % groups != 0 || l.c_out % groups != 0 {
                bail!(
                    "--groups {groups} does not divide layer {:?} ({}->{} channels)",
                    l.name,
                    l.c_in,
                    l.c_out
                );
            }
            l.groups = groups;
        }
        if dilation > 0 {
            l.dilation = dilation;
        }
        if transposed {
            l.transposed = true;
        }
    }
    let threads: usize = cli.opt_parse("threads", 0)?;
    let top_k: usize = cli.opt_parse("top-k", 0)?;
    // The streaming-density mode: `--density B` histograms the whole
    // singular-value population into B bins instead of materializing it;
    // `--density-sample S` additionally solves only every S-th dual-grid
    // row/column (~1/S² of the SVD work, with DKW error bars).
    let density_bins: u32 = cli.opt_parse("density", 0u32)?;
    let density_sample: u32 = cli.opt_parse("density-sample", 1u32)?;
    if density_sample != 1 && density_bins == 0 {
        bail!("--density-sample requires --density B");
    }
    if density_bins > 0 && top_k > 0 {
        bail!(
            "--density conflicts with --top-k: the density sweep runs its \
             own exact top-1 extremes pass"
        );
    }
    let folding = if cli.flag("no-fold") { Fold::Off } else { Fold::Auto };
    let request =
        if top_k > 0 { SpectrumRequest::TopK(top_k) } else { SpectrumRequest::Full };
    let backend = match cli.opt("backend").unwrap_or("auto") {
        "auto" => Backend::Auto,
        "native" => Backend::Native,
        "pjrt" => Backend::Pjrt,
        other => bail!("unknown backend {other:?}"),
    };
    let artifacts_dir = match cli.opt("artifacts") {
        Some(d) => Some(std::path::PathBuf::from(d)),
        None if backend != Backend::Native => Some(SpectralService::default_artifacts_dir()),
        None => None,
    };
    let svc = SpectralService::start(ServiceConfig {
        workers: threads,
        backend,
        artifacts_dir,
        folding,
        precision: precision_opt(cli)?,
        cache_bytes: cache_budget(cli)?,
        disk_cache_dir: disk_cache_dir(cli),
        strict_health: cli.flag("strict-health"),
        ..Default::default()
    })?;
    if density_bins > 0 {
        if backend == Backend::Pjrt {
            svc.shutdown();
            bail!(
                "--density runs on the native engine (AOT artifacts bake \
                 in the full SVD); drop --backend pjrt"
            );
        }
        let result = audit_density(
            cli,
            &svc,
            &model,
            DensityRequest { bins: density_bins, sample: density_sample },
        );
        svc.shutdown();
        return result;
    }
    let reports = svc.audit_model_with(&model, request)?;
    if top_k > 0 {
        println!(
            "partial-spectrum audit: top-{top_k} values per frequency \
             (σ_min and cond report NaN — the retained extremes say \
             nothing about the small end; Frobenius verification needs \
             the full spectrum)"
        );
    }
    let mut table = Table::new([
        "layer", "grid", "c_out", "c_in", "#σ", "σ_max", "σ_min", "cond", "fro-defect", "time",
        "backend",
    ]);
    for r in &reports {
        table.row([
            r.name.clone(),
            format!("{}x{}", r.n, r.m),
            r.c_out.to_string(),
            r.c_in.to_string(),
            commas(r.num_values as u128),
            format!("{:.4}", r.sigma_max),
            format!("{:.4}", r.sigma_min),
            format!("{:.2}", r.condition),
            format!("{:.1e}", r.frobenius_defect),
            secs(r.elapsed),
            if r.pjrt_tiles > 0 { format!("pjrt x{}", r.pjrt_tiles) } else { "native".into() },
        ]);
    }
    println!(
        "model {} ({} layers, {} singular values total)",
        model.name,
        model.layers.len(),
        commas(model.total_values() as u128)
    );
    print!("{}", table.render());
    let m = svc.metrics();
    println!(
        "metrics: {} jobs, {} tiles ({} pjrt / {} native), {} values, Σ tile work {}",
        m.jobs_completed,
        m.tiles_completed,
        m.pjrt_tiles,
        m.native_tiles,
        commas(m.values_computed as u128),
        secs(m.tile_work)
    );
    // The numerical-health line: escalation-ladder traffic plus anything
    // still degraded (strict mode never reaches this point with either).
    println!(
        "health: {} degraded freqs / {} escalations / {} nonfinite rejections",
        m.degraded_freqs, m.escalations, m.nonfinite_rejections
    );
    let degraded: Vec<&str> =
        reports.iter().filter(|r| r.health.is_degraded()).map(|r| r.name.as_str()).collect();
    if !degraded.is_empty() {
        println!(
            "warning: degraded spectra served flagged, never cached: {} \
             (re-run with --strict-health to fail instead)",
            degraded.join(", ")
        );
    }
    // Fold/cache accounting from what actually ran, per layer: each
    // report's solved_freqs is what that layer's tiles decomposed — the
    // folded fundamental domain natively, the full grid on PJRT, nothing
    // when the result cache served it.
    let total_freqs: usize = model
        .layers
        .iter()
        .map(|l| (l.height / l.stride) * (l.width / l.stride))
        .sum();
    let solved_freqs: usize = reports.iter().map(|r| r.solved_freqs).sum();
    let cached_layers = reports.iter().filter(|r| r.cached).count();
    // A layer "folded" iff it executed and decomposed fewer frequencies
    // than its grid holds — PJRT-routed layers never do, whatever the
    // folding flag says.
    let folded_layers = reports
        .iter()
        .zip(&model.layers)
        .filter(|(r, l)| {
            let freqs = (l.height / l.stride) * (l.width / l.stride);
            r.solved_freqs > 0 && r.solved_freqs < freqs
        })
        .count();
    println!("{}", freqs_solved_line(solved_freqs, total_freqs, cached_layers, folded_layers));
    println!("{}", cache_line(svc.cache_stats()));
    if disk_cache_dir(cli).is_some() {
        if let Some(line) = disk_line(svc.cache_stats()) {
            println!("{line}");
        }
    }
    if cli.flag("csv") {
        let path = table.save_csv(&format!("audit_{}", model.name))?;
        println!("csv: {}", path.display());
    }
    svc.shutdown();
    Ok(())
}

/// The `audit --density B` report: per-layer streaming singular-value
/// histograms off the service's density sweep — exact extremes from the
/// warm top-1 pass, sampled bulk with 95% DKW error bars, results keyed
/// and cached like spectra.
fn audit_density(
    cli: &Cli,
    svc: &SpectralService,
    model: &ModelConfig,
    req: DensityRequest,
) -> Result<()> {
    let audit = svc.audit_model_density(model, req)?;
    println!(
        "model {} — singular-value density audit: {} bins, sample {} \
         ({} layer(s), sweep {})",
        model.name,
        req.bins,
        req.sample.max(1),
        audit.layers.len(),
        secs(audit.elapsed)
    );
    let table = density_table(&audit.layers);
    print!("{}", table.render());
    let covered: u64 = audit.layers.iter().map(|l| l.density.covered_freqs).sum();
    let total: u64 = audit.layers.iter().map(|l| l.density.total_freqs).sum();
    // A cache-served layer keeps its original solved count inside the
    // stored density; only layers that actually swept solved anything now.
    let solved: u64 =
        audit.layers.iter().filter(|l| !l.cached).map(|l| l.density.solved_freqs).sum();
    let cached = audit.layers.iter().filter(|l| l.cached).count();
    println!(
        "coverage: {covered}/{total} frequencies binned — {solved} solved \
         this run, {cached} layer(s) served from cache"
    );
    let degraded: Vec<&str> = audit
        .layers
        .iter()
        .filter(|l| l.density.is_degraded())
        .map(|l| l.name.as_str())
        .collect();
    if !degraded.is_empty() {
        println!(
            "warning: degraded densities served flagged, never cached: {} \
             (re-run with --strict-health to fail instead)",
            degraded.join(", ")
        );
    }
    println!("{}", cache_line(svc.cache_stats()));
    if disk_cache_dir(cli).is_some() {
        if let Some(line) = disk_line(svc.cache_stats()) {
            println!("{line}");
        }
    }
    if cli.flag("csv") {
        let path = table.save_csv(&format!("audit_density_{}", model.name))?;
        println!("csv: {}", path.display());
    }
    Ok(())
}

/// Whole-model spectral report straight off a [`ModelPlan`] — every layer
/// planned once, equal-shape layers batched into shared workspace groups,
/// one batched sweep, per-layer + aggregate report.
fn cmd_audit_model(cli: &Cli) -> Result<()> {
    let target = cli
        .positional
        .first()
        .ok_or_else(|| err!("audit-model needs a builtin name or config path"))?;
    let model = load_model(target)?;
    let threads: usize = cli.opt_parse("threads", 0)?;
    let top: usize = cli.opt_parse("top", 4)?;
    let top_k: usize = cli.opt_parse("top-k", 0)?;
    let repeat: usize = cli.opt_parse("repeat", 1)?;
    if repeat == 0 {
        bail!("--repeat must be at least 1");
    }
    let folding = if cli.flag("no-fold") { Fold::Off } else { Fold::Auto };
    let solver = match cli.opt("solver").unwrap_or("jacobi") {
        "jacobi" => BlockSolver::Jacobi,
        "gram" => BlockSolver::GramEigen,
        other => bail!("unknown solver {other:?} (jacobi|gram)"),
    };
    // The result/plan cache the repeat sweeps run against (the
    // repeat-audit shape: sweep 1 populates it, sweeps 2..R hit it), with
    // the persistent disk tier below it when --disk-cache-dir is given.
    let cache = match (cache_budget(cli)?, disk_cache_dir(cli)) {
        (None, Some(_)) => bail!(
            "--disk-cache-dir requires caching: the disk tier sits below \
             the in-memory result cache (drop --no-cache)"
        ),
        (None, None) => None,
        (Some(budget), dir) => {
            let mut c = SpectralCache::with_budget_or_default(budget);
            if let Some(dir) = dir {
                c = c.with_disk(conv_svd_lfa::engine::DiskCache::open(dir)?);
            }
            Some(c)
        }
    };
    let t0 = std::time::Instant::now();
    // Build through the cache when one exists: the build stores each
    // layer's plan signature, so every repeat sweep derives its result
    // keys instead of re-hashing the weight tensors per sweep.
    let opts = LfaOptions {
        threads,
        solver,
        folding,
        precision: precision_opt(cli)?,
        ..Default::default()
    };
    let plan = match &cache {
        Some(c) => ModelPlan::build_cached(&model, opts, c)?,
        None => ModelPlan::build(&model, opts)?,
    };
    let t_plan = t0.elapsed();
    let total_freqs: usize = (0..plan.layer_count()).map(|i| plan.layer_plan(i).freqs()).sum();
    if top_k > 0 {
        return audit_model_topk(cli, &plan, top_k, t_plan, cache.as_ref(), repeat, total_freqs);
    }
    let t1 = std::time::Instant::now();
    let (spectra, solved_freqs, cached_layers) = match &cache {
        Some(c) => {
            let mut exec = plan.execute_cached(c);
            for _ in 1..repeat {
                exec = plan.execute_cached(c);
            }
            (exec.spectra, exec.freqs_solved, exec.cache_hits)
        }
        None => {
            let mut spectra = plan.execute();
            for _ in 1..repeat {
                spectra = plan.execute();
            }
            let solved: usize =
                (0..plan.layer_count()).map(|i| plan.layer_plan(i).solved_freqs()).sum();
            (spectra, solved, 0)
        }
    };
    let t_exec = t1.elapsed();

    let mut table = Table::new([
        "layer", "grid", "stride", "c", "#σ", "σ_max", "σ_min", "cond", "fro-defect", "top σ",
    ]);
    for (i, layer) in spectra.layers.iter().enumerate() {
        let lp = plan.layer_plan(i);
        let k = lp.kernel();
        let s = &layer.spectrum;
        let defect = lfa::svd::frobenius_check_strided(
            k,
            lp.fine_rows(),
            lp.fine_cols(),
            lp.stride(),
            s,
        );
        let shown: Vec<String> =
            s.sorted_desc().iter().take(top).map(|v| format!("{v:.3}")).collect();
        table.row([
            layer.name.clone(),
            format!("{}x{}", lp.fine_rows(), lp.fine_cols()),
            lp.stride().to_string(),
            channels_desc(k),
            commas(s.num_values() as u128),
            format!("{:.4}", s.sigma_max()),
            format!("{:.4}", s.sigma_min()),
            format!("{:.2}", s.condition_number()),
            format!("{defect:.1e}"),
            shown.join(" "),
        ]);
    }
    println!(
        "model {} — {} layers planned once into {} equal-shape group(s), \
         plan {} + sweep {} ({} run(s), {} worker(s))",
        spectra.model,
        plan.layer_count(),
        plan.group_count(),
        secs(t_plan),
        secs(t_exec),
        repeat,
        plan.effective_threads()
    );
    print!("{}", table.render());
    println!(
        "aggregate: {} singular values, global σ_max {:.4}, global σ_min {:.4}, \
         Lipschitz composition bound {:.4}",
        commas(spectra.num_values() as u128),
        spectra.sigma_max(),
        spectra.sigma_min(),
        spectra.lipschitz_upper_bound()
    );
    model_health_report(&spectra, cli.flag("strict-health"))?;
    // The last sweep's accounting: all-hit repeats solve 0 frequencies.
    // ModelPlan sweeps are all-native: every executed layer folds unless
    // folding is off.
    let folded_layers =
        if folding == Fold::Off { 0 } else { plan.layer_count() - cached_layers };
    println!("{}", freqs_solved_line(solved_freqs, total_freqs, cached_layers, folded_layers));
    println!("{}", cache_line(cache.as_ref().map(|c| c.stats())));
    if disk_cache_dir(cli).is_some() {
        if let Some(line) = disk_line(cache.as_ref().map(|c| c.stats())) {
            println!("{line}");
        }
    }
    for g in 0..plan.group_count() {
        let members = plan.group_members(g);
        let (rows, cols) = plan.layer_plan(members[0]).block_shape();
        println!(
            "  group {g}: {} layer(s) with {rows}x{cols} blocks share one workspace pool",
            members.len()
        );
    }
    if cli.flag("csv") {
        let path = table.save_csv(&format!("audit_model_{}", spectra.model))?;
        println!("csv: {}", path.display());
    }
    Ok(())
}

/// The `audit-model --top-k K` report: the partial-spectrum sweep off the
/// same planned object, with the iteration counts that show what the
/// cross-frequency warm starts saved. With a cache, partial spectra are
/// content-addressed under their `TopK(k)` signature like full ones.
fn audit_model_topk(
    cli: &Cli,
    plan: &ModelPlan,
    k: usize,
    t_plan: std::time::Duration,
    cache: Option<&SpectralCache>,
    repeat: usize,
    total_freqs: usize,
) -> Result<()> {
    let t1 = std::time::Instant::now();
    let (spectra, iterations, solved_freqs, cached_layers) = match cache {
        Some(c) => {
            let mut exec = plan.top_k_all_cached(k, c);
            for _ in 1..repeat {
                exec = plan.top_k_all_cached(k, c);
            }
            (exec.spectra, exec.iterations, exec.freqs_solved, exec.cache_hits)
        }
        None => {
            let mut warm = plan.top_k_all(k);
            for _ in 1..repeat {
                warm = plan.top_k_all(k);
            }
            let solved: usize =
                (0..plan.layer_count()).map(|i| plan.layer_plan(i).solved_freqs()).sum();
            (warm.spectra, warm.iterations, solved, 0)
        }
    };
    let t_exec = t1.elapsed();
    let mut table = Table::new(["layer", "grid", "stride", "c", "k", "σ_max", "top σ"]);
    for (i, layer) in spectra.layers.iter().enumerate() {
        let lp = plan.layer_plan(i);
        let kernel = lp.kernel();
        let s = &layer.spectrum;
        let shown: Vec<String> =
            s.sorted_desc().iter().take(k).map(|v| format!("{v:.3}")).collect();
        table.row([
            layer.name.clone(),
            format!("{}x{}", lp.fine_rows(), lp.fine_cols()),
            lp.stride().to_string(),
            channels_desc(kernel),
            s.rank_per_freq().to_string(),
            format!("{:.4}", s.sigma_max()),
            shown.join(" "),
        ]);
    }
    println!(
        "model {} — top-{k} partial-spectrum sweep: {} layers planned once into \
         {} equal-shape group(s), plan {} + sweep {} ({} run(s), {} worker(s))",
        plan.name(),
        plan.layer_count(),
        plan.group_count(),
        secs(t_plan),
        secs(t_exec),
        repeat,
        plan.effective_threads()
    );
    print!("{}", table.render());
    println!(
        "aggregate: {} singular values computed, global σ_max {:.4}, \
         Lipschitz composition bound {:.4}",
        commas(spectra.num_values() as u128),
        spectra.sigma_max(),
        spectra.lipschitz_upper_bound()
    );
    model_health_report(&spectra, cli.flag("strict-health"))?;
    // All layers share the build options, so layer 0 carries the sweep's
    // folding mode; ModelPlan sweeps are all-native, so every executed
    // layer folds unless folding is off.
    let folded_layers = if plan.layer_plan(0).folding() == Fold::Off {
        0
    } else {
        plan.layer_count() - cached_layers
    };
    println!("{}", freqs_solved_line(solved_freqs, total_freqs, cached_layers, folded_layers));
    println!("{}", cache_line(cache.map(|c| c.stats())));
    println!(
        "warm-start effort: {} Krylov iteration steps over {} frequencies \
         ({:.2} per frequency; cold starts typically cost an order of \
         magnitude more — see bench_scaling)",
        commas(iterations as u128),
        commas(total_freqs as u128),
        iterations as f64 / total_freqs.max(1) as f64
    );
    if cli.flag("csv") {
        let path = table.save_csv(&format!("audit_model_topk_{}", spectra.model))?;
        println!("csv: {}", path.display());
    }
    Ok(())
}

/// `serve` — run `lfa-convd`, the long-running spectral-audit daemon
/// (loopback line protocol + `GET /metrics`; see `coordinator::server`).
/// Blocks until a client sends `SHUTDOWN`.
#[cfg(feature = "daemon")]
fn cmd_serve(cli: &Cli) -> Result<()> {
    use conv_svd_lfa::coordinator::server::{self, DaemonConfig};
    use std::time::Duration;
    let addr = cli.opt("addr").unwrap_or("127.0.0.1:7733").to_string();
    let parsed = server::parse_addr(&addr)?;
    server::ensure_loopback(&parsed, cli.flag("allow-remote"))?;
    let service = ServiceConfig {
        workers: cli.opt_parse("threads", 0)?,
        folding: if cli.flag("no-fold") { Fold::Off } else { Fold::Auto },
        precision: precision_opt(cli)?,
        cache_bytes: cache_budget(cli)?,
        disk_cache_dir: disk_cache_dir(cli),
        tenant_quota: cli.opt_parse("tenant-quota", 0usize)?,
        strict_health: cli.flag("strict-health"),
        ..Default::default()
    };
    let config = DaemonConfig {
        service,
        addr,
        max_inflight: cli.opt_parse("max-inflight", 0usize)?,
        request_timeout: Duration::from_millis(cli.opt_parse("request-timeout-ms", 0u64)?),
        io_timeout: Duration::from_millis(cli.opt_parse("io-timeout-ms", 0u64)?),
        quantum: cli.opt_parse("quantum", 0usize)?,
        start_paused: false,
    };
    let handle = server::serve(config)?;
    println!(
        "lfa-convd listening on {} (line protocol + GET /metrics; SHUTDOWN to stop)",
        handle.addr()
    );
    handle.wait();
    println!("lfa-convd stopped");
    Ok(())
}

fn cmd_compare(cli: &Cli) -> Result<()> {
    let n: usize = cli.opt_parse("n", 32)?;
    let c: usize = cli.opt_parse("c", 16)?;
    let threads: usize = cli.opt_parse("threads", 0)?;
    let seed: u64 = cli.opt_parse("seed", 2025)?;
    let mut rng = Pcg64::seeded(seed);
    let kernel = ConvKernel::random_he(c, c, 3, 3, &mut rng);

    let t0 = std::time::Instant::now();
    let s_lfa = lfa::singular_values(&kernel, n, n, LfaOptions { threads, ..Default::default() });
    let t_lfa = t0.elapsed();
    let t0 = std::time::Instant::now();
    let s_fft = fft_svd::singular_values(&kernel, n, n, FftLayoutPolicy::Natural, threads);
    let t_fft = t0.elapsed();

    let mut table = Table::new(["method", "#σ", "σ_max", "time", "vs LFA"]);
    table.row([
        "LFA".to_string(),
        commas(s_lfa.num_values() as u128),
        format!("{:.6}", s_lfa.sigma_max()),
        secs(t_lfa),
        "1.00x".into(),
    ]);
    table.row([
        "FFT".to_string(),
        commas(s_fft.num_values() as u128),
        format!("{:.6}", s_fft.sigma_max()),
        secs(t_fft),
        format!("{:.2}x", t_fft.as_secs_f64() / t_lfa.as_secs_f64()),
    ]);
    if cli.flag("with-explicit") {
        let t0 = std::time::Instant::now();
        let s_exp = explicit_svd::singular_values(&kernel, n, n, Boundary::Periodic);
        let t_exp = t0.elapsed();
        table.row([
            "explicit".to_string(),
            commas(s_exp.num_values() as u128),
            format!("{:.6}", s_exp.sigma_max()),
            secs(t_exp),
            format!("{:.2}x", t_exp.as_secs_f64() / t_lfa.as_secs_f64()),
        ]);
    }
    let agree = {
        let a = s_lfa.sorted_desc();
        let b = s_fft.sorted_desc();
        a.iter().zip(&b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
    };
    print!("{}", table.render());
    println!("LFA vs FFT max |Δσ| = {agree:.3e}");
    Ok(())
}

fn cmd_artifacts(cli: &Cli) -> Result<()> {
    let dir = cli
        .opt("dir")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(SpectralService::default_artifacts_dir);
    let specs = load_manifest(&dir)?;
    let mut table = Table::new(["name", "grid", "channels", "tile_rows", "σ/call", "file"]);
    for s in &specs {
        table.row([
            s.name.clone(),
            format!("{}x{}", s.n, s.m),
            format!("{}x{}", s.c_out, s.c_in),
            s.tile_rows.to_string(),
            s.out_len().to_string(),
            s.file.file_name().unwrap().to_string_lossy().to_string(),
        ]);
    }
    print!("{}", table.render());
    if let Some(name) = cli.opt("run") {
        #[cfg(not(feature = "pjrt"))]
        {
            let _ = name;
            bail!("artifact execution needs PJRT; rebuild with --features pjrt");
        }
        #[cfg(feature = "pjrt")]
        {
            let spec = specs
                .iter()
                .find(|s| s.name == name)
                .ok_or_else(|| err!("no artifact named {name:?}"))?;
            let mut rng = Pcg64::seeded(7);
            let kernel = ConvKernel::random_he(spec.c_out, spec.c_in, spec.kh, spec.kw, &mut rng);
            let w: Vec<f32> = kernel.data.iter().map(|&v| v as f32).collect();
            let mut engine = PjrtEngine::cpu()?;
            let t0 = std::time::Instant::now();
            let values = engine.run_grid(spec, &w)?;
            let dt = t0.elapsed();
            let native = lfa::singular_values(&kernel, spec.n, spec.m, LfaOptions::default());
            let worst = values
                .iter()
                .zip(&native.values)
                .map(|(a, b)| (*a as f64 - b).abs())
                .fold(0.0, f64::max);
            println!(
                "ran {name} on {}: {} values in {}, max |Δσ| vs native = {worst:.2e}",
                engine.platform(),
                commas(values.len() as u128),
                secs(dt)
            );
        }
    }
    Ok(())
}
