//! Power and Krylov iteration for extreme singular values.
//!
//! Two layers live here:
//!
//! - [`spectral_norm`]: the Yoshida–Miyato baseline (§II-b of the paper) —
//!   approximate only σ_max, either on the *true* convolution operator (via
//!   [`LinOp`]) or on the loose reshaped `c_out × c_in·k²` matrix. A
//!   comparison point for the full-spectrum methods. Stays `f64`-only: it is
//!   a reference baseline, not a hot path.
//! - [`block_topk`]: the per-frequency solver behind the engine's
//!   `SpectrumRequest::TopK` mode — **Krylov-accelerated power iteration
//!   (Lanczos with full reorthogonalization) on the Gram operator, plus a
//!   deflated completion probe**. Plain block subspace iteration converges
//!   at the *relative eigenvalue gap*, which for conv symbols (dense,
//!   quasi-uniform spectra) shrinks like `1/c` — making it as expensive as
//!   the full Jacobi decomposition it was meant to beat. The Krylov form
//!   converges like Chebyshev (square-root of the gap), needs one
//!   matvec pair per step, and a power-iteration probe on the deflated
//!   operator catches the degenerate copies single-vector Lanczos can
//!   miss. The reusable [`TopKScratch`] carries the converged singular
//!   basis from one solve into the starting vector of the next, so a sweep
//!   over smoothly varying symbols — neighboring frequencies — spends
//!   measurably fewer steps than isolated cold solves (the paper's
//!   smooth-symbol observation turned into an iteration-count win).
//!
//! [`block_topk`] and its scratch are generic over the [`Real`] width
//! (`f64` default, `f32` for the reduced-precision tier); the dense matvec,
//! reorthogonalization, and deflation inner loops run through the
//! [`SimdReal`] kernels. Tolerances self-adapt: the caller's `tol` is
//! floored at a few machine epsilons of the active width so an `f32` solve
//! with default options terminates instead of chasing round-off.

use crate::linalg::SolveCert;
use crate::numeric::{C, C64, Mat, Pcg64, Real, SimdReal};
use crate::testing::chaos;

/// A real linear operator `A : R^in → R^out` exposing the two matvecs the
/// power method needs. Implemented by dense matrices and by the convolution
/// operator (`conv::apply`) without ever materializing the unrolled matrix.
pub trait LinOp {
    fn in_dim(&self) -> usize;
    fn out_dim(&self) -> usize;
    /// `y = A x`
    fn apply(&self, x: &[f64]) -> Vec<f64>;
    /// `y = Aᵀ x`
    fn apply_t(&self, x: &[f64]) -> Vec<f64>;
}

impl LinOp for Mat {
    fn in_dim(&self) -> usize {
        self.cols
    }
    fn out_dim(&self) -> usize {
        self.rows
    }
    fn apply(&self, x: &[f64]) -> Vec<f64> {
        self.matvec(x)
    }
    fn apply_t(&self, x: &[f64]) -> Vec<f64> {
        self.matvec_t(x)
    }
}

/// Outcome of [`spectral_norm`].
pub struct PowerResult {
    /// Estimated largest singular value.
    pub sigma_max: f64,
    /// Iterations actually run.
    pub iterations: usize,
    /// Final relative change — convergence indicator.
    pub residual: f64,
    /// Whether the relative change met `tol` within `max_iters`. A `false`
    /// here means `sigma_max` is a lower-bound estimate only — consumers
    /// (Lipschitz screening, clipping) must not treat it as certified.
    pub converged: bool,
}

/// Estimate `σ_max(A)` by power iteration on `AᵀA`.
pub fn spectral_norm<O: LinOp>(op: &O, max_iters: usize, tol: f64, rng: &mut Pcg64) -> PowerResult {
    let n = op.in_dim();
    let mut x = rng.normal_vec(n);
    normalize(&mut x);
    let mut sigma = 0.0f64;
    let mut last = f64::INFINITY;
    let mut iters = 0;
    let mut residual = f64::INFINITY;
    while iters < max_iters {
        iters += 1;
        let y = op.apply(&x);
        sigma = norm(&y);
        if sigma == 0.0 {
            return PowerResult { sigma_max: 0.0, iterations: iters, residual: 0.0, converged: true };
        }
        x = op.apply_t(&y);
        normalize(&mut x);
        residual = ((sigma - last) / sigma).abs();
        if residual < tol {
            break;
        }
        last = sigma;
    }
    PowerResult { sigma_max: sigma, iterations: iters, residual, converged: residual < tol }
}

/// Convergence controls for [`block_topk`].
#[derive(Clone, Copy, Debug)]
pub struct TopKOptions {
    /// Ritz-residual tolerance, relative to the largest eigenvalue of the
    /// Gram operator: pair `j` is converged when
    /// `‖AᴴA x_j − λ_j x_j‖ ≤ tol·λ_max`. For a Hermitian operator the
    /// eigenvalue error is bounded by the residual, so the default keeps
    /// σ errors below `1e-8·σ_max` even for values as small as
    /// `~1e-4·σ_max` (the σ²→σ conversion divides the λ error by `2σ_j`).
    /// Internally floored at `8·ε` of the active scalar width, so the
    /// same options work at `f32` without spinning on round-off.
    pub tol: f64,
    /// Hard cap on iteration steps per solve (Lanczos steps + probe power
    /// steps). The Krylov dimension is additionally capped by the scratch
    /// sizing; at either cap the best available estimates are reported.
    pub max_iters: usize,
}

impl Default for TopKOptions {
    fn default() -> Self {
        Self { tol: 1e-12, max_iters: 4000 }
    }
}

/// Reusable scratch for [`block_topk`]: the Lanczos basis and tridiagonal,
/// the small-eigenproblem buffers, and the output singular vectors. After a
/// solve the scratch is **warm**: the converged right singular vectors are
/// kept and the next call seeds its Krylov start vector from them — call
/// [`TopKScratch::reset`] at the start of every new sweep (or unrelated
/// block) to force a cold start. All buffers are sized by
/// [`TopKScratch::reserve`], so repeated solves on one shape are
/// allocation-free.
#[derive(Default)]
pub struct TopKScratch<T = f64> {
    rows: usize,
    cols: usize,
    k: usize,
    /// Dimension the Gram iteration runs in: `min(rows, cols)`.
    dim: usize,
    /// Krylov-basis capacity (`≤ dim`).
    tmax: usize,
    /// Output right singular vectors, vector-major: `v[j·cols..]`.
    v: Vec<C<T>>,
    /// Output scaled left vectors `A v_j = σ_j u_j`, vector-major over rows.
    w: Vec<C<T>>,
    /// Current Lanczos vector (`dim`).
    q: Vec<C<T>>,
    /// Lanczos work vector (`dim`).
    u: Vec<C<T>>,
    /// Matvec intermediate (`max(rows, cols)`).
    aw: Vec<C<T>>,
    /// Orthonormal Krylov basis, vector-major: `qbasis[t·dim..]`.
    qbasis: Vec<C<T>>,
    /// Tridiagonal diagonal / off-diagonal.
    alpha: Vec<T>,
    beta: Vec<T>,
    /// tqli work: eigenvalues, off-diagonal copy, last-row components.
    td: Vec<T>,
    te: Vec<T>,
    tz: Vec<T>,
    /// Top-k eigenvalue indices into `td`.
    idx: Vec<usize>,
    /// Tridiagonal eigenvectors of the chosen pairs, vector-major `k×tmax`.
    svecs: Vec<T>,
    /// Inverse-iteration solve buffers (`tmax`).
    sdd: Vec<T>,
    sup: Vec<T>,
    /// Probe vectors (right space / mapped).
    pv: Vec<C<T>>,
    pz: Vec<C<T>>,
    pw: Vec<C<T>>,
    warm: bool,
}

impl<T: Real> TopKScratch<T> {
    pub fn new() -> Self {
        Self {
            rows: 0,
            cols: 0,
            k: 0,
            dim: 0,
            tmax: 0,
            v: Vec::new(),
            w: Vec::new(),
            q: Vec::new(),
            u: Vec::new(),
            aw: Vec::new(),
            qbasis: Vec::new(),
            alpha: Vec::new(),
            beta: Vec::new(),
            td: Vec::new(),
            te: Vec::new(),
            tz: Vec::new(),
            idx: Vec::new(),
            svecs: Vec::new(),
            sdd: Vec::new(),
            sup: Vec::new(),
            pv: Vec::new(),
            pz: Vec::new(),
            pw: Vec::new(),
            warm: false,
        }
    }

    /// Pre-size for `rows×cols` blocks and `k` values so solves do not
    /// allocate. Resizing invalidates any warm basis.
    pub fn reserve(&mut self, rows: usize, cols: usize, k: usize) {
        if self.rows != rows || self.cols != cols || self.k != k {
            self.warm = false;
        }
        self.rows = rows;
        self.cols = cols;
        self.k = k;
        let dim = rows.min(cols);
        self.dim = dim;
        // Krylov capacity: comfortably past the observed step counts for
        // dense conv-symbol spectra, never past the space dimension.
        self.tmax = dim.min((8 * k).max(48) + dim / 8).max(k.min(dim)).max(1);
        self.v.resize(k * cols, C::ZERO);
        self.w.resize(k * rows, C::ZERO);
        self.q.resize(dim, C::ZERO);
        self.u.resize(dim, C::ZERO);
        self.aw.resize(rows.max(cols), C::ZERO);
        self.qbasis.resize(self.tmax * dim, C::ZERO);
        self.alpha.resize(self.tmax, T::ZERO);
        self.beta.resize(self.tmax, T::ZERO);
        self.td.resize(self.tmax, T::ZERO);
        self.te.resize(self.tmax, T::ZERO);
        self.tz.resize(self.tmax, T::ZERO);
        self.idx.resize(self.tmax, 0);
        self.svecs.resize(k * self.tmax, T::ZERO);
        self.sdd.resize(self.tmax, T::ZERO);
        self.sup.resize(self.tmax, T::ZERO);
        self.pv.resize(cols, C::ZERO);
        self.pz.resize(cols, C::ZERO);
        self.pw.resize(rows, C::ZERO);
    }

    /// Forget the warm basis: the next [`block_topk`] call cold-starts.
    pub fn reset(&mut self) {
        self.warm = false;
    }

    /// Conjugate the stored warm basis in place. For real kernel weights
    /// the symbol satisfies `A(−θ) = conj(A(θ))` with conjugated singular
    /// vectors, so a folded sweep (engine `Fold`) crossing the `θ → −θ`
    /// seam continues its warm start through the mirror by conjugating the
    /// carried basis: the next frequencies it visits are the conjugates of
    /// neighbors of the frequencies just solved. (For strided plans the
    /// aliasing groups additionally permute — the conjugate is then a
    /// partial hint, which is all a warm start needs.) No-op when cold.
    pub fn conjugate_basis(&mut self) {
        if !self.warm {
            return;
        }
        for z in self.v.iter_mut() {
            *z = z.conj();
        }
        for z in self.w.iter_mut() {
            *z = z.conj();
        }
    }

    /// Whether the next solve will warm-start from a converged basis.
    pub fn is_warm(&self) -> bool {
        self.warm
    }

    /// Right singular vector `j` (length `cols`) after a solve, descending
    /// value order.
    pub fn right_vector(&self, j: usize) -> &[C<T>] {
        &self.v[j * self.cols..(j + 1) * self.cols]
    }

    /// Scaled left vector `j` after a solve: `A v_j = σ_j u_j` (length
    /// `rows`). Divide by `σ_j` for the unit left singular vector.
    pub fn left_scaled(&self, j: usize) -> &[C<T>] {
        &self.w[j * self.rows..(j + 1) * self.rows]
    }
}

/// `⟨a, b⟩ = Σ conj(a_i)·b_i` — the conjugate of the SIMD `cdot_conj`.
#[inline]
fn cdot<T: SimdReal>(a: &[C<T>], b: &[C<T>]) -> C<T> {
    T::cdot_conj(a, b).conj()
}

#[inline]
fn cnorm2<T: Real>(a: &[C<T>]) -> T {
    a.iter().map(|z| z.norm_sqr()).sum()
}

/// `y = A x` for a row-major `rows×cols` block: one SIMD dot per row.
fn mat_vec<T: SimdReal>(a: &[C<T>], rows: usize, cols: usize, x: &[C<T>], y: &mut [C<T>]) {
    for i in 0..rows {
        y[i] = T::cdot(&a[i * cols..(i + 1) * cols], &x[..cols]);
    }
}

/// `y = Aᴴ x` for a row-major `rows×cols` block (streamed over rows; the
/// conjugated-source axpy has no SIMD kernel, so this stays scalar FMA).
fn mat_vec_h<T: Real>(a: &[C<T>], rows: usize, cols: usize, x: &[C<T>], y: &mut [C<T>]) {
    y[..cols].fill(C::ZERO);
    for i in 0..rows {
        let arow = &a[i * cols..(i + 1) * cols];
        let xi = x[i];
        for c in 0..cols {
            y[c] = y[c].mul_add(arow[c].conj(), xi);
        }
    }
}

/// Eigenvalues of the symmetric tridiagonal `(d, e)` (size `t`) by implicit
/// QL with Wilkinson shifts, plus the **last component** of every
/// eigenvector (accumulated through the rotations) — exactly what the
/// Lanczos residual bound `|β_t·s_{t,i}|` needs. `d` is overwritten with
/// the (unsorted) eigenvalues, `e` is clobbered, `z` receives the last-row
/// components. `O(t²)`.
fn tqli_values_lastrow<T: Real>(d: &mut [T], e: &mut [T], z: &mut [T], t: usize) {
    z[..t].fill(T::ZERO);
    z[t - 1] = T::ONE;
    if t == 1 {
        return;
    }
    e[t - 1] = T::ZERO;
    for l in 0..t {
        let mut iters = 0;
        loop {
            let mut m = l;
            while m < t - 1 {
                let dd = d[m].abs() + d[m + 1].abs();
                if e[m].abs() <= T::TINY + T::QL_EPS * dd {
                    break;
                }
                m += 1;
            }
            if m == l {
                break;
            }
            iters += 1;
            if iters > 50 {
                break;
            }
            let mut g = (d[l + 1] - d[l]) / (T::TWO * e[l]);
            let mut r = g.hypot(T::ONE);
            g = d[m] - d[l] + e[l] / (g + if g >= T::ZERO { r } else { -r });
            let mut s = T::ONE;
            let mut c = T::ONE;
            let mut p = T::ZERO;
            let mut underflow = false;
            let mut i = m;
            while i > l {
                i -= 1;
                let f = s * e[i];
                let b = c * e[i];
                r = f.hypot(g);
                e[i + 1] = r;
                if r == T::ZERO {
                    d[i + 1] -= p;
                    e[m] = T::ZERO;
                    underflow = true;
                    break;
                }
                s = f / r;
                c = g / r;
                g = d[i + 1] - p;
                let rr = (d[i] - g) * s + T::TWO * c * b;
                p = s * rr;
                d[i + 1] = g + p;
                g = c * rr - b;
                // The same rotation, applied to the last-row accumulator.
                let zi = z[i];
                let zi1 = z[i + 1];
                z[i + 1] = s * zi + c * zi1;
                z[i] = c * zi - s * zi1;
            }
            if !underflow {
                d[l] -= p;
                e[l] = g;
                e[m] = T::ZERO;
            }
        }
    }
}

/// One eigenvector of the symmetric tridiagonal `(alpha, beta)` (size `t`)
/// for the (already computed) eigenvalue `lam`, by inverse iteration with a
/// perturbed shift; written into `s[..t]`, normalized. `O(t)` per solve.
fn tridiag_eigvec<T: Real>(
    alpha: &[T],
    beta: &[T],
    t: usize,
    lam: T,
    seed: u64,
    dd: &mut [T],
    up: &mut [T],
    s: &mut [T],
) {
    let mut rng = Pcg64::seeded(0x7071_u64 ^ seed);
    for x in s[..t].iter_mut() {
        *x = T::from_f64(rng.normal());
    }
    let shift = lam + T::SHIFT * lam.abs().max(T::ONE);
    for _round in 0..3 {
        // Thomas solve (T − shift·I) y = s, in place on s.
        for i in 0..t {
            dd[i] = alpha[i] - shift;
        }
        up[..t.saturating_sub(1)].copy_from_slice(&beta[..t.saturating_sub(1)]);
        for i in 0..t - 1 {
            if dd[i].abs() < T::TINY {
                dd[i] = T::TINY;
            }
            let w = up[i] / dd[i];
            dd[i + 1] -= w * up[i];
            s[i + 1] -= w * s[i];
        }
        if dd[t - 1].abs() < T::TINY {
            dd[t - 1] = T::TINY;
        }
        s[t - 1] /= dd[t - 1];
        let mut i = t - 1;
        while i > 0 {
            i -= 1;
            s[i] = (s[i] - up[i] * s[i + 1]) / dd[i];
        }
        let n: T = s[..t].iter().map(|x| *x * *x).sum::<T>().sqrt();
        if n == T::ZERO {
            return;
        }
        for x in s[..t].iter_mut() {
            *x /= n;
        }
    }
}

/// Top-`k` singular values of a row-major `rows×cols` complex block,
/// written descending into `out` (`k ≤ min(rows, cols)` values), with the
/// corresponding singular vectors left in `scratch`
/// ([`TopKScratch::right_vector`] / [`TopKScratch::left_scaled`]). Returns
/// the convergence certificate: `effort` is the number of iteration steps
/// spent (Lanczos steps + probe power steps), `residual` the worst
/// relative Ritz residual of the returned pairs, and `converged` whether
/// every pair met the tolerance (or the Krylov space was exhausted — an
/// exact invariant subspace) within the budget.
///
/// The engine: Lanczos on the Gram operator of the smaller side (`AᴴA` or
/// `AAᴴ`), fully reorthogonalized, with the Ritz residual bound
/// `|β_t·s_{t,i}| ≤ tol·λ_max` as the stopping rule — convergence like
/// Chebyshev in the relative gap, one matvec pair per step. A deflated
/// power-iteration **probe** then checks the orthogonal complement of the
/// returned vectors for a larger hidden eigenvalue (the degenerate-copy
/// case single-vector Krylov cannot see) and completes the set if one is
/// found. A warm scratch (see [`TopKScratch`]) seeds the start vector from
/// the previous block's singular basis. Allocation-free once the scratch
/// has seen the shape.
///
/// Like every Gram-side method (including the `GramEigen` ablation
/// solver), exactly-zero singular values are reported at the `√ε·σ_max`
/// noise floor of the squared formulation (≈2e-8·σ_max at f64); nonzero
/// values are accurate to the residual tolerance.
pub fn block_topk<T: SimdReal>(
    a: &[C<T>],
    rows: usize,
    cols: usize,
    k: usize,
    opts: TopKOptions,
    scratch: &mut TopKScratch<T>,
    out: &mut [T],
) -> SolveCert {
    debug_assert_eq!(a.len(), rows * cols);
    debug_assert!(k >= 1 && k <= rows.min(cols), "k must be in 1..=min(rows, cols)");
    debug_assert_eq!(out.len(), k);
    // Fault injection: report exhaustion (values stay correct) so the
    // escalation ladder is exercisable without a pathological matrix.
    let stall = chaos::fire(chaos::SOLVER_STALL);
    scratch.reserve(rows, cols, k);
    let dim = scratch.dim;
    let tmax = scratch.tmax;
    let use_right = cols <= rows;
    // Floor the tolerance at a few machine epsilons of the active width:
    // a Ritz residual cannot shrink below ~ε·λ_max, so an f32 run with the
    // f64 default (1e-12) would otherwise spin to max_iters on round-off.
    let tol = T::from_f64(opts.tol).max(T::EPS * T::from_f64(8.0));
    // √ε of the active width: probe noise floors and degeneracy margins.
    let sqrt_eps = T::EPS.sqrt();
    let max_steps = opts.max_iters.max(k + 1);
    let mut steps = 0usize;
    // Certificate state: the worst relative Ritz residual seen at the most
    // recent convergence check, and whether the loop exited converged
    // (Ritz tolerance met, or the Krylov space exhausted — exact).
    let mut ritz_res = T::ZERO;
    let mut converged = false;

    // --- starting vector: warm hint (sum of previous right vectors,
    // mapped through A when iterating the left Gram side) or random ---
    let mut warm_ok = false;
    if scratch.warm {
        if use_right {
            scratch.q.fill(C::ZERO);
            for j in 0..k {
                let vj = &scratch.v[j * cols..(j + 1) * cols];
                for (qc, vc) in scratch.q.iter_mut().zip(vj.iter()) {
                    *qc += *vc;
                }
            }
        } else {
            scratch.aw[..cols].fill(C::ZERO);
            for j in 0..k {
                let vj = &scratch.v[j * cols..(j + 1) * cols];
                for (ac, vc) in scratch.aw[..cols].iter_mut().zip(vj.iter()) {
                    *ac += *vc;
                }
            }
            let (hint, q) = (&scratch.aw[..cols], &mut scratch.q[..]);
            mat_vec(a, rows, cols, hint, q);
        }
        let n2 = cnorm2(&scratch.q);
        if n2.sqrt() > T::SMALL {
            let inv = n2.sqrt().recip();
            for x in scratch.q.iter_mut() {
                *x = x.scale(inv);
            }
            warm_ok = true;
        }
    }
    if !warm_ok {
        let mut rng = Pcg64::seeded(0x7091_u64 ^ ((dim as u64) << 12) ^ (k as u64));
        for x in scratch.q.iter_mut() {
            *x = C::new(T::from_f64(rng.normal()), T::from_f64(rng.normal()));
        }
        let inv = cnorm2(&scratch.q).sqrt().max(T::TINY).recip();
        for x in scratch.q.iter_mut() {
            *x = x.scale(inv);
        }
    }

    // --- Lanczos with full reorthogonalization ---
    let mut t = 0usize;
    let mut scale = T::ZERO;
    let mut lmax = T::ZERO;
    loop {
        scratch.qbasis[t * dim..(t + 1) * dim].copy_from_slice(&scratch.q);
        steps += 1;
        // u = Gram · q through the block (one matvec pair).
        if use_right {
            mat_vec(a, rows, cols, &scratch.q, &mut scratch.aw[..rows]);
            mat_vec_h(a, rows, cols, &scratch.aw[..rows], &mut scratch.u);
        } else {
            mat_vec_h(a, rows, cols, &scratch.q, &mut scratch.aw[..cols]);
            mat_vec(a, rows, cols, &scratch.aw[..cols], &mut scratch.u);
        }
        let alpha_t = cdot(&scratch.q, &scratch.u).re;
        scratch.alpha[t] = alpha_t;
        // u ← u − α_t·q_t − β_{t-1}·q_{t-1}, then one full classical-GS
        // pass against the whole basis (the "full reorthogonalization"
        // that keeps the basis orthonormal to machine precision).
        T::caxpy(C::new(-alpha_t, T::ZERO), &scratch.q, &mut scratch.u);
        if t > 0 {
            let bprev = scratch.beta[t - 1];
            let qprev = &scratch.qbasis[(t - 1) * dim..t * dim];
            T::caxpy(C::new(-bprev, T::ZERO), qprev, &mut scratch.u);
        }
        for i in 0..=t {
            let qi = &scratch.qbasis[i * dim..(i + 1) * dim];
            let coef = cdot(qi, &scratch.u);
            T::caxpy(-coef, qi, &mut scratch.u);
        }
        let b = cnorm2(&scratch.u).sqrt();
        scale = scale.max(alpha_t.abs()).max(b);
        t += 1;
        // Convergence: Ritz residuals of the current tridiagonal. Reaching
        // `dim` means the Krylov space is the whole space (exact invariant
        // subspace); hitting `tmax`/`max_steps` alone is budget exhaustion.
        if t >= dim {
            converged = true;
        }
        let mut done = t >= dim || t >= tmax || steps >= max_steps;
        if t >= k.min(dim) {
            scratch.td[..t].copy_from_slice(&scratch.alpha[..t]);
            scratch.te[..t].copy_from_slice(&scratch.beta[..t]);
            tqli_values_lastrow(&mut scratch.td, &mut scratch.te, &mut scratch.tz, t);
            select_topk_desc(&scratch.td[..t], &mut scratch.idx, k.min(t));
            lmax = scratch.td[scratch.idx[0]].max(T::ZERO);
            if lmax > T::ZERO && t >= k {
                let mut ok = true;
                let mut worst = T::ZERO;
                for j in 0..k {
                    let r = b * scratch.tz[scratch.idx[j]].abs();
                    worst = worst.max(r);
                    if r > tol * lmax {
                        ok = false;
                    }
                }
                ritz_res = worst / lmax;
                if ok {
                    done = true;
                    converged = true;
                }
            }
        }
        if !done && b <= T::BREAKDOWN * scale.max(T::TINY) {
            // Breakdown: the Krylov space went invariant. That is only a
            // *converged* state if it already exposed a nonzero top-k set;
            // otherwise — fewer than k columns, or everything seen so far
            // is zero (a warm hint that landed exactly in the null space
            // of a nonzero block looks like this) — restart with a fresh
            // random vector orthogonal to the basis and keep growing, so
            // the true spectrum is picked up and the all-zero answer is
            // only ever reported once the basis exhausts the space.
            if t >= k && lmax > T::ZERO {
                // Invariant subspace with a nonzero top-k set: exact.
                done = true;
                converged = true;
            } else {
                let mut rng = Pcg64::seeded(0xbdbd_u64 ^ (t as u64));
                for x in scratch.q.iter_mut() {
                    *x = C::new(T::from_f64(rng.normal()), T::from_f64(rng.normal()));
                }
                for i in 0..t {
                    let qi = &scratch.qbasis[i * dim..(i + 1) * dim];
                    let coef = cdot(qi, &scratch.q);
                    T::caxpy(-coef, qi, &mut scratch.q);
                }
                let inv = cnorm2(&scratch.q).sqrt().max(T::TINY).recip();
                for x in scratch.q.iter_mut() {
                    *x = x.scale(inv);
                }
                scratch.beta[t - 1] = T::ZERO;
                continue;
            }
        }
        if done {
            break;
        }
        scratch.beta[t - 1] = b;
        let inv = b.recip();
        for (qc, uc) in scratch.q.iter_mut().zip(scratch.u.iter()) {
            *qc = uc.scale(inv);
        }
    }

    // --- extract the top-k Ritz pairs of the final tridiagonal ---
    scratch.td[..t].copy_from_slice(&scratch.alpha[..t]);
    scratch.te[..t].copy_from_slice(&scratch.beta[..t]);
    tqli_values_lastrow(&mut scratch.td, &mut scratch.te, &mut scratch.tz, t);
    let kk = k.min(t);
    select_topk_desc(&scratch.td[..t], &mut scratch.idx, kk);
    lmax = scratch.td[scratch.idx[0]].max(T::ZERO);
    for j in 0..kk {
        let lam = scratch.td[scratch.idx[j]];
        tridiag_eigvec(
            &scratch.alpha,
            &scratch.beta,
            t,
            lam,
            ((j as u64) << 32) | (t as u64),
            &mut scratch.sdd,
            &mut scratch.sup,
            &mut scratch.svecs[j * tmax..j * tmax + t],
        );
    }
    // Orthonormalize the k tridiagonal eigenvectors (clustered eigenvalues
    // can make inverse iteration return nearly parallel vectors).
    for j in 0..kk {
        for _pass in 0..2 {
            for p in 0..j {
                let mut dot = T::ZERO;
                for i in 0..t {
                    dot += scratch.svecs[p * tmax + i] * scratch.svecs[j * tmax + i];
                }
                for i in 0..t {
                    let sub = dot * scratch.svecs[p * tmax + i];
                    scratch.svecs[j * tmax + i] -= sub;
                }
            }
        }
        let n: T =
            scratch.svecs[j * tmax..j * tmax + t].iter().map(|x| *x * *x).sum::<T>().sqrt();
        if n > T::SMALL {
            for i in 0..t {
                scratch.svecs[j * tmax + i] /= n;
            }
        }
    }
    // Map back to singular vectors and values.
    for j in 0..k {
        if j < kk {
            let lam = scratch.td[scratch.idx[j]].max(T::ZERO);
            out[j] = lam.sqrt();
        } else {
            out[j] = T::ZERO;
        }
    }
    for j in 0..k {
        // x_j = Σ_i s_j[i]·q_i, built in scratch.u (dim long).
        scratch.u.fill(C::ZERO);
        if j < kk {
            for i in 0..t {
                let si = scratch.svecs[j * tmax + i];
                let qi = &scratch.qbasis[i * dim..(i + 1) * dim];
                T::caxpy(C::new(si, T::ZERO), qi, &mut scratch.u);
            }
        }
        let sigma = out[j];
        if use_right {
            // x is the right singular vector directly.
            scratch.v[j * cols..(j + 1) * cols].copy_from_slice(&scratch.u);
            let (v, w) = (&scratch.v[j * cols..(j + 1) * cols], &mut scratch.pw);
            mat_vec(a, rows, cols, v, w);
            scratch.w[j * rows..(j + 1) * rows].copy_from_slice(&scratch.pw);
        } else {
            // x is the left singular vector u_j: w_j = σ_j·u_j and
            // v_j = Aᴴu_j / σ_j.
            for (wc, uc) in
                scratch.w[j * rows..(j + 1) * rows].iter_mut().zip(scratch.u.iter())
            {
                *wc = uc.scale(sigma);
            }
            mat_vec_h(a, rows, cols, &scratch.u, &mut scratch.pz);
            let inv = if sigma > T::ZERO { sigma.recip() } else { T::ZERO };
            for (vc, zc) in
                scratch.v[j * cols..(j + 1) * cols].iter_mut().zip(scratch.pz.iter())
            {
                *vc = zc.scale(inv);
            }
        }
    }

    // --- deflated completion probe: catch missed degenerate copies ---
    // A single Krylov start vector carries one fixed direction per
    // eigenspace, so an exact multiplicity among the top k can surface as
    // the *next* eigenvalue instead. Power-iterate a random vector in the
    // orthogonal complement of the returned right vectors; if its Rayleigh
    // quotient beats λ_k, a copy was missed — converge it and insert.
    if lmax > T::ZERO {
        'rounds: for round in 0..k {
            if k >= cols {
                break;
            }
            let mut rng =
                Pcg64::seeded(0x9b0e_u64 ^ ((round as u64) << 24) ^ (cols as u64));
            for x in scratch.pv.iter_mut() {
                *x = C::new(T::from_f64(rng.normal()), T::from_f64(rng.normal()));
            }
            deflate_against(&mut scratch.pv, &scratch.v, k, cols);
            let n2 = cnorm2(&scratch.pv);
            if n2.sqrt() <= sqrt_eps * T::from_usize(cols).sqrt() {
                break;
            }
            let inv = n2.sqrt().recip();
            for x in scratch.pv.iter_mut() {
                *x = x.scale(inv);
            }
            let lam_k = out[k - 1] * out[k - 1];
            let threshold = lam_k * (T::ONE + sqrt_eps) + tol * lmax;
            let mut rq = T::ZERO;
            for _ in 0..12 {
                steps += 1;
                mat_vec(a, rows, cols, &scratch.pv, &mut scratch.pw);
                mat_vec_h(a, rows, cols, &scratch.pw, &mut scratch.pz);
                deflate_against(&mut scratch.pz, &scratch.v, k, cols);
                rq = cdot(&scratch.pv, &scratch.pz).re;
                let n = cnorm2(&scratch.pz).sqrt();
                if n == T::ZERO || rq > threshold {
                    // Zero complement, or detection already confirmed (the
                    // Rayleigh quotient only lower-bounds the deflated
                    // operator's top eigenvalue, so exceeding the threshold
                    // early is conclusive — the clean case has no such
                    // shortcut and runs the full amplification budget).
                    break;
                }
                let inv = n.recip();
                for (pc, zc) in scratch.pv.iter_mut().zip(scratch.pz.iter()) {
                    *pc = zc.scale(inv);
                }
            }
            if rq <= threshold {
                break 'rounds;
            }
            // Missed copy: converge the probe, then insert it in order.
            for _ in 0..50 {
                steps += 1;
                mat_vec(a, rows, cols, &scratch.pv, &mut scratch.pw);
                mat_vec_h(a, rows, cols, &scratch.pw, &mut scratch.pz);
                deflate_against(&mut scratch.pz, &scratch.v, k, cols);
                rq = cdot(&scratch.pv, &scratch.pz).re;
                let mut res2 = T::ZERO;
                for (zc, pc) in scratch.pz.iter().zip(scratch.pv.iter()) {
                    res2 += (*zc - pc.scale(rq)).norm_sqr();
                }
                let n = cnorm2(&scratch.pz).sqrt();
                if n == T::ZERO {
                    break;
                }
                let inv = n.recip();
                for (pc, zc) in scratch.pv.iter_mut().zip(scratch.pz.iter()) {
                    *pc = zc.scale(inv);
                }
                if res2.sqrt() <= tol * lmax {
                    break;
                }
            }
            let sigma_new = rq.max(T::ZERO).sqrt();
            // Shift the smaller entries down and insert at the right rank.
            let mut pos = k;
            for j in 0..k {
                if sigma_new > out[j] {
                    pos = j;
                    break;
                }
            }
            if pos >= k {
                break 'rounds;
            }
            let mut j = k - 1;
            while j > pos {
                out[j] = out[j - 1];
                let (head, tail) = scratch.v.split_at_mut(j * cols);
                tail[..cols].copy_from_slice(&head[(j - 1) * cols..j * cols]);
                let (whead, wtail) = scratch.w.split_at_mut(j * rows);
                wtail[..rows].copy_from_slice(&whead[(j - 1) * rows..j * rows]);
                j -= 1;
            }
            out[pos] = sigma_new;
            scratch.v[pos * cols..(pos + 1) * cols].copy_from_slice(&scratch.pv);
            mat_vec(a, rows, cols, &scratch.pv, &mut scratch.pw);
            scratch.w[pos * rows..(pos + 1) * rows].copy_from_slice(&scratch.pw);
        }
    }
    scratch.warm = true;
    SolveCert {
        effort: steps,
        residual: ritz_res.to_f64(),
        converged: converged && !stall,
        restarted: false,
    }
}

/// Write the indices of the `k` largest entries of `vals` (descending)
/// into `idx[..k]` — selection without sorting the whole array.
fn select_topk_desc<T: Real>(vals: &[T], idx: &mut [usize], k: usize) {
    for j in 0..k {
        let mut best = usize::MAX;
        for (i, &v) in vals.iter().enumerate() {
            if idx[..j].contains(&i) {
                continue;
            }
            if best == usize::MAX || v > vals[best] {
                best = i;
            }
        }
        idx[j] = best;
    }
}

/// Subtract the projections of `x` onto the `k` stored vectors
/// (vector-major, `len` entries each) — the deflation step of the probe.
fn deflate_against<T: SimdReal>(x: &mut [C<T>], vecs: &[C<T>], k: usize, len: usize) {
    for j in 0..k {
        let vj = &vecs[j * len..(j + 1) * len];
        let coef = cdot(vj, x);
        T::caxpy(-coef, vj, x);
    }
}

/// Refine f32 top-k values against the exact f64 block: `σ_j = ‖A·v_j‖`
/// with `v_j` the (widened) f32 right singular vector. First-order errors
/// in `v_j` perturb `‖A v_j‖` only at second order around a singular
/// vector, so an `O(ε_32)` vector yields an `O(ε_32²) ≈ 1e-14` value —
/// the top-k half of the `F32Refined` tier. `vtmp` is a `cols`-long
/// widening buffer; values are written descending into `out`.
pub fn refine_topk_values(
    a64: &[C64],
    rows: usize,
    cols: usize,
    scratch32: &TopKScratch<f32>,
    k: usize,
    vtmp: &mut [C64],
    out: &mut [f64],
) {
    debug_assert_eq!(a64.len(), rows * cols);
    debug_assert_eq!(out.len(), k);
    debug_assert!(vtmp.len() >= cols);
    for j in 0..k {
        let v32 = scratch32.right_vector(j);
        let mut n2 = 0.0f64;
        for (wide, narrow) in vtmp[..cols].iter_mut().zip(v32.iter()) {
            *wide = narrow.to_c64();
            n2 += wide.norm_sqr();
        }
        if n2 <= 0.0 {
            out[j] = 0.0;
            continue;
        }
        // ‖A v‖ / ‖v‖ — the Rayleigh quotient for singular values.
        let mut num2 = 0.0f64;
        for i in 0..rows {
            let yi = <f64 as SimdReal>::cdot(&a64[i * cols..(i + 1) * cols], &vtmp[..cols]);
            num2 += yi.norm_sqr();
        }
        out[j] = (num2 / n2).sqrt();
    }
    out.sort_unstable_by(|x, y| y.partial_cmp(x).unwrap());
}

fn norm(x: &[f64]) -> f64 {
    x.iter().map(|v| v * v).sum::<f64>().sqrt()
}

fn normalize(x: &mut [f64]) {
    let n = norm(x);
    if n > 0.0 {
        for v in x.iter_mut() {
            *v /= n;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gk_svd;

    #[test]
    fn matches_svd_on_dense() {
        let mut rng = Pcg64::seeded(51);
        let a = Mat::random_normal(12, 8, &mut rng);
        let want = gk_svd::singular_values(&a)[0];
        let got = spectral_norm(&a, 500, 1e-12, &mut rng);
        assert!(
            (got.sigma_max - want).abs() / want < 1e-8,
            "power {} vs svd {want}",
            got.sigma_max
        );
    }

    #[test]
    fn exact_on_diagonal() {
        let a = Mat::from_rows(&[&[3.0, 0.0], &[0.0, 9.0]]);
        let mut rng = Pcg64::seeded(52);
        let got = spectral_norm(&a, 200, 1e-14, &mut rng);
        assert!((got.sigma_max - 9.0).abs() < 1e-10);
    }

    #[test]
    fn zero_operator() {
        let a = Mat::zeros(4, 4);
        let mut rng = Pcg64::seeded(53);
        let got = spectral_norm(&a, 100, 1e-10, &mut rng);
        assert_eq!(got.sigma_max, 0.0);
    }

    #[test]
    fn converges_within_budget() {
        let mut rng = Pcg64::seeded(54);
        let a = Mat::random_normal(20, 20, &mut rng);
        let got = spectral_norm(&a, 2000, 1e-10, &mut rng);
        assert!(got.residual < 1e-10, "residual {}", got.residual);
        assert!(got.converged);
    }

    #[test]
    fn tiny_budget_reports_unconverged() {
        let mut rng = Pcg64::seeded(60);
        let a = Mat::random_normal(20, 20, &mut rng);
        let got = spectral_norm(&a, 2, 1e-14, &mut rng);
        assert!(!got.converged, "2 iterations cannot certify 1e-14");
    }

    #[test]
    fn block_topk_matches_jacobi() {
        use crate::linalg::jacobi_svd;
        use crate::numeric::CMat;
        let mut rng = Pcg64::seeded(55);
        for &(rows, cols, k) in &[(6usize, 6usize, 1usize), (6, 6, 3), (8, 5, 2), (4, 9, 4)] {
            let a = CMat::random_normal(rows, cols, &mut rng);
            let want = jacobi_svd::singular_values(&a);
            let mut scratch = TopKScratch::new();
            let mut got = vec![0.0f64; k];
            let cert =
                block_topk(&a.data, rows, cols, k, TopKOptions::default(), &mut scratch, &mut got);
            assert!(cert.effort >= 1);
            assert!(cert.converged, "healthy random block must certify");
            for j in 0..k {
                assert!(
                    (got[j] - want[j]).abs() <= 1e-9 * want[0].max(1.0),
                    "{rows}x{cols} k={k} j={j}: {} vs {}",
                    got[j],
                    want[j]
                );
            }
        }
    }

    #[test]
    fn block_topk_warm_start_uses_fewer_steps() {
        use crate::numeric::CMat;
        let mut rng = Pcg64::seeded(56);
        let a = CMat::random_normal(8, 8, &mut rng);
        let mut scratch = TopKScratch::new();
        let mut out = vec![0.0f64; 3];
        let cold =
            block_topk(&a.data, 8, 8, 3, TopKOptions::default(), &mut scratch, &mut out).effort;
        assert!(scratch.is_warm());
        // Same block again: the warm hint spans the invariant subspace, so
        // the Krylov loop exhausts it after ~k steps instead of sweeping
        // the whole space (both runs pay the fixed completion-probe steps).
        let warm =
            block_topk(&a.data, 8, 8, 3, TopKOptions::default(), &mut scratch, &mut out).effort;
        assert!(cold > warm, "cold {cold} vs warm {warm}");
    }

    #[test]
    fn block_topk_finds_degenerate_copies() {
        use crate::numeric::C64;
        // diag(3, 3, 1): a single Krylov start vector carries one fixed
        // direction of the 2-dim eigenspace, so Lanczos alone would report
        // [3, 1] — the deflated completion probe must recover the copy.
        let mut a = vec![C64::ZERO; 9];
        a[0] = C64::real(3.0);
        a[4] = C64::real(3.0);
        a[8] = C64::real(1.0);
        let mut scratch = TopKScratch::new();
        let mut out = vec![0.0f64; 2];
        block_topk(&a, 3, 3, 2, TopKOptions::default(), &mut scratch, &mut out);
        assert!(
            (out[0] - 3.0).abs() < 1e-8 && (out[1] - 3.0).abs() < 1e-8,
            "degenerate pair lost: {out:?}"
        );
    }

    #[test]
    fn block_topk_recovers_from_null_warm_hint() {
        use crate::numeric::C64;
        // Warm the scratch on a block whose top right vector is e_2 …
        let mut b = vec![C64::ZERO; 9];
        b[8] = C64::real(5.0);
        let mut scratch = TopKScratch::new();
        let mut out = vec![0.0f64; 1];
        block_topk(&b, 3, 3, 1, TopKOptions::default(), &mut scratch, &mut out);
        assert!(scratch.is_warm());
        assert!((out[0] - 5.0).abs() < 1e-8);
        // … then solve a block for which e_2 is exactly the null direction.
        // The warm hint annihilates under the Gram operator; the solver
        // must restart internally instead of reporting σ_max = 0.
        let mut a = vec![C64::ZERO; 9];
        a[0] = C64::real(2.0);
        a[4] = C64::real(1.0);
        block_topk(&a, 3, 3, 1, TopKOptions::default(), &mut scratch, &mut out);
        assert!((out[0] - 2.0).abs() < 1e-8, "null warm hint zeroed the solve: {out:?}");
    }

    #[test]
    fn conjugated_basis_warm_starts_the_conjugate_block() {
        use crate::numeric::CMat;
        let mut rng = Pcg64::seeded(59);
        let a = CMat::random_normal(12, 12, &mut rng);
        let conj_a: Vec<C64> = a.data.iter().map(|z| z.conj()).collect();
        // Cold reference on conj(A).
        let mut cold_scratch = TopKScratch::new();
        let mut want = vec![0.0f64; 3];
        let cold = block_topk(&conj_a, 12, 12, 3, TopKOptions::default(), &mut cold_scratch, &mut want)
            .effort;
        // Solve A, conjugate the carried basis, then solve conj(A): the
        // conjugated basis spans conj(A)'s invariant subspace exactly, so
        // the warm solve converges in fewer steps with the same values.
        let mut scratch = TopKScratch::new();
        let mut out = vec![0.0f64; 3];
        block_topk(&a.data, 12, 12, 3, TopKOptions::default(), &mut scratch, &mut out);
        scratch.conjugate_basis();
        assert!(scratch.is_warm(), "conjugation must not drop the warm state");
        let warm =
            block_topk(&conj_a, 12, 12, 3, TopKOptions::default(), &mut scratch, &mut out).effort;
        for (x, y) in out.iter().zip(&want) {
            assert!((x - y).abs() <= 1e-9 * want[0].max(1.0), "{x} vs {y}");
        }
        assert!(warm < cold, "warm {warm} !< cold {cold}");
        // Conjugating a cold scratch is a no-op.
        let mut empty = TopKScratch::<f64>::new();
        empty.conjugate_basis();
        assert!(!empty.is_warm());
    }

    #[test]
    fn block_topk_zero_block() {
        let a = vec![crate::numeric::C64::ZERO; 12];
        let mut scratch = TopKScratch::new();
        let mut out = vec![1.0f64; 2];
        block_topk(&a, 3, 4, 2, TopKOptions::default(), &mut scratch, &mut out);
        assert_eq!(out, vec![0.0, 0.0]);
    }

    #[test]
    fn block_topk_reset_forces_cold_start() {
        use crate::numeric::CMat;
        let mut rng = Pcg64::seeded(57);
        let a = CMat::random_normal(7, 7, &mut rng);
        let mut scratch = TopKScratch::new();
        let mut out = vec![0.0f64; 2];
        let first =
            block_topk(&a.data, 7, 7, 2, TopKOptions::default(), &mut scratch, &mut out).effort;
        scratch.reset();
        assert!(!scratch.is_warm());
        let again =
            block_topk(&a.data, 7, 7, 2, TopKOptions::default(), &mut scratch, &mut out).effort;
        assert_eq!(first, again, "cold starts are deterministic");
    }

    #[test]
    fn f32_topk_tracks_f64_and_refines_to_1e12() {
        use crate::numeric::CMat;
        let mut rng = Pcg64::seeded(58);
        for &(rows, cols, k) in &[(8usize, 8usize, 3usize), (10, 6, 2), (6, 10, 2)] {
            let a = CMat::random_normal(rows, cols, &mut rng);
            let mut s64 = TopKScratch::new();
            let mut want = vec![0.0f64; k];
            block_topk(&a.data, rows, cols, k, TopKOptions::default(), &mut s64, &mut want);
            let a32: CMat<f32> = a.convert();
            let mut s32 = TopKScratch::<f32>::new();
            let mut got32 = vec![0.0f32; k];
            block_topk(&a32.data, rows, cols, k, TopKOptions::default(), &mut s32, &mut got32);
            let scale = want[0].max(1.0);
            for (x, y) in want.iter().zip(&got32) {
                assert!(
                    (x - *y as f64).abs() <= 1e-3 * scale,
                    "{rows}x{cols} k={k}: f64 {x} vs f32 {y}"
                );
            }
            // Refinement against the exact block recovers f64 accuracy.
            let mut vtmp = vec![C64::ZERO; cols];
            let mut refined = vec![0.0f64; k];
            refine_topk_values(&a.data, rows, cols, &s32, k, &mut vtmp, &mut refined);
            for (x, y) in want.iter().zip(&refined) {
                assert!(
                    (x - y).abs() <= 1e-9 * scale,
                    "{rows}x{cols} k={k}: refined {y} vs f64 {x}"
                );
            }
        }
    }
}
