//! Power iteration for the spectral norm (largest singular value).
//!
//! The Yoshida–Miyato baseline (§II-b of the paper): approximate only σ_max,
//! either on the *true* convolution operator (via `LinOp`) or on the loose
//! reshaped `c_out × c_in·k²` matrix. Used as a comparison point for the
//! full-spectrum methods.

use crate::numeric::{Mat, Pcg64};

/// A real linear operator `A : R^in → R^out` exposing the two matvecs the
/// power method needs. Implemented by dense matrices and by the convolution
/// operator (`conv::apply`) without ever materializing the unrolled matrix.
pub trait LinOp {
    fn in_dim(&self) -> usize;
    fn out_dim(&self) -> usize;
    /// `y = A x`
    fn apply(&self, x: &[f64]) -> Vec<f64>;
    /// `y = Aᵀ x`
    fn apply_t(&self, x: &[f64]) -> Vec<f64>;
}

impl LinOp for Mat {
    fn in_dim(&self) -> usize {
        self.cols
    }
    fn out_dim(&self) -> usize {
        self.rows
    }
    fn apply(&self, x: &[f64]) -> Vec<f64> {
        self.matvec(x)
    }
    fn apply_t(&self, x: &[f64]) -> Vec<f64> {
        self.matvec_t(x)
    }
}

/// Outcome of [`spectral_norm`].
pub struct PowerResult {
    /// Estimated largest singular value.
    pub sigma_max: f64,
    /// Iterations actually run.
    pub iterations: usize,
    /// Final relative change — convergence indicator.
    pub residual: f64,
}

/// Estimate `σ_max(A)` by power iteration on `AᵀA`.
pub fn spectral_norm<O: LinOp>(op: &O, max_iters: usize, tol: f64, rng: &mut Pcg64) -> PowerResult {
    let n = op.in_dim();
    let mut x = rng.normal_vec(n);
    normalize(&mut x);
    let mut sigma = 0.0f64;
    let mut last = f64::INFINITY;
    let mut iters = 0;
    let mut residual = f64::INFINITY;
    while iters < max_iters {
        iters += 1;
        let y = op.apply(&x);
        sigma = norm(&y);
        if sigma == 0.0 {
            return PowerResult { sigma_max: 0.0, iterations: iters, residual: 0.0 };
        }
        x = op.apply_t(&y);
        normalize(&mut x);
        residual = ((sigma - last) / sigma).abs();
        if residual < tol {
            break;
        }
        last = sigma;
    }
    PowerResult { sigma_max: sigma, iterations: iters, residual }
}

fn norm(x: &[f64]) -> f64 {
    x.iter().map(|v| v * v).sum::<f64>().sqrt()
}

fn normalize(x: &mut [f64]) {
    let n = norm(x);
    if n > 0.0 {
        for v in x.iter_mut() {
            *v /= n;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gk_svd;

    #[test]
    fn matches_svd_on_dense() {
        let mut rng = Pcg64::seeded(51);
        let a = Mat::random_normal(12, 8, &mut rng);
        let want = gk_svd::singular_values(&a)[0];
        let got = spectral_norm(&a, 500, 1e-12, &mut rng);
        assert!(
            (got.sigma_max - want).abs() / want < 1e-8,
            "power {} vs svd {want}",
            got.sigma_max
        );
    }

    #[test]
    fn exact_on_diagonal() {
        let a = Mat::from_rows(&[&[3.0, 0.0], &[0.0, 9.0]]);
        let mut rng = Pcg64::seeded(52);
        let got = spectral_norm(&a, 200, 1e-14, &mut rng);
        assert!((got.sigma_max - 9.0).abs() < 1e-10);
    }

    #[test]
    fn zero_operator() {
        let a = Mat::zeros(4, 4);
        let mut rng = Pcg64::seeded(53);
        let got = spectral_norm(&a, 100, 1e-10, &mut rng);
        assert_eq!(got.sigma_max, 0.0);
    }

    #[test]
    fn converges_within_budget() {
        let mut rng = Pcg64::seeded(54);
        let a = Mat::random_normal(20, 20, &mut rng);
        let got = spectral_norm(&a, 2000, 1e-10, &mut rng);
        assert!(got.residual < 1e-10, "residual {}", got.residual);
    }
}
