//! One-sided Jacobi SVD for small complex matrices.
//!
//! This is the per-frequency hot path of the LFA pipeline: each symbol
//! `A_k ∈ C^{c_out×c_in}` is decomposed independently (`n·m` of them per
//! layer). One-sided Jacobi is ideal for this regime — small blocks, high
//! accuracy, trivially vectorizable/parallelizable across blocks, no
//! Householder bookkeeping.

use crate::numeric::{C64, CMat};

/// Full SVD of a complex block: `A = U · diag(s) · Vᴴ`.
pub struct CSvd {
    /// `m×r` left singular vectors, `r = min(m, n)`.
    pub u: CMat,
    /// Singular values, descending.
    pub s: Vec<f64>,
    /// `n×r` right singular vectors (not transposed).
    pub v: CMat,
}

const MAX_SWEEPS: usize = 40;
const TOL: f64 = 1e-12;

/// Reusable scratch for [`singular_values_into`]: the row-form work matrix
/// and the incremental Gram-diagonal buffer. Owned per worker by the
/// [`crate::engine`] workspaces so the per-frequency hot loop of a
/// [`crate::engine::SpectralPlan`] performs **zero heap allocation**.
#[derive(Default)]
pub struct JacobiScratch {
    b: Vec<C64>,
    norms: Vec<f64>,
}

impl JacobiScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Pre-size for `rows×cols` blocks so the first solve does not allocate.
    pub fn reserve(&mut self, rows: usize, cols: usize) {
        self.b.resize(rows * cols, C64::ZERO);
        self.norms.resize(rows.min(cols), 0.0);
    }
}

/// Singular values (descending) of a complex matrix via one-sided Jacobi.
///
/// Orthogonalizes the columns of a working copy; the column norms at
/// convergence are the singular values. Handles `m < n` by transposing.
///
/// PERF: internally the work matrix is `B = Aᴴ` stored row-major, so every
/// "column rotation" touches two *contiguous* rows — no strided access and
/// no per-element layout dispatch in the hot loop. Blocks this small are
/// cache-resident either way, so the measured gain is modest (~2% at c=16,
/// larger for c ≥ 64); see EXPERIMENTS.md §Perf.
pub fn singular_values(a: &CMat) -> Vec<f64> {
    if a.rows < a.cols {
        return singular_values(&a.hermitian());
    }
    // rows of B = conjugated columns of A.
    let (mut b, n, m) = to_row_form(a);
    jacobi_rows(&mut b, n, m, None);
    let mut s: Vec<f64> = (0..n).map(|j| row_norm(&b[j * m..(j + 1) * m])).collect();
    s.sort_unstable_by(|a, b| b.partial_cmp(a).unwrap());
    s
}

/// Allocation-free variant of [`singular_values`] on a raw row-major block.
///
/// `a` is `rows×cols` row-major; the `min(rows, cols)` descending singular
/// values are written into `out`. After `scratch` has seen a block of this
/// shape once, the call performs no heap allocation — this is the
/// per-frequency hot path of the planned LFA pipeline.
pub fn singular_values_into(
    a: &[C64],
    rows: usize,
    cols: usize,
    scratch: &mut JacobiScratch,
    out: &mut [f64],
) {
    debug_assert_eq!(a.len(), rows * cols);
    let r = rows.min(cols);
    debug_assert_eq!(out.len(), r);
    // Row-form work matrix: `nvec` vectors of length `vlen`. For a tall (or
    // square) block the vectors are the conjugated columns of A (B = Aᴴ);
    // for a wide block the rows of A already are the conjugated columns of
    // Aᴴ, so B = A verbatim — no recursion, no transpose copy.
    let (nvec, vlen) = if rows >= cols { (cols, rows) } else { (rows, cols) };
    scratch.b.resize(nvec * vlen, C64::ZERO);
    scratch.norms.resize(nvec, 0.0);
    if rows >= cols {
        for j in 0..cols {
            for i in 0..rows {
                scratch.b[j * vlen + i] = a[i * cols + j].conj();
            }
        }
    } else {
        scratch.b.copy_from_slice(a);
    }
    jacobi_rows_with(&mut scratch.b, nvec, vlen, None, &mut scratch.norms);
    for (j, o) in out.iter_mut().enumerate() {
        *o = row_norm(&scratch.b[j * vlen..(j + 1) * vlen]);
    }
    out.sort_unstable_by(|x, y| y.partial_cmp(x).unwrap());
}

/// Flatten `Aᴴ` (n×m, row-major): row j = conj of column j of A.
fn to_row_form(a: &CMat) -> (Vec<C64>, usize, usize) {
    let (m, n) = (a.rows, a.cols);
    let mut b = vec![C64::ZERO; n * m];
    for j in 0..n {
        for i in 0..m {
            b[j * m + i] = a[(i, j)].conj();
        }
    }
    (b, n, m)
}

#[inline]
fn row_norm(row: &[C64]) -> f64 {
    row.iter().map(|z| z.norm_sqr()).sum::<f64>().sqrt()
}

/// Full SVD via one-sided Jacobi (with V accumulation + U normalization).
pub fn svd(a: &CMat) -> CSvd {
    if a.rows < a.cols {
        // A = U Σ Vᴴ  ⇔  Aᴴ = V Σ Uᴴ
        let r = svd(&a.hermitian());
        return CSvd { u: r.v, s: r.s, v: r.u };
    }
    let (m, n) = (a.rows, a.cols);
    let (mut b, _, _) = to_row_form(a);
    // V carried in row form as well (row j = conj of V's column j).
    let mut vrows = vec![C64::ZERO; n * n];
    for j in 0..n {
        vrows[j * n + j] = C64::ONE;
    }
    jacobi_rows(&mut b, n, m, Some(&mut vrows));

    // Row norms of B = column norms of A = singular values; sort descending.
    let mut idx: Vec<usize> = (0..n).collect();
    let norms: Vec<f64> = (0..n).map(|j| row_norm(&b[j * m..(j + 1) * m])).collect();
    idx.sort_by(|&i, &j| norms[j].partial_cmp(&norms[i]).unwrap());

    let r = n.min(m);
    let mut u = CMat::zeros(m, r);
    let mut vs = CMat::zeros(n, r);
    let mut s = Vec::with_capacity(r);
    let scale_floor = norms.iter().cloned().fold(0.0f64, f64::max) * 1e-300;
    for (out_j, &j) in idx.iter().take(r).enumerate() {
        let sigma = norms[j];
        s.push(sigma);
        if sigma > scale_floor && sigma > 0.0 {
            let inv = 1.0 / sigma;
            for i in 0..m {
                u[(i, out_j)] = b[j * m + i].conj().scale(inv);
            }
        } else {
            // Null column: produce any unit vector orthogonal to the previous
            // ones via Gram–Schmidt over the standard basis.
            'basis: for basis in 0..m {
                let mut cand = vec![C64::ZERO; m];
                cand[basis] = C64::ONE;
                for p in 0..out_j {
                    let mut dot = C64::ZERO;
                    for i in 0..m {
                        dot = dot.mul_add(u[(i, p)].conj(), cand[i]);
                    }
                    for i in 0..m {
                        cand[i] -= u[(i, p)] * dot;
                    }
                }
                let nrm = cand.iter().map(|z| z.norm_sqr()).sum::<f64>().sqrt();
                if nrm > 0.5 {
                    let inv = 1.0 / nrm;
                    for i in 0..m {
                        u[(i, out_j)] = cand[i].scale(inv);
                    }
                    break 'basis;
                }
            }
        }
        for i in 0..n {
            vs[(i, out_j)] = vrows[j * n + i].conj();
        }
    }
    CSvd { u, s, v: vs }
}

/// Cyclic one-sided Jacobi sweeps on the **row form** `B = Aᴴ`
/// (`n` rows of length `m`, flat row-major): orthogonalizes the rows of
/// `B` (⇔ the columns of `A`) in place. If `vrows` is given (`n×n`, same
/// convention: row j = conj of V's column j), accumulates the rotations.
///
/// Row pair `(p, q)` updates, with `apq = Σ_i B[p,i]·conj(B[q,i])`
/// (= A_pᴴA_q) and `φ = arg(apq)`:
///
/// ```text
///   B_p ← c·B_p − s·e^{+iφ}·B_q
///   B_q ← s·e^{−iφ}·B_p + c·B_q
/// ```
fn jacobi_rows(b: &mut [C64], n: usize, m: usize, vrows: Option<&mut [C64]>) {
    let mut norms = vec![0.0f64; n];
    jacobi_rows_with(b, n, m, vrows, &mut norms);
}

/// [`jacobi_rows`] with a caller-provided norms buffer (`n` long) so the
/// planned hot path stays allocation-free.
fn jacobi_rows_with(
    b: &mut [C64],
    n: usize,
    m: usize,
    mut vrows: Option<&mut [C64]>,
    norms: &mut [f64],
) {
    if n < 2 {
        return;
    }
    debug_assert_eq!(b.len(), n * m);
    debug_assert_eq!(norms.len(), n);
    // PERF: row norms (the Gram diagonal) are tracked incrementally via the
    // Rutishauser update (app ← app − t·|apq|, aqq ← aqq + t·|apq|) instead
    // of being re-accumulated for every pair — drops ~40% of the per-pair
    // dot work. Refreshed exactly at each sweep start to stop FP drift.
    for _sweep in 0..MAX_SWEEPS {
        for (j, nj) in norms.iter_mut().enumerate() {
            *nj = b[j * m..(j + 1) * m].iter().map(|z| z.norm_sqr()).sum();
        }
        let mut off = 0.0f64;
        for p in 0..n - 1 {
            for q in p + 1..n {
                // Split-borrow the two contiguous rows.
                let (head, tail) = b.split_at_mut(q * m);
                let row_p = &mut head[p * m..p * m + m];
                let row_q = &mut tail[..m];
                let app = norms[p];
                let aqq = norms[q];
                // Four independent accumulators: a single running product
                // is FMA-latency-bound (measured 25% slower end-to-end).
                let mut acc = [C64::ZERO; 4];
                let chunks_p = row_p.chunks_exact(4);
                let chunks_q = row_q.chunks_exact(4);
                let rem_p = chunks_p.remainder();
                let rem_q = chunks_q.remainder();
                for (cp, cq) in chunks_p.zip(chunks_q) {
                    for l in 0..4 {
                        acc[l] = acc[l].mul_add(cp[l], cq[l].conj());
                    }
                }
                let mut apq = acc[0] + acc[1] + acc[2] + acc[3];
                for (bp, bq) in rem_p.iter().zip(rem_q.iter()) {
                    apq = apq.mul_add(*bp, bq.conj());
                }
                let denom = (app * aqq).sqrt();
                if denom == 0.0 {
                    continue;
                }
                let rel = apq.abs() / denom;
                off = off.max(rel);
                if rel <= TOL {
                    continue;
                }
                let r = apq.abs();
                let phase = apq.scale(1.0 / r); // e^{iφ}
                let tau = (aqq - app) / (2.0 * r);
                let t = if tau >= 0.0 {
                    1.0 / (tau + (1.0 + tau * tau).sqrt())
                } else {
                    -1.0 / (-tau + (1.0 + tau * tau).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                let sp = phase.scale(s); // s·e^{+iφ}
                let sm = phase.conj().scale(s); // s·e^{−iφ}
                for (bp, bq) in row_p.iter_mut().zip(row_q.iter_mut()) {
                    let old_p = *bp;
                    let old_q = *bq;
                    *bp = old_p.scale(c) - sp * old_q;
                    *bq = sm * old_p + old_q.scale(c);
                }
                // Rutishauser diagonal update (exact for the 2x2 rotation).
                norms[p] = app - t * r;
                norms[q] = aqq + t * r;
                if let Some(v) = vrows.as_deref_mut() {
                    let (vh, vt) = v.split_at_mut(q * n);
                    let vrow_p = &mut vh[p * n..p * n + n];
                    let vrow_q = &mut vt[..n];
                    for (vp, vq) in vrow_p.iter_mut().zip(vrow_q.iter_mut()) {
                        let old_p = *vp;
                        let old_q = *vq;
                        *vp = old_p.scale(c) - sp * old_q;
                        *vq = sm * old_p + old_q.scale(c);
                    }
                }
            }
        }
        if off <= TOL {
            return;
        }
    }
    // MAX_SWEEPS exceeded: tolerate — rows are orthogonal to ~sqrt(eps),
    // which is still far below the verification thresholds used by callers.
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numeric::{c64, Pcg64};

    fn reconstruct(r: &CSvd) -> CMat {
        let mut us = CMat::zeros(r.u.rows, r.s.len());
        for i in 0..r.u.rows {
            for j in 0..r.s.len() {
                us[(i, j)] = r.u[(i, j)].scale(r.s[j]);
            }
        }
        us.matmul(&r.v.hermitian())
    }

    #[test]
    fn real_diagonal() {
        let mut a = CMat::zeros(3, 3);
        a[(0, 0)] = c64(2.0, 0.0);
        a[(1, 1)] = c64(-5.0, 0.0);
        a[(2, 2)] = c64(1.0, 0.0);
        let s = singular_values(&a);
        assert!((s[0] - 5.0).abs() < 1e-12);
        assert!((s[1] - 2.0).abs() < 1e-12);
        assert!((s[2] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn unitary_has_unit_singular_values() {
        // DFT matrix scaled to unitary.
        let n = 5;
        let mut a = CMat::zeros(n, n);
        let scale = 1.0 / (n as f64).sqrt();
        for r in 0..n {
            for c in 0..n {
                let theta = -2.0 * std::f64::consts::PI * (r * c) as f64 / n as f64;
                a[(r, c)] = C64::cis(theta).scale(scale);
            }
        }
        for s in singular_values(&a) {
            assert!((s - 1.0).abs() < 1e-12, "σ = {s}");
        }
    }

    #[test]
    fn reconstruction_random_complex() {
        let mut rng = Pcg64::seeded(31);
        for &(m, n) in &[(4usize, 4usize), (6, 3), (3, 6), (8, 8), (1, 1), (5, 2)] {
            let a = CMat::random_normal(m, n, &mut rng);
            let r = svd(&a);
            let recon = reconstruct(&r);
            let err = recon.max_abs_diff(&a);
            assert!(err < 1e-10, "{m}x{n}: err {err}");
            assert!(r.u.orthonormality_defect() < 1e-10, "{m}x{n} U defect");
            assert!(r.v.orthonormality_defect() < 1e-10, "{m}x{n} V defect");
            for w in r.s.windows(2) {
                assert!(w[0] >= w[1]);
            }
        }
    }

    #[test]
    fn agrees_with_gk_on_real_input() {
        use crate::linalg::gk_svd;
        use crate::numeric::Mat;
        let mut rng = Pcg64::seeded(32);
        let a = Mat::random_normal(7, 5, &mut rng);
        let s_gk = gk_svd::singular_values(&a);
        let s_j = singular_values(&CMat::from_real(&a));
        for (x, y) in s_gk.iter().zip(&s_j) {
            assert!((x - y).abs() < 1e-9, "gk {x} vs jacobi {y}");
        }
    }

    #[test]
    fn rank_deficient_block() {
        // Two proportional columns (complex factor).
        let mut a = CMat::zeros(3, 2);
        for i in 0..3 {
            let base = c64(i as f64 + 1.0, -(i as f64));
            a[(i, 0)] = base;
            a[(i, 1)] = base * c64(0.0, 2.0); // 2i · col0
        }
        let r = svd(&a);
        assert!(r.s[1].abs() < 1e-10, "second σ should vanish: {:?}", r.s);
        let recon = reconstruct(&r);
        assert!(recon.max_abs_diff(&a) < 1e-10);
        assert!(r.u.orthonormality_defect() < 1e-9);
    }

    #[test]
    fn zero_matrix() {
        let a = CMat::zeros(4, 2);
        let r = svd(&a);
        assert!(r.s.iter().all(|&s| s == 0.0));
        assert!(r.u.orthonormality_defect() < 1e-12);
    }

    #[test]
    fn frobenius_identity() {
        let mut rng = Pcg64::seeded(33);
        let a = CMat::random_normal(6, 6, &mut rng);
        let s = singular_values(&a);
        let fro2: f64 = s.iter().map(|x| x * x).sum();
        assert!((fro2 - a.frobenius_norm().powi(2)).abs() < 1e-8);
    }

    #[test]
    fn scratch_path_matches_allocating_path() {
        let mut rng = Pcg64::seeded(34);
        let mut ws = JacobiScratch::new();
        for &(m, n) in &[(4usize, 4usize), (6, 3), (3, 6), (1, 5), (5, 1), (8, 8)] {
            let a = CMat::random_normal(m, n, &mut rng);
            let want = singular_values(&a);
            let mut got = vec![0.0f64; m.min(n)];
            // CMat::random_normal is row-major, so `data` is the raw block.
            singular_values_into(&a.data, m, n, &mut ws, &mut got);
            for (x, y) in want.iter().zip(&got) {
                assert!((x - y).abs() < 1e-12, "{m}x{n}: {x} vs {y}");
            }
        }
    }
}
