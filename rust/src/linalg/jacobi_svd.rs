//! One-sided Jacobi SVD for small complex matrices.
//!
//! This is the per-frequency hot path of the LFA pipeline: each symbol
//! `A_k ∈ C^{c_out×c_in}` is decomposed independently (`n·m` of them per
//! layer). One-sided Jacobi is ideal for this regime — small blocks, high
//! accuracy, trivially vectorizable/parallelizable across blocks, no
//! Householder bookkeeping.
//!
//! Everything here is generic over the [`Real`] scalar width: `f64` is the
//! default tier, `f32` the SIMD half-width tier, and
//! [`singular_values_refined_into`] is the mixed-precision bridge — an f32
//! sweep whose accumulated rotations warm-start a short f64 cleanup sweep,
//! recovering the full ≤1e-12 guarantee at a fraction of the f64 cost.
//! The inner conjugate-dot and paired-row rotation run through the
//! [`SimdReal`] kernels (AVX2 when available, bit-identical portable
//! fallback otherwise).

use crate::linalg::SolveCert;
use crate::numeric::{C, C32, C64, CMat, Real, SimdReal};
use crate::testing::chaos;

/// Full SVD of a complex block: `A = U · diag(s) · Vᴴ`.
pub struct CSvd<T = f64> {
    /// `m×r` left singular vectors, `r = min(m, n)`.
    pub u: CMat<T>,
    /// Singular values, descending.
    pub s: Vec<T>,
    /// `n×r` right singular vectors (not transposed).
    pub v: CMat<T>,
    /// Convergence certificate of the sweep that produced this
    /// decomposition (sweeps used, final relative off-diagonal).
    pub cert: SolveCert,
}

const MAX_SWEEPS: usize = 40;

/// Reusable scratch for [`singular_values_into`]: the row-form work matrix
/// and the incremental Gram-diagonal buffer. Owned per worker by the
/// [`crate::engine`] workspaces so the per-frequency hot loop of a
/// [`crate::engine::SpectralPlan`] performs **zero heap allocation**.
#[derive(Default)]
pub struct JacobiScratch<T = f64> {
    b: Vec<C<T>>,
    norms: Vec<T>,
}

impl<T: Real> JacobiScratch<T> {
    pub fn new() -> Self {
        Self { b: Vec::new(), norms: Vec::new() }
    }

    /// Pre-size for `rows×cols` blocks so the first solve does not allocate.
    pub fn reserve(&mut self, rows: usize, cols: usize) {
        self.b.resize(rows * cols, C::ZERO);
        self.norms.resize(rows.min(cols), T::ZERO);
    }
}

/// Scratch for the mixed-precision refined solve
/// ([`singular_values_refined_into`]): the f32 sweep state, the widened
/// rotation accumulator, and the f64 cleanup work matrix.
#[derive(Default)]
pub struct RefineScratch {
    /// f32 row-form work matrix + norms.
    b32: Vec<C32>,
    norms32: Vec<f32>,
    /// Accumulated f32 rotations, row form (`nvec×nvec`).
    v32: Vec<C32>,
    /// The widened, re-orthonormalized rotation basis (`nvec×nvec`).
    v: Vec<C64>,
    /// Exact f64 row form of the input block.
    b0: Vec<C64>,
    /// f64 cleanup work matrix (`V64 · B0`) + norms.
    b: Vec<C64>,
    norms: Vec<f64>,
}

impl RefineScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Pre-size for `rows×cols` blocks so the first solve does not allocate.
    pub fn reserve(&mut self, rows: usize, cols: usize) {
        let nvec = rows.min(cols);
        let vlen = rows.max(cols);
        self.b32.resize(nvec * vlen, C::ZERO);
        self.norms32.resize(nvec, 0.0);
        self.v32.resize(nvec * nvec, C::ZERO);
        self.v.resize(nvec * nvec, C::ZERO);
        self.b0.resize(nvec * vlen, C::ZERO);
        self.b.resize(nvec * vlen, C::ZERO);
        self.norms.resize(nvec, 0.0);
    }
}

/// Singular values (descending) of a complex matrix via one-sided Jacobi.
///
/// Orthogonalizes the columns of a working copy; the column norms at
/// convergence are the singular values. Handles `m < n` by transposing.
///
/// PERF: internally the work matrix is `B = Aᴴ` stored row-major, so every
/// "column rotation" touches two *contiguous* rows — no strided access and
/// no per-element layout dispatch in the hot loop. Blocks this small are
/// cache-resident either way, so the measured gain is modest (~2% at c=16,
/// larger for c ≥ 64); see EXPERIMENTS.md §Perf.
pub fn singular_values<T: SimdReal>(a: &CMat<T>) -> Vec<T> {
    if a.rows < a.cols {
        return singular_values(&a.hermitian());
    }
    // rows of B = conjugated columns of A.
    let (mut b, n, m) = to_row_form(a);
    jacobi_rows(&mut b, n, m, None);
    let mut s: Vec<T> = (0..n).map(|j| row_norm(&b[j * m..(j + 1) * m])).collect();
    s.sort_unstable_by(|a, b| b.partial_cmp(a).unwrap());
    s
}

/// Allocation-free variant of [`singular_values`] on a raw row-major block.
///
/// `a` is `rows×cols` row-major; the `min(rows, cols)` descending singular
/// values are written into `out`. After `scratch` has seen a block of this
/// shape once, the call performs no heap allocation — this is the
/// per-frequency hot path of the planned LFA pipeline.
pub fn singular_values_into<T: SimdReal>(
    a: &[C<T>],
    rows: usize,
    cols: usize,
    scratch: &mut JacobiScratch<T>,
    out: &mut [T],
) -> SolveCert {
    debug_assert_eq!(a.len(), rows * cols);
    let r = rows.min(cols);
    debug_assert_eq!(out.len(), r);
    // Row-form work matrix: `nvec` vectors of length `vlen`. For a tall (or
    // square) block the vectors are the conjugated columns of A (B = Aᴴ);
    // for a wide block the rows of A already are the conjugated columns of
    // Aᴴ, so B = A verbatim — no recursion, no transpose copy.
    let (nvec, vlen) = if rows >= cols { (cols, rows) } else { (rows, cols) };
    scratch.b.resize(nvec * vlen, C::ZERO);
    scratch.norms.resize(nvec, T::ZERO);
    row_form_into(a, rows, cols, &mut scratch.b);
    let mut cert = jacobi_rows_with(&mut scratch.b, nvec, vlen, None, &mut scratch.norms);
    if !cert.converged {
        // Fresh-restart retry: the iterate is already nearly orthogonal, so
        // one more full sweep budget from here usually finishes the job.
        // Only if this *also* exhausts does the caller see `converged: false`.
        let retry = jacobi_rows_with(&mut scratch.b, nvec, vlen, None, &mut scratch.norms);
        cert = cert.after_restart(retry);
    }
    for (j, o) in out.iter_mut().enumerate() {
        *o = row_norm(&scratch.b[j * vlen..(j + 1) * vlen]);
    }
    out.sort_unstable_by(|x, y| y.partial_cmp(x).unwrap());
    cert
}

/// Mixed-precision solve with the full f64 guarantee
/// ([`crate::lfa::Precision::F32Refined`]): run the one-sided sweep in f32
/// with rotation accumulation, re-orthonormalize the widened basis in f64
/// (modified Gram–Schmidt over the rows of `V32↑f64` — the accumulated
/// rotations are only f32-unitary, and replaying through a non-unitary
/// basis would bake an f32-scale error into the spectrum that no exact
/// sweep can remove), then replay it against the **exact** f64 block
/// (`B_start = V · B0`, whose rows are orthogonal to f32 round-off
/// already) and let one or two quadratic f64 sweeps polish it to ≤1e-12.
/// The MGS pass is `O(nvec³)` — cheap next to the `O(sweeps·nvec²·vlen)`
/// it replaces in f64. Allocation-free once `scratch` has seen the shape.
pub fn singular_values_refined_into(
    a: &[C64],
    rows: usize,
    cols: usize,
    scratch: &mut RefineScratch,
    out: &mut [f64],
) -> SolveCert {
    debug_assert_eq!(a.len(), rows * cols);
    let r = rows.min(cols);
    debug_assert_eq!(out.len(), r);
    let (nvec, vlen) = if rows >= cols { (cols, rows) } else { (rows, cols) };
    scratch.reserve(rows, cols);
    // 1. Exact f64 row form, narrowed to the f32 work matrix.
    row_form_into(a, rows, cols, &mut scratch.b0);
    for (w, z) in scratch.b32.iter_mut().zip(&scratch.b0) {
        *w = z.to_c32();
    }
    // 2. f32 sweep, accumulating the rotations (V starts at identity).
    scratch.v32.iter_mut().for_each(|z| *z = C::ZERO);
    for j in 0..nvec {
        scratch.v32[j * nvec + j] = C::ONE;
    }
    let cert32 =
        jacobi_rows_with(&mut scratch.b32, nvec, vlen, Some(&mut scratch.v32), &mut scratch.norms32);
    // 3. Widen the basis and restore exact unitarity: modified Gram–Schmidt
    //    over the rows. V32 is near-unitary (‖VᴴV−I‖ ~ ε_f32), so MGS is
    //    stable here and each projection coefficient is O(ε_f32).
    for (w, z) in scratch.v.iter_mut().zip(&scratch.v32) {
        *w = z.to_c64();
    }
    for p in 0..nvec {
        let (head, rest) = scratch.v.split_at_mut(p * nvec);
        let rp = &mut rest[..nvec];
        for j in 0..p {
            let rj = &head[j * nvec..(j + 1) * nvec];
            let c = <f64 as SimdReal>::cdot_conj(rp, rj);
            <f64 as SimdReal>::caxpy(-c, rj, rp);
        }
        let nrm = row_norm(rp);
        if nrm > f64::TINY {
            let inv = nrm.recip();
            rp.iter_mut().for_each(|z| *z = z.scale(inv));
        }
    }
    // 4. Replay against the exact block: B_start[p,·] = Σ_j V[p,j]·B0[j,·].
    scratch.b.iter_mut().for_each(|z| *z = C::ZERO);
    for p in 0..nvec {
        let dst = p * vlen;
        for j in 0..nvec {
            let s = scratch.v[p * nvec + j];
            let src = &scratch.b0[j * vlen..(j + 1) * vlen];
            <f64 as SimdReal>::caxpy(s, src, &mut scratch.b[dst..dst + vlen]);
        }
    }
    // 5. Quadratic f64 cleanup (normally 1–2 sweeps). The f64 polish is
    //    what carries the ≤1e-12 guarantee, so its certificate (plus the
    //    f32 sweep effort) is the one reported; a stalled f32 sweep that
    //    the polish fully recovers is *not* a health event.
    let mut cert = jacobi_rows_with(&mut scratch.b, nvec, vlen, None, &mut scratch.norms);
    if !cert.converged {
        let retry = jacobi_rows_with(&mut scratch.b, nvec, vlen, None, &mut scratch.norms);
        cert = cert.after_restart(retry);
    }
    for (j, o) in out.iter_mut().enumerate() {
        *o = row_norm(&scratch.b[j * vlen..(j + 1) * vlen]);
    }
    out.sort_unstable_by(|x, y| y.partial_cmp(x).unwrap());
    SolveCert { effort: cert32.effort + cert.effort, ..cert }
}

/// Fill `b` (`min×max` row-major) with the row form of the `rows×cols`
/// block `a`: conjugated columns for a tall block, the rows verbatim for a
/// wide one.
fn row_form_into<T: Real>(a: &[C<T>], rows: usize, cols: usize, b: &mut [C<T>]) {
    if rows >= cols {
        let vlen = rows;
        for j in 0..cols {
            for i in 0..rows {
                b[j * vlen + i] = a[i * cols + j].conj();
            }
        }
    } else {
        b.copy_from_slice(a);
    }
}

/// Flatten `Aᴴ` (n×m, row-major): row j = conj of column j of A.
fn to_row_form<T: Real>(a: &CMat<T>) -> (Vec<C<T>>, usize, usize) {
    let (m, n) = (a.rows, a.cols);
    let mut b = vec![C::ZERO; n * m];
    for j in 0..n {
        for i in 0..m {
            b[j * m + i] = a[(i, j)].conj();
        }
    }
    (b, n, m)
}

#[inline]
fn row_norm<T: Real>(row: &[C<T>]) -> T {
    row.iter().map(|z| z.norm_sqr()).sum::<T>().sqrt()
}

/// Full SVD via one-sided Jacobi (with V accumulation + U normalization).
pub fn svd<T: SimdReal>(a: &CMat<T>) -> CSvd<T> {
    if a.rows < a.cols {
        // A = U Σ Vᴴ  ⇔  Aᴴ = V Σ Uᴴ
        let r = svd(&a.hermitian());
        return CSvd { u: r.v, s: r.s, v: r.u, cert: r.cert };
    }
    let (m, n) = (a.rows, a.cols);
    let (mut b, _, _) = to_row_form(a);
    // V carried in row form as well (row j = conj of V's column j).
    let mut vrows = vec![C::ZERO; n * n];
    for j in 0..n {
        vrows[j * n + j] = C::ONE;
    }
    let mut cert = jacobi_rows(&mut b, n, m, Some(&mut vrows));
    if !cert.converged {
        // Fresh-restart retry: resuming the sweep keeps accumulating the
        // (still-valid) rotations, so V stays consistent with B.
        let retry = jacobi_rows(&mut b, n, m, Some(&mut vrows));
        cert = cert.after_restart(retry);
    }

    // Row norms of B = column norms of A = singular values; sort descending.
    let mut idx: Vec<usize> = (0..n).collect();
    let norms: Vec<T> = (0..n).map(|j| row_norm(&b[j * m..(j + 1) * m])).collect();
    idx.sort_by(|&i, &j| norms[j].partial_cmp(&norms[i]).unwrap());

    let r = n.min(m);
    let mut u = CMat::zeros(m, r);
    let mut vs = CMat::zeros(n, r);
    let mut s = Vec::with_capacity(r);
    let scale_floor = norms.iter().cloned().fold(T::ZERO, T::max) * T::TINY;
    for (out_j, &j) in idx.iter().take(r).enumerate() {
        let sigma = norms[j];
        s.push(sigma);
        if sigma > scale_floor && sigma > T::ZERO {
            let inv = sigma.recip();
            for i in 0..m {
                u[(i, out_j)] = b[j * m + i].conj().scale(inv);
            }
        } else {
            // Null column: produce any unit vector orthogonal to the previous
            // ones via Gram–Schmidt over the standard basis.
            'basis: for basis in 0..m {
                let mut cand = vec![C::ZERO; m];
                cand[basis] = C::ONE;
                for p in 0..out_j {
                    let mut dot = C::ZERO;
                    for i in 0..m {
                        dot = dot.mul_add(u[(i, p)].conj(), cand[i]);
                    }
                    for i in 0..m {
                        cand[i] -= u[(i, p)] * dot;
                    }
                }
                let nrm = cand.iter().map(|z| z.norm_sqr()).sum::<T>().sqrt();
                if nrm > T::HALF {
                    let inv = nrm.recip();
                    for i in 0..m {
                        u[(i, out_j)] = cand[i].scale(inv);
                    }
                    break 'basis;
                }
            }
        }
        for i in 0..n {
            vs[(i, out_j)] = vrows[j * n + i].conj();
        }
    }
    CSvd { u, s, v: vs, cert }
}

/// Cyclic one-sided Jacobi sweeps on the **row form** `B = Aᴴ`
/// (`n` rows of length `m`, flat row-major): orthogonalizes the rows of
/// `B` (⇔ the columns of `A`) in place. If `vrows` is given (`n×n`, same
/// convention: row j = conj of V's column j), accumulates the rotations.
///
/// Row pair `(p, q)` updates, with `apq = Σ_i B[p,i]·conj(B[q,i])`
/// (= A_pᴴA_q) and `φ = arg(apq)`:
///
/// ```text
///   B_p ← c·B_p − s·e^{+iφ}·B_q
///   B_q ← s·e^{−iφ}·B_p + c·B_q
/// ```
fn jacobi_rows<T: SimdReal>(
    b: &mut [C<T>],
    n: usize,
    m: usize,
    vrows: Option<&mut [C<T>]>,
) -> SolveCert {
    let mut norms = vec![T::ZERO; n];
    jacobi_rows_with(b, n, m, vrows, &mut norms)
}

/// [`jacobi_rows`] with a caller-provided norms buffer (`n` long) so the
/// planned hot path stays allocation-free. Returns the convergence
/// certificate: sweeps used and the final relative off-diagonal.
fn jacobi_rows_with<T: SimdReal>(
    b: &mut [C<T>],
    n: usize,
    m: usize,
    mut vrows: Option<&mut [C<T>]>,
    norms: &mut [T],
) -> SolveCert {
    if n < 2 {
        return SolveCert::TRIVIAL;
    }
    debug_assert_eq!(b.len(), n * m);
    debug_assert_eq!(norms.len(), n);
    // Fault injection: report sweep exhaustion (values stay correct) so the
    // escalation ladder is exercisable without a pathological matrix.
    let stall = chaos::fire(chaos::SOLVER_STALL);
    let mut last_off = T::ZERO;
    // PERF: row norms (the Gram diagonal) are tracked incrementally via the
    // Rutishauser update (app ← app − t·|apq|, aqq ← aqq + t·|apq|) instead
    // of being re-accumulated for every pair — drops ~40% of the per-pair
    // dot work. Refreshed exactly at each sweep start to stop FP drift.
    for sweep in 0..MAX_SWEEPS {
        for (j, nj) in norms.iter_mut().enumerate() {
            *nj = b[j * m..(j + 1) * m].iter().map(|z| z.norm_sqr()).sum();
        }
        let mut off = T::ZERO;
        for p in 0..n - 1 {
            for q in p + 1..n {
                // Split-borrow the two contiguous rows.
                let (head, tail) = b.split_at_mut(q * m);
                let row_p = &mut head[p * m..p * m + m];
                let row_q = &mut tail[..m];
                let app = norms[p];
                let aqq = norms[q];
                // Lane-parallel conjugate dot (AVX2 or the bit-identical
                // portable emulation — see numeric::simd).
                let apq = T::cdot_conj(row_p, row_q);
                let denom = (app * aqq).sqrt();
                if denom == T::ZERO {
                    continue;
                }
                let rel = apq.abs() / denom;
                off = off.max(rel);
                if rel <= T::SVD_TOL {
                    continue;
                }
                let r = apq.abs();
                let phase = apq.scale(r.recip()); // e^{iφ}
                let tau = (aqq - app) / (T::TWO * r);
                let t = if tau >= T::ZERO {
                    (tau + (T::ONE + tau * tau).sqrt()).recip()
                } else {
                    -(-tau + (T::ONE + tau * tau).sqrt()).recip()
                };
                let c = (T::ONE + t * t).sqrt().recip();
                let s = c * t;
                let sp = phase.scale(s); // s·e^{+iφ}
                let sm = phase.conj().scale(s); // s·e^{−iφ}
                T::crot(row_p, row_q, c, sp, sm);
                // Rutishauser diagonal update (exact for the 2x2 rotation).
                norms[p] = app - t * r;
                norms[q] = aqq + t * r;
                if let Some(v) = vrows.as_deref_mut() {
                    let (vh, vt) = v.split_at_mut(q * n);
                    let vrow_p = &mut vh[p * n..p * n + n];
                    let vrow_q = &mut vt[..n];
                    T::crot(vrow_p, vrow_q, c, sp, sm);
                }
            }
        }
        if off <= T::SVD_TOL {
            return SolveCert {
                effort: sweep + 1,
                residual: off.to_f64(),
                converged: !stall,
                restarted: false,
            };
        }
        last_off = off;
    }
    // MAX_SWEEPS exceeded. The rows are still orthogonal to ~sqrt(eps), so
    // the values remain usable — but the caller now *knows*: callers retry
    // with a fresh sweep budget and ultimately flag the frequency degraded
    // instead of silently serving a best-effort spectrum.
    SolveCert {
        effort: MAX_SWEEPS,
        residual: last_off.to_f64(),
        converged: false,
        restarted: false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numeric::{c64, C64, Pcg64};

    fn reconstruct(r: &CSvd) -> CMat {
        let mut us = CMat::zeros(r.u.rows, r.s.len());
        for i in 0..r.u.rows {
            for j in 0..r.s.len() {
                us[(i, j)] = r.u[(i, j)].scale(r.s[j]);
            }
        }
        us.matmul(&r.v.hermitian())
    }

    #[test]
    fn real_diagonal() {
        let mut a = CMat::zeros(3, 3);
        a[(0, 0)] = c64(2.0, 0.0);
        a[(1, 1)] = c64(-5.0, 0.0);
        a[(2, 2)] = c64(1.0, 0.0);
        let s = singular_values(&a);
        assert!((s[0] - 5.0).abs() < 1e-12);
        assert!((s[1] - 2.0).abs() < 1e-12);
        assert!((s[2] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn unitary_has_unit_singular_values() {
        // DFT matrix scaled to unitary.
        let n = 5;
        let mut a = CMat::zeros(n, n);
        let scale = 1.0 / (n as f64).sqrt();
        for r in 0..n {
            for c in 0..n {
                let theta = -2.0 * std::f64::consts::PI * (r * c) as f64 / n as f64;
                a[(r, c)] = C64::cis(theta).scale(scale);
            }
        }
        for s in singular_values(&a) {
            assert!((s - 1.0).abs() < 1e-12, "σ = {s}");
        }
    }

    #[test]
    fn reconstruction_random_complex() {
        let mut rng = Pcg64::seeded(31);
        for &(m, n) in &[(4usize, 4usize), (6, 3), (3, 6), (8, 8), (1, 1), (5, 2)] {
            let a = CMat::random_normal(m, n, &mut rng);
            let r = svd(&a);
            let recon = reconstruct(&r);
            let err = recon.max_abs_diff(&a);
            assert!(err < 1e-10, "{m}x{n}: err {err}");
            assert!(r.u.orthonormality_defect() < 1e-10, "{m}x{n} U defect");
            assert!(r.v.orthonormality_defect() < 1e-10, "{m}x{n} V defect");
            for w in r.s.windows(2) {
                assert!(w[0] >= w[1]);
            }
        }
    }

    #[test]
    fn agrees_with_gk_on_real_input() {
        use crate::linalg::gk_svd;
        use crate::numeric::Mat;
        let mut rng = Pcg64::seeded(32);
        let a = Mat::random_normal(7, 5, &mut rng);
        let s_gk = gk_svd::singular_values(&a);
        let s_j = singular_values(&CMat::from_real(&a));
        for (x, y) in s_gk.iter().zip(&s_j) {
            assert!((x - y).abs() < 1e-9, "gk {x} vs jacobi {y}");
        }
    }

    #[test]
    fn rank_deficient_block() {
        // Two proportional columns (complex factor).
        let mut a = CMat::zeros(3, 2);
        for i in 0..3 {
            let base = c64(i as f64 + 1.0, -(i as f64));
            a[(i, 0)] = base;
            a[(i, 1)] = base * c64(0.0, 2.0); // 2i · col0
        }
        let r = svd(&a);
        assert!(r.s[1].abs() < 1e-10, "second σ should vanish: {:?}", r.s);
        let recon = reconstruct(&r);
        assert!(recon.max_abs_diff(&a) < 1e-10);
        assert!(r.u.orthonormality_defect() < 1e-9);
    }

    #[test]
    fn zero_matrix() {
        let a = CMat::zeros(4, 2);
        let r = svd(&a);
        assert!(r.s.iter().all(|&s| s == 0.0));
        assert!(r.u.orthonormality_defect() < 1e-12);
    }

    #[test]
    fn frobenius_identity() {
        let mut rng = Pcg64::seeded(33);
        let a = CMat::random_normal(6, 6, &mut rng);
        let s = singular_values(&a);
        let fro2: f64 = s.iter().map(|x| x * x).sum();
        assert!((fro2 - a.frobenius_norm().powi(2)).abs() < 1e-8);
    }

    #[test]
    fn scratch_path_matches_allocating_path() {
        let mut rng = Pcg64::seeded(34);
        let mut ws = JacobiScratch::new();
        for &(m, n) in &[(4usize, 4usize), (6, 3), (3, 6), (1, 5), (5, 1), (8, 8)] {
            let a = CMat::random_normal(m, n, &mut rng);
            let want = singular_values(&a);
            let mut got = vec![0.0f64; m.min(n)];
            // CMat::random_normal is row-major, so `data` is the raw block.
            singular_values_into(&a.data, m, n, &mut ws, &mut got);
            for (x, y) in want.iter().zip(&got) {
                assert!((x - y).abs() < 1e-12, "{m}x{n}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn f32_singular_values_track_f64() {
        let mut rng = Pcg64::seeded(35);
        let mut ws = JacobiScratch::<f32>::new();
        for &(m, n) in &[(4usize, 4usize), (6, 3), (3, 6), (8, 8), (16, 16)] {
            let a = CMat::random_normal(m, n, &mut rng);
            let want = singular_values(&a);
            let a32: CMat<f32> = a.convert();
            let mut got = vec![0.0f32; m.min(n)];
            singular_values_into(&a32.data, m, n, &mut ws, &mut got);
            let scale = want[0].max(1.0);
            for (x, y) in want.iter().zip(&got) {
                assert!(
                    (x - *y as f64).abs() <= 1e-4 * scale,
                    "{m}x{n}: f64 {x} vs f32 {y}"
                );
            }
        }
    }

    #[test]
    fn refined_matches_f64_to_1e12() {
        let mut rng = Pcg64::seeded(36);
        let mut ws = JacobiScratch::new();
        let mut rs = RefineScratch::new();
        for &(m, n) in &[(4usize, 4usize), (6, 3), (3, 6), (8, 8), (16, 16), (1, 1)] {
            let a = CMat::random_normal(m, n, &mut rng);
            let mut want = vec![0.0f64; m.min(n)];
            singular_values_into(&a.data, m, n, &mut ws, &mut want);
            let mut got = vec![0.0f64; m.min(n)];
            singular_values_refined_into(&a.data, m, n, &mut rs, &mut got);
            let scale = want[0].max(1.0);
            for (x, y) in want.iter().zip(&got) {
                assert!((x - y).abs() <= 1e-12 * scale, "{m}x{n}: {x} vs {y}");
            }
        }
    }
}
