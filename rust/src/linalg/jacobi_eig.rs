//! Two-sided Jacobi eigendecomposition for Hermitian matrices.
//!
//! The *Gram route* to singular values: `σ(A) = √λ(AᴴA)`. Forming the Gram
//! matrix squares the condition number, so the one-sided Jacobi SVD
//! (`jacobi_svd`) is the default in the LFA pipeline — this solver exists as
//! an ablation (`bench_ablation_svd`) and because the PJRT artifact uses the
//! same algorithm in pure-HLO form (where one-sidedness is awkward to batch).
//!
//! The allocation-free Gram path ([`singular_values_gram_into`]) is generic
//! over the [`Real`] width and runs its Gram formation and row rotations
//! through the [`SimdReal`] kernels: the tall case streams each row of `A`
//! once as a rank-1 `caxpy` update into the upper triangle (contiguous in
//! both operands), the wide case is a straight conjugate dot per pair.

use crate::linalg::SolveCert;
use crate::numeric::{C, CMat, Real, SimdReal};
use crate::testing::chaos;

const MAX_SWEEPS: usize = 40;
const TOL: f64 = 1e-15;

/// Eigendecomposition of a Hermitian matrix: `H = Q diag(λ) Qᴴ`,
/// eigenvalues descending.
pub struct HEig {
    pub lambda: Vec<f64>,
    pub q: CMat,
}

/// Eigenvalues (descending) of a Hermitian matrix.
pub fn eigenvalues(h: &CMat) -> Vec<f64> {
    decompose(h, false).0.lambda
}

/// Full Hermitian eigendecomposition via cyclic two-sided Jacobi rotations.
pub fn eigh(h: &CMat) -> HEig {
    decompose(h, true).0
}

/// [`eigh`] plus the convergence certificate of the sweep.
pub fn eigh_certified(h: &CMat) -> (HEig, SolveCert) {
    decompose(h, true)
}

fn decompose(h: &CMat, compute_q: bool) -> (HEig, SolveCert) {
    let n = h.rows;
    assert_eq!(h.rows, h.cols, "eigh requires a square matrix");
    debug_assert!(hermitian_defect(h) < 1e-10, "input must be Hermitian");
    let mut a = h.clone();
    let mut q = CMat::eye(n);

    let stall = chaos::fire(chaos::SOLVER_STALL);
    let mut cert =
        SolveCert { effort: MAX_SWEEPS, residual: 0.0, converged: false, restarted: false };
    for sweep in 0..MAX_SWEEPS {
        let mut off = 0.0f64;
        for p in 0..n.saturating_sub(1) {
            for qi in p + 1..n {
                let apq = a[(p, qi)];
                let mag = apq.abs();
                let scale = (a[(p, p)].re.abs() + a[(qi, qi)].re.abs()).max(1e-300);
                if mag / scale <= TOL {
                    continue;
                }
                off = off.max(mag / scale);
                // Phase-align then real Jacobi rotation.
                let phase = apq.scale(1.0 / mag); // e^{iφ}
                let app = a[(p, p)].re;
                let aqq = a[(qi, qi)].re;
                let tau = (aqq - app) / (2.0 * mag);
                let t = if tau >= 0.0 {
                    1.0 / (tau + (1.0 + tau * tau).sqrt())
                } else {
                    -1.0 / (-tau + (1.0 + tau * tau).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                // Unitary R: columns (p,q) mix with
                //   R = [[c, s·e^{iφ}], [−s·e^{−iφ}, c]]  (R acting on the right)
                let se_pos = phase.scale(s); // s·e^{iφ}
                let se_neg = phase.conj().scale(s); // s·e^{−iφ}
                // A ← Rᴴ A R : update columns then rows.
                for i in 0..n {
                    let aip = a[(i, p)];
                    let aiq = a[(i, qi)];
                    a[(i, p)] = aip.scale(c) - aiq * se_neg;
                    a[(i, qi)] = aip * se_pos + aiq.scale(c);
                }
                for j in 0..n {
                    let apj = a[(p, j)];
                    let aqj = a[(qi, j)];
                    // Rᴴ acting from the left: row_p ← c·row_p − s·e^{iφ}·row_q,
                    // row_q ← s·e^{−iφ}·row_p + c·row_q.
                    a[(p, j)] = apj.scale(c) - aqj * se_pos;
                    a[(qi, j)] = apj * se_neg + aqj.scale(c);
                }
                if compute_q {
                    for i in 0..n {
                        let qip = q[(i, p)];
                        let qiq = q[(i, qi)];
                        q[(i, p)] = qip.scale(c) - qiq * se_neg;
                        q[(i, qi)] = qip * se_pos + qiq.scale(c);
                    }
                }
            }
        }
        cert.residual = off;
        if off <= TOL {
            cert.effort = sweep + 1;
            cert.converged = !stall;
            break;
        }
    }

    let mut idx: Vec<usize> = (0..n).collect();
    let diag: Vec<f64> = (0..n).map(|i| a[(i, i)].re).collect();
    idx.sort_by(|&i, &j| diag[j].partial_cmp(&diag[i]).unwrap());
    let lambda = idx.iter().map(|&i| diag[i]).collect();
    let mut q_sorted = CMat::zeros(n, n);
    if compute_q {
        for (newj, &oldj) in idx.iter().enumerate() {
            for i in 0..n {
                q_sorted[(i, newj)] = q[(i, oldj)];
            }
        }
    }
    (HEig { lambda, q: q_sorted }, cert)
}

/// Singular values of `A` via eigenvalues of its Gram matrix.
pub fn singular_values_gram(a: &CMat) -> Vec<f64> {
    let g = if a.rows >= a.cols { a.gram() } else { a.hermitian().gram() };
    eigenvalues(&g).into_iter().map(|l| l.max(0.0).sqrt()).collect()
}

/// Reusable scratch for [`singular_values_gram_into`]: the Gram work matrix,
/// diagonalized in place. Owned per worker by the [`crate::engine`]
/// workspaces (Gram-route ablation of the planned pipeline).
#[derive(Default)]
pub struct GramScratch<T = f64> {
    g: Vec<C<T>>,
}

impl<T: Real> GramScratch<T> {
    pub fn new() -> Self {
        Self { g: Vec::new() }
    }

    /// Pre-size for `rows×cols` blocks so the first solve does not allocate.
    pub fn reserve(&mut self, rows: usize, cols: usize) {
        let k = rows.min(cols);
        self.g.resize(k * k, C::ZERO);
    }
}

/// Allocation-free Gram-route singular values on a raw row-major block.
///
/// `a` is `rows×cols` row-major; the `min(rows, cols)` descending singular
/// values are written into `out`. Forms the smaller of `AᴴA` / `AAᴴ` in the
/// scratch buffer and diagonalizes it in place; after `scratch` has seen a
/// block of this shape once, the call performs no heap allocation.
pub fn singular_values_gram_into<T: SimdReal>(
    a: &[C<T>],
    rows: usize,
    cols: usize,
    scratch: &mut GramScratch<T>,
    out: &mut [T],
) -> SolveCert {
    debug_assert_eq!(a.len(), rows * cols);
    let k = rows.min(cols);
    debug_assert_eq!(out.len(), k);
    scratch.g.resize(k * k, C::ZERO);
    let g = &mut scratch.g[..];
    if rows >= cols {
        // G = AᴴA (cols×cols), upper triangle only. Formed as a stream of
        // rank-1 row updates G[p, p..] += conj(A[i,p])·A[i, p..] — both
        // operands contiguous, so each update is one SIMD caxpy and every
        // row of A is read exactly once (cache-blocked by construction).
        g.iter_mut().for_each(|z| *z = C::ZERO);
        for i in 0..rows {
            let row = &a[i * cols..(i + 1) * cols];
            for p in 0..k {
                let s = row[p].conj();
                T::caxpy(s, &row[p..], &mut g[p * k + p..p * k + k]);
            }
        }
        for p in 0..k {
            for q in p + 1..k {
                g[q * k + p] = g[p * k + q].conj();
            }
        }
    } else {
        // G = AAᴴ (rows×rows): each entry is a conjugate dot of two
        // contiguous rows of A.
        for p in 0..k {
            for q in p..k {
                let acc = T::cdot_conj(&a[p * cols..(p + 1) * cols], &a[q * cols..(q + 1) * cols]);
                g[p * k + q] = acc;
                g[q * k + p] = acc.conj();
            }
        }
    }
    let mut cert = diagonalize_in_place(g, k);
    if !cert.converged {
        // Fresh-restart retry on the current (already nearly diagonal) Gram
        // iterate before reporting exhaustion to the escalation ladder.
        let retry = diagonalize_in_place(g, k);
        cert = cert.after_restart(retry);
    }
    for (j, o) in out.iter_mut().enumerate() {
        *o = g[j * k + j].re.max(T::ZERO).sqrt();
    }
    out.sort_unstable_by(|x, y| y.partial_cmp(x).unwrap());
    cert
}

/// Cyclic two-sided Jacobi sweeps on a flat row-major Hermitian `n×n`
/// matrix, eigenvalues left on the diagonal (unsorted). Identical rotation
/// schedule and formulas to [`eigh`], minus the eigenvector accumulation.
/// The paired-row update is the lane-parallel [`SimdReal::crot`] kernel;
/// the column update is strided and stays scalar.
fn diagonalize_in_place<T: SimdReal>(g: &mut [C<T>], n: usize) -> SolveCert {
    debug_assert_eq!(g.len(), n * n);
    let stall = chaos::fire(chaos::SOLVER_STALL);
    let mut last_off = T::ZERO;
    for sweep in 0..MAX_SWEEPS {
        let mut off = T::ZERO;
        for p in 0..n.saturating_sub(1) {
            for q in p + 1..n {
                let apq = g[p * n + q];
                let mag = apq.abs();
                let scale = (g[p * n + p].re.abs() + g[q * n + q].re.abs()).max(T::TINY);
                if mag / scale <= T::EIG_TOL {
                    continue;
                }
                off = off.max(mag / scale);
                let phase = apq.scale(mag.recip()); // e^{iφ}
                let app = g[p * n + p].re;
                let aqq = g[q * n + q].re;
                let tau = (aqq - app) / (T::TWO * mag);
                let t = if tau >= T::ZERO {
                    (tau + (T::ONE + tau * tau).sqrt()).recip()
                } else {
                    -(-tau + (T::ONE + tau * tau).sqrt()).recip()
                };
                let c = (T::ONE + t * t).sqrt().recip();
                let s = c * t;
                let se_pos = phase.scale(s); // s·e^{iφ}
                let se_neg = phase.conj().scale(s); // s·e^{−iφ}
                for i in 0..n {
                    let aip = g[i * n + p];
                    let aiq = g[i * n + q];
                    g[i * n + p] = aip.scale(c) - aiq * se_neg;
                    g[i * n + q] = aip * se_pos + aiq.scale(c);
                }
                // Rows p and q are contiguous: row_p ← c·row_p − se_pos·row_q,
                // row_q ← se_neg·row_p + c·row_q — exactly the crot kernel.
                let (head, tail) = g.split_at_mut(q * n);
                let row_p = &mut head[p * n..p * n + n];
                let row_q = &mut tail[..n];
                T::crot(row_p, row_q, c, se_pos, se_neg);
            }
        }
        if off <= T::EIG_TOL {
            return SolveCert {
                effort: sweep + 1,
                residual: off.to_f64(),
                converged: !stall,
                restarted: false,
            };
        }
        last_off = off;
    }
    SolveCert {
        effort: MAX_SWEEPS,
        residual: last_off.to_f64(),
        converged: false,
        restarted: false,
    }
}

fn hermitian_defect(h: &CMat) -> f64 {
    let mut worst = 0.0f64;
    for i in 0..h.rows {
        for j in 0..h.cols {
            worst = worst.max((h[(i, j)] - h[(j, i)].conj()).abs());
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numeric::{c64, Pcg64};

    fn random_hermitian(n: usize, rng: &mut Pcg64) -> CMat {
        let a = CMat::random_normal(n, n, rng);
        let mut h = CMat::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                h[(i, j)] = (a[(i, j)] + a[(j, i)].conj()).scale(0.5);
            }
        }
        h
    }

    #[test]
    fn real_diagonal() {
        let mut h = CMat::zeros(3, 3);
        h[(0, 0)] = c64(1.0, 0.0);
        h[(1, 1)] = c64(-2.0, 0.0);
        h[(2, 2)] = c64(5.0, 0.0);
        let l = eigenvalues(&h);
        assert!((l[0] - 5.0).abs() < 1e-12);
        assert!((l[1] - 1.0).abs() < 1e-12);
        assert!((l[2] + 2.0).abs() < 1e-12);
    }

    #[test]
    fn pauli_y_eigenvalues() {
        // σ_y = [[0, -i], [i, 0]] has eigenvalues ±1.
        let mut h = CMat::zeros(2, 2);
        h[(0, 1)] = c64(0.0, -1.0);
        h[(1, 0)] = c64(0.0, 1.0);
        let l = eigenvalues(&h);
        assert!((l[0] - 1.0).abs() < 1e-12);
        assert!((l[1] + 1.0).abs() < 1e-12);
    }

    #[test]
    fn decomposition_reconstructs() {
        let mut rng = Pcg64::seeded(41);
        for &n in &[2usize, 3, 5, 8] {
            let h = random_hermitian(n, &mut rng);
            let e = eigh(&h);
            // Q diag(λ) Qᴴ == H
            let mut ql = CMat::zeros(n, n);
            for i in 0..n {
                for j in 0..n {
                    ql[(i, j)] = e.q[(i, j)].scale(e.lambda[j]);
                }
            }
            let recon = ql.matmul(&e.q.hermitian());
            assert!(recon.max_abs_diff(&h) < 1e-9, "n={n}");
            assert!(e.q.orthonormality_defect() < 1e-10, "n={n}");
        }
    }

    #[test]
    fn trace_preserved() {
        let mut rng = Pcg64::seeded(42);
        let h = random_hermitian(6, &mut rng);
        let tr: f64 = (0..6).map(|i| h[(i, i)].re).sum();
        let l = eigenvalues(&h);
        assert!((l.iter().sum::<f64>() - tr).abs() < 1e-10);
    }

    #[test]
    fn gram_scratch_matches_allocating_path() {
        let mut rng = Pcg64::seeded(44);
        let mut ws = GramScratch::new();
        for &(m, n) in &[(5usize, 5usize), (7, 4), (4, 7), (1, 3), (3, 1)] {
            let a = CMat::random_normal(m, n, &mut rng);
            let want = singular_values_gram(&a);
            let mut got = vec![0.0f64; m.min(n)];
            singular_values_gram_into(&a.data, m, n, &mut ws, &mut got);
            for (x, y) in want.iter().take(got.len()).zip(&got) {
                assert!((x - y).abs() < 1e-8, "{m}x{n}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn gram_route_matches_one_sided() {
        use crate::linalg::jacobi_svd;
        let mut rng = Pcg64::seeded(43);
        for &(m, n) in &[(5usize, 5usize), (7, 4), (4, 7)] {
            let a = CMat::random_normal(m, n, &mut rng);
            let s1 = jacobi_svd::singular_values(&a);
            let s2 = singular_values_gram(&a);
            for (x, y) in s1.iter().zip(&s2) {
                assert!((x - y).abs() < 1e-8, "{m}x{n}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn f32_gram_route_tracks_f64() {
        let mut rng = Pcg64::seeded(45);
        let mut ws64 = GramScratch::new();
        let mut ws32 = GramScratch::<f32>::new();
        for &(m, n) in &[(5usize, 5usize), (8, 4), (4, 8)] {
            let a = CMat::random_normal(m, n, &mut rng);
            let k = m.min(n);
            let mut want = vec![0.0f64; k];
            singular_values_gram_into(&a.data, m, n, &mut ws64, &mut want);
            let a32: CMat<f32> = a.convert();
            let mut got = vec![0.0f32; k];
            singular_values_gram_into(&a32.data, m, n, &mut ws32, &mut got);
            // The Gram route squares the condition number, so the f32 tier
            // carries a looser bound than the one-sided path.
            let scale = want[0].max(1.0);
            for (x, y) in want.iter().zip(&got) {
                assert!((x - *y as f64).abs() <= 5e-3 * scale, "{m}x{n}: {x} vs {y}");
            }
        }
    }
}
