//! Dense real SVD via Golub–Kahan bidiagonalization + implicit-shift QR
//! (the classic Golub–Reinsch algorithm).
//!
//! This is the engine behind the paper's *explicit* baseline: unroll the
//! convolution into its `(m·n·c_out) × (m·n·c_in)` matrix and decompose it
//! directly — the `O(n⁶c³)` approach of Table I that the LFA route obsoletes.
//! `compute_uv = false` mirrors `numpy.linalg.svd(..., compute_uv=False)`
//! used by the paper and skips all U/V accumulation work. Generic over the
//! [`Real`] width like the rest of the linalg layer (`f64` default; the
//! deflation tests `x.abs() + anorm == anorm` are precision-relative and
//! work unchanged at `f32`).

use crate::numeric::{Layout, Mat, Real};

/// Result of [`svd`]: `A = U · diag(s) · Vᵀ` with `s` sorted descending.
pub struct SvdResult<T = f64> {
    /// `m×n` left singular vectors (thin), if requested.
    pub u: Option<Mat<T>>,
    /// Singular values, descending.
    pub s: Vec<T>,
    /// `n×n` transposed right singular vectors, if requested.
    pub vt: Option<Mat<T>>,
}

#[inline]
fn pythag<T: Real>(a: T, b: T) -> T {
    a.hypot(b)
}

#[inline]
fn sign_of<T: Real>(a: T, b: T) -> T {
    if b >= T::ZERO {
        a.abs()
    } else {
        -a.abs()
    }
}

/// Singular value decomposition of a real dense matrix.
///
/// Handles `m < n` by decomposing the transpose and swapping factors.
/// Iteration cap is 60 sweeps per singular value (well above the ~30 the
/// literature suggests); convergence failures panic loudly rather than
/// returning garbage.
pub fn svd<T: Real>(a: &Mat<T>, compute_uv: bool) -> SvdResult<T> {
    if a.rows < a.cols {
        let at = a.transpose();
        let r = svd(&at, compute_uv);
        return SvdResult {
            u: r.vt.map(|vt| vt.transpose()),
            s: r.s,
            vt: r.u.map(|u| u.transpose()),
        };
    }
    let m = a.rows;
    let n = a.cols;
    // Working copy holds U progressively (Golub–Reinsch accumulates in place).
    let mut u = a.to_layout(Layout::RowMajor);
    let mut w = vec![T::ZERO; n];
    let mut rv1 = vec![T::ZERO; n];
    let mut v = Mat::zeros(n, n);

    // --- Householder bidiagonalization ---
    let mut g = T::ZERO;
    let mut scale = T::ZERO;
    let mut anorm = T::ZERO;
    for i in 0..n {
        let l = i + 1;
        rv1[i] = scale * g;
        g = T::ZERO;
        let mut s;
        scale = T::ZERO;
        if i < m {
            for k in i..m {
                scale += u[(k, i)].abs();
            }
            if scale != T::ZERO {
                s = T::ZERO;
                for k in i..m {
                    u[(k, i)] /= scale;
                    s += u[(k, i)] * u[(k, i)];
                }
                let f = u[(i, i)];
                g = -sign_of(s.sqrt(), f);
                let h = f * g - s;
                u[(i, i)] = f - g;
                // Column-Householder applied to the trailing block with
                // row-contiguous access (PERF: the original j-outer/k-inner
                // order walks n-strided columns and thrashes the cache —
                // 5-10x slower at n ≥ 1024; see EXPERIMENTS.md §Perf).
                if l < n {
                    // dots[j] = Σ_k v_k · A[k, j], accumulated row-wise.
                    let mut dots = vec![T::ZERO; n - l];
                    for k in i..m {
                        let vk = u[(k, i)];
                        if vk == T::ZERO {
                            continue;
                        }
                        let row = k * n;
                        let (row_l, row_n) = (row + l, row + n);
                        for (d, a) in dots.iter_mut().zip(&u.data[row_l..row_n]) {
                            *d += vk * *a;
                        }
                    }
                    let hinv = h.recip();
                    for d in dots.iter_mut() {
                        *d *= hinv;
                    }
                    for k in i..m {
                        let vk = u[(k, i)];
                        if vk == T::ZERO {
                            continue;
                        }
                        let row = k * n;
                        for (d, a) in dots.iter().zip(&mut u.data[row + l..row + n]) {
                            *a += vk * *d;
                        }
                    }
                }
                for k in i..m {
                    u[(k, i)] *= scale;
                }
            }
        }
        w[i] = scale * g;
        g = T::ZERO;
        s = T::ZERO;
        scale = T::ZERO;
        if i < m && i != n - 1 {
            for k in l..n {
                scale += u[(i, k)].abs();
            }
            if scale != T::ZERO {
                for k in l..n {
                    u[(i, k)] /= scale;
                    s += u[(i, k)] * u[(i, k)];
                }
                let f = u[(i, l)];
                g = -sign_of(s.sqrt(), f);
                let h = f * g - s;
                u[(i, l)] = f - g;
                for k in l..n {
                    rv1[k] = u[(i, k)] / h;
                }
                for j in l..m {
                    s = T::ZERO;
                    for k in l..n {
                        s += u[(j, k)] * u[(i, k)];
                    }
                    for k in l..n {
                        let d = s * rv1[k];
                        u[(j, k)] += d;
                    }
                }
                for k in l..n {
                    u[(i, k)] *= scale;
                }
            }
        }
        anorm = anorm.max(w[i].abs() + rv1[i].abs());
    }

    // --- Accumulate right-hand transformations (V) ---
    if compute_uv {
        let mut l = n; // l tracks i+1 from the previous iteration
        let mut gprev = T::ZERO;
        for i in (0..n).rev() {
            if i < n - 1 {
                if gprev != T::ZERO {
                    for j in l..n {
                        v[(j, i)] = (u[(i, j)] / u[(i, l)]) / gprev;
                    }
                    for j in l..n {
                        let mut s = T::ZERO;
                        for k in l..n {
                            s += u[(i, k)] * v[(k, j)];
                        }
                        for k in l..n {
                            let d = s * v[(k, i)];
                            v[(k, j)] += d;
                        }
                    }
                }
                for j in l..n {
                    v[(i, j)] = T::ZERO;
                    v[(j, i)] = T::ZERO;
                }
            }
            v[(i, i)] = T::ONE;
            gprev = rv1[i];
            l = i;
        }
    }

    // --- Accumulate left-hand transformations (U) ---
    if compute_uv {
        for i in (0..n.min(m)).rev() {
            let l = i + 1;
            let g = w[i];
            for j in l..n {
                u[(i, j)] = T::ZERO;
            }
            if g != T::ZERO {
                let ginv = g.recip();
                for j in l..n {
                    let mut s = T::ZERO;
                    for k in l..m {
                        s += u[(k, i)] * u[(k, j)];
                    }
                    let f = (s / u[(i, i)]) * ginv;
                    for k in i..m {
                        let d = f * u[(k, i)];
                        u[(k, j)] += d;
                    }
                }
                for j in i..m {
                    u[(j, i)] *= ginv;
                }
            } else {
                for j in i..m {
                    u[(j, i)] = T::ZERO;
                }
            }
            u[(i, i)] += T::ONE;
        }
    }

    // --- Diagonalize the bidiagonal form: implicit-shift QR with deflation ---
    for k in (0..n).rev() {
        let mut its = 0;
        loop {
            its += 1;
            let mut flag = true;
            let mut l = k;
            let mut nm = 0usize;
            // Test for splitting.
            while l > 0 {
                nm = l - 1;
                if rv1[l].abs() + anorm == anorm {
                    flag = false;
                    break;
                }
                if w[nm].abs() + anorm == anorm {
                    break;
                }
                l -= 1;
            }
            if l == 0 {
                // rv1[0] is always zero by construction
                flag = false;
            }
            if flag {
                // Cancel rv1[l] if w[l-1] is negligible.
                let mut c = T::ZERO;
                let mut s = T::ONE;
                for i in l..=k {
                    let f = s * rv1[i];
                    rv1[i] = c * rv1[i];
                    if f.abs() + anorm == anorm {
                        break;
                    }
                    let g = w[i];
                    let h = pythag(f, g);
                    w[i] = h;
                    let hinv = h.recip();
                    c = g * hinv;
                    s = -f * hinv;
                    if compute_uv {
                        for j in 0..m {
                            let y = u[(j, nm)];
                            let z = u[(j, i)];
                            u[(j, nm)] = y * c + z * s;
                            u[(j, i)] = z * c - y * s;
                        }
                    }
                }
            }
            let z = w[k];
            if l == k {
                // Converged; enforce non-negative singular value.
                if z < T::ZERO {
                    w[k] = -z;
                    if compute_uv {
                        for j in 0..n {
                            v[(j, k)] = -v[(j, k)];
                        }
                    }
                }
                break;
            }
            assert!(
                its <= 60,
                "gk_svd: no convergence after 60 iterations (k={k}, n={n})"
            );
            // Shift from bottom 2x2 minor.
            let mut x = w[l];
            let nm = k - 1;
            let mut y = w[nm];
            let mut g = rv1[nm];
            let mut h = rv1[k];
            let mut f = ((y - z) * (y + z) + (g - h) * (g + h)) / (T::TWO * h * y);
            g = pythag(f, T::ONE);
            f = ((x - z) * (x + z) + h * ((y / (f + sign_of(g, f))) - h)) / x;
            // Next QR transformation.
            let mut c = T::ONE;
            let mut s = T::ONE;
            for j in l..=nm {
                let i = j + 1;
                g = rv1[i];
                y = w[i];
                h = s * g;
                g = c * g;
                let mut zz = pythag(f, h);
                rv1[j] = zz;
                let zinv = zz.recip();
                c = f * zinv;
                s = h * zinv;
                f = x * c + g * s;
                g = g * c - x * s;
                h = y * s;
                y *= c;
                if compute_uv {
                    for jj in 0..n {
                        let xx = v[(jj, j)];
                        let z2 = v[(jj, i)];
                        v[(jj, j)] = xx * c + z2 * s;
                        v[(jj, i)] = z2 * c - xx * s;
                    }
                }
                zz = pythag(f, h);
                w[j] = zz;
                if zz != T::ZERO {
                    let zi = zz.recip();
                    c = f * zi;
                    s = h * zi;
                }
                f = c * g + s * y;
                x = c * y - s * g;
                if compute_uv {
                    for jj in 0..m {
                        let yy = u[(jj, j)];
                        let z2 = u[(jj, i)];
                        u[(jj, j)] = yy * c + z2 * s;
                        u[(jj, i)] = z2 * c - yy * s;
                    }
                }
            }
            rv1[l] = T::ZERO;
            rv1[k] = f;
            w[k] = x;
        }
    }

    // --- Sort descending (and permute U, V consistently) ---
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| w[b].partial_cmp(&w[a]).unwrap());
    let s_sorted: Vec<T> = order.iter().map(|&i| w[i]).collect();
    if !compute_uv {
        return SvdResult { u: None, s: s_sorted, vt: None };
    }
    let mut u_sorted = Mat::zeros(m, n);
    let mut vt_sorted = Mat::zeros(n, n);
    for (newj, &oldj) in order.iter().enumerate() {
        for i in 0..m {
            u_sorted[(i, newj)] = u[(i, oldj)];
        }
        for i in 0..n {
            vt_sorted[(newj, i)] = v[(i, oldj)];
        }
    }
    SvdResult { u: Some(u_sorted), s: s_sorted, vt: Some(vt_sorted) }
}

/// Convenience: singular values only, descending.
pub fn singular_values<T: Real>(a: &Mat<T>) -> Vec<T> {
    svd(a, false).s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::qr::orthonormality_defect;
    use crate::numeric::Pcg64;

    fn reconstruct(r: &SvdResult, m: usize, _n: usize) -> Mat {
        let u = r.u.as_ref().unwrap();
        let vt = r.vt.as_ref().unwrap();
        let rank = r.s.len();
        let mut us = Mat::zeros(m, rank);
        for i in 0..m {
            for j in 0..rank {
                us[(i, j)] = u[(i, j)] * r.s[j];
            }
        }
        us.matmul(vt)
    }

    #[test]
    fn diagonal_matrix() {
        let a = Mat::from_rows(&[&[3.0, 0.0], &[0.0, 7.0]]);
        let s = singular_values(&a);
        assert!((s[0] - 7.0).abs() < 1e-12);
        assert!((s[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn known_2x2() {
        // A = [[1, 1], [0, 1]] has σ = golden-ratio-ish values: sqrt((3±sqrt5)/2)
        let a = Mat::from_rows(&[&[1.0, 1.0], &[0.0, 1.0]]);
        let s = singular_values(&a);
        let want0 = ((3.0 + 5.0f64.sqrt()) / 2.0).sqrt();
        let want1 = ((3.0 - 5.0f64.sqrt()) / 2.0).sqrt();
        assert!((s[0] - want0).abs() < 1e-12);
        assert!((s[1] - want1).abs() < 1e-12);
    }

    #[test]
    fn reconstruction_square_and_tall_and_wide() {
        let mut rng = Pcg64::seeded(21);
        for &(m, n) in &[(6usize, 6usize), (10, 4), (4, 10), (1, 5), (5, 1), (16, 16)] {
            let a = Mat::random_normal(m, n, &mut rng);
            let r = svd(&a, true);
            let recon = reconstruct(&r, m, n);
            let err = recon.max_abs_diff(&a);
            assert!(err < 1e-9, "{m}x{n}: reconstruction err {err}");
            // Orthonormality
            assert!(orthonormality_defect(r.u.as_ref().unwrap()) < 1e-9, "{m}x{n} U");
            assert!(
                orthonormality_defect(&r.vt.as_ref().unwrap().transpose()) < 1e-9,
                "{m}x{n} V"
            );
        }
    }

    #[test]
    fn values_sorted_nonnegative() {
        let mut rng = Pcg64::seeded(22);
        let a = Mat::random_normal(12, 9, &mut rng);
        let s = singular_values(&a);
        for w in s.windows(2) {
            assert!(w[0] >= w[1]);
        }
        assert!(s.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn rank_deficient() {
        // rank-1 matrix: one nonzero singular value = ‖u‖·‖v‖
        let u = [1.0, 2.0, 3.0];
        let v = [4.0, 5.0];
        let mut a = Mat::zeros(3, 2);
        for i in 0..3 {
            for j in 0..2 {
                a[(i, j)] = u[i] * v[j];
            }
        }
        let s = singular_values(&a);
        let want = (14.0f64).sqrt() * (41.0f64).sqrt();
        assert!((s[0] - want).abs() < 1e-10, "{} vs {want}", s[0]);
        assert!(s[1].abs() < 1e-10);
    }

    #[test]
    fn zero_matrix() {
        let a = Mat::zeros(4, 3);
        let s = singular_values(&a);
        assert!(s.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn frobenius_identity() {
        // ‖A‖_F² = Σ σᵢ²
        let mut rng = Pcg64::seeded(23);
        let a = Mat::random_normal(8, 8, &mut rng);
        let s = singular_values(&a);
        let fro2: f64 = s.iter().map(|x| x * x).sum();
        assert!((fro2 - a.frobenius_norm().powi(2)).abs() < 1e-8);
    }

    #[test]
    fn values_match_uv_mode() {
        let mut rng = Pcg64::seeded(24);
        let a = Mat::random_normal(9, 7, &mut rng);
        let s1 = svd(&a, false).s;
        let s2 = svd(&a, true).s;
        for (a, b) in s1.iter().zip(&s2) {
            assert!((a - b).abs() < 1e-10);
        }
    }

    #[test]
    fn f32_values_track_f64() {
        let mut rng = Pcg64::seeded(25);
        let a = Mat::random_normal(10, 7, &mut rng);
        let want = singular_values(&a);
        let a32: Mat<f32> = a.convert();
        let got = singular_values(&a32);
        let scale = want[0].max(1.0);
        for (x, y) in want.iter().zip(&got) {
            assert!((x - *y as f64).abs() <= 1e-4 * scale, "{x} vs {y}");
        }
    }
}
