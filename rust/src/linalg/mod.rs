//! Linear-algebra substrate built from scratch (no LAPACK in this image):
//! Householder QR, Golub–Reinsch dense SVD, one-sided Jacobi SVD for the
//! per-frequency complex blocks, Hermitian Jacobi eigensolver (Gram-route
//! ablation), power/Krylov iteration (including the warm-startable
//! block top-k solver behind the engine's partial-spectrum mode), and
//! induced-norm bounds.

pub mod gk_svd;
pub mod jacobi_eig;
pub mod jacobi_svd;
pub mod norms;
pub mod power;
pub mod qr;

pub use gk_svd::SvdResult;
pub use jacobi_svd::CSvd;
pub use power::{block_topk, LinOp, TopKOptions, TopKScratch};
