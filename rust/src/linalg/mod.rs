//! Linear-algebra substrate built from scratch (no LAPACK in this image):
//! Householder QR, Golub–Reinsch dense SVD, one-sided Jacobi SVD for the
//! per-frequency complex blocks, Hermitian Jacobi eigensolver (Gram-route
//! ablation), power/Krylov iteration (including the warm-startable
//! block top-k solver behind the engine's partial-spectrum mode), and
//! induced-norm bounds. Every solver is generic over the
//! [`crate::numeric::Real`] scalar width (`f64` default, `f32` for the
//! reduced-precision tier), with the complex hot loops dispatched through
//! the [`crate::numeric::SimdReal`] kernels; the mixed-precision refinement
//! entry points (`jacobi_svd::singular_values_refined_into`,
//! `power::refine_topk_values`) recover full f64 accuracy from f32 sweeps.

pub mod gk_svd;
pub mod jacobi_eig;
pub mod jacobi_svd;
pub mod norms;
pub mod power;
pub mod qr;

pub use gk_svd::SvdResult;
pub use jacobi_svd::CSvd;
pub use power::{block_topk, LinOp, TopKOptions, TopKScratch};
