//! Linear-algebra substrate built from scratch (no LAPACK in this image):
//! Householder QR, Golub–Reinsch dense SVD, one-sided Jacobi SVD for the
//! per-frequency complex blocks, Hermitian Jacobi eigensolver (Gram-route
//! ablation), power/Krylov iteration (including the warm-startable
//! block top-k solver behind the engine's partial-spectrum mode), and
//! induced-norm bounds. Every solver is generic over the
//! [`crate::numeric::Real`] scalar width (`f64` default, `f32` for the
//! reduced-precision tier), with the complex hot loops dispatched through
//! the [`crate::numeric::SimdReal`] kernels; the mixed-precision refinement
//! entry points (`jacobi_svd::singular_values_refined_into`,
//! `power::refine_topk_values`) recover full f64 accuracy from f32 sweeps.

pub mod gk_svd;
pub mod jacobi_eig;
pub mod jacobi_svd;
pub mod norms;
pub mod power;
pub mod qr;

pub use gk_svd::SvdResult;
pub use jacobi_svd::CSvd;
pub use power::{block_topk, LinOp, TopKOptions, TopKScratch};

/// Convergence certificate returned by the iterative solvers (Jacobi
/// sweeps, Krylov top-k). Instead of silently "tolerating" iteration-budget
/// exhaustion, every solve reports how hard it worked and how good the
/// result actually is, so the engine's escalation ladder
/// ([`crate::engine::SpectralPlan`]) can retry, re-solve in higher
/// precision, or flag the frequency as degraded.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SolveCert {
    /// Iteration effort spent: Jacobi sweeps used, or Krylov steps taken.
    pub effort: usize,
    /// Final relative residual: the worst relative off-diagonal element at
    /// exit (Jacobi), or the worst relative Ritz residual (top-k). Zero for
    /// trivial solves that need no iteration.
    pub residual: f64,
    /// Whether the residual met the solver's tolerance within the
    /// iteration budget. `false` means the values are best-effort.
    pub converged: bool,
    /// Whether an internal fresh-restart retry was taken (sweep
    /// exhaustion recovered by restarting from the current iterate).
    pub restarted: bool,
}

impl SolveCert {
    /// Certificate for a trivial solve (nothing to iterate on).
    pub const TRIVIAL: Self =
        Self { effort: 0, residual: 0.0, converged: true, restarted: false };

    /// Combine the certificate of a retry pass with the original attempt:
    /// effort accumulates, the retry's verdict and residual win, and the
    /// result is marked restarted.
    pub fn after_restart(self, retry: Self) -> Self {
        Self {
            effort: self.effort + retry.effort,
            residual: retry.residual,
            converged: retry.converged,
            restarted: true,
        }
    }
}
