//! Induced matrix norms and the Gouk et al. spectral-norm bound.
//!
//! §II-b of the paper cites Gouk et al. (2021): `‖A‖₂ ≤ √(‖A‖₁ · ‖A‖_∞)`
//! (Hölder interpolation), where for the unrolled convolution both one-norms
//! are cheap — with periodic boundary conditions every row (resp. column)
//! has the same absolute sum, so they reduce to sums over the weight tensor.
//! Generic over the [`Real`] width (`f64` default).

use crate::numeric::{Mat, Real};

/// `‖A‖₁` — maximum absolute column sum.
pub fn norm_1<T: Real>(a: &Mat<T>) -> T {
    let mut worst = T::ZERO;
    for j in 0..a.cols {
        let mut s = T::ZERO;
        for i in 0..a.rows {
            s += a[(i, j)].abs();
        }
        worst = worst.max(s);
    }
    worst
}

/// `‖A‖_∞` — maximum absolute row sum.
pub fn norm_inf<T: Real>(a: &Mat<T>) -> T {
    let mut worst = T::ZERO;
    for i in 0..a.rows {
        let mut s = T::ZERO;
        for j in 0..a.cols {
            s += a[(i, j)].abs();
        }
        worst = worst.max(s);
    }
    worst
}

/// Hölder bound on the spectral norm: `√(‖A‖₁ ‖A‖_∞)`.
pub fn holder_bound<T: Real>(a: &Mat<T>) -> T {
    (norm_1(a) * norm_inf(a)).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gk_svd;
    use crate::numeric::Pcg64;

    #[test]
    fn norms_on_known_matrix() {
        let a = Mat::from_rows(&[&[1.0, -2.0], &[3.0, 4.0]]);
        assert_eq!(norm_1(&a), 6.0); // col sums: 4, 6
        assert_eq!(norm_inf(&a), 7.0); // row sums: 3, 7
    }

    #[test]
    fn holder_bounds_spectral_norm() {
        let mut rng = Pcg64::seeded(61);
        for _ in 0..10 {
            let a = Mat::random_normal(9, 7, &mut rng);
            let sigma = gk_svd::singular_values(&a)[0];
            let bound = holder_bound(&a);
            assert!(sigma <= bound + 1e-10, "σ={sigma} bound={bound}");
        }
    }

    #[test]
    fn tight_on_nonnegative_rank_one() {
        // For A = 1·1ᵀ (all ones, n×n): σ_max = n = √(n·n).
        let n = 5;
        let mut a = Mat::zeros(n, n);
        a.data.iter_mut().for_each(|v| *v = 1.0);
        let sigma = gk_svd::singular_values(&a)[0];
        assert!((holder_bound(&a) - sigma).abs() < 1e-9);
    }

    #[test]
    fn f32_norms_match() {
        let a = Mat::from_rows(&[&[1.0f32, -2.0], &[3.0, 4.0]]);
        assert_eq!(norm_1(&a), 6.0);
        assert_eq!(norm_inf(&a), 7.0);
    }
}
