//! Householder QR decomposition for real dense matrices.
//!
//! Substrate for the Golub–Kahan SVD (bidiagonalization uses the same
//! reflector machinery) and for orthogonality checks in tests. Generic over
//! the [`Real`] width like the rest of the linalg layer (`f64` default).

use crate::numeric::{Layout, Mat, Real};

/// Result of a QR decomposition: `A = Q · R` with `Q` having orthonormal
/// columns (thin factorization, `Q: m×n`, `R: n×n` for `m ≥ n`).
pub struct Qr<T = f64> {
    pub q: Mat<T>,
    pub r: Mat<T>,
}

/// Compute a Householder reflector `v, β` such that
/// `(I − β v vᵀ) x = ∓‖x‖ e₁`, with `v[0] = 1` implicit.
/// Returns `(v, beta, alpha)` where `alpha` is the resulting leading entry.
pub(crate) fn householder<T: Real>(x: &[T]) -> (Vec<T>, T, T) {
    let n = x.len();
    let mut v = x.to_vec();
    if n == 0 {
        return (v, T::ZERO, T::ZERO);
    }
    let sigma: T = x[1..].iter().map(|a| *a * *a).sum();
    let x0 = x[0];
    if sigma == T::ZERO && x0 >= T::ZERO {
        v[0] = T::ONE;
        return (v, T::ZERO, x0);
    }
    let mu = (x0 * x0 + sigma).sqrt();
    let v0 = if x0 <= T::ZERO { x0 - mu } else { -sigma / (x0 + mu) };
    let beta = T::TWO * v0 * v0 / (sigma + v0 * v0);
    for vi in v.iter_mut().skip(1) {
        *vi /= v0;
    }
    v[0] = T::ONE;
    // Both branches of v0 equal x0 − mu (the second computed stably), so the
    // reflection always maps x ↦ +‖x‖·e₁.
    (v, beta, mu)
}

/// Thin QR via Householder reflectors. Requires `m ≥ n`.
pub fn qr<T: Real>(a: &Mat<T>) -> Qr<T> {
    let (m, n) = (a.rows, a.cols);
    assert!(m >= n, "qr requires rows >= cols (got {m}x{n})");
    let mut r = a.to_layout(Layout::RowMajor);
    // Store reflectors (v, beta) to build Q afterwards.
    let mut reflectors: Vec<(Vec<T>, T)> = Vec::with_capacity(n);

    for k in 0..n {
        let col: Vec<T> = (k..m).map(|i| r[(i, k)]).collect();
        let (v, beta, alpha) = householder(&col);
        // Apply (I - beta v vT) to R[k.., k..]
        if beta != T::ZERO {
            for j in k..n {
                let mut dot = T::ZERO;
                for i in k..m {
                    dot += v[i - k] * r[(i, j)];
                }
                let bd = beta * dot;
                for i in k..m {
                    let d = bd * v[i - k];
                    r[(i, j)] -= d;
                }
            }
        }
        r[(k, k)] = alpha;
        for i in k + 1..m {
            r[(i, k)] = T::ZERO;
        }
        reflectors.push((v, beta));
    }

    // Accumulate thin Q by applying reflectors to I (m×n), backwards.
    let mut q = Mat::zeros(m, n);
    for i in 0..n {
        q[(i, i)] = T::ONE;
    }
    for k in (0..n).rev() {
        let (v, beta) = &reflectors[k];
        if *beta == T::ZERO {
            continue;
        }
        for j in 0..n {
            let mut dot = T::ZERO;
            for i in k..m {
                dot += v[i - k] * q[(i, j)];
            }
            let bd = *beta * dot;
            for i in k..m {
                let d = bd * v[i - k];
                q[(i, j)] -= d;
            }
        }
    }

    // Keep R upper-triangular n×n
    let mut rn = Mat::zeros(n, n);
    for i in 0..n {
        for j in i..n {
            rn[(i, j)] = r[(i, j)];
        }
    }
    Qr { q, r: rn }
}

/// Orthonormality defect `‖QᵀQ − I‖_max` of a real matrix.
pub fn orthonormality_defect<T: Real>(q: &Mat<T>) -> T {
    let mut worst = T::ZERO;
    for i in 0..q.cols {
        for j in 0..q.cols {
            let mut dot = T::ZERO;
            for r in 0..q.rows {
                dot += q[(r, i)] * q[(r, j)];
            }
            let want = if i == j { T::ONE } else { T::ZERO };
            worst = worst.max((dot - want).abs());
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numeric::Pcg64;

    #[test]
    fn qr_reconstructs() {
        let mut rng = Pcg64::seeded(10);
        for &(m, n) in &[(4usize, 4usize), (8, 5), (12, 3), (6, 6)] {
            let a = Mat::random_normal(m, n, &mut rng);
            let f = qr(&a);
            let recon = f.q.matmul(&f.r);
            assert!(recon.max_abs_diff(&a) < 1e-10, "{m}x{n}: {}", recon.max_abs_diff(&a));
        }
    }

    #[test]
    fn q_is_orthonormal() {
        let mut rng = Pcg64::seeded(11);
        let a = Mat::random_normal(10, 6, &mut rng);
        let f = qr(&a);
        assert!(orthonormality_defect(&f.q) < 1e-12);
    }

    #[test]
    fn r_is_upper_triangular() {
        let mut rng = Pcg64::seeded(12);
        let a = Mat::random_normal(7, 7, &mut rng);
        let f = qr(&a);
        for i in 0..7 {
            for j in 0..i {
                assert_eq!(f.r[(i, j)], 0.0);
            }
        }
    }

    #[test]
    fn rank_deficient_ok() {
        // Two identical columns — still reconstructs.
        let a = Mat::from_rows(&[&[1.0, 1.0], &[2.0, 2.0], &[3.0, 3.0]]);
        let f = qr(&a);
        assert!(f.q.matmul(&f.r).max_abs_diff(&a) < 1e-12);
    }

    #[test]
    fn householder_annihilates() {
        let x = vec![3.0, 1.0, 5.0, 1.0];
        let (v, beta, alpha) = householder(&x);
        // y = (I - beta v v^T) x should be (alpha, 0, 0, 0)
        let dot: f64 = v.iter().zip(&x).map(|(a, b)| a * b).sum();
        let y: Vec<f64> = x.iter().zip(&v).map(|(xi, vi)| xi - beta * dot * vi).collect();
        assert!((y[0] - alpha).abs() < 1e-12);
        for yi in &y[1..] {
            assert!(yi.abs() < 1e-12);
        }
        let norm: f64 = x.iter().map(|a| a * a).sum::<f64>().sqrt();
        assert!((alpha.abs() - norm).abs() < 1e-12);
    }

    #[test]
    fn f32_qr_reconstructs() {
        let mut rng = Pcg64::seeded(13);
        let a64 = Mat::random_normal(8, 5, &mut rng);
        let a: Mat<f32> = a64.convert();
        let f = qr(&a);
        let recon = f.q.matmul(&f.r);
        assert!(recon.max_abs_diff(&a) < 1e-4);
        assert!(orthonormality_defect(&f.q) < 1e-5);
    }
}
