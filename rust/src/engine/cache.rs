//! [`SpectralCache`]: content-addressed result & plan caching for
//! repeat-traffic audits.
//!
//! A service handling heavy repeat traffic — training-loop clipping
//! (Senderovich et al. 2022), repeated Lipschitz audits (Sedghi et al.
//! 2019) — recomputes identical spectra every time a layer's weights
//! haven't changed. This module makes the recomputation a hash lookup:
//!
//! - a **result cache**: a deterministic [`Signature`] over the kernel
//!   weight *bits*, the grid, stride, block layout, solver,
//!   [`SpectrumRequest`] and [`Fold`] mode maps to an `Arc<Spectrum>`.
//!   Equal signature ⇒ the same operator spectrum, so a hit returns
//!   previously computed values without touching a single frequency.
//!   For `Full` requests that sharing is **bit-identical** (per-frequency
//!   Jacobi is partition-invariant); `TopK` values are converged to the
//!   Krylov solver's tolerance and their final bits depend on the sweep
//!   shape (thread strips, batched model sweeps), so a served `TopK`
//!   entry may differ from a particular resweep in the last bits — the
//!   same variation threaded-vs-serial top-k already has without a
//!   cache. Entries are evicted **least-recently-used under a byte
//!   budget** ([`SpectralCache::with_budget`]). Spectral **densities**
//!   ([`crate::lfa::SpectralDensity`], the streaming-histogram sink's
//!   output) cache alongside spectra — same byte budget, one global LRU
//!   order, same degraded-refusal gate — keyed by
//!   [`Signature::for_density`]; they are memory-only (no disk tier: a
//!   density is a small derived summary, recomputable from a spectrum
//!   hit or a cheap resweep).
//! - a **plan cache**: jobs and [`super::ModelPlan`] groups with equal
//!   plan signatures (weights + geometry + options + resolved worker
//!   count) share one [`SpectralPlan`] instead of re-planning phase
//!   tables; a shared plan also shares its workspace pool, so repeat
//!   jobs reuse warmed scratch. Capped by **entry count**, deliberately
//!   modest: a cached plan pins its `O(n·kh + m·kw)` phase tables, the
//!   kernel clone *and* its warmed workspace pool (which grows with the
//!   worker count), none of which is charged against the byte budget —
//!   `cache_bytes` budgets *results* only.
//!
//! - an optional **disk tier** ([`SpectralCache::with_disk`] →
//!   [`super::disk_cache::DiskCache`]): inserted results are written
//!   through to checksummed, versioned spill files named by the
//!   signature's [`Signature::file_digest`], and a memory miss falls back
//!   to a disk read — so warm repeat traffic survives process restarts
//!   (the daemon's deploy-restart shape). Disk I/O never holds the
//!   in-memory mutex.
//!
//! The coordinator's [`crate::coordinator::Scheduler`] consults the cache
//! before tiling a job and populates it at job finish;
//! [`super::ModelPlan::execute_cached`] does the same for direct
//! whole-model sweeps, so a repeated `audit-model` of an unchanged model
//! re-solves zero frequencies. Hit / miss / eviction counts are exposed
//! via [`SpectralCache::stats`] and the coordinator's `MetricsSnapshot`.
//!
//! Keys are *content hashes* of the weight bits (two independent FNV-1a
//! streams, 128 bits total) plus every structural field compared exactly —
//! a collision requires two weight tensors of equal length agreeing on
//! both digests, which does not happen by accident. Weight mutation (a
//! clipped layer, a training step) changes the bits and therefore the
//! signature: stale entries are never *returned*, they simply age out of
//! the LRU order.

use super::disk_cache::{DiskCache, DiskStats};
use super::plan::SpectralPlan;
use super::{DensityRequest, SpectrumRequest};
use crate::conv::ConvKernel;
use crate::lfa::spectrum::{SpectralDensity, Spectrum};
use crate::lfa::svd::{BlockSolver, Fold, LfaOptions, Precision};
use crate::lfa::symbol::BlockLayout;
use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Default result-cache byte budget (256 MiB ≈ 32M singular values).
pub const DEFAULT_CACHE_BYTES: usize = 256 << 20;

/// Plan-cache entry cap. Each cached plan retains phase tables, a kernel
/// clone and its warmed workspace pool (outside the byte budget — see the
/// module docs), so the cap is modest; it also bounds pathological churn
/// (a service cycling through thousands of distinct layer shapes).
const PLAN_CACHE_CAP: usize = 64;

/// Byte-wise FNV-1a over a stream of `u64`s (the weight bit patterns),
/// maintaining **two** digests from different offset bases in one fused
/// pass — 128 bits of content address for a single sweep of the tensor
/// (hashing is the dominant cost of a signature on big layers; two
/// separate passes would double the memory traffic).
fn fnv1a_u64s2(words: impl Iterator<Item = u64>) -> [u64; 2] {
    const PRIME: u64 = 0x100000001b3;
    let mut h0: u64 = 0xcbf29ce484222325;
    let mut h1: u64 = 0x6c62272e07bb0142;
    for w in words {
        for shift in [0u32, 8, 16, 24, 32, 40, 48, 56] {
            let b = (w >> shift) & 0xff;
            h0 = (h0 ^ b).wrapping_mul(PRIME);
            h1 = (h1 ^ b).wrapping_mul(PRIME);
        }
    }
    [h0, h1]
}

/// Deterministic content signature of one spectral computation (or of one
/// plan, when [`Signature::plan`] built it): kernel weight **bits**, grid,
/// stride, layout, solver, folding, and — for result signatures — the
/// [`SpectrumRequest`]. Plan signatures additionally pin the resolved
/// worker count (a plan built for 1 thread partitions differently than one
/// built for 8; results are invariant, plans are not).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Signature {
    /// 128-bit FNV-1a content digest of the weight bit patterns.
    weights: [u64; 2],
    weight_len: usize,
    c_out: usize,
    c_in: usize,
    kh: usize,
    kw: usize,
    anchor: (usize, usize),
    /// Channel-group count. Structure fields are compared exactly: a
    /// grouped kernel and a dense kernel with bit-identical weight
    /// tensors describe *different operators* (the grouped one masks
    /// cross-group taps), so they must never share a cache entry.
    groups: usize,
    /// Tap-spacing factor (1 = ordinary convolution).
    dilation: usize,
    /// Whether the audited operator is the adjoint (transposed conv).
    transposed: bool,
    n: usize,
    m: usize,
    stride: usize,
    layout: BlockLayout,
    solver: BlockSolver,
    folding: Fold,
    /// Scalar width of the sweep. Pinned into the digest: an f32 spectrum
    /// (~1e-4 relative) must never be served where an f64 or refined one
    /// was requested — and vice versa, so each tier caches independently.
    precision: Precision,
    /// `Some(request)` for result signatures, `None` for plan signatures.
    request: Option<SpectrumRequest>,
    /// `Some(req)` for **density** signatures ([`Self::for_density`]) —
    /// mutually exclusive with `request`. Bins and sampling stride are
    /// part of the content address: a 64-bin histogram is not a 256-bin
    /// one, and a sub-lattice sample is not a census.
    density: Option<DensityRequest>,
    /// Resolved worker count for plan signatures, 0 for result signatures
    /// (values are identical no matter how many workers solved them).
    threads: usize,
}

impl Signature {
    fn common(kernel: &ConvKernel, n: usize, m: usize, stride: usize, opts: &LfaOptions) -> Self {
        Signature {
            weights: fnv1a_u64s2(kernel.data.iter().map(|v| v.to_bits())),
            weight_len: kernel.data.len(),
            c_out: kernel.c_out,
            c_in: kernel.c_in,
            kh: kernel.kh,
            kw: kernel.kw,
            anchor: kernel.anchor,
            groups: kernel.groups,
            dilation: kernel.dilation,
            transposed: kernel.transposed,
            n,
            m,
            stride,
            layout: opts.layout,
            solver: opts.solver,
            folding: opts.folding,
            precision: opts.precision,
            request: None,
            density: None,
            threads: 0,
        }
    }

    /// Per-frequency rank of the signed configuration: `TopK(k)` requests
    /// are normalized to their clamped `k` so equivalent requests —
    /// `TopK(rank)` and any `TopK(k > rank)` run the identical sweep —
    /// share one cache entry instead of storing duplicate values.
    ///
    /// For grouped kernels `c_in` is the per-group width (the kernel's
    /// storage convention), so the block-diagonal rank is
    /// `min(c_out, groups·s²·c_in)` — `groups` independent blocks of
    /// `min(c_out/groups, s²·c_in)` values each. Transposition is rank-
    /// preserving (the adjoint has the same singular values).
    fn rank(&self) -> usize {
        self.c_out.min(self.groups * self.stride * self.stride * self.c_in)
    }

    fn normalized(request: SpectrumRequest, rank: usize) -> SpectrumRequest {
        match request {
            SpectrumRequest::Full => SpectrumRequest::Full,
            SpectrumRequest::TopK(_) => SpectrumRequest::TopK(request.values_per_freq(rank)),
        }
    }

    /// Signature of the spectrum `request` computes for `kernel` on an
    /// `n×m` fine grid at `stride` under `opts`. Thread count is
    /// deliberately excluded: the values do not depend on it. Top-k
    /// requests are normalized to the clamped `k` (see [`Self::rank`]).
    pub fn result(
        kernel: &ConvKernel,
        n: usize,
        m: usize,
        stride: usize,
        opts: &LfaOptions,
        request: SpectrumRequest,
    ) -> Self {
        let common = Self::common(kernel, n, m, stride, opts);
        Signature { request: Some(Self::normalized(request, common.rank())), ..common }
    }

    /// Signature of the [`SpectralPlan`] `opts` would build (thread count
    /// resolved, so `0 = auto` and the explicit core count coincide).
    pub fn plan(kernel: &ConvKernel, n: usize, m: usize, stride: usize, opts: &LfaOptions) -> Self {
        Signature {
            threads: super::resolve_threads(opts.threads),
            ..Self::common(kernel, n, m, stride, opts)
        }
    }

    /// Derive the **result** signature for `request` from any signature
    /// of the same content. The weight digest is reused, not re-hashed —
    /// streaming a big layer's tensor through both FNV streams is the
    /// dominant cost of a repeat lookup, so paths that already hold a
    /// plan signature derive instead of recomputing. Top-k requests are
    /// normalized exactly as [`Self::result`] does.
    pub fn for_request(&self, request: SpectrumRequest) -> Signature {
        Signature {
            request: Some(Self::normalized(request, self.rank())),
            density: None,
            threads: 0,
            ..*self
        }
    }

    /// Derive the **density** signature for `req` from any signature of
    /// the same content — no re-hash. Density results are keyed exactly
    /// like spectra (weight bits + geometry + options), with the
    /// histogram shape (`bins`) and dual-lattice sampling stride
    /// (`sample`) in place of the [`SpectrumRequest`].
    pub fn for_density(&self, req: DensityRequest) -> Signature {
        Signature { request: None, density: Some(req), threads: 0, ..*self }
    }

    /// Derive the **plan** signature (worker count resolved, request
    /// cleared) from any signature of the same content — no re-hash.
    pub fn for_plan(&self, threads: usize) -> Signature {
        Signature {
            request: None,
            density: None,
            threads: super::resolve_threads(threads),
            ..*self
        }
    }

    /// The same signature pinned to a different scalar width — no re-hash.
    /// The scheduler keys PJRT-routed work with this: AOT artifacts compute
    /// in f32, so their results are interchangeable with a native
    /// [`Precision::F32`] sweep of the same content, and with nothing else.
    pub fn with_precision(&self, precision: Precision) -> Signature {
        Signature { precision, ..*self }
    }

    /// Stable 128-bit digest of the **entire** signature — every field,
    /// enums mapped to explicit tags — used by the disk tier
    /// ([`super::disk_cache::DiskCache`]) to name spill files and to
    /// verify on read that a file really belongs to the key that looked it
    /// up. Unlike `Hash`, the encoding is explicit and stable across
    /// builds (the spill-file format version, not the compiler, owns it).
    pub fn file_digest(&self) -> [u64; 2] {
        let layout = match self.layout {
            BlockLayout::BlockContiguous => 0u64,
            BlockLayout::PlanarStrided => 1,
        };
        let solver = match self.solver {
            BlockSolver::Jacobi => 0u64,
            BlockSolver::GramEigen => 1,
        };
        let folding = match self.folding {
            Fold::Auto => 0u64,
            Fold::Off => 1,
        };
        let precision = match self.precision {
            Precision::F64 => 0u64,
            Precision::F32 => 1,
            Precision::F32Refined => 2,
        };
        // Tag 3 extends the request word for density signatures without
        // disturbing any pre-existing digest (spill-file names are part
        // of the on-disk format; plan/Full/TopK words are unchanged).
        let request = match (self.request, self.density) {
            (None, None) => 0u64,
            (Some(SpectrumRequest::Full), _) => 1,
            (Some(SpectrumRequest::TopK(k)), _) => 2 | ((k as u64) << 2),
            (None, Some(d)) => 3 | ((d.bins as u64) << 2) | ((d.sample as u64) << 34),
        };
        let words = [
            self.weights[0],
            self.weights[1],
            self.weight_len as u64,
            self.c_out as u64,
            self.c_in as u64,
            self.kh as u64,
            self.kw as u64,
            self.anchor.0 as u64,
            self.anchor.1 as u64,
            self.groups as u64,
            self.dilation as u64,
            self.transposed as u64,
            self.n as u64,
            self.m as u64,
            self.stride as u64,
            layout,
            solver,
            folding,
            precision,
            request,
            self.threads as u64,
        ];
        fnv1a_u64s2(words.into_iter())
    }
}

struct ResultEntry {
    spectrum: Arc<Spectrum>,
    bytes: usize,
    last_used: u64,
}

struct DensityEntry {
    density: Arc<SpectralDensity>,
    bytes: usize,
    last_used: u64,
}

struct PlanEntry {
    plan: Arc<SpectralPlan>,
    last_used: u64,
}

struct Inner {
    results: HashMap<Signature, ResultEntry>,
    /// Density results, keyed by [`Signature::for_density`] signatures.
    /// Charged against the same byte budget as `results` and aged by the
    /// same recency index (a key lives in exactly one of the two maps —
    /// the `density` field makes the signatures disjoint). Memory-only:
    /// a density is a cheap derived summary, not worth a spill file.
    densities: HashMap<Signature, DensityEntry>,
    /// Recency index over `results` ∪ `densities`: LRU tick → key. Ticks
    /// are unique (monotone, bumped under the mutex), so eviction pops
    /// the smallest tick in `O(log n)` instead of scanning every entry —
    /// a large insert that evicts many small entries stays cheap while
    /// every submission path waits on this mutex.
    recency: BTreeMap<u64, Signature>,
    plans: HashMap<Signature, PlanEntry>,
    /// Total bytes held by `results` and `densities` entries.
    bytes: usize,
    /// Monotone LRU clock: bumped on every touch.
    tick: u64,
}

impl Inner {
    /// Evict least-recently-used entries (spectra **or** densities — one
    /// global LRU order) until `incoming` more bytes fit under
    /// `max_bytes`. Returns how many entries were evicted.
    fn evict_for(&mut self, incoming: usize, max_bytes: usize) -> u64 {
        let mut evicted = 0u64;
        while self.bytes + incoming > max_bytes {
            let (_, lru) =
                self.recency.pop_first().expect("nonzero bytes imply an evictable entry");
            let freed = match self.results.remove(&lru) {
                Some(e) => e.bytes,
                None => {
                    self.densities.remove(&lru).expect("recency index tracks both stores").bytes
                }
            };
            self.bytes -= freed;
            evicted += 1;
        }
        evicted
    }
}

/// Point-in-time cache counters ([`SpectralCache::stats`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Result-cache lookups that returned a spectrum.
    pub hits: u64,
    /// Result-cache lookups that found nothing.
    pub misses: u64,
    /// Result entries evicted to stay under the byte budget.
    pub evictions: u64,
    /// Plan-cache lookups that reused a planned object.
    pub plan_hits: u64,
    /// Plan-cache lookups that had to plan.
    pub plan_misses: u64,
    /// Result entries currently held.
    pub entries: usize,
    /// Density entries currently held (memory-only tier; shares the byte
    /// budget and LRU order with `entries`).
    pub density_entries: usize,
    /// Plans currently held.
    pub plan_entries: usize,
    /// Bytes currently held by result entries.
    pub bytes: usize,
    /// Result-cache byte budget.
    pub capacity: usize,
    /// Disk-tier lookups served from a valid spill file (0 if no disk
    /// tier is attached).
    pub disk_hits: u64,
    /// Disk-tier lookups that found no spill file.
    pub disk_misses: u64,
    /// Spectra newly spilled to disk.
    pub disk_spills: u64,
    /// Spill files that failed validation and were quarantined.
    pub disk_corruptions: u64,
}

/// Content-addressed result & plan cache — see the module docs. All
/// methods are `&self` and thread-safe; share one instance via `Arc`.
pub struct SpectralCache {
    max_bytes: usize,
    inner: Mutex<Inner>,
    /// Optional persistent tier below the LRU — see
    /// [`super::disk_cache::DiskCache`] and [`Self::with_disk`].
    disk: Option<DiskCache>,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    plan_hits: AtomicU64,
    plan_misses: AtomicU64,
}

impl SpectralCache {
    /// Cache with the default byte budget ([`DEFAULT_CACHE_BYTES`]).
    pub fn new() -> Self {
        Self::with_budget(DEFAULT_CACHE_BYTES)
    }

    /// [`Self::with_budget`] with the `0 = default` convention shared by
    /// the CLI's `--cache-bytes` and the coordinator's
    /// [`crate::coordinator::SchedulerConfig`] `cache_bytes` field: `0`
    /// means [`DEFAULT_CACHE_BYTES`].
    pub fn with_budget_or_default(max_bytes: usize) -> Self {
        Self::with_budget(if max_bytes == 0 { DEFAULT_CACHE_BYTES } else { max_bytes })
    }

    /// Cache whose result entries are bounded by `max_bytes` (LRU
    /// eviction). A spectrum larger than the whole budget is simply not
    /// cached.
    pub fn with_budget(max_bytes: usize) -> Self {
        Self {
            max_bytes,
            inner: Mutex::new(Inner {
                results: HashMap::new(),
                densities: HashMap::new(),
                recency: BTreeMap::new(),
                plans: HashMap::new(),
                bytes: 0,
                tick: 0,
            }),
            disk: None,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            plan_hits: AtomicU64::new(0),
            plan_misses: AtomicU64::new(0),
        }
    }

    /// Attach a persistent disk tier below the LRU. Every inserted result
    /// is **written through** to disk (not just spilled on eviction — a
    /// restart must find everything the process computed, and evicting at
    /// process death is exactly when no code runs), and a memory miss
    /// falls back to a disk read before reporting a miss to the caller.
    /// Disk I/O happens outside the in-memory mutex.
    pub fn with_disk(mut self, disk: DiskCache) -> Self {
        self.disk = Some(disk);
        self
    }

    /// The attached disk tier, if any.
    pub fn disk(&self) -> Option<&DiskCache> {
        self.disk.as_ref()
    }

    /// Approximate heap bytes a cached spectrum occupies (values buffer +
    /// entry bookkeeping) — the unit of the byte budget.
    fn entry_bytes(spectrum: &Spectrum) -> usize {
        spectrum.values.len() * std::mem::size_of::<f64>()
            + std::mem::size_of::<Spectrum>()
            + std::mem::size_of::<Signature>()
            + std::mem::size_of::<ResultEntry>()
    }

    /// Look a result up. A memory hit bumps the entry's LRU position and
    /// returns the shared spectrum — zero per-frequency work, zero
    /// allocation. A memory miss falls back to the disk tier (if one is
    /// attached): a valid spill file is promoted back into the LRU
    /// (without re-spilling) and served; the `hits`/`misses` counters
    /// track the memory tier, `disk_*` the fallback.
    pub fn get(&self, key: &Signature) -> Option<Arc<Spectrum>> {
        if let Some(spectrum) = self.get_mem(key) {
            return Some(spectrum);
        }
        let disk = self.disk.as_ref()?;
        let spectrum = Arc::new(disk.get(key)?);
        self.insert_mem(key, Arc::clone(&spectrum));
        Some(spectrum)
    }

    /// Memory-tier lookup (counts a hit or a miss).
    fn get_mem(&self, key: &Signature) -> Option<Arc<Spectrum>> {
        let mut guard = self.inner.lock().expect("cache poisoned");
        let inner = &mut *guard;
        inner.tick += 1;
        let tick = inner.tick;
        match inner.results.get_mut(key) {
            Some(e) => {
                inner.recency.remove(&e.last_used);
                inner.recency.insert(tick, *key);
                e.last_used = tick;
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(Arc::clone(&e.spectrum))
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Insert (or refresh) a result. With a disk tier attached the
    /// spectrum is written through to disk first (outside the mutex;
    /// content-addressed, so a re-insert of spilled content skips the
    /// write). In memory, least-recently-used entries are evicted until
    /// the byte budget holds (each eviction `O(log n)` through the
    /// recency index); returns how many were evicted. A spectrum that
    /// alone exceeds the memory budget is not stored in the LRU — but
    /// with a disk tier it remains servable from disk.
    ///
    /// A spectrum still flagged degraded after the escalation ladder is
    /// **refused outright** — neither spilled to disk nor admitted to the
    /// LRU (returns 0). This is the single admission gate of the
    /// numerical-health layer: a degraded result may be *served* flagged,
    /// once, but never replayed from cache as if it were trustworthy.
    pub fn insert(&self, key: Signature, spectrum: Arc<Spectrum>) -> u64 {
        if spectrum.health.is_degraded() {
            return 0;
        }
        if let Some(disk) = &self.disk {
            disk.put(&key, &spectrum);
        }
        self.insert_mem(&key, spectrum)
    }

    /// Memory-tier insert (LRU + byte budget only; no disk write).
    fn insert_mem(&self, key: &Signature, spectrum: Arc<Spectrum>) -> u64 {
        let key = *key;
        let bytes = Self::entry_bytes(&spectrum);
        let mut guard = self.inner.lock().expect("cache poisoned");
        let inner = &mut *guard;
        inner.tick += 1;
        let tick = inner.tick;
        if let Some(old) = inner.results.remove(&key) {
            inner.recency.remove(&old.last_used);
            inner.bytes -= old.bytes;
        }
        if bytes > self.max_bytes {
            return 0;
        }
        let evicted = inner.evict_for(bytes, self.max_bytes);
        inner.bytes += bytes;
        inner.recency.insert(tick, key);
        inner.results.insert(key, ResultEntry { spectrum, bytes, last_used: tick });
        self.evictions.fetch_add(evicted, Ordering::Relaxed);
        evicted
    }

    /// Approximate heap bytes a cached density occupies — the unit of the
    /// (shared) byte budget.
    fn density_entry_bytes(density: &SpectralDensity) -> usize {
        density.approx_bytes() + std::mem::size_of::<Signature>() + std::mem::size_of::<DensityEntry>()
    }

    /// Look a **density** result up (a [`Signature::for_density`] key).
    /// A hit bumps the entry's position in the same global LRU order the
    /// spectra use and returns the shared histogram. Densities are
    /// memory-only — there is no disk fallback — so a miss is final.
    /// Counts into the same `hits`/`misses` counters as spectra (one
    /// result cache, two value shapes).
    pub fn get_density(&self, key: &Signature) -> Option<Arc<SpectralDensity>> {
        let mut guard = self.inner.lock().expect("cache poisoned");
        let inner = &mut *guard;
        inner.tick += 1;
        let tick = inner.tick;
        match inner.densities.get_mut(key) {
            Some(e) => {
                inner.recency.remove(&e.last_used);
                inner.recency.insert(tick, *key);
                e.last_used = tick;
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(Arc::clone(&e.density))
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Insert (or refresh) a density result under the shared byte budget
    /// (global LRU against spectra **and** densities); returns how many
    /// entries were evicted. The numerical-health admission gate is
    /// identical to [`Self::insert`]: a density whose solves are still
    /// flagged degraded after the escalation ladder is refused outright.
    /// No disk write-through — densities are cheap derived summaries.
    pub fn insert_density(&self, key: Signature, density: Arc<SpectralDensity>) -> u64 {
        if density.is_degraded() {
            return 0;
        }
        let bytes = Self::density_entry_bytes(&density);
        let mut guard = self.inner.lock().expect("cache poisoned");
        let inner = &mut *guard;
        inner.tick += 1;
        let tick = inner.tick;
        if let Some(old) = inner.densities.remove(&key) {
            inner.recency.remove(&old.last_used);
            inner.bytes -= old.bytes;
        }
        if bytes > self.max_bytes {
            return 0;
        }
        let evicted = inner.evict_for(bytes, self.max_bytes);
        inner.bytes += bytes;
        inner.recency.insert(tick, key);
        inner.densities.insert(key, DensityEntry { density, bytes, last_used: tick });
        self.evictions.fetch_add(evicted, Ordering::Relaxed);
        evicted
    }

    /// Look a plan up by signature (bumping its LRU position). Counts a
    /// plan hit or miss.
    pub fn plan_lookup(&self, key: &Signature) -> Option<Arc<SpectralPlan>> {
        let mut inner = self.inner.lock().expect("cache poisoned");
        inner.tick += 1;
        let tick = inner.tick;
        match inner.plans.get_mut(key) {
            Some(e) => {
                e.last_used = tick;
                self.plan_hits.fetch_add(1, Ordering::Relaxed);
                Some(Arc::clone(&e.plan))
            }
            None => {
                self.plan_misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Store a freshly built plan. If another thread won a build race for
    /// the same signature, the incumbent is kept (so every caller shares
    /// one workspace pool) — the returned `Arc` is the plan to use.
    pub fn plan_store(&self, key: Signature, plan: Arc<SpectralPlan>) -> Arc<SpectralPlan> {
        let mut inner = self.inner.lock().expect("cache poisoned");
        inner.tick += 1;
        let tick = inner.tick;
        if let Some(e) = inner.plans.get_mut(&key) {
            e.last_used = tick;
            return Arc::clone(&e.plan);
        }
        while inner.plans.len() >= PLAN_CACHE_CAP {
            let lru = inner
                .plans
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| *k)
                .expect("len >= cap > 0");
            inner.plans.remove(&lru);
        }
        inner.plans.insert(key, PlanEntry { plan: Arc::clone(&plan), last_used: tick });
        plan
    }

    /// Get-or-build the plan for `kernel` on an `n×m` fine grid at
    /// `stride` under `opts`: plans with equal signatures are shared, so
    /// repeat jobs skip the phase-table construction *and* reuse the
    /// plan's warmed workspace pool. The build happens outside the cache
    /// lock (concurrent misses may race to build; one winner is kept).
    pub fn plan_for(
        &self,
        kernel: &ConvKernel,
        n: usize,
        m: usize,
        stride: usize,
        opts: LfaOptions,
    ) -> Arc<SpectralPlan> {
        let key = Signature::plan(kernel, n, m, stride, &opts);
        if let Some(plan) = self.plan_lookup(&key) {
            return plan;
        }
        let plan = Arc::new(SpectralPlan::with_stride(kernel, n, m, stride, opts));
        self.plan_store(key, plan)
    }

    /// Drop every cached result and plan from **memory** (counters are
    /// kept — they record lifetime traffic, not current contents). The
    /// disk tier is untouched: its files belong to the operator
    /// ([`DiskCache::purge`] empties it explicitly), and a post-`clear`
    /// lookup may still be served from disk.
    pub fn clear(&self) {
        let mut inner = self.inner.lock().expect("cache poisoned");
        inner.results.clear();
        inner.densities.clear();
        inner.recency.clear();
        inner.plans.clear();
        inner.bytes = 0;
    }

    /// Drop cached **results** from memory but keep the plans (and their
    /// warmed workspace pools). This is the restart-shaped probe the
    /// disk-tier bench and tests use: after `clear_results`, a repeat
    /// audit's values must come from disk while its plans stay warm —
    /// isolating disk-read cost from re-planning cost.
    pub fn clear_results(&self) {
        let mut inner = self.inner.lock().expect("cache poisoned");
        inner.results.clear();
        inner.densities.clear();
        inner.recency.clear();
        inner.bytes = 0;
    }

    /// Current counters and occupancy (both tiers).
    pub fn stats(&self) -> CacheStats {
        let disk = self.disk.as_ref().map(|d| d.stats()).unwrap_or_default();
        let inner = self.inner.lock().expect("cache poisoned");
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            plan_hits: self.plan_hits.load(Ordering::Relaxed),
            plan_misses: self.plan_misses.load(Ordering::Relaxed),
            entries: inner.results.len(),
            density_entries: inner.densities.len(),
            plan_entries: inner.plans.len(),
            bytes: inner.bytes,
            capacity: self.max_bytes,
            disk_hits: disk.hits,
            disk_misses: disk.misses,
            disk_spills: disk.spills,
            disk_corruptions: disk.corruptions,
        }
    }
}

impl Default for SpectralCache {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numeric::Pcg64;

    fn kernel(seed: u64) -> ConvKernel {
        let mut rng = Pcg64::seeded(seed);
        ConvKernel::random_he(3, 2, 3, 3, &mut rng)
    }

    fn spectrum_of(plan: &SpectralPlan) -> Arc<Spectrum> {
        Arc::new(plan.execute())
    }

    #[test]
    fn signature_is_content_addressed() {
        let k = kernel(1);
        let opts = LfaOptions::default();
        let a = Signature::result(&k, 8, 8, 1, &opts, SpectrumRequest::Full);
        let b = Signature::result(&k.clone(), 8, 8, 1, &opts, SpectrumRequest::Full);
        assert_eq!(a, b, "equal content, equal signature");
        // Any single axis changing changes the signature.
        let mut k2 = k.clone();
        k2.data[0] += 1e-16;
        assert_ne!(Signature::result(&k2, 8, 8, 1, &opts, SpectrumRequest::Full), a);
        assert_ne!(Signature::result(&k, 8, 4, 1, &opts, SpectrumRequest::Full), a);
        assert_ne!(Signature::result(&k, 8, 8, 2, &opts, SpectrumRequest::Full), a);
        assert_ne!(Signature::result(&k, 8, 8, 1, &opts, SpectrumRequest::TopK(2)), a);
        let off = LfaOptions { folding: Fold::Off, ..opts };
        assert_ne!(Signature::result(&k, 8, 8, 1, &off, SpectrumRequest::Full), a);
        let gram = LfaOptions { solver: BlockSolver::GramEigen, ..opts };
        assert_ne!(Signature::result(&k, 8, 8, 1, &gram, SpectrumRequest::Full), a);
        let planar = LfaOptions { layout: BlockLayout::PlanarStrided, ..opts };
        assert_ne!(Signature::result(&k, 8, 8, 1, &planar, SpectrumRequest::Full), a);
        // Structure fields hash: bit-identical weight tensors describe
        // different operators when grouped / dilated / transposed, so
        // each must miss against the dense entry — and against each
        // other.
        let kg = k.clone().with_groups(3);
        let kd = k.clone().with_dilation(2);
        let kt = k.clone().with_transposed(true);
        assert_ne!(Signature::result(&kg, 8, 8, 1, &opts, SpectrumRequest::Full), a);
        assert_ne!(Signature::result(&kd, 8, 8, 1, &opts, SpectrumRequest::Full), a);
        assert_ne!(Signature::result(&kt, 8, 8, 1, &opts, SpectrumRequest::Full), a);
        assert_ne!(
            Signature::result(&kg, 8, 8, 1, &opts, SpectrumRequest::Full),
            Signature::result(&kd, 8, 8, 1, &opts, SpectrumRequest::Full)
        );
        // Precision is pinned: each tier caches independently.
        let f32p = LfaOptions { precision: Precision::F32, ..opts };
        assert_ne!(Signature::result(&k, 8, 8, 1, &f32p, SpectrumRequest::Full), a);
        let refp = LfaOptions { precision: Precision::F32Refined, ..opts };
        assert_ne!(Signature::result(&k, 8, 8, 1, &refp, SpectrumRequest::Full), a);
        assert_ne!(
            Signature::result(&k, 8, 8, 1, &f32p, SpectrumRequest::Full),
            Signature::result(&k, 8, 8, 1, &refp, SpectrumRequest::Full)
        );
        // Re-pinning equals computing at that tier directly — this is how
        // the scheduler keys PJRT (f32) results without a second hash.
        assert_eq!(
            a.with_precision(Precision::F32),
            Signature::result(&k, 8, 8, 1, &f32p, SpectrumRequest::Full)
        );
        assert_eq!(a.with_precision(Precision::F64), a);
        // Thread count does NOT change a result signature …
        let t8 = LfaOptions { threads: 8, ..opts };
        assert_eq!(Signature::result(&k, 8, 8, 1, &t8, SpectrumRequest::Full), a);
        // … but does change a plan signature (and 0 = auto resolves).
        let p1 = Signature::plan(&k, 8, 8, 1, &LfaOptions { threads: 1, ..opts });
        let p8 = Signature::plan(&k, 8, 8, 1, &t8);
        assert_ne!(p1, p8);
        let auto = Signature::plan(&k, 8, 8, 1, &LfaOptions { threads: 0, ..opts });
        let explicit = Signature::plan(
            &k,
            8,
            8,
            1,
            &LfaOptions { threads: crate::engine::resolve_threads(0), ..opts },
        );
        assert_eq!(auto, explicit);
        // Derived signatures equal directly computed ones (no re-hash).
        assert_eq!(auto.for_request(SpectrumRequest::Full), a);
        assert_eq!(a.for_plan(opts.threads), auto);
        assert_eq!(a.for_request(SpectrumRequest::TopK(2)).for_request(SpectrumRequest::Full), a);
        // Equivalent top-k requests share one key: k clamps to the rank
        // (min(c_out, c_in) = 2 here), so TopK(2), TopK(3) and TopK(9)
        // all run the identical sweep and must hit the same entry.
        let top2 = Signature::result(&k, 8, 8, 1, &opts, SpectrumRequest::TopK(2));
        assert_eq!(Signature::result(&k, 8, 8, 1, &opts, SpectrumRequest::TopK(3)), top2);
        assert_eq!(a.for_request(SpectrumRequest::TopK(9)), top2);
        assert_ne!(Signature::result(&k, 8, 8, 1, &opts, SpectrumRequest::TopK(1)), top2);
    }

    #[test]
    fn file_digest_is_stable_and_field_sensitive() {
        let k = kernel(9);
        let opts = LfaOptions::default();
        let a = Signature::result(&k, 8, 8, 1, &opts, SpectrumRequest::Full);
        assert_eq!(a.file_digest(), a.file_digest(), "deterministic");
        assert_eq!(
            a.file_digest(),
            Signature::result(&k.clone(), 8, 8, 1, &opts, SpectrumRequest::Full).file_digest(),
            "equal content, equal digest"
        );
        // Every enum axis feeds the digest (spill files for different
        // solver/fold/precision/request configurations must not collide).
        let mut seen = vec![a.file_digest()];
        for sig in [
            Signature::result(&k, 8, 8, 1, &opts, SpectrumRequest::TopK(1)),
            Signature::result(
                &k,
                8,
                8,
                1,
                &LfaOptions { folding: Fold::Off, ..opts },
                SpectrumRequest::Full,
            ),
            Signature::result(
                &k,
                8,
                8,
                1,
                &LfaOptions { solver: BlockSolver::GramEigen, ..opts },
                SpectrumRequest::Full,
            ),
            a.with_precision(Precision::F32),
            a.with_precision(Precision::F32Refined),
            a.for_plan(1),
            a.for_plan(2),
        ] {
            let d = sig.file_digest();
            assert!(!seen.contains(&d), "digest collision for {sig:?}");
            seen.push(d);
        }
    }

    #[test]
    fn get_insert_roundtrip_and_counters() {
        let cache = SpectralCache::new();
        let k = kernel(2);
        let opts = LfaOptions { threads: 1, ..Default::default() };
        let key = Signature::result(&k, 6, 6, 1, &opts, SpectrumRequest::Full);
        assert!(cache.get(&key).is_none());
        let plan = SpectralPlan::new(&k, 6, 6, opts);
        let sp = spectrum_of(&plan);
        cache.insert(key, Arc::clone(&sp));
        let hit = cache.get(&key).expect("hit");
        assert!(Arc::ptr_eq(&hit, &sp), "hit returns the shared spectrum");
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.evictions), (1, 1, 0));
        assert_eq!(s.entries, 1);
        assert!(s.bytes > 0 && s.bytes <= s.capacity);
    }

    #[test]
    fn lru_evicts_under_byte_budget() {
        let k = kernel(3);
        let opts = LfaOptions { threads: 1, ..Default::default() };
        let plan = SpectralPlan::new(&k, 4, 4, opts);
        let sp = spectrum_of(&plan);
        let one = SpectralCache::entry_bytes(&sp);
        // Room for exactly two entries. Keys differ by grid size (the
        // cache never validates an entry against its key, so inserting
        // the same spectrum under each key keeps the sizes equal).
        let cache = SpectralCache::with_budget(2 * one);
        let keys: Vec<Signature> = (0..3)
            .map(|i| Signature::result(&k, 4, 4 + i, 1, &opts, SpectrumRequest::Full))
            .collect();
        cache.insert(keys[0], Arc::clone(&sp));
        cache.insert(keys[1], Arc::clone(&sp));
        // Touch key 0 so key 1 is the LRU …
        assert!(cache.get(&keys[0]).is_some());
        // … and inserting a third evicts key 1, not key 0.
        assert_eq!(cache.insert(keys[2], Arc::clone(&sp)), 1);
        assert!(cache.get(&keys[0]).is_some());
        assert!(cache.get(&keys[1]).is_none(), "LRU entry evicted");
        assert!(cache.get(&keys[2]).is_some());
        let s = cache.stats();
        assert_eq!(s.evictions, 1);
        assert_eq!(s.entries, 2);
        // An entry bigger than the whole budget is not stored.
        let tiny = SpectralCache::with_budget(one - 1);
        assert_eq!(tiny.insert(keys[0], Arc::clone(&sp)), 0);
        assert!(tiny.get(&keys[0]).is_none());
    }

    #[test]
    fn plan_cache_shares_planned_objects() {
        let cache = SpectralCache::new();
        let k = kernel(4);
        let opts = LfaOptions { threads: 1, ..Default::default() };
        let a = cache.plan_for(&k, 8, 8, 1, opts);
        let b = cache.plan_for(&k, 8, 8, 1, opts);
        assert!(Arc::ptr_eq(&a, &b), "equal plan signatures share one plan");
        let c = cache.plan_for(&k, 8, 8, 2, opts);
        assert!(!Arc::ptr_eq(&a, &c), "different stride, different plan");
        let s = cache.stats();
        assert_eq!((s.plan_hits, s.plan_misses, s.plan_entries), (1, 2, 2));
        // Shared plans execute identically to fresh ones.
        assert_eq!(a.execute().values, SpectralPlan::new(&k, 8, 8, opts).execute().values);
    }

    #[test]
    fn density_signature_is_its_own_axis() {
        let k = kernel(7);
        let opts = LfaOptions::default();
        let full = Signature::result(&k, 8, 8, 1, &opts, SpectrumRequest::Full);
        let d64 = full.for_density(DensityRequest { bins: 64, sample: 1 });
        // A density key never collides with a spectrum key of the same
        // content, and every density parameter is part of the address.
        assert_ne!(d64, full);
        assert_ne!(d64, full.for_request(SpectrumRequest::TopK(1)));
        assert_ne!(d64, full.for_density(DensityRequest { bins: 128, sample: 1 }));
        assert_ne!(d64, full.for_density(DensityRequest { bins: 64, sample: 2 }));
        // Deriving is idempotent content-wise and clears the request axis.
        assert_eq!(full.for_density(DensityRequest { bins: 64, sample: 1 }), d64);
        assert_eq!(d64.for_request(SpectrumRequest::Full), full);
        // The file digest separates density keys too (tag 3), while the
        // pre-existing words are untouched for non-density signatures.
        let mut seen = vec![full.file_digest()];
        for sig in [
            d64,
            full.for_density(DensityRequest { bins: 128, sample: 1 }),
            full.for_density(DensityRequest { bins: 64, sample: 2 }),
        ] {
            let d = sig.file_digest();
            assert!(!seen.contains(&d), "digest collision for {sig:?}");
            seen.push(d);
        }
    }

    #[test]
    fn density_entries_roundtrip_and_share_the_budget() {
        let k = kernel(8);
        let opts = LfaOptions { threads: 1, ..Default::default() };
        let plan = SpectralPlan::new(&k, 6, 6, opts);
        let req = DensityRequest { bins: 32, sample: 1 };
        let dens = Arc::new(plan.density(req));
        let key = plan.density_signature(req);
        let cache = SpectralCache::new();
        assert!(cache.get_density(&key).is_none());
        cache.insert_density(key, Arc::clone(&dens));
        let hit = cache.get_density(&key).expect("hit");
        assert!(Arc::ptr_eq(&hit, &dens), "hit returns the shared density");
        assert_eq!(cache.stats().density_entries, 1);
        // Global LRU: a byte budget sized for one entry evicts across
        // stores — inserting a spectrum after the density evicts the
        // density (it is the older touch), and vice versa.
        let sp = spectrum_of(&plan);
        let skey = plan.result_signature(SpectrumRequest::Full);
        let one = SpectralCache::entry_bytes(&sp).max(SpectralCache::density_entry_bytes(&dens));
        let tiny = SpectralCache::with_budget(one);
        tiny.insert_density(key, Arc::clone(&dens));
        assert_eq!(tiny.insert(skey, Arc::clone(&sp)), 1, "density evicted");
        assert!(tiny.get_density(&key).is_none());
        assert!(tiny.get(&skey).is_some());
        assert_eq!(tiny.insert_density(key, Arc::clone(&dens)), 1, "spectrum evicted");
        assert!(tiny.get(&skey).is_none());
        assert!(tiny.get_density(&key).is_some());
        // clear_results drops densities too.
        tiny.clear_results();
        assert!(tiny.get_density(&key).is_none());
        assert_eq!(tiny.stats().density_entries, 0);
    }

    #[test]
    fn clear_empties_contents_but_keeps_counters() {
        let cache = SpectralCache::new();
        let k = kernel(5);
        let opts = LfaOptions { threads: 1, ..Default::default() };
        let key = Signature::result(&k, 4, 4, 1, &opts, SpectrumRequest::Full);
        let plan = cache.plan_for(&k, 4, 4, 1, opts);
        cache.insert(key, spectrum_of(&plan));
        assert!(cache.get(&key).is_some());
        cache.clear();
        assert!(cache.get(&key).is_none());
        let s = cache.stats();
        assert_eq!((s.entries, s.plan_entries, s.bytes), (0, 0, 0));
        assert!(s.hits >= 1 && s.plan_misses >= 1, "counters survive clear");
    }
}
