//! The planned, allocation-free execution core behind every SVD path.
//!
//! The paper's `O(n·m·c³)` advantage over the FFT route only materializes
//! when the per-frequency hot loop is allocation-free and the
//! "embarrassingly parallel" dual grid is driven by one engine rather than
//! several duplicated pipelines. This module is that engine:
//!
//! - [`SpectralPlan`] — created once per `(kernel, grid, stride, layout,
//!   solver, threads)`; precomputes the twiddle/phase tables and owns a
//!   pool of per-worker scratch [`Workspace`]s. `execute()` can be called
//!   many times (training-loop clipping, repeated audits) without
//!   re-planning or re-allocating.
//! - [`SpectrumRequest`] — how much of the spectrum an execution computes:
//!   the full per-frequency SVD, or only the `k` largest values per
//!   frequency via warm-started Krylov iteration
//!   ([`SpectralPlan::execute_topk`]) — the regime spectral-norm clipping
//!   and Lipschitz certification actually need.
//! - **Conjugate-pair frequency folding** ([`crate::lfa::Fold`], on by
//!   default): real kernels give `A(−θ) = conj(A(θ))`, so every full-grid
//!   execution solves only the fundamental domain of `θ → −θ` (about half
//!   the blocks; self-paired DC/Nyquist frequencies exactly once) and
//!   mirrors the conjugate half — values copied, factors conjugated.
//!   `LfaOptions { folding: Fold::Off, .. }` is the unfolded reference.
//! - [`SpectrumSink`] — the pluggable consumer of the unified sweep: the
//!   assembly sinks ([`FullAssembly`], [`TopKAssembly`], [`FactorAssembly`])
//!   reproduce the classic buffers, [`DensitySink`] streams singular-value
//!   histograms ([`SpectralPlan::density`], shaped by [`DensityRequest`]).
//!   New per-frequency analytics are one `impl SpectrumSink`, not a new
//!   driver.
//! - [`Workspace`] — per-worker scratch: symbol block, per-tap phases, the
//!   Jacobi / Gram solver work matrices, and the top-k Krylov basis that
//!   carries warm starts between neighboring frequencies, pooled in a
//!   [`WorkspacePool`].
//! - [`SpectralBackend`] — execution strategies over a plan:
//!   [`NativeSerial`], [`NativeThreaded`], and (feature `pjrt`) a PJRT
//!   artifact backend.
//! - [`ModelPlan`] — every conv layer of a model planned once: layers with
//!   equal block shape share one workspace pool, and whole-model audits,
//!   clipping and compression run as a single batched sweep (top-k variant:
//!   [`ModelPlan::top_k_all`]).
//!
//! `lfa::svd`, `lfa::stride`, the FFT baseline's SVD stage and the
//! coordinator's tile workers are all thin wrappers over this module.

pub mod backend;
pub mod cache;
pub mod disk_cache;
pub mod model_plan;
pub mod plan;
pub mod sink;
pub mod workspace;

#[cfg(feature = "pjrt")]
pub use backend::PjrtBackend;
pub use backend::{NativeSerial, NativeThreaded, SpectralBackend};
pub use cache::{CacheStats, Signature, SpectralCache, DEFAULT_CACHE_BYTES};
pub use disk_cache::{DiskCache, DiskStats};
pub use model_plan::{
    CachedExecution, LayerDensity, LayerSpectrum, ModelPlan, ModelSpectra, ModelTopK,
};
pub use plan::{SpectralPlan, SweepOptions, TopKResult};
pub use sink::{DensitySink, FactorAssembly, FullAssembly, SpectrumSink, TopKAssembly};
pub use workspace::{Workspace, WorkspacePool};

/// How much of the spectrum one execution computes.
///
/// `Full` runs the fused symbol→SVD pipeline (every `min(c_out, c_in)`
/// singular value per frequency). `TopK(k)` runs Krylov-accelerated power
/// iteration per frequency instead ([`crate::linalg::power::block_topk`]),
/// warm-started along the plan's locality-preserving sweep order — the
/// right mode when only the extreme values are consumed (spectral-norm
/// clipping, Lipschitz bounds, low-rank compression). `k` is clamped to
/// the per-frequency rank.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SpectrumRequest {
    /// Every singular value per frequency (the fused Jacobi/Gram path).
    Full,
    /// Only the `k` largest singular values per frequency.
    TopK(usize),
}

impl SpectrumRequest {
    /// Values this request stores per frequency, for a block of rank
    /// `rank = min(c_out, c_in)`.
    pub fn values_per_freq(&self, rank: usize) -> usize {
        match *self {
            SpectrumRequest::Full => rank,
            SpectrumRequest::TopK(k) => k.clamp(1, rank.max(1)),
        }
    }
}

/// Shape of a streaming singular-value **density** request
/// ([`SpectralPlan::density`]): histogram resolution plus the coarse
/// sub-lattice step over the dual grid. `sample == 1` is an exact census;
/// `sample == s > 1` solves every `s`-th frequency row and column
/// (`~1/s²` of the SVD work) and reports the sampling error bar
/// ([`crate::lfa::spectrum::SpectralDensity::cdf_epsilon`]). Hashable —
/// density results are keyed and cached like spectra.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct DensityRequest {
    /// Histogram bins over `[0, σ_max]`.
    pub bins: u32,
    /// Dual-grid sub-lattice step (1 = census); clamped to ≥ 1.
    pub sample: u32,
}

impl Default for DensityRequest {
    fn default() -> Self {
        Self { bins: 64, sample: 1 }
    }
}

/// Resolve a thread-count option: `0` means auto (`available_parallelism`),
/// anything else is taken literally. This is the single source of truth for
/// the `threads == 0` convention shared by [`crate::lfa::LfaOptions`], the
/// coordinator's scheduler, and the CLI.
pub fn resolve_threads(threads: usize) -> usize {
    if threads == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        threads
    }
}

#[cfg(test)]
mod tests {
    use super::SpectrumRequest;

    #[test]
    fn zero_threads_resolves_to_at_least_one() {
        assert!(super::resolve_threads(0) >= 1);
        assert_eq!(super::resolve_threads(3), 3);
    }

    #[test]
    fn request_values_per_freq_clamps() {
        assert_eq!(SpectrumRequest::Full.values_per_freq(4), 4);
        assert_eq!(SpectrumRequest::TopK(2).values_per_freq(4), 2);
        assert_eq!(SpectrumRequest::TopK(9).values_per_freq(4), 4, "clamped to rank");
        assert_eq!(SpectrumRequest::TopK(0).values_per_freq(4), 1, "at least one value");
    }
}
