//! The planned, allocation-free execution core behind every SVD path.
//!
//! The paper's `O(n·m·c³)` advantage over the FFT route only materializes
//! when the per-frequency hot loop is allocation-free and the
//! "embarrassingly parallel" dual grid is driven by one engine rather than
//! several duplicated pipelines. This module is that engine:
//!
//! - [`SpectralPlan`] — created once per `(kernel, grid, stride, layout,
//!   solver, threads)`; precomputes the twiddle/phase tables and owns a
//!   pool of per-worker scratch [`Workspace`]s. `execute()` can be called
//!   many times (training-loop clipping, repeated audits) without
//!   re-planning or re-allocating.
//! - [`Workspace`] — per-worker scratch: symbol block, per-tap phases, and
//!   the Jacobi / Gram solver work matrices, pooled in a [`WorkspacePool`].
//! - [`SpectralBackend`] — execution strategies over a plan:
//!   [`NativeSerial`], [`NativeThreaded`], and (feature `pjrt`) a PJRT
//!   artifact backend.
//! - [`ModelPlan`] — every conv layer of a model planned once: layers with
//!   equal block shape share one workspace pool, and whole-model audits,
//!   clipping and compression run as a single batched sweep.
//!
//! `lfa::svd`, `lfa::stride`, the FFT baseline's SVD stage and the
//! coordinator's tile workers are all thin wrappers over this module.

pub mod backend;
pub mod model_plan;
pub mod plan;
pub mod workspace;

#[cfg(feature = "pjrt")]
pub use backend::PjrtBackend;
pub use backend::{NativeSerial, NativeThreaded, SpectralBackend};
pub use model_plan::{LayerSpectrum, ModelPlan, ModelSpectra};
pub use plan::SpectralPlan;
pub use workspace::{Workspace, WorkspacePool};

/// Resolve a thread-count option: `0` means auto (`available_parallelism`),
/// anything else is taken literally. This is the single source of truth for
/// the `threads == 0` convention shared by [`crate::lfa::LfaOptions`], the
/// coordinator's scheduler, and the CLI.
pub fn resolve_threads(threads: usize) -> usize {
    if threads == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        threads
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn zero_threads_resolves_to_at_least_one() {
        assert!(super::resolve_threads(0) >= 1);
        assert_eq!(super::resolve_threads(3), 3);
    }
}
