//! Pluggable per-frequency consumers of the engine's unified sweep.
//!
//! [`SpectralPlan::sweep_with`](super::SpectralPlan::sweep_with) (and the
//! `execute*` entry points built on the same internal driver) solves one
//! frequency at a time and hands each result to a [`SpectrumSink`]. The
//! sink owns *what happens to* a per-frequency result; the sweep owns
//! everything else — visit order, fold/mirror bookkeeping, precision
//! tiers, the escalation ladder, workspace pooling. The contract per
//! canonical frequency `f = ki·mc + kj` is:
//!
//! ```text
//!   sweep                           sink
//!   ─────                           ────
//!   slot(f) ──────────────────────▶ &mut [f64]   (preallocated, per_freq long)
//!   … solver writes σ descending …
//!   commit(f, ki, kj) ────────────▶ result at f is final
//!   mirror(src, dst) ─────────────▶ dst is a conjugate mirror of src
//! ```
//!
//! `mirror` is only emitted on folded plans, exactly once per
//! non-canonical frequency (σ(−θ) = σ(θ) for real kernels). A sink may
//! treat it as a copy ([`FullAssembly`], [`TopKAssembly`]), a weighted
//! pre-count at `commit` time ([`DensitySink`]), or ignore it entirely.
//! The sweep performs **zero heap allocation per frequency** — `slot`
//! must hand back preallocated storage, which is what keeps the sink
//! indirection free (see `tests/engine_alloc.rs`).
//!
//! The built-in sinks reproduce the engine's historical outputs
//! bit-identically; [`DensitySink`] is the first genuinely new consumer
//! (streaming singular-value histograms). Adding another consumer is one
//! `impl SpectrumSink` — not a driver fork.

use super::plan::SpectralPlan;
use super::DensityRequest;
use crate::lfa::spectrum::{conj_factor, SpectralDensity, SpectrumHealth};
use crate::numeric::CMat;

/// A per-frequency consumer of the unified sweep. See the module docs for
/// the `slot → commit → mirror` protocol and its guarantees.
pub trait SpectrumSink {
    /// Storage for frequency `f`'s singular values (`values_per_freq`
    /// long). The solver writes the descending values straight into this
    /// slice — the sink must not allocate here (the sweep's hot loop is
    /// allocation-free).
    fn slot(&mut self, f: usize) -> &mut [f64];

    /// Frequency `f = ki·mc + kj` has been solved; the values last written
    /// through [`Self::slot`] are final. Streaming sinks fold the slot
    /// into their state here; assembly sinks that hand out in-place slices
    /// need do nothing.
    fn commit(&mut self, f: usize, ki: usize, kj: usize);

    /// Frequency `dst` is the conjugate mirror of already-committed
    /// frequency `src` (`σ(dst) = σ(src)`). Emitted only on folded plans,
    /// exactly once per non-canonical frequency.
    fn mirror(&mut self, src: usize, dst: usize);
}

/// Assembles a full-spectrum sweep into a caller-provided frequency-major
/// buffer — the sink behind every `SpectrumRequest::Full` execution and
/// the coordinator's full tiles. `slot` hands out the destination slice
/// itself, so committing is free and the output is written exactly once,
/// bit-identical to the historical row drivers.
pub struct FullAssembly<'a> {
    out: &'a mut [f64],
    per_freq: usize,
    /// Global frequency index of `out[0]` (`row_lo · mc`): strips index
    /// relative to their own start.
    base: usize,
}

impl<'a> FullAssembly<'a> {
    /// Assembly over solved rows starting at `row_lo`, writing into `out`
    /// (`rows · mc · rank` values).
    pub fn strip(plan: &SpectralPlan, row_lo: usize, out: &'a mut [f64]) -> Self {
        Self { per_freq: plan.rank(), base: row_lo * plan.coarse_cols(), out }
    }
}

impl SpectrumSink for FullAssembly<'_> {
    #[inline]
    fn slot(&mut self, f: usize) -> &mut [f64] {
        let r = self.per_freq;
        let o = (f - self.base) * r;
        &mut self.out[o..o + r]
    }

    #[inline]
    fn commit(&mut self, _f: usize, _ki: usize, _kj: usize) {}

    #[inline]
    fn mirror(&mut self, src: usize, dst: usize) {
        let r = self.per_freq;
        let s = (src - self.base) * r;
        let d = (dst - self.base) * r;
        self.out.copy_within(s..s + r, d);
    }
}

/// [`FullAssembly`]'s top-k twin: `k` values per frequency
/// (`plan.topk_per_freq(k)`), same in-place contract, behind every
/// `SpectrumRequest::TopK` execution and the coordinator's top-k tiles.
pub struct TopKAssembly<'a> {
    out: &'a mut [f64],
    per_freq: usize,
    base: usize,
}

impl<'a> TopKAssembly<'a> {
    /// Assembly over solved rows starting at `row_lo`, writing into `out`
    /// (`rows · mc · topk_per_freq(k)` values).
    pub fn strip(plan: &SpectralPlan, k: usize, row_lo: usize, out: &'a mut [f64]) -> Self {
        Self { per_freq: plan.topk_per_freq(k), base: row_lo * plan.coarse_cols(), out }
    }
}

impl SpectrumSink for TopKAssembly<'_> {
    #[inline]
    fn slot(&mut self, f: usize) -> &mut [f64] {
        let r = self.per_freq;
        let o = (f - self.base) * r;
        &mut self.out[o..o + r]
    }

    #[inline]
    fn commit(&mut self, _f: usize, _ki: usize, _kj: usize) {}

    #[inline]
    fn mirror(&mut self, src: usize, dst: usize) {
        let r = self.per_freq;
        let s = (src - self.base) * r;
        let d = (dst - self.base) * r;
        self.out.copy_within(s..s + r, d);
    }
}

/// The factor paths' sink: owns the values buffer **and** the per-frequency
/// `U`/`V` factor matrices the SVD paths
/// ([`SpectralPlan::full_svd`](super::SpectralPlan::full_svd),
/// [`SpectralPlan::topk_svd`](super::SpectralPlan::topk_svd)) produce.
/// The `SpectrumSink` impl covers the values plane; factor mirroring —
/// conjugation plus the stride aliasing permutation on `V` — needs the
/// plan's geometry and goes through [`Self::mirror_triplet`].
pub struct FactorAssembly {
    pub(crate) per_freq: usize,
    /// Frequency-major singular values, `freqs · per_freq` long.
    pub(crate) values: Vec<f64>,
    /// Per-frequency left factors.
    pub(crate) u: Vec<CMat>,
    /// Per-frequency right factors.
    pub(crate) v: Vec<CMat>,
}

impl FactorAssembly {
    /// Factor storage for the whole dual grid: `per_freq` values and
    /// `rows×per_freq` / `cols×per_freq` factor matrices per frequency.
    /// Fresh allocations by necessity — the factors are the output.
    pub fn new(plan: &SpectralPlan, per_freq: usize, rows: usize, cols: usize) -> Self {
        let freqs = plan.freqs();
        Self {
            per_freq,
            values: vec![0.0f64; freqs * per_freq],
            u: (0..freqs).map(|_| CMat::zeros(rows, per_freq)).collect(),
            v: (0..freqs).map(|_| CMat::zeros(cols, per_freq)).collect(),
        }
    }

    /// Mirror the whole triplet of canonical frequency `src` (coords
    /// `(ki, kj)`) onto its conjugate partner `dst`: values copied,
    /// `U(−θ) = conj(U(θ))`, `V(−θ) = Pᵀ·conj(V(θ))` with the stride
    /// aliasing permutation `P` — exact by the symbol symmetry.
    pub fn mirror_triplet(
        &mut self,
        plan: &SpectralPlan,
        src: usize,
        dst: usize,
        ki: usize,
        kj: usize,
    ) {
        let r = self.per_freq;
        self.values.copy_within(src * r..(src + 1) * r, dst * r);
        self.u[dst] = conj_factor(&self.u[src]);
        self.v[dst] = plan.mirror_right_factor(&self.v[src], ki, kj);
    }
}

impl SpectrumSink for FactorAssembly {
    #[inline]
    fn slot(&mut self, f: usize) -> &mut [f64] {
        let r = self.per_freq;
        &mut self.values[f * r..(f + 1) * r]
    }

    #[inline]
    fn commit(&mut self, _f: usize, _ki: usize, _kj: usize) {}

    /// Values-plane mirror only; the factor sweeps follow up with
    /// [`Self::mirror_triplet`] for the vectors.
    #[inline]
    fn mirror(&mut self, src: usize, dst: usize) {
        let r = self.per_freq;
        self.values.copy_within(src * r..(src + 1) * r, dst * r);
    }
}

/// Streaming singular-value **histogram** — the first post-refactor sink,
/// and the engine's answer to the asymptotic-distribution workload (Yi
/// 2020): the bulk shape of the spectrum without materializing
/// `n·m·rank` values. Each committed frequency's values are binned over
/// `[0, hi]` immediately and only `O(bins)` state is retained.
///
/// Folding never biases the histogram: every committed canonical
/// frequency is weighted by its conjugate-mirror multiplicity (2 for a
/// paired frequency, 1 for a self-paired one), so [`Self::mirror`] is a
/// no-op and the weighted counts sum to the full-grid census. This also
/// makes the sink correct under coarse sub-lattice sampling, where
/// mirrors of sampled frequencies are never visited at all.
pub struct DensitySink {
    folded: bool,
    nc: usize,
    mc: usize,
    /// Histogram upper edge (the exact σ_max from the extremes pass);
    /// values ≥ `hi` clamp into the last bin.
    hi: f64,
    bins: Vec<u64>,
    /// Per-frequency slot the solver writes into (`rank` long) — reused
    /// across frequencies, folded into `bins` at commit.
    scratch: Vec<f64>,
    /// Smallest committed value (the sampled σ_min proxy).
    min: f64,
    /// Frequencies actually solved.
    solved: u64,
    /// Frequencies accounted for including mirror weights.
    covered: u64,
}

impl DensitySink {
    /// A histogram sink for `plan` with `bins` bins over `[0, hi]`.
    pub fn new(plan: &SpectralPlan, bins: usize, hi: f64) -> Self {
        Self {
            folded: plan.folded(),
            nc: plan.coarse_rows(),
            mc: plan.coarse_cols(),
            hi,
            bins: vec![0u64; bins.max(1)],
            scratch: vec![0.0f64; plan.rank()],
            min: f64::INFINITY,
            solved: 0,
            covered: 0,
        }
    }

    /// How many grid frequencies `(ki, kj)` accounts for: itself plus its
    /// conjugate mirror when folding pairs them.
    #[inline]
    fn weight(&self, ki: usize, kj: usize) -> u64 {
        if !self.folded {
            return 1;
        }
        let (mi, mj) = ((self.nc - ki) % self.nc, (self.mc - kj) % self.mc);
        if (mi, mj) == (ki, kj) {
            1
        } else {
            2
        }
    }

    /// Fold another worker's partial histogram into this one (counts add,
    /// min mins) — the threaded density sweep's reduction.
    pub fn merge(&mut self, other: &DensitySink) {
        debug_assert_eq!(self.bins.len(), other.bins.len());
        for (a, b) in self.bins.iter_mut().zip(&other.bins) {
            *a += b;
        }
        if other.min < self.min {
            self.min = other.min;
        }
        self.solved += other.solved;
        self.covered += other.covered;
    }

    /// Package the accumulated histogram as a [`SpectralDensity`] carrying
    /// the plan's grid metadata and the sweep's effort/health ledger.
    pub(crate) fn into_density(
        self,
        plan: &SpectralPlan,
        req: DensityRequest,
        sigma_max: f64,
        iterations: u64,
        health: SpectrumHealth,
    ) -> SpectralDensity {
        SpectralDensity {
            n: plan.coarse_rows(),
            m: plan.coarse_cols(),
            per_freq: plan.rank(),
            bins: self.bins,
            hi: self.hi,
            sigma_max,
            sigma_min_sampled: if self.min.is_finite() { self.min } else { 0.0 },
            solved_freqs: self.solved,
            covered_freqs: self.covered,
            total_freqs: plan.freqs() as u64,
            sample: req.sample.max(1),
            iterations,
            health,
        }
    }
}

impl SpectrumSink for DensitySink {
    #[inline]
    fn slot(&mut self, _f: usize) -> &mut [f64] {
        &mut self.scratch
    }

    fn commit(&mut self, _f: usize, ki: usize, kj: usize) {
        let w = self.weight(ki, kj);
        self.solved += 1;
        self.covered += w;
        let nb = self.bins.len();
        let inv = if self.hi > 0.0 { nb as f64 / self.hi } else { 0.0 };
        for i in 0..self.scratch.len() {
            let v = self.scratch[i];
            if v < self.min {
                self.min = v;
            }
            let b = ((v * inv) as usize).min(nb - 1);
            self.bins[b] += w;
        }
    }

    /// Mirrors are pre-counted by [`Self::weight`] at commit time.
    #[inline]
    fn mirror(&mut self, _src: usize, _dst: usize) {}
}
