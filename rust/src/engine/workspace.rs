//! Per-worker scratch for the planned execution core.
//!
//! A [`Workspace`] owns everything a worker needs to turn one frequency into
//! its singular values: the symbol block buffer, the per-tap phase scratch,
//! and the solver work matrices (one-sided Jacobi row form, Gram/eigen work
//! matrix). All buffers are sized once — either at plan construction or on a
//! worker's first block — so the per-frequency hot loop performs **zero heap
//! allocation**. Workspaces live in the plan's pool (see
//! [`super::SpectralPlan`]) and are checked out per execution range, which
//! makes repeated `execute()` calls on one plan allocation-free end to end.

use crate::lfa::svd::BlockSolver;
use crate::linalg::jacobi_eig::{self, GramScratch};
use crate::linalg::jacobi_svd::{self, JacobiScratch};
use crate::numeric::C64;

/// Reusable per-worker scratch buffers for block symbol + SVD work.
pub struct Workspace {
    /// Row-major `block_rows×block_cols` symbol block under construction.
    pub block: Vec<C64>,
    /// Per-tap phase factors `e^{2πi⟨k,y⟩}`, `kh·kw` long.
    pub tap_phase: Vec<C64>,
    /// One-sided Jacobi work matrices.
    pub jacobi: JacobiScratch,
    /// Gram-route work matrix (ablation solver).
    pub gram: GramScratch,
}

impl Workspace {
    /// Workspace pre-sized for `rows×cols` blocks with `ntaps` kernel taps.
    pub fn for_block(rows: usize, cols: usize, ntaps: usize) -> Self {
        let mut jacobi = JacobiScratch::new();
        jacobi.reserve(rows, cols);
        let mut gram = GramScratch::new();
        gram.reserve(rows, cols);
        Self {
            block: vec![C64::ZERO; rows * cols],
            tap_phase: vec![C64::ZERO; ntaps.max(1)],
            jacobi,
            gram,
        }
    }

    /// Singular values (descending) of the current contents of `self.block`,
    /// interpreted as a row-major `rows×cols` matrix, written into `out`
    /// (`min(rows, cols)` long). Allocation-free.
    #[inline]
    pub fn solve_block(&mut self, solver: BlockSolver, rows: usize, cols: usize, out: &mut [f64]) {
        match solver {
            BlockSolver::Jacobi => {
                jacobi_svd::singular_values_into(&self.block, rows, cols, &mut self.jacobi, out)
            }
            BlockSolver::GramEigen => {
                jacobi_eig::singular_values_gram_into(&self.block, rows, cols, &mut self.gram, out)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numeric::{CMat, Pcg64};

    #[test]
    fn solve_block_matches_direct_solvers() {
        let mut rng = Pcg64::seeded(500);
        let a = CMat::random_normal(4, 3, &mut rng);
        let mut ws = Workspace::for_block(4, 3, 9);
        ws.block.copy_from_slice(&a.data);
        let mut got = vec![0.0f64; 3];
        ws.solve_block(BlockSolver::Jacobi, 4, 3, &mut got);
        let want = crate::linalg::jacobi_svd::singular_values(&a);
        for (x, y) in want.iter().zip(&got) {
            assert!((x - y).abs() < 1e-12);
        }
        ws.solve_block(BlockSolver::GramEigen, 4, 3, &mut got);
        for (x, y) in want.iter().zip(&got) {
            assert!((x - y).abs() < 1e-7);
        }
    }
}
