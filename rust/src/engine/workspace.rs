//! Per-worker scratch for the planned execution core.
//!
//! A [`Workspace`] owns everything a worker needs to turn one frequency into
//! its singular values: the symbol block buffer, the per-tap phase scratch,
//! and the solver work matrices (one-sided Jacobi row form, Gram/eigen work
//! matrix). All buffers are sized once — either at pool construction or on a
//! worker's first block — so the per-frequency hot loop performs **zero heap
//! allocation**.
//!
//! Workspaces live in a [`WorkspacePool`]. Every [`super::SpectralPlan`]
//! owns (or shares) one: a standalone plan creates its own, while
//! [`super::ModelPlan`] hands one shared pool to every layer of an
//! equal-shape group, so a whole-model sweep reuses the same scratch buffers
//! across layers instead of warming one set per layer.

use crate::lfa::svd::BlockSolver;
use crate::linalg::jacobi_eig::{self, GramScratch};
use crate::linalg::jacobi_svd::{self, JacobiScratch};
use crate::linalg::power::{self, TopKOptions, TopKScratch};
use crate::numeric::C64;
use std::sync::Mutex;

/// Reusable per-worker scratch buffers for block symbol + SVD work.
pub struct Workspace {
    /// Row-major `block_rows×block_cols` symbol block under construction.
    pub block: Vec<C64>,
    /// Per-tap phase factors `e^{2πi⟨k,y⟩}`, `kh·kw` long.
    pub tap_phase: Vec<C64>,
    /// One-sided Jacobi work matrices.
    pub jacobi: JacobiScratch,
    /// Gram-route work matrix (ablation solver).
    pub gram: GramScratch,
    /// Krylov-solver scratch for the top-k partial-spectrum mode. The
    /// converged basis of one frequency **warm-starts the next** along a
    /// sweep; [`power::TopKScratch::reset`] at a sweep boundary forces a
    /// cold start. Sized lazily on the first top-k solve (a warm-up
    /// execution, after which the hot loop is allocation-free).
    pub topk: TopKScratch,
}

impl Workspace {
    /// Workspace pre-sized for `rows×cols` blocks with `ntaps` kernel taps.
    pub fn for_block(rows: usize, cols: usize, ntaps: usize) -> Self {
        let mut jacobi = JacobiScratch::new();
        jacobi.reserve(rows, cols);
        let mut gram = GramScratch::new();
        gram.reserve(rows, cols);
        Self {
            block: vec![C64::ZERO; rows * cols],
            tap_phase: vec![C64::ZERO; ntaps.max(1)],
            jacobi,
            gram,
            topk: TopKScratch::new(),
        }
    }

    /// Singular values (descending) of the current contents of `self.block`,
    /// interpreted as a row-major `rows×cols` matrix, written into `out`
    /// (`min(rows, cols)` long). Allocation-free.
    #[inline]
    pub fn solve_block(&mut self, solver: BlockSolver, rows: usize, cols: usize, out: &mut [f64]) {
        match solver {
            BlockSolver::Jacobi => {
                jacobi_svd::singular_values_into(&self.block, rows, cols, &mut self.jacobi, out)
            }
            BlockSolver::GramEigen => {
                jacobi_eig::singular_values_gram_into(&self.block, rows, cols, &mut self.gram, out)
            }
        }
    }

    /// Top-`k` singular values (descending) of the current contents of
    /// `self.block` via warm-started Krylov iteration, seeded from
    /// whatever basis the previous solve on this workspace converged to.
    /// Returns the iterations spent. Allocation-free after the scratch has
    /// seen the shape once.
    #[inline]
    pub fn solve_block_topk(
        &mut self,
        rows: usize,
        cols: usize,
        k: usize,
        opts: TopKOptions,
        out: &mut [f64],
    ) -> usize {
        power::block_topk(&self.block, rows, cols, k, opts, &mut self.topk, out)
    }
}

/// A shared pool of [`Workspace`]s for one per-frequency block shape.
///
/// The pool is sized for `rows×cols` blocks and for the *largest* tap count
/// of any plan drawing from it (tap counts may differ within an equal-shape
/// layer group — a 3×3 and a 5×5 kernel with the same channel counts share a
/// pool sized for 25 taps). `checkout` pops a ready workspace or builds one
/// at the recorded sizing; `restore` returns it for later executions and
/// other workers. One workspace is prewarmed at construction so serial
/// executions never allocate.
pub struct WorkspacePool {
    rows: usize,
    cols: usize,
    ntaps: usize,
    pool: Mutex<Vec<Workspace>>,
}

impl WorkspacePool {
    /// Pool for `rows×cols` blocks with up to `ntaps` kernel taps.
    pub fn for_block(rows: usize, cols: usize, ntaps: usize) -> Self {
        Self {
            rows,
            cols,
            ntaps,
            pool: Mutex::new(vec![Workspace::for_block(rows, cols, ntaps)]),
        }
    }

    /// The block shape this pool's workspaces are sized for.
    pub fn block_shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Whether workspaces from this pool can serve `rows×cols` blocks with
    /// `ntaps` taps (exact block shape, tap capacity at least `ntaps`).
    pub fn covers(&self, rows: usize, cols: usize, ntaps: usize) -> bool {
        self.rows == rows && self.cols == cols && self.ntaps >= ntaps
    }

    /// Check a workspace out (or build a fresh one if all are in use).
    pub fn checkout(&self) -> Workspace {
        let ws = self.pool.lock().expect("workspace pool poisoned").pop();
        ws.unwrap_or_else(|| Workspace::for_block(self.rows, self.cols, self.ntaps))
    }

    /// Return a checked-out workspace for reuse.
    pub fn restore(&self, ws: Workspace) {
        self.pool.lock().expect("workspace pool poisoned").push(ws);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numeric::{CMat, Pcg64};

    #[test]
    fn solve_block_matches_direct_solvers() {
        let mut rng = Pcg64::seeded(500);
        let a = CMat::random_normal(4, 3, &mut rng);
        let mut ws = Workspace::for_block(4, 3, 9);
        ws.block.copy_from_slice(&a.data);
        let mut got = vec![0.0f64; 3];
        ws.solve_block(BlockSolver::Jacobi, 4, 3, &mut got);
        let want = crate::linalg::jacobi_svd::singular_values(&a);
        for (x, y) in want.iter().zip(&got) {
            assert!((x - y).abs() < 1e-12);
        }
        ws.solve_block(BlockSolver::GramEigen, 4, 3, &mut got);
        for (x, y) in want.iter().zip(&got) {
            assert!((x - y).abs() < 1e-7);
        }
    }

    #[test]
    fn solve_block_topk_matches_full_extremes() {
        let mut rng = Pcg64::seeded(501);
        let a = CMat::random_normal(5, 4, &mut rng);
        let mut ws = Workspace::for_block(5, 4, 9);
        ws.block.copy_from_slice(&a.data);
        let mut full = vec![0.0f64; 4];
        ws.solve_block(BlockSolver::Jacobi, 5, 4, &mut full);
        let mut top = vec![0.0f64; 2];
        let iters = ws.solve_block_topk(5, 4, 2, TopKOptions::default(), &mut top);
        assert!(iters >= 1);
        assert!(ws.topk.is_warm());
        for j in 0..2 {
            assert!((top[j] - full[j]).abs() < 1e-9 * full[0].max(1.0), "{j}");
        }
    }

    #[test]
    fn pool_checkout_restore_reuses_and_covers() {
        let pool = WorkspacePool::for_block(3, 2, 9);
        assert_eq!(pool.block_shape(), (3, 2));
        assert!(pool.covers(3, 2, 9));
        assert!(pool.covers(3, 2, 4), "smaller tap counts are covered");
        assert!(!pool.covers(3, 2, 25), "larger tap counts are not");
        assert!(!pool.covers(2, 3, 9), "shape must match exactly");
        let a = pool.checkout();
        let b = pool.checkout(); // pool empty → fresh build
        assert!(a.block.len() >= 6 && b.block.len() >= 6);
        pool.restore(a);
        pool.restore(b);
        let c = pool.checkout();
        assert_eq!(c.block.len(), 6, "restored workspace comes back");
        pool.restore(c);
    }
}
