//! Per-worker scratch for the planned execution core.
//!
//! A [`Workspace`] owns everything a worker needs to turn one frequency into
//! its singular values: the symbol block buffer, the per-tap phase scratch,
//! and the solver work matrices (one-sided Jacobi row form, Gram/eigen work
//! matrix). All buffers are sized once — either at pool construction or on a
//! worker's first block — so the per-frequency hot loop performs **zero heap
//! allocation**.
//!
//! Every buffer exists in two widths: the f64 set serves
//! [`crate::lfa::Precision::F64`] and the refinement polish, the f32 twins
//! (`block32`, `jacobi32`, `gram32`, `topk32`, the split tap planes) serve
//! the reduced-precision tiers — so one pooled workspace can execute a plan
//! of any precision without reallocating. The per-tap phase factors are
//! stored as **split re/im planes** rather than interleaved complex: that is
//! the layout the [`crate::numeric::SimdReal::dot_split`] kernel consumes
//! when contracting real weights against complex phases in symbol assembly.
//!
//! Workspaces live in a [`WorkspacePool`]. Every [`super::SpectralPlan`]
//! owns (or shares) one: a standalone plan creates its own, while
//! [`super::ModelPlan`] hands one shared pool to every layer of an
//! equal-shape group, so a whole-model sweep reuses the same scratch buffers
//! across layers instead of warming one set per layer.

use crate::lfa::svd::BlockSolver;
use crate::linalg::jacobi_eig::{self, GramScratch};
use crate::linalg::jacobi_svd::{self, JacobiScratch, RefineScratch};
use crate::linalg::power::{self, TopKOptions, TopKScratch};
use crate::linalg::SolveCert;
use crate::numeric::{C32, C64};
use std::sync::Mutex;

/// Reusable per-worker scratch buffers for block symbol + SVD work.
pub struct Workspace {
    /// Row-major `block_rows×block_cols` symbol block under construction.
    pub block: Vec<C64>,
    /// f32 twin of `block` for the reduced-precision tiers.
    pub block32: Vec<C32>,
    /// Per-tap phase factors `e^{2πi⟨k,y⟩}` as split re/im planes,
    /// `kh·kw` long each — the operand layout of
    /// [`crate::numeric::SimdReal::dot_split`].
    pub tap_re: Vec<f64>,
    /// Imaginary plane of the per-tap phases.
    pub tap_im: Vec<f64>,
    /// f32 twin of `tap_re`.
    pub tap_re32: Vec<f32>,
    /// f32 twin of `tap_im`.
    pub tap_im32: Vec<f32>,
    /// One-sided Jacobi work matrices.
    pub jacobi: JacobiScratch,
    /// Gram-route work matrix (ablation solver).
    pub gram: GramScratch,
    /// f32 twin of `jacobi`.
    pub jacobi32: JacobiScratch<f32>,
    /// f32 twin of `gram`.
    pub gram32: GramScratch<f32>,
    /// Mixed-precision refinement scratch: f32 Jacobi sweep, widened
    /// basis replay, f64 polish
    /// ([`jacobi_svd::singular_values_refined_into`]).
    pub refine: RefineScratch,
    /// Widened right-vector buffer for the top-k Rayleigh refinement
    /// ([`power::refine_topk_values`]), `block_cols` long.
    pub refine_v: Vec<C64>,
    /// f32 staging for singular values before widening into f64 output.
    pub svals32: Vec<f32>,
    /// Krylov-solver scratch for the top-k partial-spectrum mode. The
    /// converged basis of one frequency **warm-starts the next** along a
    /// sweep; [`power::TopKScratch::reset`] at a sweep boundary forces a
    /// cold start. Sized lazily on the first top-k solve (a warm-up
    /// execution, after which the hot loop is allocation-free).
    pub topk: TopKScratch,
    /// f32 twin of `topk`: carries the warm basis of the reduced-precision
    /// top-k sweeps (both `F32` and the `F32Refined` f32 stage).
    pub topk32: TopKScratch<f32>,
    /// Merge buffer for grouped top-k solves: per-group candidate values
    /// are gathered here, sorted, and the global top-k copied out. Always
    /// f64 (the top-k output boundary), sized lazily on the first grouped
    /// solve — like `topk`, a warm-up execution makes the hot loop
    /// allocation-free.
    pub merge: Vec<f64>,
}

impl Workspace {
    /// Workspace pre-sized for `rows×cols` blocks with `ntaps` kernel taps.
    pub fn for_block(rows: usize, cols: usize, ntaps: usize) -> Self {
        let mut jacobi = JacobiScratch::new();
        jacobi.reserve(rows, cols);
        let mut gram = GramScratch::new();
        gram.reserve(rows, cols);
        let mut jacobi32 = JacobiScratch::<f32>::new();
        jacobi32.reserve(rows, cols);
        let mut gram32 = GramScratch::<f32>::new();
        gram32.reserve(rows, cols);
        let mut refine = RefineScratch::new();
        refine.reserve(rows, cols);
        let ntaps = ntaps.max(1);
        Self {
            block: vec![C64::ZERO; rows * cols],
            block32: vec![C32::ZERO; rows * cols],
            tap_re: vec![0.0f64; ntaps],
            tap_im: vec![0.0f64; ntaps],
            tap_re32: vec![0.0f32; ntaps],
            tap_im32: vec![0.0f32; ntaps],
            jacobi,
            gram,
            jacobi32,
            gram32,
            refine,
            refine_v: vec![C64::ZERO; cols.max(1)],
            svals32: vec![0.0f32; rows.min(cols).max(1)],
            topk: TopKScratch::new(),
            topk32: TopKScratch::<f32>::new(),
            merge: Vec::new(),
        }
    }

    /// Singular values (descending) of the current contents of `self.block`,
    /// interpreted as a row-major `rows×cols` matrix, written into `out`
    /// (`min(rows, cols)` long). Returns the solver's convergence
    /// certificate. Allocation-free.
    #[inline]
    pub fn solve_block(
        &mut self,
        solver: BlockSolver,
        rows: usize,
        cols: usize,
        out: &mut [f64],
    ) -> SolveCert {
        match solver {
            BlockSolver::Jacobi => {
                jacobi_svd::singular_values_into(&self.block, rows, cols, &mut self.jacobi, out)
            }
            BlockSolver::GramEigen => {
                jacobi_eig::singular_values_gram_into(&self.block, rows, cols, &mut self.gram, out)
            }
        }
    }

    /// [`Self::solve_block`] over the f32 twin `self.block32`: the whole
    /// Jacobi / Gram sweep runs in f32 (twice the SIMD lanes per rotation),
    /// and the converged values are widened into the f64 output. Expect
    /// ~1e-4 relative accuracy — the [`crate::lfa::Precision::F32`] tier.
    #[inline]
    pub fn solve_block32(
        &mut self,
        solver: BlockSolver,
        rows: usize,
        cols: usize,
        out: &mut [f64],
    ) -> SolveCert {
        let r = rows.min(cols);
        let vals = &mut self.svals32[..r];
        let cert = match solver {
            BlockSolver::Jacobi => jacobi_svd::singular_values_into(
                &self.block32,
                rows,
                cols,
                &mut self.jacobi32,
                vals,
            ),
            BlockSolver::GramEigen => jacobi_eig::singular_values_gram_into(
                &self.block32,
                rows,
                cols,
                &mut self.gram32,
                vals,
            ),
        };
        for (o, &v) in out[..r].iter_mut().zip(vals.iter()) {
            *o = v as f64;
        }
        cert
    }

    /// Mixed-precision solve of the f64 block: an f32 Jacobi sweep does the
    /// bulk of the rotations, then the accumulated basis is replayed against
    /// the exact f64 rows and polished with one or two f64 sweeps —
    /// ≤1e-12 relative to the all-f64 path at roughly f32 sweep cost
    /// (the [`crate::lfa::Precision::F32Refined`] tier; always the Jacobi
    /// route — the Gram ablation has no refinement ladder).
    #[inline]
    pub fn solve_block_refined(&mut self, rows: usize, cols: usize, out: &mut [f64]) -> SolveCert {
        jacobi_svd::singular_values_refined_into(&self.block, rows, cols, &mut self.refine, out)
    }

    /// Top-`k` singular values (descending) of the current contents of
    /// `self.block` via warm-started Krylov iteration, seeded from
    /// whatever basis the previous solve on this workspace converged to.
    /// Returns the convergence certificate (`effort` = iterations spent).
    /// Allocation-free after the scratch has seen the shape once.
    #[inline]
    pub fn solve_block_topk(
        &mut self,
        rows: usize,
        cols: usize,
        k: usize,
        opts: TopKOptions,
        out: &mut [f64],
    ) -> SolveCert {
        power::block_topk(&self.block, rows, cols, k, opts, &mut self.topk, out)
    }

    /// [`Self::solve_block_topk`] over the f32 twin `self.block32` with the
    /// f32 Krylov scratch; converged values are widened into the f64
    /// output.
    #[inline]
    pub fn solve_block_topk32(
        &mut self,
        rows: usize,
        cols: usize,
        k: usize,
        opts: TopKOptions,
        out: &mut [f64],
    ) -> SolveCert {
        let vals = &mut self.svals32[..k];
        let cert = power::block_topk(&self.block32, rows, cols, k, opts, &mut self.topk32, vals);
        for (o, &v) in out[..k].iter_mut().zip(vals.iter()) {
            *o = v as f64;
        }
        cert
    }

    /// Mixed-precision top-`k` of the f64 block: narrow it into `block32`,
    /// run the f32 Krylov solve (warm starts carried in `topk32`), then
    /// refine each value against the exact f64 block by a Rayleigh
    /// quotient over the widened right vector — second-order accurate in
    /// the f32 error, so the values land within ~1e-12 of the f64 path.
    #[inline]
    pub fn solve_block_topk_refined(
        &mut self,
        rows: usize,
        cols: usize,
        k: usize,
        opts: TopKOptions,
        out: &mut [f64],
    ) -> SolveCert {
        let len = rows * cols;
        for (d, s) in self.block32[..len].iter_mut().zip(self.block[..len].iter()) {
            *d = s.to_c32();
        }
        let vals = &mut self.svals32[..k];
        let cert = power::block_topk(&self.block32, rows, cols, k, opts, &mut self.topk32, vals);
        power::refine_topk_values(
            &self.block[..len],
            rows,
            cols,
            &self.topk32,
            k,
            &mut self.refine_v[..cols],
            out,
        );
        cert
    }
}

/// A shared pool of [`Workspace`]s for one per-frequency block shape.
///
/// The pool is sized for `rows×cols` blocks and for the *largest* tap count
/// of any plan drawing from it (tap counts may differ within an equal-shape
/// layer group — a 3×3 and a 5×5 kernel with the same channel counts share a
/// pool sized for 25 taps). `checkout` pops a ready workspace or builds one
/// at the recorded sizing; `restore` returns it for later executions and
/// other workers. One workspace is prewarmed at construction so serial
/// executions never allocate.
pub struct WorkspacePool {
    rows: usize,
    cols: usize,
    ntaps: usize,
    pool: Mutex<Vec<Workspace>>,
}

impl WorkspacePool {
    /// Pool for `rows×cols` blocks with up to `ntaps` kernel taps.
    pub fn for_block(rows: usize, cols: usize, ntaps: usize) -> Self {
        Self {
            rows,
            cols,
            ntaps,
            pool: Mutex::new(vec![Workspace::for_block(rows, cols, ntaps)]),
        }
    }

    /// The block shape this pool's workspaces are sized for.
    pub fn block_shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Whether workspaces from this pool can serve `rows×cols` blocks with
    /// `ntaps` taps (exact block shape, tap capacity at least `ntaps`).
    pub fn covers(&self, rows: usize, cols: usize, ntaps: usize) -> bool {
        self.rows == rows && self.cols == cols && self.ntaps >= ntaps
    }

    /// Check a workspace out (or build a fresh one if all are in use).
    pub fn checkout(&self) -> Workspace {
        let ws = self.pool.lock().expect("workspace pool poisoned").pop();
        ws.unwrap_or_else(|| Workspace::for_block(self.rows, self.cols, self.ntaps))
    }

    /// Return a checked-out workspace for reuse.
    pub fn restore(&self, ws: Workspace) {
        self.pool.lock().expect("workspace pool poisoned").push(ws);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numeric::{CMat, Pcg64};

    #[test]
    fn solve_block_matches_direct_solvers() {
        let mut rng = Pcg64::seeded(500);
        let a = CMat::random_normal(4, 3, &mut rng);
        let mut ws = Workspace::for_block(4, 3, 9);
        ws.block.copy_from_slice(&a.data);
        let mut got = vec![0.0f64; 3];
        ws.solve_block(BlockSolver::Jacobi, 4, 3, &mut got);
        let want = crate::linalg::jacobi_svd::singular_values(&a);
        for (x, y) in want.iter().zip(&got) {
            assert!((x - y).abs() < 1e-12);
        }
        ws.solve_block(BlockSolver::GramEigen, 4, 3, &mut got);
        for (x, y) in want.iter().zip(&got) {
            assert!((x - y).abs() < 1e-7);
        }
    }

    #[test]
    fn reduced_precision_solves_track_the_f64_path() {
        let mut rng = Pcg64::seeded(502);
        let a = CMat::random_normal(5, 4, &mut rng);
        let mut ws = Workspace::for_block(5, 4, 9);
        ws.block.copy_from_slice(&a.data);
        for (d, s) in ws.block32.iter_mut().zip(&a.data) {
            *d = s.to_c32();
        }
        let mut want = vec![0.0f64; 4];
        ws.solve_block(BlockSolver::Jacobi, 5, 4, &mut want);
        let scale = want[0].max(1.0);
        // Pure f32: ~1e-4 relative.
        let mut got32 = vec![0.0f64; 4];
        ws.solve_block32(BlockSolver::Jacobi, 5, 4, &mut got32);
        for (x, y) in want.iter().zip(&got32) {
            assert!((x - y).abs() <= 1e-4 * scale, "{x} vs {y}");
        }
        ws.solve_block32(BlockSolver::GramEigen, 5, 4, &mut got32);
        for (x, y) in want.iter().zip(&got32) {
            assert!((x - y).abs() <= 5e-3 * scale, "gram32 {x} vs {y}");
        }
        // Refined: back to f64-grade accuracy.
        let mut refined = vec![0.0f64; 4];
        ws.solve_block_refined(5, 4, &mut refined);
        for (x, y) in want.iter().zip(&refined) {
            assert!((x - y).abs() <= 1e-12 * scale, "{x} vs {y}");
        }
    }

    #[test]
    fn solve_block_topk_matches_full_extremes() {
        let mut rng = Pcg64::seeded(501);
        let a = CMat::random_normal(5, 4, &mut rng);
        let mut ws = Workspace::for_block(5, 4, 9);
        ws.block.copy_from_slice(&a.data);
        let mut full = vec![0.0f64; 4];
        ws.solve_block(BlockSolver::Jacobi, 5, 4, &mut full);
        let mut top = vec![0.0f64; 2];
        let cert = ws.solve_block_topk(5, 4, 2, TopKOptions::default(), &mut top);
        assert!(cert.effort >= 1 && cert.converged);
        assert!(ws.topk.is_warm());
        for j in 0..2 {
            assert!((top[j] - full[j]).abs() < 1e-9 * full[0].max(1.0), "{j}");
        }
    }

    #[test]
    fn reduced_precision_topk_tracks_and_refines() {
        let mut rng = Pcg64::seeded(503);
        let a = CMat::random_normal(6, 5, &mut rng);
        let mut ws = Workspace::for_block(6, 5, 9);
        ws.block.copy_from_slice(&a.data);
        let mut full = vec![0.0f64; 5];
        ws.solve_block(BlockSolver::Jacobi, 6, 5, &mut full);
        let scale = full[0].max(1.0);
        // Pure f32 top-k widens to ~1e-3 relative accuracy.
        for (d, s) in ws.block32.iter_mut().zip(&a.data) {
            *d = s.to_c32();
        }
        let mut top32 = vec![0.0f64; 2];
        let cert = ws.solve_block_topk32(6, 5, 2, TopKOptions::default(), &mut top32);
        assert!(cert.effort >= 1 && cert.converged);
        assert!(ws.topk32.is_warm());
        for j in 0..2 {
            assert!((top32[j] - full[j]).abs() <= 1e-3 * scale, "{j}");
        }
        // Refined top-k recovers near-f64 values from the f32 basis.
        ws.topk32.reset();
        let mut refined = vec![0.0f64; 2];
        ws.solve_block_topk_refined(6, 5, 2, TopKOptions::default(), &mut refined);
        for j in 0..2 {
            assert!((refined[j] - full[j]).abs() <= 1e-9 * scale, "{j}");
        }
    }

    #[test]
    fn pool_checkout_restore_reuses_and_covers() {
        let pool = WorkspacePool::for_block(3, 2, 9);
        assert_eq!(pool.block_shape(), (3, 2));
        assert!(pool.covers(3, 2, 9));
        assert!(pool.covers(3, 2, 4), "smaller tap counts are covered");
        assert!(!pool.covers(3, 2, 25), "larger tap counts are not");
        assert!(!pool.covers(2, 3, 9), "shape must match exactly");
        let a = pool.checkout();
        let b = pool.checkout(); // pool empty → fresh build
        assert!(a.block.len() >= 6 && b.block.len() >= 6);
        pool.restore(a);
        pool.restore(b);
        let c = pool.checkout();
        assert_eq!(c.block.len(), 6, "restored workspace comes back");
        pool.restore(c);
    }
}
