//! [`DiskCache`]: the content-addressed on-disk tier below the in-memory
//! [`super::SpectralCache`] LRU.
//!
//! The in-memory cache dies with the process; a long-running audit daemon
//! restarted for a deploy would re-solve every frequency of every layer it
//! had already decomposed. This tier spills computed [`Spectrum`]s to
//! checksummed, versioned files keyed by their weight-bit [`Signature`],
//! and reads them back across process restarts — a warm repeat audit after
//! a restart re-solves **zero** frequencies and returns bit-identical
//! values (spectra are stored as raw `f64` bit patterns, so the round trip
//! is exact).
//!
//! Spill-file format (all integers little-endian):
//!
//! ```text
//! offset  size  field
//! 0       8     magic  b"LFASPILL"
//! 8       4     format version (u32) — mismatches are quarantined
//! 12      4     reserved (zero)
//! 16      16    signature digest ([`Signature::file_digest`], 2×u64)
//! 32      40    n, m, c_out, c_in, per_freq (5×u64)
//! 72      8     value count (u64)
//! 80      8·V   singular values (f64 bit patterns)
//! 80+8·V  16    checksum: dual FNV-1a over bytes 16..80+8·V
//! ```
//!
//! Writes are atomic (temp file + rename), so a crash mid-spill leaves at
//! worst an orphaned temp file, never a half-written entry under a live
//! name. Reads re-verify everything: magic, version, checksum, the key
//! digest, and geometry consistency. **Any** failure quarantines the file
//! (it is deleted), counts a corruption, and reads as a miss — a truncated,
//! bit-flipped or wrong-version spill is never served. Entries are
//! content-addressed, so there is no invalidation: stale files for mutated
//! weights are simply never looked up again, and `put` of an
//! already-spilled signature is a no-op (the bytes would be identical).
//!
//! Counter semantics: `hits + misses + corruptions` = total lookups;
//! `spills` counts files newly written. The tier has no byte budget of its
//! own — the operator points [`DiskCache::open`] at a directory and owns
//! its lifecycle ([`DiskCache::purge`] empties it).

use super::cache::Signature;
use crate::error::{Context, Result};
use crate::lfa::spectrum::Spectrum;
use std::fs;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

/// First 8 bytes of every spill file.
pub const SPILL_MAGIC: [u8; 8] = *b"LFASPILL";
/// Current spill-file format version. Bump on any layout change: readers
/// quarantine files from other versions instead of misparsing them.
pub const SPILL_VERSION: u32 = 1;

/// Bytes before the checksummed region (magic + version + reserved).
const PREFIX_LEN: usize = 16;
/// Header words inside the checksummed region (digest + geometry + count).
const HEADER_WORDS: usize = 8;
/// Trailing checksum bytes (two u64 FNV-1a streams).
const CHECKSUM_LEN: usize = 16;

/// Unique temp-file suffix counter (several threads may spill at once).
static NEXT_TMP: AtomicU64 = AtomicU64::new(0);

/// Point-in-time disk-tier counters ([`DiskCache::stats`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DiskStats {
    /// Lookups served from a valid spill file.
    pub hits: u64,
    /// Lookups that found no spill file.
    pub misses: u64,
    /// Spill files newly written.
    pub spills: u64,
    /// Spill files that failed validation and were quarantined.
    pub corruptions: u64,
}

/// Dual FNV-1a over a byte slice — 128 bits of checksum in one pass (same
/// construction as the weight-bit content digest in `engine::cache`).
fn fnv1a_bytes2(bytes: &[u8]) -> [u64; 2] {
    const PRIME: u64 = 0x100000001b3;
    let mut h0: u64 = 0xcbf29ce484222325;
    let mut h1: u64 = 0x6c62272e07bb0142;
    for &b in bytes {
        h0 = (h0 ^ b as u64).wrapping_mul(PRIME);
        h1 = (h1 ^ b as u64).wrapping_mul(PRIME);
    }
    [h0, h1]
}

fn read_u64(bytes: &[u8]) -> u64 {
    u64::from_le_bytes(bytes[..8].try_into().expect("8-byte slice"))
}

/// The on-disk spill tier — see the module docs. All methods are `&self`
/// and thread-safe (the filesystem is the shared state; writes are atomic
/// renames).
pub struct DiskCache {
    root: PathBuf,
    hits: AtomicU64,
    misses: AtomicU64,
    spills: AtomicU64,
    corruptions: AtomicU64,
}

impl DiskCache {
    /// Open (creating if needed) the spill directory. Fails only if the
    /// directory cannot be created — an unreadable *entry* later is a
    /// per-lookup miss, never an error.
    pub fn open(root: impl Into<PathBuf>) -> Result<Self> {
        let root = root.into();
        fs::create_dir_all(&root)
            .with_context(|| format!("creating disk cache dir {}", root.display()))?;
        Ok(Self {
            root,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            spills: AtomicU64::new(0),
            corruptions: AtomicU64::new(0),
        })
    }

    /// The spill directory.
    pub fn root(&self) -> &Path {
        &self.root
    }

    /// The file a signature spills to (content-addressed name).
    pub fn path_for(&self, key: &Signature) -> PathBuf {
        let d = key.file_digest();
        self.root.join(format!("{:016x}{:016x}.spill", d[0], d[1]))
    }

    /// Read a spectrum back. A missing file is a miss; a file that fails
    /// **any** validation (magic, version, checksum, key digest, geometry)
    /// is quarantined — deleted, counted as a corruption — and also reads
    /// as a miss. Corrupt bytes are never served.
    pub fn get(&self, key: &Signature) -> Option<Spectrum> {
        let path = self.path_for(key);
        let bytes = match fs::read(&path) {
            Ok(b) => b,
            Err(_) => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                return None;
            }
        };
        match decode(key, &bytes) {
            Ok(spectrum) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(spectrum)
            }
            Err(_why) => {
                let _ = fs::remove_file(&path);
                self.corruptions.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Spill a spectrum. Content-addressed: if the file already exists the
    /// bytes would be identical, so the write is skipped. Returns whether
    /// a file was newly written. I/O failures are swallowed (the disk tier
    /// degrades to a smaller cache, it never fails a job).
    pub fn put(&self, key: &Signature, spectrum: &Spectrum) -> bool {
        let path = self.path_for(key);
        if path.exists() {
            return false;
        }
        // Fault-injection point: a full / read-only disk shrinks the cache,
        // it never fails the job that computed the spectrum.
        if crate::testing::chaos::fire(crate::testing::chaos::DISK_WRITE_FAIL) {
            return false;
        }
        let bytes = encode(key, spectrum);
        let tmp = self.root.join(format!(
            ".tmp-{}-{}",
            std::process::id(),
            NEXT_TMP.fetch_add(1, Ordering::Relaxed)
        ));
        let written = fs::write(&tmp, &bytes).and_then(|()| fs::rename(&tmp, &path)).is_ok();
        if written {
            self.spills.fetch_add(1, Ordering::Relaxed);
        } else {
            let _ = fs::remove_file(&tmp);
        }
        written
    }

    /// Number of spill files currently on disk.
    pub fn len(&self) -> usize {
        self.spill_files().count()
    }

    /// Whether the spill directory holds no entries.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Delete every spill file (bench / test hygiene). Returns how many
    /// were removed. Counters are kept — they record lifetime traffic.
    pub fn purge(&self) -> usize {
        self.spill_files().filter(|p| fs::remove_file(p).is_ok()).count()
    }

    fn spill_files(&self) -> impl Iterator<Item = PathBuf> {
        fs::read_dir(&self.root)
            .into_iter()
            .flatten()
            .flatten()
            .map(|e| e.path())
            .filter(|p| p.extension().is_some_and(|x| x == "spill"))
    }

    /// Current counters.
    pub fn stats(&self) -> DiskStats {
        DiskStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            spills: self.spills.load(Ordering::Relaxed),
            corruptions: self.corruptions.load(Ordering::Relaxed),
        }
    }
}

/// Serialize a spectrum under `key` into the spill-file layout.
fn encode(key: &Signature, spectrum: &Spectrum) -> Vec<u8> {
    let words = [
        spectrum.n as u64,
        spectrum.m as u64,
        spectrum.c_out as u64,
        spectrum.c_in as u64,
        spectrum.per_freq as u64,
        spectrum.values.len() as u64,
    ];
    let mut buf =
        Vec::with_capacity(PREFIX_LEN + (HEADER_WORDS + spectrum.values.len()) * 8 + CHECKSUM_LEN);
    buf.extend_from_slice(&SPILL_MAGIC);
    buf.extend_from_slice(&SPILL_VERSION.to_le_bytes());
    buf.extend_from_slice(&0u32.to_le_bytes());
    let digest = key.file_digest();
    for w in digest.iter().copied().chain(words) {
        buf.extend_from_slice(&w.to_le_bytes());
    }
    for v in &spectrum.values {
        buf.extend_from_slice(&v.to_bits().to_le_bytes());
    }
    let sum = fnv1a_bytes2(&buf[PREFIX_LEN..]);
    buf.extend_from_slice(&sum[0].to_le_bytes());
    buf.extend_from_slice(&sum[1].to_le_bytes());
    buf
}

/// Parse + fully validate a spill file against the key that looked it up.
fn decode(key: &Signature, bytes: &[u8]) -> std::result::Result<Spectrum, &'static str> {
    if bytes.len() < PREFIX_LEN + HEADER_WORDS * 8 + CHECKSUM_LEN {
        return Err("truncated");
    }
    if bytes[..8] != SPILL_MAGIC {
        return Err("bad magic");
    }
    let version = u32::from_le_bytes(bytes[8..12].try_into().expect("4-byte slice"));
    if version != SPILL_VERSION {
        return Err("version mismatch");
    }
    let body = &bytes[PREFIX_LEN..bytes.len() - CHECKSUM_LEN];
    let tail = &bytes[bytes.len() - CHECKSUM_LEN..];
    let stored = [read_u64(tail), read_u64(&tail[8..])];
    if fnv1a_bytes2(body) != stored {
        return Err("checksum mismatch");
    }
    let word = |i: usize| read_u64(&body[i * 8..]);
    if [word(0), word(1)] != key.file_digest() {
        return Err("key digest mismatch");
    }
    let n = word(2) as usize;
    let m = word(3) as usize;
    let c_out = word(4) as usize;
    let c_in = word(5) as usize;
    let per_freq = word(6) as usize;
    let count = word(7) as usize;
    if body.len() != (HEADER_WORDS + count) * 8 {
        return Err("length mismatch");
    }
    let expect = n
        .checked_mul(m)
        .and_then(|nm| nm.checked_mul(per_freq))
        .ok_or("geometry overflow")?;
    if count != expect {
        return Err("inconsistent geometry");
    }
    let values = body[HEADER_WORDS * 8..]
        .chunks_exact(8)
        .map(|c| f64::from_bits(u64::from_le_bytes(c.try_into().expect("8-byte chunk"))))
        .collect();
    // Degraded spectra are refused at the cache's admission gate, so a
    // spill file always holds a clean result; restore it with the
    // matching clean certificate (one record per frequency).
    let health = crate::lfa::spectrum::SpectrumHealth::clean((n * m) as u64);
    Ok(Spectrum { n, m, c_out, c_in, per_freq, values, health })
}
