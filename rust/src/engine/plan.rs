//! The [`SpectralPlan`]: plan once, execute many.
//!
//! A plan is created once per `(kernel, grid, stride, layout, solver,
//! threads)` configuration and captures everything that is invariant across
//! executions:
//!
//! - the **twiddle/phase tables** `e^{2πi·i·dy/n}`, `e^{2πi·j·dx/m}` for
//!   every (axis, tap-offset) pair — `O(n·kh + m·kw)` trig total, evaluated
//!   exactly once per plan instead of once per call;
//! - a **pool of per-worker workspaces** (symbol block, per-tap phases,
//!   Jacobi/Gram work matrices) so the per-frequency hot loop performs zero
//!   heap allocation;
//! - the **strided dual-grid geometry**: for stride `s > 1` the plan's
//!   frequency space is the coarse torus `(n/s)×(m/s)` and each block is the
//!   `c_out × s²·c_in` concatenation of the `s²` aliasing fine symbols.
//!
//! `execute*` then runs the fused symbol→SVD pipeline over any row range of
//! the dual grid. Every SVD entry point in the crate — `lfa::svd`,
//! `lfa::stride`, the FFT baseline's SVD stage, the coordinator's tiles —
//! is a thin wrapper over this type.

use super::workspace::{Workspace, WorkspacePool};
use crate::conv::ConvKernel;
use crate::lfa::spectrum::{FullSvd, Spectrum};
use crate::lfa::svd::{BlockSolver, LfaOptions};
use crate::lfa::symbol::{scatter_shard, BlockLayout, SymbolGrid};
use crate::linalg::jacobi_svd;
use crate::numeric::{C64, CMat};
use std::f64::consts::PI;
use std::sync::Arc;

/// A planned, reusable symbol→SVD execution for one convolution layer.
pub struct SpectralPlan {
    kernel: ConvKernel,
    /// Fine input grid.
    n: usize,
    m: usize,
    stride: usize,
    layout: BlockLayout,
    solver: BlockSolver,
    threads: usize,
    /// Coarse (output) dual grid: `n/stride × m/stride`.
    nc: usize,
    mc: usize,
    /// Per-frequency block shape: `c_out × stride²·c_in`.
    block_rows: usize,
    block_cols: usize,
    rank: usize,
    /// Row-axis phase table, flattened `[kh][n]`: `py[d·n + i] =
    /// e^{2πi·i·(d − anchor_row)/n}`.
    py: Vec<C64>,
    /// Column-axis phase table, flattened `[kw][m]`.
    px: Vec<C64>,
    /// Reusable per-worker workspaces (checked out per execution range).
    /// Owned by this plan alone, or shared with other equal-shape plans of a
    /// [`super::ModelPlan`] group.
    pool: Arc<WorkspacePool>,
}

impl SpectralPlan {
    /// Plan the dense (stride-1) pipeline for `kernel` on an `n×m` grid.
    pub fn new(kernel: &ConvKernel, n: usize, m: usize, opts: LfaOptions) -> Self {
        Self::with_stride(kernel, n, m, 1, opts)
    }

    /// Plan the stride-`s` pipeline (`C = D_s ∘ A`) on an `n×m` fine grid.
    /// The coarse output grid is `(n/s)×(m/s)`; `s` must divide both axes.
    pub fn with_stride(
        kernel: &ConvKernel,
        n: usize,
        m: usize,
        s: usize,
        opts: LfaOptions,
    ) -> Self {
        // Prewarm one workspace: the serial path never allocates at execute
        // time, and threaded paths grow the pool once on first use.
        let pool = Arc::new(WorkspacePool::for_block(
            kernel.c_out,
            s * s * kernel.c_in,
            kernel.kh * kernel.kw,
        ));
        Self::with_shared_pool(kernel, n, m, s, opts, pool)
    }

    /// [`Self::with_stride`] drawing scratch from an existing shared pool
    /// instead of creating one. This is how [`super::ModelPlan`] batches
    /// layers with equal block shape into one workspace-sharing group; the
    /// pool must cover this plan's `c_out × s²·c_in` blocks and tap count.
    pub fn with_shared_pool(
        kernel: &ConvKernel,
        n: usize,
        m: usize,
        s: usize,
        opts: LfaOptions,
        pool: Arc<WorkspacePool>,
    ) -> Self {
        assert!(s > 0 && n % s == 0 && m % s == 0, "stride must divide the grid");
        assert!(n > 0 && m > 0, "grid must be nonempty");
        assert!(
            pool.covers(kernel.c_out, s * s * kernel.c_in, kernel.kh * kernel.kw),
            "workspace pool does not cover the plan's block shape"
        );
        let (ar, ac) = (kernel.anchor.0 as isize, kernel.anchor.1 as isize);
        let mut py = vec![C64::ZERO; kernel.kh * n];
        for d in 0..kernel.kh {
            let dy = d as isize - ar;
            for i in 0..n {
                py[d * n + i] = C64::cis(2.0 * PI * (i as f64) * (dy as f64) / (n as f64));
            }
        }
        let mut px = vec![C64::ZERO; kernel.kw * m];
        for d in 0..kernel.kw {
            let dx = d as isize - ac;
            for j in 0..m {
                px[d * m + j] = C64::cis(2.0 * PI * (j as f64) * (dx as f64) / (m as f64));
            }
        }
        let block_rows = kernel.c_out;
        let block_cols = s * s * kernel.c_in;
        Self {
            kernel: kernel.clone(),
            n,
            m,
            stride: s,
            layout: opts.layout,
            solver: opts.solver,
            threads: opts.threads,
            nc: n / s,
            mc: m / s,
            block_rows,
            block_cols,
            rank: block_rows.min(block_cols),
            py,
            px,
            pool,
        }
    }

    /// Rows of the coarse dual grid (the shardable axis).
    pub fn coarse_rows(&self) -> usize {
        self.nc
    }

    /// Columns of the coarse dual grid.
    pub fn coarse_cols(&self) -> usize {
        self.mc
    }

    /// Number of frequencies (= blocks) the plan executes.
    pub fn freqs(&self) -> usize {
        self.nc * self.mc
    }

    /// Singular values per frequency: `min(c_out, stride²·c_in)`.
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Total output length of [`Self::execute_into`].
    pub fn values_len(&self) -> usize {
        self.freqs() * self.rank
    }

    /// The solver the plan was built with.
    pub fn solver(&self) -> BlockSolver {
        self.solver
    }

    /// Per-frequency block shape `(c_out, stride²·c_in)`.
    pub fn block_shape(&self) -> (usize, usize) {
        (self.block_rows, self.block_cols)
    }

    /// The stride the plan was built with (1 = dense).
    pub fn stride(&self) -> usize {
        self.stride
    }

    /// Rows of the fine input grid (`coarse_rows · stride`).
    pub fn fine_rows(&self) -> usize {
        self.n
    }

    /// Columns of the fine input grid (`coarse_cols · stride`).
    pub fn fine_cols(&self) -> usize {
        self.m
    }

    /// The kernel the plan owns (a clone of the one it was built from).
    pub fn kernel(&self) -> &ConvKernel {
        &self.kernel
    }

    /// Worker count the plan will use (0 in options means auto).
    pub fn effective_threads(&self) -> usize {
        // Tiny grids: thread spawn overhead dominates the whole pipeline.
        if self.freqs() < 64 {
            return 1;
        }
        super::resolve_threads(self.threads).min(self.nc.max(1))
    }

    /// Check a workspace out of the plan's pool (or build a fresh one if all
    /// are in use). Return it with [`Self::restore`] so later executions and
    /// other workers — including other plans sharing the pool — can reuse
    /// the buffers.
    pub fn checkout(&self) -> Workspace {
        self.pool.checkout()
    }

    /// Return a checked-out workspace to the pool.
    pub fn restore(&self, ws: Workspace) {
        self.pool.restore(ws);
    }

    /// The workspace pool this plan draws from (shared across a
    /// [`super::ModelPlan`] group, private otherwise).
    pub fn workspace_pool(&self) -> &Arc<WorkspacePool> {
        &self.pool
    }

    /// Fill `ws.block` with the symbol at coarse frequency `(ki, kj)`:
    /// the `c_out×c_in` symbol for stride 1, the horizontal concatenation
    /// `(1/s)·[A_{k_00} | … | A_{k_(s-1)(s-1)}]` for stride `s`. Uses only
    /// the precomputed phase tables — no trig, no allocation.
    fn fill_block(&self, ki: usize, kj: usize, ws: &mut Workspace) {
        let (kh, kw) = (self.kernel.kh, self.kernel.kw);
        let (cout, cin) = (self.kernel.c_out, self.kernel.c_in);
        let s = self.stride;
        let ntaps = kh * kw;
        let inv_s = 1.0 / s as f64;
        for a in 0..s {
            for b in 0..s {
                // Fine frequency this sub-block aliases from.
                let fi = ki + a * self.nc;
                let fj = kj + b * self.mc;
                // Combine the two 1-D tables into per-tap phases.
                for r in 0..kh {
                    let pyr = self.py[r * self.n + fi];
                    for c in 0..kw {
                        ws.tap_phase[r * kw + c] = pyr * self.px[c * self.m + fj];
                    }
                }
                // Contract taps against the OIHW weight tensor; taps are the
                // innermost stride, so each (o, i) pair's weights are
                // contiguous.
                let col0 = (a * s + b) * cin;
                for o in 0..cout {
                    for i in 0..cin {
                        let p = o * cin + i;
                        let w = &self.kernel.data[p * ntaps..(p + 1) * ntaps];
                        let mut acc = C64::ZERO;
                        for (wv, ph) in w.iter().zip(ws.tap_phase.iter()) {
                            acc.re += wv * ph.re;
                            acc.im += wv * ph.im;
                        }
                        if s > 1 {
                            acc = acc.scale(inv_s);
                        }
                        ws.block[o * self.block_cols + col0 + i] = acc;
                    }
                }
            }
        }
    }

    /// Execute coarse frequency rows `[row_lo, row_hi)` into `out`
    /// (`(row_hi−row_lo)·mc·rank` values, frequency-major, descending per
    /// frequency). Zero heap allocation per frequency.
    pub fn execute_rows(&self, row_lo: usize, row_hi: usize, ws: &mut Workspace, out: &mut [f64]) {
        debug_assert!(row_lo <= row_hi && row_hi <= self.nc);
        debug_assert_eq!(out.len(), (row_hi - row_lo) * self.mc * self.rank);
        let r = self.rank;
        for ki in row_lo..row_hi {
            for kj in 0..self.mc {
                self.fill_block(ki, kj, ws);
                let f = (ki - row_lo) * self.mc + kj;
                let dst = &mut out[f * r..(f + 1) * r];
                ws.solve_block(self.solver, self.block_rows, self.block_cols, dst);
            }
        }
    }

    /// [`Self::execute_rows`] with pool-managed workspace checkout — the
    /// entry point the coordinator's tile workers use against a shared plan.
    pub fn execute_rows_pooled(&self, row_lo: usize, row_hi: usize, out: &mut [f64]) {
        let mut ws = self.checkout();
        self.execute_rows(row_lo, row_hi, &mut ws, out);
        self.restore(ws);
    }

    /// Execute the full dual grid into a caller-provided buffer
    /// (`values_len()` long). After the first call on a plan this performs
    /// no heap allocation in the serial path.
    pub fn execute_into(&self, out: &mut [f64]) {
        self.execute_into_threads(self.effective_threads(), out);
    }

    /// [`Self::execute_into`] with an explicit worker count (0 = auto).
    pub fn execute_into_threads(&self, threads: usize, out: &mut [f64]) {
        assert_eq!(out.len(), self.values_len(), "output buffer length mismatch");
        let threads = super::resolve_threads(threads).min(self.nc.max(1));
        if threads <= 1 || self.nc <= 1 {
            self.execute_rows_pooled(0, self.nc, out);
            return;
        }
        let rows_per = self.nc.div_ceil(threads);
        let row_vals = self.mc * self.rank;
        std::thread::scope(|scope| {
            let mut rest: &mut [f64] = out;
            let mut lo = 0usize;
            while lo < self.nc {
                let hi = (lo + rows_per).min(self.nc);
                let (head, tail) = std::mem::take(&mut rest).split_at_mut((hi - lo) * row_vals);
                rest = tail;
                scope.spawn(move || self.execute_rows_pooled(lo, hi, head));
                lo = hi;
            }
        });
    }

    /// Execute the full dual grid and package the result as a [`Spectrum`].
    pub fn execute(&self) -> Spectrum {
        let mut values = vec![0.0f64; self.values_len()];
        self.execute_into(&mut values);
        Spectrum { n: self.nc, m: self.mc, c_out: self.block_rows, c_in: self.block_cols, values }
    }

    /// Full SVD with per-frequency factors `U_k, Σ_k, V_k` (the factor
    /// matrices are fresh allocations by necessity — they are the output).
    pub fn execute_full(&self) -> FullSvd {
        let freqs = self.freqs();
        let r = self.rank;
        let mut u = Vec::with_capacity(freqs);
        let mut v = Vec::with_capacity(freqs);
        let mut values = vec![0.0f64; freqs * r];
        let mut ws = self.checkout();
        let mut block = CMat::zeros(self.block_rows, self.block_cols);
        for ki in 0..self.nc {
            for kj in 0..self.mc {
                self.fill_block(ki, kj, &mut ws);
                block.data.copy_from_slice(&ws.block);
                let dec = jacobi_svd::svd(&block);
                let f = ki * self.mc + kj;
                values[f * r..(f + 1) * r].copy_from_slice(&dec.s[..r]);
                u.push(dec.u);
                v.push(dec.v);
            }
        }
        self.restore(ws);
        FullSvd {
            n: self.nc,
            m: self.mc,
            c_out: self.block_rows,
            c_in: self.block_cols,
            u,
            sigma: Spectrum {
                n: self.nc,
                m: self.mc,
                c_out: self.block_rows,
                c_in: self.block_cols,
                values,
            },
            v,
        }
    }

    /// Materialize the symbol grid in the plan's layout (stride 1 only) —
    /// the `s_F` stage of the timed Table III/IV pipelines and the input to
    /// spectral-transfer reconstruction.
    pub fn compute_symbols(&self) -> SymbolGrid {
        assert_eq!(self.stride, 1, "symbol grids are only defined for stride 1");
        let (cout, cin) = (self.kernel.c_out, self.kernel.c_in);
        let block_len = cout * cin;
        let mut grid = SymbolGrid::zeros(self.n, self.m, cout, cin, self.layout);
        match self.layout {
            BlockLayout::BlockContiguous => {
                // The grid's buffer is already block-contiguous: fill it
                // directly, sharded over rows.
                let mut data = std::mem::take(&mut grid.data);
                self.symbols_into(&mut data);
                grid.data = data;
            }
            BlockLayout::PlanarStrided => {
                let mut buf = vec![C64::ZERO; self.n * self.m * block_len];
                self.symbols_into(&mut buf);
                scatter_shard(&mut grid, 0, self.n, &buf);
            }
        }
        grid
    }

    /// Fill `out` (`n·m·c_out·c_in` long) with all symbols in
    /// block-contiguous order, sharded across the plan's workers.
    fn symbols_into(&self, out: &mut [C64]) {
        debug_assert_eq!(self.stride, 1);
        let block_len = self.block_rows * self.block_cols;
        let threads = self.effective_threads();
        if threads <= 1 || self.nc <= 1 {
            let mut ws = self.checkout();
            self.symbol_rows(0, self.n, &mut ws, out);
            self.restore(ws);
            return;
        }
        let rows_per = self.n.div_ceil(threads);
        let row_elems = self.m * block_len;
        std::thread::scope(|scope| {
            let mut rest: &mut [C64] = out;
            let mut lo = 0usize;
            while lo < self.n {
                let hi = (lo + rows_per).min(self.n);
                let (head, tail) = std::mem::take(&mut rest).split_at_mut((hi - lo) * row_elems);
                rest = tail;
                scope.spawn(move || {
                    let mut ws = self.checkout();
                    self.symbol_rows(lo, hi, &mut ws, head);
                    self.restore(ws);
                });
                lo = hi;
            }
        });
    }

    /// Symbols for rows `[row_lo, row_hi)`, block-contiguous into `out`.
    fn symbol_rows(&self, row_lo: usize, row_hi: usize, ws: &mut Workspace, out: &mut [C64]) {
        let block_len = self.block_rows * self.block_cols;
        for ki in row_lo..row_hi {
            for kj in 0..self.mc {
                self.fill_block(ki, kj, ws);
                let f = (ki - row_lo) * self.mc + kj;
                out[f * block_len..(f + 1) * block_len].copy_from_slice(&ws.block);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lfa::symbol::symbol_at;
    use crate::numeric::Pcg64;

    fn jacobi_block(b: &CMat) -> Vec<f64> {
        crate::linalg::jacobi_svd::singular_values(b)
    }

    #[test]
    fn plan_matches_per_frequency_reference() {
        let mut rng = Pcg64::seeded(600);
        let k = ConvKernel::random_he(3, 2, 3, 3, &mut rng);
        let (n, m) = (5, 7);
        let plan = SpectralPlan::new(&k, n, m, LfaOptions { threads: 1, ..Default::default() });
        let got = plan.execute();
        for ki in 0..n {
            for kj in 0..m {
                let want = jacobi_block(&symbol_at(&k, n, m, ki, kj));
                let at = got.at(ki * m + kj);
                for (a, b) in want.iter().take(at.len()).zip(at) {
                    assert!((a - b).abs() < 1e-12, "({ki},{kj}): {a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn pool_reuse_is_deterministic() {
        let mut rng = Pcg64::seeded(601);
        let k = ConvKernel::random_he(4, 4, 3, 3, &mut rng);
        let plan = SpectralPlan::new(&k, 8, 8, LfaOptions { threads: 2, ..Default::default() });
        let a = plan.execute();
        let b = plan.execute();
        assert_eq!(a.values, b.values, "repeated execution must be bitwise identical");
    }

    #[test]
    fn shared_pool_plans_agree_with_private_pool_plans() {
        let mut rng = Pcg64::seeded(603);
        let k1 = ConvKernel::random_he(3, 2, 3, 3, &mut rng);
        let k2 = ConvKernel::random_he(3, 2, 3, 3, &mut rng);
        let opts = LfaOptions { threads: 1, ..Default::default() };
        let pool = Arc::new(WorkspacePool::for_block(3, 2, 9));
        let a = SpectralPlan::with_shared_pool(&k1, 6, 6, 1, opts, Arc::clone(&pool));
        let b = SpectralPlan::with_shared_pool(&k2, 4, 8, 1, opts, pool);
        assert_eq!(a.execute().values, SpectralPlan::new(&k1, 6, 6, opts).execute().values);
        assert_eq!(b.execute().values, SpectralPlan::new(&k2, 4, 8, opts).execute().values);
    }

    #[test]
    #[should_panic(expected = "does not cover")]
    fn mismatched_shared_pool_is_rejected() {
        let mut rng = Pcg64::seeded(604);
        let k = ConvKernel::random_he(4, 4, 3, 3, &mut rng);
        let pool = Arc::new(WorkspacePool::for_block(2, 2, 9));
        let _ = SpectralPlan::with_shared_pool(&k, 4, 4, 1, LfaOptions::default(), pool);
    }

    #[test]
    fn materialized_symbols_match_fused_path() {
        let mut rng = Pcg64::seeded(602);
        let k = ConvKernel::random_he(2, 3, 3, 3, &mut rng);
        let plan = SpectralPlan::new(&k, 6, 4, LfaOptions { threads: 1, ..Default::default() });
        let grid = plan.compute_symbols();
        for ki in 0..6 {
            for kj in 0..4 {
                let want = symbol_at(&k, 6, 4, ki, kj);
                let gotb = grid.block(ki * 4 + kj);
                assert!(gotb.max_abs_diff(&want) < 1e-12, "({ki},{kj})");
            }
        }
    }
}
